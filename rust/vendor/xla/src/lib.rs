//! Offline stub of the `xla` crate's PJRT surface.
//!
//! The real `xla` crate links the PJRT CPU runtime, which is not
//! available in this build environment. This stub keeps the crate's
//! PJRT-facing modules ([`runtime`](../autotvm/runtime), the PJRT
//! measurer and the neural cost model) compiling; every runtime entry
//! point reports an "unavailable" error, which the artifact-gated tests
//! and benches already treat as a skip condition.
//!
//! Swap this path dependency for the real crate to run on actual
//! hardware; the API subset below matches it.

use std::fmt;
use std::path::Path;

/// Stub error: everything fails with a clear message.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable (offline stub build — \
         link the real `xla` crate to run on hardware)"
    ))
}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub; construction succeeds so input staging works,
/// execution is what fails).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _shape: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_ok());
    }
}
