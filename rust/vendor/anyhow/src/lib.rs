//! Minimal, API-compatible stand-in for the `anyhow` crate (the build is
//! fully offline, so the real crate is not vendored).
//!
//! Implements the surface this repository uses:
//! * [`Error`] — a message plus an optional cause chain.
//! * [`Result<T>`] — alias defaulting the error type.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * `?`-conversion from any `std::error::Error + Send + Sync + 'static`.
//!
//! Display follows anyhow's convention: `{}` prints the outermost
//! message, `{:#}` prints the whole chain separated by `: `.

use std::error::Error as StdError;
use std::fmt;

/// Error with a context chain (outermost message first).
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), cause: None }
    }

    /// Wrap `self` as the cause of a new outer message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), cause: Some(Box::new(self)) }
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = &self.cause;
        while let Some(c) = cur {
            msgs.push(c.msg.as_str());
            cur = &c.cause;
        }
        msgs.into_iter()
    }

    /// The innermost message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for m in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error`, exactly
// like anyhow — that is what makes this blanket conversion coherent
// next to the language's reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs: Vec<String> = Vec::new();
        let mut cur: Option<&(dyn StdError + 'static)> = Some(&e);
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut err = Error { msg: it.next().unwrap_or_default(), cause: None };
        for m in it {
            err = Error { msg: m, cause: Some(Box::new(err)) };
        }
        err
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error variant of a `Result` or to `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::Error::msg(format!($fmt $(, $arg)*))
    };
    ($e:expr) => {
        $crate::Error::msg(format!("{}", $e))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $fmt:literal $(, $arg:expr)* $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!($fmt $(, $arg)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {}", flag);
        Ok(7)
    }

    #[test]
    fn display_and_chain() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn macros_and_context() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(format!("{e}"), "flag was false");
        let r: Result<()> = Err(anyhow!("x = {}", 3));
        assert_eq!(format!("{}", r.unwrap_err()), "x = 3");
        let o: Option<u32> = None;
        let e = o.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn std_error_conversion_keeps_chain() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        let e = io().unwrap_err().context("loading config");
        assert!(format!("{e:#}").starts_with("loading config: "));
    }

    #[test]
    fn collect_into_result() {
        let ok: Result<Vec<u32>> = (0u32..3).map(Ok).collect();
        assert_eq!(ok.unwrap(), vec![0, 1, 2]);
        let items: Vec<Result<u32>> = vec![Ok(1), Err(anyhow!("boom")), Ok(3)];
        let err: Result<Vec<u32>> = items.into_iter().collect();
        assert!(err.is_err());
    }
}
