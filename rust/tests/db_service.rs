//! Tier-1 tests for the TuningDb service layer: concurrent live
//! streaming from the pipelined tuner, WAL persistence through a tuning
//! run, serial-equivalence with a sink attached, and the end-to-end
//! cross-workload warm-start path (tune task A into the DB, then tune
//! task B warm-started from A's records).

use autotvm::coordinator::experiments::{
    collect_source_db, run_method, run_method_warm, ExpOpts, Method,
};
use autotvm::expr::ops;
use autotvm::gbt::GbtParams;
use autotvm::measure::SimMeasurer;
use autotvm::model::GbtModel;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::sim::devices;
use autotvm::tuner::db::Database;
use autotvm::tuner::pipeline::PipelinedTuner;
use autotvm::tuner::{tune_gbt, tune_gbt_pipelined, DbSink, SaParams, TuneOptions};
use autotvm::workloads;
use std::sync::atomic::{AtomicBool, Ordering};

fn quick(n_trials: usize, batch: usize, seed: u64, depth: usize) -> TuneOptions {
    TuneOptions {
        n_trials,
        batch,
        sa: SaParams { n_chains: 16, n_steps: 30, ..Default::default() },
        seed,
        pipeline_depth: depth,
        ..Default::default()
    }
}

fn exp(trials: usize) -> ExpOpts {
    ExpOpts {
        trials,
        batch: 32,
        sa: SaParams { n_chains: 32, n_steps: 50, ..Default::default() },
        ..Default::default()
    }
}

/// The pipelined tuner streams records into the shared DB while a
/// concurrent reader queries `best_config` and `len`: no lost records,
/// monotone visibility, and the final index agrees with the run.
#[test]
fn concurrent_streaming_no_lost_records() {
    let db = Database::new();
    let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let mut o = quick(96, 16, 5, 3);
    o.sink = Some(DbSink::new(&db, &task, "sim-gpu"));
    let m = SimMeasurer::with_seed(devices::sim_gpu(), 7);
    let params = GbtParams { seed: o.seed, ..Default::default() };
    let stop = AtomicBool::new(false);

    let res = std::thread::scope(|s| {
        let reader_db = db.clone();
        let key = task.key();
        let stop = &stop;
        let reader = s.spawn(move || {
            let mut seen_len = 0usize;
            let mut seen_best = 0.0f64;
            while !stop.load(Ordering::SeqCst) {
                let n = reader_db.len();
                assert!(n >= seen_len, "record count went backwards");
                seen_len = n;
                if let Some((_, g)) = reader_db.best_config(&key, "sim-gpu") {
                    assert!(g >= seen_best, "per-task best went backwards");
                    seen_best = g;
                }
                std::thread::yield_now();
            }
        });
        let mut tuner = PipelinedTuner::new(task.clone(), Box::new(GbtModel::new(params)), o);
        let res = tuner.tune(&m);
        stop.store(true, Ordering::SeqCst);
        reader.join().expect("reader panicked");
        res
    });

    assert_eq!(res.records.len(), 96);
    assert_eq!(db.len(), 96, "streamed records lost");
    // DB shard content matches the run's records exactly, in order
    let recs = db.for_task(&task.key(), "sim-gpu");
    assert_eq!(recs.len(), 96);
    for (a, b) in recs.iter().zip(&res.records) {
        assert_eq!(a.choices, b.entity.choices);
        assert_eq!(a.gflops, b.gflops);
        assert_eq!(a.error, b.error);
    }
    assert_eq!(
        db.best_config(&task.key(), "sim-gpu").map(|(_, g)| g),
        Some(res.best_gflops()),
        "indexed best diverged from the run's best"
    );
}

/// Attaching a live DB sink must not perturb the determinism contract:
/// depth-1 pipelined with a sink still reproduces the serial schedule
/// bit-for-bit.
#[test]
fn depth1_with_live_db_still_matches_serial() {
    let mk = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let base = quick(64, 16, 4, 1);
    let ms = SimMeasurer::with_seed(devices::sim_gpu(), 3);
    let serial = tune_gbt(mk(), &ms, base.clone());

    let db = Database::new();
    let task = mk();
    let mut o = base;
    o.sink = Some(DbSink::new(&db, &task, "sim-gpu"));
    let mp = SimMeasurer::with_seed(devices::sim_gpu(), 3);
    let piped = tune_gbt_pipelined(task, &mp, o);

    assert_eq!(serial.curve, piped.curve, "sink perturbed the schedule");
    assert_eq!(serial.records.len(), piped.records.len());
    for (a, b) in serial.records.iter().zip(&piped.records) {
        assert_eq!(a.entity, b.entity);
        assert_eq!(a.gflops, b.gflops);
    }
    assert_eq!(db.len(), 64);
}

/// A WAL-backed run persists without any explicit save: reopening the
/// file serves the run's best config from the incremental index.
#[test]
fn wal_streamed_run_survives_reopen() {
    let dir = std::env::temp_dir().join("autotvm-db-service");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("wal-stream-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let task = workloads::conv_task(3, TemplateKind::Gpu);
    let dev = devices::sim_gpu();
    let res = {
        let db = Database::open(&path).unwrap();
        let m = SimMeasurer::with_seed(dev.clone(), 5);
        let mut o = quick(48, 16, 2, 2);
        o.sink = Some(DbSink::new(&db, &task, dev.name));
        tune_gbt(task.clone(), &m, o)
    }; // no save() — the WAL is the persistence

    let back = Database::open(&path).unwrap();
    assert_eq!(back.len(), res.records.len());
    let (cfg, g) = back.best_config(&task.key(), dev.name).unwrap();
    assert_eq!(g, res.best_gflops());
    assert_eq!(back.best_config_scan(&task.key(), dev.name).unwrap().1, g);
    // the served config is a real schedule of this task
    assert!(task.lower(&cfg).is_ok());
    let _ = std::fs::remove_file(&path);
}

/// End-to-end transfer path (acceptance): tune source workloads into
/// the DB, then tune a new workload warm-started from their records.
/// At an equal (early-regime) trial budget the warm-started search must
/// do at least as well as the cold start, seed-averaged.
#[test]
fn warm_start_from_db_beats_cold_start_at_equal_budget() {
    let device = devices::sim_gpu();
    // task A (well, two source tasks) → DB, streamed via the sink
    let db = collect_source_db(&[4, 6], TemplateKind::Gpu, &device, 128, 0);
    assert!(!db.is_empty(), "source runs streamed nothing");
    let target = workloads::conv_task(7, TemplateKind::Gpu);

    let mut warm_total = 0.0;
    let mut cold_total = 0.0;
    for seed in 0..3u64 {
        // 64 trials: the early regime where reusing D' must pay off
        let mut o = exp(64);
        o.seed = seed;
        let m = SimMeasurer::with_seed(device.clone(), 900 + seed);
        let warm = run_method_warm(&target, &m, Method::GbtRank, &o, &db, device.name, false)
            .expect("DB holds source records; warm path must engage");
        let m2 = SimMeasurer::with_seed(device.clone(), 900 + seed);
        let cold = run_method(&target, &m2, Method::GbtRank, &o);
        assert_eq!(warm.curve.len(), cold.curve.len(), "unequal trial budgets");
        warm_total += warm.best_gflops();
        cold_total += cold.best_gflops();
    }
    assert!(
        warm_total >= cold_total,
        "warm-start {warm_total:.0} GFLOPS (sum over seeds) fell below cold start \
         {cold_total:.0}"
    );
}

/// The pipelined warm-start path: the epoch-0 snapshot is the global
/// model (first SA round already informed), the run completes its
/// budget, and a fixed seed reproduces it bit-for-bit.
#[test]
fn warm_start_pipelined_is_deterministic() {
    let device = devices::sim_gpu();
    let db = collect_source_db(&[6], TemplateKind::Gpu, &device, 96, 0);
    let target = workloads::conv_task(7, TemplateKind::Gpu);
    let o = exp(64);
    let m = SimMeasurer::with_seed(device.clone(), 42);
    let a = run_method_warm(&target, &m, Method::GbtRank, &o, &db, device.name, true)
        .expect("warm pipelined path");
    assert_eq!(a.curve.len(), 64);
    assert!(a.best_gflops() > 0.0);
    let m2 = SimMeasurer::with_seed(device.clone(), 42);
    let b = run_method_warm(&target, &m2, Method::GbtRank, &o, &db, device.name, true)
        .expect("warm pipelined path");
    assert_eq!(a.curve, b.curve, "warm pipelined run not reproducible");
}

/// Methods without a transfer path decline the warm start instead of
/// silently running cold inside `run_method_warm`.
#[test]
fn warm_start_declines_unsupported_methods() {
    let device = devices::sim_gpu();
    let db = Database::new();
    let target = workloads::conv_task(7, TemplateKind::Gpu);
    let m = SimMeasurer::with_seed(device.clone(), 1);
    let o = exp(32);
    // empty DB: even GBT declines
    assert!(run_method_warm(&target, &m, Method::GbtRank, &o, &db, device.name, false)
        .is_none());
    // black-box baseline: declines regardless of DB content
    let db2 = collect_source_db(&[6], TemplateKind::Gpu, &device, 64, 0);
    assert!(run_method_warm(&target, &m, Method::Random, &o, &db2, device.name, false)
        .is_none());
}
