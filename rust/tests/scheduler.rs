//! Graph-level task scheduler: the end-to-end allocation claims.
//!
//! The allocation *decision* is tested on the deterministic simulated
//! farm ([`TaskCurve`] replay): at equal total budget, gradient
//! allocation must produce end-to-end latency ≤ uniform allocation for
//! ResNet-18 — exactly, every run, with no task starved (ε floor). The
//! *execution* path (incremental serial/pipelined loops + DB streaming
//! + cross-task warm starts) is smoke-tested on real tuning loops at CI
//! budgets.

use autotvm::expr::ops;
use autotvm::measure::SimMeasurer;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::sim::devices::{sim_gpu, TaskCurve};
use autotvm::tuner::db::Database;
use autotvm::tuner::pipeline::PipelinedTuner;
use autotvm::tuner::scheduler::{
    AllocPolicy, CurveExecutor, LoopExecutor, SchedulerOptions, TaskScheduler,
};
use autotvm::tuner::{SaParams, TuneOptions, Tuner};
use autotvm::workloads;

fn small_tune_options(batch: usize, seed: u64) -> TuneOptions {
    TuneOptions {
        batch,
        sa: SaParams { n_chains: 16, n_steps: 25, ..Default::default() },
        seed,
        ..Default::default()
    }
}

fn resnet_scheduler(policy: AllocPolicy, budget: usize, slice: usize) -> TaskScheduler {
    let dev = sim_gpu();
    let fused = workloads::resnet18().fuse();
    TaskScheduler::from_graph(
        &fused,
        &dev,
        TemplateKind::Gpu,
        SchedulerOptions { budget, slice, policy, ..Default::default() },
    )
    .unwrap()
}

fn resnet_curves(sched: &TaskScheduler) -> CurveExecutor {
    let dev = sim_gpu();
    CurveExecutor::new(
        sched.plans().iter().map(|p| TaskCurve::for_task(&p.task, &dev)).collect(),
    )
}

/// The acceptance claim: on the simulated farm, at equal total trial
/// budget, gradient allocation ends at end-to-end ResNet-18 latency ≤
/// uniform allocation, and no task receives zero trials. Deterministic:
/// curves are replayed, not sampled.
#[test]
fn resnet18_gradient_beats_uniform_at_equal_budget() {
    // budget = k × slice × 4: an exact multiple of the slice, two
    // bootstrap slices per task plus headroom for greedy rounds
    let grad_sched = resnet_scheduler(AllocPolicy::Gradient, 1, 8);
    let k = grad_sched.plans().len();
    assert!(k >= 13, "resnet18 should expose at least C1..C12 + dense, got {k}");
    let (slice, budget) = (8usize, k * 8 * 4);

    let grad_sched = grad_sched.with_budget(budget);
    let mut grad_farm = resnet_curves(&grad_sched);
    let grad = grad_sched.run(&mut grad_farm);

    let uni_sched = resnet_scheduler(AllocPolicy::Uniform, budget, slice);
    let mut uni_farm = resnet_curves(&uni_sched);
    let uni = uni_sched.run(&mut uni_farm);

    // equal budgets, fully spent
    assert_eq!(grad.trials.iter().sum::<usize>(), budget);
    assert_eq!(uni.trials.iter().sum::<usize>(), budget);
    // ε floor: nobody starves under either policy
    assert!(grad.trials.iter().all(|&n| n > 0), "{:?}", grad.trials);
    assert!(uni.trials.iter().all(|&n| n > 0), "{:?}", uni.trials);
    // the headline inequality
    assert!(
        grad.est_latency <= uni.est_latency * (1.0 + 1e-12),
        "gradient {:.6}ms should beat uniform {:.6}ms",
        grad.est_latency * 1e3,
        uni.est_latency * 1e3
    );
    // gradient is not uniform in disguise: it reallocates
    assert_ne!(grad.trials, uni.trials);
}

/// The allocator is deterministic: identical runs produce identical
/// allocations and latency estimates.
#[test]
fn scheduler_is_deterministic() {
    let budget = 13 * 8 * 4;
    let a_sched = resnet_scheduler(AllocPolicy::Gradient, budget, 8);
    let mut a_farm = resnet_curves(&a_sched);
    let a = a_sched.run(&mut a_farm);
    let b_sched = resnet_scheduler(AllocPolicy::Gradient, budget, 8);
    let mut b_farm = resnet_curves(&b_sched);
    let b = b_sched.run(&mut b_farm);
    assert_eq!(a.trials, b.trials);
    assert_eq!(a.est_latency, b.est_latency);
    assert_eq!(a.rounds, b.rounds);
}

/// Real execution path: the scheduler drives incremental serial loops
/// over a small network, streaming every trial into the shared DB and
/// warm-starting later tasks from earlier tasks' records.
#[test]
fn loop_executor_tunes_a_graph_with_db_streaming_and_warm_starts() {
    // CPU simulator: no resource-limit errors, so every trial succeeds
    // and the finiteness assertions below are deterministic
    let dev = autotvm::sim::devices::sim_cpu();
    let fused = workloads::dqn().fuse();
    let template = TemplateKind::Cpu;
    let sched = TaskScheduler::from_graph(
        &fused,
        &dev,
        template,
        SchedulerOptions {
            budget: 0, // set below once k is known
            slice: 8,
            policy: AllocPolicy::Gradient,
            ..Default::default()
        },
    )
    .unwrap();
    let k = sched.plans().len();
    assert!(k >= 4, "dqn should expose conv + dense tasks, got {k}");
    let budget = k * 8 * 2;
    let sched = sched.with_budget(budget);
    let tasks: Vec<Task> = sched.plans().iter().map(|p| p.task.clone()).collect();
    let db = Database::new();
    let measurer = SimMeasurer::with_seed(dev.clone(), 42);
    let mut exec = LoopExecutor::new(
        tasks.clone(),
        &measurer,
        db.clone(),
        small_tune_options(8, 5),
        false,
        true,
    );
    let alloc = sched.run(&mut exec);
    // every task received trials, the whole budget was spent
    assert!(alloc.trials.iter().all(|&n| n > 0), "{:?}", alloc.trials);
    assert_eq!(alloc.trials.iter().sum::<usize>(), budget);
    // every trial was streamed into the shared DB, for every task
    assert_eq!(db.len(), budget);
    assert_eq!(db.task_keys(dev.name).len(), k);
    // the DB serves a config for each task and the graph compiles
    for t in &tasks {
        assert!(db.best_config(&t.key(), dev.name).is_some(), "{}", t.key());
    }
    let (secs, _) = fused
        .latency(&dev, template, |t| db.best_config(&t.key(), dev.name).map(|(e, _)| e))
        .unwrap();
    assert!(secs.is_finite() && secs > 0.0);
    // the estimate is consistent with the decomposition identity
    assert!(alloc.est_latency.is_finite());
    assert!(sched.fixed_secs() >= 0.0);
}

/// The pipelined incremental loop works as the scheduler's executor
/// too (explore ∥ measure ∥ refit within each slice).
#[test]
fn loop_executor_pipelined_spends_the_budget() {
    let dev = sim_gpu();
    let tasks = vec![
        Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu),
        Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu),
    ];
    let budget = 2 * 16 * 2;
    let sched = TaskScheduler::for_tasks(
        tasks.clone(),
        SchedulerOptions {
            budget,
            slice: 16,
            policy: AllocPolicy::Gradient,
            ..Default::default()
        },
    );
    let db = Database::new();
    let measurer = SimMeasurer::with_seed(dev, 7);
    let mut exec = LoopExecutor::new(
        tasks,
        &measurer,
        db.clone(),
        small_tune_options(8, 3),
        true, // pipelined slices
        true,
    );
    let alloc = sched.run(&mut exec);
    assert_eq!(alloc.trials.iter().sum::<usize>(), budget);
    assert!(alloc.trials.iter().all(|&n| n > 0));
    assert_eq!(db.len(), budget);
}

/// The incremental contract under the scheduler: a serial run sliced at
/// batch boundaries is bit-identical to the unsliced run (same SA
/// chains, same RNG stream, refit on all of `D`).
#[test]
fn sliced_serial_run_equals_unsliced() {
    let mk_task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let mk_model = || {
        let params = autotvm::gbt::GbtParams { seed: 3, ..Default::default() };
        Box::new(autotvm::model::GbtModel::new(params))
    };
    let mut o = small_tune_options(16, 3);
    o.n_trials = 96;

    let m1 = SimMeasurer::with_seed(sim_gpu(), 11);
    let mut whole = Tuner::new(mk_task(), mk_model(), o.clone());
    let res_whole = whole.tune(&m1);

    let m2 = SimMeasurer::with_seed(sim_gpu(), 11);
    let mut sliced = Tuner::new(mk_task(), mk_model(), o.clone());
    for _ in 0..3 {
        sliced.tune_more(&m2, 32);
    }
    let res_sliced = sliced.result();

    assert_eq!(res_whole.curve, res_sliced.curve);
    assert_eq!(res_whole.best, res_sliced.best);
    assert_eq!(res_whole.records.len(), res_sliced.records.len());
    for (a, b) in res_whole.records.iter().zip(&res_sliced.records) {
        assert_eq!(a.entity, b.entity);
    }
}

/// Depth-1 pipelined slices reproduce the serial sliced schedule
/// exactly (the pipelined determinism contract extends to
/// `tune_more`).
#[test]
fn sliced_pipelined_depth1_equals_serial() {
    let mk_task = || Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
    let mk_model = || {
        let params = autotvm::gbt::GbtParams { seed: 5, ..Default::default() };
        Box::new(autotvm::model::GbtModel::new(params))
    };
    let mut o = small_tune_options(16, 5);
    o.n_trials = 64;
    o.pipeline_depth = 1;

    let dev = autotvm::sim::devices::sim_cpu;
    let m1 = SimMeasurer::with_seed(dev(), 21);
    let mut serial = Tuner::new(mk_task(), mk_model(), o.clone());
    for _ in 0..2 {
        serial.tune_more(&m1, 32);
    }

    let m2 = SimMeasurer::with_seed(dev(), 21);
    let mut piped = PipelinedTuner::new(mk_task(), mk_model(), o.clone());
    for _ in 0..2 {
        piped.tune_more(&m2, 32);
    }

    let a = serial.result();
    let b = piped.result();
    assert_eq!(a.curve, b.curve);
    assert_eq!(a.best, b.best);
}
