//! Tier-1 tests for the asynchronous device-farm measurement service
//! (`measure::service`): bit-for-bit equivalence of the 1-replica
//! service with the direct measurer (serial and depth-1 pipelined),
//! board-fault paths (worker panic mid-job, timeout → retry on another
//! replica, all replicas broken, all replicas flaky), class-aware
//! fault paths on a heterogeneous fleet (sole board of a class
//! degrading then recovering, a whole class suspect while its sibling
//! class keeps serving), backpressure, and multi-replica utilization
//! on a latency farm.

use autotvm::expr::ops;
use autotvm::measure::farm::DeviceFarm;
use autotvm::measure::service::{MeasureService, MeasurerFactory, ServiceOptions};
use autotvm::measure::{MeasureResult, Measurer, SimMeasurer};
use autotvm::schedule::space::ConfigEntity;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::sim::devices::{sim_cpu, sim_gpu};
use autotvm::tuner::{tune_gbt, tune_gbt_pipelined, SaParams, TuneOptions, TuneResult};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn opts(n_trials: usize, batch: usize, seed: u64) -> TuneOptions {
    TuneOptions {
        n_trials,
        batch,
        sa: SaParams { n_chains: 16, n_steps: 25, ..Default::default() },
        seed,
        ..Default::default()
    }
}

fn assert_same_result(a: &TuneResult, b: &TuneResult) {
    assert_eq!(a.curve, b.curve, "best-so-far curves diverged");
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.entity, rb.entity, "measured configs diverged");
        assert_eq!(ra.gflops, rb.gflops);
        assert_eq!(ra.error, rb.error);
    }
    assert_eq!(
        a.best.as_ref().map(|(e, _)| e.clone()),
        b.best.as_ref().map(|(e, _)| e.clone())
    );
}

fn sample_batch(task: &Task, n: usize, seed: u64) -> Vec<ConfigEntity> {
    let mut rng = autotvm::util::Rng::seed_from_u64(seed);
    (0..n).map(|_| task.space.sample(&mut rng)).collect()
}

/// The acceptance proptest: across a sweep of tasks and seeds, the
/// serial loop measured through a 1-replica `MeasureService` is
/// bit-for-bit identical to the same loop over the direct measurer —
/// the service's sequence-ordered dispatch never perturbs a fixed-seed
/// run.
#[test]
fn prop_serial_loop_through_service_equals_direct_measurer() {
    let cases: Vec<(Task, _)> = vec![
        (Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu), sim_gpu()),
        (Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu), sim_gpu()),
        (Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu), sim_cpu()),
        (Task::new(ops::dense(16, 256, 128), TemplateKind::Cpu), sim_cpu()),
    ];
    for (i, (task, dev)) in cases.into_iter().enumerate() {
        let seed = 90 + i as u64;
        let o = opts(32, 8, seed);
        let direct = SimMeasurer::with_seed(dev.clone(), seed);
        let want = tune_gbt(task.clone(), &direct, o.clone());
        let svc = MeasureService::with_defaults(Arc::new(DeviceFarm::new(dev, 1, seed)));
        let got = tune_gbt(task, &svc, o);
        assert_same_result(&want, &got);
    }
}

/// Depth-1 pipelined through the 1-replica service equals the serial
/// loop over the direct measurer — the existing serial/pipelined
/// invariant holds through the new service path too.
#[test]
fn depth1_pipelined_through_service_equals_serial_direct() {
    let task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let mut o = opts(64, 16, 4);
    o.pipeline_depth = 1;
    let direct = SimMeasurer::with_seed(sim_gpu(), 3);
    let serial = tune_gbt(task(), &direct, o.clone());
    let svc = MeasureService::with_defaults(Arc::new(DeviceFarm::new(sim_gpu(), 1, 3)));
    let piped = tune_gbt_pipelined(task(), &svc, o);
    assert_same_result(&serial, &piped);
}

/// Pipelined through a multi-replica service: same budget, valid
/// results, and two identical runs are bit-for-bit equal (deterministic
/// job ordering across replica workers).
#[test]
fn pipelined_through_multi_replica_service_is_deterministic() {
    let task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let o = opts(64, 16, 7);
    let run = || {
        let svc = MeasureService::with_defaults(Arc::new(DeviceFarm::new(sim_gpu(), 4, 11)));
        tune_gbt_pipelined(task(), &svc, o.clone())
    };
    let a = run();
    let b = run();
    assert_same_result(&a, &b);
    assert_eq!(a.curve.len(), 64);
    assert!(a.best_gflops() > 0.0);
}

// ---------------------------------------------------------------------
// Fault paths
// ---------------------------------------------------------------------

/// Measurer that panics on every call (a crashing board).
struct PanicMeasurer;

impl Measurer for PanicMeasurer {
    fn measure(&self, _task: &Task, _batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        panic!("injected board crash");
    }

    fn target(&self) -> String {
        "panic-board".to_string()
    }
}

/// Measurer that sleeps per candidate, then answers (a hung board from
/// the monitor's point of view once the timeout is shorter than the
/// sleep). Reports a recognizable throughput so tests can tell whose
/// answer won.
struct SlowMeasurer {
    delay: Duration,
    gflops: f64,
}

impl Measurer for SlowMeasurer {
    fn measure(&self, _task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        std::thread::sleep(self.delay * batch.len().max(1) as u32);
        batch.iter().map(|_| MeasureResult::ok(self.gflops, 1e-3)).collect()
    }

    fn target(&self) -> String {
        "slow-board".to_string()
    }
}

/// Fast measurer with a recognizable throughput.
struct FastMeasurer {
    gflops: f64,
}

impl Measurer for FastMeasurer {
    fn measure(&self, _task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        batch.iter().map(|_| MeasureResult::ok(self.gflops, 1e-3)).collect()
    }

    fn target(&self) -> String {
        "fast-board".to_string()
    }
}

/// Factory handing each replica a different test measurer.
struct MixedFactory {
    boards: Vec<fn() -> Box<dyn Measurer>>,
}

impl MeasurerFactory for MixedFactory {
    fn make(&self, replica: usize) -> anyhow::Result<Box<dyn Measurer>> {
        Ok((self.boards[replica])())
    }

    fn replicas(&self) -> usize {
        self.boards.len()
    }

    fn board(&self) -> String {
        "test-board".to_string()
    }
}

/// A worker panic mid-job is absorbed: the job retries on the healthy
/// replica and every result comes back valid, the crashing board is
/// struck and eventually quarantined, and nothing hangs or is lost.
#[test]
fn worker_panic_mid_job_is_retried_on_another_replica() {
    let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
    let batch = sample_batch(&task, 12, 1);
    let factory = MixedFactory {
        boards: vec![
            || Box::new(PanicMeasurer),
            || Box::new(FastMeasurer { gflops: 42.0 }),
        ],
    };
    let svc = MeasureService::new(
        Arc::new(factory),
        ServiceOptions { retries: 1, quarantine_after: 2, ..Default::default() },
    );
    let results = svc.measure(&task, &batch);
    assert_eq!(results.len(), 12);
    for r in &results {
        assert!(r.is_ok(), "panic leaked into a result: {:?}", r.error);
        assert_eq!(r.gflops, 42.0, "result must come from the healthy replica");
    }
    let s = svc.stats();
    assert!(s.panics >= 2, "panics not recorded: {s:?}");
    assert!(s.retries >= 2, "no retries recorded: {s:?}");
    assert!(s.quarantined[0], "crashing board never quarantined: {s:?}");
    assert!(!s.quarantined[1]);
    assert_eq!(s.completed, 12);
}

/// Every replica broken: jobs exhaust their retries and complete as
/// error results (never hang), and the farm reports the carnage.
#[test]
fn all_replicas_broken_jobs_complete_as_errors() {
    let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
    let batch = sample_batch(&task, 8, 2);
    let factory = MixedFactory {
        boards: vec![|| Box::new(PanicMeasurer), || Box::new(PanicMeasurer)],
    };
    let svc = MeasureService::new(
        Arc::new(factory),
        ServiceOptions { retries: 1, quarantine_after: 2, ..Default::default() },
    );
    let results = svc.measure(&task, &batch);
    assert_eq!(results.len(), 8);
    for r in &results {
        assert!(!r.is_ok(), "a broken board produced a success");
        let msg = r.error.as_deref().unwrap_or("");
        assert!(msg.contains("board fault"), "unexpected error: {msg}");
    }
    // even with every board quarantined, a further batch still completes
    let more = svc.measure(&task, &sample_batch(&task, 4, 3));
    assert_eq!(more.len(), 4);
    assert!(more.iter().all(|r| !r.is_ok()));
    let s = svc.stats();
    assert_eq!(s.completed, 12);
    assert!(s.quarantined.iter().all(|&q| q), "both boards should be quarantined");
}

/// A job that exceeds the per-job timeout is retried on another replica
/// and succeeds there; the slow board's late answer is discarded.
#[test]
fn timeout_retries_on_another_replica() {
    let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
    let batch = sample_batch(&task, 4, 4);
    let factory = MixedFactory {
        boards: vec![
            || Box::new(SlowMeasurer { delay: Duration::from_millis(400), gflops: 1.0 }),
            || Box::new(FastMeasurer { gflops: 7.0 }),
        ],
    };
    let svc = MeasureService::new(
        Arc::new(factory),
        ServiceOptions {
            timeout: Some(Duration::from_millis(50)),
            retries: 1,
            quarantine_after: 0, // exercise the retry path alone
            ..Default::default()
        },
    );
    let results = svc.measure(&task, &batch);
    assert_eq!(results.len(), 4);
    for r in &results {
        assert!(r.is_ok(), "timeout surfaced as an error: {:?}", r.error);
        assert_eq!(r.gflops, 7.0, "result must come from the fast replica");
    }
    let s = svc.stats();
    // The running job times out; jobs queued behind it on the stalled
    // board are relocated without waiting for their own timeouts.
    assert!(s.timeouts >= 1, "timeouts not recorded: {s:?}");
    assert!(s.retries >= 2, "retry + stall relocation not recorded: {s:?}");
    assert_eq!(s.completed, 4);
}

/// All replicas flaky (injected measurement failures, not crashes): the
/// errors are legitimate results — not retried, recorded as 0-GFLOPS
/// trials — and the tuning loop keeps going, exactly like the paper's
/// farm absorbing board timeouts.
#[test]
fn all_replicas_flaky_tuning_survives() {
    let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
    let farm = DeviceFarm::new(sim_gpu(), 2, 4).with_flakiness(1.0);
    let svc = MeasureService::with_defaults(Arc::new(farm));
    let res = tune_gbt(task, &svc, opts(32, 16, 1));
    assert_eq!(res.records.len(), 32);
    assert!(res.best.is_none(), "a failed trial became best");
    assert!(res.records.iter().all(|r| r.error.is_some() && r.gflops == 0.0));
    let s = svc.stats();
    assert_eq!(s.completed, 32);
    assert_eq!(s.retries, 0, "flaky results must not be retried as board faults");
    assert_eq!(s.panics, 0);
}

/// Partially flaky farm: the loop still improves (mirrors the paper's
/// robustness claim) with the flakiness injected per replica *inside*
/// the service rather than wrapped around a monolithic farm.
#[test]
fn partially_flaky_service_farm_still_improves() {
    let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let farm = DeviceFarm::new(sim_gpu(), 3, 2).with_flakiness(0.2);
    let svc = MeasureService::with_defaults(Arc::new(farm));
    let res = tune_gbt(task, &svc, opts(96, 32, 0));
    assert_eq!(res.curve.len(), 96);
    assert!(res.best_gflops() > 0.0);
    assert!(res.records.iter().any(|r| r.error.is_some()), "no failures recorded");
    assert!(
        res.best_at(96) >= res.best_at(32),
        "search failed to improve under failures"
    );
}

/// Backpressure: a tiny in-flight bound still completes a large batch
/// correctly (submission blocks instead of flooding the farm).
#[test]
fn bounded_inflight_backpressure_completes_batches() {
    let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
    let batch = sample_batch(&task, 32, 6);
    let svc = MeasureService::new(
        Arc::new(DeviceFarm::new(sim_gpu(), 2, 3)),
        ServiceOptions { max_inflight: 4, ..Default::default() },
    );
    let results = svc.measure(&task, &batch);
    assert_eq!(results.len(), 32);
    // same results as an unbounded service (backpressure is invisible
    // to the caller)
    let svc2 = MeasureService::with_defaults(Arc::new(DeviceFarm::new(sim_gpu(), 2, 3)));
    let results2 = svc2.measure(&task, &batch);
    for (a, b) in results.iter().zip(&results2) {
        assert_eq!(a.gflops, b.gflops);
    }
}

/// Concurrent-farm acceptance: a pipelined tune on a 4-replica latency
/// farm must actually use the fleet — average busy replicas measurably
/// above one board's worth.
#[test]
fn latency_farm_utilization_exceeds_one_replica() {
    let task = autotvm::workloads::conv_task(6, TemplateKind::Gpu);
    let farm = DeviceFarm::with_latency(sim_gpu(), 4, 1, Duration::from_millis(5));
    let svc = MeasureService::with_defaults(Arc::new(farm));
    let o = opts(96, 32, 0);
    let res = tune_gbt_pipelined(task, &svc, o);
    assert_eq!(res.curve.len(), 96);
    let s = svc.stats();
    assert_eq!(s.completed, 96);
    assert!(
        s.utilization() > 1.3,
        "farm utilization {:.2} not above one replica ({s:?})",
        s.utilization()
    );
    // round-robin home dispatch spreads jobs across every board
    assert!(s.jobs.iter().all(|&j| j > 0), "idle replica: {:?}", s.jobs);
}

// ---------------------------------------------------------------------
// Class-aware fault paths (heterogeneous fleet)
// ---------------------------------------------------------------------

/// Measurer that faults (panics) while the shared countdown is
/// positive, then recovers and answers with a recognizable throughput.
/// The countdown lives in an `Arc` so it survives the worker rebuilding
/// the measurer after each panic.
struct RecoveringMeasurer {
    fails_left: Arc<AtomicI64>,
    gflops: f64,
}

impl Measurer for RecoveringMeasurer {
    fn measure(&self, _task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        if self.fails_left.fetch_sub(1, Ordering::SeqCst) > 0 {
            panic!("injected recoverable fault");
        }
        batch.iter().map(|_| MeasureResult::ok(self.gflops, 1e-3)).collect()
    }

    fn target(&self) -> String {
        "recovering-board".to_string()
    }
}

/// Board wedged far past any reasonable timeout.
struct HungMeasurer;

impl Measurer for HungMeasurer {
    fn measure(&self, _task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        std::thread::sleep(Duration::from_secs(5));
        batch.iter().map(|_| MeasureResult::ok(1.0, 1e-3)).collect()
    }

    fn target(&self) -> String {
        "hung-board".to_string()
    }
}

/// Two-class heterogeneous test factory: each replica row names its
/// board class (the dispatch target) and builds its own measurer.
struct ClassedFactory {
    boards: Vec<(&'static str, Box<dyn Fn() -> Box<dyn Measurer> + Send + Sync>)>,
}

impl MeasurerFactory for ClassedFactory {
    fn make(&self, replica: usize) -> anyhow::Result<Box<dyn Measurer>> {
        Ok((self.boards[replica].1)())
    }

    fn replicas(&self) -> usize {
        self.boards.len()
    }

    fn board(&self) -> String {
        self.boards[0].0.to_string()
    }

    fn target_of(&self, replica: usize) -> String {
        self.boards[replica].0.to_string()
    }
}

/// The *only* board of a class faults: class-aware dispatch makes
/// route-elsewhere impossible, so jobs must degrade to error results —
/// never deadlock, never leak onto the other class — and once the board
/// answers again the quarantine (a soft preference, not a veto) is
/// readmitted and lifted.
#[test]
fn sole_board_of_class_degrades_then_recovers() {
    let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
    let fails = Arc::new(AtomicI64::new(2));
    let fails_in = fails.clone();
    let factory = ClassedFactory {
        boards: vec![
            (
                "class-a",
                Box::new(move || {
                    Box::new(RecoveringMeasurer { fails_left: fails_in.clone(), gflops: 5.0 })
                }),
            ),
            ("class-b", Box::new(|| Box::new(FastMeasurer { gflops: 9.0 }))),
            ("class-b", Box::new(|| Box::new(FastMeasurer { gflops: 9.0 }))),
        ],
    };
    let svc = MeasureService::new(
        Arc::new(factory),
        ServiceOptions { retries: 1, quarantine_after: 2, ..Default::default() },
    );
    let view = svc.for_target("class-a");
    // Wave 1: the sole class-a board panics both jobs. No other board
    // serves the class, so each job exhausts after its only possible
    // attempt and completes as an error — degraded, not deadlocked.
    let first = view.measure(&task, &sample_batch(&task, 2, 7));
    assert_eq!(first.len(), 2);
    for r in &first {
        assert!(!r.is_ok(), "fault leaked into a success");
        let msg = r.error.as_deref().unwrap_or("");
        assert!(msg.contains("board fault"), "unexpected error: {msg}");
    }
    {
        let s = svc.stats();
        assert!(s.quarantined[0], "sole class board never quarantined: {s:?}");
        assert_eq!(s.jobs_for("class-b"), 0, "class-a jobs leaked onto class-b");
    }
    // Wave 2: the board recovered. Quarantine must readmit the only
    // board serving the class, and its first in-time answer lifts it.
    let second = view.measure(&task, &sample_batch(&task, 4, 8));
    assert_eq!(second.len(), 4);
    for r in &second {
        assert!(r.is_ok(), "recovered board still failing: {:?}", r.error);
        assert_eq!(r.gflops, 5.0, "result must come from the class-a board");
    }
    let s = svc.stats();
    assert!(!s.quarantined[0], "an in-time answer must lift quarantine: {s:?}");
    assert_eq!(s.jobs_for("class-b"), 0, "class-a jobs leaked onto class-b");
    assert_eq!(s.completed, 6);
}

/// Every board of one class suspect (wedged past the timeout): jobs
/// already in flight exhaust as errors, new submissions for that class
/// fail fast naming the unserved target — class-aware dispatch must not
/// route them to the healthy class — and the sibling class keeps
/// serving untouched.
#[test]
fn all_boards_of_class_suspect_fail_fast_other_class_unaffected() {
    let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
    let factory = ClassedFactory {
        boards: vec![
            ("class-hung", Box::new(|| Box::new(HungMeasurer))),
            ("class-hung", Box::new(|| Box::new(HungMeasurer))),
            ("class-live", Box::new(|| Box::new(FastMeasurer { gflops: 9.0 }))),
        ],
    };
    let svc = MeasureService::new(
        Arc::new(factory),
        ServiceOptions {
            timeout: Some(Duration::from_millis(40)),
            retries: 1,
            quarantine_after: 1,
            ..Default::default()
        },
    );
    let hung = svc.for_target("class-hung");
    let first = hung.measure(&task, &sample_batch(&task, 2, 9));
    assert_eq!(first.len(), 2);
    for r in &first {
        assert!(!r.is_ok(), "wedged class produced a success");
        let msg = r.error.as_deref().unwrap_or("");
        assert!(msg.contains("board fault"), "unexpected error: {msg}");
    }
    {
        let s = svc.stats();
        assert!(s.timeouts >= 2, "timeouts not recorded: {s:?}");
    }
    // Both class-hung boards are now suspect: a fresh batch for the
    // class completes immediately as errors naming the unserved target.
    let more = hung.measure(&task, &sample_batch(&task, 3, 10));
    assert_eq!(more.len(), 3);
    for r in &more {
        assert!(!r.is_ok(), "suspect class produced a success");
        let msg = r.error.as_deref().unwrap_or("");
        assert!(
            msg.contains("no responsive board serving class-hung"),
            "unexpected error: {msg}"
        );
    }
    // The healthy class is untouched by its sibling class's collapse.
    let live = svc.for_target("class-live");
    let ok = live.measure(&task, &sample_batch(&task, 4, 11));
    assert_eq!(ok.len(), 4);
    for r in &ok {
        assert!(r.is_ok(), "healthy class failed: {:?}", r.error);
        assert_eq!(r.gflops, 9.0, "result must come from the class-live board");
    }
    let s = svc.stats();
    assert_eq!(s.jobs_for("class-live"), 4);
}
