//! Tier-1 tests for the heterogeneous device fleet and cross-target
//! transfer tier: target-invariance of the `ContextRelation`
//! representation (the property that makes cross-device transfer
//! sound), chaos/equivalence of fixed-seed multi-target tuning under
//! per-class replica counts and RTT skew, the single-class
//! `HeteroFarm` ≡ `DeviceFarm` regression anchor, the headline
//! multi-target-beats-sequential allocation claim on deterministic
//! curve replays, and the CPU-warm-started GPU search reaching the
//! cold-start best in fewer trials.

use autotvm::coordinator::experiments::{
    collect_source_db, run_method, run_method_warm, ExpOpts, Method,
};
use autotvm::features::Representation;
use autotvm::gbt::Objective;
use autotvm::measure::farm::{BoardClass, DeviceFarm, HeteroFarm};
use autotvm::measure::service::MeasureService;
use autotvm::measure::{Measurer, SimMeasurer};
use autotvm::model::TransferModel;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::sim::devices::{self, sim_cpu, sim_gpu, TaskCurve};
use autotvm::tuner::db::{Database, Record};
use autotvm::tuner::scheduler::{
    Allocation, AllocPolicy, CurveExecutor, SchedulerOptions, TaskScheduler,
};
use autotvm::tuner::{tune_gbt, SaParams, TuneOptions};
use autotvm::workloads;
use std::sync::Arc;
use std::time::Duration;

fn opts(n_trials: usize, batch: usize, seed: u64) -> TuneOptions {
    TuneOptions {
        n_trials,
        batch,
        sa: SaParams { n_chains: 16, n_steps: 25, ..Default::default() },
        seed,
        ..Default::default()
    }
}

fn exp(trials: usize, seed: u64) -> ExpOpts {
    ExpOpts {
        trials,
        batch: 32,
        sa: SaParams { n_chains: 32, n_steps: 50, ..Default::default() },
        seed,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------
// Satellite: the invariant representation is target-invariant
// ---------------------------------------------------------------------

/// The property the whole cross-target tier rests on: featurizing the
/// same `(task, config)` records under [`Representation::ContextRelation`]
/// is byte-identical regardless of the target name the records are
/// stamped with — the target never enters featurization. Randomized
/// over tasks of both templates and sampled configs.
#[test]
fn prop_context_relation_featurization_is_target_invariant() {
    let mut rng = autotvm::util::Rng::seed_from_u64(42);
    let tasks: Vec<Task> = vec![
        workloads::conv_task(2, TemplateKind::Cpu),
        workloads::conv_task(6, TemplateKind::Gpu),
        workloads::conv_task(9, TemplateKind::Gpu),
        workloads::matmul_1024_task(TemplateKind::Cpu),
    ];
    for (i, task) in tasks.iter().enumerate() {
        let db_a = Database::new();
        let db_b = Database::new();
        for j in 0..12usize {
            let cfg = task.space.sample(&mut rng);
            let gflops = 1.0 + (i * 12 + j) as f64;
            for (db, target) in [(&db_a, "sim-cpu"), (&db_b, "mali-quad-board")] {
                db.append(Record {
                    task_key: task.key(),
                    target: target.to_string(),
                    choices: cfg.choices.clone(),
                    gflops,
                    seconds: 1e-3,
                    error: None,
                })
                .unwrap();
            }
        }
        let (xa, ya, ga) =
            db_a.to_training(&[task], "sim-cpu", Representation::ContextRelation, usize::MAX);
        let (xb, yb, gb) = db_b.to_training(
            &[task],
            "mali-quad-board",
            Representation::ContextRelation,
            usize::MAX,
        );
        assert!(xa.rows > 0, "no rows featurized for {}", task.key());
        assert_eq!((xa.rows, xa.cols), (xb.rows, xb.cols), "{}", task.key());
        assert_eq!(xa.data, xb.data, "features diverged across targets for {}", task.key());
        assert_eq!(ya, yb, "labels diverged across targets for {}", task.key());
        assert_eq!(ga, gb, "rank groups diverged across targets for {}", task.key());
    }
}

// ---------------------------------------------------------------------
// Chaos / equivalence
// ---------------------------------------------------------------------

/// Regression anchor: a single-class [`HeteroFarm`] behind the service
/// reproduces today's [`DeviceFarm`] tuning results bit-for-bit (class
/// 0 derives the identity board seeds).
#[test]
fn single_class_hetero_farm_matches_device_farm_tuning() {
    let mk = || workloads::conv_task(6, TemplateKind::Gpu);
    let o = opts(48, 16, 3);
    let dsvc = MeasureService::with_defaults(Arc::new(DeviceFarm::new(sim_gpu(), 3, 11)));
    let want = tune_gbt(mk(), &dsvc, o.clone());
    let hsvc = MeasureService::with_defaults(Arc::new(HeteroFarm::new(
        vec![BoardClass::new(sim_gpu(), 3)],
        11,
    )));
    let got = tune_gbt(mk(), &hsvc, o);
    assert_eq!(want.curve, got.curve, "single-class HeteroFarm diverged from DeviceFarm");
    assert_eq!(want.records.len(), got.records.len());
    for (a, b) in want.records.iter().zip(&got.records) {
        assert_eq!(a.entity, b.entity);
        assert_eq!(a.gflops, b.gflops);
        assert_eq!(a.error, b.error);
    }
}

/// One fixed-seed multi-target `tune-graph` run over a two-class
/// `HeteroFarm`, parameterized by per-class replica counts and
/// per-class RTT. Returns the allocation plus every DB shard's records
/// in plan order — the full bit-for-bit artifact.
#[allow(clippy::type_complexity)]
fn multi_target_run(
    replicas: (usize, usize),
    latency_ms: (u64, u64),
) -> (Allocation, Vec<Vec<(Vec<u32>, f64)>>, usize) {
    let devs = [sim_cpu(), sim_gpu()];
    let fused = workloads::dqn().fuse();
    let sched = TaskScheduler::from_graph_multi(
        &fused,
        &devs,
        SchedulerOptions {
            budget: 0,
            slice: 8,
            policy: AllocPolicy::Gradient,
            ..Default::default()
        },
    )
    .unwrap();
    let budget = sched.plans().len() * 8 * 2;
    let sched = sched.with_budget(budget);
    let classes = vec![
        BoardClass::new(sim_cpu(), replicas.0)
            .with_latency(Duration::from_millis(latency_ms.0)),
        BoardClass::new(sim_gpu(), replicas.1)
            .with_latency(Duration::from_millis(latency_ms.1)),
    ];
    let svc = MeasureService::with_defaults(Arc::new(HeteroFarm::new(classes, 5)));
    let views: Vec<_> = devs
        .iter()
        .map(|d| (d.name.to_string(), svc.for_target(d.name)))
        .collect();
    let measurers: Vec<(String, &dyn Measurer)> =
        views.iter().map(|(n, v)| (n.clone(), v as &dyn Measurer)).collect();
    let db = Database::new();
    let alloc = sched.run_tuning_multi(&measurers, &db, opts(512, 8, 5), false, true);
    let recs: Vec<Vec<(Vec<u32>, f64)>> = sched
        .plans()
        .iter()
        .map(|p| {
            let t = p.target.as_deref().expect("multi-target plans carry a target");
            db.for_task(&p.task.key(), t)
                .iter()
                .map(|r| (r.choices.clone(), r.gflops))
                .collect()
        })
        .collect();
    assert_eq!(db.len(), budget, "streamed records lost");
    (alloc, recs, budget)
}

fn assert_same_run(
    a: &(Allocation, Vec<Vec<(Vec<u32>, f64)>>, usize),
    b: &(Allocation, Vec<Vec<(Vec<u32>, f64)>>, usize),
    what: &str,
) {
    assert_eq!(a.0.trials, b.0.trials, "{what}: trial allocation diverged");
    assert_eq!(a.0.secs, b.0.secs, "{what}: per-task bests diverged");
    assert_eq!(a.0.rounds, b.0.rounds, "{what}: round counts diverged");
    assert_eq!(a.0.est_latency, b.0.est_latency, "{what}: latency estimates diverged");
    assert_eq!(a.0.log, b.0.log, "{what}: allocation logs diverged");
    assert_eq!(a.1, b.1, "{what}: measured records diverged");
}

/// The chaos/equivalence claim: a fixed-seed multi-target run is
/// bit-for-bit reproducible, and per-class RTT skew (which shifts every
/// completion time) changes nothing — dispatch is sequence-ordered and
/// board noise streams never see the clock. Checked at one board per
/// class and at asymmetric per-class replica counts.
#[test]
fn multi_target_run_is_bitwise_stable_under_rtt_and_reruns() {
    // run-to-run reproducibility at (1, 1) boards, zero RTT
    let a1 = multi_target_run((1, 1), (0, 0));
    let a2 = multi_target_run((1, 1), (0, 0));
    assert_same_run(&a1, &a2, "rerun at (1,1)");
    // per-class RTT skew is invisible to the results
    let b = multi_target_run((1, 1), (3, 1));
    assert_same_run(&a1, &b, "RTT skew at (1,1)");
    // asymmetric replica counts: RTT skew still invisible
    let c1 = multi_target_run((2, 3), (0, 0));
    let c2 = multi_target_run((2, 3), (5, 2));
    assert_same_run(&c1, &c2, "RTT skew at (2,3)");
    // the budget is fully spent and nobody starves, under every shape
    for (alloc, _, budget) in [&a1, &b, &c1] {
        assert_eq!(alloc.trials.iter().sum::<usize>(), *budget);
        assert!(alloc.trials.iter().all(|&n| n > 0), "{:?}", alloc.trials);
    }
}

// ---------------------------------------------------------------------
// Acceptance: one global budget beats rigid per-target budgets
// ---------------------------------------------------------------------

/// The headline multi-target claim on deterministic curve replays: at
/// equal *total* trial budget, one `from_graph_multi` scheduler
/// spending a single global budget across tasks × targets ends at
/// combined end-to-end latency ≤ two sequential per-target schedulers
/// each given half the budget — the gradient allocator shifts trials
/// toward whichever device's tasks still improve.
#[test]
fn multi_target_beats_sequential_per_target_at_equal_budget() {
    let devs = [sim_cpu(), sim_gpu()];
    let fused = workloads::dqn().fuse();
    let sopts = |budget| SchedulerOptions {
        budget,
        slice: 8,
        policy: AllocPolicy::Gradient,
        ..Default::default()
    };
    let multi = TaskScheduler::from_graph_multi(&fused, &devs, sopts(0)).unwrap();
    let k = multi.plans().len();
    assert!(k >= 8, "two devices of dqn should expose ≥ 8 plans, got {k}");
    let budget = k * 8 * 4;
    let multi = multi.with_budget(budget);
    let mut farm = CurveExecutor::new(
        multi
            .plans()
            .iter()
            .map(|p| {
                let dev = devs
                    .iter()
                    .find(|d| p.target.as_deref() == Some(d.name))
                    .expect("plan target names a fleet device");
                TaskCurve::for_task(&p.task, dev)
            })
            .collect(),
    );
    let alloc = multi.run(&mut farm);
    assert_eq!(alloc.trials.iter().sum::<usize>(), budget);
    assert!(alloc.trials.iter().all(|&n| n > 0), "{:?}", alloc.trials);

    // sequential baseline: one scheduler per device, half the budget each
    let mut seq_total = 0.0;
    for dev in &devs {
        let template = TemplateKind::for_class(dev.class);
        let s = TaskScheduler::from_graph(&fused, dev, template, sopts(budget / 2)).unwrap();
        let mut f = CurveExecutor::new(
            s.plans().iter().map(|p| TaskCurve::for_task(&p.task, dev)).collect(),
        );
        let a = s.run(&mut f);
        assert_eq!(a.trials.iter().sum::<usize>(), budget / 2);
        seq_total += a.est_latency;
    }
    assert!(
        alloc.est_latency <= seq_total * (1.0 + 1e-12),
        "one global budget {:.6}ms should beat rigid per-target halves {:.6}ms",
        alloc.est_latency * 1e3,
        seq_total * 1e3
    );
}

// ---------------------------------------------------------------------
// Acceptance: CPU records warm-start a GPU search
// ---------------------------------------------------------------------

/// Cross-target transfer acceptance: with *only* CPU records in the DB
/// (tier 1 empty — the old single-tier warm start returned `None`
/// here), the tiered warm start engages through the cross-target tier,
/// and the warm-started GPU search reaches the cold start's best in
/// fewer trials, summed over fixed seeds.
#[test]
fn cpu_records_warm_start_gpu_search_in_fewer_trials() {
    let cpu = sim_cpu();
    let gpu = sim_gpu();
    let db = collect_source_db(&[6], TemplateKind::Cpu, &cpu, 128, 0);
    assert!(!db.is_empty(), "source run streamed nothing");
    assert!(db.task_keys(gpu.name).is_empty(), "DB must hold no same-target rows");
    let target_task = workloads::conv_task(6, TemplateKind::Gpu);

    // the tier API itself: provenance must show a pure tier-2 build
    let candidates =
        vec![workloads::conv_task(6, TemplateKind::Cpu), target_task.clone()];
    let (_model, stats) = TransferModel::warm_start_tiered(
        &db,
        &candidates,
        &target_task,
        gpu.name,
        Objective::Rank,
        0,
    )
    .expect("cross-target records must engage the tiered warm start");
    assert_eq!(stats.same_target_rows, 0);
    assert!(stats.used_cross_target(), "{stats:?}");
    assert_eq!(stats.cross_targets, vec![cpu.name.to_string()], "{stats:?}");

    // the search-level claim, seed-summed: trials to reach the cold
    // best (never reaching counts as budget + cold's own)
    let mut warm_sum = 0usize;
    let mut cold_sum = 0usize;
    let mut reached = 0usize;
    for seed in 0..3u64 {
        let o = exp(64, seed);
        let m = SimMeasurer::with_seed(gpu.clone(), 700 + seed);
        let warm = run_method_warm(&target_task, &m, Method::GbtRank, &o, &db, gpu.name, false)
            .expect("CPU records must engage the warm path for a GPU search");
        let m2 = SimMeasurer::with_seed(gpu.clone(), 700 + seed);
        let cold = run_method(&target_task, &m2, Method::GbtRank, &o);
        assert_eq!(warm.curve.len(), cold.curve.len(), "unequal trial budgets");
        let cold_best = cold.best_gflops();
        let tc = cold.trials_to_reach(cold_best).expect("cold run reaches its own best");
        let tw = warm.trials_to_reach(cold_best);
        if tw.is_some() {
            reached += 1;
        }
        warm_sum += tw.unwrap_or(o.trials + tc);
        cold_sum += tc;
    }
    assert!(reached >= 2, "warm start reached the cold best in only {reached}/3 seeds");
    assert!(
        warm_sum < cold_sum,
        "warm start took {warm_sum} trials (sum over seeds) to reach the cold best vs \
         {cold_sum} cold"
    );
}

// keep the namespace import exercised even if device lists change shape
#[test]
fn fleet_devices_resolve_by_name() {
    for name in ["sim-cpu", "sim-gpu"] {
        assert!(devices::by_name(name).is_some(), "{name} must resolve");
    }
}
