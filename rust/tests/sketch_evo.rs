//! Sketch-space containment and evolutionary-search acceptance tests
//! (ISSUE 10 / ROADMAP item 3).
//!
//! * `sketch_contains_template_*`: the generated sketch space strictly
//!   contains the hand template — every template config maps (via
//!   [`embed_template_config`]) to a sketch config with the *identical*
//!   lowered `Schedule`, and the sketch space is strictly larger.
//! * `evo_matches_or_beats_sa_*`: at an equal measurement-trial budget
//!   on the deterministic simulator, the model-guided evolutionary
//!   refiner is no worse than parallel SA, summed over seeds (the
//!   seed-summing idiom of the hetero-fleet tests damps per-seed noise).
//!
//! [`embed_template_config`]: autotvm::schedule::sketch::embed_template_config

use autotvm::explore::{EvoParams, SaParams, SearchKind};
use autotvm::expr::ops;
use autotvm::measure::SimMeasurer;
use autotvm::schedule::sketch::embed_template_config;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::sim::devices;
use autotvm::tuner::{tune_gbt, TuneOptions};
use autotvm::util::Rng;
use autotvm::workloads;

/// Assert the containment guarantee for one task pair: sampled template
/// configs (plus the index-space corners) embed into the sketch space
/// with bit-identical schedules, and the sketch space is strictly
/// larger than the template's.
fn assert_contains(tpl: Task, samples: usize, seed: u64) {
    let skt = Task::with_sketches(tpl.def.clone(), tpl.template);
    assert!(
        skt.space.size() > tpl.space.size(),
        "sketch space {} not strictly larger than template space {}",
        skt.space.size(),
        tpl.space.size()
    );
    let mut rng = Rng::seed_from_u64(seed);
    let mut configs: Vec<_> = (0..samples).map(|_| tpl.space.sample(&mut rng)).collect();
    configs.push(tpl.space.entity(0));
    configs.push(tpl.space.entity(tpl.space.size() - 1));
    for e in &configs {
        let emb = embed_template_config(&tpl, &skt, e);
        assert_eq!(
            skt.schedule(&emb),
            tpl.schedule(e),
            "embedded schedule differs for template config {e:?}"
        );
    }
}

#[test]
fn sketch_contains_template_conv2d() {
    for t in [TemplateKind::Gpu, TemplateKind::Cpu] {
        assert_contains(workloads::conv_task(6, t), 50, 0xC6);
    }
}

#[test]
fn sketch_contains_template_matmul() {
    for t in [TemplateKind::Gpu, TemplateKind::Cpu] {
        assert_contains(Task::new(ops::matmul(128, 128, 128), t), 50, 0x88);
    }
}

/// One tuning run at a fixed measurement budget; only the exploration
/// strategy differs between the SA and evo arms.
fn run(search: SearchKind, seed: u64) -> f64 {
    let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let m = SimMeasurer::with_seed(devices::sim_gpu(), seed);
    let o = TuneOptions {
        n_trials: 96,
        batch: 16,
        seed,
        search,
        sa: SaParams { n_chains: 16, n_steps: 40, ..Default::default() },
        evo: EvoParams { population: 64, generations: 20, ..Default::default() },
        ..Default::default()
    };
    let res = tune_gbt(task, &m, o);
    assert_eq!(res.curve.len(), 96);
    res.best_gflops()
}

#[test]
fn evo_matches_or_beats_sa_at_equal_trial_budget() {
    let mut sa_sum = 0.0;
    let mut evo_sum = 0.0;
    for seed in [11u64, 23, 37] {
        sa_sum += run(SearchKind::Sa, seed);
        evo_sum += run(SearchKind::Evo, seed);
    }
    assert!(sa_sum > 0.0 && evo_sum > 0.0);
    assert!(
        evo_sum >= sa_sum - 1e-9,
        "evolutionary refiner ({evo_sum:.2} summed GFLOPS) lost to SA ({sa_sum:.2})"
    );
}
