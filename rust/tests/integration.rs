//! Cross-module integration tests: full tuning flows on every device,
//! database persistence through the tuner, graph compilation, CLI.

use autotvm::explore::SaParams;
use autotvm::measure::SimMeasurer;
use autotvm::schedule::template::TemplateKind;
use autotvm::sim::devices;
use autotvm::tuner::db::Database;
use autotvm::tuner::{tune_gbt, TuneOptions};
use autotvm::workloads;

fn quick_opts(n: usize, seed: u64) -> TuneOptions {
    TuneOptions {
        n_trials: n,
        batch: 16,
        sa: SaParams { n_chains: 16, n_steps: 30, ..Default::default() },
        seed,
        ..Default::default()
    }
}

#[test]
fn tune_c6_on_every_device() {
    for (dev, template) in [
        (devices::sim_gpu(), TemplateKind::Gpu),
        (devices::sim_cpu(), TemplateKind::Cpu),
        (devices::sim_mali(), TemplateKind::Gpu),
    ] {
        let task = workloads::conv_task(6, template);
        let m = SimMeasurer::with_seed(dev.clone(), 11);
        let res = tune_gbt(task, &m, quick_opts(64, 1));
        assert!(
            res.best_gflops() > 0.0,
            "{}: no valid schedule found",
            dev.name
        );
        // sanity: below device peak
        let peak = dev.max_concurrency * dev.flops_per_cycle * dev.clock_ghz;
        assert!(res.best_gflops() < peak, "{}: above peak", dev.name);
    }
}

#[test]
fn database_roundtrip_through_tuner() {
    let task = workloads::conv_task(3, TemplateKind::Gpu);
    let dev = devices::sim_gpu();
    let m = SimMeasurer::with_seed(dev.clone(), 5);
    let res = tune_gbt(task.clone(), &m, quick_opts(48, 2));
    let db = Database::new();
    db.add_run(&task, dev.name, &res.records).unwrap();
    let dir = std::env::temp_dir().join("autotvm-int-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("db.jsonl");
    db.save(&path).unwrap();
    let back = Database::load(&path).unwrap();
    assert_eq!(back.len(), res.records.len());
    // best config must re-lower and re-evaluate to the recorded gflops
    let (cfg, gflops) = back.best_config(&task.key(), dev.name).unwrap();
    let prog = task.lower(&cfg).unwrap();
    let r = dev.evaluate(&prog).unwrap();
    // recorded value includes noise; evaluate() is noise-free
    assert!((r.gflops / gflops).ln().abs() < 0.5, "{} vs {gflops}", r.gflops);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn resnet_e2e_autotvm_beats_vendor_baseline() {
    // miniature Fig-11 flow on DQN (smallest net) for test speed
    let dev = devices::sim_gpu();
    let graph = workloads::dqn();
    let (base, _) = graph
        .latency(&dev, TemplateKind::Gpu, |t| Some(autotvm::baselines::vendor_config(t)))
        .unwrap();
    let fused = graph.fuse();
    let m = SimMeasurer::with_seed(dev.clone(), 9);
    let tuned =
        autotvm::graph::tune_graph_tasks(&fused, TemplateKind::Gpu, &m, quick_opts(96, 3));
    let (auto_s, _) = fused
        .latency(&dev, TemplateKind::Gpu, |t| tuned.get(&t.key()).cloned())
        .unwrap();
    assert!(
        auto_s < base,
        "AutoTVM {:.3}ms should beat baseline {:.3}ms",
        auto_s * 1e3,
        base * 1e3
    );
}

#[test]
fn all_networks_compile_and_report_latency() {
    let dev = devices::sim_cpu();
    for g in workloads::all_networks() {
        let (secs, breakdown) = g
            .latency(&dev, TemplateKind::Cpu, |t| Some(autotvm::baselines::vendor_config(t)))
            .unwrap();
        assert!(secs.is_finite() && secs > 0.0, "{}", g.name);
        assert!(!breakdown.is_empty());
    }
}

#[test]
fn cli_smoke() {
    autotvm::coordinator::run(&["table1".to_string()]).unwrap();
    let argv: Vec<String> = [
        "tune", "--workload", "C3", "--device", "sim-cpu", "--trials", "32",
        "--method", "random",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    autotvm::coordinator::run(&argv).unwrap();
    assert!(autotvm::coordinator::run(&["nope".to_string()]).is_err());
}

#[test]
fn neural_tuning_loop_if_artifacts_present() {
    if !autotvm::runtime::artifacts_dir().join("costmodel_meta.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    use autotvm::coordinator::experiments::{run_method, ExpOpts, Method};
    let task = workloads::conv_task(3, TemplateKind::Gpu);
    let m = SimMeasurer::with_seed(devices::sim_gpu(), 21);
    let opts = ExpOpts {
        trials: 64,
        batch: 32,
        sa: SaParams { n_chains: 16, n_steps: 25, ..Default::default() },
        ..Default::default()
    };
    let res = run_method(&task, &m, Method::NeuralRank, &opts);
    assert!(res.best_gflops() > 0.0, "neural tuner found nothing");
    assert_eq!(res.curve.len(), 64);
}
