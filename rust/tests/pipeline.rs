//! Tier-1 tests for the pipelined tuning loop (`tuner::pipeline`):
//! determinism under a fixed seed, bounded-channel backpressure, clean
//! shutdown with no lost trial records, failure robustness behind a
//! flaky device farm, and exact serial equivalence at depth 1.

use autotvm::expr::ops;
use autotvm::gbt::GbtParams;
use autotvm::measure::farm::{DeviceFarm, FlakyMeasurer};
use autotvm::measure::SimMeasurer;
use autotvm::model::GbtModel;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::sim::devices::{sim_cpu, sim_gpu};
use autotvm::tuner::pipeline::PipelinedTuner;
use autotvm::tuner::{tune_gbt, tune_gbt_pipelined, SaParams, TuneOptions, TuneResult};
use std::time::Duration;

fn opts(n_trials: usize, batch: usize, seed: u64, depth: usize) -> TuneOptions {
    TuneOptions {
        n_trials,
        batch,
        sa: SaParams { n_chains: 16, n_steps: 30, ..Default::default() },
        seed,
        pipeline_depth: depth,
        ..Default::default()
    }
}

fn assert_same_result(a: &TuneResult, b: &TuneResult) {
    assert_eq!(a.curve, b.curve, "best-so-far curves diverged");
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.entity, rb.entity, "measured configs diverged");
        assert_eq!(ra.gflops, rb.gflops);
        assert_eq!(ra.error, rb.error);
    }
    assert_eq!(
        a.best.as_ref().map(|(e, _)| e.clone()),
        b.best.as_ref().map(|(e, _)| e.clone())
    );
}

/// A fixed seed reproduces the pipelined run bit-for-bit even though
/// the three stages race in wall-clock time.
#[test]
fn pipelined_deterministic_under_fixed_seed() {
    let task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    for depth in [2, 3] {
        let o = opts(80, 16, 9, depth);
        let m1 = SimMeasurer::with_seed(sim_gpu(), 7);
        let r1 = tune_gbt_pipelined(task(), &m1, o.clone());
        let m2 = SimMeasurer::with_seed(sim_gpu(), 7);
        let r2 = tune_gbt_pipelined(task(), &m2, o);
        assert_same_result(&r1, &r2);
        assert_eq!(r1.curve.len(), 80);
        assert!(r1.best_gflops() > 0.0);
    }
}

/// Depth 1 forces lockstep: the pipelined loop must reproduce the
/// serial Algorithm-1 schedule exactly (same model epochs, same RNG
/// streams, same measurements).
#[test]
fn depth1_pipelined_equals_serial() {
    let task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let o = opts(64, 16, 4, 1);
    let ms = SimMeasurer::with_seed(sim_gpu(), 3);
    let serial = tune_gbt(task(), &ms, o.clone());
    let mp = SimMeasurer::with_seed(sim_gpu(), 3);
    let piped = tune_gbt_pipelined(task(), &mp, o);
    assert_same_result(&serial, &piped);
}

/// Proposals never outrun measurement by more than the configured
/// depth — even when measurement is slow enough that the proposal stage
/// could sprint far ahead.
#[test]
fn pipelined_backpressure_bounded_by_depth() {
    let task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    for depth in [1usize, 2, 3] {
        let o = opts(96, 16, 1, depth);
        let farm = DeviceFarm::with_latency(sim_gpu(), 4, 2, Duration::from_millis(1));
        let params = GbtParams { seed: o.seed, ..Default::default() };
        let mut tuner = PipelinedTuner::new(task(), Box::new(GbtModel::new(params)), o);
        let res = tuner.tune(&farm);
        let stats = tuner.stats();
        assert_eq!(res.curve.len(), 96);
        assert_eq!(stats.measured_batches(), 6, "96 trials / batch 16");
        assert_eq!(stats.proposed_batches(), 6);
        assert_eq!(stats.fitted_epochs(), 6, "model refits once per batch");
        assert!(
            stats.max_lead() >= 1 && stats.max_lead() <= depth,
            "depth {depth}: observed lead {} outside [1, {depth}]",
            stats.max_lead()
        );
    }
}

/// Uneven trial budgets shut the stages down cleanly: every proposed
/// and measured trial is accounted, none lost, none duplicated.
#[test]
fn pipelined_clean_shutdown_no_lost_records() {
    let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
    // 50 = 3 full batches of 16 + a final batch of 2
    let o = opts(50, 16, 5, 2);
    let m = SimMeasurer::with_seed(sim_cpu(), 11);
    let res = tune_gbt_pipelined(task, &m, o);
    assert_eq!(res.records.len(), 50);
    assert_eq!(res.curve.len(), 50);
    let mut uniq = std::collections::HashSet::new();
    for r in &res.records {
        assert!(uniq.insert(r.entity.clone()), "config measured twice");
    }
}

/// A tiny config space exhausts before the budget: the pipeline must
/// terminate (no deadlocked stage) with every measured trial recorded
/// at most once.
#[test]
fn pipelined_space_exhaustion_terminates() {
    // matmul 2×2×2 on the GPU template: |S_e| = 3·3·2·4·2 = 144
    let task = Task::new(ops::matmul(2, 2, 2), TemplateKind::Gpu);
    let size = task.space.size() as usize;
    let o = opts(size + 16, 8, 2, 2);
    let m = SimMeasurer::with_seed(sim_gpu(), 13);
    let res = tune_gbt_pipelined(task, &m, o);
    assert!(!res.records.is_empty());
    assert!(res.records.len() <= size, "{} measured > |S_e| = {size}", res.records.len());
    let mut uniq = std::collections::HashSet::new();
    for r in &res.records {
        assert!(uniq.insert(r.entity.clone()), "config measured twice");
    }
}

/// Board flakiness (timeouts / build errors) injected around the farm
/// must not deadlock any stage; failures are recorded as 0-GFLOPS
/// trials and the search keeps improving.
#[test]
fn pipelined_absorbs_flaky_farm() {
    let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let farm = DeviceFarm::new(sim_gpu(), 3, 2);
    let flaky = FlakyMeasurer::new(farm, 0.25, 3);
    let o = opts(96, 32, 0, 2);
    let res = tune_gbt_pipelined(task, &flaky, o);
    assert_eq!(res.curve.len(), 96, "flaky farm stalled the pipeline");
    assert!(res.best_gflops() > 0.0);
    assert!(res.records.iter().any(|r| r.error.is_some()), "no failures recorded");
    for w in res.curve.windows(2) {
        assert!(w[1] >= w[0], "curve must stay monotone under failures");
    }
    assert!(
        res.best_at(96) >= res.best_at(32),
        "search failed to improve under failures"
    );
}

/// `best_gflops` ignores failed trials entirely: with a 100% failure
/// rate there is no best config and the curve stays at zero.
#[test]
fn pipelined_all_failures_yield_no_best() {
    let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
    let farm = DeviceFarm::new(sim_gpu(), 2, 4);
    let flaky = FlakyMeasurer::new(farm, 1.0, 5);
    let o = opts(32, 16, 1, 2);
    let res = tune_gbt_pipelined(task, &flaky, o);
    assert_eq!(res.records.len(), 32);
    assert!(res.best.is_none(), "a failed trial became best");
    assert_eq!(res.best_gflops(), 0.0);
    assert!(res.curve.iter().all(|&g| g == 0.0));
    assert!(res.records.iter().all(|r| r.error.is_some() && r.gflops == 0.0));
}

/// The pipelined loop on a ≥4-replica farm completes the same budget
/// as the serial loop and, with per-board latency to hide, does not
/// regress wall-clock (the bench asserts the actual speedup; here we
/// only guard the contract cheaply enough for CI).
#[test]
fn pipelined_farm_matches_serial_budget() {
    let task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let o = opts(96, 32, 6, 2);
    let serial_farm = DeviceFarm::with_latency(sim_gpu(), 4, 8, Duration::from_millis(1));
    let serial = tune_gbt(task(), &serial_farm, o.clone());
    let piped_farm = DeviceFarm::with_latency(sim_gpu(), 4, 8, Duration::from_millis(1));
    let piped = tune_gbt_pipelined(task(), &piped_farm, o);
    assert_eq!(serial.curve.len(), piped.curve.len());
    assert!(piped.best_gflops() > 0.0);
}
