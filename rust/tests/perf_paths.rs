//! Hot-path equivalence properties (own mini-harness; proptest is not
//! vendored): the compiled [`PredictPlan`] must be bit-identical to the
//! scalar tree walk on random models and batches (including rows with
//! out-of-range and non-finite values), incremental SA featurization
//! must equal fresh extraction, structure-cached delta analysis must
//! equal fresh `analyze` over random templates × knob-mutation chains
//! (including structure-changing knobs, which must take the full
//! lower+analyze path via a new donor entry), and fixed-seed tuning
//! runs must be bit-for-bit unchanged by the fast paths (under every
//! representation), by a capped feature row cache, and by mid-tune WAL
//! auto-compaction.
//!
//! [`PredictPlan`]: autotvm::gbt::PredictPlan

use autotvm::explore::SaParams;
use autotvm::gbt::{Gbt, GbtParams, Matrix, Objective};
use autotvm::measure::SimMeasurer;
use autotvm::schedule::template::TemplateKind;
use autotvm::tuner::db::Database;
use autotvm::tuner::{tune_gbt, DbSink, Featurizer, TuneOptions, TuneResult};
use autotvm::util::Rng;
use autotvm::workloads;

/// Mini property harness: run `f` over `n` seeded cases, reporting the
/// failing seed through the assertion messages.
fn forall(n: u64, mut f: impl FnMut(&mut Rng, u64)) {
    for seed in 0..n {
        let mut rng = Rng::seed_from_u64(seed * 6151 + 29);
        f(&mut rng, seed);
    }
}

/// Random training matrix with values in `[0, 1)`.
fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    let data: Vec<f32> = (0..rows * cols).map(|_| rng.gen_f64() as f32).collect();
    Matrix::new(rows, cols, data)
}

/// Query batch that deliberately strays outside the training range:
/// negatives, huge magnitudes, infinities and NaNs — the plan's binning
/// must route all of them exactly like the scalar comparisons do.
fn hostile_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| match rng.gen_range(0..8) {
            0 => -(rng.gen_f64() as f32) * 100.0,
            1 => rng.gen_f64() as f32 * 1e6,
            2 => f32::INFINITY,
            3 => f32::NEG_INFINITY,
            4 => f32::NAN,
            _ => rng.gen_f64() as f32,
        })
        .collect();
    Matrix::new(rows, cols, data)
}

#[test]
fn prop_plan_bitmatches_scalar_walk() {
    forall(24, |rng, seed| {
        let cols = 2 + rng.gen_range(0..30);
        let n = 40 + rng.gen_range(0..300);
        let x = random_matrix(rng, n, cols);
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let r = x.row(i);
                r[0] as f64 * 3.0 - r[cols / 2] as f64 + (r[cols - 1] as f64).abs()
            })
            .collect();
        let params = GbtParams {
            objective: if rng.gen_bool(0.5) { Objective::Rank } else { Objective::Regression },
            n_trees: 1 + rng.gen_range(0..40),
            max_depth: 1 + rng.gen_range(0..7),
            seed,
            ..Default::default()
        };
        let model = Gbt::train(&x, &y, &[], params);
        let plan = model.compile();

        // in-range, out-of-range, empty and single-row batches
        let batches = [
            random_matrix(rng, 1 + rng.gen_range(0..200), cols),
            hostile_matrix(rng, 1 + rng.gen_range(0..64), cols),
            Matrix::new(0, cols, Vec::new()),
            random_matrix(rng, 1, cols),
        ];
        for (bi, q) in batches.iter().enumerate() {
            let scalar = model.predict_batch(q);
            let planned = plan.predict_batch(q);
            assert_eq!(scalar.len(), planned.len(), "seed {seed} batch {bi}");
            for (i, (a, b)) in scalar.iter().zip(&planned).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "seed {seed} batch {bi} row {i}: plan {b} != scalar {a}"
                );
            }
            // single-row entry point agrees too
            for i in 0..q.rows.min(4) {
                assert_eq!(
                    model.predict(q.row(i)).to_bits(),
                    plan.predict(q.row(i)).to_bits(),
                    "seed {seed} batch {bi}: single-row predict diverged"
                );
            }
        }
    });
}

#[test]
fn prop_incremental_featurization_matches_fresh() {
    use autotvm::features::Representation;
    forall(12, |rng, seed| {
        let wl = 1 + (seed as usize % 12);
        let template = if rng.gen_bool(0.5) { TemplateKind::Gpu } else { TemplateKind::Cpu };
        let task = workloads::conv_task(wl, template);
        let parents: Vec<_> = (0..24).map(|_| task.space.sample(rng)).collect();
        let mut knobs = Vec::new();
        let proposals: Vec<_> = parents
            .iter()
            .map(|p| {
                let (n, j) = task.space.mutate_knob(p, rng);
                knobs.push(j);
                n
            })
            .collect();

        // warm cache: parents featurized once, then per-knob updates
        let warm = Featurizer::new(Representation::Config);
        warm.features(&task, &parents);
        let inc = warm
            .neighbor_features(&task, &parents, &proposals, &knobs)
            .expect("parents are cached, Config repr is incremental");
        // reference: full extraction with the fast paths off
        let fresh =
            Featurizer::with_fast(Representation::Config, false).features(&task, &proposals);
        assert_eq!(inc.rows, fresh.rows, "seed {seed}");
        assert_eq!(inc.cols, fresh.cols, "seed {seed}");
        for i in 0..inc.rows {
            assert_eq!(
                inc.row(i),
                fresh.row(i),
                "seed {seed} row {i} (knob {}): incremental row diverged",
                knobs[i]
            );
        }

        // a cold featurizer has no parent rows to patch: must decline
        let cold = Featurizer::new(Representation::Config);
        assert!(cold.neighbor_features(&task, &parents, &proposals, &knobs).is_none());
    });
}

/// Delta analysis (donor replay per structure) must be bit-identical to
/// a fresh lower+analyze at every step of a random knob-mutation chain.
/// Every call resolves to exactly one of: a new donor entry (first
/// sighting of a structure key — the full path), a delta replay, or a
/// recipe-less fallback (also the full path), so the counters must
/// account for the whole chain.
#[test]
fn prop_delta_analysis_matches_fresh() {
    use autotvm::ast::analysis::{analyze, ProgramAnalysis, StructureCache};
    let mut total_delta_hits = 0u64;
    forall(10, |rng, seed| {
        let wl = 1 + (seed as usize % 12);
        let template = if rng.gen_bool(0.5) { TemplateKind::Gpu } else { TemplateKind::Cpu };
        let task = workloads::conv_task(wl, template);
        let mut cache = StructureCache::new();
        let mut out = ProgramAnalysis { chains: Vec::new() };
        let mut e = task.space.sample(rng);
        let steps = 40;
        for step in 0..steps {
            cache.analyze_delta(&task, &e, &mut out).unwrap();
            let fresh = analyze(&task.lower(&e).unwrap());
            assert_eq!(out, fresh, "seed {seed} step {step}: delta analysis diverged");
            let (n, _) = task.space.mutate_knob(&e, rng);
            e = n;
        }
        let s = cache.stats();
        assert!(s.structures >= 1, "seed {seed}: no structures cached");
        assert_eq!(
            s.structures as u64 + s.delta_hits + s.fallbacks,
            steps,
            "seed {seed}: every call must be a donor build, a replay or a fallback"
        );
        total_delta_hits += s.delta_hits;

        // A structure-changing mutation (new structure key) must create
        // a new donor entry — i.e. take the full lower+analyze path —
        // and still match a fresh analysis exactly.
        let e0 = task.space.sample(rng);
        cache.analyze_delta(&task, &e0, &mut out).unwrap();
        let k0 = task.structure_key(&e0);
        for _ in 0..64 {
            let (n, _) = task.space.mutate_knob(&e0, rng);
            if task.structure_key(&n) == k0 {
                continue;
            }
            let before = cache.stats().structures;
            cache.analyze_delta(&task, &n, &mut out).unwrap();
            assert!(
                cache.stats().structures > before,
                "seed {seed}: structure-key change did not build a new donor"
            );
            assert_eq!(
                out,
                analyze(&task.lower(&n).unwrap()),
                "seed {seed}: post-fallback analysis diverged"
            );
            break;
        }
    });
    // The chains must actually exercise the replay path somewhere —
    // all-fallback (every recipe failing verification) would make the
    // equality above vacuous.
    assert!(total_delta_hits > 0, "no delta replays across any seed");
}

fn fixed_seed_run_with(
    repr: autotvm::features::Representation,
    fast: bool,
    sink: Option<DbSink>,
    cap: Option<usize>,
) -> TuneResult {
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    let measurer = SimMeasurer::with_seed(autotvm::sim::devices::sim_gpu(), 17);
    let opts = TuneOptions {
        n_trials: 48,
        batch: 16,
        sa: SaParams { n_chains: 16, n_steps: 25, ..Default::default() },
        seed: 5,
        repr,
        fast_paths: fast,
        feat_cache_cap: cap,
        sink,
        ..Default::default()
    };
    tune_gbt(task, &measurer, opts)
}

fn fixed_seed_run(fast: bool, sink: Option<DbSink>) -> TuneResult {
    fixed_seed_run_with(autotvm::features::Representation::Full, fast, sink, None)
}

fn assert_bit_identical(a: &TuneResult, b: &TuneResult, what: &str) {
    assert_eq!(a.curve.len(), b.curve.len(), "{what}: trial counts diverged");
    for (i, (x, y)) in a.curve.iter().zip(&b.curve).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: curve[{i}] diverged");
    }
    for (i, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
        assert_eq!(ra.entity, rb.entity, "{what}: trial {i} config diverged");
        assert_eq!(ra.gflops.to_bits(), rb.gflops.to_bits(), "{what}: trial {i} gflops");
    }
}

#[test]
fn fixed_seed_tune_bit_identical_with_fast_paths_off() {
    let fast = fixed_seed_run(true, None);
    let scalar = fixed_seed_run(false, None);
    assert_bit_identical(&fast, &scalar, "fast vs scalar");
}

/// The program-derived representations route SA scoring through the
/// structure-cached delta path when the fast paths are on; the whole
/// fixed-seed run must be bit-identical to the scalar reference.
#[test]
fn fixed_seed_tune_bit_identical_under_program_reprs() {
    use autotvm::features::Representation;
    for repr in [Representation::Full, Representation::ContextRelation] {
        let fast = fixed_seed_run_with(repr, true, None, None);
        let scalar = fixed_seed_run_with(repr, false, None, None);
        assert_bit_identical(&fast, &scalar, &format!("{repr:?}: fast vs scalar"));
    }
}

/// Satellite regression: a row cache far smaller than the run's working
/// set (capacity 12 vs batches of 16 and a training set that grows to
/// 48) evicts constantly, and must still reproduce the uncapped
/// fixed-seed results bit-for-bit — eviction only ever forces
/// recomputation, never approximation.
#[test]
fn capped_feature_cache_preserves_fixed_seed_results() {
    use autotvm::features::Representation;
    for repr in [Representation::Config, Representation::ContextRelation] {
        let base = fixed_seed_run_with(repr, true, None, None);
        let capped = fixed_seed_run_with(repr, true, None, Some(12));
        assert_bit_identical(&base, &capped, &format!("{repr:?}: capped row cache"));
    }
}

/// Satellite regression: auto-compaction kicking in mid-tune (tiny WAL
/// threshold, every append crosses it) must not perturb the fixed-seed
/// trial sequence — compaction folds the WAL under the keep-all policy
/// and the model trains from the in-memory store, so only the on-disk
/// layout may change.
#[test]
fn mid_tune_auto_compaction_preserves_fixed_seed_results() {
    let dir = std::env::temp_dir().join("autotvm-test-autocompact");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("midtune-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let baseline = fixed_seed_run(true, None);

    let db = Database::open(&path).unwrap();
    db.set_auto_compact_bytes(256); // every batch of appends crosses this
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    let compacted = fixed_seed_run(true, Some(DbSink::new(&db, &task, "sim-gpu")));

    assert!(
        db.auto_compactions() >= 1,
        "threshold of 256 bytes never triggered ({} WAL bytes)",
        db.wal_bytes().unwrap_or(0)
    );
    assert_bit_identical(&baseline, &compacted, "auto-compaction mid-tune");
    assert_eq!(db.len(), baseline.curve.len(), "sink lost records across compactions");

    // and the compacted file reopens to the same record set
    let reopened = Database::open(&path).unwrap();
    assert_eq!(reopened.len(), db.len(), "compacted DB lost records on reopen");
    let _ = std::fs::remove_file(&path);
}
