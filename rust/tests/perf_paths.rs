//! Hot-path equivalence properties (own mini-harness; proptest is not
//! vendored): the compiled [`PredictPlan`] must be bit-identical to the
//! scalar tree walk on random models and batches (including rows with
//! out-of-range and non-finite values), incremental SA featurization
//! must equal fresh extraction, and fixed-seed tuning runs must be
//! bit-for-bit unchanged by the fast paths and by mid-tune WAL
//! auto-compaction.
//!
//! [`PredictPlan`]: autotvm::gbt::PredictPlan

use autotvm::explore::SaParams;
use autotvm::gbt::{Gbt, GbtParams, Matrix, Objective};
use autotvm::measure::SimMeasurer;
use autotvm::schedule::template::TemplateKind;
use autotvm::tuner::db::Database;
use autotvm::tuner::{tune_gbt, DbSink, Featurizer, TuneOptions, TuneResult};
use autotvm::util::Rng;
use autotvm::workloads;

/// Mini property harness: run `f` over `n` seeded cases, reporting the
/// failing seed through the assertion messages.
fn forall(n: u64, mut f: impl FnMut(&mut Rng, u64)) {
    for seed in 0..n {
        let mut rng = Rng::seed_from_u64(seed * 6151 + 29);
        f(&mut rng, seed);
    }
}

/// Random training matrix with values in `[0, 1)`.
fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    let data: Vec<f32> = (0..rows * cols).map(|_| rng.gen_f64() as f32).collect();
    Matrix::new(rows, cols, data)
}

/// Query batch that deliberately strays outside the training range:
/// negatives, huge magnitudes, infinities and NaNs — the plan's binning
/// must route all of them exactly like the scalar comparisons do.
fn hostile_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| match rng.gen_range(0..8) {
            0 => -(rng.gen_f64() as f32) * 100.0,
            1 => rng.gen_f64() as f32 * 1e6,
            2 => f32::INFINITY,
            3 => f32::NEG_INFINITY,
            4 => f32::NAN,
            _ => rng.gen_f64() as f32,
        })
        .collect();
    Matrix::new(rows, cols, data)
}

#[test]
fn prop_plan_bitmatches_scalar_walk() {
    forall(24, |rng, seed| {
        let cols = 2 + rng.gen_range(0..30);
        let n = 40 + rng.gen_range(0..300);
        let x = random_matrix(rng, n, cols);
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let r = x.row(i);
                r[0] as f64 * 3.0 - r[cols / 2] as f64 + (r[cols - 1] as f64).abs()
            })
            .collect();
        let params = GbtParams {
            objective: if rng.gen_bool(0.5) { Objective::Rank } else { Objective::Regression },
            n_trees: 1 + rng.gen_range(0..40),
            max_depth: 1 + rng.gen_range(0..7),
            seed,
            ..Default::default()
        };
        let model = Gbt::train(&x, &y, &[], params);
        let plan = model.compile();

        // in-range, out-of-range, empty and single-row batches
        let batches = [
            random_matrix(rng, 1 + rng.gen_range(0..200), cols),
            hostile_matrix(rng, 1 + rng.gen_range(0..64), cols),
            Matrix::new(0, cols, Vec::new()),
            random_matrix(rng, 1, cols),
        ];
        for (bi, q) in batches.iter().enumerate() {
            let scalar = model.predict_batch(q);
            let planned = plan.predict_batch(q);
            assert_eq!(scalar.len(), planned.len(), "seed {seed} batch {bi}");
            for (i, (a, b)) in scalar.iter().zip(&planned).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "seed {seed} batch {bi} row {i}: plan {b} != scalar {a}"
                );
            }
            // single-row entry point agrees too
            for i in 0..q.rows.min(4) {
                assert_eq!(
                    model.predict(q.row(i)).to_bits(),
                    plan.predict(q.row(i)).to_bits(),
                    "seed {seed} batch {bi}: single-row predict diverged"
                );
            }
        }
    });
}

#[test]
fn prop_incremental_featurization_matches_fresh() {
    use autotvm::features::Representation;
    forall(12, |rng, seed| {
        let wl = 1 + (seed as usize % 12);
        let template = if rng.gen_bool(0.5) { TemplateKind::Gpu } else { TemplateKind::Cpu };
        let task = workloads::conv_task(wl, template);
        let parents: Vec<_> = (0..24).map(|_| task.space.sample(rng)).collect();
        let mut knobs = Vec::new();
        let proposals: Vec<_> = parents
            .iter()
            .map(|p| {
                let (n, j) = task.space.mutate_knob(p, rng);
                knobs.push(j);
                n
            })
            .collect();

        // warm cache: parents featurized once, then per-knob updates
        let warm = Featurizer::new(Representation::Config);
        warm.features(&task, &parents);
        let inc = warm
            .neighbor_features(&task, &parents, &proposals, &knobs)
            .expect("parents are cached, Config repr is incremental");
        // reference: full extraction with the fast paths off
        let fresh =
            Featurizer::with_fast(Representation::Config, false).features(&task, &proposals);
        assert_eq!(inc.rows, fresh.rows, "seed {seed}");
        assert_eq!(inc.cols, fresh.cols, "seed {seed}");
        for i in 0..inc.rows {
            assert_eq!(
                inc.row(i),
                fresh.row(i),
                "seed {seed} row {i} (knob {}): incremental row diverged",
                knobs[i]
            );
        }

        // a cold featurizer has no parent rows to patch: must decline
        let cold = Featurizer::new(Representation::Config);
        assert!(cold.neighbor_features(&task, &parents, &proposals, &knobs).is_none());
    });
}

fn fixed_seed_run(fast: bool, sink: Option<DbSink>) -> TuneResult {
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    let measurer = SimMeasurer::with_seed(autotvm::sim::devices::sim_gpu(), 17);
    let opts = TuneOptions {
        n_trials: 48,
        batch: 16,
        sa: SaParams { n_chains: 16, n_steps: 25, ..Default::default() },
        seed: 5,
        fast_paths: fast,
        sink,
        ..Default::default()
    };
    tune_gbt(task, &measurer, opts)
}

fn assert_bit_identical(a: &TuneResult, b: &TuneResult, what: &str) {
    assert_eq!(a.curve.len(), b.curve.len(), "{what}: trial counts diverged");
    for (i, (x, y)) in a.curve.iter().zip(&b.curve).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: curve[{i}] diverged");
    }
    for (i, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
        assert_eq!(ra.entity, rb.entity, "{what}: trial {i} config diverged");
        assert_eq!(ra.gflops.to_bits(), rb.gflops.to_bits(), "{what}: trial {i} gflops");
    }
}

#[test]
fn fixed_seed_tune_bit_identical_with_fast_paths_off() {
    let fast = fixed_seed_run(true, None);
    let scalar = fixed_seed_run(false, None);
    assert_bit_identical(&fast, &scalar, "fast vs scalar");
}

/// Satellite regression: auto-compaction kicking in mid-tune (tiny WAL
/// threshold, every append crosses it) must not perturb the fixed-seed
/// trial sequence — compaction folds the WAL under the keep-all policy
/// and the model trains from the in-memory store, so only the on-disk
/// layout may change.
#[test]
fn mid_tune_auto_compaction_preserves_fixed_seed_results() {
    let dir = std::env::temp_dir().join("autotvm-test-autocompact");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("midtune-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let baseline = fixed_seed_run(true, None);

    let db = Database::open(&path).unwrap();
    db.set_auto_compact_bytes(256); // every batch of appends crosses this
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    let compacted = fixed_seed_run(true, Some(DbSink::new(&db, &task, "sim-gpu")));

    assert!(
        db.auto_compactions() >= 1,
        "threshold of 256 bytes never triggered ({} WAL bytes)",
        db.wal_bytes().unwrap_or(0)
    );
    assert_bit_identical(&baseline, &compacted, "auto-compaction mid-tune");
    assert_eq!(db.len(), baseline.curve.len(), "sink lost records across compactions");

    // and the compacted file reopens to the same record set
    let reopened = Database::open(&path).unwrap();
    assert_eq!(reopened.len(), db.len(), "compacted DB lost records on reopen");
    let _ = std::fs::remove_file(&path);
}
