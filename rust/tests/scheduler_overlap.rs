//! Chaos & equivalence suite for the overlapped task scheduler
//! (`tuner::scheduler` with `SchedulerOptions::overlap > 1`).
//!
//! The claims under test, in order of importance:
//!
//! 1. `overlap = 1` reproduces the barrier scheduler **bit-for-bit** —
//!    on replayed curves and on the real tuning loops (same allocation
//!    log, same trials, same latencies, same DB contents).
//! 2. At any overlap, allocation decisions are a pure function of the
//!    commit sequence: the [`GainLedger`] pins slice `k`'s decision to
//!    ledger version `max(0, k − N + 1)`, so wall-clock completion
//!    order (modeled by an executor with arbitrary completion delays,
//!    and by a real farm with/without per-board RTT) cannot leak into
//!    the allocation.
//! 3. Chaos: a flaky multi-replica farm under overlap loses nothing —
//!    the budget is exactly spent, every trial (including injected
//!    board errors) is streamed into the DB exactly once, and the farm
//!    really did hold more than one task in flight.
//! 4. Gain-accounting edge cases: spaces exhausting mid-slice under
//!    overlap refund their budget; all-tasks-exhausted terminates; EMA
//!    restart detection fires exactly once per genuine regime change.
//! 5. The pollable slice sessions (`begin_slice`/`step_slice`) match
//!    the joined `tune_more` drivers bit-for-bit, and a slice's outcome
//!    is only released after its DB sink has fully flushed.
//!
//! [`GainLedger`]: autotvm::tuner::scheduler::GainLedger

use autotvm::expr::ops;
use autotvm::gbt::GbtParams;
use autotvm::measure::farm::DeviceFarm;
use autotvm::measure::service::MeasureService;
use autotvm::measure::SimMeasurer;
use autotvm::model::GbtModel;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::sim::devices::{sim_cpu, sim_gpu, LatencyCurve, StagedCurve, TaskCurve};
use autotvm::tuner::db::Database;
use autotvm::tuner::pipeline::PipelinedTuner;
use autotvm::tuner::scheduler::{
    AllocPolicy, Allocation, CurveExecutor, LoopExecutor, SchedulerOptions, SliceExecutor,
    SliceOutcome, TaskScheduler,
};
use autotvm::tuner::{SaParams, SliceStep, TuneOptions, TuneResult, Tuner};
use autotvm::util::Rng;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn tiny_tasks(n: usize, template: TemplateKind) -> Vec<Task> {
    (0..n).map(|i| Task::new(ops::matmul(64 << i, 64, 64), template)).collect()
}

fn small_tune_options(batch: usize, seed: u64) -> TuneOptions {
    TuneOptions {
        batch,
        sa: SaParams { n_chains: 16, n_steps: 25, ..Default::default() },
        seed,
        ..Default::default()
    }
}

fn assert_same_alloc(a: &Allocation, b: &Allocation) {
    assert_eq!(a.log, b.log, "allocation decision logs diverged");
    assert_eq!(a.trials, b.trials);
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.secs, b.secs, "per-task latencies diverged");
    assert_eq!(a.est_latency, b.est_latency);
    assert_eq!(a.restarts, b.restarts);
}

fn assert_same_result(a: &TuneResult, b: &TuneResult) {
    assert_eq!(a.curve, b.curve, "best-so-far curves diverged");
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.entity, rb.entity, "measured configs diverged");
        assert_eq!(ra.gflops, rb.gflops);
        assert_eq!(ra.error, rb.error);
    }
    assert_eq!(
        a.best.as_ref().map(|(e, _)| e.clone()),
        b.best.as_ref().map(|(e, _)| e.clone())
    );
}

/// Hand-built curves: no hashing, so the test controls the shape.
fn curves(params: &[(f64, f64, f64)]) -> CurveExecutor {
    CurveExecutor::new(
        params.iter().map(|&(floor, span, tau)| TaskCurve { floor, span, tau }).collect(),
    )
}

// ---------------------------------------------------------------------
// 1. overlap = 1 ≡ barrier, bit-for-bit
// ---------------------------------------------------------------------

#[test]
fn overlap1_matches_barrier_bit_for_bit_on_curves() {
    let shapes = [(1.0, 1.0, 10.0), (2.0, 3.0, 40.0), (0.5, 0.1, 5.0)];
    let opts = SchedulerOptions {
        budget: 3 * 16 * 4,
        slice: 16,
        policy: AllocPolicy::Gradient,
        ..Default::default()
    };
    let sched = TaskScheduler::for_tasks(tiny_tasks(3, TemplateKind::Cpu), opts);
    let mut barrier_exec = curves(&shapes);
    let barrier = sched.run(&mut barrier_exec); // overlap = 1 → barrier loop
    let mut overlap_exec = curves(&shapes);
    let overlapped = sched.run_overlapped(&mut overlap_exec); // same N, cooperative loop
    assert_same_alloc(&barrier, &overlapped);
    assert_eq!(barrier_exec.spent(), overlap_exec.spent());
    // the log records one decision per round, versions counting up
    assert_eq!(barrier.log.len(), barrier.rounds);
    for (k, e) in barrier.log.iter().enumerate() {
        assert_eq!(e.slice, k);
        assert_eq!(e.version, k as u64, "barrier decisions read every prior commit");
    }
}

#[test]
fn overlap1_matches_barrier_bit_for_bit_on_real_loops() {
    let dev = sim_cpu();
    let tasks = tiny_tasks(2, TemplateKind::Cpu);
    let budget = 2 * 16 * 2;
    let sched = TaskScheduler::for_tasks(
        tasks.clone(),
        SchedulerOptions {
            budget,
            slice: 16,
            policy: AllocPolicy::Gradient,
            ..Default::default()
        },
    );
    let run = |overlapped: bool| {
        let db = Database::new();
        let m = SimMeasurer::with_seed(dev.clone(), 42);
        let mut exec = LoopExecutor::new(
            tasks.clone(),
            &m,
            db.clone(),
            small_tune_options(8, 5),
            false,
            true,
        );
        let alloc =
            if overlapped { sched.run_overlapped(&mut exec) } else { sched.run(&mut exec) };
        (alloc, db)
    };
    let (barrier, db_a) = run(false);
    let (overlapped, db_b) = run(true);
    assert_same_alloc(&barrier, &overlapped);
    assert_eq!(barrier.trials.iter().sum::<usize>(), budget);
    // the DBs saw the same record stream
    assert_eq!(db_a.len(), db_b.len());
    for t in &tasks {
        let (ea, ga) = db_a.best_config(&t.key(), dev.name).expect("tuned");
        let (eb, gb) = db_b.best_config(&t.key(), dev.name).expect("tuned");
        assert_eq!(ea, eb, "best config diverged for {}", t.key());
        assert_eq!(ga, gb);
    }
}

// ---------------------------------------------------------------------
// 2. decisions are invariant to physical completion timing
// ---------------------------------------------------------------------

/// Wraps [`CurveExecutor`] with per-slice completion delays: a slice
/// reports `None` for a seed-dependent number of polls before
/// completing — the model of "task B's measurements returned first".
/// The ledger must make the allocation blind to it.
struct DelayedCurves {
    inner: CurveExecutor,
    delays: Vec<usize>,
    pending: HashMap<u64, usize>,
    begun: usize,
}

impl SliceExecutor for DelayedCurves {
    fn best_secs(&mut self, idx: usize) -> f64 {
        self.inner.best_secs(idx)
    }

    fn run_slice(&mut self, idx: usize, trials: usize) -> usize {
        self.inner.run_slice(idx, trials)
    }

    fn begin_slice(&mut self, no: u64, _idx: usize, _trials: usize) {
        let d = self.delays[self.begun % self.delays.len()];
        self.begun += 1;
        self.pending.insert(no, d);
    }

    fn step_slice(&mut self, no: u64, idx: usize, trials: usize) -> Option<SliceOutcome> {
        let left = self.pending.get_mut(&no).expect("begun");
        if *left > 0 {
            *left -= 1;
            return None;
        }
        self.pending.remove(&no);
        let spent = self.inner.run_slice(idx, trials);
        Some(SliceOutcome { spent, secs_after: self.inner.best_secs(idx) })
    }
}

#[test]
fn overlap_decisions_invariant_to_completion_timing() {
    let shapes =
        [(1.0, 2.0, 12.0), (0.7, 1.5, 30.0), (1.3, 0.4, 8.0), (0.9, 2.5, 50.0)];
    for overlap in [2usize, 3, 4] {
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(4, TemplateKind::Cpu),
            SchedulerOptions {
                budget: 4 * 8 * 6,
                slice: 8,
                policy: AllocPolicy::Gradient,
                overlap,
                ..Default::default()
            },
        );
        // reference: every slice completes at its first poll
        let mut instant = DelayedCurves {
            inner: curves(&shapes),
            delays: vec![0],
            pending: HashMap::new(),
            begun: 0,
        };
        let reference = sched.run_overlapped(&mut instant);
        assert_eq!(reference.trials.iter().sum::<usize>(), 4 * 8 * 6);
        // the ledger pins decision k to version max(0, k − N + 1)
        for (k, e) in reference.log.iter().enumerate() {
            let want = (k + 1).saturating_sub(overlap) as u64;
            assert_eq!(e.version, want, "slice {k} at overlap {overlap}");
        }
        // chaos over completion orderings: seeded delay patterns
        for delay_seed in 0..12u64 {
            let mut rng = Rng::seed_from_u64(delay_seed * 7919 + 3);
            let delays: Vec<usize> = (0..17).map(|_| rng.gen_range(0..4)).collect();
            let mut delayed = DelayedCurves {
                inner: curves(&shapes),
                delays,
                pending: HashMap::new(),
                begun: 0,
            };
            let chaotic = sched.run_overlapped(&mut delayed);
            assert_same_alloc(&reference, &chaotic);
        }
    }
}

#[test]
fn overlap_run_identical_with_and_without_farm_latency() {
    // Same 4-replica farm, same seeds — only the wall-clock timing of
    // completions differs (per-board RTT). The allocation, and every
    // measured record, must be identical.
    let tasks = tiny_tasks(3, TemplateKind::Gpu);
    let budget = 3 * 16 * 2;
    let sched = TaskScheduler::for_tasks(
        tasks.clone(),
        SchedulerOptions {
            budget,
            slice: 16,
            policy: AllocPolicy::Gradient,
            overlap: 3,
            ..Default::default()
        },
    );
    let run = |latency_ms: u64| {
        let farm =
            DeviceFarm::with_latency(sim_gpu(), 4, 9, Duration::from_millis(latency_ms));
        let svc = MeasureService::with_defaults(Arc::new(farm));
        let db = Database::new();
        let mut exec = LoopExecutor::new(
            tasks.clone(),
            &svc,
            db.clone(),
            small_tune_options(8, 3),
            false,
            false,
        );
        let alloc = sched.run_overlapped(&mut exec);
        (alloc, db)
    };
    let (fast, db_fast) = run(0);
    let (slow, db_slow) = run(3);
    assert_same_alloc(&fast, &slow);
    assert_eq!(fast.trials.iter().sum::<usize>(), budget);
    assert_eq!(db_fast.len(), db_slow.len());
    for t in &tasks {
        let a = db_fast.best_config(&t.key(), "sim-gpu");
        let b = db_slow.best_config(&t.key(), "sim-gpu");
        assert_eq!(a.map(|(_, g)| g), b.map(|(_, g)| g), "{}", t.key());
    }
}

// ---------------------------------------------------------------------
// 3. chaos: flaky multi-replica farm under overlap
// ---------------------------------------------------------------------

#[test]
fn chaos_flaky_overlap_farm_loses_nothing() {
    let tasks = tiny_tasks(3, TemplateKind::Gpu);
    let budget = 3 * 16 * 3;
    // 50 ms per job: a batch wave (8 jobs on 4 boards) outlives the
    // tiny SA proposals below by a wide margin, so both tasks' jobs
    // really coexist on the farm (the peak assertion at the bottom).
    let farm = DeviceFarm::with_latency(sim_gpu(), 4, 11, Duration::from_millis(50))
        .with_flakiness(0.2);
    let svc = MeasureService::with_defaults(Arc::new(farm));
    let db = Database::new();
    let sched = TaskScheduler::for_tasks(
        tasks.clone(),
        SchedulerOptions {
            budget,
            slice: 16,
            policy: AllocPolicy::Gradient,
            overlap: 2,
            ..Default::default()
        },
    );
    let mut tune = small_tune_options(8, 7);
    tune.sa = SaParams { n_chains: 8, n_steps: 15, ..Default::default() };
    // pipelined slices: up to depth × overlap batches on the farm
    let mut exec = LoopExecutor::new(tasks.clone(), &svc, db.clone(), tune, true, true);
    let alloc = sched.run_overlapped(&mut exec);
    // budget exactly spent: injected board errors are measurement
    // outcomes and consume trials, never retried or double-counted
    assert_eq!(alloc.trials.iter().sum::<usize>(), budget, "budget exactly spent");
    assert_eq!(db.len(), budget, "no lost or double-counted trials");
    for (i, t) in tasks.iter().enumerate() {
        assert_eq!(
            db.for_task(&t.key(), "sim-gpu").len(),
            alloc.trials[i],
            "per-task record count diverged for {}",
            t.key()
        );
    }
    // the flaky farm really did inject failures, and they were recorded
    let errored = db.records().iter().filter(|r| r.error.is_some()).count();
    assert!(errored > 0, "flakiness 0.2 produced no errors?");
    let stats = svc.stats();
    // every trial plus one vendor-baseline measurement per task
    assert_eq!(stats.completed as usize, budget + tasks.len());
    assert!(stats.inflight_by_task.is_empty(), "in-flight accounting must drain");
    assert!(
        stats.peak_tasks_overlapped >= 2,
        "overlap 2 never had two tasks on the farm at once (peak {})",
        stats.peak_tasks_overlapped
    );
}

// ---------------------------------------------------------------------
// 4. gain-accounting edge cases
// ---------------------------------------------------------------------

/// Executor whose tasks run out of configs (default synchronous slice
/// protocol — exhaustion semantics are the scheduler's to handle).
struct CappedExecutor {
    caps: Vec<usize>,
    spent: Vec<usize>,
}

impl SliceExecutor for CappedExecutor {
    fn best_secs(&mut self, idx: usize) -> f64 {
        1.0 / (1.0 + self.spent[idx] as f64)
    }

    fn run_slice(&mut self, idx: usize, trials: usize) -> usize {
        let n = trials.min(self.caps[idx] - self.spent[idx]);
        self.spent[idx] += n;
        n
    }
}

#[test]
fn overlap_exhaustion_mid_slice_refunds_and_reallocates() {
    // task 0 dies mid-slice; its refunded budget must flow to task 1
    let sched = TaskScheduler::for_tasks(
        tiny_tasks(2, TemplateKind::Cpu),
        SchedulerOptions {
            budget: 160,
            slice: 16,
            policy: AllocPolicy::Gradient,
            overlap: 2,
            ..Default::default()
        },
    );
    let mut exec = CappedExecutor { caps: vec![24, 1000], spent: vec![0, 0] };
    let alloc = sched.run_overlapped(&mut exec);
    assert_eq!(alloc.trials[0], 24, "exhausted task charged phantom trials");
    assert_eq!(exec.spent, alloc.trials);
    // the full budget still lands: what task 0 couldn't spend, task 1 did
    assert_eq!(alloc.trials.iter().sum::<usize>(), 160);
}

#[test]
fn overlap_all_tasks_exhausted_terminates() {
    let sched = TaskScheduler::for_tasks(
        tiny_tasks(2, TemplateKind::Cpu),
        SchedulerOptions {
            budget: 320,
            slice: 16,
            policy: AllocPolicy::Gradient,
            overlap: 3,
            ..Default::default()
        },
    );
    // total capacity (40) far below the budget (320): must terminate
    // without charging phantom trials, with bounded probe rounds
    let mut exec = CappedExecutor { caps: vec![24, 16], spent: vec![0, 0] };
    let alloc = sched.run_overlapped(&mut exec);
    assert_eq!(alloc.trials, vec![24, 16], "trials must reflect real spend");
    assert_eq!(exec.spent, vec![24, 16]);
    assert!(alloc.rounds <= 10, "{} rounds", alloc.rounds);
}

#[test]
fn ema_restart_fires_exactly_once_per_regime_change() {
    // task 0: smooth decay that flattens, then a genuine regime change
    // at trial 96 (fresh headroom below the old floor); task 1: one
    // smooth regime throughout. Uniform policy pins the trial schedule
    // (16-trial slices, strict alternation), so the gain sequence — and
    // the single restart — is exact.
    let staged = StagedCurve::new(TaskCurve { floor: 1.0, span: 2.0, tau: 12.0 })
        .then(96, TaskCurve { floor: 0.1, span: 0.88, tau: 6.0 });
    let plain = TaskCurve { floor: 0.8, span: 1.0, tau: 30.0 };
    let mk_exec = || {
        CurveExecutor::from_curves(vec![
            Box::new(staged.clone()) as Box<dyn LatencyCurve>,
            Box::new(plain.clone()),
        ])
    };
    let mk_sched = |overlap: usize, gain_ema: Option<f64>| {
        TaskScheduler::for_tasks(
            tiny_tasks(2, TemplateKind::Cpu),
            SchedulerOptions {
                budget: 320,
                slice: 16,
                policy: AllocPolicy::Uniform,
                overlap,
                gain_ema,
                ..Default::default()
            },
        )
    };
    let mut exec = mk_exec();
    let alloc = mk_sched(1, Some(0.5)).run(&mut exec);
    assert_eq!(alloc.trials, vec![160, 160]);
    assert_eq!(
        alloc.restarts,
        vec![1, 0],
        "exactly one restart, on the regime-changing task only"
    );
    // the detection is overlap-independent (same commit sequence)
    let mut exec2 = mk_exec();
    let alloc2 = mk_sched(2, Some(0.5)).run(&mut exec2);
    assert_eq!(alloc2.restarts, vec![1, 0]);
    // raw mode has no restart detection at all
    let mut exec3 = mk_exec();
    let alloc3 = mk_sched(1, None).run(&mut exec3);
    assert_eq!(alloc3.restarts, vec![0, 0]);
}

// ---------------------------------------------------------------------
// 5. pollable slice sessions
// ---------------------------------------------------------------------

#[test]
fn polled_serial_slices_match_joined_tune_more() {
    let mk_task = || Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
    let mk_model = || {
        let params = GbtParams { seed: 5, ..Default::default() };
        Box::new(GbtModel::new(params))
    };
    let o = small_tune_options(16, 5);

    let m1 = SimMeasurer::with_seed(sim_cpu(), 21);
    let mut joined = Tuner::new(mk_task(), mk_model(), o.clone());
    joined.tune_more(&m1, 32);
    joined.tune_more(&m1, 32);

    let m2 = SimMeasurer::with_seed(sim_cpu(), 21);
    let mut polled = Tuner::new(mk_task(), mk_model(), o.clone());
    for _ in 0..2 {
        let mut run = polled.begin_slice(32);
        while polled.step_slice(&m2, &mut run) == SliceStep::Working {}
    }
    assert_same_result(&joined.result(), &polled.result());
}

#[test]
fn polled_pipelined_slices_match_joined_tune_more() {
    let mk_task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
    let mk_model = || {
        let params = GbtParams { seed: 3, ..Default::default() };
        Box::new(GbtModel::new(params))
    };
    let mut o = small_tune_options(16, 9);
    o.pipeline_depth = 2;

    let m1 = SimMeasurer::with_seed(sim_gpu(), 7);
    let mut joined = PipelinedTuner::new(mk_task(), mk_model(), o.clone());
    joined.tune_more(&m1, 48);
    joined.tune_more(&m1, 32);

    let m2 = SimMeasurer::with_seed(sim_gpu(), 7);
    let mut polled = PipelinedTuner::new(mk_task(), mk_model(), o.clone());
    for extra in [48usize, 32] {
        let mut run = polled.begin_slice(extra);
        while polled.step_slice(&m2, &mut run) == SliceStep::Working {}
    }
    assert_same_result(&joined.result(), &polled.result());
}

/// Regression (gain-vs-sink race): a slice's outcome must not be
/// released while any of its measurement batches — and therefore any of
/// its DB-sink appends — is still in flight. With pipelined slices the
/// session keeps up to `depth` batches submitted; an implementation
/// that reported completion when the last batch was *proposed* (rather
/// than absorbed) would leave the DB short exactly here.
#[test]
fn slice_outcome_waits_for_sink_flush() {
    let dev = sim_cpu();
    let tasks = tiny_tasks(2, TemplateKind::Cpu);
    let db = Database::new();
    let m = SimMeasurer::with_seed(dev.clone(), 11);
    let mut o = small_tune_options(8, 7);
    o.pipeline_depth = 2;
    let mut exec = LoopExecutor::new(tasks.clone(), &m, db.clone(), o, true, false);
    exec.begin_slice(0, 0, 24); // 3 batches, depth-2 pipelined slice
    let mut steps = 0;
    let out = loop {
        assert!(db.len() <= 24, "sink overshot the slice");
        if let Some(out) = exec.step_slice(0, 0, 24) {
            break out;
        }
        steps += 1;
        assert!(steps < 100, "slice did not complete");
    };
    assert_eq!(out.spent, 24);
    // the completion barrier covers the sink: at the instant the
    // outcome is released, every record of the slice is in the DB
    assert_eq!(db.len(), out.spent, "slice outcome released before sink flush");
    assert!(out.secs_after.is_finite());
}
