//! Targeted behavioural tests across modules — scenarios the unit tests
//! don't reach: hardware-adaptation ablation, winograd end-to-end,
//! model persistence in the transfer flow, farm + tuner composition,
//! elementwise template edge cases, CLI figure plumbing.

use autotvm::expr::ops::{self, Conv2dParams};
use autotvm::expr::winograd;
use autotvm::measure::farm::DeviceFarm;
use autotvm::measure::{Measurer, SimMeasurer};
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::sim::devices::{sim_cpu, sim_gpu, sim_tpu};
use autotvm::util::Rng;
use autotvm::workloads;

/// Hardware-adaptation ablation (DESIGN.md §Hardware-Adaptation): on the
/// MXU device, the tuner's best schedules should achieve a higher
/// fraction of peak than on the plain GPU — the search discovers
/// MXU-aligned tiles.
#[test]
fn sim_tpu_search_finds_mxu_aligned_tiles() {
    let task = Task::new(ops::matmul(512, 512, 512), TemplateKind::Gpu);
    let tpu = sim_tpu();
    let gpu = sim_gpu();
    let mut rng = Rng::seed_from_u64(1);
    let mut best_tpu = 0.0f64;
    let mut best_gpu = 0.0f64;
    for _ in 0..300 {
        let e = task.space.sample(&mut rng);
        let p = task.lower(&e).unwrap();
        if let Ok(r) = tpu.evaluate(&p) {
            best_tpu = best_tpu.max(r.gflops);
        }
        if let Ok(r) = gpu.evaluate(&p) {
            best_gpu = best_gpu.max(r.gflops);
        }
    }
    let peak_tpu = tpu.max_concurrency * tpu.flops_per_cycle * tpu.clock_ghz
        * tpu.mxu.map(|(_, s)| s).unwrap_or(1.0);
    let peak_gpu = gpu.max_concurrency * gpu.flops_per_cycle * gpu.clock_ghz;
    assert!(best_tpu > 0.0 && best_gpu > 0.0);
    // MXU acceleration must be visible in absolute terms
    assert!(
        best_tpu > best_gpu * 0.5,
        "tpu {best_tpu:.0} vs gpu {best_gpu:.0} (peaks {peak_tpu:.0}/{peak_gpu:.0})"
    );
}

/// Winograd full pipeline: tune the bgemm, add transform costs, compare
/// effective GFLOPS against the tuned direct conv — must be in the same
/// ballpark (either may win per device, as in Fig. 10).
#[test]
fn winograd_pipeline_is_competitive_on_cpu() {
    let p = workloads::conv_workload(6);
    assert!(winograd::applicable(&p));
    let dev = sim_cpu();
    let stages = winograd::stages(p);
    let quick = |def: autotvm::expr::ComputeDef| -> f64 {
        let t = Task::new(def, TemplateKind::Cpu);
        let e = autotvm::graph::quick_best(&t, &dev, 48, 2);
        dev.evaluate(&t.lower(&e).unwrap()).unwrap().seconds
    };
    let t_direct = quick(ops::conv2d(p));
    let t_wino = quick(stages.bgemm.clone())
        + quick(stages.input_transform.clone())
        + quick(stages.output_transform.clone());
    let direct_gf = stages.direct_flops as f64 / t_direct / 1e9;
    let wino_gf = stages.direct_flops as f64 / t_wino / 1e9;
    assert!(
        wino_gf > 0.3 * direct_gf,
        "winograd collapsed: {wino_gf:.1} vs direct {direct_gf:.1}"
    );
}

/// Persistence round-trip inside the transfer flow: save the global
/// model, reload it, predictions must be identical.
#[test]
fn persisted_global_model_reusable() {
    use autotvm::gbt::{Gbt, GbtParams, Matrix, Objective};
    let mut rng = Rng::seed_from_u64(3);
    let rows: Vec<Vec<f64>> =
        (0..300).map(|_| (0..20).map(|_| rng.gen_f64()).collect()).collect();
    let y: Vec<f64> = rows.iter().map(|r| r[0] * 5.0 - r[1]).collect();
    let x = Matrix::from_rows(&rows);
    let m = Gbt::train(
        &x,
        &y,
        &[],
        GbtParams { objective: Objective::Rank, n_trees: 20, ..Default::default() },
    );
    let dir = std::env::temp_dir().join("autotvm-cov");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("global.json");
    m.save(&path).unwrap();
    let m2 = Gbt::load(&path).unwrap();
    assert_eq!(m.predict_batch(&x), m2.predict_batch(&x));
    let _ = std::fs::remove_file(&path);
}

/// Elementwise ops tune end-to-end (no reduce axes — the degenerate
/// template path).
#[test]
fn elementwise_ops_tune() {
    for def in [ops::relu(&[64, 56, 56]), ops::elemwise_add(&[128, 28, 28])] {
        let task = Task::new(def, TemplateKind::Gpu);
        let m = SimMeasurer::with_seed(sim_gpu(), 4);
        let o = autotvm::tuner::TuneOptions {
            n_trials: 32,
            batch: 16,
            sa: autotvm::explore::SaParams { n_chains: 8, n_steps: 20, ..Default::default() },
            ..Default::default()
        };
        let res = autotvm::tuner::tune_gbt(task, &m, o);
        assert!(res.best_gflops() > 0.0);
    }
}

/// Farm measurement inside a graph-level tuning flow.
#[test]
fn farm_backed_graph_tuning() {
    let graph = workloads::dqn().fuse();
    let farm = DeviceFarm::new(sim_gpu(), 4, 5);
    assert_eq!(farm.target(), "farm(4xsim-gpu)");
    let o = autotvm::tuner::TuneOptions {
        n_trials: 48,
        batch: 16,
        sa: autotvm::explore::SaParams { n_chains: 16, n_steps: 25, ..Default::default() },
        ..Default::default()
    };
    let tuned = autotvm::graph::tune_graph_tasks(&graph, TemplateKind::Gpu, &farm, o);
    assert!(!tuned.is_empty());
    // every tuned config lowers
    for task in graph.tasks(TemplateKind::Gpu) {
        if let Some(e) = tuned.get(&task.key()) {
            assert!(task.lower(e).is_ok());
        }
    }
}

/// Stride-2 convs (half of Table 1) produce non-contiguous innermost
/// input access — the simulator must still reward vectorization *less*
/// than for stride-1.
#[test]
fn stride2_vectorization_less_profitable() {
    let dev = sim_cpu();
    let gain = |wl: usize| -> f64 {
        let task = workloads::conv_task(wl, TemplateKind::Cpu);
        let iv = task.space.knob_index("vec").unwrap();
        let mut rng = Rng::seed_from_u64(6);
        let mut ratios = Vec::new();
        for _ in 0..40 {
            let mut e = task.space.sample(&mut rng);
            e.choices[iv] = 0;
            let mut ev = e.clone();
            ev.choices[iv] = 1;
            if let (Ok(a), Ok(b)) = (
                dev.evaluate(&task.lower(&e).unwrap()),
                dev.evaluate(&task.lower(&ev).unwrap()),
            ) {
                ratios.push(a.seconds / b.seconds); // >1 = vec helps
            }
        }
        autotvm::util::mean(&ratios)
    };
    let s1 = gain(2); // C2: stride 1
    let s2 = gain(4); // C4: stride 2
    assert!(
        s1 > s2 * 0.98,
        "stride-1 vec gain {s1:.3} should be >= stride-2 {s2:.3}"
    );
}

/// The e2e CLI path for a non-default network/device combination.
#[test]
fn cli_e2e_dqn_on_mali() {
    let argv: Vec<String> = [
        "e2e", "--network", "dqn", "--device", "sim-mali", "--trials", "32",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    autotvm::coordinator::run(&argv).unwrap();
}

/// Depthwise conv template end-to-end on the Mali device (the MobileNet
/// on mobile-GPU scenario of Fig. 11).
#[test]
fn depthwise_tunes_on_mali() {
    let p = Conv2dParams {
        n: 1, h: 56, w: 56, ic: 128, oc: 128, kh: 3, kw: 3, stride: 1, pad: 1,
    };
    let task = Task::new(ops::depthwise_conv2d(p), TemplateKind::Gpu);
    let m = SimMeasurer::with_seed(autotvm::sim::devices::sim_mali(), 8);
    let o = autotvm::tuner::TuneOptions {
        n_trials: 48,
        batch: 16,
        sa: autotvm::explore::SaParams { n_chains: 16, n_steps: 25, ..Default::default() },
        ..Default::default()
    };
    let res = autotvm::tuner::tune_gbt(task, &m, o);
    assert!(res.best_gflops() > 0.0);
}

/// Database accumulates across runs and filters per task/target.
#[test]
fn database_multi_target_isolation() {
    use autotvm::tuner::db::Database;
    let task = workloads::conv_task(3, TemplateKind::Gpu);
    let db = Database::new();
    for (target, seed) in [("sim-gpu", 1u64), ("sim-mali", 2)] {
        let dev = autotvm::sim::devices::by_name(target).unwrap();
        let m = SimMeasurer::with_seed(dev, seed);
        let o = autotvm::tuner::TuneOptions {
            n_trials: 24,
            batch: 8,
            sa: autotvm::explore::SaParams { n_chains: 8, n_steps: 15, ..Default::default() },
            seed,
            ..Default::default()
        };
        let res = autotvm::tuner::tune_gbt(task.clone(), &m, o);
        db.add_run(&task, target, &res.records).unwrap();
    }
    assert_eq!(db.for_task(&task.key(), "sim-gpu").len(), 24);
    assert_eq!(db.for_task(&task.key(), "sim-mali").len(), 24);
    assert!(db.best_config(&task.key(), "sim-gpu").is_some());
    assert!(db.for_task(&task.key(), "sim-cpu").is_empty());
}
