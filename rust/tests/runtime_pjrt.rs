//! Integration tests over the PJRT runtime and the AOT artifacts.
//!
//! These need `make artifacts` to have run; they are skipped (with a
//! loud message) when artifacts are absent so `cargo test` stays green
//! on a fresh checkout.

use autotvm::features::{CONTEXT_DIM, MAX_LOOPS};
use autotvm::gbt::Matrix;
use autotvm::model::neural::{NeuralModel, NeuralObjective};
use autotvm::model::CostModel;
use autotvm::runtime::{artifacts_dir, literal_f32, to_vec_f32, PjrtRuntime};
use autotvm::util::Rng;

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("costmodel_meta.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
    }
    ok
}

fn have_variants() -> bool {
    let ok = artifacts_dir()
        .join(autotvm::measure::pjrt::variant_artifact(32, 32, 64))
        .exists();
    if !ok {
        eprintln!("SKIP: variant artifacts missing — run `make artifacts` (variants)");
    }
    ok
}

#[test]
fn load_and_run_costmodel_fwd() {
    if !have_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let exe = rt.load(artifacts_dir().join("costmodel_fwd.hlo.txt")).unwrap();
    let meta = autotvm::model::neural::NeuralMeta::load().unwrap();
    let theta_bytes = std::fs::read(artifacts_dir().join("costmodel_init.f32")).unwrap();
    let theta: Vec<f32> = theta_bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let x = vec![0.5f32; meta.pred_batch * MAX_LOOPS * CONTEXT_DIM];
    let out = exe
        .run(&[
            literal_f32(&theta, &[meta.theta_dim as i64]).unwrap(),
            literal_f32(
                &x,
                &[meta.pred_batch as i64, MAX_LOOPS as i64, CONTEXT_DIM as i64],
            )
            .unwrap(),
        ])
        .unwrap();
    assert_eq!(out.len(), 1);
    let scores = to_vec_f32(&out[0]).unwrap();
    assert_eq!(scores.len(), meta.pred_batch);
    assert!(scores.iter().all(|s| s.is_finite()));
    // identical inputs → identical scores
    assert!(scores.windows(2).all(|w| w[0] == w[1]));
}

/// Full neural-model lifecycle: the rank-loss Adam train step (which
/// contains the L1 Pallas matmul) runs from Rust, loss decreases, and
/// the fitted model ranks a synthetic signal.
#[test]
fn neural_model_trains_via_pjrt() {
    if !have_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let mut model = NeuralModel::load(&rt, NeuralObjective::Rank, 0).unwrap();
    model.epochs = 12;

    // synthetic dataset in the padded context-matrix layout
    let mut rng = Rng::seed_from_u64(1);
    let n = 192;
    let row = MAX_LOOPS * CONTEXT_DIM;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut y = Vec::new();
    for _ in 0..n {
        let mut r = vec![0f64; row];
        let mut signal = 0.0;
        for l in 0..10 {
            for d in 0..CONTEXT_DIM {
                let v = rng.gen_f64() * 3.0 + 0.2;
                r[l * CONTEXT_DIM + d] = v;
            }
            signal += r[l * CONTEXT_DIM] - 0.7 * r[l * CONTEXT_DIM + 1];
        }
        rows.push(r);
        y.push(signal);
    }
    let x = Matrix::from_rows(&rows);
    assert!(!model.ready());
    let loss = model.fit_verbose(&x, &y).unwrap();
    assert!(model.ready());
    assert!(loss.is_finite() && loss < 0.693, "final rank loss {loss} not below ln2");

    let pred = model.predict(&x);
    let acc = autotvm::gbt::rank_accuracy(&pred, &y);
    assert!(acc > 0.8, "neural in-sample rank accuracy {acc}");
}

#[test]
fn regression_train_step_artifact_works() {
    if !have_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let mut model = NeuralModel::load(&rt, NeuralObjective::Regression, 1).unwrap();
    model.epochs = 4;
    let mut rng = Rng::seed_from_u64(2);
    let row = MAX_LOOPS * CONTEXT_DIM;
    let rows: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..row).map(|_| rng.gen_f64()).collect())
        .collect();
    let y: Vec<f64> = rows.iter().map(|r| r.iter().sum::<f64>()).collect();
    let x = Matrix::from_rows(&rows);
    model.fit(&x, &y, &[]);
    let pred = model.predict(&x);
    assert!(pred.iter().all(|p| p.is_finite()));
}

/// Real-hardware measurement loop: wall-clock Pallas variants through
/// PJRT and check the measurements are sane.
#[test]
fn pjrt_measurer_times_variants() {
    if !have_variants() {
        return;
    }
    use autotvm::measure::pjrt::{matmul_variant_task, PjrtMeasurer};
    use autotvm::measure::Measurer;
    let rt = PjrtRuntime::cpu().unwrap();
    let m = PjrtMeasurer::new(rt).unwrap();
    let task = matmul_variant_task();
    // measure three distinct variants (the 27-point grid makes 26 the
    // last valid index; clamp explicitly — entity() asserts in-range)
    let batch: Vec<_> =
        [0u64, 13, 26].iter().map(|&i| task.space.entity(i % task.space.size())).collect();
    let results = m.measure(&task, &batch);
    for r in &results {
        assert!(r.is_ok(), "variant failed: {:?}", r.error);
        assert!(r.gflops > 0.01, "implausible gflops {}", r.gflops);
        assert!(r.seconds.unwrap() < 30.0);
    }
}
