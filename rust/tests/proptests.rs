//! Property-based tests (own mini-harness; proptest is not vendored):
//! seeded random-case sweeps over workloads × configs asserting
//! structural invariants of the compiler, analysis, simulator, features
//! and utility layers.

use autotvm::ast::analysis::analyze;
use autotvm::ast::{MemScope, Stmt};
use autotvm::expr::ops::{self, Conv2dParams};
use autotvm::features::{self, Representation};
use autotvm::schedule::space::factorizations;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::util::json::Json;
use autotvm::util::Rng;

/// Mini property harness: run `f` over `n` seeded cases, reporting the
/// failing seed.
fn forall(n: u64, mut f: impl FnMut(&mut Rng, u64)) {
    for seed in 0..n {
        let mut rng = Rng::seed_from_u64(seed * 7919 + 13);
        f(&mut rng, seed);
    }
}

/// Random tunable workload.
fn random_task(rng: &mut Rng) -> Task {
    let template =
        if rng.gen_bool(0.5) { TemplateKind::Gpu } else { TemplateKind::Cpu };
    let def = match rng.gen_range(0..4) {
        0 => {
            let n = 1i64 << rng.gen_range(4..8);
            ops::matmul(n, n, n)
        }
        1 => {
            let c = [16, 32, 64][rng.gen_range(0..3)];
            let h = [14, 28, 56][rng.gen_range(0..3)];
            let s = 1 + rng.gen_range(0..2) as i64;
            let k = [1, 3][rng.gen_range(0..2)];
            ops::conv2d(Conv2dParams {
                n: 1, h, w: h, ic: c, oc: c * 2, kh: k, kw: k, stride: s, pad: k / 2,
            })
        }
        2 => ops::dense(1 << rng.gen_range(0..5), 256, 128),
        _ => {
            let c = [16, 32][rng.gen_range(0..2)];
            ops::depthwise_conv2d(Conv2dParams {
                n: 1, h: 28, w: 28, ic: c, oc: c, kh: 3, kw: 3, stride: 1, pad: 1,
            })
        }
    };
    Task::new(def, template)
}

#[test]
fn prop_every_config_lowers_and_validates() {
    forall(60, |rng, seed| {
        let task = random_task(rng);
        let e = task.space.sample(rng);
        let sched = task.schedule(&e);
        let extents: Vec<i64> = task.def.all_axes().map(|a| a.extent).collect();
        sched.validate(&extents).unwrap_or_else(|err| panic!("seed {seed}: {err}"));
        task.lower(&e).unwrap_or_else(|err| panic!("seed {seed}: {err}"));
    });
}

#[test]
fn prop_lowering_preserves_iteration_domain() {
    // the accumulating chain's trip must equal the full iteration domain
    forall(40, |rng, seed| {
        let task = random_task(rng);
        if task.def.reduce_axes.is_empty() {
            return;
        }
        let e = task.space.sample(rng);
        let prog = task.lower(&e).unwrap();
        let a = analyze(&prog);
        let domain: f64 =
            task.def.all_axes().map(|ax| ax.extent as f64).product();
        // the main update chain has the largest trip (init/copy/writeback
        // nests cover subsets of the domain)
        let main = a
            .chains
            .iter()
            .max_by(|x, y| x.trip.partial_cmp(&y.trip).unwrap())
            .unwrap_or_else(|| panic!("seed {seed}: no chains"));
        assert_eq!(main.trip, domain, "seed {seed}: trip mismatch");
    });
}

#[test]
fn prop_flops_invariant_under_schedule() {
    forall(40, |rng, _| {
        let task = random_task(rng);
        let e1 = task.space.sample(rng);
        let e2 = task.space.sample(rng);
        let p1 = task.lower(&e1).unwrap();
        let p2 = task.lower(&e2).unwrap();
        assert_eq!(p1.flops, p2.flops, "flops must not depend on the schedule");
        assert_eq!(p1.flops, task.def.total_flops());
    });
}

#[test]
fn prop_touch_counts_bounded_by_buffer_size() {
    forall(40, |rng, seed| {
        let task = random_task(rng);
        let e = task.space.sample(rng);
        let prog = task.lower(&e).unwrap();
        let a = analyze(&prog);
        for chain in &a.chains {
            for acc in &chain.accesses {
                let buf = prog.buffer(&acc.buffer).unwrap();
                for (l, &t) in acc.touch.iter().enumerate() {
                    assert!(
                        t <= buf.numel() as f64 + 0.5,
                        "seed {seed}: touch[{l}]={t} > |{}|={}",
                        acc.buffer,
                        buf.numel()
                    );
                }
                for &r in &acc.reuse {
                    assert!(r >= 1.0, "seed {seed}: reuse < 1");
                }
            }
        }
    });
}

#[test]
fn prop_features_finite_and_fixed_dim() {
    forall(40, |rng, seed| {
        let task = random_task(rng);
        let e = task.space.sample(rng);
        let a = analyze(&task.lower(&e).unwrap());
        for repr in [
            Representation::Config,
            Representation::FlatAst,
            Representation::ContextRelation,
            Representation::Full,
        ] {
            let f = features::extract(repr, &task, &e, &a);
            assert_eq!(f.len(), repr.dim(), "seed {seed} {repr:?}");
            assert!(
                f.iter().all(|x| x.is_finite()),
                "seed {seed} {repr:?}: non-finite feature"
            );
        }
    });
}

#[test]
fn prop_sim_is_deterministic_positive_and_noise_seeded() {
    forall(40, |rng, seed| {
        let task = random_task(rng);
        let dev = match task.template {
            TemplateKind::Gpu => autotvm::sim::devices::sim_gpu(),
            TemplateKind::Cpu => autotvm::sim::devices::sim_cpu(),
        };
        let e = task.space.sample(rng);
        let prog = task.lower(&e).unwrap();
        match (dev.evaluate(&prog), dev.evaluate(&prog)) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.seconds, b.seconds, "seed {seed}: nondeterministic");
                assert!(a.seconds > 0.0 && a.gflops > 0.0);
                let m1 = dev.measure(&prog, 1).unwrap();
                let m2 = dev.measure(&prog, 1).unwrap();
                assert_eq!(m1.seconds, m2.seconds);
            }
            (Err(a), Err(b)) => assert_eq!(a, b),
            _ => panic!("seed {seed}: evaluate flip-flopped"),
        }
    });
}

#[test]
fn prop_shared_buffers_only_from_gpu_templates() {
    forall(30, |rng, _| {
        let task = random_task(rng);
        let e = task.space.sample(rng);
        let prog = task.lower(&e).unwrap();
        let has_shared =
            prog.buffers.iter().any(|b| b.scope == MemScope::Shared);
        if task.template == TemplateKind::Cpu {
            assert!(!has_shared, "CPU template produced shared memory");
        }
        // every Alloc'd buffer is declared
        fn walk(s: &Stmt, prog: &autotvm::ast::Program) {
            match s {
                Stmt::Alloc { buffer, body } => {
                    assert!(prog.buffer(buffer).is_some(), "undeclared {buffer}");
                    body.iter().for_each(|b| walk(b, prog));
                }
                Stmt::For { body, .. } => body.iter().for_each(|b| walk(b, prog)),
                Stmt::Store { buffer, .. } => {
                    assert!(prog.buffer(buffer).is_some(), "undeclared {buffer}");
                }
            }
        }
        prog.stmts.iter().for_each(|s| walk(s, &prog));
    });
}

#[test]
fn prop_factorizations_exact_cover() {
    forall(50, |rng, _| {
        let n = 1 + rng.gen_range(0..200) as i64;
        let parts = 1 + rng.gen_range(0..4);
        let fs = factorizations(n, parts);
        assert!(!fs.is_empty());
        let mut seen = std::collections::HashSet::new();
        for f in &fs {
            assert_eq!(f.len(), parts);
            assert_eq!(f.iter().product::<i64>(), n);
            assert!(seen.insert(f.clone()), "duplicate factorization {f:?}");
        }
    });
}

#[test]
fn prop_config_entity_index_roundtrip() {
    forall(30, |rng, _| {
        let task = random_task(rng);
        let e = task.space.sample(rng);
        let idx = task.space.index_of(&e);
        assert_eq!(task.space.entity(idx), e);
        assert!(idx < task.space.size());
        // boundary: first and last valid indices roundtrip too (the
        // last used to be where silent wrapping hid off-by-ones)
        assert_eq!(task.space.index_of(&task.space.entity(0)), 0);
        let last = task.space.size() - 1;
        assert_eq!(task.space.index_of(&task.space.entity(last)), last);
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { rng.gen_range(0..4) } else { rng.gen_range(0..6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_bool(0.5)),
            2 => Json::Num((rng.gen_f64() * 1e6).round() / 4.0),
            3 => {
                let n = rng.gen_range(0..12);
                Json::Str((0..n).map(|_| ('a'..='z').nth(rng.gen_range(0..26)).unwrap()).collect())
            }
            4 => Json::Arr((0..rng.gen_range(0..5)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.gen_range(0..5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall(200, |rng, seed| {
        let v = random_json(rng, 0);
        let s = v.dump();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("seed {seed}: {e}: {s}"));
        assert_eq!(v, back, "seed {seed}");
    });
}

#[test]
fn prop_diverse_select_subset_and_distinct() {
    forall(30, |rng, _| {
        let task = random_task(rng);
        let n = 20 + rng.gen_range(0..30);
        let ranked: Vec<_> = (0..n)
            .map(|i| (task.space.sample(rng), 100.0 - i as f64))
            .collect();
        let b = 1 + rng.gen_range(0..15);
        let sel = autotvm::explore::diverse_select(task.space.num_knobs(), &ranked, b, 1.0);
        assert!(sel.len() <= b.min(n));
        // all selected come from the pool
        for s in &sel {
            assert!(ranked.iter().any(|(c, _)| c == s));
        }
    });
}

#[test]
fn prop_vendor_config_always_lowers() {
    forall(40, |rng, seed| {
        let task = random_task(rng);
        let cfg = autotvm::baselines::vendor_config(&task);
        task.lower(&cfg).unwrap_or_else(|e| panic!("seed {seed}: vendor config: {e}"));
    });
}

/// Serial and pipelined tuning loops agree on structural invariants for
/// random workloads: same trial count at the same budget, monotone
/// non-decreasing best-so-far curves, every measured config a member of
/// the task's `ConfigSpace`, and no config measured twice.
#[test]
fn prop_serial_and_pipelined_loops_agree_on_invariants() {
    use autotvm::measure::SimMeasurer;
    use autotvm::tuner::{tune_gbt, tune_gbt_pipelined, TuneOptions, TuneResult};

    fn check_invariants(which: &str, seed: u64, task: &Task, res: &TuneResult) {
        for w in res.curve.windows(2) {
            assert!(w[1] >= w[0], "seed {seed} {which}: curve not monotone");
        }
        for k in [1usize, 8, 16, 32] {
            assert!(
                res.best_at(32) >= res.best_at(k),
                "seed {seed} {which}: best_at not monotone"
            );
        }
        assert_eq!(res.curve.len(), res.records.len(), "seed {seed} {which}");
        let mut uniq = std::collections::HashSet::new();
        for r in &res.records {
            assert_eq!(
                r.entity.choices.len(),
                task.space.num_knobs(),
                "seed {seed} {which}: wrong knob count"
            );
            for (j, knob) in task.space.knobs.iter().enumerate() {
                assert!(
                    (r.entity.component(j) as usize) < knob.cardinality(),
                    "seed {seed} {which}: choice out of range"
                );
            }
            assert!(task.space.index_of(&r.entity) < task.space.size(), "seed {seed} {which}");
            assert!(uniq.insert(r.entity.clone()), "seed {seed} {which}: duplicate config");
        }
    }

    forall(5, |rng, seed| {
        let task = random_task(rng);
        let dev = match task.template {
            TemplateKind::Gpu => autotvm::sim::devices::sim_gpu(),
            TemplateKind::Cpu => autotvm::sim::devices::sim_cpu(),
        };
        let o = TuneOptions {
            n_trials: 32,
            batch: 8,
            sa: autotvm::explore::SaParams { n_chains: 8, n_steps: 15, ..Default::default() },
            seed,
            pipeline_depth: 2,
            ..Default::default()
        };
        let serial =
            tune_gbt(task.clone(), &SimMeasurer::with_seed(dev.clone(), 40 + seed), o.clone());
        let piped =
            tune_gbt_pipelined(task.clone(), &SimMeasurer::with_seed(dev.clone(), 40 + seed), o);
        // spaces here are far larger than the budget, so both loops must
        // spend it fully — and therefore agree on the trial count
        assert_eq!(
            serial.curve.len(),
            piped.curve.len(),
            "seed {seed}: trial counts diverged"
        );
        assert_eq!(serial.curve.len(), 32, "seed {seed}: budget not spent");
        check_invariants("serial", seed, &task, &serial);
        check_invariants("pipelined", seed, &task, &piped);
    });
}
