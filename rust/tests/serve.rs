//! Serving-tier tests: compaction crash-safety (snapshot / torn tail /
//! interrupted rename-swap recovery), retention-policy eviction bounds,
//! the streaming save path, and the concurrent ServeConfig storm.

use autotvm::tuner::db::{Database, Record, RetentionPolicy, TOP_K};
use autotvm::tuner::serve::{fill_synthetic, query_storm, ServeConfig, StormOptions};
use autotvm::util::Rng;
use std::path::PathBuf;
use std::time::Duration;

/// Mini property harness (proptest is not vendored): run `f` over `n`
/// seeded cases, reporting the failing seed.
fn forall(n: u64, mut f: impl FnMut(&mut Rng, u64)) {
    for seed in 0..n {
        let mut rng = Rng::seed_from_u64(seed * 7919 + 13);
        f(&mut rng, seed);
    }
}

fn temp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("autotvm-serve-tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.jsonl", std::process::id()))
}

fn snap_of(path: &PathBuf) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".snap");
    PathBuf::from(os)
}

fn cleanup(path: &PathBuf) {
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(snap_of(path));
}

/// A random record: mostly valid, some errored, some NaN (invalid but
/// parseable) — the population the WAL sees in production.
fn rand_record(rng: &mut Rng, tasks: usize) -> Record {
    Record {
        task_key: format!("t{}@Serve", rng.gen_range(0..tasks)),
        target: format!("dev{}", rng.gen_range(0..2)),
        choices: vec![rng.next_u64() as u32, rng.next_u64() as u32],
        gflops: if rng.gen_bool(0.05) { f64::NAN } else { rng.gen_f64() * 100.0 },
        seconds: 1e-4,
        error: if rng.gen_bool(0.1) { Some("boom".into()) } else { None },
    }
}

/// Every shard's serving answers, comparable across reloads (record
/// indices are renumbered by compaction, so compare configs + gflops).
type Answers = Vec<((String, String), Option<(Vec<u32>, f64)>, Vec<(Vec<u32>, f64)>)>;

fn serving_answers(db: &Database) -> Answers {
    db.shard_keys()
        .into_iter()
        .map(|(t, d)| {
            let best = db.best_config(&t, &d).map(|(e, g)| (e.choices, g));
            let top: Vec<(Vec<u32>, f64)> = db
                .top_k(&t, &d, TOP_K)
                .into_iter()
                .map(|(e, g)| (e.choices, g))
                .collect();
            ((t, d), best, top)
        })
        .collect()
}

/// Compact-then-open equals never-compacted serving answers: a keep-all
/// compaction must be invisible to `best_config`/`top_k`, both live and
/// across a snapshot-then-tail reload (including post-compaction
/// appends landing on the fresh tail).
#[test]
fn prop_compact_then_open_preserves_serving() {
    forall(6, |rng, seed| {
        let path = temp_path(&format!("roundtrip-{seed}"));
        cleanup(&path);
        let db = Database::open(&path).unwrap();
        for _ in 0..rng.gen_range(30..120) {
            db.append(rand_record(rng, 5)).unwrap();
        }
        let n = db.len();
        let before = serving_answers(&db);
        let stats = db.compact(&RetentionPolicy::keep_all()).unwrap();
        assert_eq!(stats.dropped, 0, "seed {seed}: keep-all evicted records");
        assert_eq!(db.len(), n);
        assert_eq!(serving_answers(&db), before, "seed {seed}: live answers changed");
        // post-compaction appends land on the fresh tail
        let extra = rand_record(rng, 5);
        db.append(extra.clone()).unwrap();
        drop(db);
        let back = Database::open(&path).unwrap();
        assert_eq!(back.len(), n + 1, "seed {seed}: snapshot+tail reload lost records");
        let tail_rec = back.for_task(&extra.task_key, &extra.target);
        assert_eq!(tail_rec.last().unwrap().choices, extra.choices, "seed {seed}");
        // reloading again (snapshot + tail, no crash) is stable
        drop(back);
        let again = Database::open(&path).unwrap();
        assert_eq!(again.len(), n + 1);
        assert_eq!(again.snapshot_gen(), Some(1));
        cleanup(&path);
    });
}

/// A retention policy bounds every shard at top-k + newest-N while
/// leaving best/top-k answers untouched, live and across reload.
#[test]
fn prop_compact_retention_bounds_memory() {
    forall(6, |rng, seed| {
        let path = temp_path(&format!("retain-{seed}"));
        cleanup(&path);
        let db = Database::open(&path).unwrap();
        for _ in 0..rng.gen_range(100..300) {
            db.append(rand_record(rng, 3)).unwrap();
        }
        let before = serving_answers(&db);
        let newest = rng.gen_range(2..10);
        let stats = db.compact(&RetentionPolicy::newest(newest)).unwrap();
        let shards = db.shard_keys().len();
        assert!(
            db.len() <= shards * (TOP_K + newest),
            "seed {seed}: {} records retained above the {}-shard bound",
            db.len(),
            shards
        );
        assert_eq!(stats.kept, db.len());
        assert_eq!(
            serving_answers(&db),
            before,
            "seed {seed}: eviction disturbed best/top-k"
        );
        drop(db);
        let back = Database::open(&path).unwrap();
        assert_eq!(back.len(), stats.kept, "seed {seed}: reload diverged");
        assert_eq!(serving_answers(&back), before, "seed {seed}: reload answers diverged");
        cleanup(&path);
    });
}

/// Crash window 3 of the rename-swap protocol: the snapshot committed
/// but the WAL swap never happened, so the WAL still holds the full
/// pre-compaction history (with no generation marker). `open` must
/// prefer the snapshot, yield exactly the retained records, and
/// complete the swap.
#[test]
fn interrupted_rename_swap_recovers() {
    forall(4, |rng, seed| {
        let path = temp_path(&format!("swapcrash-{seed}"));
        cleanup(&path);
        let db = Database::open(&path).unwrap();
        for _ in 0..rng.gen_range(60..150) {
            db.append(rand_record(rng, 4)).unwrap();
        }
        let old_wal = std::fs::read(&path).unwrap();
        let stats = db.compact(&RetentionPolicy::newest(5)).unwrap();
        let retained = serving_answers(&db);
        let kept = db.len();
        drop(db);
        // simulate the crash: snapshot is committed, WAL swap is undone
        std::fs::write(&path, &old_wal).unwrap();
        let back = Database::open(&path).unwrap();
        assert_eq!(back.len(), kept, "seed {seed}: recovery duplicated/lost records");
        assert_eq!(
            serving_answers(&back),
            retained,
            "seed {seed}: recovered answers diverged from the retained set"
        );
        // open completed the swap: the tail is now the marker line only
        let tail = std::fs::read_to_string(&path).unwrap();
        assert_eq!(tail.lines().count(), 1, "seed {seed}: swap not completed");
        assert!(tail.contains("autotvm_wal_gen"), "seed {seed}: marker missing");
        assert_eq!(back.snapshot_gen(), Some(stats.gen));
        drop(back);
        // and the recovered state is stable across another reload
        let again = Database::open(&path).unwrap();
        assert_eq!(again.len(), kept);
        assert_eq!(serving_answers(&again), retained, "seed {seed}: second reload");
        cleanup(&path);
    });
}

/// Crash window 1 after a compaction: a torn trailing line on the fresh
/// tail is dropped and truncated, keeping every durable record.
#[test]
fn torn_tail_after_compaction_recovers() {
    let path = temp_path("torntail");
    cleanup(&path);
    let mut rng = Rng::seed_from_u64(99);
    let db = Database::open(&path).unwrap();
    for _ in 0..50 {
        db.append(rand_record(&mut rng, 3)).unwrap();
    }
    db.compact(&RetentionPolicy::keep_all()).unwrap();
    db.append(rand_record(&mut rng, 3)).unwrap();
    db.append(rand_record(&mut rng, 3)).unwrap();
    drop(db);
    // crash mid-append: an unparseable fragment with no newline
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"task\":\"t0@S").unwrap();
    }
    let back = Database::open(&path).unwrap();
    assert_eq!(back.len(), 52, "torn tail cost durable records");
    // the fragment was truncated from the file, so appends start clean
    back.append(rand_record(&mut rng, 3)).unwrap();
    drop(back);
    assert_eq!(Database::open(&path).unwrap().len(), 53);
    cleanup(&path);
}

/// Crash window 2: leftover `.tmp` staging files (snapshot or WAL) from
/// a compaction that died before its rename are ignored and removed.
#[test]
fn staging_leftovers_are_ignored() {
    let path = temp_path("staging");
    cleanup(&path);
    let mut rng = Rng::seed_from_u64(7);
    {
        let db = Database::open(&path).unwrap();
        for _ in 0..20 {
            db.append(rand_record(&mut rng, 2)).unwrap();
        }
    }
    let snap_tmp = {
        let mut os = snap_of(&path).into_os_string();
        os.push(".tmp");
        PathBuf::from(os)
    };
    let wal_tmp = {
        let mut os = path.clone().into_os_string();
        os.push(".tmp");
        PathBuf::from(os)
    };
    std::fs::write(&snap_tmp, "half-written garbage").unwrap();
    std::fs::write(&wal_tmp, "more garbage").unwrap();
    let db = Database::open(&path).unwrap();
    assert_eq!(db.len(), 20, "staging garbage corrupted the load");
    assert!(!snap_tmp.exists(), "stale snapshot staging file not removed");
    assert!(!wal_tmp.exists(), "stale WAL staging file not removed");
    cleanup(&path);
}

/// A WAL that declares a snapshot generation without its snapshot file
/// is an inconsistent pair, not silently-loadable data.
#[test]
fn marker_without_snapshot_is_rejected() {
    let path = temp_path("orphan-marker");
    cleanup(&path);
    std::fs::write(&path, "{\"autotvm_wal_gen\":3}\n").unwrap();
    assert!(Database::open(&path).is_err(), "orphaned WAL marker must not open");
    cleanup(&path);
}

/// Satellite regression (streaming save): `save` streams shard-by-shard
/// through the Write sink and its output round-trips exactly.
#[test]
fn streaming_save_matches_records() {
    let path = temp_path("stream-save");
    cleanup(&path);
    let db = Database::new();
    let mut rng = Rng::seed_from_u64(21);
    for _ in 0..200 {
        // no NaN here: Record equality below is exact
        let mut r = rand_record(&mut rng, 6);
        if r.gflops.is_nan() {
            r.gflops = 1.0;
        }
        db.append(r).unwrap();
    }
    db.save(&path).unwrap();
    let back = Database::load(&path).unwrap();
    assert_eq!(back.len(), db.len());
    assert_eq!(back.records(), db.records(), "streamed save lost ordering or data");
    // write_jsonl agrees with save byte-for-byte
    let mut buf: Vec<u8> = Vec::new();
    db.write_jsonl(&mut buf).unwrap();
    assert_eq!(buf, std::fs::read(&path).unwrap());
    cleanup(&path);
}

/// The ServeConfig front-end under concurrent readers and a live
/// writer: lookups succeed, latency percentiles are recorded, and the
/// DB keeps growing under the storm.
#[test]
fn serve_config_concurrent_storm() {
    let db = Database::new();
    fill_synthetic(&db, 500, 8, 2, 3);
    assert_eq!(db.len(), 500);
    let serve = ServeConfig::new(db.clone());
    let report = query_storm(
        &serve,
        &StormOptions {
            threads: 8,
            writers: 1,
            duration: Duration::from_millis(200),
            seed: 11,
        },
    );
    assert!(report.lookups > 0, "storm issued no lookups");
    assert!(report.hits > 0, "filled DB served no hits");
    assert!(report.writes > 0, "live writer appended nothing");
    assert!(report.qps > 0.0);
    assert!(report.p50_ns <= report.p99_ns);
    assert!(db.len() > 500, "writer appends not visible in the shared DB");
}
