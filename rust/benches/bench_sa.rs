//! Simulated-annealing proposal throughput (128-chain step rate).
//!
//! Beyond the cheap-scorer machinery baseline, the model-guided cases
//! time the real SA inner loop the tuner runs: score every neighbor
//! batch with a trained GBT under the Config representation, scalar
//! reference (full re-extraction + scalar tree walk) vs fast paths
//! (incremental per-knob featurization + compiled [`PredictPlan`]).
//! A second model-bound configuration runs under `ContextRelation`,
//! pitting structure-cached delta featurization against the
//! memoize-only baseline (`speedup_delta_vs_fresh`). Every pairing is
//! asserted to pick bit-identical candidates before timing.
//! Emits `BENCH_sa.json`.
//!
//! [`PredictPlan`]: autotvm::gbt::PredictPlan
mod harness;

use autotvm::explore::{ParallelSa, SaParams, Scorer};
use autotvm::model::{CostModel, GbtModel};
use autotvm::schedule::space::ConfigEntity;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::tuner::Featurizer;
use autotvm::util::bench::Bench;
use autotvm::util::Rng;
use autotvm::workloads;

/// The tuner's scoring shape, rebuilt from public parts (the in-crate
/// `TunerScorer` is private): features through a [`Featurizer`], scores
/// through a [`CostModel`], neighbor batches through the incremental
/// path when the featurizer allows it.
struct ModelScorer<'a> {
    task: &'a Task,
    feat: Featurizer,
    model: &'a GbtModel,
}

impl Scorer for ModelScorer<'_> {
    fn score(&self, entities: &[ConfigEntity]) -> Vec<f64> {
        self.model.predict(&self.feat.features(self.task, entities))
    }

    fn score_neighbors(
        &self,
        parents: &[ConfigEntity],
        proposals: &[ConfigEntity],
        knobs: &[usize],
    ) -> Vec<f64> {
        if let Some(x) = self.feat.neighbor_features(self.task, parents, proposals, knobs) {
            return self.model.predict(&x);
        }
        self.score(proposals)
    }
}

fn main() {
    let mut b = Bench::new("sa");
    let mut report = harness::Report::new("sa");
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    // cheap synthetic scorer isolates SA machinery from featurization
    let scorer = |es: &[ConfigEntity]| -> Vec<f64> {
        es.iter().map(|e| e.choices.iter().map(|&c| c as f64).sum()).collect()
    };
    let mut rng = Rng::seed_from_u64(3);
    b.run("sa_128x100_steps_cheap_scorer", || {
        let mut sa = ParallelSa::new(SaParams { n_chains: 128, n_steps: 100, ..Default::default() });
        Scorer::score(&scorer, &[]); // keep trait in scope
        sa.collect(&task.space, &scorer, 128, &mut rng)
    });
    b.run("mutate_128", || {
        (0..128).map(|_| task.space.sample(&mut rng)).collect::<Vec<_>>()
    });

    // --- model-guided collect: the tuner's actual inner loop ---
    // Train one GBT per path on identical data (Config representation);
    // the fast model carries a compiled plan, the scalar one does not.
    let train_feat = Featurizer::new(autotvm::features::Representation::Config);
    let configs: Vec<ConfigEntity> =
        (0..512).map(|_| task.space.sample(&mut rng)).collect();
    let x = train_feat.features(&task, &configs);
    let y: Vec<f64> = configs
        .iter()
        .map(|e| e.choices.iter().map(|&c| (c as f64 + 1.0).ln()).sum())
        .collect();
    let mut fast_model = GbtModel::with_fast_paths(Default::default(), true);
    fast_model.fit(&x, &y, &[]);
    let mut scalar_model = GbtModel::with_fast_paths(Default::default(), false);
    scalar_model.fit(&x, &y, &[]);

    let sa_params = SaParams { n_chains: 64, n_steps: 60, ..Default::default() };

    // Identical candidates from both paths (fixed RNG stream) — the
    // fast path must change wall-clock only.
    let run_collect = |model: &GbtModel, fast: bool, seed: u64| {
        let scorer = ModelScorer {
            task: &task,
            feat: Featurizer::with_fast(autotvm::features::Representation::Config, fast),
            model,
        };
        let mut sa = ParallelSa::new(sa_params.clone());
        let mut r = Rng::seed_from_u64(seed);
        sa.collect(&task.space, &scorer, 128, &mut r)
    };
    let a = run_collect(&scalar_model, false, 77);
    let c = run_collect(&fast_model, true, 77);
    assert_eq!(a.len(), c.len());
    for ((ea, sa_), (ec, sc)) in a.iter().zip(&c) {
        assert_eq!(ea, ec, "fast SA path picked different candidates");
        assert_eq!(sa_.to_bits(), sc.to_bits(), "fast SA path changed scores");
    }

    let scalar = b.run("sa_collect_model_scalar", || run_collect(&scalar_model, false, 5));
    let fast = b.run("sa_collect_model_fast", || run_collect(&fast_model, true, 5));
    let speedup = scalar.mean_ns / fast.mean_ns;
    println!("sa/fast_collect_speedup                           {speedup:.2}x");

    // --- model-bound ContextRelation collect: delta vs memoize-only ---
    // Same plan-compiled model on both sides; only featurization
    // differs. `fast=false` is the pre-delta baseline (full extraction
    // with whole-row memoization), `fast=true` replays the structure
    // cache per neighbor. Featurizers are rebuilt per run, so each
    // timed iteration starts with cold caches, like a fresh tune.
    let ctx_repr = autotvm::features::Representation::ContextRelation;
    let cx = Featurizer::new(ctx_repr).features(&task, &configs);
    let mut ctx_model = GbtModel::with_fast_paths(Default::default(), true);
    ctx_model.fit(&cx, &y, &[]);
    let run_ctx = |fast_feat: bool, seed: u64| {
        let scorer = ModelScorer {
            task: &task,
            feat: Featurizer::with_fast(ctx_repr, fast_feat),
            model: &ctx_model,
        };
        let mut sa = ParallelSa::new(sa_params.clone());
        let mut r = Rng::seed_from_u64(seed);
        sa.collect(&task.space, &scorer, 128, &mut r)
    };
    // Bit-identical trial sequence before any timing.
    let m = run_ctx(false, 77);
    let d = run_ctx(true, 77);
    assert_eq!(m.len(), d.len());
    for ((em, sm), (ed, sd)) in m.iter().zip(&d) {
        assert_eq!(em, ed, "delta SA path picked different candidates");
        assert_eq!(sm.to_bits(), sd.to_bits(), "delta SA path changed scores");
    }
    let memo = b.run("sa_collect_context_memoized", || run_ctx(false, 5));
    let delta = b.run("sa_collect_context_delta", || run_ctx(true, 5));
    let delta_speedup = memo.mean_ns / delta.mean_ns;
    println!("sa/speedup_delta_vs_fresh                         {delta_speedup:.2}x");
    // Full-scale runs must clear 2x; short CI smokes (tiny
    // BENCH_MEASURE_SECS budgets) only gate on >= 1 via the recorded
    // JSON field, so the hard assert is opt-in.
    if std::env::var("BENCH_ASSERT_FULL_SCALE").is_ok() {
        assert!(
            delta_speedup >= 2.0,
            "delta featurization speedup {delta_speedup:.2}x < 2x at full scale"
        );
    }

    report.import(&b);
    report.field("fast_collect_speedup", speedup.into());
    report.field("speedup_delta_vs_fresh", delta_speedup.into());
    report.write();
}
