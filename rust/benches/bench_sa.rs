//! Simulated-annealing proposal throughput (128-chain step rate).
use autotvm::explore::{ParallelSa, SaParams, Scorer};
use autotvm::schedule::space::ConfigEntity;
use autotvm::schedule::template::TemplateKind;
use autotvm::util::bench::Bench;
use autotvm::util::Rng;
use autotvm::workloads;

fn main() {
    let mut b = Bench::new("sa");
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    // cheap synthetic scorer isolates SA machinery from featurization
    let scorer = |es: &[ConfigEntity]| -> Vec<f64> {
        es.iter().map(|e| e.choices.iter().map(|&c| c as f64).sum()).collect()
    };
    let mut rng = Rng::seed_from_u64(3);
    b.run("sa_128x100_steps_cheap_scorer", || {
        let mut sa = ParallelSa::new(SaParams { n_chains: 128, n_steps: 100, ..Default::default() });
        Scorer::score(&scorer, &[]); // keep trait in scope
        sa.collect(&task.space, &scorer, 128, &mut rng)
    });
    b.run("mutate_128", || {
        (0..128).map(|_| task.space.sample(&mut rng)).collect::<Vec<_>>()
    });
}
