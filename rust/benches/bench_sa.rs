//! Simulated-annealing proposal throughput (128-chain step rate).
//!
//! Beyond the cheap-scorer machinery baseline, the model-guided cases
//! time the real SA inner loop the tuner runs: score every neighbor
//! batch with a trained GBT under the Config representation, scalar
//! reference (full re-extraction + scalar tree walk) vs fast paths
//! (incremental per-knob featurization + compiled [`PredictPlan`]).
//! Both are asserted to pick identical candidates before timing.
//! Emits `BENCH_sa.json`.
//!
//! [`PredictPlan`]: autotvm::gbt::PredictPlan
mod harness;

use autotvm::explore::{ParallelSa, SaParams, Scorer};
use autotvm::model::{CostModel, GbtModel};
use autotvm::schedule::space::ConfigEntity;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::tuner::Featurizer;
use autotvm::util::bench::Bench;
use autotvm::util::Rng;
use autotvm::workloads;

/// The tuner's scoring shape, rebuilt from public parts (the in-crate
/// `TunerScorer` is private): features through a [`Featurizer`], scores
/// through a [`CostModel`], neighbor batches through the incremental
/// path when the featurizer allows it.
struct ModelScorer<'a> {
    task: &'a Task,
    feat: Featurizer,
    model: &'a GbtModel,
}

impl Scorer for ModelScorer<'_> {
    fn score(&self, entities: &[ConfigEntity]) -> Vec<f64> {
        self.model.predict(&self.feat.features(self.task, entities))
    }

    fn score_neighbors(
        &self,
        parents: &[ConfigEntity],
        proposals: &[ConfigEntity],
        knobs: &[usize],
    ) -> Vec<f64> {
        if let Some(x) = self.feat.neighbor_features(self.task, parents, proposals, knobs) {
            return self.model.predict(&x);
        }
        self.score(proposals)
    }
}

fn main() {
    let mut b = Bench::new("sa");
    let mut report = harness::Report::new("sa");
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    // cheap synthetic scorer isolates SA machinery from featurization
    let scorer = |es: &[ConfigEntity]| -> Vec<f64> {
        es.iter().map(|e| e.choices.iter().map(|&c| c as f64).sum()).collect()
    };
    let mut rng = Rng::seed_from_u64(3);
    b.run("sa_128x100_steps_cheap_scorer", || {
        let mut sa = ParallelSa::new(SaParams { n_chains: 128, n_steps: 100, ..Default::default() });
        Scorer::score(&scorer, &[]); // keep trait in scope
        sa.collect(&task.space, &scorer, 128, &mut rng)
    });
    b.run("mutate_128", || {
        (0..128).map(|_| task.space.sample(&mut rng)).collect::<Vec<_>>()
    });

    // --- model-guided collect: the tuner's actual inner loop ---
    // Train one GBT per path on identical data (Config representation);
    // the fast model carries a compiled plan, the scalar one does not.
    let train_feat = Featurizer::new(autotvm::features::Representation::Config);
    let configs: Vec<ConfigEntity> =
        (0..512).map(|_| task.space.sample(&mut rng)).collect();
    let x = train_feat.features(&task, &configs);
    let y: Vec<f64> = configs
        .iter()
        .map(|e| e.choices.iter().map(|&c| (c as f64 + 1.0).ln()).sum())
        .collect();
    let mut fast_model = GbtModel::with_fast_paths(Default::default(), true);
    fast_model.fit(&x, &y, &[]);
    let mut scalar_model = GbtModel::with_fast_paths(Default::default(), false);
    scalar_model.fit(&x, &y, &[]);

    let sa_params = SaParams { n_chains: 64, n_steps: 60, ..Default::default() };

    // Identical candidates from both paths (fixed RNG stream) — the
    // fast path must change wall-clock only.
    let run_collect = |model: &GbtModel, fast: bool, seed: u64| {
        let scorer = ModelScorer {
            task: &task,
            feat: Featurizer::with_fast(autotvm::features::Representation::Config, fast),
            model,
        };
        let mut sa = ParallelSa::new(sa_params.clone());
        let mut r = Rng::seed_from_u64(seed);
        sa.collect(&task.space, &scorer, 128, &mut r)
    };
    let a = run_collect(&scalar_model, false, 77);
    let c = run_collect(&fast_model, true, 77);
    assert_eq!(a.len(), c.len());
    for ((ea, sa_), (ec, sc)) in a.iter().zip(&c) {
        assert_eq!(ea, ec, "fast SA path picked different candidates");
        assert_eq!(sa_.to_bits(), sc.to_bits(), "fast SA path changed scores");
    }

    let scalar = b.run("sa_collect_model_scalar", || run_collect(&scalar_model, false, 5));
    let fast = b.run("sa_collect_model_fast", || run_collect(&fast_model, true, 5));
    let speedup = scalar.mean_ns / fast.mean_ns;
    println!("sa/fast_collect_speedup                           {speedup:.2}x");

    report.import(&b);
    report.field("fast_collect_speedup", speedup.into());
    report.write();
}
