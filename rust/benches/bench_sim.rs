//! Simulator evaluation throughput — f(x) queries per second.
use autotvm::schedule::template::TemplateKind;
use autotvm::sim::devices::{sim_cpu, sim_gpu};
use autotvm::util::bench::Bench;
use autotvm::util::Rng;
use autotvm::workloads;

fn main() {
    let mut b = Bench::new("sim");
    let mut rng = Rng::seed_from_u64(1);
    for (name, task, dev) in [
        ("conv_c6_gpu", workloads::conv_task(6, TemplateKind::Gpu), sim_gpu()),
        ("conv_c1_cpu", workloads::conv_task(1, TemplateKind::Cpu), sim_cpu()),
        ("matmul1024_gpu", workloads::matmul_1024_task(TemplateKind::Gpu), sim_gpu()),
    ] {
        let e = task.space.sample(&mut rng);
        let prog = task.lower(&e).unwrap();
        b.run(&format!("evaluate_{name}"), || dev.evaluate(&prog));
        b.run(&format!("lower_and_evaluate_{name}"), || {
            dev.evaluate(&task.lower(&e).unwrap())
        });
    }
}
