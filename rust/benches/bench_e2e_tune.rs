//! End-to-end tuning wall-clock — the L3 hot path.
//!
//! Five cases at the same trial budget:
//! * serial loop on a single simulated board (the Algorithm-1 baseline),
//! * serial loop on a single board with per-board RTT — the makespan
//!   reference the device-farm service must beat,
//! * serial loop on a 4-replica in-place device farm with per-board
//!   latency,
//! * pipelined loop (explore ∥ measure ∥ retrain) on the same in-place
//!   farm,
//! * pipelined loop through the asynchronous [`MeasureService`] over a
//!   4-replica RTT farm — batches shard across replica workers *and*
//!   batch `k+1` measures while batch `k` drains.
//!
//! The farm latency emulates the RPC + run time of the paper's remote
//! boards. Acceptance: the service-backed pipelined makespan must come
//! in **under 0.5×** the single-board serial makespan (the final ratio
//! line), while depth-1 single-replica service output stays bit-for-bit
//! identical to the serial loop (asserted in `tests/farm_service.rs`).
//!
//! `E2E_TUNE_SMOKE=1` shrinks the budget for CI check-only runs.
//!
//! A final **model-bound** configuration isolates the hot-path speed
//! pass: instant simulated measurement + a heavy SA budget under the
//! Config representation, so wall-clock is dominated by model queries
//! and featurization. The same fixed-seed run is timed with the fast
//! paths off (scalar tree walk, full per-neighbor re-extraction) and on
//! (compiled [`PredictPlan`], incremental featurization); results are
//! asserted bit-identical and the trials/sec ratio is recorded in
//! `BENCH_e2e_tune.json`. Acceptance (full scale only): ≥ 2×.
//!
//! [`MeasureService`]: autotvm::measure::service::MeasureService
//! [`PredictPlan`]: autotvm::gbt::PredictPlan
mod harness;

use autotvm::explore::SaParams;
use autotvm::features::Representation;
use autotvm::measure::farm::{DeviceFarm, LatencyMeasurer};
use autotvm::measure::service::MeasureService;
use autotvm::measure::SimMeasurer;
use autotvm::schedule::template::TemplateKind;
use autotvm::sim::devices::sim_gpu;
use autotvm::tuner::db::Database;
use autotvm::tuner::scheduler::{AllocPolicy, SchedulerOptions, TaskScheduler};
use autotvm::tuner::{tune_gbt, tune_gbt_pipelined, TuneOptions};
use autotvm::util::bench::Bench;
use autotvm::workloads;
use autotvm::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let smoke = std::env::var("E2E_TUNE_SMOKE").is_ok();
    let mut b = Bench::new("e2e_tune");
    let mut report = harness::Report::new("e2e_tune");
    let opts = TuneOptions {
        n_trials: if smoke { 32 } else { 128 },
        batch: 32,
        sa: SaParams {
            n_chains: if smoke { 16 } else { 64 },
            n_steps: if smoke { 20 } else { 60 },
            ..Default::default()
        },
        ..Default::default()
    };
    let rtt = Duration::from_millis(2);
    let task = || workloads::conv_task(6, TemplateKind::Gpu);
    let farm = || DeviceFarm::with_latency(sim_gpu(), 4, 1, rtt);

    b.run("tune_c6_serial_sim", {
        let opts = opts.clone();
        move || {
            let m = SimMeasurer::with_seed(sim_gpu(), 1);
            tune_gbt(task(), &m, opts.clone())
        }
    });
    let serial_one = b.run("tune_c6_serial_board1_rtt", {
        let opts = opts.clone();
        move || {
            let m = LatencyMeasurer { inner: SimMeasurer::with_seed(sim_gpu(), 1), latency: rtt };
            tune_gbt(task(), &m, opts.clone())
        }
    });
    let serial = b.run("tune_c6_serial_farm4", {
        let opts = opts.clone();
        move || tune_gbt(task(), &farm(), opts.clone())
    });
    let piped = b.run("tune_c6_pipelined_farm4", {
        let opts = opts.clone();
        move || tune_gbt_pipelined(task(), &farm(), opts.clone())
    });
    let service = b.run("tune_c6_pipelined_service_farm4", {
        let opts = opts.clone();
        move || {
            let svc = MeasureService::with_defaults(Arc::new(farm()));
            tune_gbt_pipelined(task(), &svc, opts.clone())
        }
    });
    println!(
        "e2e_tune/pipeline_speedup_over_serial_farm4       {:.2}x",
        serial.mean_ns / piped.mean_ns
    );
    println!(
        "e2e_tune/service_speedup_over_serial_farm4        {:.2}x",
        serial.mean_ns / service.mean_ns
    );
    // The acceptance ratio: pipelined-through-service on 4 RTT replicas
    // vs the serial single-board makespan. Must print below 0.50.
    println!(
        "e2e_tune/service_makespan_vs_serial_board1        {:.2}x (target < 0.50x)",
        service.mean_ns / serial_one.mean_ns
    );

    // Graph-scheduler makespan: barrier slices vs overlap-2 slices
    // across three tasks on the same 4-replica RTT farm service. The
    // overlapped scheduler keeps task B proposing/refitting while task
    // A's batches drain, so its makespan shrinks and its farm
    // utilization rises at identical total budget.
    let sched_budget = if smoke { 48 } else { 144 };
    // Utilization of the most recent timed run per case, captured from
    // inside the bench closure so no extra (untimed) run is needed.
    let barrier_util = std::cell::Cell::new(0.0f64);
    let overlap_util = std::cell::Cell::new(0.0f64);
    let sched_run = |overlap: usize, util: &std::cell::Cell<f64>| {
        let svc = MeasureService::with_defaults(Arc::new(farm()));
        let db = Database::new();
        let sched = TaskScheduler::for_tasks(
            vec![
                workloads::conv_task(2, TemplateKind::Gpu),
                workloads::conv_task(6, TemplateKind::Gpu),
                workloads::conv_task(9, TemplateKind::Gpu),
            ],
            SchedulerOptions {
                budget: sched_budget,
                slice: 16,
                policy: AllocPolicy::Gradient,
                overlap,
                ..Default::default()
            },
        );
        let alloc = sched.run_tuning(&svc, &db, opts.clone(), false, false);
        util.set(svc.stats().utilization());
        alloc
    };
    let sched_barrier =
        b.run("sched_barrier_service_farm4", || sched_run(1, &barrier_util));
    let sched_overlap =
        b.run("sched_overlap2_service_farm4", || sched_run(2, &overlap_util));
    println!(
        "e2e_tune/sched_overlap2_makespan_vs_barrier       {:.2}x (lower is better)",
        sched_overlap.mean_ns / sched_barrier.mean_ns
    );
    let (bu, ou) = (barrier_util.get(), overlap_util.get());
    println!(
        "e2e_tune/sched_overlap2_utilization_vs_barrier    {ou:.2}x vs {bu:.2}x \
         (ratio {:.2})",
        ou / bu.max(1e-9)
    );

    // --- model-bound configuration: the hot-path speed pass ---
    // Instant measurement + heavy SA budget under the Config
    // representation: wall-clock is model queries + featurization, the
    // exact surface the compiled plan and the incremental featurizer
    // accelerate. Scalar and fast runs share one seed and are timed in
    // this same process run.
    let model_bound = TuneOptions {
        n_trials: if smoke { 48 } else { 192 },
        batch: 16,
        repr: Representation::Config,
        sa: SaParams {
            n_chains: if smoke { 32 } else { 128 },
            n_steps: if smoke { 40 } else { 300 },
            ..Default::default()
        },
        seed: 7,
        ..Default::default()
    };
    let timed_run = |fast: bool| {
        let mut o = model_bound.clone();
        o.fast_paths = fast;
        let m = SimMeasurer::with_seed(sim_gpu(), 2);
        let t0 = Instant::now();
        let res = tune_gbt(task(), &m, o);
        (res, t0.elapsed())
    };
    let (res_scalar, dt_scalar) = timed_run(false);
    let (res_fast, dt_fast) = timed_run(true);
    // Fast paths are bit-exact: same trials, same curve, same best.
    assert_eq!(res_scalar.curve, res_fast.curve, "fast paths changed the tuning curve");
    assert_eq!(
        res_scalar.records.iter().map(|r| &r.entity).collect::<Vec<_>>(),
        res_fast.records.iter().map(|r| &r.entity).collect::<Vec<_>>(),
        "fast paths changed the trial sequence"
    );
    let trials = res_fast.curve.len() as f64;
    let tps_scalar = trials / dt_scalar.as_secs_f64();
    let tps_fast = trials / dt_fast.as_secs_f64();
    let speedup = tps_fast / tps_scalar;
    println!(
        "e2e_tune/model_bound_trials_per_sec               scalar {tps_scalar:.1} \
         fast {tps_fast:.1} ({speedup:.2}x, target >= 2.00x at full scale)"
    );
    if !smoke {
        assert!(
            speedup >= 2.0,
            "model-bound fast-path speedup {speedup:.2}x below the 2x acceptance bar"
        );
    }

    report.import(&b);
    report.field("smoke", Json::from(smoke));
    report.field("model_bound_trials", Json::from(trials));
    report.field("trials_per_sec_scalar", Json::from(tps_scalar));
    report.field("trials_per_sec_fast", Json::from(tps_fast));
    report.field("speedup_trials_per_sec", Json::from(speedup));
    report.write();
}
