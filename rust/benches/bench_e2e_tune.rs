//! One full Algorithm-1 tuning round (SA collect + diversity select +
//! batch measure + model refit) — the end-to-end L3 hot path.
use autotvm::explore::SaParams;
use autotvm::measure::SimMeasurer;
use autotvm::schedule::template::TemplateKind;
use autotvm::sim::devices::sim_gpu;
use autotvm::tuner::{tune_gbt, TuneOptions};
use autotvm::util::bench::Bench;
use autotvm::workloads;

fn main() {
    let mut b = Bench::new("e2e_tune");
    let opts = TuneOptions {
        n_trials: 128,
        batch: 64,
        sa: SaParams { n_chains: 64, n_steps: 60, ..Default::default() },
        ..Default::default()
    };
    b.run("tune_c6_128_trials", || {
        let task = workloads::conv_task(6, TemplateKind::Gpu);
        let m = SimMeasurer::with_seed(sim_gpu(), 1);
        tune_gbt(task, &m, opts.clone())
    });
}
