//! End-to-end tuning wall-clock — the L3 hot path.
//!
//! Three cases at the same trial budget:
//! * serial loop on a single simulated board (the Algorithm-1 baseline),
//! * serial loop on a 4-replica device farm with per-board latency,
//! * pipelined loop (explore ∥ measure ∥ retrain) on the same farm.
//!
//! The farm latency emulates the RPC + run time of the paper's remote
//! boards; the pipelined loop should hide SA and GBT refits behind it,
//! so the last case must come in measurably under the second.
//!
//! `E2E_TUNE_SMOKE=1` shrinks the budget for CI check-only runs.

use autotvm::explore::SaParams;
use autotvm::measure::farm::DeviceFarm;
use autotvm::measure::SimMeasurer;
use autotvm::schedule::template::TemplateKind;
use autotvm::sim::devices::sim_gpu;
use autotvm::tuner::{tune_gbt, tune_gbt_pipelined, TuneOptions};
use autotvm::util::bench::Bench;
use autotvm::workloads;
use std::time::Duration;

fn main() {
    let smoke = std::env::var("E2E_TUNE_SMOKE").is_ok();
    let mut b = Bench::new("e2e_tune");
    let opts = TuneOptions {
        n_trials: if smoke { 32 } else { 128 },
        batch: 32,
        sa: SaParams {
            n_chains: if smoke { 16 } else { 64 },
            n_steps: if smoke { 20 } else { 60 },
            ..Default::default()
        },
        ..Default::default()
    };
    let task = || workloads::conv_task(6, TemplateKind::Gpu);
    let farm = || DeviceFarm::with_latency(sim_gpu(), 4, 1, Duration::from_millis(2));

    b.run("tune_c6_serial_sim", {
        let opts = opts.clone();
        move || {
            let m = SimMeasurer::with_seed(sim_gpu(), 1);
            tune_gbt(task(), &m, opts.clone())
        }
    });
    let serial = b.run("tune_c6_serial_farm4", {
        let opts = opts.clone();
        move || tune_gbt(task(), &farm(), opts.clone())
    });
    let piped = b.run("tune_c6_pipelined_farm4", {
        let opts = opts.clone();
        move || tune_gbt_pipelined(task(), &farm(), opts.clone())
    });
    println!(
        "e2e_tune/pipeline_speedup_over_serial_farm4       {:.2}x",
        serial.mean_ns / piped.mean_ns
    );
}
