//! GBT cost-model train/predict throughput (paper §2: "model training
//! and inference must be fast ... otherwise no benefit over profiling").
use autotvm::gbt::{Gbt, GbtParams, Matrix, Objective};
use autotvm::util::bench::Bench;
use autotvm::util::Rng;

fn synth(n: usize, cols: usize, seed: u64) -> (Matrix, Vec<f64>) {
    let mut rng = Rng::seed_from_u64(seed);
    let data: Vec<f32> = (0..n * cols).map(|_| rng.gen_f64() as f32).collect();
    let x = Matrix::new(n, cols, data);
    let y: Vec<f64> = (0..n).map(|i| x.row(i)[0] as f64 * 2.0 - x.row(i)[1] as f64).collect();
    (x, y)
}

fn main() {
    let mut b = Bench::new("gbt");
    let (x1k, y1k) = synth(1000, 361, 1); // FULL_DIM-sized features
    let (x8k, y8k) = synth(8000, 361, 2);
    let params = GbtParams { objective: Objective::Rank, ..Default::default() };

    b.run("train_1k_rows_50_trees", || Gbt::train(&x1k, &y1k, &[], params.clone()));
    let model = Gbt::train(&x8k, &y8k, &[], params.clone());
    let s = b.run("predict_8k_rows", || model.predict_batch(&x8k));
    let _ = s;
    b.throughput("predict_8k_rows", 8000.0, "rows");
    let (x128, _) = synth(128, 361, 3);
    b.run("predict_sa_batch_128", || model.predict_batch(&x128));
}
