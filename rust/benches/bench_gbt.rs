//! GBT cost-model train/predict throughput (paper §2: "model training
//! and inference must be fast ... otherwise no benefit over profiling").
//!
//! The headline comparison is scalar pointer-chasing `predict_batch`
//! vs the compiled [`PredictPlan`] (binned SoA arena, tree-at-a-time
//! over row blocks) on the SA-sized batches the tuner actually issues.
//! Both paths are asserted bit-identical before timing. Emits
//! `BENCH_gbt.json` with a recorded `plan_speedup_8k` ratio.
//!
//! [`PredictPlan`]: autotvm::gbt::PredictPlan
mod harness;

use autotvm::gbt::{Gbt, GbtParams, Matrix, Objective};
use autotvm::util::bench::Bench;
use autotvm::util::json::Json;
use autotvm::util::Rng;

fn synth(n: usize, cols: usize, seed: u64) -> (Matrix, Vec<f64>) {
    let mut rng = Rng::seed_from_u64(seed);
    let data: Vec<f32> = (0..n * cols).map(|_| rng.gen_f64() as f32).collect();
    let x = Matrix::new(n, cols, data);
    let y: Vec<f64> = (0..n).map(|i| x.row(i)[0] as f64 * 2.0 - x.row(i)[1] as f64).collect();
    (x, y)
}

fn main() {
    let mut b = Bench::new("gbt");
    let mut report = harness::Report::new("gbt");
    let (x1k, y1k) = synth(1000, 361, 1); // FULL_DIM-sized features
    let (x8k, y8k) = synth(8000, 361, 2);
    let params = GbtParams { objective: Objective::Rank, ..Default::default() };

    b.run("train_1k_rows_50_trees", || Gbt::train(&x1k, &y1k, &[], params.clone()));
    let model = Gbt::train(&x8k, &y8k, &[], params.clone());
    let plan = model.compile();
    println!(
        "gbt: plan has {} trees / {} nodes (narrow bins: {})",
        plan.n_trees(),
        plan.n_nodes(),
        plan.is_narrow()
    );
    // The toggle exists because the plan is bit-exact — prove it before
    // timing anything.
    for x in [&x8k, &x1k] {
        let a = model.predict_batch(x);
        let p = plan.predict_batch(x);
        assert_eq!(a.len(), p.len());
        for (l, r) in a.iter().zip(&p) {
            assert_eq!(l.to_bits(), r.to_bits(), "plan diverged from scalar walk");
        }
    }

    b.run("compile_plan", || model.compile());
    let scalar = b.run("predict_8k_rows_scalar", || model.predict_batch(&x8k));
    let planned = b.run("predict_8k_rows_plan", || plan.predict_batch(&x8k));
    b.throughput("predict_8k_rows_plan", 8000.0, "rows");
    let speedup = scalar.mean_ns / planned.mean_ns;
    println!("gbt/plan_speedup_8k                               {speedup:.2}x");

    // SA-sized batch (the per-step proposal pool of the tuner loop).
    let (x128, _) = synth(128, 361, 3);
    let scalar128 = b.run("predict_sa_batch_128_scalar", || model.predict_batch(&x128));
    let plan128 = b.run("predict_sa_batch_128_plan", || plan.predict_batch(&x128));
    println!(
        "gbt/plan_speedup_sa_128                           {:.2}x",
        scalar128.mean_ns / plan128.mean_ns
    );

    // Parallel-cutoff sweep: where row-parallel prediction starts to pay.
    for cutoff in [usize::MAX, 256] {
        let p = GbtParams {
            objective: Objective::Rank,
            parallel_cutoff: cutoff,
            ..Default::default()
        };
        let m = Gbt::train(&x8k, &y8k, &[], p);
        let label = if cutoff == usize::MAX {
            "predict_8k_serial_cutoff_off"
        } else {
            "predict_8k_parallel_cutoff_256"
        };
        b.run(label, || m.predict_batch(&x8k));
    }

    report.import(&b);
    report.field("plan_speedup_8k", Json::from(speedup));
    report.field("plan_trees", Json::from(plan.n_trees()));
    report.field("plan_nodes", Json::from(plan.n_nodes()));
    report.write();
}
