//! Lowering throughput g(e, s) across operator classes.
use autotvm::schedule::template::TemplateKind;
use autotvm::util::bench::Bench;
use autotvm::util::Rng;
use autotvm::workloads;

fn main() {
    let mut b = Bench::new("lower");
    let mut rng = Rng::seed_from_u64(2);
    for (name, task) in [
        ("conv_c1_gpu", workloads::conv_task(1, TemplateKind::Gpu)),
        ("conv_c12_cpu", workloads::conv_task(12, TemplateKind::Cpu)),
        ("matmul1024_gpu", workloads::matmul_1024_task(TemplateKind::Gpu)),
    ] {
        let e = task.space.sample(&mut rng);
        b.run(&format!("lower_{name}"), || task.lower(&e).unwrap());
        b.run(&format!("schedule_{name}"), || task.schedule(&e));
    }
}
