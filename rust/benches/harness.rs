//! Shared perf-report plumbing for the bench binaries: every hot-path
//! bench (`bench_features`, `bench_gbt`, `bench_sa`, `bench_e2e_tune`)
//! funnels its measured [`Stats`] through a [`Report`] and writes one
//! `BENCH_<area>.json` artifact — the same record-the-trajectory shape
//! `bench_serve` established, so CI uploads a uniform set of files the
//! `scripts/check_bench_json.py` validator can gate on.
//!
//! JSON shape:
//!
//! ```json
//! {
//!   "area": "gbt",
//!   "cases": {
//!     "predict_8k_rows": {"mean_ns": ..., "median_ns": ..., "p95_ns": ..., "iters": ...}
//!   },
//!   "<extra field>": ...
//! }
//! ```
//!
//! Output lands in the working directory as `BENCH_<area>.json`;
//! `BENCH_<AREA>_JSON` overrides the path (mirroring
//! `BENCH_SERVE_JSON`). Not a bench target itself — each bench binary
//! pulls this file in with `mod harness;` (autobenches is off in
//! Cargo.toml so cargo does not try to compile it standalone).

use autotvm::util::bench::{Bench, Stats};
use autotvm::util::json::Json;
use std::collections::BTreeMap;

/// Accumulates a bench binary's measured cases plus free-form summary
/// fields, then serializes the `BENCH_<area>.json` artifact.
pub struct Report {
    area: String,
    cases: BTreeMap<String, Json>,
    extra: Vec<(String, Json)>,
}

#[allow(dead_code)] // each bench uses the subset it needs
impl Report {
    /// Empty report for one bench area (`gbt`, `sa`, ...).
    pub fn new(area: &str) -> Self {
        Report { area: area.to_string(), cases: BTreeMap::new(), extra: Vec::new() }
    }

    /// Record one measured case.
    pub fn stats(&mut self, name: &str, s: &Stats) {
        self.cases.insert(
            name.to_string(),
            Json::obj(vec![
                ("mean_ns", Json::from(s.mean_ns)),
                ("median_ns", Json::from(s.median_ns)),
                ("p95_ns", Json::from(s.p95_ns)),
                ("iters", Json::from(s.iters)),
            ]),
        );
    }

    /// Record every case a [`Bench`] has run so far.
    pub fn import(&mut self, b: &Bench) {
        for (name, s) in b.results() {
            self.stats(name, s);
        }
    }

    /// Attach a top-level summary field (speedup ratios, scale knobs).
    pub fn field(&mut self, key: &str, value: Json) {
        self.extra.push((key.to_string(), value));
    }

    /// Write `BENCH_<area>.json` (or the `BENCH_<AREA>_JSON` override)
    /// and print the path, like `bench_serve` does.
    pub fn write(self) {
        let env_key = format!("BENCH_{}_JSON", self.area.to_uppercase());
        let json_path = std::env::var(&env_key)
            .unwrap_or_else(|_| format!("BENCH_{}.json", self.area));
        let mut fields: BTreeMap<String, Json> = BTreeMap::new();
        fields.insert("area".to_string(), Json::from(self.area.clone()));
        fields.insert("cases".to_string(), Json::Obj(self.cases));
        for (k, v) in self.extra {
            fields.insert(k, v);
        }
        std::fs::write(&json_path, Json::Obj(fields).dump()).expect("write bench json");
        println!("wrote {json_path}");
    }
}
