//! Feature-extraction throughput — the per-candidate hot path of the SA
//! inner loop (lower → analyze → featurize). Perf target (DESIGN.md
//! §Perf): the model pipeline must stay far below measurement cost.
use autotvm::ast::analysis::analyze;
use autotvm::features::{self, Representation};
use autotvm::schedule::template::TemplateKind;
use autotvm::util::bench::Bench;
use autotvm::util::Rng;
use autotvm::workloads;

fn main() {
    let mut b = Bench::new("features");
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    let mut rng = Rng::seed_from_u64(1);
    let e = task.space.sample(&mut rng);
    let prog = task.lower(&e).unwrap();
    let analysis = analyze(&prog);

    b.run("lower_conv_c6", || task.lower(&e).unwrap());
    b.run("analyze_conv_c6", || analyze(&prog));
    b.run("context_relation", || features::context_relation(&analysis));
    b.run("full_repr", || features::full(&analysis));
    b.run("lower_analyze_featurize", || {
        let p = task.lower(&e).unwrap();
        let a = analyze(&p);
        features::extract(Representation::Full, &task, &e, &a)
    });
}
