//! Feature-extraction throughput — the per-candidate hot path of the SA
//! inner loop (lower → analyze → featurize). Perf target (DESIGN.md
//! §Perf): the model pipeline must stay far below measurement cost.
//!
//! The incremental cases time what the fast paths actually replace: a
//! full lower+analyze+extract per SA neighbor vs the Config-repr
//! skip-lower path and the per-knob slice update
//! ([`Featurizer::neighbor_features`]), plus the structure-cached delta
//! replay for the program-derived `Full`/`ContextRelation`
//! representations (recorded as `speedup_delta_vs_fresh`). Emits
//! `BENCH_features.json`.
//!
//! [`Featurizer::neighbor_features`]: autotvm::tuner::Featurizer::neighbor_features
mod harness;

use autotvm::ast::analysis::analyze;
use autotvm::features::{self, Representation};
use autotvm::schedule::space::ConfigEntity;
use autotvm::schedule::template::TemplateKind;
use autotvm::tuner::Featurizer;
use autotvm::util::bench::Bench;
use autotvm::util::Rng;
use autotvm::workloads;

fn main() {
    let mut b = Bench::new("features");
    let mut report = harness::Report::new("features");
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    let mut rng = Rng::seed_from_u64(1);
    let e = task.space.sample(&mut rng);
    let prog = task.lower(&e).unwrap();
    let analysis = analyze(&prog);

    b.run("lower_conv_c6", || task.lower(&e).unwrap());
    b.run("analyze_conv_c6", || analyze(&prog));
    b.run("context_relation", || features::context_relation(&analysis));
    b.run("full_repr", || features::full(&analysis));
    b.run("lower_analyze_featurize", || {
        let p = task.lower(&e).unwrap();
        let a = analyze(&p);
        features::extract(Representation::Full, &task, &e, &a)
    });

    // --- SA-neighbor featurization: the batch shape the tuner issues ---
    let parents: Vec<ConfigEntity> =
        (0..128).map(|_| task.space.sample(&mut rng)).collect();
    let mut knobs = Vec::new();
    let proposals: Vec<ConfigEntity> = parents
        .iter()
        .map(|p| {
            let (n, j) = task.space.mutate_knob(p, &mut rng);
            knobs.push(j);
            n
        })
        .collect();

    // Reference: full Config extraction, fresh featurizer each time
    // (what every SA step paid before this pass).
    let full_batch = b.run("config_batch_128_full_extract", || {
        Featurizer::with_fast(Representation::Config, false).features(&task, &proposals)
    });
    // Skip-lower Config path, fresh cache (still computes every row).
    b.run("config_batch_128_skip_lower", || {
        Featurizer::new(Representation::Config).features(&task, &proposals)
    });
    // Incremental: parent rows cached, only the mutated knob slice is
    // rewritten per neighbor — the steady state of the SA inner loop.
    let warm = Featurizer::new(Representation::Config);
    warm.features(&task, &parents);
    let incremental = b.run("config_batch_128_incremental", || {
        warm.neighbor_features(&task, &parents, &proposals, &knobs)
            .expect("parents cached")
    });
    let speedup = full_batch.mean_ns / incremental.mean_ns;
    println!("features/incremental_speedup_128                  {speedup:.2}x");

    // --- program-derived reprs: structure-cached delta vs fresh ---
    // Fresh pays a full lower + analyze + extract per row; the delta
    // path lowers one donor per loop structure and replays only the
    // extent-derived quantities for every other row. Both featurizers
    // start cold each iteration, so the donor cost is included.
    let mut ctx_speedup = 0.0;
    for (name, repr) in [
        ("context", Representation::ContextRelation),
        ("full", Representation::Full),
    ] {
        let fresh = b.run(&format!("{name}_batch_128_fresh_extract"), || {
            Featurizer::with_fast(repr, false).features(&task, &proposals)
        });
        let delta = b.run(&format!("{name}_batch_128_delta"), || {
            Featurizer::new(repr).features(&task, &proposals)
        });
        let sp = fresh.mean_ns / delta.mean_ns;
        println!("features/{name}_delta_speedup_128                 {sp:.2}x");
        report.field(&format!("{name}_delta_speedup_128"), sp.into());
        if repr == Representation::ContextRelation {
            ctx_speedup = sp;
        }
    }

    report.import(&b);
    report.field("incremental_speedup_128", speedup.into());
    report.field("speedup_delta_vs_fresh", ctx_speedup.into());
    report.write();
}
