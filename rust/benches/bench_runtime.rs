//! PJRT execute latency for the cost-model artifacts (prediction is on
//! the SA hot path when the neural model is selected).
use autotvm::util::bench::Bench;

fn main() {
    let dir = autotvm::runtime::artifacts_dir();
    if !dir.join("costmodel_fwd.hlo.txt").exists() {
        eprintln!("SKIP bench_runtime: run `make artifacts` first");
        return;
    }
    let rt = autotvm::runtime::PjrtRuntime::cpu().unwrap();
    let meta = autotvm::model::neural::NeuralMeta::load().unwrap();
    let exe = rt.load(dir.join("costmodel_fwd.hlo.txt")).unwrap();
    let theta = vec![0.01f32; meta.theta_dim];
    let x = vec![0.5f32; meta.pred_batch * meta.max_loops * meta.context_dim];
    let tl = autotvm::runtime::literal_f32(&theta, &[meta.theta_dim as i64]).unwrap();
    let xl = autotvm::runtime::literal_f32(
        &x,
        &[meta.pred_batch as i64, meta.max_loops as i64, meta.context_dim as i64],
    )
    .unwrap();
    let mut b = Bench::new("runtime");
    b.run("costmodel_fwd_batch128", || exe.run(&[tl.clone(), xl.clone()]).unwrap());
    let mut bench2 = Bench::new("runtime_compile");
    bench2.measure_time = std::time::Duration::from_millis(200);
    bench2.run("load_and_compile_fwd", || {
        rt.load(dir.join("costmodel_fwd.hlo.txt")).unwrap()
    });
}
