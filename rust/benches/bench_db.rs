//! TuningDb serving hot path — the acceptance comparison for the
//! service-layer refactor: `best_config` served from the incremental
//! per-shard index vs the old linear scan, on a 50k-record DB, plus the
//! per-task feature cache's effect on repeated `to_training` calls.

use autotvm::features::Representation;
use autotvm::schedule::template::TemplateKind;
use autotvm::tuner::db::{Database, Record};
use autotvm::util::bench::Bench;
use autotvm::util::Rng;
use autotvm::workloads;

fn main() {
    let mut b = Bench::new("tuning_db");

    // 50k synthetic records over 10 tasks on one target (serving only —
    // best_config never lowers, so choices need not be real schedules).
    let db = Database::new();
    let mut rng = Rng::seed_from_u64(1);
    let tasks: Vec<String> = (0..10).map(|i| format!("task{i}@Gpu")).collect();
    for i in 0..50_000usize {
        db.append(Record {
            task_key: tasks[i % tasks.len()].clone(),
            target: "sim-gpu".into(),
            choices: (0..8).map(|_| rng.gen_range(0..64) as u32).collect(),
            gflops: rng.gen_f64() * 1000.0,
            seconds: 1e-3,
            error: if i % 97 == 0 { Some("timeout".into()) } else { None },
        })
        .expect("in-memory append");
    }
    let sanity = db.best_config("task3@Gpu", "sim-gpu").map(|(_, g)| g);
    assert_eq!(sanity, db.best_config_scan("task3@Gpu", "sim-gpu").map(|(_, g)| g));

    b.run("best_config_indexed_50k", || db.best_config("task3@Gpu", "sim-gpu"));
    b.run("best_config_scan_50k", || db.best_config_scan("task3@Gpu", "sim-gpu"));
    b.run("top_k8_indexed_50k", || db.top_k("task3@Gpu", "sim-gpu", 8));

    // Feature cache: to_training over 192 real records — cold pays the
    // lower+analyze+extract cost, warm is served from the shard cache.
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    let mut rng = Rng::seed_from_u64(2);
    let records: Vec<Record> = (0..192)
        .map(|_| {
            let e = task.space.sample(&mut rng);
            Record {
                task_key: task.key(),
                target: "sim-gpu".into(),
                choices: e.choices,
                gflops: rng.gen_f64() * 500.0,
                seconds: 1e-3,
                error: None,
            }
        })
        .collect();
    b.run("to_training_192_cold", || {
        let fresh = Database::new();
        for r in &records {
            fresh.append(r.clone()).expect("in-memory append");
        }
        fresh.to_training(&[&task], "sim-gpu", Representation::ContextRelation, usize::MAX)
    });
    let warm_db = Database::new();
    for r in &records {
        warm_db.append(r.clone()).expect("in-memory append");
    }
    // prime the cache once, then measure cache-served calls
    warm_db.to_training(&[&task], "sim-gpu", Representation::ContextRelation, usize::MAX);
    b.run("to_training_192_warm_cache", || {
        warm_db.to_training(&[&task], "sim-gpu", Representation::ContextRelation, usize::MAX)
    });
}
