//! Serving-tier acceptance bench: O(1) `best_config` lookups at 1M+
//! records under a 64-thread query storm with live writers, plus the
//! compaction payoff (snapshot-then-tail `open` vs full-history
//! replay). Emits `BENCH_serve.json` for the perf-trajectory record.
//!
//! Scale knobs (env): `SERVE_RECORDS` (default 1_000_000),
//! `SERVE_THREADS` (64), `SERVE_WRITERS` (4), `SERVE_STORM_MS` (2000),
//! `BENCH_SERVE_JSON` (output path). The hard acceptance asserts (p99
//! storm ≤ 2× idle, compacted open ≪ full replay) fire only at full
//! scale — reduced CI smokes record results without gating on a
//! loaded shared runner's scheduling jitter.

use autotvm::tuner::db::{Database, RetentionPolicy};
use autotvm::tuner::serve::{fill_synthetic, query_storm, ServeConfig, StormOptions};
use autotvm::util::bench::Bench;
use autotvm::util::json::Json;
use std::time::{Duration, Instant};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let records = env_usize("SERVE_RECORDS", 1_000_000);
    let threads = env_usize("SERVE_THREADS", 64);
    let writers = env_usize("SERVE_WRITERS", 4);
    let storm_ms = env_usize("SERVE_STORM_MS", 2000);
    let json_path = std::env::var("BENCH_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let full_scale = records >= 1_000_000;

    let dir = std::env::temp_dir();
    let path = dir.join(format!("autotvm-bench-serve-{}.jsonl", std::process::id()));
    let snap = {
        let mut os = path.as_os_str().to_os_string();
        os.push(".snap");
        std::path::PathBuf::from(os)
    };
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&snap);

    // Build the WAL fast: fill in memory (64 tasks × 2 targets = 128
    // shards), then stream it out once.
    println!("bench_serve: building {records}-record WAL ...");
    let mem = Database::new();
    fill_synthetic(&mem, records, 64, 2, 42);
    mem.save(&path).expect("streaming save");
    drop(mem);

    // Full-history replay: the pre-compaction startup cost.
    let t0 = Instant::now();
    let db = Database::open(&path).expect("open full WAL");
    let open_full_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(db.len(), records, "full replay lost records");
    println!("open (full replay, {records} records): {open_full_ms:.1} ms");

    // Single-thread hot path through the Bench harness.
    let serve = ServeConfig::new(db.clone());
    let keys = db.shard_keys();
    let (task, target) = keys[keys.len() / 2].clone();
    let mut b = Bench::new("serve");
    b.run(&format!("best_config_{}k", records / 1000), || {
        serve.best_config(&task, &target)
    });

    // Idle baseline vs contended storm.
    let duration = Duration::from_millis(storm_ms as u64);
    let idle = query_storm(
        &serve,
        &StormOptions { threads: 1, writers: 0, duration, seed: 7 },
    );
    println!("idle  {idle}");
    let storm = query_storm(
        &serve,
        &StormOptions { threads, writers, duration, seed: 7 },
    );
    println!("storm {storm}");
    let idle_p99 = idle.p99_ns.max(1);
    let p99_ratio = storm.p99_ns as f64 / idle_p99 as f64;
    println!(
        "p99 ratio storm/idle: {p99_ratio:.2} ({} ns vs {} ns)",
        storm.p99_ns, idle_p99
    );

    // Compact under the serving retention policy and measure the
    // snapshot-then-tail reopen.
    let stats = db.compact(&RetentionPolicy::newest(64)).expect("compact");
    println!(
        "compacted to gen {}: kept {}, dropped {}, snapshot {} bytes",
        stats.gen, stats.kept, stats.dropped, stats.snapshot_bytes
    );
    drop(serve);
    drop(db);
    let t0 = Instant::now();
    let back = Database::open(&path).expect("open compacted");
    let open_compacted_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(back.len(), stats.kept, "snapshot-then-tail load diverged");
    let tail_lines = std::fs::read_to_string(&path).map(|t| t.lines().count()).unwrap_or(0);
    assert_eq!(tail_lines, 1, "post-compaction tail still replays history");
    println!(
        "open (snapshot-then-tail, {} records): {open_compacted_ms:.1} ms",
        stats.kept
    );

    if full_scale {
        assert!(
            p99_ratio <= 2.0,
            "storm p99 {} ns exceeds 2x idle p99 {} ns",
            storm.p99_ns,
            idle_p99
        );
        assert!(
            stats.kept * 5 < records,
            "retention barely evicted: kept {} of {records}",
            stats.kept
        );
        assert!(
            open_compacted_ms * 5.0 < open_full_ms,
            "compacted open ({open_compacted_ms:.1} ms) not clearly faster than full \
             replay ({open_full_ms:.1} ms)"
        );
    }

    let report = Json::obj(vec![
        ("records", Json::from(records)),
        ("threads", Json::from(threads)),
        ("writers", Json::from(writers)),
        ("storm_ms", Json::from(storm_ms)),
        ("full_scale", Json::from(full_scale)),
        ("open_full_ms", Json::from(open_full_ms)),
        ("open_compacted_ms", Json::from(open_compacted_ms)),
        ("retained", Json::from(stats.kept)),
        ("dropped", Json::from(stats.dropped)),
        ("snapshot_bytes", Json::from(stats.snapshot_bytes)),
        ("idle", idle.to_json()),
        ("storm", storm.to_json()),
        ("p99_ratio", Json::from(p99_ratio)),
    ]);
    std::fs::write(&json_path, report.dump()).expect("write bench json");
    println!("wrote {json_path}");

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&snap);
}
