//! Low-level loop AST — the program representation `x = g(e, s)`.
//!
//! This is what the cost models see (the paper's Fig. 3a): a nest of
//! annotated `for` loops over stores whose values read buffers through
//! affine index expressions. [`analysis`] derives the loop-context
//! quantities (extent, top-down/bottom-up products, per-buffer touch
//! counts, reuse ratios, strides — Table 2 of the paper) shared by the
//! feature extractors and the hardware simulator.

pub mod analysis;

use crate::expr::{IndexExpr, VarId, VarPool};

/// Loop annotation — the `s` choices visible in the final program.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ForKind {
    /// Plain sequential loop.
    Serial,
    /// Fully unrolled loop.
    Unrolled,
    /// SIMD-vectorized loop.
    Vectorized,
    /// CPU multi-core parallel loop.
    Parallel,
    /// GPU block index binding (grid dimension).
    BlockBind,
    /// GPU thread index binding (threads within a block).
    ThreadBind,
}

impl ForKind {
    /// Number of annotation kinds (one-hot feature width).
    pub const COUNT: usize = 6;

    /// Position of this kind in the one-hot feature encoding.
    pub fn one_hot_index(self) -> usize {
        match self {
            ForKind::Serial => 0,
            ForKind::Unrolled => 1,
            ForKind::Vectorized => 2,
            ForKind::Parallel => 3,
            ForKind::BlockBind => 4,
            ForKind::ThreadBind => 5,
        }
    }

    /// Short keyword used by the pretty-printer.
    pub fn short(self) -> &'static str {
        match self {
            ForKind::Serial => "for",
            ForKind::Unrolled => "unroll",
            ForKind::Vectorized => "vec",
            ForKind::Parallel => "parallel",
            ForKind::BlockBind => "blockIdx",
            ForKind::ThreadBind => "threadIdx",
        }
    }
}

/// Memory scope of a buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemScope {
    /// Off-chip memory (DRAM / HBM).
    Global,
    /// On-chip software-managed memory (GPU shared memory / TPU VMEM).
    Shared,
    /// Register-allocated accumulator.
    Local,
}

/// A buffer referenced by the program.
#[derive(Clone, Debug, PartialEq)]
pub struct BufferDecl {
    /// Buffer name (unique within the program).
    pub name: String,
    /// Row-major dimensions.
    pub shape: Vec<i64>,
    /// Memory scope the buffer lives in.
    pub scope: MemScope,
}

impl BufferDecl {
    /// Total number of elements.
    pub fn numel(&self) -> i64 {
        self.shape.iter().product()
    }

    /// Row-major strides (elements).
    pub fn strides(&self) -> Vec<i64> {
        let mut s = vec![1i64; self.shape.len()];
        for d in (0..self.shape.len().saturating_sub(1)).rev() {
            s[d] = s[d + 1] * self.shape[d + 1];
        }
        s
    }
}

/// Scalar value expression in the lowered program.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Constant.
    Imm(f64),
    /// `buffer[indices...]`
    Load {
        /// Buffer read from.
        buffer: String,
        /// One affine index per dimension.
        indices: Vec<IndexExpr>,
    },
    /// Addition.
    Add(Box<Value>, Box<Value>),
    /// Subtraction.
    Sub(Box<Value>, Box<Value>),
    /// Multiplication.
    Mul(Box<Value>, Box<Value>),
    /// Elementwise maximum.
    Max(Box<Value>, Box<Value>),
    /// `max(x, 0)` activation.
    Relu(Box<Value>),
    /// Bounds-guarded value (padding): in-bounds value, else `else_`.
    Guarded {
        /// `(index, lo, hi)` half-open bounds that must all hold.
        bounds: Vec<(IndexExpr, i64, i64)>,
        /// Value when every bound holds.
        value: Box<Value>,
        /// Value otherwise (the padding constant).
        else_: Box<Value>,
    },
}

impl Value {
    /// Convenience constructor for [`Value::Load`].
    pub fn load(buffer: impl Into<String>, indices: Vec<IndexExpr>) -> Self {
        Value::Load { buffer: buffer.into(), indices }
    }

    /// Collect `(buffer, indices)` loads in evaluation order.
    pub fn loads(&self) -> Vec<(&str, &[IndexExpr])> {
        let mut out = Vec::new();
        self.collect_loads(&mut out);
        out
    }

    fn collect_loads<'a>(&'a self, out: &mut Vec<(&'a str, &'a [IndexExpr])>) {
        match self {
            Value::Imm(_) => {}
            Value::Load { buffer, indices } => out.push((buffer, indices)),
            Value::Add(a, b) | Value::Sub(a, b) | Value::Mul(a, b) | Value::Max(a, b) => {
                a.collect_loads(out);
                b.collect_loads(out);
            }
            Value::Relu(a) => a.collect_loads(out),
            Value::Guarded { value, else_, .. } => {
                value.collect_loads(out);
                else_.collect_loads(out);
            }
        }
    }

    /// Arithmetic op count per evaluation.
    pub fn flops(&self) -> u64 {
        match self {
            Value::Imm(_) | Value::Load { .. } => 0,
            Value::Add(a, b) | Value::Sub(a, b) | Value::Mul(a, b) | Value::Max(a, b) => {
                1 + a.flops() + b.flops()
            }
            Value::Relu(a) => 1 + a.flops(),
            Value::Guarded { value, else_, .. } => 1 + value.flops() + else_.flops(),
        }
    }
}

/// Statement of the lowered program.
#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// Annotated counted loop over `body`.
    For {
        /// Loop variable.
        var: VarId,
        /// Trip count.
        extent: i64,
        /// Loop annotation.
        kind: ForKind,
        /// Loop body.
        body: Vec<Stmt>,
    },
    /// `buffer[indices...] = value` (or `+=` when `accumulate`).
    Store {
        /// Buffer written to.
        buffer: String,
        /// One affine index per dimension.
        indices: Vec<IndexExpr>,
        /// Stored value expression.
        value: Value,
        /// `+=` instead of `=`.
        accumulate: bool,
    },
    /// Declare an on-chip buffer live for `body`.
    Alloc {
        /// The declared buffer's name.
        buffer: String,
        /// Statements the buffer is live for.
        body: Vec<Stmt>,
    },
}

/// A complete lowered tensor program: `x = g(e, s)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Program {
    /// Program (operator) name.
    pub name: String,
    /// Top-level statements.
    pub stmts: Vec<Stmt>,
    /// All buffers the program references.
    pub buffers: Vec<BufferDecl>,
    /// Variable pool resolving [`VarId`]s to names.
    pub vars: VarPool,
    /// Total useful flops of the underlying operator (for GFLOPS).
    pub flops: u64,
}

impl Program {
    /// Look up a buffer declaration by name.
    pub fn buffer(&self, name: &str) -> Option<&BufferDecl> {
        self.buffers.iter().find(|b| b.name == name)
    }

    /// Pretty-print as pseudo-C (the paper's Fig. 1 right column).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        for st in &self.stmts {
            self.pretty_stmt(st, 0, &mut s);
        }
        s
    }

    fn pretty_stmt(&self, st: &Stmt, depth: usize, out: &mut String) {
        let pad = "  ".repeat(depth);
        match st {
            Stmt::For { var, extent, kind, body } => {
                out.push_str(&format!(
                    "{pad}{} {} in 0..{extent}:\n",
                    kind.short(),
                    self.vars.name(*var)
                ));
                for b in body {
                    self.pretty_stmt(b, depth + 1, out);
                }
            }
            Stmt::Store { buffer, indices, value, accumulate } => {
                let idx: Vec<String> =
                    indices.iter().map(|i| i.display(&self.vars)).collect();
                let op = if *accumulate { "+=" } else { "=" };
                out.push_str(&format!(
                    "{pad}{buffer}[{}] {op} {}\n",
                    idx.join(", "),
                    self.pretty_value(value)
                ));
            }
            Stmt::Alloc { buffer, body } => {
                let b = self.buffer(buffer);
                out.push_str(&format!(
                    "{pad}alloc {buffer}{:?} @{}\n",
                    b.map(|b| b.shape.clone()).unwrap_or_default(),
                    b.map(|b| format!("{:?}", b.scope)).unwrap_or_default()
                ));
                for s2 in body {
                    self.pretty_stmt(s2, depth + 1, out);
                }
            }
        }
    }

    fn pretty_value(&self, v: &Value) -> String {
        match v {
            Value::Imm(x) => format!("{x}"),
            Value::Load { buffer, indices } => {
                let idx: Vec<String> =
                    indices.iter().map(|i| i.display(&self.vars)).collect();
                format!("{buffer}[{}]", idx.join(", "))
            }
            Value::Add(a, b) => format!("({} + {})", self.pretty_value(a), self.pretty_value(b)),
            Value::Sub(a, b) => format!("({} - {})", self.pretty_value(a), self.pretty_value(b)),
            Value::Mul(a, b) => format!("({} * {})", self.pretty_value(a), self.pretty_value(b)),
            Value::Max(a, b) => format!("max({}, {})", self.pretty_value(a), self.pretty_value(b)),
            Value::Relu(a) => format!("relu({})", self.pretty_value(a)),
            Value::Guarded { value, .. } => format!("guard({})", self.pretty_value(value)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forkind_one_hot_distinct() {
        use ForKind::*;
        let all = [Serial, Unrolled, Vectorized, Parallel, BlockBind, ThreadBind];
        let mut seen = std::collections::HashSet::new();
        for k in all {
            assert!(seen.insert(k.one_hot_index()));
            assert!(k.one_hot_index() < ForKind::COUNT);
        }
    }

    #[test]
    fn value_loads_and_flops() {
        let v = Value::Add(
            Box::new(Value::Mul(
                Box::new(Value::load("A", vec![])),
                Box::new(Value::load("B", vec![])),
            )),
            Box::new(Value::Imm(1.0)),
        );
        assert_eq!(v.loads().len(), 2);
        assert_eq!(v.flops(), 2);
    }
}
