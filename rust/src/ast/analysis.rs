//! Static loop-nest analysis shared by the feature extractors and the
//! hardware simulator.
//!
//! For every `Store` in a program we recover its enclosing loop chain
//! and, per loop level and per buffer access, the quantities the paper
//! builds features from (Table 2): loop extent, annotation, top-down /
//! bottom-up extent products, touched-element counts, reuse ratios and
//! the stride of the loop variable in the flattened buffer index.

use super::{ForKind, MemScope, Program, Stmt, Value};
use crate::expr::{IndexExpr, VarId};

/// One loop in a chain, outermost first.
#[derive(Clone, Debug, PartialEq)]
pub struct LoopLevel {
    /// Loop variable.
    pub var: VarId,
    /// Loop trip count.
    pub extent: i64,
    /// Loop annotation (serial / unrolled / vectorized / bound …).
    pub kind: ForKind,
}

/// Per-(access, chain) analysis.
#[derive(Clone, Debug)]
pub struct AccessInfo {
    /// Name of the accessed buffer.
    pub buffer: String,
    /// Memory scope of the accessed buffer.
    pub scope: MemScope,
    /// Whether this access is the store target (vs a load).
    pub is_write: bool,
    /// Stride (elements) of each chain loop's variable in the flattened
    /// buffer index; `strides[l]` corresponds to `chain.loops[l]`.
    pub strides: Vec<i64>,
    /// `touch[l]` — distinct elements touched by loops `l..` (inclusive),
    /// capped at the buffer size.
    pub touch: Vec<f64>,
    /// `reuse[l] = bottom_up[l] / touch[l]` — average temporal reuse of
    /// an element across iterations of loops `l..`.
    pub reuse: Vec<f64>,
}

impl AccessInfo {
    /// Stride of the innermost loop with nonzero extent > 1; 0 when the
    /// access is invariant across all inner loops.
    pub fn innermost_stride(&self) -> i64 {
        for (i, s) in self.strides.iter().enumerate().rev() {
            if *s != 0 {
                return if i + 1 == self.strides.len() { *s } else { 0.max(*s) };
            }
        }
        0
    }
}

/// One store statement with its loop context.
#[derive(Clone, Debug)]
pub struct StoreChain {
    /// Enclosing loops, outermost first.
    pub loops: Vec<LoopLevel>,
    /// Store target first, then loads in evaluation order.
    pub accesses: Vec<AccessInfo>,
    /// Arithmetic ops per innermost iteration (incl. the accumulate add).
    pub value_flops: u64,
    /// Whether the store accumulates into its target (`+=`).
    pub accumulate: bool,
    /// Whether the value contains a padding guard.
    pub has_guard: bool,
    /// Π extents — total innermost iterations.
    pub trip: f64,
    /// `top_down[l]` — product of extents of loops strictly outer than l.
    pub top_down: Vec<f64>,
    /// `bottom_up[l]` — product of extents of loops `l..` (inclusive).
    pub bottom_up: Vec<f64>,
}

impl StoreChain {
    /// The access of `buffer` in this chain, if it reads/writes it.
    pub fn access(&self, buffer: &str) -> Option<&AccessInfo> {
        self.accesses.iter().find(|a| a.buffer == buffer)
    }
}

/// Full program analysis.
#[derive(Clone, Debug)]
pub struct ProgramAnalysis {
    /// One entry per store statement, in program order.
    pub chains: Vec<StoreChain>,
}

impl ProgramAnalysis {
    /// The longest store chain — the paper uses it as the canonical
    /// feature chain ("we pick the longest chain from the AST").
    pub fn longest_chain(&self) -> &StoreChain {
        self.chains
            .iter()
            .max_by(|a, b| {
                (a.loops.len(), a.trip).partial_cmp(&(b.loops.len(), b.trip)).unwrap()
            })
            .expect("program has no store")
    }
}

/// Flattened stride of `var` in an access with the given per-dimension
/// index expressions and row-major dimension strides.
fn flat_stride(indices: &[IndexExpr], dim_strides: &[i64], var: VarId) -> i64 {
    indices
        .iter()
        .zip(dim_strides.iter())
        .map(|(e, s)| e.coeff(var) * s)
        .sum()
}

struct Walker<'p> {
    program: &'p Program,
    loops: Vec<LoopLevel>,
    chains: Vec<StoreChain>,
}

impl<'p> Walker<'p> {
    fn visit(&mut self, stmt: &Stmt) {
        match stmt {
            Stmt::For { var, extent, kind, body } => {
                self.loops.push(LoopLevel { var: *var, extent: *extent, kind: *kind });
                for s in body {
                    self.visit(s);
                }
                self.loops.pop();
            }
            Stmt::Alloc { body, .. } => {
                for s in body {
                    self.visit(s);
                }
            }
            Stmt::Store { buffer, indices, value, accumulate } => {
                self.chains.push(self.analyze_store(buffer, indices, value, *accumulate));
            }
        }
    }

    fn access_info(
        &self,
        buffer: &str,
        indices: &[IndexExpr],
        is_write: bool,
        bottom_up: &[f64],
    ) -> AccessInfo {
        let decl = self
            .program
            .buffer(buffer)
            .unwrap_or_else(|| panic!("unknown buffer {buffer}"));
        let dim_strides = decl.strides();
        let n = self.loops.len();
        let strides: Vec<i64> = self
            .loops
            .iter()
            .map(|l| flat_stride(indices, &dim_strides, l.var))
            .collect();
        // touch[l]: product over loops j >= l of extent_j when the loop
        // moves this access, capped at the buffer footprint.
        let cap = decl.numel() as f64;
        let mut touch = vec![0f64; n];
        let mut acc = 1f64;
        for l in (0..n).rev() {
            if strides[l] != 0 {
                acc *= self.loops[l].extent as f64;
            }
            touch[l] = acc.min(cap);
        }
        let reuse: Vec<f64> =
            (0..n).map(|l| (bottom_up[l] / touch[l].max(1.0)).max(1.0)).collect();
        AccessInfo { buffer: buffer.to_string(), scope: decl.scope, is_write, strides, touch, reuse }
    }

    fn analyze_store(
        &self,
        buffer: &str,
        indices: &[IndexExpr],
        value: &Value,
        accumulate: bool,
    ) -> StoreChain {
        let n = self.loops.len();
        let mut top_down = vec![1f64; n];
        for l in 1..n {
            top_down[l] = top_down[l - 1] * self.loops[l - 1].extent as f64;
        }
        let mut bottom_up = vec![1f64; n];
        for l in (0..n).rev() {
            bottom_up[l] =
                self.loops[l].extent as f64 * bottom_up.get(l + 1).copied().unwrap_or(1.0);
        }
        let trip = bottom_up.first().copied().unwrap_or(1.0);

        let mut accesses =
            vec![self.access_info(buffer, indices, true, &bottom_up)];
        for (b, idx) in value.loads() {
            accesses.push(self.access_info(b, idx, false, &bottom_up));
        }
        let has_guard = has_guard(value);
        StoreChain {
            loops: self.loops.clone(),
            accesses,
            value_flops: value.flops() + accumulate as u64,
            accumulate,
            has_guard,
            trip,
            top_down,
            bottom_up,
        }
    }
}

fn has_guard(v: &Value) -> bool {
    match v {
        Value::Guarded { .. } => true,
        Value::Imm(_) | Value::Load { .. } => false,
        Value::Add(a, b) | Value::Sub(a, b) | Value::Mul(a, b) | Value::Max(a, b) => {
            has_guard(a) || has_guard(b)
        }
        Value::Relu(a) => has_guard(a),
    }
}

/// Analyze a program.
pub fn analyze(program: &Program) -> ProgramAnalysis {
    let mut out = ProgramAnalysis { chains: Vec::new() };
    analyze_into(program, &mut out);
    out
}

/// [`analyze`] into an existing [`ProgramAnalysis`], reusing its
/// `chains` allocation. Hot loops that analyze one mutated program per
/// SA step (the batch featurizer) keep a per-thread scratch analysis
/// and call this instead of allocating a fresh one per neighbor.
pub fn analyze_into(program: &Program, out: &mut ProgramAnalysis) {
    let mut chains = std::mem::take(&mut out.chains);
    chains.clear();
    let mut w = Walker { program, loops: Vec::new(), chains };
    for s in &program.stmts {
        w.visit(s);
    }
    assert!(!w.chains.is_empty(), "program {} has no store", program.name);
    out.chains = w.chains;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ast::{BufferDecl, MemScope, Program, Stmt, Value};
    use crate::expr::{IndexExpr, VarPool};

    /// Build the naive matmul of Fig. 1 (x0 default code):
    /// for y, x, k: C[y][x] += A[k][y] * B[k][x]
    fn naive_matmul(n: i64) -> Program {
        let mut pool = VarPool::new();
        let y = pool.fresh("y");
        let x = pool.fresh("x");
        let k = pool.fresh("k");
        let store = Stmt::Store {
            buffer: "C".into(),
            indices: vec![IndexExpr::var(y), IndexExpr::var(x)],
            value: Value::Mul(
                Box::new(Value::load("A", vec![IndexExpr::var(k), IndexExpr::var(y)])),
                Box::new(Value::load("B", vec![IndexExpr::var(k), IndexExpr::var(x)])),
            ),
            accumulate: true,
        };
        let nest = Stmt::For {
            var: y,
            extent: n,
            kind: ForKind::Serial,
            body: vec![Stmt::For {
                var: x,
                extent: n,
                kind: ForKind::Serial,
                body: vec![Stmt::For { var: k, extent: n, kind: ForKind::Serial, body: vec![store] }],
            }],
        };
        Program {
            name: "naive_matmul".into(),
            stmts: vec![nest],
            buffers: vec![
                BufferDecl { name: "C".into(), shape: vec![n, n], scope: MemScope::Global },
                BufferDecl { name: "A".into(), shape: vec![n, n], scope: MemScope::Global },
                BufferDecl { name: "B".into(), shape: vec![n, n], scope: MemScope::Global },
            ],
            vars: pool,
            flops: 2 * (n as u64).pow(3),
        }
    }

    #[test]
    fn naive_matmul_chain_quantities() {
        let p = naive_matmul(64);
        let a = analyze(&p);
        assert_eq!(a.chains.len(), 1);
        let c = &a.chains[0];
        assert_eq!(c.loops.len(), 3);
        assert_eq!(c.trip, 64f64.powi(3));
        assert_eq!(c.top_down, vec![1.0, 64.0, 64.0 * 64.0]);
        assert_eq!(c.bottom_up, vec![64f64.powi(3), 64f64.powi(2), 64.0]);

        // Store C[y][x]: strides (y: 64, x: 1, k: 0)
        let cs = c.access("C").unwrap();
        assert_eq!(cs.strides, vec![64, 1, 0]);
        // touch from level 0: all 64*64 elements; from level 2 (k): 1.
        assert_eq!(cs.touch, vec![4096.0, 64.0, 1.0]);
        // reuse at k level: 64 iterations hit the same element
        assert_eq!(cs.reuse[2], 64.0);

        // A[k][y]: strides (y: 1, x: 0, k: 64)
        let as_ = c.access("A").unwrap();
        assert_eq!(as_.strides, vec![1, 0, 64]);
        assert_eq!(as_.reuse[1], 64.0); // x loop re-reads the same A column
        assert_eq!(c.value_flops, 2); // mul + accumulate add
    }

    #[test]
    fn touch_capped_at_buffer_size() {
        // Loop over 128 iterations of a 16-element buffer with stride 1:
        // touch must cap at 16.
        let mut pool = VarPool::new();
        let i = pool.fresh("i");
        let p = Program {
            name: "cap".into(),
            stmts: vec![Stmt::For {
                var: i,
                extent: 128,
                kind: ForKind::Serial,
                body: vec![Stmt::Store {
                    buffer: "O".into(),
                    indices: vec![IndexExpr::var(i)],
                    value: Value::load("S", vec![IndexExpr::var(i)]),
                    accumulate: false,
                }],
            }],
            buffers: vec![
                BufferDecl { name: "O".into(), shape: vec![128], scope: MemScope::Global },
                BufferDecl { name: "S".into(), shape: vec![16], scope: MemScope::Shared },
            ],
            vars: pool,
            flops: 0,
        };
        let a = analyze(&p);
        let s = a.chains[0].access("S").unwrap();
        assert_eq!(s.touch[0], 16.0);
        assert_eq!(s.scope, MemScope::Shared);
    }

    #[test]
    fn longest_chain_picks_deepest() {
        let mut p = naive_matmul(8);
        // append a shallow init store
        let mut pool = p.vars.clone();
        let t = pool.fresh("t");
        p.vars = pool;
        p.stmts.insert(
            0,
            Stmt::For {
                var: t,
                extent: 8,
                kind: ForKind::Serial,
                body: vec![Stmt::Store {
                    buffer: "C".into(),
                    indices: vec![IndexExpr::var(t), IndexExpr::constant(0)],
                    value: Value::Imm(0.0),
                    accumulate: false,
                }],
            },
        );
        let a = analyze(&p);
        assert_eq!(a.chains.len(), 2);
        assert_eq!(a.longest_chain().loops.len(), 3);
    }
}
