//! Static loop-nest analysis shared by the feature extractors and the
//! hardware simulator.
//!
//! For every `Store` in a program we recover its enclosing loop chain
//! and, per loop level and per buffer access, the quantities the paper
//! builds features from (Table 2): loop extent, annotation, top-down /
//! bottom-up extent products, touched-element counts, reuse ratios and
//! the stride of the loop variable in the flattened buffer index.

use super::{ForKind, MemScope, Program, Stmt, Value};
use crate::expr::{IndexExpr, VarId};

/// One loop in a chain, outermost first.
#[derive(Clone, Debug, PartialEq)]
pub struct LoopLevel {
    /// Loop variable.
    pub var: VarId,
    /// Loop trip count.
    pub extent: i64,
    /// Loop annotation (serial / unrolled / vectorized / bound …).
    pub kind: ForKind,
}

/// Per-(access, chain) analysis.
#[derive(Clone, Debug, PartialEq)]
pub struct AccessInfo {
    /// Name of the accessed buffer.
    pub buffer: String,
    /// Memory scope of the accessed buffer.
    pub scope: MemScope,
    /// Whether this access is the store target (vs a load).
    pub is_write: bool,
    /// Stride (elements) of each chain loop's variable in the flattened
    /// buffer index; `strides[l]` corresponds to `chain.loops[l]`.
    pub strides: Vec<i64>,
    /// `touch[l]` — distinct elements touched by loops `l..` (inclusive),
    /// capped at the buffer size.
    pub touch: Vec<f64>,
    /// `reuse[l] = bottom_up[l] / touch[l]` — average temporal reuse of
    /// an element across iterations of loops `l..`.
    pub reuse: Vec<f64>,
}

impl AccessInfo {
    /// Stride of the innermost loop with nonzero extent > 1; 0 when the
    /// access is invariant across all inner loops.
    pub fn innermost_stride(&self) -> i64 {
        for (i, s) in self.strides.iter().enumerate().rev() {
            if *s != 0 {
                return if i + 1 == self.strides.len() { *s } else { 0.max(*s) };
            }
        }
        0
    }
}

/// One store statement with its loop context.
#[derive(Clone, Debug, PartialEq)]
pub struct StoreChain {
    /// Enclosing loops, outermost first.
    pub loops: Vec<LoopLevel>,
    /// Store target first, then loads in evaluation order.
    pub accesses: Vec<AccessInfo>,
    /// Arithmetic ops per innermost iteration (incl. the accumulate add).
    pub value_flops: u64,
    /// Whether the store accumulates into its target (`+=`).
    pub accumulate: bool,
    /// Whether the value contains a padding guard.
    pub has_guard: bool,
    /// Π extents — total innermost iterations.
    pub trip: f64,
    /// `top_down[l]` — product of extents of loops strictly outer than l.
    pub top_down: Vec<f64>,
    /// `bottom_up[l]` — product of extents of loops `l..` (inclusive).
    pub bottom_up: Vec<f64>,
}

impl StoreChain {
    /// The access of `buffer` in this chain, if it reads/writes it.
    pub fn access(&self, buffer: &str) -> Option<&AccessInfo> {
        self.accesses.iter().find(|a| a.buffer == buffer)
    }
}

/// Full program analysis.
#[derive(Clone, Debug, PartialEq)]
pub struct ProgramAnalysis {
    /// One entry per store statement, in program order.
    pub chains: Vec<StoreChain>,
}

impl ProgramAnalysis {
    /// The longest store chain — the paper uses it as the canonical
    /// feature chain ("we pick the longest chain from the AST").
    pub fn longest_chain(&self) -> &StoreChain {
        self.chains
            .iter()
            .max_by(|a, b| {
                (a.loops.len(), a.trip).partial_cmp(&(b.loops.len(), b.trip)).unwrap()
            })
            .expect("program has no store")
    }
}

/// Flattened stride of `var` in an access with the given per-dimension
/// index expressions and row-major dimension strides.
fn flat_stride(indices: &[IndexExpr], dim_strides: &[i64], var: VarId) -> i64 {
    indices
        .iter()
        .zip(dim_strides.iter())
        .map(|(e, s)| e.coeff(var) * s)
        .sum()
}

/// Fill `top_down` / `bottom_up` extent products for a loop chain and
/// return the trip count. Shared by the fresh walker and the delta
/// replay so both produce bit-identical floats (same operation order).
fn fill_products(loops: &[LoopLevel], top_down: &mut Vec<f64>, bottom_up: &mut Vec<f64>) -> f64 {
    let n = loops.len();
    top_down.clear();
    top_down.resize(n, 1.0);
    for l in 1..n {
        top_down[l] = top_down[l - 1] * loops[l - 1].extent as f64;
    }
    bottom_up.clear();
    bottom_up.resize(n, 1.0);
    for l in (0..n).rev() {
        bottom_up[l] = loops[l].extent as f64 * bottom_up.get(l + 1).copied().unwrap_or(1.0);
    }
    bottom_up.first().copied().unwrap_or(1.0)
}

/// Fill per-level `touch` / `reuse` for one access from its strides and
/// footprint cap. Shared by the fresh walker and the delta replay
/// (bit-identical float sequence in both paths).
fn fill_touch_reuse(
    loops: &[LoopLevel],
    strides: &[i64],
    cap: f64,
    bottom_up: &[f64],
    touch: &mut Vec<f64>,
    reuse: &mut Vec<f64>,
) {
    let n = loops.len();
    touch.clear();
    touch.resize(n, 0.0);
    let mut acc = 1f64;
    for l in (0..n).rev() {
        if strides[l] != 0 {
            acc *= loops[l].extent as f64;
        }
        touch[l] = acc.min(cap);
    }
    reuse.clear();
    reuse.resize(n, 0.0);
    for l in 0..n {
        reuse[l] = (bottom_up[l] / touch[l].max(1.0)).max(1.0);
    }
}

struct Walker<'p> {
    program: &'p Program,
    loops: Vec<LoopLevel>,
    chains: Vec<StoreChain>,
}

impl<'p> Walker<'p> {
    fn visit(&mut self, stmt: &Stmt) {
        match stmt {
            Stmt::For { var, extent, kind, body } => {
                self.loops.push(LoopLevel { var: *var, extent: *extent, kind: *kind });
                for s in body {
                    self.visit(s);
                }
                self.loops.pop();
            }
            Stmt::Alloc { body, .. } => {
                for s in body {
                    self.visit(s);
                }
            }
            Stmt::Store { buffer, indices, value, accumulate } => {
                self.chains.push(self.analyze_store(buffer, indices, value, *accumulate));
            }
        }
    }

    fn access_info(
        &self,
        buffer: &str,
        indices: &[IndexExpr],
        is_write: bool,
        bottom_up: &[f64],
    ) -> AccessInfo {
        let decl = self
            .program
            .buffer(buffer)
            .unwrap_or_else(|| panic!("unknown buffer {buffer}"));
        let dim_strides = decl.strides();
        let strides: Vec<i64> = self
            .loops
            .iter()
            .map(|l| flat_stride(indices, &dim_strides, l.var))
            .collect();
        // touch[l]: product over loops j >= l of extent_j when the loop
        // moves this access, capped at the buffer footprint.
        let cap = decl.numel() as f64;
        let mut touch = Vec::new();
        let mut reuse = Vec::new();
        fill_touch_reuse(&self.loops, &strides, cap, bottom_up, &mut touch, &mut reuse);
        AccessInfo { buffer: buffer.to_string(), scope: decl.scope, is_write, strides, touch, reuse }
    }

    fn analyze_store(
        &self,
        buffer: &str,
        indices: &[IndexExpr],
        value: &Value,
        accumulate: bool,
    ) -> StoreChain {
        let mut top_down = Vec::new();
        let mut bottom_up = Vec::new();
        let trip = fill_products(&self.loops, &mut top_down, &mut bottom_up);

        let mut accesses =
            vec![self.access_info(buffer, indices, true, &bottom_up)];
        for (b, idx) in value.loads() {
            accesses.push(self.access_info(b, idx, false, &bottom_up));
        }
        let has_guard = has_guard(value);
        StoreChain {
            loops: self.loops.clone(),
            accesses,
            value_flops: value.flops() + accumulate as u64,
            accumulate,
            has_guard,
            trip,
            top_down,
            bottom_up,
        }
    }
}

fn has_guard(v: &Value) -> bool {
    match v {
        Value::Guarded { .. } => true,
        Value::Imm(_) | Value::Load { .. } => false,
        Value::Add(a, b) | Value::Sub(a, b) | Value::Mul(a, b) | Value::Max(a, b) => {
            has_guard(a) || has_guard(b)
        }
        Value::Relu(a) => has_guard(a),
    }
}

/// Analyze a program.
pub fn analyze(program: &Program) -> ProgramAnalysis {
    let mut out = ProgramAnalysis { chains: Vec::new() };
    analyze_into(program, &mut out);
    out
}

/// [`analyze`] into an existing [`ProgramAnalysis`], reusing its
/// `chains` allocation. Hot loops that analyze one mutated program per
/// SA step (the batch featurizer) keep a per-thread scratch analysis
/// and call this instead of allocating a fresh one per neighbor.
pub fn analyze_into(program: &Program, out: &mut ProgramAnalysis) {
    let mut chains = std::mem::take(&mut out.chains);
    chains.clear();
    let mut w = Walker { program, loops: Vec::new(), chains };
    for s in &program.stmts {
        w.visit(s);
    }
    assert!(!w.chains.is_empty(), "program {} has no store", program.name);
    out.chains = w.chains;
}

/// Counters of a [`StructureCache`] — exposed through the tuner's
/// featurizer stats and asserted by the hot-path property tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StructureStats {
    /// Distinct structure keys seen (each cost one donor lower+analyze).
    pub structures: usize,
    /// Analyses served by delta replay, with no lowering at all.
    pub delta_hits: u64,
    /// Full lower+analyze fallbacks on structures whose recipe failed
    /// its build-time self-verification.
    pub fallbacks: u64,
}

/// Per-structure [`ProgramAnalysis`] cache with delta replay.
///
/// Under a fixed template, a knob mutation usually preserves the
/// lowered program's *structure* — same store chains, loop kinds and
/// buffer topology, changed loop extents. The first config seen for a
/// [`Task::structure_key`] pays the full `lower` + [`analyze`] (the
/// *donor*) and derives a replay recipe; every later config with the
/// same key is analyzed by [`StructureCache::analyze_delta`] without
/// lowering: clone the donor's static facts, set extents from the
/// config's split sizes, and recompute the extent-derived quantities
/// (products, strides, touch, reuse) through the same helpers the
/// fresh walker uses — so the result is bit-for-bit identical.
///
/// The recipe build self-verifies by replaying the donor's own config
/// and comparing against the fresh analysis; any mismatch permanently
/// routes that structure through the full lower+analyze fallback
/// (counted in [`StructureStats::fallbacks`]). A cache instance is
/// per-[`Task`]: keys from different tasks must not share a cache.
///
/// [`Task`]: crate::schedule::template::Task
/// [`Task::structure_key`]: crate::schedule::template::Task::structure_key
#[derive(Default)]
pub struct StructureCache {
    entries: std::collections::HashMap<u64, StructureEntry>,
    scratch: ReplayScratch,
    delta_hits: u64,
    fallbacks: u64,
}

struct StructureEntry {
    analysis: ProgramAnalysis,
    recipe: Option<StructureRecipe>,
}

/// Reused per-replay table: `ip[axis][part]` = product of the axis's
/// split sizes strictly inner to `part` under the config being
/// replayed (the change of basis from template-fixed axis weights to
/// per-leaf strides).
#[derive(Default)]
struct ReplayScratch {
    ip: Vec<Vec<i64>>,
}

use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;

impl StructureCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Analyze `task.lower(e)` into `out`, by delta replay when the
    /// config's structure is cached, by full lower+analyze otherwise
    /// (first sighting of a structure, or a structure whose recipe
    /// failed self-verification).
    pub fn analyze_delta(
        &mut self,
        task: &Task,
        e: &ConfigEntity,
        out: &mut ProgramAnalysis,
    ) -> anyhow::Result<()> {
        let key = task.structure_key(e);
        let Self { entries, scratch, delta_hits, fallbacks } = self;
        if let Some(entry) = entries.get(&key) {
            if let Some(recipe) = &entry.recipe {
                *delta_hits += 1;
                recipe.replay(task, e, &entry.analysis, scratch, out);
            } else {
                *fallbacks += 1;
                let program = task.lower(e)?;
                analyze_into(&program, out);
            }
            return Ok(());
        }
        let program = task.lower(e)?;
        analyze_into(&program, out);
        let recipe = StructureRecipe::build(task, &program, out, e);
        entries.insert(key, StructureEntry { analysis: out.clone(), recipe });
        Ok(())
    }

    /// Cache counters.
    pub fn stats(&self) -> StructureStats {
        StructureStats {
            structures: self.entries.len(),
            delta_hits: self.delta_hits,
            fallbacks: self.fallbacks,
        }
    }
}

/// How one access's per-loop strides and footprint cap are recomputed
/// for a new config sharing the donor's structure key.
enum AccessRecipe {
    /// Fixed-shape global tensor: the stride of chain loop `l` holding
    /// split part `(a, p)` is `w · Π_{q>p} splits[a][q]`, where `w` is
    /// the template-fixed flattened weight of axis `a` in this access
    /// (recovered from the donor by exact division); `None` marks loops
    /// whose axis does not appear in the tensor's index (stride 0 under
    /// every config).
    Global { per_loop: Vec<Option<(usize, usize, i64)>>, cap: f64 },
    /// Scratch buffer (`.acc` / `.shared`) addressed by a mixed-radix
    /// index over `members` (chain-loop positions, outermost first):
    /// stride at member `j` is the product of later member extents and
    /// the footprint is the product of all member extents.
    Radix { members: Vec<usize> },
}

struct ChainRecipe {
    /// `(axis, part)` split provenance of each chain loop, parsed from
    /// the donor's leaf variable names.
    loop_leaf: Vec<(usize, usize)>,
    accesses: Vec<AccessRecipe>,
}

struct StructureRecipe {
    chains: Vec<ChainRecipe>,
}

impl StructureRecipe {
    /// Derive the replay recipe from a donor lowering. Every claim the
    /// recipe encodes is verified against the donor analysis — exact
    /// stride divisibility for globals, suffix-product strides and
    /// footprint for scratch buffers, and finally a full replay of the
    /// donor's own config compared bit-for-bit. Returns `None` if any
    /// check fails (that structure then always takes the full path).
    fn build(
        task: &Task,
        program: &Program,
        analysis: &ProgramAnalysis,
        e: &ConfigEntity,
    ) -> Option<Self> {
        let mut axis_of = std::collections::HashMap::new();
        for (i, ax) in task.def.all_axes().enumerate() {
            axis_of.insert(ax.name.clone(), i);
        }
        let mut chains = Vec::with_capacity(analysis.chains.len());
        for chain in &analysis.chains {
            let mut loop_leaf = Vec::with_capacity(chain.loops.len());
            for l in &chain.loops {
                let name = program.vars.name(l.var);
                let (base, part) = name.rsplit_once('.')?;
                let part: usize = part.parse().ok()?;
                let &axis = axis_of.get(base)?;
                let sizes = task.split_sizes(e, axis);
                if part >= sizes.len() || sizes[part] != l.extent {
                    return None;
                }
                loop_leaf.push((axis, part));
            }
            let mut accesses = Vec::with_capacity(chain.accesses.len());
            for a in &chain.accesses {
                let decl = program.buffer(&a.buffer)?;
                accesses.push(if decl.scope == MemScope::Global {
                    let mut per_loop = Vec::with_capacity(loop_leaf.len());
                    for (l, &(axis, part)) in loop_leaf.iter().enumerate() {
                        let s = a.strides[l];
                        if s == 0 {
                            per_loop.push(None);
                            continue;
                        }
                        let sizes = task.split_sizes(e, axis);
                        let ip: i64 = sizes[part + 1..].iter().product();
                        if ip == 0 || s % ip != 0 {
                            return None;
                        }
                        per_loop.push(Some((axis, part, s / ip)));
                    }
                    AccessRecipe::Global { per_loop, cap: decl.numel() as f64 }
                } else {
                    let members: Vec<usize> =
                        (0..loop_leaf.len()).filter(|&l| a.strides[l] != 0).collect();
                    // the flattened index must be exactly mixed-radix
                    // over the members, covering the whole buffer
                    let mut acc = 1i64;
                    for &m in members.iter().rev() {
                        if a.strides[m] != acc {
                            return None;
                        }
                        acc *= chain.loops[m].extent;
                    }
                    if acc.max(1) != decl.numel() {
                        return None;
                    }
                    AccessRecipe::Radix { members }
                });
            }
            chains.push(ChainRecipe { loop_leaf, accesses });
        }
        let recipe = StructureRecipe { chains };
        // Final gate: replaying the donor's own config must reproduce
        // the donor analysis bit-for-bit.
        let mut scratch = ReplayScratch::default();
        let mut probe = ProgramAnalysis { chains: Vec::new() };
        recipe.replay(task, e, analysis, &mut scratch, &mut probe);
        if probe != *analysis {
            return None;
        }
        Some(recipe)
    }

    /// Re-derive the donor analysis for config `e` without lowering:
    /// static facts copied from the donor, extents set from `e`'s split
    /// sizes, every extent-derived quantity recomputed through the same
    /// helpers [`analyze`] uses.
    fn replay(
        &self,
        task: &Task,
        e: &ConfigEntity,
        donor: &ProgramAnalysis,
        scratch: &mut ReplayScratch,
        out: &mut ProgramAnalysis,
    ) {
        let n_axes = task.def.axes.len() + task.def.reduce_axes.len();
        if scratch.ip.len() < n_axes {
            scratch.ip.resize(n_axes, Vec::new());
        }
        for axis in 0..n_axes {
            let sizes = task.split_sizes(e, axis);
            let ip = &mut scratch.ip[axis];
            ip.clear();
            ip.resize(sizes.len(), 1);
            let mut acc = 1i64;
            for p in (0..sizes.len()).rev() {
                ip[p] = acc;
                acc *= sizes[p];
            }
        }
        if out.chains.len() != donor.chains.len() {
            out.chains.clear();
            out.chains.extend(donor.chains.iter().cloned());
        }
        for ((oc, dc), rc) in out.chains.iter_mut().zip(&donor.chains).zip(&self.chains) {
            if oc.loops.len() != dc.loops.len() || oc.accesses.len() != dc.accesses.len() {
                *oc = dc.clone();
            }
            let StoreChain {
                loops,
                accesses,
                value_flops,
                accumulate,
                has_guard,
                trip,
                top_down,
                bottom_up,
            } = oc;
            *value_flops = dc.value_flops;
            *accumulate = dc.accumulate;
            *has_guard = dc.has_guard;
            for ((ol, dl), &(axis, part)) in
                loops.iter_mut().zip(&dc.loops).zip(&rc.loop_leaf)
            {
                ol.var = dl.var;
                ol.kind = dl.kind;
                ol.extent = task.split_sizes(e, axis)[part];
            }
            *trip = fill_products(loops, top_down, bottom_up);
            let n = loops.len();
            for ((oa, da), ra) in accesses.iter_mut().zip(&dc.accesses).zip(&rc.accesses) {
                oa.buffer.clone_from(&da.buffer);
                oa.scope = da.scope;
                oa.is_write = da.is_write;
                oa.strides.clear();
                oa.strides.resize(n, 0);
                let cap = match ra {
                    AccessRecipe::Global { per_loop, cap } => {
                        for (l, w) in per_loop.iter().enumerate() {
                            if let Some((axis, part, w)) = w {
                                oa.strides[l] = w * scratch.ip[*axis][*part];
                            }
                        }
                        *cap
                    }
                    AccessRecipe::Radix { members } => {
                        let mut acc = 1i64;
                        for &m in members.iter().rev() {
                            oa.strides[m] = acc;
                            acc *= loops[m].extent;
                        }
                        acc.max(1) as f64
                    }
                };
                fill_touch_reuse(loops, &oa.strides, cap, bottom_up, &mut oa.touch, &mut oa.reuse);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ast::{BufferDecl, MemScope, Program, Stmt, Value};
    use crate::expr::{IndexExpr, VarPool};

    /// Build the naive matmul of Fig. 1 (x0 default code):
    /// for y, x, k: C[y][x] += A[k][y] * B[k][x]
    fn naive_matmul(n: i64) -> Program {
        let mut pool = VarPool::new();
        let y = pool.fresh("y");
        let x = pool.fresh("x");
        let k = pool.fresh("k");
        let store = Stmt::Store {
            buffer: "C".into(),
            indices: vec![IndexExpr::var(y), IndexExpr::var(x)],
            value: Value::Mul(
                Box::new(Value::load("A", vec![IndexExpr::var(k), IndexExpr::var(y)])),
                Box::new(Value::load("B", vec![IndexExpr::var(k), IndexExpr::var(x)])),
            ),
            accumulate: true,
        };
        let nest = Stmt::For {
            var: y,
            extent: n,
            kind: ForKind::Serial,
            body: vec![Stmt::For {
                var: x,
                extent: n,
                kind: ForKind::Serial,
                body: vec![Stmt::For { var: k, extent: n, kind: ForKind::Serial, body: vec![store] }],
            }],
        };
        Program {
            name: "naive_matmul".into(),
            stmts: vec![nest],
            buffers: vec![
                BufferDecl { name: "C".into(), shape: vec![n, n], scope: MemScope::Global },
                BufferDecl { name: "A".into(), shape: vec![n, n], scope: MemScope::Global },
                BufferDecl { name: "B".into(), shape: vec![n, n], scope: MemScope::Global },
            ],
            vars: pool,
            flops: 2 * (n as u64).pow(3),
        }
    }

    #[test]
    fn naive_matmul_chain_quantities() {
        let p = naive_matmul(64);
        let a = analyze(&p);
        assert_eq!(a.chains.len(), 1);
        let c = &a.chains[0];
        assert_eq!(c.loops.len(), 3);
        assert_eq!(c.trip, 64f64.powi(3));
        assert_eq!(c.top_down, vec![1.0, 64.0, 64.0 * 64.0]);
        assert_eq!(c.bottom_up, vec![64f64.powi(3), 64f64.powi(2), 64.0]);

        // Store C[y][x]: strides (y: 64, x: 1, k: 0)
        let cs = c.access("C").unwrap();
        assert_eq!(cs.strides, vec![64, 1, 0]);
        // touch from level 0: all 64*64 elements; from level 2 (k): 1.
        assert_eq!(cs.touch, vec![4096.0, 64.0, 1.0]);
        // reuse at k level: 64 iterations hit the same element
        assert_eq!(cs.reuse[2], 64.0);

        // A[k][y]: strides (y: 1, x: 0, k: 64)
        let as_ = c.access("A").unwrap();
        assert_eq!(as_.strides, vec![1, 0, 64]);
        assert_eq!(as_.reuse[1], 64.0); // x loop re-reads the same A column
        assert_eq!(c.value_flops, 2); // mul + accumulate add
    }

    #[test]
    fn touch_capped_at_buffer_size() {
        // Loop over 128 iterations of a 16-element buffer with stride 1:
        // touch must cap at 16.
        let mut pool = VarPool::new();
        let i = pool.fresh("i");
        let p = Program {
            name: "cap".into(),
            stmts: vec![Stmt::For {
                var: i,
                extent: 128,
                kind: ForKind::Serial,
                body: vec![Stmt::Store {
                    buffer: "O".into(),
                    indices: vec![IndexExpr::var(i)],
                    value: Value::load("S", vec![IndexExpr::var(i)]),
                    accumulate: false,
                }],
            }],
            buffers: vec![
                BufferDecl { name: "O".into(), shape: vec![128], scope: MemScope::Global },
                BufferDecl { name: "S".into(), shape: vec![16], scope: MemScope::Shared },
            ],
            vars: pool,
            flops: 0,
        };
        let a = analyze(&p);
        let s = a.chains[0].access("S").unwrap();
        assert_eq!(s.touch[0], 16.0);
        assert_eq!(s.scope, MemScope::Shared);
    }

    #[test]
    fn longest_chain_picks_deepest() {
        let mut p = naive_matmul(8);
        // append a shallow init store
        let mut pool = p.vars.clone();
        let t = pool.fresh("t");
        p.vars = pool;
        p.stmts.insert(
            0,
            Stmt::For {
                var: t,
                extent: 8,
                kind: ForKind::Serial,
                body: vec![Stmt::Store {
                    buffer: "C".into(),
                    indices: vec![IndexExpr::var(t), IndexExpr::constant(0)],
                    value: Value::Imm(0.0),
                    accumulate: false,
                }],
            },
        );
        let a = analyze(&p);
        assert_eq!(a.chains.len(), 2);
        assert_eq!(a.longest_chain().loops.len(), 3);
    }
}
