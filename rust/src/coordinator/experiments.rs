//! Experiment drivers — one function per table/figure of the paper's
//! evaluation (§6, Figs. 4–11 and the supplementary sweeps 13–16).
//!
//! Each driver prints machine-readable series (`fig,workload,method,
//! trial,gflops` CSV rows) plus a human summary, and returns the raw
//! data so tests can assert the paper's qualitative claims (model-based
//! beats black-box, transfer gives 2–10×, invariant features transfer
//! across operator types, AutoTVM beats the vendor baseline
//! end-to-end). `ExpOpts::full` switches from CI-scale budgets to the
//! paper's (800 trials, 128×500 SA).

use crate::explore::{SaParams, SearchKind};
use crate::features::Representation;
use crate::gbt::{GbtParams, Objective};
use crate::measure::{Measurer, SimMeasurer};
use crate::model::{Acquisition, EnsembleModel, GbtModel, TransferModel};
use crate::schedule::template::{Task, TemplateKind};
use crate::sim::devices;
use crate::sim::DeviceModel;
use crate::tuner::db::Database;
use crate::tuner::scheduler::{AllocPolicy, SchedulerOptions, TaskScheduler};
use crate::tuner::{tune_ga, tune_random, DbSink, TuneOptions, TuneResult, Tuner};
use crate::workloads;

/// Budgets for one experiment run.
#[derive(Clone, Debug)]
pub struct ExpOpts {
    /// Measurement trials per tuning run.
    pub trials: usize,
    /// Measurement batch size.
    pub batch: usize,
    /// Simulated-annealing exploration budget.
    pub sa: SaParams,
    /// Exploration strategy over the cost model (`--search sa|evo`).
    pub search: SearchKind,
    /// Seed of every RNG stream.
    pub seed: u64,
    /// Paper-scale budgets (800 trials, full SA).
    pub full: bool,
    /// Evaluate on all 12 workloads (supplementary Figs. 13–16).
    pub all_workloads: bool,
    /// Stage depth for [`run_method_pipelined`] (see
    /// [`crate::tuner::pipeline`]).
    pub pipeline_depth: usize,
    /// Live record sink: stream every measured trial into a shared
    /// [`Database`] (see [`TuneOptions::sink`]).
    pub sink: Option<DbSink>,
    /// Per-round progress printing (see [`TuneOptions::verbose`]).
    pub verbose: bool,
    /// Bit-exact hot paths (compiled GBT plan, incremental SA
    /// featurization — see [`TuneOptions::fast_paths`]); `false` is the
    /// `--no-fast-paths` scalar reference.
    pub fast_paths: bool,
    /// Feature representation override (`--repr`); `None` keeps the
    /// [`TuneOptions`] default.
    pub repr: Option<crate::features::Representation>,
    /// Worker-thread pin (`--threads N`): exported as `PALLAS_THREADS`
    /// by the CLI so every parallel helper (featurization, GBT predict,
    /// measurement fan-out) runs at this width.
    pub threads: Option<usize>,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            trials: 256,
            batch: 64,
            sa: SaParams { n_chains: 64, n_steps: 120, ..Default::default() },
            search: SearchKind::Sa,
            seed: 0,
            full: false,
            all_workloads: false,
            pipeline_depth: 2,
            sink: None,
            verbose: false,
            fast_paths: true,
            repr: None,
            threads: None,
        }
    }
}

impl ExpOpts {
    /// The paper's experiment configuration (800 trials, full SA).
    pub fn paper_scale() -> Self {
        ExpOpts {
            trials: 800,
            batch: 64,
            sa: SaParams::default(),
            full: true,
            ..Default::default()
        }
    }

    pub(crate) fn tune_options(&self) -> TuneOptions {
        let mut o = TuneOptions {
            n_trials: self.trials,
            batch: self.batch,
            sa: self.sa.clone(),
            search: self.search,
            seed: self.seed,
            pipeline_depth: self.pipeline_depth,
            sink: self.sink.clone(),
            verbose: self.verbose,
            fast_paths: self.fast_paths,
            ..Default::default()
        };
        if let Some(r) = self.repr {
            o.repr = r;
        }
        o
    }

    fn workloads(&self, representative: &[usize]) -> Vec<usize> {
        if self.all_workloads {
            (1..=12).collect()
        } else {
            representative.to_vec()
        }
    }
}

/// Tuning method axis of Figs. 4–7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Uniform random search.
    Random,
    /// Random search with a 2× measurement budget (Fig. 4's `random_x2`).
    RandomX2,
    /// Genetic-algorithm black-box search.
    Ga,
    /// GA with a 2× measurement budget.
    GaX2,
    /// GBT cost model, rank objective (the paper's default).
    GbtRank,
    /// GBT cost model, regression objective.
    GbtReg,
    /// context-encoded neural model via PJRT (needs artifacts)
    NeuralRank,
    /// Neural model with the regression objective.
    NeuralReg,
    /// bootstrap-ensemble GBT with an acquisition function
    EnsembleMean,
    /// Ensemble with UCB acquisition.
    EnsembleUcb,
    /// Ensemble with expected-improvement acquisition.
    EnsembleEi,
}

impl Method {
    /// CLI / CSV name of the method.
    pub fn name(self) -> &'static str {
        match self {
            Method::Random => "random",
            Method::RandomX2 => "random_x2",
            Method::Ga => "ga",
            Method::GaX2 => "ga_x2",
            Method::GbtRank => "gbt_rank",
            Method::GbtReg => "gbt_reg",
            Method::NeuralRank => "neural_rank",
            Method::NeuralReg => "neural_reg",
            Method::EnsembleMean => "ens_mean",
            Method::EnsembleUcb => "ens_ucb",
            Method::EnsembleEi => "ens_ei",
        }
    }
}

/// Model construction shared by the serial and pipelined drivers for
/// the snapshot-capable model-based methods (GBT, bootstrap ensembles;
/// the ensemble arms also set `o.acquisition`). `None` for the
/// black-box baselines and the thread-affine neural model — keeping one
/// builder guarantees serial and pipelined runs of the same method use
/// identical models.
fn snapshot_model(
    method: Method,
    o: &mut TuneOptions,
) -> Option<Box<dyn crate::model::CostModel + Send>> {
    match method {
        Method::GbtRank | Method::GbtReg => {
            let objective = if method == Method::GbtRank {
                Objective::Rank
            } else {
                Objective::Regression
            };
            let params = GbtParams { objective, seed: o.seed, ..Default::default() };
            Some(Box::new(GbtModel::with_fast_paths(params, o.fast_paths)))
        }
        Method::EnsembleMean | Method::EnsembleUcb | Method::EnsembleEi => {
            // the paper's Fig. 7 setup: 5 bootstrap models, regression
            // objective (as in Bayesian-optimization practice)
            let params = GbtParams {
                objective: Objective::Regression,
                n_trees: 30,
                seed: o.seed,
                ..Default::default()
            };
            o.acquisition = match method {
                Method::EnsembleUcb => Acquisition::Ucb(1.0),
                Method::EnsembleEi => Acquisition::Ei,
                _ => Acquisition::Mean,
            };
            Some(Box::new(EnsembleModel::with_fast_paths(params, 5, o.fast_paths)))
        }
        _ => None,
    }
}

/// Run one method on one task. Returns the best-so-far curve indexed by
/// *trials* (×2 methods consume double measurements per trial).
pub fn run_method(
    task: &Task,
    measurer: &dyn Measurer,
    method: Method,
    opts: &ExpOpts,
) -> TuneResult {
    let mut o = opts.tune_options();
    match method {
        Method::Random => tune_random(task.clone(), measurer, o),
        Method::Ga => tune_ga(task.clone(), measurer, o),
        Method::RandomX2 | Method::GaX2 => {
            o.n_trials *= 2;
            let r = if method == Method::RandomX2 {
                tune_random(task.clone(), measurer, o)
            } else {
                tune_ga(task.clone(), measurer, o)
            };
            // two measurements per trial: compress the curve 2:1
            let curve: Vec<f64> =
                r.curve.chunks(2).map(|c| c[c.len() - 1]).collect();
            TuneResult { curve, ..r }
        }
        Method::NeuralRank | Method::NeuralReg => {
            use crate::model::neural::{NeuralModel, NeuralObjective};
            let rt = crate::runtime::PjrtRuntime::cpu().expect("PJRT client");
            let nobj = if method == Method::NeuralRank {
                NeuralObjective::Rank
            } else {
                NeuralObjective::Regression
            };
            let model = Box::new(
                NeuralModel::load(&rt, nobj, o.seed).expect("run `make artifacts`"),
            );
            o.repr = Representation::FlatAst; // the context-matrix layout
            Tuner::new(task.clone(), model, o).tune(measurer)
        }
        Method::GbtRank
        | Method::GbtReg
        | Method::EnsembleMean
        | Method::EnsembleUcb
        | Method::EnsembleEi => {
            let model = snapshot_model(method, &mut o).expect("model-based method");
            Tuner::new(task.clone(), model, o).tune(measurer)
        }
    }
}

/// Pipelined counterpart of [`run_method`] for the model-based methods
/// (the production path: explore ∥ measure ∥ retrain, see
/// [`crate::tuner::pipeline`]). Returns `None` for methods without a
/// pipelined implementation — the black-box baselines measure every
/// proposal immediately, and the PJRT-backed neural model is
/// thread-affine — so callers can fall back to [`run_method`].
pub fn run_method_pipelined(
    task: &Task,
    measurer: &dyn Measurer,
    method: Method,
    opts: &ExpOpts,
) -> Option<TuneResult> {
    use crate::tuner::pipeline::PipelinedTuner;
    let mut o = opts.tune_options();
    let model = snapshot_model(method, &mut o)?;
    Some(PipelinedTuner::new(task.clone(), model, o).tune(measurer))
}

fn emit_curve(fig: &str, workload: &str, method: &str, curve: &[f64], stride: usize) {
    for (i, g) in curve.iter().enumerate() {
        if (i + 1) % stride == 0 || i + 1 == curve.len() {
            println!("{fig},{workload},{method},{},{g:.2}", i + 1);
        }
    }
}

/// Fig. 4: statistical cost model (GBT / neural) vs GA and Random.
pub fn fig4(opts: &ExpOpts, with_neural: bool) -> Vec<(String, String, TuneResult)> {
    println!("# Fig 4: cost model vs black-box baselines ({} trials, sim-gpu)", opts.trials);
    println!("fig,workload,method,trial,best_gflops");
    let mut methods = vec![
        Method::Random,
        Method::RandomX2,
        Method::Ga,
        Method::GaX2,
        Method::GbtRank,
    ];
    if with_neural {
        methods.push(Method::NeuralRank);
    }
    let mut out = Vec::new();
    for wl in opts.workloads(&[3, 6, 9]) {
        let task = workloads::conv_task(wl, TemplateKind::Gpu);
        for m in &methods {
            let measurer = SimMeasurer::with_seed(devices::sim_gpu(), 1000 + wl as u64);
            let res = run_method(&task, &measurer, *m, opts);
            emit_curve("fig4", &format!("C{wl}"), m.name(), &res.curve, opts.batch);
            out.push((format!("C{wl}"), m.name().to_string(), res));
        }
    }
    summarize_final(&out);
    out
}

/// Fig. 5: rank vs regression training objective (both model families).
pub fn fig5(opts: &ExpOpts, with_neural: bool) -> Vec<(String, String, TuneResult)> {
    println!("# Fig 5: rank vs regression objective (sim-gpu)");
    println!("fig,workload,method,trial,best_gflops");
    let mut methods = vec![Method::GbtRank, Method::GbtReg];
    if with_neural {
        methods.extend([Method::NeuralRank, Method::NeuralReg]);
    }
    let mut out = Vec::new();
    for wl in opts.workloads(&[3, 6]) {
        let task = workloads::conv_task(wl, TemplateKind::Gpu);
        for m in &methods {
            let measurer = SimMeasurer::with_seed(devices::sim_gpu(), 2000 + wl as u64);
            let res = run_method(&task, &measurer, *m, opts);
            emit_curve("fig5", &format!("C{wl}"), m.name(), &res.curve, opts.batch);
            out.push((format!("C{wl}"), m.name().to_string(), res));
        }
    }
    summarize_final(&out);
    out
}

/// Fig. 6: diversity-aware exploration with different λ.
pub fn fig6(opts: &ExpOpts) -> Vec<(String, String, TuneResult)> {
    println!("# Fig 6: diversity-aware selection, lambda sweep (sim-gpu)");
    println!("fig,workload,method,trial,best_gflops");
    let mut out = Vec::new();
    for wl in opts.workloads(&[3, 6]) {
        let task = workloads::conv_task(wl, TemplateKind::Gpu);
        for (name, lambda, diversity) in
            [("no_diversity", 1usize, false), ("lambda2", 2, true), ("lambda4", 4, true)]
        {
            let measurer = SimMeasurer::with_seed(devices::sim_gpu(), 3000 + wl as u64);
            let mut o = opts.tune_options();
            o.lambda = lambda;
            o.diversity = diversity;
            let params = GbtParams { seed: o.seed, ..Default::default() };
            let res = Tuner::new(task.clone(), Box::new(GbtModel::new(params)), o)
                .tune(&measurer);
            emit_curve("fig6", &format!("C{wl}"), name, &res.curve, opts.batch);
            out.push((format!("C{wl}"), name.to_string(), res));
        }
    }
    summarize_final(&out);
    out
}

/// Fig. 7: uncertainty-aware acquisition functions.
pub fn fig7(opts: &ExpOpts) -> Vec<(String, String, TuneResult)> {
    println!("# Fig 7: acquisition functions over a bootstrap ensemble (sim-gpu)");
    println!("fig,workload,method,trial,best_gflops");
    let mut out = Vec::new();
    for wl in opts.workloads(&[3, 6]) {
        let task = workloads::conv_task(wl, TemplateKind::Gpu);
        for m in [Method::EnsembleMean, Method::EnsembleUcb, Method::EnsembleEi] {
            let measurer = SimMeasurer::with_seed(devices::sim_gpu(), 4000 + wl as u64);
            let res = run_method(&task, &measurer, m, opts);
            emit_curve("fig7", &format!("C{wl}"), m.name(), &res.curve, opts.batch);
            out.push((format!("C{wl}"), m.name().to_string(), res));
        }
    }
    summarize_final(&out);
    out
}

/// Collect a source-domain database `D'` by tuning `source_workloads`.
pub fn collect_source_db(
    source_workloads: &[usize],
    template: TemplateKind,
    device: &DeviceModel,
    trials_per_task: usize,
    seed: u64,
) -> Database {
    let db = Database::new();
    for &wl in source_workloads {
        let task = workloads::conv_task(wl, template);
        let measurer = SimMeasurer::with_seed(device.clone(), 9000 + wl as u64);
        let mut o = TuneOptions {
            n_trials: trials_per_task,
            seed: seed + wl as u64,
            ..Default::default()
        };
        o.sa = SaParams { n_chains: 64, n_steps: 100, ..Default::default() };
        o.sink = Some(DbSink::new(&db, &task, device.name));
        crate::tuner::tune_gbt(task, &measurer, o);
    }
    db
}

/// Build a transfer model from `db` under a representation.
pub fn transfer_model_from(
    db: &Database,
    source_tasks: &[&Task],
    target: &str,
    repr: Representation,
    limit_per_task: usize,
    seed: u64,
) -> TransferModel {
    let (x, y, groups) = db.to_training(source_tasks, target, repr, limit_per_task);
    let params = GbtParams { objective: Objective::Rank, seed, ..Default::default() };
    TransferModel::from_source(&x, &y, &groups, params)
}

/// The task inventory the service knows how to re-lower when replaying
/// DB records: every Table-1 conv under both templates, plus the
/// matmul transfer target of Fig. 9.
fn known_tasks() -> Vec<Task> {
    let mut tasks = Vec::new();
    for template in [TemplateKind::Cpu, TemplateKind::Gpu] {
        for wl in 1..=12 {
            tasks.push(workloads::conv_task(wl, template));
        }
        tasks.push(workloads::matmul_1024_task(template));
    }
    tasks
}

/// Automatic cross-workload warm start: query `db` for records of
/// *other* known tasks on the same `target` (tier 1, full weight) and
/// of known tasks on *other* targets (tier 2, down-weighted — the
/// heterogeneous-fleet transfer path), build `D'` under the invariant
/// `ContextRelation` representation and train the Eq.-4 global model.
/// Returns `None` when the DB holds nothing usable.
///
/// Thin wrapper over the shared [`TransferModel::warm_start_tiered`]
/// entry point (the graph scheduler's `LoopExecutor` wraps the same
/// function with its plan's sibling tasks as the inventory) — source
/// discovery, representation and model hyper-parameters live in one
/// place.
pub fn warm_start_model(
    db: &Database,
    target_task: &Task,
    target: &str,
    objective: Objective,
    seed: u64,
) -> Option<TransferModel> {
    let inventory = known_tasks();
    let (model, stats) =
        TransferModel::warm_start_tiered(db, &inventory, target_task, target, objective, seed)?;
    println!("# warm-start: global model from sibling task records on {target} (ContextRelation D')");
    if stats.used_cross_target() {
        println!(
            "# warm-start: cross-target D' on {target}: {} rows from [{}] at weight {}",
            stats.cross_target_rows,
            stats.cross_targets.join(", "),
            crate::model::CROSS_TARGET_WEIGHT,
        );
    }
    Some(model)
}

/// Warm-started counterpart of [`run_method`] / [`run_method_pipelined`]
/// — the default service path when the shared DB is non-empty. The
/// global model is the tuner's initial model (and the pipelined loop's
/// epoch-0 snapshot), so even the first SA round is informed. Returns
/// `None` for methods without a transfer path (black-box baselines,
/// ensembles, the thread-affine neural model) or when the DB has no
/// usable source rows; callers fall back to the cold path.
pub fn run_method_warm(
    task: &Task,
    measurer: &dyn Measurer,
    method: Method,
    opts: &ExpOpts,
    db: &Database,
    target: &str,
    pipelined: bool,
) -> Option<TuneResult> {
    let objective = match method {
        Method::GbtRank => Objective::Rank,
        Method::GbtReg => Objective::Regression,
        _ => return None,
    };
    let model = warm_start_model(db, task, target, objective, opts.seed)?;
    let mut o = opts.tune_options();
    // features must match the representation the global model was
    // trained on
    o.repr = Representation::ContextRelation;
    Some(if pipelined {
        crate::tuner::pipeline::PipelinedTuner::new(task.clone(), Box::new(model), o)
            .tune(measurer)
    } else {
        Tuner::new(task.clone(), Box::new(model), o).tune(measurer)
    })
}

/// Fig. 8: transfer learning speedup, C1–C6 → C7, C8, C9.
pub fn fig8(opts: &ExpOpts) -> Vec<(String, String, TuneResult)> {
    println!("# Fig 8: transfer from C1-C6 (sim-gpu)");
    println!("fig,workload,method,trial,best_gflops");
    let device = devices::sim_gpu();
    let per_task = if opts.full { 800 } else { opts.trials };
    let source: Vec<usize> = (1..=6).collect();
    let db = collect_source_db(&source, TemplateKind::Gpu, &device, per_task, opts.seed);
    let source_tasks: Vec<Task> = source
        .iter()
        .map(|&w| workloads::conv_task(w, TemplateKind::Gpu))
        .collect();
    let refs: Vec<&Task> = source_tasks.iter().collect();

    let mut out = Vec::new();
    for wl in [7usize, 8, 9] {
        let task = workloads::conv_task(wl, TemplateKind::Gpu);
        // transfer-enabled
        let measurer = SimMeasurer::with_seed(device.clone(), 5000 + wl as u64);
        let model = transfer_model_from(
            &db, &refs, device.name, Representation::Full, usize::MAX, opts.seed,
        );
        let mut o = opts.tune_options();
        o.repr = Representation::Full;
        let res_t =
            Tuner::new(task.clone(), Box::new(model), o.clone()).tune(&measurer);
        emit_curve("fig8", &format!("C{wl}"), "transfer", &res_t.curve, opts.batch);
        // cold start
        let measurer2 = SimMeasurer::with_seed(device.clone(), 5000 + wl as u64);
        let params = GbtParams { seed: o.seed, ..Default::default() };
        let res_c = Tuner::new(task.clone(), Box::new(GbtModel::new(params)), o)
            .tune(&measurer2);
        emit_curve("fig8", &format!("C{wl}"), "scratch", &res_c.curve, opts.batch);

        // speedup: trials for scratch to reach transfer's curve value at
        // 25% budget
        let target = res_t.best_at(opts.trials / 4);
        let t_t = res_t.trials_to_reach(target).unwrap_or(opts.trials);
        let t_c = res_c.trials_to_reach(target).unwrap_or(opts.trials * 2);
        println!(
            "# C{wl}: transfer reached {target:.0} GFLOPS in {t_t} trials, \
             scratch needed {t_c} ({:.1}x speedup)",
            t_c as f64 / t_t as f64
        );
        out.push((format!("C{wl}"), "transfer".into(), res_t));
        out.push((format!("C{wl}"), "scratch".into(), res_c));
    }
    out
}

/// Fig. 9: invariance of representations across domain distances.
pub fn fig9(opts: &ExpOpts) -> Vec<(String, String, TuneResult)> {
    println!("# Fig 9: representation invariance across transfer distances");
    println!("fig,scenario,representation,trial,best_gflops");
    let device = devices::sim_gpu();
    let per_task = if opts.full { 800 } else { opts.trials };
    let source: Vec<usize> = (1..=6).collect();
    let db = collect_source_db(&source, TemplateKind::Gpu, &device, per_task, opts.seed);
    let source_tasks: Vec<Task> = source
        .iter()
        .map(|&w| workloads::conv_task(w, TemplateKind::Gpu))
        .collect();
    let refs: Vec<&Task> = source_tasks.iter().collect();

    let reprs = [
        ("config", Representation::Config),
        ("flat_ast", Representation::FlatAst),
        ("context_relation", Representation::ContextRelation),
    ];
    let scenarios: Vec<(&str, Task)> = vec![
        ("conv_to_conv_C7", workloads::conv_task(7, TemplateKind::Gpu)),
        ("conv_to_matmul1024", workloads::matmul_1024_task(TemplateKind::Gpu)),
    ];
    let mut out = Vec::new();
    for (scen, task) in &scenarios {
        for (rname, repr) in reprs {
            let measurer = SimMeasurer::with_seed(device.clone(), 6000);
            let model =
                transfer_model_from(&db, &refs, device.name, repr, usize::MAX, opts.seed);
            let mut o = opts.tune_options();
            o.repr = repr;
            let res = Tuner::new(task.clone(), Box::new(model), o).tune(&measurer);
            emit_curve("fig9", scen, rname, &res.curve, opts.batch);
            out.push((scen.to_string(), rname.to_string(), res));
        }
        // no-transfer reference
        let measurer = SimMeasurer::with_seed(device.clone(), 6000);
        let o = opts.tune_options();
        let res = crate::tuner::tune_gbt(task.clone(), &measurer, o);
        emit_curve("fig9", scen, "no_transfer", &res.curve, opts.batch);
        out.push((scen.to_string(), "no_transfer".into(), res));
    }

    // Fig. 9d: cross-device transfer (the paper's Mali → Cortex-A53
    // study) — D' collected on sim-mali (GPU template), target tuned on
    // sim-cpu (CPU template; different knob space, so only the program-
    // level representations can transfer).
    let mali = devices::sim_mali();
    let db_mali =
        collect_source_db(&[2, 4, 6], TemplateKind::Gpu, &mali, per_task, opts.seed);
    let mali_tasks: Vec<Task> =
        [2, 4, 6].iter().map(|&w| workloads::conv_task(w, TemplateKind::Gpu)).collect();
    let mali_refs: Vec<&Task> = mali_tasks.iter().collect();
    let cpu = devices::sim_cpu();
    let target = workloads::conv_task(7, TemplateKind::Cpu);
    for (rname, repr) in reprs {
        let measurer = SimMeasurer::with_seed(cpu.clone(), 6100);
        let model =
            transfer_model_from(&db_mali, &mali_refs, mali.name, repr, usize::MAX, opts.seed);
        let mut o = opts.tune_options();
        o.repr = repr;
        let res = Tuner::new(target.clone(), Box::new(model), o).tune(&measurer);
        emit_curve("fig9", "mali_to_a53_C7", rname, &res.curve, opts.batch);
        out.push(("mali_to_a53_C7".into(), rname.to_string(), res));
    }
    let measurer = SimMeasurer::with_seed(cpu.clone(), 6100);
    let res = crate::tuner::tune_gbt(target, &measurer, opts.tune_options());
    emit_curve("fig9", "mali_to_a53_C7", "no_transfer", &res.curve, opts.batch);
    out.push(("mali_to_a53_C7".into(), "no_transfer".into(), res));

    summarize_final(&out);
    out
}

/// Fig. 10: single-operator performance vs the vendor baseline, all
/// C1–C12 on a device. Returns (workload, vendor GFLOPS, tc GFLOPS,
/// autotvm GFLOPS).
pub fn fig10(opts: &ExpOpts, device: &DeviceModel) -> Vec<(String, f64, f64, f64)> {
    let template = match device.class {
        crate::sim::DeviceClass::Gpu => TemplateKind::Gpu,
        crate::sim::DeviceClass::Cpu => TemplateKind::Cpu,
    };
    println!(
        "# Fig 10: single-op performance on {} (vendor vs TC(GA) vs AutoTVM vs AutoTVM-PT)",
        device.name
    );
    println!("fig,workload,vendor_gflops,tc_gflops,autotvm_gflops,pt_gflops,speedup");
    let mut out = Vec::new();
    for wl in 1..=12 {
        let task = workloads::conv_task(wl, template);
        // vendor library: expert fixed schedule
        let vendor_cfg = crate::baselines::vendor_config(&task);
        let vendor = task
            .lower(&vendor_cfg)
            .ok()
            .and_then(|p| device.evaluate(&p).ok())
            .map(|r| r.gflops)
            .unwrap_or(0.0);
        // TensorComprehensions stand-in: GA search (gpu only, like the paper)
        let tc = if template == TemplateKind::Gpu {
            let measurer = SimMeasurer::with_seed(device.clone(), 7000 + wl as u64);
            run_method(&task, &measurer, Method::Ga, opts).best_gflops()
        } else {
            0.0
        };
        // AutoTVM
        let measurer = SimMeasurer::with_seed(device.clone(), 7000 + wl as u64);
        let autotvm = run_method(&task, &measurer, Method::GbtRank, opts).best_gflops();
        // AutoTVM-PT: Winograd with pre-transformed weights (3×3 s1 only)
        let params = workloads::conv_workload(wl);
        let pt = if crate::expr::winograd::applicable(&params) {
            let s = crate::expr::winograd::stages(params);
            let bt = Task::new(s.bgemm.clone(), template);
            let measurer = SimMeasurer::with_seed(device.clone(), 7500 + wl as u64);
            let best = run_method(&bt, &measurer, Method::GbtRank, opts);
            let bgemm_secs = best
                .best
                .as_ref()
                .map(|(e, _)| {
                    device.evaluate(&bt.lower(e).unwrap()).map(|r| r.seconds).unwrap_or(f64::INFINITY)
                })
                .unwrap_or(f64::INFINITY);
            let t_aux: f64 = [&s.input_transform, &s.output_transform]
                .iter()
                .map(|d| {
                    let t = Task::new((*d).clone(), template);
                    let e = crate::graph::quick_best(&t, device, 24, 5);
                    device
                        .evaluate(&t.lower(&e).unwrap())
                        .map(|r| r.seconds)
                        .unwrap_or(f64::INFINITY)
                })
                .sum();
            s.direct_flops as f64 / (bgemm_secs + t_aux) / 1e9
        } else {
            0.0
        };
        println!(
            "fig10,C{wl},{vendor:.1},{tc:.1},{autotvm:.1},{pt:.1},{:.2}",
            autotvm.max(pt) / vendor.max(1e-9)
        );
        out.push((format!("C{wl}"), vendor, tc, autotvm.max(pt)));
    }
    out
}

/// Fig. 11: end-to-end network latency, AutoTVM (fused + tuned) vs the
/// vendor baseline (unfused + fixed schedules). The AutoTVM side runs
/// through the graph-level [`TaskScheduler`]: one global budget of
/// `tasks × trials`, allocated to tasks by expected end-to-end gain,
/// with every trial streamed into a shared DB so later tasks warm-start
/// from earlier ones.
pub fn fig11(
    opts: &ExpOpts,
    device: &DeviceModel,
    nets: &[&str],
) -> Vec<(String, f64, f64)> {
    let template = match device.class {
        crate::sim::DeviceClass::Gpu => TemplateKind::Gpu,
        crate::sim::DeviceClass::Cpu => TemplateKind::Cpu,
    };
    println!("# Fig 11: end-to-end inference on {}", device.name);
    println!("fig,network,baseline_ms,autotvm_ms,speedup");
    let mut out = Vec::new();
    for &name in nets {
        let graph = workloads::network(name)
            .unwrap_or_else(|| panic!("unknown network {name}"));
        // baseline: unfused graph + vendor fixed schedules
        let (base_s, _) = graph
            .latency(device, template, |t| Some(crate::baselines::vendor_config(t)))
            .expect("baseline latency");
        // AutoTVM: fused graph + scheduler-allocated per-task tuning
        let fused = graph.fuse();
        let sched = TaskScheduler::from_graph(
            &fused,
            device,
            template,
            SchedulerOptions {
                budget: 0, // set below: tasks × per-task trials
                slice: opts.batch,
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        )
        .expect("graph decomposition");
        let n_tasks = sched.plans().len();
        let sched = sched.with_budget(n_tasks * opts.trials);
        let db = Database::new();
        let measurer = SimMeasurer::with_seed(device.clone(), 8000);
        sched.run_tuning(&measurer, &db, opts.tune_options(), false, true);
        let (auto_s, _) = fused
            .latency(device, template, |t| {
                db.best_config(&t.key(), device.name).map(|(e, _)| e)
            })
            .expect("autotvm latency");
        println!(
            "fig11,{name},{:.3},{:.3},{:.2}",
            base_s * 1e3,
            auto_s * 1e3,
            base_s / auto_s
        );
        out.push((name.to_string(), base_s, auto_s));
    }
    out
}

fn summarize_final(results: &[(String, String, TuneResult)]) {
    println!("# final best per (workload, method):");
    for (wl, m, r) in results {
        println!("#   {wl:>16} {m:<18} {:.1} GFLOPS", r.best_gflops());
    }
}
