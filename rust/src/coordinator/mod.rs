//! Coordinator: CLI, argument parsing and the tuning-job runner (the
//! L3 entry point — `autotvm <command>`).
//!
//! Commands:
//! * `table1` — print the Table-1 workload inventory.
//! * `tune` — tune one workload on a device with a chosen method. With
//!   `--db FILE` the run streams every trial into a WAL-backed
//!   [`TuningDb`](crate::tuner::db::TuningDb) live, and — by default,
//!   when that DB already holds records of *other* tasks — warm-starts
//!   a transfer model from them (`--no-warm-start` disables,
//!   `--warm-start` forces the attempt). With `--replicas R` (and the
//!   other farm flags) measurement runs through the shared asynchronous
//!   [`MeasureService`](crate::measure::service::MeasureService) and the
//!   run ends with a farm utilization report.
//! * `tune-all` — tune C1–C12 into the shared DB; each task after the
//!   first warm-starts from its predecessors' records (the §4
//!   cross-workload service flow). `--alloc gradient` replaces the
//!   fixed per-task budget with the graph-level scheduler.
//! * `tune-graph` — tune a whole network end-to-end: the
//!   [`TaskScheduler`](crate::tuner::scheduler::TaskScheduler) spreads
//!   one global trial budget across the network's tasks by expected
//!   marginal reduction in end-to-end latency (`--alloc
//!   uniform|gradient`), then reports tuned vs vendor latency. With
//!   `--targets cpu,gpu` the budget spans the cross-product of tasks ×
//!   targets on a heterogeneous farm
//!   ([`HeteroFarm`](crate::measure::farm::HeteroFarm)): class-aware
//!   dispatch keeps each trial on boards of its target, and records of
//!   one target warm-start searches on the others.
//! * `e2e` — end-to-end network latency vs the vendor baseline.
//! * `fig` — regenerate a paper figure (4–11).
//! * `serve` — open a tuned DB as a long-lived config-serving tier:
//!   optionally compact it under a retention policy
//!   (`--retain-per-task N`), then run a concurrent lookup storm
//!   ([`query_storm`](crate::tuner::serve::query_storm)) and report
//!   QPS + p50/p99 lookup latency (`--bench-json FILE` dumps the
//!   report as JSON).
//! * `pjrt-demo` — tune the Pallas matmul tile family where `f(x)` is
//!   real wall-clock through PJRT.

pub mod experiments;

use crate::measure::farm::{BoardClass, DeviceFarm, HeteroFarm};
use crate::measure::service::{MeasureService, ServiceOptions, TargetedMeasurer};
use crate::measure::{Measurer, SimMeasurer};
use crate::schedule::template::TemplateKind;
use crate::sim::devices;
use crate::tuner::db::{Database, RetentionPolicy};
use crate::tuner::scheduler::{AllocPolicy, SchedulerOptions, TaskScheduler};
use crate::tuner::serve::{fill_synthetic, query_storm, ServeConfig, StormOptions};
use crate::tuner::{DbSink, TuneOptions};
use crate::workloads;
use anyhow::{bail, Context, Result};
use experiments::{ExpOpts, Method};
use std::sync::Arc;
use std::time::Duration;

/// Minimal flag parser: `--key value` and `--flag` pairs after the
/// subcommand (clap is not vendored in the offline build).
pub struct Args {
    /// Non-flag arguments, in order.
    pub positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    /// Parse an argv tail into flags and positionals.
    pub fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value =
                    argv.get(i + 1).map_or(false, |n| !n.starts_with("--"));
                if next_is_value {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    /// Value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// `--key` parsed as usize, or `default`.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Whether `--key` was passed (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn device_of(args: &Args) -> Result<crate::sim::DeviceModel> {
    let name = args.get("device").unwrap_or("sim-gpu");
    devices::by_name(name)
        .with_context(|| format!("unknown device {name}; try sim-gpu/sim-cpu/sim-mali/sim-tpu"))
}

fn template_of(dev: &crate::sim::DeviceModel) -> TemplateKind {
    match dev.class {
        crate::sim::DeviceClass::Gpu => TemplateKind::Gpu,
        crate::sim::DeviceClass::Cpu => TemplateKind::Cpu,
    }
}

/// `--targets a,b` resolves a comma-separated device list for the
/// heterogeneous `tune-graph` path. Short class names resolve through
/// the `sim-` registry prefix (`cpu` → `sim-cpu`); full registry names
/// (`sim-mali`) pass through. `None` when the flag is absent (the
/// single-device path).
fn targets_of(args: &Args) -> Result<Option<Vec<crate::sim::DeviceModel>>> {
    let Some(spec) = args.get("targets") else { return Ok(None) };
    let mut devs: Vec<crate::sim::DeviceModel> = Vec::new();
    for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let dev = devices::by_name(tok)
            .or_else(|| devices::by_name(&format!("sim-{tok}")))
            .with_context(|| {
                format!("unknown target {tok}; try cpu/gpu/mali/tpu or sim-* names")
            })?;
        anyhow::ensure!(devs.iter().all(|d| d.name != dev.name), "duplicate target {tok}");
        devs.push(dev);
    }
    anyhow::ensure!(!devs.is_empty(), "--targets needs at least one device");
    Ok(Some(devs))
}

fn workload_of(args: &Args) -> Result<usize> {
    let w = args.get("workload").unwrap_or("C6");
    let n: usize = w.trim_start_matches(['C', 'c']).parse().context("workload like C6")?;
    anyhow::ensure!((1..=12).contains(&n), "workloads are C1..C12");
    Ok(n)
}

fn method_of(args: &Args) -> Result<Method> {
    Ok(match args.get("method").unwrap_or("gbt_rank") {
        "random" => Method::Random,
        "ga" => Method::Ga,
        "gbt_rank" => Method::GbtRank,
        "gbt_reg" => Method::GbtReg,
        "neural" | "neural_rank" => Method::NeuralRank,
        "neural_reg" => Method::NeuralReg,
        other => bail!("unknown method {other}"),
    })
}

fn alloc_of(args: &Args, default: AllocPolicy) -> Result<AllocPolicy> {
    match args.get("alloc") {
        None => Ok(default),
        Some(s) => AllocPolicy::parse(s)
            .with_context(|| format!("unknown --alloc {s}; try uniform/gradient")),
    }
}

/// `--overlap N` (how many task-slices the scheduler keeps in flight;
/// 1 = the barrier scheduler) and `--gain-ema A` (EMA smoothing factor
/// for gain estimates, with restart detection; absent = raw last-slice
/// gains).
fn overlap_of(args: &Args) -> Result<(usize, Option<f64>)> {
    let overlap = args.get_usize("overlap", 1).max(1);
    let gain_ema = match args.get("gain-ema") {
        None => None,
        Some(v) => {
            let a: f64 = v.parse().with_context(|| format!("--gain-ema {v} is not a number"))?;
            anyhow::ensure!(a > 0.0 && a <= 1.0, "--gain-ema must be in (0, 1], got {a}");
            Some(a)
        }
    };
    Ok((overlap, gain_ema))
}

/// Build the asynchronous device-farm [`MeasureService`] when any farm
/// flag is present (`--replicas N`, `--measure-timeout MS`,
/// `--farm-latency-ms MS`, `--flaky P`); `None` keeps the plain
/// single-board simulator path. One service instance is shared by every
/// tuning loop of the command — `tune-all` and `tune-graph` measure all
/// their tasks' slices on the same farm.
fn service_of(args: &Args, dev: &crate::sim::DeviceModel, seed: u64) -> Option<MeasureService> {
    let replicas = args.get_usize("replicas", 1);
    let timeout_ms = args.get("measure-timeout").and_then(|v| v.parse::<u64>().ok());
    let latency_ms = args.get_usize("farm-latency-ms", 0);
    let flaky: f64 = args.get("flaky").and_then(|v| v.parse().ok()).unwrap_or(0.0);
    if replicas <= 1 && timeout_ms.is_none() && latency_ms == 0 && flaky <= 0.0 {
        return None;
    }
    let farm = DeviceFarm::with_latency(
        dev.clone(),
        replicas.max(1),
        seed,
        Duration::from_millis(latency_ms as u64),
    )
    .with_flakiness(flaky);
    let opts =
        ServiceOptions { timeout: timeout_ms.map(Duration::from_millis), ..Default::default() };
    Some(MeasureService::new(Arc::new(farm), opts))
}

/// One measurement back-end per coordinator command: the shared
/// device-farm service when any farm flag is present, else a plain
/// single-board simulator. One place to build, select and report, so
/// the `tune`/`tune-all`/`tune-graph` arms cannot drift.
struct FarmOrBoard {
    service: Option<MeasureService>,
    direct: SimMeasurer,
}

impl FarmOrBoard {
    fn new(args: &Args, dev: &crate::sim::DeviceModel, seed: u64) -> Self {
        FarmOrBoard {
            service: service_of(args, dev, seed),
            direct: SimMeasurer::with_seed(dev.clone(), seed),
        }
    }

    /// The measurer tuning loops should drive.
    fn measurer(&self) -> &dyn Measurer {
        match &self.service {
            Some(s) => s,
            None => &self.direct,
        }
    }

    /// Service measurer, or `fallback` when no farm flag was given —
    /// the `tune-all` per-workload loop keeps its historical per-task
    /// seeding on the direct path.
    fn measurer_or<'x>(&'x self, fallback: &'x dyn Measurer) -> &'x dyn Measurer {
        match &self.service {
            Some(s) => s,
            None => fallback,
        }
    }

    /// Print the farm utilization report of a service-backed run.
    fn report(&self) {
        if let Some(s) = &self.service {
            println!("{}", s.report());
        }
    }
}

/// `--repr config|flat|context|full` overrides the feature
/// representation of the tuning loop; absent keeps the default.
fn repr_of(args: &Args) -> Result<Option<crate::features::Representation>> {
    use crate::features::Representation;
    Ok(match args.get("repr") {
        None => None,
        Some("config") => Some(Representation::Config),
        Some("flat") | Some("flat_ast") => Some(Representation::FlatAst),
        Some("context") | Some("context_relation") => Some(Representation::ContextRelation),
        Some("full") => Some(Representation::Full),
        Some(other) => bail!("unknown --repr {other}; try config/flat/context/full"),
    })
}

fn exp_opts(args: &Args) -> Result<ExpOpts> {
    let mut o = if args.has("full") { ExpOpts::paper_scale() } else { ExpOpts::default() };
    o.trials = args.get_usize("trials", o.trials);
    o.all_workloads = args.has("all-workloads");
    o.seed = args.get_usize("seed", 0) as u64;
    o.pipeline_depth = args.get_usize("depth", o.pipeline_depth);
    // Fast paths are bit-exact, so on by default; --no-fast-paths is
    // the scalar reference for perf A/B runs.
    o.fast_paths = !args.has("no-fast-paths");
    o.repr = repr_of(args)?;
    // --search sa|evo selects the model-guided exploration strategy
    // (parallel simulated annealing vs the evolutionary refiner).
    if let Some(v) = args.get("search") {
        o.search = crate::explore::SearchKind::parse(v)
            .ok_or_else(|| anyhow::anyhow!("unknown --search {v}; try sa/evo"))?;
    }
    // --threads N pins every parallel helper's width for this process
    // (benches and CI smokes want run-to-run comparable wall-clock).
    if let Some(v) = args.get("threads") {
        let n: usize = v.parse().with_context(|| format!("--threads {v} is not a count"))?;
        anyhow::ensure!(n >= 1, "--threads must be >= 1");
        o.threads = Some(n);
        std::env::set_var("PALLAS_THREADS", n.to_string());
    }
    Ok(o)
}

/// `--auto-compact-bytes N` arms threshold-triggered WAL folding on a
/// live DB: the appender whose write pushes the WAL tail past N bytes
/// folds everything into a fresh snapshot under the keep-all policy
/// (nothing is evicted, so served configs and fixed-seed tuning results
/// are unchanged). No-op for in-memory DBs.
fn arm_auto_compact(args: &Args, db: &Database) -> Result<()> {
    if let Some(v) = args.get("auto-compact-bytes") {
        let bytes: u64 = v
            .parse()
            .with_context(|| format!("--auto-compact-bytes {v} is not a byte count"))?;
        db.set_auto_compact_bytes(bytes);
        println!("auto-compaction armed at {bytes} WAL bytes");
    }
    Ok(())
}

/// CLI entry point (called by `main`).
pub fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first().cloned() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "table1" => {
            println!("| workload | H,W | IC,OC | K,S | MACs |");
            for i in 1..=12 {
                let p = workloads::conv_workload(i);
                println!(
                    "| C{i} | {},{} | {},{} | {},{} | {:.2}M |",
                    p.h, p.w, p.ic, p.oc, p.kh, p.stride,
                    p.macs() as f64 / 1e6
                );
            }
        }
        "tune" => {
            let dev = device_of(&args)?;
            let wl = workload_of(&args)?;
            let method = method_of(&args)?;
            let mut opts = exp_opts(&args)?;
            // --sketch swaps the hand template's space for the generated
            // sketch space (multi-level tiling / cache-stage / fusion
            // derivations); the template point stays reachable inside it.
            let task = if args.has("sketch") {
                let base = workloads::conv_task(wl, template_of(&dev));
                crate::schedule::template::Task::with_sketches(base.def, base.template)
            } else {
                workloads::conv_task(wl, template_of(&dev))
            };
            // --db FILE opens (or creates) the WAL-backed service DB;
            // every measured trial is streamed in live by the trial
            // accountant, so a crash loses at most one record.
            let db = args.get("db").map(Database::open).transpose()?;
            if let Some(db) = &db {
                arm_auto_compact(&args, db)?;
                opts.sink = Some(DbSink::new(db, &task, dev.name));
            }
            // --replicas N measures through the asynchronous device-farm
            // service (per-replica workers, deterministic job ordering;
            // --measure-timeout / --farm-latency-ms / --flaky set the
            // board-fault policy and the emulated fleet). --pipeline runs
            // the asynchronous explore ∥ measure ∥ retrain loop (GBT
            // methods; others fall back to serial).
            let farm = FarmOrBoard::new(&args, &dev, opts.seed + 1);
            let measurer = farm.measurer();
            println!(
                "tuning C{wl} on {} with {}{}{} ({} trials, |S_e| = {:.2e})",
                measurer.target(),
                method.name(),
                if opts.search == crate::explore::SearchKind::Evo { " [evo]" } else { "" },
                if args.has("pipeline") { " [pipelined]" } else { "" },
                opts.trials,
                task.space.size() as f64
            );
            // Warm start is the default service path whenever the DB
            // already holds records (necessarily of other tasks — this
            // run's own records only start streaming in below).
            let warm = match &db {
                Some(d) => {
                    !args.has("no-warm-start") && (args.has("warm-start") || !d.is_empty())
                }
                None => false,
            };
            let pipelined = args.has("pipeline");
            let mut res = None;
            if warm {
                res = experiments::run_method_warm(
                    &task,
                    measurer,
                    method,
                    &opts,
                    db.as_ref().expect("warm implies db"),
                    dev.name,
                    pipelined,
                );
                if res.is_none() {
                    println!(
                        "warm-start unavailable (no usable source records or method \
                         without a transfer path); cold start"
                    );
                }
            }
            let res = match res {
                Some(r) => r,
                None if pipelined => {
                    experiments::run_method_pipelined(&task, measurer, method, &opts)
                        .unwrap_or_else(|| {
                            experiments::run_method(&task, measurer, method, &opts)
                        })
                }
                None => experiments::run_method(&task, measurer, method, &opts),
            };
            if let Some((e, g)) = &res.best {
                println!("best: {g:.1} GFLOPS");
                println!("config: {}", task.space.describe(e));
            }
            if let (Some(path), Some(db)) = (args.get("db"), &db) {
                println!(
                    "streamed {} records into {path} ({} total)",
                    res.records.len(),
                    db.len()
                );
            }
            farm.report();
        }
        "tune-all" => {
            let dev = device_of(&args)?;
            let mut opts = exp_opts(&args)?;
            opts.verbose = true;
            let base_seed = opts.seed;
            let path = args.get("db").unwrap_or("tuning_db.jsonl").to_string();
            let db = Database::open(&path)?;
            arm_auto_compact(&args, &db)?;
            let pipelined = args.has("pipeline");
            // One shared measurement service (if any farm flag is set)
            // spans every task's loop — the whole C1–C12 run measures on
            // the same fleet.
            let farm = FarmOrBoard::new(&args, &dev, base_seed + 1);
            // Cross-workload service flow: C2 warm-starts from C1's
            // streamed records, C3 from C1–C2, … (§4 reuse of D).
            let warm_enabled = !args.has("no-warm-start");
            // --alloc gradient hands the whole C1–C12 budget to the
            // task scheduler instead of fixed per-task shares.
            if alloc_of(&args, AllocPolicy::Uniform)? == AllocPolicy::Gradient {
                let template = template_of(&dev);
                let tasks: Vec<crate::schedule::template::Task> =
                    (1..=12).map(|wl| workloads::conv_task(wl, template)).collect();
                let budget = args.get_usize("budget", tasks.len() * opts.trials);
                let (overlap, gain_ema) = overlap_of(&args)?;
                let sched = TaskScheduler::for_tasks(
                    tasks,
                    SchedulerOptions {
                        budget,
                        slice: args.get_usize("slice", opts.batch),
                        policy: AllocPolicy::Gradient,
                        overlap,
                        gain_ema,
                        verbose: true,
                        ..Default::default()
                    },
                );
                let measurer = farm.measurer();
                println!(
                    "tune-all via gradient scheduler ({budget} trials total, overlap {overlap})"
                );
                let alloc = sched.run_tuning(
                    measurer,
                    &db,
                    opts.tune_options(),
                    pipelined,
                    warm_enabled,
                );
                for (i, plan) in sched.plans().iter().enumerate() {
                    println!(
                        "C{}: {} trials, best {:.3} ms  ({})",
                        i + 1,
                        alloc.trials[i],
                        alloc.secs[i] * 1e3,
                        plan.task.key()
                    );
                }
                println!("tuning DB: {path} ({} records)", db.len());
                farm.report();
                return Ok(());
            }
            for wl in 1..=12 {
                let task = workloads::conv_task(wl, template_of(&dev));
                let direct = SimMeasurer::with_seed(dev.clone(), base_seed + wl as u64);
                let measurer = farm.measurer_or(&direct);
                opts.seed = base_seed + wl as u64;
                opts.sink = Some(DbSink::new(&db, &task, dev.name));
                let warm_res = if warm_enabled && !db.is_empty() {
                    experiments::run_method_warm(
                        &task,
                        measurer,
                        Method::GbtRank,
                        &opts,
                        &db,
                        dev.name,
                        pipelined,
                    )
                } else {
                    None
                };
                let res = warm_res.unwrap_or_else(|| {
                    let o = opts.tune_options();
                    if pipelined {
                        crate::tuner::tune_gbt_pipelined(task.clone(), measurer, o)
                    } else {
                        crate::tuner::tune_gbt(task.clone(), measurer, o)
                    }
                });
                println!("C{wl}: best {:.1} GFLOPS", res.best_gflops());
            }
            println!("tuning DB: {path} ({} records)", db.len());
            farm.report();
        }
        "tune-graph" => {
            let dev = device_of(&args)?;
            let template = template_of(&dev);
            let name = args
                .positional
                .first()
                .map(String::as_str)
                .or_else(|| args.get("network"))
                .unwrap_or("resnet18")
                .to_string();
            let graph = workloads::network(&name).with_context(|| {
                format!("unknown network {name}; try resnet18/mobilenet/dqn/lstm/dcgan")
            })?;
            let opts = exp_opts(&args)?;
            let policy = alloc_of(&args, AllocPolicy::Gradient)?;
            let (overlap, gain_ema) = overlap_of(&args)?;
            // AutoTVM compiles the fused graph (§6.3)
            let fused = graph.fuse();
            let sched_opts = SchedulerOptions {
                budget: 0, // set below once the task count is known
                slice: args.get_usize("slice", opts.batch),
                policy,
                overlap,
                gain_ema,
                verbose: args.has("verbose"),
                ..Default::default()
            };
            // --targets cpu,gpu: the heterogeneous-fleet path — one
            // plan per (task, target) under one global budget, measured
            // on a class-aware HeteroFarm service where a job for
            // target T only lands on boards serving T.
            if let Some(devs) = targets_of(&args)? {
                let sched = TaskScheduler::from_graph_multi(&fused, &devs, sched_opts)?;
                let budget =
                    args.get_usize("budget", sched.plans().len().max(1) * opts.trials);
                let sched = sched.with_budget(budget);
                let db = match args.get("db") {
                    Some(p) => Database::open(p)?,
                    None => Database::new(),
                };
                arm_auto_compact(&args, &db)?;
                let replicas = args.get_usize("replicas", 1).max(1);
                let latency =
                    Duration::from_millis(args.get_usize("farm-latency-ms", 0) as u64);
                let flaky: f64 = args.get("flaky").and_then(|v| v.parse().ok()).unwrap_or(0.0);
                let classes: Vec<BoardClass> = devs
                    .iter()
                    .map(|d| {
                        BoardClass::new(d.clone(), replicas)
                            .with_latency(latency)
                            .with_flakiness(flaky)
                    })
                    .collect();
                let svc_opts = ServiceOptions {
                    timeout: args
                        .get("measure-timeout")
                        .and_then(|v| v.parse::<u64>().ok())
                        .map(Duration::from_millis),
                    ..Default::default()
                };
                let svc = MeasureService::new(
                    Arc::new(HeteroFarm::new(classes, opts.seed + 1)),
                    svc_opts,
                );
                let views: Vec<(String, TargetedMeasurer<'_>)> = devs
                    .iter()
                    .map(|d| (d.name.to_string(), svc.for_target(d.name)))
                    .collect();
                let measurers: Vec<(String, &dyn Measurer)> =
                    views.iter().map(|(n, v)| (n.clone(), v as &dyn Measurer)).collect();
                let fleet: Vec<&str> = devs.iter().map(|d| d.name).collect();
                println!(
                    "tuning {name} end-to-end across [{}] — {} tasks, {budget} trials \
                     total, {} allocation, overlap {overlap}, {replicas} board(s)/target",
                    fleet.join(", "),
                    sched.plans().len(),
                    policy.name()
                );
                let alloc = sched.run_tuning_multi(
                    &measurers,
                    &db,
                    opts.tune_options(),
                    args.has("pipeline"),
                    !args.has("no-warm-start"),
                );
                println!(
                    "task                                    target    weight  trials  best ms"
                );
                for (i, plan) in sched.plans().iter().enumerate() {
                    println!(
                        "{:<40} {:<9} {:>5}  {:>6}  {:>8.4}",
                        plan.task.key(),
                        plan.target.as_deref().unwrap_or("-"),
                        plan.weight,
                        alloc.trials[i],
                        alloc.secs[i] * 1e3
                    );
                }
                for d in &devs {
                    let total: usize = sched
                        .plans()
                        .iter()
                        .zip(&alloc.trials)
                        .filter(|(p, _)| p.target.as_deref() == Some(d.name))
                        .map(|(_, &t)| t)
                        .sum();
                    println!("target {}: {} trials", d.name, total);
                }
                // per-target end-to-end: vendor baseline on the unfused
                // graph vs tuned configs served from the shared DB
                for d in &devs {
                    let template = TemplateKind::for_class(d.class);
                    let (base_s, _) = graph
                        .latency(d, template, |t| Some(crate::baselines::vendor_config(t)))?;
                    let (auto_s, _) = fused.latency(d, template, |t| {
                        db.best_config(&t.key(), d.name).map(|(e, _)| e)
                    })?;
                    println!(
                        "end-to-end on {}: vendor {:.3} ms, autotvm {:.3} ms ({:.2}x)",
                        d.name,
                        base_s * 1e3,
                        auto_s * 1e3,
                        base_s / auto_s
                    );
                }
                println!(
                    "scheduler estimate {:.3} ms across the fleet (fixed glue {:.3} ms)",
                    alloc.est_latency * 1e3,
                    sched.fixed_secs() * 1e3
                );
                if let Some(path) = args.get("db") {
                    println!("tuning DB: {path} ({} records)", db.len());
                }
                println!("{}", svc.report());
                return Ok(());
            }
            let sched = TaskScheduler::from_graph(&fused, &dev, template, sched_opts)?;
            let budget =
                args.get_usize("budget", sched.plans().len().max(1) * opts.trials);
            let sched = sched.with_budget(budget);
            let db = match args.get("db") {
                Some(p) => Database::open(p)?,
                None => Database::new(),
            };
            arm_auto_compact(&args, &db)?;
            // Every task's slices measure on one shared service when a
            // farm flag is set (the scheduler's loops all feed the same
            // fleet); otherwise the plain single-board simulator.
            let farm = FarmOrBoard::new(&args, &dev, opts.seed + 1);
            let measurer = farm.measurer();
            println!(
                "tuning {name} end-to-end on {} — {} tasks, {budget} trials total, \
                 {} allocation, overlap {overlap}",
                dev.name,
                sched.plans().len(),
                policy.name()
            );
            let alloc = sched.run_tuning(
                measurer,
                &db,
                opts.tune_options(),
                args.has("pipeline"),
                !args.has("no-warm-start"),
            );
            println!("task                                    weight  trials  best ms");
            for (i, plan) in sched.plans().iter().enumerate() {
                println!(
                    "{:<40} {:>5}  {:>6}  {:>8.4}",
                    plan.task.key(),
                    plan.weight,
                    alloc.trials[i],
                    alloc.secs[i] * 1e3
                );
            }
            // end-to-end: vendor baseline on the unfused graph vs tuned
            // configs (served from the DB) on the fused graph
            let (base_s, _) = graph
                .latency(&dev, template, |t| Some(crate::baselines::vendor_config(t)))?;
            let (auto_s, _) = fused.latency(&dev, template, |t| {
                db.best_config(&t.key(), dev.name).map(|(e, _)| e)
            })?;
            println!(
                "end-to-end: vendor {:.3} ms, autotvm {:.3} ms ({:.2}x), \
                 scheduler estimate {:.3} ms (fixed glue {:.3} ms)",
                base_s * 1e3,
                auto_s * 1e3,
                base_s / auto_s,
                alloc.est_latency * 1e3,
                sched.fixed_secs() * 1e3
            );
            if let Some(path) = args.get("db") {
                println!("tuning DB: {path} ({} records)", db.len());
            }
            farm.report();
        }
        "e2e" => {
            let dev = device_of(&args)?;
            let opts = exp_opts(&args)?;
            let net = args.get("network").unwrap_or("resnet18").to_string();
            experiments::fig11(&opts, &dev, &[net.as_str()]);
        }
        "fig" => {
            let n = args
                .positional
                .first()
                .and_then(|s| s.parse::<u32>().ok())
                .context("usage: autotvm fig <4..11> [--full] [--all-workloads]")?;
            let opts = exp_opts(&args)?;
            let neural = args.has("neural");
            match n {
                4 => {
                    experiments::fig4(&opts, neural);
                }
                5 => {
                    experiments::fig5(&opts, neural);
                }
                6 => {
                    experiments::fig6(&opts);
                }
                7 => {
                    experiments::fig7(&opts);
                }
                8 => {
                    experiments::fig8(&opts);
                }
                9 => {
                    experiments::fig9(&opts);
                }
                10 => {
                    let dev = device_of(&args)?;
                    experiments::fig10(&opts, &dev);
                }
                11 => {
                    let dev = device_of(&args)?;
                    let nets: Vec<&str> = match dev.class {
                        crate::sim::DeviceClass::Gpu if dev.name == "sim-gpu" => {
                            vec!["resnet18", "mobilenet", "lstm", "dqn", "dcgan"]
                        }
                        // the paper's baselines don't support LSTM/DCGAN
                        // on A53/Mali (Fig. 11 footnote)
                        _ => vec!["resnet18", "mobilenet", "dqn"],
                    };
                    experiments::fig11(&opts, &dev, &nets);
                }
                other => bail!("no figure {other}; supported: 4..11"),
            }
        }
        "serve" => {
            let path = args.get("db").context("serve requires --db FILE")?;
            let t0 = std::time::Instant::now();
            let db = Database::open(path)?;
            let open_ms = t0.elapsed().as_secs_f64() * 1e3;
            arm_auto_compact(&args, &db)?;
            let synthetic = args.get_usize("synthetic", 0);
            if synthetic > 0 {
                fill_synthetic(&db, synthetic, (synthetic / 1000).max(16), 2, 0);
                println!("filled {synthetic} synthetic records");
            }
            println!(
                "opened {path}: {} records (snapshot gen {}, WAL tail {} bytes) in {:.1} ms",
                db.len(),
                db.snapshot_gen().unwrap_or(0),
                db.wal_bytes().unwrap_or(0),
                open_ms
            );
            if args.has("compact") || args.has("retain-per-task") {
                let policy = match args.get("retain-per-task") {
                    Some(v) => RetentionPolicy::newest(
                        v.parse().context("--retain-per-task expects a count")?,
                    ),
                    None => RetentionPolicy::keep_all(),
                };
                let c = db.compact(&policy)?;
                println!(
                    "compacted to gen {}: kept {} records, dropped {}, snapshot {} bytes",
                    c.gen, c.kept, c.dropped, c.snapshot_bytes
                );
            }
            let opts = StormOptions {
                threads: args.get_usize("threads", 64),
                writers: args.get_usize("writers", 0),
                duration: Duration::from_millis(
                    args.get_usize("duration-ms", 2000) as u64
                ),
                seed: args.get_usize("seed", 0) as u64,
            };
            let serve = ServeConfig::new(db);
            let report = query_storm(&serve, &opts);
            println!("{report}");
            if let Some(out) = args.get("bench-json") {
                std::fs::write(out, report.to_json().dump())
                    .with_context(|| format!("writing {out}"))?;
                println!("wrote {out}");
            }
        }
        "pjrt-demo" => {
            use crate::measure::pjrt::{matmul_variant_task, PjrtMeasurer};
            let rt = crate::runtime::PjrtRuntime::cpu()?;
            let measurer = PjrtMeasurer::new(rt)?;
            let task = matmul_variant_task();
            println!(
                "tuning Pallas matmul tile family on real {} (|S_e| = {})",
                measurer.target(),
                task.space.size()
            );
            let opts = TuneOptions {
                n_trials: args.get_usize("trials", 18),
                batch: 6,
                sa: crate::explore::SaParams {
                    n_chains: 8,
                    n_steps: 30,
                    ..Default::default()
                },
                ..Default::default()
            };
            let res = crate::tuner::tune_gbt(task.clone(), &measurer, opts);
            for r in &res.records {
                let (bm, bn, bk) =
                    crate::measure::pjrt::variant_tiles(&task, &r.entity);
                println!(
                    "  bm={bm:<4} bn={bn:<4} bk={bk:<4} {:>8.2} GFLOPS",
                    r.gflops
                );
            }
            if let Some((e, g)) = &res.best {
                let (bm, bn, bk) = crate::measure::pjrt::variant_tiles(&task, e);
                println!("best tile: ({bm}, {bn}, {bk}) at {g:.2} GFLOPS (real wall-clock)");
            }
        }
        other => {
            print_usage();
            bail!("unknown command {other}");
        }
    }
    Ok(())
}

fn print_usage() {
    println!(
        "autotvm — learning to optimize tensor programs (NeurIPS'18 reproduction)

USAGE:
  autotvm table1
  autotvm tune      --workload C6 --device sim-gpu --method gbt_rank \\
                    [--trials N] [--db file.jsonl] [--full] \\
                    [--pipeline] [--depth D] [--replicas R] \\
                    [--measure-timeout MS] [--farm-latency-ms MS] [--flaky P] \\
                    [--warm-start] [--no-warm-start] [--no-fast-paths] \\
                    [--repr config|flat|context|full] [--threads N] \\
                    [--search sa|evo] [--sketch] \\
                    [--auto-compact-bytes N]
  autotvm tune-all  --device sim-gpu [--trials N] [--db file.jsonl] \\
                    [--pipeline] [--no-warm-start] [--alloc uniform|gradient] \\
                    [--overlap N] [--gain-ema A] [--no-fast-paths] \\
                    [--auto-compact-bytes N] \\
                    [--replicas R] [--measure-timeout MS] \\
                    [--farm-latency-ms MS] [--flaky P]
  autotvm tune-graph <resnet18|mobilenet|dqn|lstm|dcgan> --device sim-gpu \\
                    [--targets cpu,gpu] \\
                    [--budget N] [--slice S] [--alloc uniform|gradient] \\
                    [--overlap N] [--gain-ema A] [--no-fast-paths] \\
                    [--db file.jsonl] [--pipeline] [--no-warm-start] [--verbose] \\
                    [--auto-compact-bytes N] \\
                    [--replicas R] [--measure-timeout MS] \\
                    [--farm-latency-ms MS] [--flaky P]
  autotvm e2e       --network resnet18 --device sim-gpu [--trials N]
  autotvm fig <4|5|6|7|8|9|10|11> [--full] [--all-workloads] [--neural] [--device D]
  autotvm serve     --db file.jsonl [--threads N] [--writers W] \\
                    [--duration-ms MS] [--seed S] [--synthetic M] \\
                    [--compact] [--retain-per-task N] [--bench-json FILE] \\
                    [--auto-compact-bytes N]
  autotvm pjrt-demo [--trials N]

devices: sim-gpu (TITAN-X-class), sim-cpu (A53-class), sim-mali, sim-tpu
methods: random, ga, gbt_rank, gbt_reg, neural, neural_reg

--db opens a WAL-backed tuning DB: trials stream in live, and new tasks
warm-start a transfer model from other tasks' records by default.
--auto-compact-bytes N folds the WAL into a fresh snapshot whenever an
append pushes the tail past N bytes (keep-all: nothing is evicted, and
fixed-seed results are bit-identical with or without it).

--no-fast-paths disables the bit-exact hot paths (compiled GBT predict
plan, incremental Config featurization, structure-cached delta
featurization for the program-derived representations) and runs the
scalar reference — same results, more wall-clock; the perf A/B toggle
of bench_e2e_tune. --repr picks the feature representation (default
full); --threads N pins the worker width of every parallel helper
(exported as PALLAS_THREADS, which also works directly as an env
override).

--search picks the exploration strategy over the cost model: sa
(default) is persistent parallel simulated annealing, evo is the
evolutionary refiner (elite survival, knob-wise crossover, mutation —
ranked by the model, not by measurements, unlike the ga method).
--sketch replaces the hand template's space with the generated sketch
space: derivation rules enumerate multi-level tiling depths,
cache-stage insertion and accumulator decisions, and knobs fill the
free extents; the hand template remains one point of the space.

--replicas R measures through the asynchronous device-farm service: R
per-replica workers, sequence-ordered jobs (fixed-seed runs stay
bit-for-bit reproducible), bounded in-flight backpressure, and a
timeout/retry/quarantine board-fault policy (--measure-timeout MS).
--farm-latency-ms emulates per-board RPC round-trips, --flaky P injects
board failures; the run ends with a farm utilization report.

tune-graph spreads one global trial budget across a network's tasks:
--alloc gradient (default) allocates each round-slice to the task with
the highest predicted end-to-end latency reduction; --alloc uniform is
the equal-shares baseline.

--targets cpu,gpu deploys the network to several devices at once: the
scheduler spends one global budget across the tasks × targets
cross-product, measurement runs on a heterogeneous farm (one board
class per target, --replicas boards each, class-aware dispatch so a
trial for target T only lands on boards serving T), and each target's
searches warm-start from the records of the others (cross-target
transfer at reduced weight). Accepts cpu/gpu/mali/tpu or full sim-*
device names.

--overlap N keeps up to N task-slices in flight at once: task B
proposes and refits while task A's batches drain on the farm, with
allocation decisions still deterministic via versioned gain snapshots
(overlap 1 is the barrier scheduler, bit-for-bit). --gain-ema A smooths
gain-per-trial estimates with an EMA plus restart detection — useful
when overlap makes raw last-slice differences noisy.

serve opens a tuned DB as the config-serving tier and storms it with
--threads concurrent readers (plus --writers live appenders) for
--duration-ms, reporting QPS and p50/p99 lookup latency. --compact
folds the WAL into a snapshot first; --retain-per-task N additionally
evicts all but each task's best top-k and newest N records, bounding
memory and startup time. --synthetic M fills M generated records before
the storm (benchmarking without a tuned DB)."
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parse_flags_and_positionals() {
        let argv: Vec<String> =
            ["9", "--full", "--trials", "128", "--device", "sim-cpu"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let a = Args::parse(&argv);
        assert_eq!(a.positional, vec!["9"]);
        assert!(a.has("full"));
        assert_eq!(a.get_usize("trials", 0), 128);
        assert_eq!(a.get("device"), Some("sim-cpu"));
    }

    #[test]
    fn workload_parsing() {
        let a = Args::parse(&["--workload".into(), "C12".into()]);
        assert_eq!(workload_of(&a).unwrap(), 12);
        let bad = Args::parse(&["--workload".into(), "C13".into()]);
        assert!(workload_of(&bad).is_err());
    }

    #[test]
    fn cli_table1_runs() {
        run(&["table1".to_string()]).unwrap();
    }
}
