//! PJRT runtime: load AOT-compiled HLO artifacts (produced once by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//!
//! Python never runs at tuning time: the JAX/Pallas cost model is
//! lowered to HLO **text** at build time (`make artifacts`; text rather
//! than serialized proto — see /opt/xla-example/README.md) and this
//! module compiles + runs it through the `xla` crate's PJRT CPU client.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Shared PJRT client wrapper.
#[derive(Clone)]
pub struct PjrtRuntime {
    client: Arc<xla::PjRtClient>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client: Arc::new(client) })
    }

    /// Backend platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }

    /// Compile HLO text directly (used by the PJRT measurer to compile
    /// kernel variants generated at tuning time).
    pub fn compile_text(&self, name: &str, text: &str) -> Result<Executable> {
        // the crate only exposes file-based text parsing; go through a
        // temp file
        let dir = std::env::temp_dir().join("autotvm-hlo");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!(
            "{name}-{}-{}.hlo.txt",
            std::process::id(),
            text.len()
        ));
        std::fs::write(&path, text)?;
        let out = self.load(&path);
        let _ = std::fs::remove_file(&path);
        out
    }
}

/// A compiled executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Source artifact name (for error messages).
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple
    /// (jax lowering uses `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let parts = lit.to_tuple().context("decomposing output tuple")?;
        Ok(parts)
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    let n: i64 = shape.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {shape:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(shape)?)
}

/// Extract f32 data from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Standard location of the artifacts directory (overridable for
/// tests / deployment via `AUTOTVM_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("AUTOTVM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Errors early with a friendly message when `make artifacts` has not
/// been run.
pub fn require_artifact(name: &str) -> Result<PathBuf> {
    let p = artifacts_dir().join(name);
    anyhow::ensure!(
        p.exists(),
        "artifact {} missing — run `make artifacts` first",
        p.display()
    );
    Ok(p)
}
