//! Operator constructors — the concrete index expressions used by the
//! paper's evaluation: matmul (Fig. 1), conv2d (Table 1, C1–C12),
//! depthwise conv2d (MobileNet), dense (DQN/LSTM), pooling and
//! elementwise ops (graph glue).
//!
//! Naming convention: the `name` encodes the shape parameters so that
//! `ComputeDef::task_key` deduplicates identical workloads during task
//! extraction.

use super::{Access, BodyExpr, Combiner, ComputeDef, Epilogue, IterKind, IterVar, PredExpr, TensorSpec};
use crate::expr::{IndexExpr, VarPool};

fn itv(pool: &mut VarPool, name: &str, extent: i64, kind: IterKind) -> IterVar {
    let var = pool.fresh(name);
    IterVar { var, name: name.to_string(), extent, kind }
}

/// `C[y, x] = Σ_k A[k, y] * B[k, x]` — the paper's Fig. 1 example
/// (note the transposed-A layout used in the paper).
pub fn matmul(n: i64, m: i64, k: i64) -> ComputeDef {
    let mut pool = VarPool::new();
    let y = itv(&mut pool, "y", n, IterKind::Spatial);
    let x = itv(&mut pool, "x", m, IterKind::Spatial);
    let kk = itv(&mut pool, "k", k, IterKind::Reduce);
    let body = BodyExpr::Mul(
        Box::new(BodyExpr::load("A", vec![IndexExpr::var(kk.var), IndexExpr::var(y.var)])),
        Box::new(BodyExpr::load("B", vec![IndexExpr::var(kk.var), IndexExpr::var(x.var)])),
    );
    ComputeDef {
        name: format!("matmul_n{n}_m{m}_k{k}"),
        output: TensorSpec::new("C", &[n, m]),
        inputs: vec![TensorSpec::new("A", &[k, n]), TensorSpec::new("B", &[k, m])],
        axes: vec![y, x],
        reduce_axes: vec![kk],
        body,
        combiner: Combiner::Sum,
        epilogue: None,
        vars: pool,
    }
}

/// Parameters of a 2-D convolution workload (NCHW, OIHW kernel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dParams {
    /// Batch size.
    pub n: i64,
    /// Input height.
    pub h: i64,
    /// Input width.
    pub w: i64,
    /// Input channels.
    pub ic: i64,
    /// Output channels.
    pub oc: i64,
    /// Kernel height.
    pub kh: i64,
    /// Kernel width.
    pub kw: i64,
    /// Stride (both dims).
    pub stride: i64,
    /// Zero padding (both dims).
    pub pad: i64,
}

impl Conv2dParams {
    /// Output height.
    pub fn out_h(&self) -> i64 {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    /// Output width.
    pub fn out_w(&self) -> i64 {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }
    /// Multiply–add count ×2, the standard conv GFLOP accounting.
    pub fn macs(&self) -> u64 {
        (self.n * self.oc * self.out_h() * self.out_w() * self.ic * self.kh * self.kw) as u64
    }
}

/// `O[n,oc,oy,ox] = Σ_{ic,ky,kx} I[n,ic,oy*s+ky-p,ox*s+kx-p] * W[oc,ic,ky,kx]`
///
/// Padding is modeled with a [`PredExpr`] select (zero outside bounds),
/// like TVM's `pad` stage folded into the consumer.
pub fn conv2d(p: Conv2dParams) -> ComputeDef {
    let mut pool = VarPool::new();
    let oh = p.out_h();
    let ow = p.out_w();
    let n = itv(&mut pool, "n", p.n, IterKind::Spatial);
    let oc = itv(&mut pool, "oc", p.oc, IterKind::Spatial);
    let oy = itv(&mut pool, "oy", oh, IterKind::Spatial);
    let ox = itv(&mut pool, "ox", ow, IterKind::Spatial);
    let ic = itv(&mut pool, "ic", p.ic, IterKind::Reduce);
    let ky = itv(&mut pool, "ky", p.kh, IterKind::Reduce);
    let kx = itv(&mut pool, "kx", p.kw, IterKind::Reduce);

    let iy = IndexExpr::scaled_var(oy.var, p.stride)
        .add(&IndexExpr::var(ky.var))
        .offset(-p.pad);
    let ix = IndexExpr::scaled_var(ox.var, p.stride)
        .add(&IndexExpr::var(kx.var))
        .offset(-p.pad);

    let data = BodyExpr::Load(Access {
        tensor: "I".into(),
        indices: vec![IndexExpr::var(n.var), IndexExpr::var(ic.var), iy.clone(), ix.clone()],
    });
    let data = if p.pad > 0 {
        BodyExpr::Select(
            PredExpr { bounds: vec![(iy, 0, p.h), (ix, 0, p.w)] },
            Box::new(data),
            Box::new(BodyExpr::Imm(0.0)),
        )
    } else {
        data
    };
    let weight = BodyExpr::Load(Access {
        tensor: "W".into(),
        indices: vec![
            IndexExpr::var(oc.var),
            IndexExpr::var(ic.var),
            IndexExpr::var(ky.var),
            IndexExpr::var(kx.var),
        ],
    });
    let body = BodyExpr::Mul(Box::new(data), Box::new(weight));

    ComputeDef {
        name: format!(
            "conv2d_n{}_h{}_w{}_ic{}_oc{}_k{}_s{}_p{}",
            p.n, p.h, p.w, p.ic, p.oc, p.kh, p.stride, p.pad
        ),
        output: TensorSpec::new("O", &[p.n, p.oc, oh, ow]),
        inputs: vec![
            TensorSpec::new("I", &[p.n, p.ic, p.h, p.w]),
            TensorSpec::new("W", &[p.oc, p.ic, p.kh, p.kw]),
        ],
        axes: vec![n, oc, oy, ox],
        reduce_axes: vec![ic, ky, kx],
        body,
        combiner: Combiner::Sum,
        epilogue: None,
        vars: pool,
    }
}

/// Depthwise conv2d (MobileNet): one filter per channel, no `ic` sum.
pub fn depthwise_conv2d(p: Conv2dParams) -> ComputeDef {
    assert_eq!(p.ic, p.oc, "depthwise conv has channel multiplier 1 here");
    let mut pool = VarPool::new();
    let oh = p.out_h();
    let ow = p.out_w();
    let n = itv(&mut pool, "n", p.n, IterKind::Spatial);
    let c = itv(&mut pool, "c", p.oc, IterKind::Spatial);
    let oy = itv(&mut pool, "oy", oh, IterKind::Spatial);
    let ox = itv(&mut pool, "ox", ow, IterKind::Spatial);
    let ky = itv(&mut pool, "ky", p.kh, IterKind::Reduce);
    let kx = itv(&mut pool, "kx", p.kw, IterKind::Reduce);

    let iy = IndexExpr::scaled_var(oy.var, p.stride)
        .add(&IndexExpr::var(ky.var))
        .offset(-p.pad);
    let ix = IndexExpr::scaled_var(ox.var, p.stride)
        .add(&IndexExpr::var(kx.var))
        .offset(-p.pad);
    let data = BodyExpr::Load(Access {
        tensor: "I".into(),
        indices: vec![IndexExpr::var(n.var), IndexExpr::var(c.var), iy.clone(), ix.clone()],
    });
    let data = if p.pad > 0 {
        BodyExpr::Select(
            PredExpr { bounds: vec![(iy, 0, p.h), (ix, 0, p.w)] },
            Box::new(data),
            Box::new(BodyExpr::Imm(0.0)),
        )
    } else {
        data
    };
    let weight = BodyExpr::Load(Access {
        tensor: "W".into(),
        indices: vec![IndexExpr::var(c.var), IndexExpr::var(ky.var), IndexExpr::var(kx.var)],
    });
    ComputeDef {
        name: format!(
            "dwconv2d_n{}_h{}_w{}_c{}_k{}_s{}_p{}",
            p.n, p.h, p.w, p.oc, p.kh, p.stride, p.pad
        ),
        output: TensorSpec::new("O", &[p.n, p.oc, oh, ow]),
        inputs: vec![
            TensorSpec::new("I", &[p.n, p.ic, p.h, p.w]),
            TensorSpec::new("W", &[p.oc, p.kh, p.kw]),
        ],
        axes: vec![n, c, oy, ox],
        reduce_axes: vec![ky, kx],
        body: BodyExpr::Mul(Box::new(data), Box::new(weight)),
        combiner: Combiner::Sum,
        epilogue: None,
        vars: pool,
    }
}

/// Dense / fully connected: `O[b, j] = Σ_k X[b, k] * W[j, k]`.
pub fn dense(batch: i64, out_dim: i64, in_dim: i64) -> ComputeDef {
    let mut pool = VarPool::new();
    let b = itv(&mut pool, "b", batch, IterKind::Spatial);
    let j = itv(&mut pool, "j", out_dim, IterKind::Spatial);
    let k = itv(&mut pool, "k", in_dim, IterKind::Reduce);
    let body = BodyExpr::Mul(
        Box::new(BodyExpr::load("X", vec![IndexExpr::var(b.var), IndexExpr::var(k.var)])),
        Box::new(BodyExpr::load("W", vec![IndexExpr::var(j.var), IndexExpr::var(k.var)])),
    );
    ComputeDef {
        name: format!("dense_b{batch}_o{out_dim}_i{in_dim}"),
        output: TensorSpec::new("O", &[batch, out_dim]),
        inputs: vec![
            TensorSpec::new("X", &[batch, in_dim]),
            TensorSpec::new("W", &[out_dim, in_dim]),
        ],
        axes: vec![b, j],
        reduce_axes: vec![k],
        body,
        combiner: Combiner::Sum,
        epilogue: None,
        vars: pool,
    }
}

/// Max pooling `kxk` stride `s` (ResNet stem / head glue).
pub fn max_pool2d(n: i64, c: i64, h: i64, w: i64, k: i64, s: i64) -> ComputeDef {
    let mut pool = VarPool::new();
    let oh = (h - k) / s + 1;
    let ow = (w - k) / s + 1;
    let nn = itv(&mut pool, "n", n, IterKind::Spatial);
    let cc = itv(&mut pool, "c", c, IterKind::Spatial);
    let oy = itv(&mut pool, "oy", oh, IterKind::Spatial);
    let ox = itv(&mut pool, "ox", ow, IterKind::Spatial);
    let ky = itv(&mut pool, "ky", k, IterKind::Reduce);
    let kx = itv(&mut pool, "kx", k, IterKind::Reduce);
    let iy = IndexExpr::scaled_var(oy.var, s).add(&IndexExpr::var(ky.var));
    let ix = IndexExpr::scaled_var(ox.var, s).add(&IndexExpr::var(kx.var));
    let data = BodyExpr::Load(Access {
        tensor: "I".into(),
        indices: vec![IndexExpr::var(nn.var), IndexExpr::var(cc.var), iy, ix],
    });
    ComputeDef {
        name: format!("maxpool_n{n}_c{c}_h{h}_w{w}_k{k}_s{s}"),
        output: TensorSpec::new("O", &[n, c, oh, ow]),
        inputs: vec![TensorSpec::new("I", &[n, c, h, w])],
        axes: vec![nn, cc, oy, ox],
        reduce_axes: vec![ky, kx],
        body: data,
        combiner: Combiner::Max,
        epilogue: None,
        vars: pool,
    }
}

/// Elementwise binary add over a flat shape (residual connections).
pub fn elemwise_add(shape: &[i64]) -> ComputeDef {
    let mut pool = VarPool::new();
    let numel: i64 = shape.iter().product();
    let i = itv(&mut pool, "i", numel, IterKind::Spatial);
    let body = BodyExpr::Add(
        Box::new(BodyExpr::load("A", vec![IndexExpr::var(i.var)])),
        Box::new(BodyExpr::load("B", vec![IndexExpr::var(i.var)])),
    );
    ComputeDef {
        name: format!("ewadd_{numel}"),
        output: TensorSpec::new("O", &[numel]),
        inputs: vec![TensorSpec::new("A", &[numel]), TensorSpec::new("B", &[numel])],
        axes: vec![i],
        reduce_axes: vec![],
        body,
        combiner: Combiner::Sum,
        epilogue: None,
        vars: pool,
    }
}

/// ReLU over a flat shape.
pub fn relu(shape: &[i64]) -> ComputeDef {
    let mut pool = VarPool::new();
    let numel: i64 = shape.iter().product();
    let i = itv(&mut pool, "i", numel, IterKind::Spatial);
    let body = BodyExpr::Relu(Box::new(BodyExpr::load("A", vec![IndexExpr::var(i.var)])));
    ComputeDef {
        name: format!("relu_{numel}"),
        output: TensorSpec::new("O", &[numel]),
        inputs: vec![TensorSpec::new("A", &[numel])],
        axes: vec![i],
        reduce_axes: vec![],
        body,
        combiner: Combiner::Sum,
        epilogue: None,
        vars: pool,
    }
}

/// Fuse a ReLU (or bias+ReLU) epilogue into a reduction compute — the
/// operator-fusion primitive the end-to-end evaluation relies on.
pub fn with_epilogue(mut def: ComputeDef, epi: Epilogue) -> ComputeDef {
    def.epilogue = Some(epi);
    def.name = format!(
        "{}_{}",
        def.name,
        match epi {
            Epilogue::Relu => "relu",
            Epilogue::BiasRelu => "biasrelu",
        }
    );
    def
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_flops() {
        let m = matmul(1024, 1024, 1024);
        // mul + add per inner iteration
        assert_eq!(m.total_flops(), 2 * 1024 * 1024 * 1024);
    }

    #[test]
    fn conv_output_shape_c1() {
        // C1 of Table 1: 224x224, 3->64, k7 s2 (pad 3)
        let p = Conv2dParams { n: 1, h: 224, w: 224, ic: 3, oc: 64, kh: 7, kw: 7, stride: 2, pad: 3 };
        assert_eq!(p.out_h(), 112);
        let c = conv2d(p);
        assert_eq!(c.output.shape, vec![1, 64, 112, 112]);
        assert_eq!(c.axes.len(), 4);
        assert_eq!(c.reduce_axes.len(), 3);
    }

    #[test]
    fn conv_padding_select_present() {
        let p = Conv2dParams { n: 1, h: 56, w: 56, ic: 64, oc: 64, kh: 3, kw: 3, stride: 1, pad: 1 };
        let c = conv2d(p);
        assert!(matches!(
            c.body,
            BodyExpr::Mul(ref a, _) if matches!(**a, BodyExpr::Select(..))
        ));
    }

    #[test]
    fn depthwise_has_two_reduce_axes() {
        let p = Conv2dParams { n: 1, h: 112, w: 112, ic: 32, oc: 32, kh: 3, kw: 3, stride: 1, pad: 1 };
        let d = depthwise_conv2d(p);
        assert_eq!(d.reduce_axes.len(), 2);
    }

    #[test]
    fn task_keys_dedupe_same_shape() {
        let p = Conv2dParams { n: 1, h: 56, w: 56, ic: 64, oc: 64, kh: 3, kw: 3, stride: 1, pad: 1 };
        assert_eq!(conv2d(p).task_key(), conv2d(p).task_key());
    }
}
