//! Winograd F(2×2, 3×3) convolution — the "AutoTVM-PT" variant of
//! Fig. 10 (weight **p**re-**t**ransformed, following Lavin & Gray [24]).
//!
//! A 3×3 stride-1 conv over 2×2 output tiles becomes, per tile `p` and
//! transform position `ε ∈ 4×4 = 16`:
//!
//! ```text
//! V[ε, ic, p]  = Bᵀ d B      (input transform, adds only)
//! M[ε, oc, p]  = Σ_ic U[ε, oc, ic] · V[ε, ic, p]    (the tunable bgemm)
//! Y[oc, 2×2·p] = Aᵀ M A      (output transform, adds only)
//! ```
//!
//! `U` is computed offline from the weights (hence zero runtime cost —
//! "pre-transformed"). The multiply count drops from `36·ic` to `16·ic`
//! per tile-channel (2.25×), which is why the paper's PT bars can
//! exceed the direct-conv roofline in *effective* GFLOPS. The bgemm is
//! an ordinary [`ComputeDef`] and goes through the normal tuner.

use super::ops::Conv2dParams;
use super::{BodyExpr, Combiner, ComputeDef, IterKind, IterVar, TensorSpec};
use crate::expr::{IndexExpr, VarPool};

/// Whether the Winograd path applies (3×3, stride 1).
pub fn applicable(p: &Conv2dParams) -> bool {
    p.kh == 3 && p.kw == 3 && p.stride == 1 && p.out_h() % 2 == 0 && p.out_w() % 2 == 0
}

/// The three runtime stages of the pre-transformed Winograd conv.
#[derive(Clone, Debug)]
pub struct WinogradStages {
    /// Input transform `V`: cheap, add-dominated, fixed schedule.
    pub input_transform: ComputeDef,
    /// The tunable batched GEMM `M[ε, oc, p] = Σ_ic U·V`.
    pub bgemm: ComputeDef,
    /// Output transform `Y`: cheap, add-dominated, fixed schedule.
    pub output_transform: ComputeDef,
    /// Tiles per image (`⌈H/2⌉·⌈W/2⌉·N`).
    pub tiles: i64,
    /// Effective flops of the *direct* conv (for effective-GFLOPS
    /// accounting, as the paper reports).
    pub direct_flops: u64,
}

/// Build the stages for a conv workload. Panics if not [`applicable`].
pub fn stages(p: Conv2dParams) -> WinogradStages {
    assert!(applicable(&p), "winograd needs 3x3 s1 with even output");
    let oh = p.out_h();
    let ow = p.out_w();
    let tiles = p.n * (oh / 2) * (ow / 2);
    let eps = 16i64; // 4×4 transform positions

    // --- input transform: V[eps, ic, tile] from 4×4 input windows ---
    // modeled as an elementwise op with ~4 adds per output element
    // (Bᵀ d B costs 32 adds over 16 outputs).
    let itf = {
        let mut pool = VarPool::new();
        let e = IterVar {
            var: pool.fresh("e"),
            name: "e".into(),
            extent: eps,
            kind: IterKind::Spatial,
        };
        let c = IterVar {
            var: pool.fresh("c"),
            name: "c".into(),
            extent: p.ic,
            kind: IterKind::Spatial,
        };
        let t = IterVar {
            var: pool.fresh("t"),
            name: "t".into(),
            extent: tiles,
            kind: IterKind::Spatial,
        };
        // 2 loads + adds approximate the transform arithmetic
        let body = BodyExpr::Add(
            Box::new(BodyExpr::Add(
                Box::new(BodyExpr::load(
                    "D",
                    vec![
                        IndexExpr::var(c.var),
                        IndexExpr::var(t.var).add(&IndexExpr::var(e.var)),
                    ],
                )),
                Box::new(BodyExpr::load(
                    "D",
                    vec![IndexExpr::var(c.var), IndexExpr::var(t.var)],
                )),
            )),
            Box::new(BodyExpr::Imm(0.0)),
        );
        ComputeDef {
            name: format!("wino_itf_ic{}_t{}", p.ic, tiles),
            output: TensorSpec::new("V", &[eps, p.ic, tiles]),
            inputs: vec![TensorSpec::new("D", &[p.ic, (p.h + 2) * (p.w + 2)])],
            axes: vec![e, c, t],
            reduce_axes: vec![],
            body,
            combiner: Combiner::Sum,
            epilogue: None,
            vars: pool,
        }
    };

    // --- the tunable bgemm ---
    let bgemm = {
        let mut pool = VarPool::new();
        let e = IterVar {
            var: pool.fresh("e"),
            name: "e".into(),
            extent: eps,
            kind: IterKind::Spatial,
        };
        let oc = IterVar {
            var: pool.fresh("oc"),
            name: "oc".into(),
            extent: p.oc,
            kind: IterKind::Spatial,
        };
        let t = IterVar {
            var: pool.fresh("t"),
            name: "t".into(),
            extent: tiles,
            kind: IterKind::Spatial,
        };
        let c = IterVar {
            var: pool.fresh("c"),
            name: "c".into(),
            extent: p.ic,
            kind: IterKind::Reduce,
        };
        let body = BodyExpr::Mul(
            Box::new(BodyExpr::load(
                "U",
                vec![IndexExpr::var(e.var), IndexExpr::var(oc.var), IndexExpr::var(c.var)],
            )),
            Box::new(BodyExpr::load(
                "V",
                vec![IndexExpr::var(e.var), IndexExpr::var(c.var), IndexExpr::var(t.var)],
            )),
        );
        ComputeDef {
            name: format!("wino_bgemm_oc{}_ic{}_t{}", p.oc, p.ic, tiles),
            output: TensorSpec::new("M", &[eps, p.oc, tiles]),
            inputs: vec![
                TensorSpec::new("U", &[eps, p.oc, p.ic]),
                TensorSpec::new("V", &[eps, p.ic, tiles]),
            ],
            axes: vec![e, oc, t],
            reduce_axes: vec![c],
            body,
            combiner: Combiner::Sum,
            epilogue: None,
            vars: pool,
        }
    };

    // --- output transform: Y[oc, oh*ow] from M (AᵀmA, adds only) ---
    let otf = {
        let mut pool = VarPool::new();
        let oc = IterVar {
            var: pool.fresh("oc"),
            name: "oc".into(),
            extent: p.oc,
            kind: IterKind::Spatial,
        };
        let xy = IterVar {
            var: pool.fresh("xy"),
            name: "xy".into(),
            extent: oh * ow * p.n,
            kind: IterKind::Spatial,
        };
        let body = BodyExpr::Add(
            Box::new(BodyExpr::load(
                "M",
                vec![IndexExpr::constant(0), IndexExpr::var(oc.var), IndexExpr::var(xy.var).scale(1).offset(0)],
            )),
            Box::new(BodyExpr::load(
                "M",
                vec![IndexExpr::constant(1), IndexExpr::var(oc.var), IndexExpr::var(xy.var)],
            )),
        );
        ComputeDef {
            name: format!("wino_otf_oc{}_hw{}", p.oc, oh * ow),
            output: TensorSpec::new("Y", &[p.oc, oh * ow * p.n]),
            inputs: vec![TensorSpec::new("M", &[eps, p.oc, oh * ow * p.n])],
            axes: vec![oc, xy],
            reduce_axes: vec![],
            body,
            combiner: Combiner::Sum,
            epilogue: None,
            vars: pool,
        }
    };

    WinogradStages {
        input_transform: itf,
        bgemm,
        output_transform: otf,
        tiles,
        direct_flops: 2 * p.macs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::template::{Task, TemplateKind};
    use crate::sim::devices::sim_gpu;

    fn c6() -> Conv2dParams {
        crate::workloads::conv_workload(6)
    }

    #[test]
    fn applicability() {
        assert!(applicable(&c6())); // 3x3 s1
        assert!(!applicable(&crate::workloads::conv_workload(1))); // 7x7 s2
        assert!(!applicable(&crate::workloads::conv_workload(3))); // 1x1
        assert!(!applicable(&crate::workloads::conv_workload(7))); // s2
    }

    #[test]
    fn bgemm_multiply_reduction_is_2_25x() {
        let s = stages(c6());
        // bgemm muls = eps * oc * tiles * ic; direct = oh*ow*oc*ic*9
        let p = c6();
        let bgemm_muls = 16 * p.oc * s.tiles * p.ic;
        let direct_muls = p.macs() as i64;
        let ratio = direct_muls as f64 / bgemm_muls as f64;
        assert!((ratio - 2.25).abs() < 1e-9, "ratio {ratio}");
    }

    #[test]
    fn bgemm_is_tunable_and_faster_than_direct_in_effective_gflops() {
        let p = c6();
        let s = stages(p);
        let dev = sim_gpu();
        let task = Task::new(s.bgemm.clone(), TemplateKind::Gpu);
        // modest random search on the bgemm
        let mut rng = crate::util::Rng::seed_from_u64(3);
        let mut best = f64::INFINITY;
        for _ in 0..60 {
            let e = task.space.sample(&mut rng);
            if let Ok(r) = dev.evaluate(&task.lower(&e).unwrap()) {
                best = best.min(r.seconds);
            }
        }
        assert!(best.is_finite());
        // transforms at default schedules
        let t_itf = {
            let t = Task::new(s.input_transform.clone(), TemplateKind::Gpu);
            let e = crate::graph::quick_best(&t, &dev, 16, 1);
            dev.evaluate(&t.lower(&e).unwrap()).unwrap().seconds
        };
        let t_otf = {
            let t = Task::new(s.output_transform.clone(), TemplateKind::Gpu);
            let e = crate::graph::quick_best(&t, &dev, 16, 1);
            dev.evaluate(&t.lower(&e).unwrap()).unwrap().seconds
        };
        let eff_gflops = s.direct_flops as f64 / (best + t_itf + t_otf) / 1e9;
        assert!(eff_gflops > 0.0);
    }
}
