//! Affine index expressions over iteration variables.
//!
//! Lowered programs keep all buffer indices affine in the leaf loop
//! variables (splits substitute `y = yo*ty + yi` rather than emitting
//! div/mod), so stride analysis — the backbone of both the simulator and
//! the loop-context features (Table 2 of the paper) — is exact.

use std::collections::HashMap;

/// Interned iteration-variable id, scoped to one [`VarPool`].
pub type VarId = u32;

/// Per-computation variable table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VarPool {
    names: Vec<String>,
}

impl VarPool {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a new variable, returning its id.
    pub fn fresh(&mut self, name: impl Into<String>) -> VarId {
        let id = self.names.len() as VarId;
        self.names.push(name.into());
        id
    }

    /// Name of variable `v`.
    pub fn name(&self, v: VarId) -> &str {
        &self.names[v as usize]
    }

    /// Number of interned variables.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// Whether the pool is empty.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

/// An affine index expression `c0 + Σ c_v · v`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IndexExpr {
    /// The constant term `c0`.
    pub constant: i64,
    /// Sorted (var, coefficient) pairs; coefficients are never zero.
    pub terms: Vec<(VarId, i64)>,
}

impl IndexExpr {
    /// The constant expression `c`.
    pub fn constant(c: i64) -> Self {
        Self { constant: c, terms: vec![] }
    }

    /// The expression `v`.
    pub fn var(v: VarId) -> Self {
        Self { constant: 0, terms: vec![(v, 1)] }
    }

    /// The expression `c·v`.
    pub fn scaled_var(v: VarId, c: i64) -> Self {
        if c == 0 {
            Self::constant(0)
        } else {
            Self { constant: 0, terms: vec![(v, c)] }
        }
    }

    /// Coefficient of `v` (0 if absent).
    pub fn coeff(&self, v: VarId) -> i64 {
        self.terms
            .iter()
            .find(|(t, _)| *t == v)
            .map(|(_, c)| *c)
            .unwrap_or(0)
    }

    /// Sum of two affine expressions.
    pub fn add(&self, other: &IndexExpr) -> IndexExpr {
        // merge two sorted term lists (hot path: called throughout
        // lowering; avoids hashing — see EXPERIMENTS.md §Perf)
        let (a, b) = (&self.terms, &other.terms);
        let mut terms = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            match a[i].0.cmp(&b[j].0) {
                std::cmp::Ordering::Less => {
                    terms.push(a[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    terms.push(b[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    let c = a[i].1 + b[j].1;
                    if c != 0 {
                        terms.push((a[i].0, c));
                    }
                    i += 1;
                    j += 1;
                }
            }
        }
        terms.extend_from_slice(&a[i..]);
        terms.extend_from_slice(&b[j..]);
        IndexExpr { constant: self.constant + other.constant, terms }
    }

    /// Multiply every term by `k`.
    pub fn scale(&self, k: i64) -> IndexExpr {
        if k == 0 {
            return IndexExpr::constant(0);
        }
        IndexExpr {
            constant: self.constant * k,
            terms: self.terms.iter().map(|(v, c)| (*v, c * k)).collect(),
        }
    }

    /// Add a constant `k`.
    pub fn offset(&self, k: i64) -> IndexExpr {
        IndexExpr { constant: self.constant + k, terms: self.terms.clone() }
    }

    /// Substitute variable `v` by expression `e`.
    pub fn substitute(&self, v: VarId, e: &IndexExpr) -> IndexExpr {
        let c = self.coeff(v);
        if c == 0 {
            return self.clone();
        }
        let mut base = IndexExpr {
            constant: self.constant,
            terms: self.terms.iter().copied().filter(|(t, _)| *t != v).collect(),
        };
        base = base.add(&e.scale(c));
        base
    }

    /// Evaluate at a concrete assignment (vars absent default to 0).
    pub fn eval(&self, env: &HashMap<VarId, i64>) -> i64 {
        self.constant
            + self
                .terms
                .iter()
                .map(|(v, c)| c * env.get(v).copied().unwrap_or(0))
                .sum::<i64>()
    }

    /// Whether the expression has no variable terms.
    pub fn is_constant(&self) -> bool {
        self.terms.is_empty()
    }

    /// Human-readable form using the pool's variable names.
    pub fn display(&self, pool: &VarPool) -> String {
        let mut parts = Vec::new();
        for (v, c) in &self.terms {
            let n = pool.name(*v);
            if *c == 1 {
                parts.push(n.to_string());
            } else {
                parts.push(format!("{c}*{n}"));
            }
        }
        if self.constant != 0 || parts.is_empty() {
            parts.push(self.constant.to_string());
        }
        parts.join(" + ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_merges_and_drops_zero() {
        let mut p = VarPool::new();
        let x = p.fresh("x");
        let y = p.fresh("y");
        let a = IndexExpr { constant: 1, terms: vec![(x, 2), (y, 3)] };
        let b = IndexExpr { constant: 2, terms: vec![(x, -2), (y, 1)] };
        let s = a.add(&b);
        assert_eq!(s.constant, 3);
        assert_eq!(s.terms, vec![(y, 4)]);
    }

    #[test]
    fn substitute_split_var() {
        // y = yo*4 + yi substituted into A[y*8 + 3]
        let mut p = VarPool::new();
        let y = p.fresh("y");
        let yo = p.fresh("yo");
        let yi = p.fresh("yi");
        let idx = IndexExpr { constant: 3, terms: vec![(y, 8)] };
        let sub = IndexExpr { constant: 0, terms: vec![(yo, 4), (yi, 1)] };
        let out = idx.substitute(y, &sub);
        assert_eq!(out.coeff(yo), 32);
        assert_eq!(out.coeff(yi), 8);
        assert_eq!(out.constant, 3);
        assert_eq!(out.coeff(y), 0);
    }

    #[test]
    fn eval_matches_structure() {
        let mut p = VarPool::new();
        let x = p.fresh("x");
        let e = IndexExpr { constant: 5, terms: vec![(x, 7)] };
        let env = HashMap::from([(x, 3)]);
        assert_eq!(e.eval(&env), 26);
    }
}
