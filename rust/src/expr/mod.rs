//! Tensor expression IR — the space `E` of §2.
//!
//! A [`ComputeDef`] is an index-expression operator specification, e.g.
//! `C[y, x] = Σ_k A[k, y] * B[k, x]` (the paper's Fig. 1 running
//! example). It names output axes, reduce axes and a scalar body over
//! tensor accesses. The schedule space `S_e` ([`crate::schedule`]) and
//! the compiler `g` ([`crate::lower`]) are defined relative to this IR.

mod index;
pub mod ops;
pub mod winograd;

pub use index::{IndexExpr, VarId, VarPool};


/// A typed tensor placeholder (an input of the computation).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Tensor (buffer) name.
    pub name: String,
    /// Row-major dimensions.
    pub shape: Vec<i64>,
}

impl TensorSpec {
    /// Placeholder with a name and shape.
    pub fn new(name: impl Into<String>, shape: &[i64]) -> Self {
        Self { name: name.into(), shape: shape.to_vec() }
    }

    /// Number of elements.
    pub fn numel(&self) -> i64 {
        self.shape.iter().product()
    }

    /// Row-major strides of the flattened buffer.
    pub fn strides(&self) -> Vec<i64> {
        let mut s = vec![1i64; self.shape.len()];
        for d in (0..self.shape.len().saturating_sub(1)).rev() {
            s[d] = s[d + 1] * self.shape[d + 1];
        }
        s
    }
}

/// Iteration variable kind: spatial (parallelizable output axis) or
/// reduction (commutative accumulate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IterKind {
    /// Parallelizable output axis.
    Spatial,
    /// Commutative reduction axis.
    Reduce,
}

/// One iteration axis of a compute definition.
#[derive(Clone, Debug, PartialEq)]
pub struct IterVar {
    /// Interned variable id.
    pub var: VarId,
    /// Axis name (e.g. `oc`, `kh`).
    pub name: String,
    /// Axis extent.
    pub extent: i64,
    /// Spatial vs reduction.
    pub kind: IterKind,
}

/// A read `T[i_0, ..., i_{r-1}]` of an input tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Access {
    /// Tensor read from.
    pub tensor: String,
    /// One affine index per dimension.
    pub indices: Vec<IndexExpr>,
}

/// Scalar value expression forming the body of a compute definition.
#[derive(Clone, Debug, PartialEq)]
pub enum BodyExpr {
    /// Read of an input tensor.
    Load(Access),
    /// Immediate constant.
    Imm(f64),
    /// Addition.
    Add(Box<BodyExpr>, Box<BodyExpr>),
    /// Subtraction.
    Sub(Box<BodyExpr>, Box<BodyExpr>),
    /// Multiplication.
    Mul(Box<BodyExpr>, Box<BodyExpr>),
    /// Elementwise maximum.
    Max(Box<BodyExpr>, Box<BodyExpr>),
    /// `max(x, 0)` — lets us fuse ReLU epilogues.
    Relu(Box<BodyExpr>),
    /// Select on an index predicate `cond ? a : b` (used for padding).
    Select(PredExpr, Box<BodyExpr>, Box<BodyExpr>),
}

impl BodyExpr {
    /// Convenience constructor for [`BodyExpr::Load`].
    pub fn load(tensor: impl Into<String>, indices: Vec<IndexExpr>) -> Self {
        BodyExpr::Load(Access { tensor: tensor.into(), indices })
    }

    /// All tensor accesses in this expression, in evaluation order.
    pub fn accesses(&self) -> Vec<&Access> {
        let mut out = Vec::new();
        self.collect_accesses(&mut out);
        out
    }

    fn collect_accesses<'a>(&'a self, out: &mut Vec<&'a Access>) {
        match self {
            BodyExpr::Load(a) => out.push(a),
            BodyExpr::Imm(_) => {}
            BodyExpr::Add(a, b)
            | BodyExpr::Sub(a, b)
            | BodyExpr::Mul(a, b)
            | BodyExpr::Max(a, b) => {
                a.collect_accesses(out);
                b.collect_accesses(out);
            }
            BodyExpr::Relu(a) => a.collect_accesses(out),
            BodyExpr::Select(_, a, b) => {
                a.collect_accesses(out);
                b.collect_accesses(out);
            }
        }
    }

    /// Number of scalar arithmetic ops per evaluation (flop estimate).
    pub fn flops(&self) -> u64 {
        match self {
            BodyExpr::Load(_) | BodyExpr::Imm(_) => 0,
            BodyExpr::Add(a, b)
            | BodyExpr::Sub(a, b)
            | BodyExpr::Mul(a, b)
            | BodyExpr::Max(a, b) => 1 + a.flops() + b.flops(),
            BodyExpr::Relu(a) => 1 + a.flops(),
            BodyExpr::Select(_, a, b) => 1 + a.flops() + b.flops(),
        }
    }
}

/// Index predicate for padding selects: `lo <= e < hi` conjunctions.
#[derive(Clone, Debug, PartialEq)]
pub struct PredExpr {
    /// `(index, lo, hi)` half-open bounds that must all hold.
    pub bounds: Vec<(IndexExpr, i64, i64)>,
}

/// Reduction combiner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Combiner {
    /// `acc += body`, identity 0.
    Sum,
    /// `acc = max(acc, body)`, identity -inf.
    Max,
}

impl Combiner {
    /// The combiner's identity element.
    pub fn identity(self) -> f64 {
        match self {
            Combiner::Sum => 0.0,
            Combiner::Max => f64::NEG_INFINITY,
        }
    }
}

/// An index-expression operator specification: `e ∈ E`.
///
/// Output element `output[axes...] = reduce(body)` over `reduce_axes`.
#[derive(Clone, Debug, PartialEq)]
pub struct ComputeDef {
    /// Operator name (encodes shape parameters; the task key).
    pub name: String,
    /// The produced tensor.
    pub output: TensorSpec,
    /// Input tensor placeholders.
    pub inputs: Vec<TensorSpec>,
    /// Spatial (output) axes.
    pub axes: Vec<IterVar>,
    /// Reduction axes.
    pub reduce_axes: Vec<IterVar>,
    /// Per-element value expression.
    pub body: BodyExpr,
    /// How reduced values combine.
    pub combiner: Combiner,
    /// Fused elementwise epilogue applied to the accumulated value
    /// (e.g. ReLU) — the operator-fusion hook used by the graph layer.
    pub epilogue: Option<Epilogue>,
    /// Variable pool resolving axis [`VarId`]s.
    pub vars: VarPool,
}

/// Elementwise epilogues that can be fused onto a reduction output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Epilogue {
    /// `max(x, 0)`.
    Relu,
    /// Add a per-channel bias then ReLU (bias read cost is negligible and
    /// modeled as one extra flop).
    BiasRelu,
}

impl ComputeDef {
    /// Total floating point operations of the full computation.
    pub fn total_flops(&self) -> u64 {
        let spatial: u64 = self.axes.iter().map(|a| a.extent as u64).product();
        let red: u64 = self.reduce_axes.iter().map(|a| a.extent as u64).product();
        let per_iter = self.body.flops() + if self.reduce_axes.is_empty() { 0 } else { 1 };
        let epi = self.epilogue.map_or(0, |e| match e {
            Epilogue::Relu => 1,
            Epilogue::BiasRelu => 2,
        });
        spatial * red * per_iter + spatial * epi
    }

    /// All iteration axes, spatial first.
    pub fn all_axes(&self) -> impl Iterator<Item = &IterVar> {
        self.axes.iter().chain(self.reduce_axes.iter())
    }

    /// Look up an axis (spatial or reduce) by name.
    pub fn find_axis(&self, name: &str) -> Option<&IterVar> {
        self.all_axes().find(|a| a.name == name)
    }

    /// A short identity key for task deduplication (op name already
    /// encodes shape parameters by convention of `ops::*`).
    pub fn task_key(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_strides_row_major() {
        let t = TensorSpec::new("A", &[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.numel(), 24);
    }

    #[test]
    fn body_flops_counts_ops() {
        let a = BodyExpr::load("A", vec![]);
        let b = BodyExpr::load("B", vec![]);
        let e = BodyExpr::Mul(Box::new(a), Box::new(b));
        assert_eq!(e.flops(), 1);
        let e2 = BodyExpr::Relu(Box::new(e.clone()));
        assert_eq!(e2.flops(), 2);
    }

    #[test]
    fn accesses_collects_in_order() {
        let e = BodyExpr::Add(
            Box::new(BodyExpr::load("A", vec![])),
            Box::new(BodyExpr::Mul(
                Box::new(BodyExpr::load("B", vec![])),
                Box::new(BodyExpr::load("C", vec![])),
            )),
        );
        let names: Vec<_> = e.accesses().iter().map(|a| a.tensor.clone()).collect();
        assert_eq!(names, vec!["A", "B", "C"]);
    }
}
