//! Gradient-boosted trees — the paper's primary statistical cost model
//! (§3.1), XGBoost-style [7]: second-order boosting with histogram
//! split finding, supporting both training objectives of §3.2:
//!
//! * [`Objective::Regression`] — squared error on the label.
//! * [`Objective::Rank`] — the pairwise logistic rank loss of Eq. 2,
//!   with per-group pair sampling (groups = measurement batches or one
//!   global group).
//!
//! Labels follow the "higher is better" convention (the tuner feeds
//! throughput scores), so `predict` output is directly usable as the SA
//! energy (negated).

pub mod persist;
pub mod plan;
pub mod tree;

pub use plan::PredictPlan;

use crate::util::Rng;
use tree::{Binner, Tree};

/// Row-major f32 feature matrix.
#[derive(Clone, Debug, Default)]
pub struct Matrix {
    /// Row-major storage, `rows × cols`.
    pub data: Vec<f32>,
    /// Number of rows (samples).
    pub rows: usize,
    /// Number of columns (features).
    pub cols: usize,
}

impl Matrix {
    /// Wrap row-major storage (length must be `rows × cols`).
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { data, rows, cols }
    }

    /// Build from f64 rows (the featurizers' native output).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let cols = rows.first().map_or(0, |r| r.len());
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged feature rows");
            data.extend(r.iter().map(|&x| x as f32));
        }
        Matrix { data, rows: rows.len(), cols }
    }

    /// Row `i` as a feature slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

/// Training objective (§3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Squared-error regression on throughput labels.
    Regression,
    /// Pairwise rank loss (the paper's default — only order matters).
    Rank,
}

/// Boosting hyper-parameters (defaults follow the paper's setup scale).
#[derive(Clone, Debug)]
pub struct GbtParams {
    /// Training objective (rank vs regression).
    pub objective: Objective,
    /// Boosting rounds.
    pub n_trees: usize,
    /// Maximum tree depth.
    pub max_depth: usize,
    /// Learning rate (shrinkage).
    pub eta: f64,
    /// L2 regularization on leaf weights.
    pub lambda: f64,
    /// Minimum hessian sum to split a node.
    pub min_child_weight: f64,
    /// Feature subsample per tree.
    pub colsample: f64,
    /// Max comparison partners per item in rank mode.
    pub rank_pairs: usize,
    /// RNG seed for subsampling / pair sampling.
    pub seed: u64,
    /// Minimum batch size before [`Gbt::predict_batch`] goes
    /// thread-parallel over rows; smaller batches stay serial (thread
    /// spawn cost dominates). Benches sweep this knob.
    pub parallel_cutoff: usize,
}

impl Default for GbtParams {
    fn default() -> Self {
        GbtParams {
            objective: Objective::Rank,
            n_trees: 50,
            max_depth: 6,
            eta: 0.3,
            lambda: 1.0,
            min_child_weight: 1.0,
            colsample: 0.9,
            rank_pairs: 16,
            seed: 0,
            parallel_cutoff: 256,
        }
    }
}

/// A trained model.
#[derive(Clone, Debug)]
pub struct Gbt {
    /// Hyper-parameters the model was trained with.
    pub params: GbtParams,
    base: f64,
    trees: Vec<Tree>,
}

impl Gbt {
    /// Train on `x` with labels `y` (higher = better). `groups` gives
    /// contiguous group sizes for the rank objective (empty = one
    /// global group).
    pub fn train(x: &Matrix, y: &[f64], groups: &[usize], params: GbtParams) -> Gbt {
        Self::train_impl(x, y, groups, None, None, params)
    }

    /// [`train`](Self::train) with a weight per rank group (must match
    /// `groups` in length): each group's gradient and hessian
    /// contributions are scaled by its weight, down-weighting
    /// lower-trust sources without dropping them. The cross-target
    /// warm-start tier uses this — same-target sibling groups at 1.0,
    /// other-target groups below. Weights of 1.0 everywhere reproduce
    /// [`train`](Self::train) bit-for-bit (no extra RNG draws).
    pub fn train_weighted(
        x: &Matrix,
        y: &[f64],
        groups: &[usize],
        group_weights: &[f64],
        params: GbtParams,
    ) -> Gbt {
        Self::train_impl(x, y, groups, None, Some(group_weights), params)
    }

    /// Train with a per-row base margin (XGBoost's `base_margin`):
    /// boosting starts from `margin` instead of a constant, and
    /// `predict` returns only the learned correction. Used by the
    /// transfer model (Eq. 4) to stack the local model on the global
    /// one.
    pub fn train_with_margin(
        x: &Matrix,
        y: &[f64],
        groups: &[usize],
        margin: &[f64],
        params: GbtParams,
    ) -> Gbt {
        Self::train_impl(x, y, groups, Some(margin), None, params)
    }

    fn train_impl(
        x: &Matrix,
        y: &[f64],
        groups: &[usize],
        margin: Option<&[f64]>,
        group_weights: Option<&[f64]>,
        params: GbtParams,
    ) -> Gbt {
        assert_eq!(x.rows, y.len());
        assert!(x.rows > 0, "empty training set");
        let binner = Binner::fit(x, 128);
        let binned = binner.bin(x);
        let mut rng = Rng::seed_from_u64(params.seed ^ SEED_SALT);
        let groups_vec: Vec<usize> =
            if groups.is_empty() { vec![x.rows] } else { groups.to_vec() };
        assert_eq!(groups_vec.iter().sum::<usize>(), x.rows, "groups must cover rows");
        if let Some(w) = group_weights {
            assert_eq!(w.len(), groups_vec.len(), "one weight per group");
        }

        let base = match (margin, params.objective) {
            (Some(_), _) => 0.0,
            (None, Objective::Regression) => y.iter().sum::<f64>() / y.len() as f64,
            (None, Objective::Rank) => 0.0,
        };
        let mut preds = match margin {
            Some(m) => {
                assert_eq!(m.len(), x.rows);
                m.to_vec()
            }
            None => vec![base; x.rows],
        };
        let mut trees = Vec::with_capacity(params.n_trees);
        let threads = crate::util::default_threads();
        for _ in 0..params.n_trees {
            let (g, h) = gradients(&params, y, &preds, &groups_vec, group_weights, &mut rng);
            let tree = Tree::fit(&binned, &binner, &g, &h, &params, &mut rng, threads);
            for i in 0..x.rows {
                preds[i] += params.eta * tree.predict(x.row(i));
            }
            trees.push(tree);
        }
        Gbt { params, base, trees }
    }

    /// Predict a single row.
    pub fn predict(&self, row: &[f32]) -> f64 {
        let mut p = self.base;
        for t in &self.trees {
            p += self.params.eta * t.predict(row);
        }
        p
    }

    /// Predict a batch (parallel over rows for large batches).
    pub fn predict_batch(&self, x: &Matrix) -> Vec<f64> {
        let threads = crate::util::default_threads();
        if x.rows < self.params.parallel_cutoff || threads <= 1 {
            (0..x.rows).map(|i| self.predict(x.row(i))).collect()
        } else {
            crate::util::parallel_map_range(x.rows, threads, |i| self.predict(x.row(i)))
        }
    }

    /// Number of trained trees.
    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

/// Salt so GBT training streams are independent of other seeded users.
const SEED_SALT: u64 = 0x6bbd_19ae_3f2c_0551;

fn gradients(
    params: &GbtParams,
    y: &[f64],
    preds: &[f64],
    groups: &[usize],
    group_weights: Option<&[f64]>,
    rng: &mut Rng,
) -> (Vec<f64>, Vec<f64>) {
    let n = y.len();
    let mut g = vec![0f64; n];
    let mut h = vec![0f64; n];
    match params.objective {
        Objective::Regression => match group_weights {
            None => {
                for i in 0..n {
                    g[i] = preds[i] - y[i];
                    h[i] = 1.0;
                }
            }
            Some(ws) => {
                // per-row weight = weight of the row's group
                let mut start = 0;
                for (gi, &len) in groups.iter().enumerate() {
                    for i in start..start + len {
                        g[i] = ws[gi] * (preds[i] - y[i]);
                        h[i] = ws[gi];
                    }
                    start += len;
                }
            }
        },
        Objective::Rank => {
            // pairwise logistic: loss = Σ log(1 + exp(-(f_i - f_j)))
            // over pairs with y_i > y_j, pairs sampled per group; a
            // group weight scales its pairs' g/h contributions (1.0 —
            // or no weights at all — leaves the math untouched, and the
            // RNG stream never depends on the weights)
            let mut start = 0;
            for (gi, &len) in groups.iter().enumerate() {
                let end = start + len;
                let w = group_weights.map_or(1.0, |ws| ws[gi]);
                if len >= 2 {
                    for i in start..end {
                        for _ in 0..params.rank_pairs.min(len - 1) {
                            let j = start + rng.gen_range(0..len);
                            if i == j || y[i] == y[j] {
                                continue;
                            }
                            let (hi, lo) = if y[i] > y[j] { (i, j) } else { (j, i) };
                            let s = preds[hi] - preds[lo];
                            let sig = 1.0 / (1.0 + s.exp()); // d loss/d s (neg)
                            g[hi] -= w * sig;
                            g[lo] += w * sig;
                            let hh = w * (sig * (1.0 - sig)).max(1e-6);
                            h[hi] += hh;
                            h[lo] += hh;
                        }
                    }
                }
                start = end;
            }
            // guard all-zero hessians (degenerate groups)
            for i in 0..n {
                if h[i] == 0.0 {
                    h[i] = 1e-6;
                }
            }
        }
    }
    (g, h)
}

/// Bootstrap ensemble for uncertainty estimation (§3.3, Fig. 7): `k`
/// models trained on resampled data; exposes mean and std of member
/// predictions.
#[derive(Clone, Debug)]
pub struct GbtEnsemble {
    /// The bootstrap members.
    pub members: Vec<Gbt>,
}

impl GbtEnsemble {
    /// Train `k` members, each on a bootstrap resample of the rows.
    pub fn train(x: &Matrix, y: &[f64], k: usize, params: GbtParams) -> GbtEnsemble {
        let n = x.rows;
        let mut members = Vec::with_capacity(k);
        let mut rng = Rng::seed_from_u64(params.seed ^ 0xB007);
        for m in 0..k {
            // bootstrap resample rows
            let mut data = Vec::with_capacity(n * x.cols);
            let mut yy = Vec::with_capacity(n);
            for _ in 0..n {
                let i = rng.gen_range(0..n);
                data.extend_from_slice(x.row(i));
                yy.push(y[i]);
            }
            let bx = Matrix::new(n, x.cols, data);
            let mut p = params.clone();
            p.seed = params.seed.wrapping_add(m as u64 + 1);
            members.push(Gbt::train(&bx, &yy, &[], p));
        }
        GbtEnsemble { members }
    }

    /// (mean, std) per row.
    pub fn predict_stats(&self, x: &Matrix) -> Vec<(f64, f64)> {
        let per: Vec<Vec<f64>> = self.members.iter().map(|m| m.predict_batch(x)).collect();
        stats_from_members(&per, x.rows)
    }
}

/// (mean, std) per row from per-member prediction vectors, in member
/// order. Shared by [`GbtEnsemble::predict_stats`] and the plan-routed
/// ensemble path in `model` so both compute the identical f64 sums.
pub fn stats_from_members(per: &[Vec<f64>], rows: usize) -> Vec<(f64, f64)> {
    (0..rows)
        .map(|i| {
            let vals: Vec<f64> = per.iter().map(|p| p[i]).collect();
            let mean = crate::util::mean(&vals);
            let var =
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
            (mean, var.sqrt())
        })
        .collect()
}

/// Kendall-tau-style pairwise ranking accuracy on a held-out set:
/// fraction of pairs ordered consistently (0.5 = random).
pub fn rank_accuracy(pred: &[f64], truth: &[f64]) -> f64 {
    let n = pred.len();
    let mut ok = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for j in i + 1..n {
            if truth[i] == truth[j] {
                continue;
            }
            total += 1;
            if (pred[i] - pred[j]) * (truth[i] - truth[j]) > 0.0 {
                ok += 1;
            }
        }
    }
    if total == 0 {
        0.5
    } else {
        ok as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic(n: usize, cols: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut data = Vec::with_capacity(n * cols);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let row: Vec<f32> = (0..cols).map(|_| rng.gen_f64() as f32 * 4.0).collect();
            // nonlinear target with interactions
            let t = 2.0 * row[0] as f64 + (row[1] as f64).powi(2)
                - 1.5 * row[2] as f64 * row[3] as f64
                + 0.5 * ((row[4] as f64) > 2.0) as u8 as f64;
            data.extend_from_slice(&row);
            y.push(t);
        }
        (Matrix::new(n, cols, data), y)
    }

    #[test]
    fn unit_group_weights_match_unweighted_bitwise() {
        let (x, y) = synthetic(300, 6, 3);
        let groups = vec![100, 100, 100];
        for objective in [Objective::Regression, Objective::Rank] {
            let params = GbtParams { objective, n_trees: 20, seed: 5, ..Default::default() };
            let a = Gbt::train(&x, &y, &groups, params.clone());
            let b = Gbt::train_weighted(&x, &y, &groups, &[1.0, 1.0, 1.0], params);
            for i in 0..x.rows {
                assert_eq!(
                    a.predict(x.row(i)),
                    b.predict(x.row(i)),
                    "all-1.0 weights must be bit-identical ({objective:?})"
                );
            }
        }
    }

    #[test]
    fn down_weighted_group_pulls_less() {
        // two groups with conflicting labels on identical features: the
        // heavier group must dominate the fit
        let mut data = Vec::new();
        let mut y = Vec::new();
        let mut rng = Rng::seed_from_u64(9);
        let n = 200;
        let rows: Vec<Vec<f32>> =
            (0..n).map(|_| (0..4).map(|_| rng.gen_f64() as f32).collect()).collect();
        for r in &rows {
            data.extend_from_slice(r);
            y.push(1.0);
        }
        for r in &rows {
            data.extend_from_slice(r);
            y.push(-1.0);
        }
        let x = Matrix::new(2 * n, 4, data);
        let params = GbtParams {
            objective: Objective::Regression,
            n_trees: 30,
            ..Default::default()
        };
        let m = Gbt::train_weighted(&x, &y, &[n, n], &[1.0, 0.25], params);
        let preds = m.predict_batch(&x);
        let mu = crate::util::mean(&preds);
        assert!(mu > 0.3, "weight-1.0 group (+1 labels) should dominate, mean {mu}");
    }

    #[test]
    fn regression_fits_synthetic() {
        let (x, y) = synthetic(2000, 8, 1);
        let (xt, yt) = synthetic(500, 8, 2);
        let params = GbtParams {
            objective: Objective::Regression,
            n_trees: 80,
            ..Default::default()
        };
        let m = Gbt::train(&x, &y, &[], params);
        let pred = m.predict_batch(&xt);
        let err: f64 = pred
            .iter()
            .zip(&yt)
            .map(|(p, t)| (p - t) * (p - t))
            .sum::<f64>()
            / yt.len() as f64;
        let var = {
            let mu = crate::util::mean(&yt);
            yt.iter().map(|t| (t - mu) * (t - mu)).sum::<f64>() / yt.len() as f64
        };
        assert!(err < 0.2 * var, "rmse² {err} vs var {var}");
    }

    #[test]
    fn rank_learns_ordering() {
        let (x, y) = synthetic(2000, 8, 3);
        let (xt, yt) = synthetic(300, 8, 4);
        let params =
            GbtParams { objective: Objective::Rank, n_trees: 60, ..Default::default() };
        let m = Gbt::train(&x, &y, &[], params);
        let pred = m.predict_batch(&xt);
        let acc = rank_accuracy(&pred, &yt);
        assert!(acc > 0.85, "rank accuracy {acc}");
    }

    #[test]
    fn rank_with_groups_trains() {
        let (x, y) = synthetic(512, 8, 5);
        let groups = vec![64; 8];
        let params =
            GbtParams { objective: Objective::Rank, n_trees: 20, ..Default::default() };
        let m = Gbt::train(&x, &y, &groups, params);
        assert_eq!(m.n_trees(), 20);
    }

    #[test]
    fn training_is_deterministic() {
        let (x, y) = synthetic(400, 6, 6);
        let p = GbtParams { n_trees: 10, seed: 42, ..Default::default() };
        let a = Gbt::train(&x, &y, &[], p.clone());
        let b = Gbt::train(&x, &y, &[], p);
        let pa = a.predict_batch(&x);
        let pb = b.predict_batch(&x);
        assert_eq!(pa, pb);
    }

    #[test]
    fn ensemble_uncertainty_positive() {
        let (x, y) = synthetic(600, 6, 7);
        let p = GbtParams {
            objective: Objective::Regression,
            n_trees: 20,
            ..Default::default()
        };
        let ens = GbtEnsemble::train(&x, &y, 5, p);
        assert_eq!(ens.members.len(), 5);
        let (xt, _) = synthetic(50, 6, 8);
        let stats = ens.predict_stats(&xt);
        assert!(stats.iter().any(|(_, s)| *s > 0.0));
        assert!(stats.iter().all(|(m, s)| m.is_finite() && s.is_finite()));
    }

    #[test]
    fn rank_accuracy_bounds() {
        assert_eq!(rank_accuracy(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]), 1.0);
        assert_eq!(rank_accuracy(&[3.0, 2.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(rank_accuracy(&[1.0, 1.0], &[1.0, 1.0]), 0.5);
    }

    #[test]
    fn constant_labels_dont_crash() {
        let (x, _) = synthetic(100, 6, 9);
        let y = vec![1.0; 100];
        for obj in [Objective::Regression, Objective::Rank] {
            let p = GbtParams { objective: obj, n_trees: 5, ..Default::default() };
            let m = Gbt::train(&x, &y, &[], p);
            let pred = m.predict_batch(&x);
            assert!(pred.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn single_row_training() {
        let x = Matrix::new(1, 6, vec![1.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
        let m = Gbt::train(&x, &[5.0], &[], GbtParams::default());
        assert!(m.predict(&[1.0, 2.0, 3.0, 0.0, 0.0, 0.0]).is_finite());
    }
}
