//! Histogram-based regression tree for second-order boosting.
//!
//! Features are quantile-binned to u8 once per training set; split
//! search accumulates (grad, hess) histograms per feature per node and
//! scans bins for the best XGBoost gain
//! `G_L²/(H_L+λ) + G_R²/(H_R+λ) − G²/(H+λ)`.

use super::{GbtParams, Matrix};
use crate::util::{parallel_map, Rng};

/// Quantile binner: per-feature ascending cut points; bin b holds
/// values ≤ cuts[b] (last bin unbounded).
#[derive(Clone, Debug)]
pub struct Binner {
    /// cuts[f] — ascending thresholds, len ≤ max_bins-1.
    pub cuts: Vec<Vec<f32>>,
}

impl Binner {
    /// Learn per-feature quantile cut points from a feature matrix.
    pub fn fit(x: &Matrix, max_bins: usize) -> Binner {
        let mut cuts = Vec::with_capacity(x.cols);
        for f in 0..x.cols {
            let mut vals: Vec<f32> = (0..x.rows).map(|i| x.row(i)[f]).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup();
            let c = if vals.len() <= max_bins {
                // midpoints between distinct values
                vals.windows(2).map(|w| (w[0] + w[1]) / 2.0).collect()
            } else {
                let mut c = Vec::with_capacity(max_bins - 1);
                for b in 1..max_bins {
                    let q = b * (vals.len() - 1) / max_bins;
                    let v = vals[q];
                    if c.last() != Some(&v) {
                        c.push(v);
                    }
                }
                c
            };
            cuts.push(c);
        }
        Binner { cuts }
    }

    #[inline]
    /// Bin index of value `v` in feature column `f`.
    pub fn bin_value(&self, f: usize, v: f32) -> u8 {
        // binary search first cut > v
        let cuts = &self.cuts[f];
        let mut lo = 0usize;
        let mut hi = cuts.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if v <= cuts[mid] {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo as u8
    }

    #[inline]
    /// Bin index of value `v` in feature column `f`, without the u8
    /// truncation of [`Binner::bin_value`]. Used by
    /// [`super::plan::PredictPlan`], whose per-feature cut lists are
    /// derived from split thresholds and may exceed 255 entries.
    pub fn bin_value_wide(&self, f: usize, v: f32) -> u16 {
        let cuts = &self.cuts[f];
        let mut lo = 0usize;
        let mut hi = cuts.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if v <= cuts[mid] {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo as u16
    }

    /// Bin a whole matrix (column-major output for cache-friendly
    /// histogram accumulation).
    pub fn bin(&self, x: &Matrix) -> BinnedMatrix {
        let mut cols = Vec::with_capacity(x.cols);
        for f in 0..x.cols {
            let col: Vec<u8> = (0..x.rows).map(|i| self.bin_value(f, x.row(i)[f])).collect();
            cols.push(col);
        }
        BinnedMatrix { cols, rows: x.rows }
    }

    /// Threshold (raw feature value) corresponding to "bin ≤ b".
    pub fn threshold(&self, f: usize, b: u8) -> f32 {
        self.cuts[f][b as usize]
    }

    /// Number of bins of feature column `f`.
    pub fn n_bins(&self, f: usize) -> usize {
        self.cuts[f].len() + 1
    }
}

/// Column-major binned features.
#[derive(Clone, Debug)]
pub struct BinnedMatrix {
    /// One bin-index column per feature.
    pub cols: Vec<Vec<u8>>,
    /// Number of rows (samples).
    pub rows: usize,
}

/// Tree node (public for (de)serialization in [`super::persist`]).
#[derive(Clone, Debug)]
pub enum Node {
    /// Terminal node.
    Leaf {
        /// Predicted value (leaf weight).
        value: f64,
    },
    /// Internal decision node.
    Split {
        /// Feature column tested.
        feature: u32,
        /// Go left when `x[feature] < threshold`.
        threshold: f32,
        /// Left child index.
        left: u32,
        /// Right child index.
        right: u32,
    },
}

/// One regression tree.
#[derive(Clone, Debug)]
pub struct Tree {
    nodes: Vec<Node>,
}

struct BuildCtx<'a> {
    binned: &'a BinnedMatrix,
    binner: &'a Binner,
    g: &'a [f64],
    h: &'a [f64],
    params: &'a GbtParams,
    features: Vec<usize>,
    threads: usize,
}

impl Tree {
    /// Grow one tree on gradients/hessians `g`/`h` by greedy
    /// histogram-based splitting.
    pub fn fit(
        binned: &BinnedMatrix,
        binner: &Binner,
        g: &[f64],
        h: &[f64],
        params: &GbtParams,
        rng: &mut Rng,
        threads: usize,
    ) -> Tree {
        let n_feat = binned.cols.len();
        let keep = ((n_feat as f64 * params.colsample).ceil() as usize).clamp(1, n_feat);
        let features = if keep == n_feat {
            (0..n_feat).collect()
        } else {
            rng.sample_indices(n_feat, keep)
        };
        let ctx = BuildCtx { binned, binner, g, h, params, features, threads };
        let mut tree = Tree { nodes: Vec::new() };
        let idx: Vec<u32> = (0..binned.rows as u32).collect();
        tree.build(&ctx, idx, 0);
        tree
    }

    fn build(&mut self, ctx: &BuildCtx, idx: Vec<u32>, depth: usize) -> u32 {
        let gsum: f64 = idx.iter().map(|&i| ctx.g[i as usize]).sum();
        let hsum: f64 = idx.iter().map(|&i| ctx.h[i as usize]).sum();
        let leaf_value = -gsum / (hsum + ctx.params.lambda);
        let node_id = self.nodes.len() as u32;
        if depth >= ctx.params.max_depth
            || idx.len() < 2
            || hsum < 2.0 * ctx.params.min_child_weight
        {
            self.nodes.push(Node::Leaf { value: leaf_value });
            return node_id;
        }

        // Best-split search over features. Thread-parallel only when the
        // node is large enough to amortize spawn cost (the dominant GBT
        // training cost before this guard — EXPERIMENTS.md §Perf).
        let work = idx.len() * ctx.features.len();
        let candidates: Vec<Option<SplitCand>> = if work >= 200_000 && ctx.threads > 1 {
            parallel_map(&ctx.features, ctx.threads, |&f| {
                best_split_for_feature(ctx, &idx, f, gsum, hsum)
            })
        } else {
            ctx.features
                .iter()
                .map(|&f| best_split_for_feature(ctx, &idx, f, gsum, hsum))
                .collect()
        };
        let best = candidates
            .into_iter()
            .flatten()
            .max_by(|a, b| a.gain.partial_cmp(&b.gain).unwrap());

        let Some(split) = best.filter(|s| s.gain > 1e-10) else {
            self.nodes.push(Node::Leaf { value: leaf_value });
            return node_id;
        };

        // Partition rows.
        let col = &ctx.binned.cols[split.feature];
        let (left_idx, right_idx): (Vec<u32>, Vec<u32>) =
            idx.iter().partition(|&&i| col[i as usize] <= split.bin);
        debug_assert!(!left_idx.is_empty() && !right_idx.is_empty());

        self.nodes.push(Node::Leaf { value: 0.0 }); // placeholder
        let left = self.build(ctx, left_idx, depth + 1);
        let right = self.build(ctx, right_idx, depth + 1);
        self.nodes[node_id as usize] = Node::Split {
            feature: split.feature as u32,
            threshold: ctx.binner.threshold(split.feature, split.bin),
            left,
            right,
        };
        node_id
    }

    /// Predict one raw feature row.
    #[inline]
    pub fn predict(&self, row: &[f32]) -> f64 {
        let mut n = 0usize;
        loop {
            match &self.nodes[n] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    n = if row[*feature as usize] <= *threshold {
                        *left as usize
                    } else {
                        *right as usize
                    };
                }
            }
        }
    }

    /// Number of nodes (leaves + splits).
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Node storage (for serialization).
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Rebuild from serialized nodes.
    pub fn from_nodes(nodes: Vec<Node>) -> Tree {
        assert!(!nodes.is_empty());
        Tree { nodes }
    }
}

struct SplitCand {
    feature: usize,
    bin: u8,
    gain: f64,
}

fn best_split_for_feature(
    ctx: &BuildCtx,
    idx: &[u32],
    f: usize,
    gsum: f64,
    hsum: f64,
) -> Option<SplitCand> {
    let n_bins = ctx.binner.n_bins(f);
    if n_bins < 2 {
        return None;
    }
    let col = &ctx.binned.cols[f];
    // thread-local scratch: histogram buffers are reused across the
    // ~10^6 (node × feature) calls of a training run instead of being
    // re-allocated (EXPERIMENTS.md §Perf)
    thread_local! {
        static SCRATCH: std::cell::RefCell<(Vec<f64>, Vec<f64>)> =
            const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
    }
    SCRATCH.with(|scratch| {
        let mut s = scratch.borrow_mut();
        let (hist_g, hist_h) = &mut *s;
        hist_g.clear();
        hist_g.resize(n_bins, 0.0);
        hist_h.clear();
        hist_h.resize(n_bins, 0.0);
        for &i in idx {
            let b = col[i as usize] as usize;
            hist_g[b] += ctx.g[i as usize];
            hist_h[b] += ctx.h[i as usize];
        }
    let lambda = ctx.params.lambda;
        let parent = gsum * gsum / (hsum + lambda);
        let mut gl = 0f64;
        let mut hl = 0f64;
        let mut best: Option<SplitCand> = None;
        for b in 0..n_bins - 1 {
            gl += hist_g[b];
            hl += hist_h[b];
            let gr = gsum - gl;
            let hr = hsum - hl;
            if hl < ctx.params.min_child_weight || hr < ctx.params.min_child_weight {
                continue;
            }
            let gain = gl * gl / (hl + lambda) + gr * gr / (hr + lambda) - parent;
            if best.as_ref().map_or(true, |s| gain > s.gain) {
                best = Some(SplitCand { feature: f, bin: b as u8, gain });
            }
        }
        best
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binner_monotone_and_invertible() {
        let x = Matrix::new(6, 1, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Binner::fit(&x, 255);
        // distinct small set: bins must preserve order
        let bins: Vec<u8> = (0..6).map(|i| b.bin_value(0, x.row(i)[0])).collect();
        for w in bins.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn binner_quantile_mode() {
        let vals: Vec<f32> = (0..10_000).map(|i| (i % 1000) as f32).collect();
        let x = Matrix::new(10_000, 1, vals);
        let b = Binner::fit(&x, 64);
        assert!(b.cuts[0].len() <= 63);
        // extremes map to first/last bins
        assert_eq!(b.bin_value(0, -1.0), 0);
        assert_eq!(b.bin_value(0, 1e9), b.cuts[0].len() as u8);
    }

    #[test]
    fn tree_fits_step_function() {
        // y = 1 if x0 > 0.5 else -1; a depth-1 tree should nail it
        let n = 200;
        let mut data = Vec::new();
        let mut g = Vec::new();
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..n {
            let v = rng.gen_f64() as f32;
            data.push(v);
            // gradient of squared loss at pred=0: g = -y
            g.push(if v > 0.5 { -1.0 } else { 1.0 });
        }
        let x = Matrix::new(n, 1, data);
        let h = vec![1.0; n];
        let params = GbtParams { max_depth: 2, ..Default::default() };
        let binner = Binner::fit(&x, 255);
        let binned = binner.bin(&x);
        let mut rng2 = Rng::seed_from_u64(2);
        let t = Tree::fit(&binned, &binner, &g, &h, &params, &mut rng2, 1);
        for i in 0..n {
            let p = t.predict(x.row(i));
            let want = if x.row(i)[0] > 0.5 { 1.0 } else { -1.0 };
            assert!((p - want).abs() < 0.1, "x={} p={p}", x.row(i)[0]);
        }
    }

    #[test]
    fn pure_node_becomes_leaf() {
        let x = Matrix::new(4, 1, vec![1.0, 1.0, 1.0, 1.0]);
        let g = vec![1.0; 4];
        let h = vec![1.0; 4];
        let binner = Binner::fit(&x, 255);
        let binned = binner.bin(&x);
        let mut rng = Rng::seed_from_u64(0);
        let t =
            Tree::fit(&binned, &binner, &g, &h, &GbtParams::default(), &mut rng, 1);
        assert_eq!(t.n_nodes(), 1);
    }
}
