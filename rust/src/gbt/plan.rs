//! Compiled batch-prediction plan: all trees of a trained [`Gbt`]
//! flattened into one contiguous SoA node arena, queried over rows that
//! are quantized **once** through a [`Binner`] built from the union of
//! split thresholds.
//!
//! The scalar walk in [`Gbt::predict`] chases `enum Node` pointers and
//! re-compares raw `f32` features at every split of every tree for
//! every row. The plan instead:
//!
//! 1. keeps only feature columns referenced by ≥1 split (`used`),
//! 2. bins each row's used columns once per batch block (`u8` bins when
//!    every used feature has ≤255 cuts, `u16` otherwise),
//! 3. walks the arena tree-at-a-time over the block
//!    *level-synchronously*: every row of the block advances one level
//!    per sweep with a branchless child select (`bin > t` indexes a
//!    `[left, right]` pair), for exactly the tree's compiled depth —
//!    leaves are compiled as self-loops, so rows that bottom out early
//!    just hold position. The inner loop has a fixed trip count and no
//!    data-dependent branches, which is what the autovectorizer needs,
//! 4. accumulates eta-pre-scaled leaf values per row in tree order.
//!
//! Bit-exactness: `Binner::bin_value` returns the first cut index `lo`
//! with `v <= cuts[lo]`, so for a split stored at cut index `t`,
//! `bin(v) <= t ⟺ v <= cuts[t] = threshold` — exactly the scalar
//! comparison, including NaN (bins past every cut → right, like
//! `NaN <= thr == false`). Leaf values are scaled by `eta` at compile
//! time with the same single f64 multiply the scalar loop performs, and
//! accumulation runs in the same tree order from the same `base`, so
//! sums are bit-identical. `tests/perf_paths.rs` proptests this against
//! random trained models.

use super::tree::{Binner, Node};
use super::{Gbt, Matrix};

/// Rows per cache-friendly prediction block: the binned block
/// (`64 × used`) and its accumulator stay L1-resident while the arena
/// streams through once per tree.
const BLOCK_ROWS: usize = 64;

/// Depth of a tree rooted at local node `i` (leaves are depth 0).
fn depth_of(nodes: &[Node], i: usize) -> u32 {
    match &nodes[i] {
        Node::Leaf { .. } => 0,
        Node::Split { left, right, .. } => {
            1 + depth_of(nodes, *left as usize).max(depth_of(nodes, *right as usize))
        }
    }
}

/// A compiled, immutable batch-prediction plan for one [`Gbt`].
#[derive(Clone, Debug)]
pub struct PredictPlan {
    /// Cut points per *dense* used-feature column (union of split
    /// thresholds, ascending).
    binner: Binner,
    /// Original feature columns referenced by ≥1 split, ascending.
    used: Vec<u32>,
    /// Rows must have at least this many columns (max split feature+1).
    min_features: usize,
    base: f64,
    /// Arena index of each tree's root, in boosting order.
    roots: Vec<u32>,
    /// Depth of each tree — the fixed trip count of its level sweep.
    depths: Vec<u32>,
    /// Dense used-feature index per node (0 for leaves, whose
    /// self-loop children make the value irrelevant but in-bounds).
    feat: Vec<u32>,
    /// Cut index per split node: go left iff `row_bin <= bin[n]`.
    bin: Vec<u16>,
    /// `[left, right]` arena children per split node.
    children: Vec<[u32; 2]>,
    /// Eta-pre-scaled leaf value per leaf node (0.0 for splits).
    value: Vec<f64>,
    /// Every used feature has ≤255 cuts → rows bin to `u8`.
    narrow: bool,
    /// Batch size at which prediction goes thread-parallel over blocks.
    parallel_cutoff: usize,
}

impl Gbt {
    /// Compile this model into a [`PredictPlan`]. The plan's batch
    /// output is bit-identical to [`Gbt::predict`] /
    /// [`Gbt::predict_batch`]; the scalar walk remains the reference.
    pub fn compile(&self) -> PredictPlan {
        // Union of split thresholds per original feature column.
        let mut per_feat: std::collections::BTreeMap<u32, Vec<f32>> =
            std::collections::BTreeMap::new();
        for t in &self.trees {
            for n in t.nodes() {
                if let Node::Split { feature, threshold, .. } = n {
                    per_feat.entry(*feature).or_default().push(*threshold);
                }
            }
        }
        let used: Vec<u32> = per_feat.keys().copied().collect();
        let mut dense_of = std::collections::HashMap::with_capacity(used.len());
        let mut cuts = Vec::with_capacity(used.len());
        for (d, (f, mut thr)) in per_feat.into_iter().enumerate() {
            thr.sort_by(|a, b| a.total_cmp(b));
            thr.dedup();
            assert!(thr.len() <= u16::MAX as usize, "feature {f}: too many cuts");
            dense_of.insert(f, d as u32);
            cuts.push(thr);
        }
        let narrow = cuts.iter().all(|c| c.len() <= u8::MAX as usize);
        let min_features = used.last().map_or(0, |&f| f as usize + 1);
        let binner = Binner { cuts };

        // Flatten every tree into the shared arena. Child indices are
        // tree-local in `Tree::nodes`, so offset them by the tree base.
        let mut roots = Vec::with_capacity(self.trees.len());
        let mut depths = Vec::with_capacity(self.trees.len());
        let mut feat = Vec::new();
        let mut bin = Vec::new();
        let mut children = Vec::new();
        let mut value = Vec::new();
        for t in &self.trees {
            let off = feat.len() as u32;
            roots.push(off);
            depths.push(depth_of(t.nodes(), 0));
            for (i, n) in t.nodes().iter().enumerate() {
                match n {
                    Node::Leaf { value: v } => {
                        // Leaves self-loop: the level sweep runs a fixed
                        // per-tree depth, and a row that bottoms out
                        // early must hold position. `feat` 0 keeps the
                        // bin read in bounds (any tree with depth > 0
                        // has ≥1 used feature).
                        let s = off + i as u32;
                        feat.push(0);
                        bin.push(0);
                        children.push([s, s]);
                        value.push(self.params.eta * v);
                    }
                    Node::Split { feature, threshold, left, right } => {
                        let d = dense_of[feature];
                        let c = &binner.cuts[d as usize];
                        let t = c
                            .binary_search_by(|x| x.total_cmp(threshold))
                            .expect("split threshold present in plan cuts");
                        feat.push(d);
                        bin.push(t as u16);
                        children.push([off + left, off + right]);
                        value.push(0.0);
                    }
                }
            }
        }
        PredictPlan {
            binner,
            used,
            min_features,
            base: self.base,
            roots,
            depths,
            feat,
            bin,
            children,
            value,
            narrow,
            parallel_cutoff: self.params.parallel_cutoff,
        }
    }
}

impl PredictPlan {
    /// Number of compiled trees.
    pub fn n_trees(&self) -> usize {
        self.roots.len()
    }

    /// Total arena nodes across all trees.
    pub fn n_nodes(&self) -> usize {
        self.feat.len()
    }

    /// Whether rows quantize to `u8` bins (every used feature ≤255
    /// cuts) — the common case for in-process models, whose training
    /// `Binner` caps at 128 bins.
    pub fn is_narrow(&self) -> bool {
        self.narrow
    }

    /// Predict one raw feature row (bit-identical to [`Gbt::predict`]).
    pub fn predict(&self, row: &[f32]) -> f64 {
        assert!(row.len() >= self.min_features, "row narrower than model");
        let w = self.used.len();
        let mut bins: Vec<u16> = Vec::with_capacity(w);
        for (d, &f) in self.used.iter().enumerate() {
            bins.push(self.binner.bin_value_wide(d, row[f as usize]));
        }
        let mut acc = [self.base];
        self.walk_rows(&bins, w, &mut acc);
        acc[0]
    }

    /// Predict a batch in cache-friendly blocks, thread-parallel over
    /// blocks for large batches. Bit-identical to
    /// [`Gbt::predict_batch`].
    pub fn predict_batch(&self, x: &Matrix) -> Vec<f64> {
        if x.rows == 0 {
            return Vec::new();
        }
        assert!(x.cols >= self.min_features, "matrix narrower than model");
        let threads = crate::util::default_threads();
        let n_blocks = x.rows.div_ceil(BLOCK_ROWS);
        if x.rows < self.parallel_cutoff || threads <= 1 {
            let mut out = Vec::with_capacity(x.rows);
            for b in 0..n_blocks {
                let lo = b * BLOCK_ROWS;
                let hi = (lo + BLOCK_ROWS).min(x.rows);
                out.extend(self.predict_block(x, lo, hi));
            }
            out
        } else {
            let blocks = crate::util::parallel_map_range(n_blocks, threads, |b| {
                let lo = b * BLOCK_ROWS;
                let hi = (lo + BLOCK_ROWS).min(x.rows);
                self.predict_block(x, lo, hi)
            });
            let mut out = Vec::with_capacity(x.rows);
            for v in blocks {
                out.extend(v);
            }
            out
        }
    }

    /// Bin then predict rows `lo..hi`.
    fn predict_block(&self, x: &Matrix, lo: usize, hi: usize) -> Vec<f64> {
        let rows = hi - lo;
        let w = self.used.len();
        let mut acc = vec![self.base; rows];
        if self.narrow {
            let mut bins: Vec<u8> = Vec::with_capacity(rows * w);
            for i in lo..hi {
                let row = x.row(i);
                for (d, &f) in self.used.iter().enumerate() {
                    bins.push(self.binner.bin_value(d, row[f as usize]));
                }
            }
            self.walk_rows(&bins, w, &mut acc);
        } else {
            let mut bins: Vec<u16> = Vec::with_capacity(rows * w);
            for i in lo..hi {
                let row = x.row(i);
                for (d, &f) in self.used.iter().enumerate() {
                    bins.push(self.binner.bin_value_wide(d, row[f as usize]));
                }
            }
            self.walk_rows(&bins, w, &mut acc);
        }
        acc
    }

    /// Tree-at-a-time, level-synchronous arena walk over row-major
    /// binned rows of width `w`, accumulating eta-scaled leaf values
    /// into `acc` (pre-seeded with `base`). Every row of the block
    /// advances one level per sweep; the sweep count is the tree's
    /// compiled depth and the inner row loop is branchless (leaves
    /// self-loop), so the hot loop has a fixed trip count and no
    /// data-dependent control flow. Accumulation stays in tree order
    /// per row — bit-identical to the scalar walk. Generic over the bin
    /// width so the narrow path walks `u8` rows without widening them
    /// in memory.
    fn walk_rows<T: Copy + Into<u16>>(&self, bins: &[T], w: usize, acc: &mut [f64]) {
        let rows = acc.len();
        let mut idx: Vec<u32> = vec![0; rows];
        for (t, &root) in self.roots.iter().enumerate() {
            idx.fill(root);
            for _ in 0..self.depths[t] {
                for r in 0..rows {
                    let n = idx[r] as usize;
                    let b: u16 = bins[r * w + self.feat[n] as usize].into();
                    idx[r] = self.children[n][(b > self.bin[n]) as usize];
                }
            }
            for (r, a) in acc.iter_mut().enumerate() {
                *a += self.value[idx[r] as usize];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Gbt, GbtParams, Matrix, Objective};
    use crate::util::Rng;

    fn synthetic(n: usize, cols: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut data = Vec::with_capacity(n * cols);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let row: Vec<f32> = (0..cols).map(|_| rng.gen_f64() as f32 * 4.0).collect();
            let t = 2.0 * row[0] as f64 - (row[1] as f64) * (row[2 % cols] as f64);
            data.extend_from_slice(&row);
            y.push(t);
        }
        (Matrix::new(n, cols, data), y)
    }

    #[test]
    fn plan_matches_scalar_bitwise() {
        let (x, y) = synthetic(600, 8, 11);
        for obj in [Objective::Regression, Objective::Rank] {
            let p = GbtParams { objective: obj, n_trees: 25, seed: 4, ..Default::default() };
            let m = Gbt::train(&x, &y, &[], p);
            let plan = m.compile();
            assert!(plan.is_narrow());
            let (xt, _) = synthetic(333, 8, 12);
            let scalar = m.predict_batch(&xt);
            let fast = plan.predict_batch(&xt);
            assert_eq!(scalar, fast, "batch diverged ({obj:?})");
            for i in 0..xt.rows {
                assert_eq!(m.predict(xt.row(i)).to_bits(), plan.predict(xt.row(i)).to_bits());
            }
        }
    }

    #[test]
    fn plan_handles_out_of_range_and_nan() {
        let (x, y) = synthetic(300, 6, 13);
        let m = Gbt::train(&x, &y, &[], GbtParams { n_trees: 10, ..Default::default() });
        let plan = m.compile();
        let weird = vec![
            vec![-1e30f32, 1e30, f32::NAN, 0.0, -0.0, f32::INFINITY],
            vec![f32::NEG_INFINITY, f32::NAN, f32::NAN, 1e-30, 4.0, 2.0],
        ];
        for row in &weird {
            assert_eq!(m.predict(row).to_bits(), plan.predict(row).to_bits());
        }
    }

    #[test]
    fn stump_free_model_compiles() {
        // constant labels → trees may be single leaves (no used features)
        let (x, _) = synthetic(50, 4, 14);
        let y = vec![2.0; 50];
        let m = Gbt::train(
            &x,
            &y,
            &[],
            GbtParams { objective: Objective::Regression, n_trees: 3, ..Default::default() },
        );
        let plan = m.compile();
        assert_eq!(m.predict_batch(&x), plan.predict_batch(&x));
    }

    #[test]
    fn plan_parallel_path_matches_serial() {
        let (x, y) = synthetic(400, 8, 15);
        let mut params = GbtParams { n_trees: 15, ..Default::default() };
        let m = Gbt::train(&x, &y, &[], params.clone());
        let (xt, _) = synthetic(2000, 8, 16);
        let serial_plan = m.compile();
        params.parallel_cutoff = 1;
        let mut m2 = m.clone();
        m2.params = params;
        let parallel_plan = m2.compile();
        assert_eq!(serial_plan.predict_batch(&xt), parallel_plan.predict_batch(&xt));
    }
}
