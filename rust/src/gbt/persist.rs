//! GBT model (de)serialization — a production tuning service keeps the
//! global transfer model on disk between sessions (§4: "the system
//! collects historical data D' from previously seen workloads").

use super::tree::{Node, Tree};
use super::{Gbt, GbtParams, Objective};
use crate::util::json::Json;

impl Gbt {
    /// Serialize to a JSON document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("objective", match self.params.objective {
                Objective::Rank => "rank".into(),
                Objective::Regression => "regression".into(),
            }),
            ("eta", self.params.eta.into()),
            ("base", self.base.into()),
            (
                "trees",
                Json::Arr(self.trees.iter().map(Tree::to_json).collect()),
            ),
        ])
    }

    /// Parse a serialized model.
    pub fn from_json(j: &Json) -> anyhow::Result<Gbt> {
        let objective = match j.get("objective").and_then(Json::as_str) {
            Some("rank") => Objective::Rank,
            Some("regression") => Objective::Regression,
            other => anyhow::bail!("bad objective {other:?}"),
        };
        let eta = j
            .get("eta")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing eta"))?;
        let base = j
            .get("base")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing base"))?;
        let trees = j
            .get("trees")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing trees"))?
            .iter()
            .map(Tree::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let params = GbtParams { objective, eta, ..Default::default() };
        Ok(Gbt { params, base, trees })
    }

    /// Serialize the model to a JSON file.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }

    /// Load a model serialized by [`Gbt::save`].
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Gbt> {
        Gbt::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

impl Tree {
    fn to_json(&self) -> Json {
        // flat node array: leaf = [value]; split = [feat, thr, l, r]
        Json::Arr(
            self.nodes()
                .iter()
                .map(|n| match n {
                    Node::Leaf { value } => Json::Arr(vec![(*value).into()]),
                    Node::Split { feature, threshold, left, right } => Json::Arr(vec![
                        (*feature as u64).into(),
                        (*threshold as f64).into(),
                        (*left as u64).into(),
                        (*right as u64).into(),
                    ]),
                })
                .collect(),
        )
    }

    fn from_json(j: &Json) -> anyhow::Result<Tree> {
        let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("tree must be array"))?;
        let mut nodes = Vec::with_capacity(arr.len());
        for n in arr {
            let parts = n.as_arr().ok_or_else(|| anyhow::anyhow!("node must be array"))?;
            match parts.len() {
                1 => nodes.push(Node::Leaf {
                    value: parts[0].as_f64().ok_or_else(|| anyhow::anyhow!("leaf value"))?,
                }),
                4 => nodes.push(Node::Split {
                    feature: parts[0].as_u64().unwrap_or(0) as u32,
                    threshold: parts[1].as_f64().unwrap_or(0.0) as f32,
                    left: parts[2].as_u64().unwrap_or(0) as u32,
                    right: parts[3].as_u64().unwrap_or(0) as u32,
                }),
                k => anyhow::bail!("node arity {k}"),
            }
        }
        Ok(Tree::from_nodes(nodes))
    }
}

#[cfg(test)]
mod tests {
    use super::super::Matrix;
    use super::*;
    use crate::util::Rng;

    #[test]
    fn save_load_preserves_predictions() {
        let mut rng = Rng::seed_from_u64(1);
        let n = 400;
        let data: Vec<f32> = (0..n * 8).map(|_| rng.gen_f64() as f32).collect();
        let x = Matrix::new(n, 8, data);
        let y: Vec<f64> =
            (0..n).map(|i| x.row(i)[0] as f64 * 3.0 - x.row(i)[3] as f64).collect();
        let m = Gbt::train(&x, &y, &[], GbtParams { n_trees: 15, ..Default::default() });
        let dir = std::env::temp_dir().join("autotvm-gbt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        m.save(&path).unwrap();
        let back = Gbt::load(&path).unwrap();
        let p1 = m.predict_batch(&x);
        let p2 = back.predict_batch(&x);
        for (a, b) in p1.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(Gbt::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(Gbt::from_json(
            &Json::parse(r#"{"objective":"rank","eta":0.3,"base":0,"trees":[[1,2]]}"#).unwrap()
        )
        .is_err());
    }
}
