//! The paper's evaluation workloads: Table 1 conv2d configs C1–C12,
//! Matmul-1024 (the transfer-across-op-types target of Fig. 9), and the
//! five end-to-end networks of Fig. 11 (ResNet-18, MobileNet, DQN,
//! LSTM-LM, DCGAN) as graphs.

use crate::expr::ops::{self, Conv2dParams};
use crate::graph::{Graph, OpKind};
use crate::schedule::template::{Task, TemplateKind};

/// Table 1: all conv2d operators of single-batch ResNet-18 inference.
/// (H, W, IC, OC, K, S); padding is K/2 for 3×3/7×7, 0 for 1×1.
pub const TABLE1: [(i64, i64, i64, i64, i64, i64); 12] = [
    (224, 224, 3, 64, 7, 2),    // C1
    (56, 56, 64, 64, 3, 1),     // C2
    (56, 56, 64, 64, 1, 1),     // C3
    (56, 56, 64, 128, 3, 2),    // C4
    (56, 56, 64, 128, 1, 2),    // C5
    (28, 28, 128, 128, 3, 1),   // C6
    (28, 28, 128, 256, 3, 2),   // C7
    (28, 28, 128, 256, 1, 2),   // C8
    (14, 14, 256, 256, 3, 1),   // C9
    (14, 14, 256, 512, 3, 2),   // C10
    (14, 14, 256, 512, 1, 2),   // C11
    (7, 7, 512, 512, 3, 1),     // C12
];

/// Conv2d params of workload `Cn` (1-based, as in the paper).
pub fn conv_workload(n: usize) -> Conv2dParams {
    assert!((1..=12).contains(&n), "workloads are C1..C12");
    let (h, w, ic, oc, k, s) = TABLE1[n - 1];
    Conv2dParams { n: 1, h, w, ic, oc, kh: k, kw: k, stride: s, pad: k / 2 }
}

/// Task for workload `Cn` under a template.
pub fn conv_task(n: usize, template: TemplateKind) -> Task {
    Task::new(ops::conv2d(conv_workload(n)), template)
}

/// Matmul-1024 — the cross-operator transfer target of Fig. 9.
pub fn matmul_1024_task(template: TemplateKind) -> Task {
    Task::new(ops::matmul(1024, 1024, 1024), template)
}

fn conv_out(p: Conv2dParams) -> (i64, i64, i64) {
    (p.oc, p.out_h(), p.out_w())
}

/// Add conv → relu to a graph, returning the relu id.
fn conv_relu(g: &mut Graph, name: &str, p: Conv2dParams, input: usize) -> usize {
    let c = g.add(format!("{name}"), OpKind::Conv2d(p), &[input]);
    let (oc, oh, ow) = conv_out(p);
    g.add(format!("{name}.relu"), OpKind::Relu { shape: vec![1, oc, oh, ow] }, &[c])
}

/// A ResNet basic block: two 3×3 convs + residual.
fn basic_block(
    g: &mut Graph,
    name: &str,
    input: usize,
    main1: Conv2dParams,
    main2: Conv2dParams,
    downsample: Option<Conv2dParams>,
) -> usize {
    let r1 = conv_relu(g, &format!("{name}.conv1"), main1, input);
    let c2 = g.add(format!("{name}.conv2"), OpKind::Conv2d(main2), &[r1]);
    let shortcut = match downsample {
        Some(dp) => g.add(format!("{name}.down"), OpKind::Conv2d(dp), &[input]),
        None => input,
    };
    let (oc, oh, ow) = conv_out(main2);
    let shape = vec![1, oc, oh, ow];
    let add = g.add(format!("{name}.add"), OpKind::Add { shape: shape.clone() }, &[c2, shortcut]);
    g.add(format!("{name}.relu"), OpKind::Relu { shape }, &[add])
}

/// Single-batch ResNet-18 (BN folded into convs). Its distinct convs
/// are exactly Table 1's C1–C12.
pub fn resnet18() -> Graph {
    let mut g = Graph::new("resnet18");
    let input = g.add("data", OpKind::Input { shape: vec![1, 3, 224, 224] }, &[]);
    let stem = conv_relu(&mut g, "stem", conv_workload(1), input); // C1
    let pool =
        g.add("pool0", OpKind::MaxPool { n: 1, c: 64, h: 112, w: 112, k: 2, s: 2 }, &[stem]);
    // layer1: 2 × [C2, C2]
    let c2 = conv_workload(2);
    let b1 = basic_block(&mut g, "layer1.0", pool, c2, c2, None);
    let b2 = basic_block(&mut g, "layer1.1", b1, c2, c2, None);
    // layer2: [C4, C6, down C5], [C6, C6]
    let b3 = basic_block(
        &mut g, "layer2.0", b2, conv_workload(4), conv_workload(6), Some(conv_workload(5)),
    );
    let b4 = basic_block(&mut g, "layer2.1", b3, conv_workload(6), conv_workload(6), None);
    // layer3: [C7, C9, down C8], [C9, C9]
    let b5 = basic_block(
        &mut g, "layer3.0", b4, conv_workload(7), conv_workload(9), Some(conv_workload(8)),
    );
    let b6 = basic_block(&mut g, "layer3.1", b5, conv_workload(9), conv_workload(9), None);
    // layer4: [C10, C12, down C11], [C12, C12]
    let b7 = basic_block(
        &mut g, "layer4.0", b6, conv_workload(10), conv_workload(12), Some(conv_workload(11)),
    );
    let b8 = basic_block(&mut g, "layer4.1", b7, conv_workload(12), conv_workload(12), None);
    let gap = g.add("gap", OpKind::Reduce { shape: vec![1, 512, 7, 7] }, &[b8]);
    g.add("fc", OpKind::Dense { batch: 1, out_dim: 1000, in_dim: 512 }, &[gap]);
    // C3 (the 1×1 56×56 64→64 conv) appears in torchvision's conv
    // inventory via the projection variant; include one instance so the
    // task set matches Table 1 exactly.
    let _aux = g.add("proj.c3", OpKind::Conv2d(conv_workload(3)), &[pool]);
    g
}

/// MobileNet v1 (width 1.0, 224): depthwise-separable stacks.
pub fn mobilenet() -> Graph {
    let mut g = Graph::new("mobilenet");
    let input = g.add("data", OpKind::Input { shape: vec![1, 3, 224, 224] }, &[]);
    let stem = Conv2dParams { n: 1, h: 224, w: 224, ic: 3, oc: 32, kh: 3, kw: 3, stride: 2, pad: 1 };
    let mut cur = conv_relu(&mut g, "stem", stem, input);
    // (in_ch, out_ch, stride) of each dw+pw pair
    let cfg: [(i64, i64, i64); 13] = [
        (32, 64, 1), (64, 128, 2), (128, 128, 1), (128, 256, 2), (256, 256, 1),
        (256, 512, 2), (512, 512, 1), (512, 512, 1), (512, 512, 1), (512, 512, 1),
        (512, 512, 1), (512, 1024, 2), (1024, 1024, 1),
    ];
    let mut h = 112i64;
    for (i, (ic, oc, s)) in cfg.iter().enumerate() {
        let dw = Conv2dParams {
            n: 1, h, w: h, ic: *ic, oc: *ic, kh: 3, kw: 3, stride: *s, pad: 1,
        };
        let d = g.add(format!("dw{i}"), OpKind::DepthwiseConv2d(dw), &[cur]);
        h = dw.out_h();
        let rd = g.add(
            format!("dw{i}.relu"),
            OpKind::Relu { shape: vec![1, *ic, h, h] },
            &[d],
        );
        let pw = Conv2dParams {
            n: 1, h, w: h, ic: *ic, oc: *oc, kh: 1, kw: 1, stride: 1, pad: 0,
        };
        cur = conv_relu(&mut g, &format!("pw{i}"), pw, rd);
    }
    let gap = g.add("gap", OpKind::Reduce { shape: vec![1, 1024, 7, 7] }, &[cur]);
    g.add("fc", OpKind::Dense { batch: 1, out_dim: 1000, in_dim: 1024 }, &[gap]);
    g
}

/// Deep Q Network (Mnih et al. [27]): Atari head.
pub fn dqn() -> Graph {
    let mut g = Graph::new("dqn");
    let input = g.add("data", OpKind::Input { shape: vec![1, 4, 84, 84] }, &[]);
    let c1 = Conv2dParams { n: 1, h: 84, w: 84, ic: 4, oc: 32, kh: 8, kw: 8, stride: 4, pad: 0 };
    let r1 = conv_relu(&mut g, "conv1", c1, input);
    let c2 = Conv2dParams { n: 1, h: 20, w: 20, ic: 32, oc: 64, kh: 4, kw: 4, stride: 2, pad: 0 };
    let r2 = conv_relu(&mut g, "conv2", c2, r1);
    let c3 = Conv2dParams { n: 1, h: 9, w: 9, ic: 64, oc: 64, kh: 3, kw: 3, stride: 1, pad: 0 };
    let r3 = conv_relu(&mut g, "conv3", c3, r2);
    let f1 = g.add("fc1", OpKind::Dense { batch: 1, out_dim: 512, in_dim: 64 * 7 * 7 }, &[r3]);
    let rf = g.add("fc1.relu", OpKind::Relu { shape: vec![1, 512] }, &[f1]);
    g.add("fc2", OpKind::Dense { batch: 1, out_dim: 18, in_dim: 512 }, &[rf]);
    g
}

/// LSTM language model (Zaremba et al. [44], medium: 2×650): one
/// decoding step, gates expressed as dense ops.
pub fn lstm_lm() -> Graph {
    let mut g = Graph::new("lstm");
    let input = g.add("data", OpKind::Input { shape: vec![1, 650] }, &[]);
    let mut cur = input;
    for layer in 0..2 {
        // input and hidden projections to the 4 gates (4*650 = 2600)
        let wi = g.add(
            format!("l{layer}.wx"),
            OpKind::Dense { batch: 1, out_dim: 2600, in_dim: 650 },
            &[cur],
        );
        let wh = g.add(
            format!("l{layer}.wh"),
            OpKind::Dense { batch: 1, out_dim: 2600, in_dim: 650 },
            &[cur],
        );
        let add = g.add(
            format!("l{layer}.gates"),
            OpKind::Add { shape: vec![1, 2600] },
            &[wi, wh],
        );
        cur = g.add(
            format!("l{layer}.act"),
            OpKind::Relu { shape: vec![1, 2600] },
            &[add],
        );
    }
    g.add("proj", OpKind::Dense { batch: 1, out_dim: 10000, in_dim: 650 }, &[cur]);
    g
}

/// DCGAN generator (Radford et al. [31]). Transposed convolutions are
/// modeled as stride-1 convs on the upsampled feature map (identical
/// MAC count and access structure; DESIGN.md §Substitution).
pub fn dcgan() -> Graph {
    let mut g = Graph::new("dcgan");
    let input = g.add("z", OpKind::Input { shape: vec![1, 100] }, &[]);
    let fc = g.add("proj", OpKind::Dense { batch: 1, out_dim: 4 * 4 * 512, in_dim: 100 }, &[input]);
    let mut cur = g.add("proj.relu", OpKind::Relu { shape: vec![1, 8192] }, &[fc]);
    let stages: [(i64, i64, i64); 4] =
        [(8, 512, 256), (16, 256, 128), (32, 128, 64), (64, 64, 3)];
    for (i, (h, ic, oc)) in stages.iter().enumerate() {
        let p = Conv2dParams {
            n: 1, h: *h, w: *h, ic: *ic, oc: *oc, kh: 3, kw: 3, stride: 1, pad: 1,
        };
        cur = conv_relu(&mut g, &format!("up{i}"), p, cur);
    }
    g
}

/// All Fig. 11 networks.
pub fn all_networks() -> Vec<Graph> {
    vec![resnet18(), mobilenet(), dqn(), lstm_lm(), dcgan()]
}

/// Look up a Fig. 11 network by CLI name
/// (`resnet18|mobilenet|dqn|lstm|dcgan`).
pub fn network(name: &str) -> Option<Graph> {
    match name {
        "resnet18" => Some(resnet18()),
        "mobilenet" => Some(mobilenet()),
        "dqn" => Some(dqn()),
        "lstm" => Some(lstm_lm()),
        "dcgan" => Some(dcgan()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        // spot-check C1, C6, C12 against Table 1
        let c1 = conv_workload(1);
        assert_eq!((c1.h, c1.ic, c1.oc, c1.kh, c1.stride), (224, 3, 64, 7, 2));
        let c6 = conv_workload(6);
        assert_eq!((c6.h, c6.ic, c6.oc, c6.kh, c6.stride), (28, 128, 128, 3, 1));
        let c12 = conv_workload(12);
        assert_eq!((c12.h, c12.ic, c12.oc, c12.kh, c12.stride), (7, 512, 512, 3, 1));
    }

    #[test]
    fn print_table1() {
        // regenerates Table 1 (run with --nocapture)
        println!("| workload | H,W | IC,OC | K,S |");
        for i in 1..=12 {
            let p = conv_workload(i);
            println!(
                "| C{i} | {},{} | {},{} | {},{} |",
                p.h, p.w, p.ic, p.oc, p.kh, p.stride
            );
        }
    }

    #[test]
    fn resnet18_tasks_are_exactly_table1_plus_dense() {
        let g = resnet18();
        let tasks = g.tasks(TemplateKind::Gpu);
        let conv_tasks: Vec<_> =
            tasks.iter().filter(|t| t.def.name.starts_with("conv2d")).collect();
        assert_eq!(conv_tasks.len(), 12, "ResNet-18 must contain C1..C12");
        // every Table-1 workload appears
        for i in 1..=12 {
            let key = crate::expr::ops::conv2d(conv_workload(i)).task_key();
            assert!(
                conv_tasks.iter().any(|t| t.def.task_key() == key),
                "C{i} missing from resnet18 tasks"
            );
        }
    }

    #[test]
    fn networks_build_and_have_flops() {
        for net in all_networks() {
            let mut flops = 0u64;
            for n in &net.nodes {
                if let Some(def) = n.op.compute(None) {
                    flops += def.total_flops();
                }
            }
            assert!(flops > 1_000_000, "{} too small: {flops}", net.name);
        }
    }

    #[test]
    fn mobilenet_has_depthwise_tasks() {
        let g = mobilenet();
        let tasks = g.tasks(TemplateKind::Cpu);
        assert!(tasks.iter().any(|t| t.def.name.starts_with("dwconv2d")));
        // 13 dw convs but only distinct shapes dedupe
        assert!(tasks.len() >= 10 && tasks.len() <= 30, "{}", tasks.len());
    }

    #[test]
    fn fusion_reduces_resnet_node_count() {
        let g = resnet18();
        let f = g.fuse();
        assert!(f.nodes.len() < g.nodes.len());
    }
}
