//! `autotvm` CLI — the L3 coordinator binary.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = autotvm::coordinator::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
