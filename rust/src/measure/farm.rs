//! Device-farm measurement and failure injection.
//!
//! The paper's system measures batches on a farm of boards behind an
//! RPC tracker; boards flake, time out and return build errors, and the
//! tuner must absorb that. [`DeviceFarm`] reproduces the farm semantics
//! (a batch is sharded round-robin across device replicas and measured
//! concurrently); [`FlakyMeasurer`] injects seeded failures into any
//! back-end so tests can assert the tuning loop is robust to them.

use super::{MeasureResult, Measurer, SimMeasurer};
use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use crate::util::Rng;
use std::sync::Mutex;

/// A farm of simulated boards of the same device type.
pub struct DeviceFarm {
    /// The simulated boards, each with its own noise stream.
    pub replicas: Vec<SimMeasurer>,
    /// Per-candidate board latency (RPC round-trip + kernel run time of
    /// the paper's remote farm). Zero by default; benches and the
    /// pipelined-tuner tests use it to emulate slow hardware that the
    /// exploration and model stages should hide behind.
    pub latency: std::time::Duration,
}

impl DeviceFarm {
    /// `n` boards of the given device model (distinct noise streams —
    /// real boards differ run to run).
    pub fn new(device: crate::sim::DeviceModel, n: usize, seed: u64) -> Self {
        let replicas = (0..n)
            .map(|i| SimMeasurer::with_seed(device.clone(), seed.wrapping_add(i as u64 * 1_000_003)))
            .collect();
        DeviceFarm { replicas, latency: std::time::Duration::ZERO }
    }

    /// Farm whose boards take `latency` wall-clock per measurement on
    /// top of the simulated kernel time.
    pub fn with_latency(
        device: crate::sim::DeviceModel,
        n: usize,
        seed: u64,
        latency: std::time::Duration,
    ) -> Self {
        let mut farm = DeviceFarm::new(device, n, seed);
        farm.latency = latency;
        farm
    }
}

impl Measurer for DeviceFarm {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        let n = self.replicas.len().max(1);
        // shard round-robin, measure shards concurrently, then reassemble
        let shards: Vec<Vec<(usize, ConfigEntity)>> = (0..n)
            .map(|r| {
                batch
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % n == r)
                    .map(|(i, e)| (i, e.clone()))
                    .collect()
            })
            .collect();
        let mut out: Vec<Option<MeasureResult>> = vec![None; batch.len()];
        let latency = self.latency;
        let results: Vec<Vec<(usize, MeasureResult)>> = std::thread::scope(|s| {
            let handles: Vec<_> = shards
                .iter()
                .zip(&self.replicas)
                .map(|(shard, replica)| {
                    s.spawn(move || {
                        let entities: Vec<ConfigEntity> =
                            shard.iter().map(|(_, e)| e.clone()).collect();
                        if !latency.is_zero() && !entities.is_empty() {
                            std::thread::sleep(latency * entities.len() as u32);
                        }
                        let rs = replica.measure(task, &entities);
                        shard
                            .iter()
                            .map(|(i, _)| *i)
                            .zip(rs)
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("farm worker")).collect()
        });
        for shard in results {
            for (i, r) in shard {
                out[i] = Some(r);
            }
        }
        out.into_iter().map(|r| r.expect("all shards returned")).collect()
    }

    fn target(&self) -> String {
        format!(
            "farm({}x{})",
            self.replicas.len(),
            self.replicas.first().map(|r| r.device.name).unwrap_or("?")
        )
    }
}

/// Failure-injecting wrapper: with probability `fail_prob` a
/// measurement is replaced by a board error (timeout / crash).
pub struct FlakyMeasurer<M: Measurer> {
    /// The wrapped back-end.
    pub inner: M,
    /// Per-candidate failure probability.
    pub fail_prob: f64,
    rng: Mutex<Rng>,
}

impl<M: Measurer> FlakyMeasurer<M> {
    /// Wrap `inner`, failing each candidate with probability `fail_prob`.
    pub fn new(inner: M, fail_prob: f64, seed: u64) -> Self {
        FlakyMeasurer { inner, fail_prob, rng: Mutex::new(Rng::seed_from_u64(seed)) }
    }
}

impl<M: Measurer> Measurer for FlakyMeasurer<M> {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        let results = self.inner.measure(task, batch);
        let mut rng = self.rng.lock().unwrap();
        results
            .into_iter()
            .map(|r| {
                if rng.gen_bool(self.fail_prob) {
                    MeasureResult::err("injected: board timeout")
                } else {
                    r
                }
            })
            .collect()
    }

    fn target(&self) -> String {
        format!("flaky({})", self.inner.target())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::schedule::template::TemplateKind;
    use crate::sim::devices::sim_gpu;

    #[test]
    fn farm_preserves_batch_order_and_results() {
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(4);
        let batch: Vec<ConfigEntity> =
            (0..24).map(|_| task.space.sample(&mut rng)).collect();
        let farm = DeviceFarm::new(sim_gpu(), 4, 7);
        let rs = farm.measure(&task, &batch);
        assert_eq!(rs.len(), batch.len());
        // noise-free comparison: each result must match a direct
        // evaluate() of the same entity up to the lognormal noise bound
        let dev = sim_gpu();
        for (e, r) in batch.iter().zip(&rs) {
            if let Some(secs) = r.seconds {
                let base = dev.evaluate(&task.lower(e).unwrap()).unwrap().seconds;
                assert!((secs / base).ln().abs() < 0.5, "order scrambled?");
            }
        }
    }

    #[test]
    fn flaky_injects_failures_at_rate() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(5);
        let batch: Vec<ConfigEntity> =
            (0..200).map(|_| task.space.sample(&mut rng)).collect();
        let m = FlakyMeasurer::new(SimMeasurer::with_seed(sim_gpu(), 1), 0.3, 9);
        let rs = m.measure(&task, &batch);
        let failures = rs.iter().filter(|r| !r.is_ok()).count();
        assert!((30..100).contains(&failures), "failure count {failures}");
    }

    #[test]
    fn tuner_survives_flaky_farm() {
        // end-to-end: 20% failure rate must not stop the search from
        // improving (the paper's system records errors as 0 GFLOPS and
        // keeps going)
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let farm = DeviceFarm::new(sim_gpu(), 3, 2);
        let flaky = FlakyMeasurer::new(farm, 0.2, 3);
        let o = crate::tuner::TuneOptions {
            n_trials: 96,
            batch: 32,
            sa: crate::explore::SaParams { n_chains: 16, n_steps: 30, ..Default::default() },
            ..Default::default()
        };
        let res = crate::tuner::tune_gbt(task, &flaky, o);
        assert!(res.best_gflops() > 0.0);
        assert!(res.records.iter().any(|r| r.error.is_some()), "no failures recorded");
        assert!(
            res.best_at(96) >= res.best_at(32),
            "search failed to improve under failures"
        );
    }
}
