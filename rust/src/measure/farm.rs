//! Device-farm measurement and failure injection.
//!
//! The paper's system measures batches on a farm of boards behind an
//! RPC tracker; boards flake, time out and return build errors, and the
//! tuner must absorb that. [`DeviceFarm`] reproduces the farm semantics
//! two ways: as a [`Measurer`] (a batch is sharded round-robin across
//! device replicas and measured concurrently — the original in-place
//! farm) and as the sim-backed [`MeasurerFactory`] behind the
//! asynchronous [`MeasureService`] (each service worker builds its own
//! per-replica board, with the farm's RTT and flakiness applied
//! per-board). [`HeteroFarm`] generalizes the factory path to a
//! *heterogeneous* fleet: several [`BoardClass`]es with distinct
//! perf/noise/RTT/flakiness profiles behind one factory, each board
//! advertising its device via [`MeasurerFactory::target_of`] so the
//! service can dispatch class-aware. [`FlakyMeasurer`] injects seeded
//! failures into any back-end and [`LatencyMeasurer`] adds
//! per-candidate round-trip latency, so tests and benches can emulate
//! slow, unreliable fleets.
//!
//! [`MeasureService`]: super::service::MeasureService
//! [`MeasurerFactory`]: super::service::MeasurerFactory

use super::service::MeasurerFactory;
use super::{MeasureResult, Measurer, SimMeasurer};
use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use crate::util::Rng;
use std::sync::Mutex;
use std::time::Duration;

/// Decorrelated per-replica noise seed (real boards differ run to run).
fn replica_seed(base: u64, replica: usize) -> u64 {
    base.wrapping_add(replica as u64 * 1_000_003)
}

/// Decorrelated per-class seed base. Class 0 maps to `base` unchanged,
/// so a single-class [`HeteroFarm`] reproduces a [`DeviceFarm`] with
/// the same seed bit-for-bit — and resizing class `k` never perturbs
/// the noise streams of any other class.
fn class_seed(base: u64, class: usize) -> u64 {
    base.wrapping_add(class as u64 * 0x9E37_79B9_7F4A_7C15)
}

/// A farm of simulated boards of the same device type.
pub struct DeviceFarm {
    /// The simulated boards, each with its own noise stream and wrapped
    /// with the farm's RTT ([`LatencyMeasurer`] is the single home of
    /// the latency semantics). These serve the in-place [`Measurer`]
    /// path; the [`MeasurerFactory`] path builds fresh boards with the
    /// same per-replica seeds on the service's worker threads.
    pub replicas: Vec<LatencyMeasurer<SimMeasurer>>,
    /// Per-candidate board latency (RPC round-trip + kernel run time of
    /// the paper's remote farm). Zero by default; benches and the
    /// pipelined-tuner tests use it to emulate slow hardware that the
    /// exploration and model stages should hide behind.
    pub latency: Duration,
    /// Per-candidate board failure probability, applied per replica on
    /// the factory path (the in-place [`Measurer`] path stays
    /// failure-free; wrap it in [`FlakyMeasurer`] instead).
    pub fail_prob: f64,
    device: crate::sim::DeviceModel,
    base_seed: u64,
}

impl DeviceFarm {
    /// `n` boards of the given device model (distinct noise streams —
    /// real boards differ run to run).
    pub fn new(device: crate::sim::DeviceModel, n: usize, seed: u64) -> Self {
        let replicas = (0..n)
            .map(|i| LatencyMeasurer {
                inner: SimMeasurer::with_seed(device.clone(), replica_seed(seed, i)),
                latency: Duration::ZERO,
            })
            .collect();
        DeviceFarm {
            replicas,
            latency: Duration::ZERO,
            fail_prob: 0.0,
            device,
            base_seed: seed,
        }
    }

    /// Farm whose boards take `latency` wall-clock per measurement on
    /// top of the simulated kernel time.
    pub fn with_latency(
        device: crate::sim::DeviceModel,
        n: usize,
        seed: u64,
        latency: Duration,
    ) -> Self {
        let mut farm = DeviceFarm::new(device, n, seed);
        farm.latency = latency;
        for board in &mut farm.replicas {
            board.latency = latency;
        }
        farm
    }

    /// Builder: boards flake with probability `fail_prob` per candidate
    /// on the [`MeasurerFactory`] path (seeded per replica).
    pub fn with_flakiness(mut self, fail_prob: f64) -> Self {
        self.fail_prob = fail_prob;
        self
    }
}

impl MeasurerFactory for DeviceFarm {
    fn make(&self, replica: usize) -> anyhow::Result<Box<dyn Measurer>> {
        let board = LatencyMeasurer {
            inner: SimMeasurer::with_seed(
                self.device.clone(),
                replica_seed(self.base_seed, replica),
            ),
            latency: self.latency,
        };
        Ok(if self.fail_prob > 0.0 {
            Box::new(FlakyMeasurer::new(
                board,
                self.fail_prob,
                replica_seed(self.base_seed ^ 0x5EED_F1A2, replica),
            ))
        } else {
            Box::new(board)
        })
    }

    fn replicas(&self) -> usize {
        self.replicas.len().max(1)
    }

    fn board(&self) -> String {
        self.device.name.to_string()
    }
}

/// One class of boards in a heterogeneous fleet: a device model plus
/// the class's own replica count, RTT and flakiness profile. Real
/// fleets mix low-power CPUs, mobile GPUs and server GPUs with very
/// different perf/noise/latency characteristics — a [`HeteroFarm`] is a
/// list of these.
#[derive(Clone)]
pub struct BoardClass {
    /// The simulated device every board of this class measures on
    /// (its `noise_sigma` is the class's noise profile).
    pub device: crate::sim::DeviceModel,
    /// Boards of this class in the fleet.
    pub replicas: usize,
    /// Per-candidate RPC round-trip of this class's boards.
    pub latency: Duration,
    /// Per-candidate failure probability of this class's boards.
    pub fail_prob: f64,
}

impl BoardClass {
    /// `replicas` reliable, zero-RTT boards of `device`.
    pub fn new(device: crate::sim::DeviceModel, replicas: usize) -> Self {
        BoardClass { device, replicas, latency: Duration::ZERO, fail_prob: 0.0 }
    }

    /// Builder: per-candidate RTT of this class's boards.
    pub fn with_latency(mut self, latency: Duration) -> Self {
        self.latency = latency;
        self
    }

    /// Builder: per-candidate failure probability of this class's
    /// boards (seeded per board on the factory path).
    pub fn with_flakiness(mut self, fail_prob: f64) -> Self {
        self.fail_prob = fail_prob;
        self
    }
}

/// A heterogeneous device fleet: several [`BoardClass`]es behind one
/// [`MeasurerFactory`]. Global replica indices are assigned
/// contiguously class by class (class 0 gets `0..n0`, class 1 gets
/// `n0..n0+n1`, …), each board draws its noise stream from a
/// class-local seed base ([`class_seed`]), and
/// [`MeasurerFactory::target_of`] reports each board's device name —
/// the hook the [`MeasureService`] uses for class-aware dispatch, so a
/// job submitted for target T only ever lands on boards serving T.
///
/// [`MeasureService`]: super::service::MeasureService
pub struct HeteroFarm {
    classes: Vec<BoardClass>,
    base_seed: u64,
}

impl HeteroFarm {
    /// Fleet of the given classes (at least one, each with at least one
    /// board — a fleet advertising a target it cannot serve would turn
    /// every job for that target into an immediate error).
    pub fn new(classes: Vec<BoardClass>, seed: u64) -> Self {
        assert!(!classes.is_empty(), "heterogeneous farm needs at least one class");
        assert!(
            classes.iter().all(|c| c.replicas > 0),
            "every board class needs at least one replica"
        );
        HeteroFarm { classes, base_seed: seed }
    }

    /// The fleet's classes, in replica-index order.
    pub fn classes(&self) -> &[BoardClass] {
        &self.classes
    }

    /// `(class index, index within class)` of a global replica index.
    fn locate(&self, replica: usize) -> (usize, usize) {
        let mut offset = 0;
        for (ci, c) in self.classes.iter().enumerate() {
            if replica < offset + c.replicas {
                return (ci, replica - offset);
            }
            offset += c.replicas;
        }
        panic!("replica {replica} out of range for {}-board fleet", offset);
    }
}

impl MeasurerFactory for HeteroFarm {
    fn make(&self, replica: usize) -> anyhow::Result<Box<dyn Measurer>> {
        let (ci, within) = self.locate(replica);
        let class = &self.classes[ci];
        let seed_base = class_seed(self.base_seed, ci);
        let board = LatencyMeasurer {
            inner: SimMeasurer::with_seed(class.device.clone(), replica_seed(seed_base, within)),
            latency: class.latency,
        };
        Ok(if class.fail_prob > 0.0 {
            Box::new(FlakyMeasurer::new(
                board,
                class.fail_prob,
                replica_seed(seed_base ^ 0x5EED_F1A2, within),
            ))
        } else {
            Box::new(board)
        })
    }

    fn replicas(&self) -> usize {
        self.classes.iter().map(|c| c.replicas).sum::<usize>().max(1)
    }

    fn board(&self) -> String {
        self.classes[0].device.name.to_string()
    }

    fn target_of(&self, replica: usize) -> String {
        let (ci, _) = self.locate(replica);
        self.classes[ci].device.name.to_string()
    }
}

/// Wrap a back-end with per-candidate round-trip latency — the RPC +
/// run time of one remote board in the paper's farm.
pub struct LatencyMeasurer<M: Measurer> {
    /// The wrapped back-end.
    pub inner: M,
    /// Sleep per candidate before measuring.
    pub latency: Duration,
}

impl<M: Measurer> Measurer for LatencyMeasurer<M> {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        if !self.latency.is_zero() && !batch.is_empty() {
            std::thread::sleep(self.latency * batch.len() as u32);
        }
        self.inner.measure(task, batch)
    }

    fn target(&self) -> String {
        self.inner.target()
    }
}

impl Measurer for DeviceFarm {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        let n = self.replicas.len().max(1);
        // shard round-robin, measure shards concurrently, then reassemble
        let shards: Vec<Vec<(usize, ConfigEntity)>> = (0..n)
            .map(|r| {
                batch
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % n == r)
                    .map(|(i, e)| (i, e.clone()))
                    .collect()
            })
            .collect();
        let mut out: Vec<Option<MeasureResult>> = vec![None; batch.len()];
        let results: Vec<Vec<(usize, MeasureResult)>> = std::thread::scope(|s| {
            let handles: Vec<_> = shards
                .iter()
                .zip(&self.replicas)
                .map(|(shard, replica)| {
                    s.spawn(move || {
                        let entities: Vec<ConfigEntity> =
                            shard.iter().map(|(_, e)| e.clone()).collect();
                        // the board itself is RTT-wrapped (LatencyMeasurer)
                        let rs = replica.measure(task, &entities);
                        shard
                            .iter()
                            .map(|(i, _)| *i)
                            .zip(rs)
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("farm worker")).collect()
        });
        for shard in results {
            for (i, r) in shard {
                out[i] = Some(r);
            }
        }
        out.into_iter().map(|r| r.expect("all shards returned")).collect()
    }

    fn target(&self) -> String {
        format!("farm({}x{})", self.replicas.len(), self.device.name)
    }
}

/// Failure-injecting wrapper: with probability `fail_prob` a
/// measurement is replaced by a board error (timeout / crash).
pub struct FlakyMeasurer<M: Measurer> {
    /// The wrapped back-end.
    pub inner: M,
    /// Per-candidate failure probability.
    pub fail_prob: f64,
    rng: Mutex<Rng>,
}

impl<M: Measurer> FlakyMeasurer<M> {
    /// Wrap `inner`, failing each candidate with probability `fail_prob`.
    pub fn new(inner: M, fail_prob: f64, seed: u64) -> Self {
        FlakyMeasurer { inner, fail_prob, rng: Mutex::new(Rng::seed_from_u64(seed)) }
    }
}

impl<M: Measurer> Measurer for FlakyMeasurer<M> {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        let results = self.inner.measure(task, batch);
        let mut rng = self.rng.lock().unwrap();
        results
            .into_iter()
            .map(|r| {
                if rng.gen_bool(self.fail_prob) {
                    MeasureResult::err("injected: board timeout")
                } else {
                    r
                }
            })
            .collect()
    }

    fn target(&self) -> String {
        format!("flaky({})", self.inner.target())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::schedule::template::TemplateKind;
    use crate::sim::devices::sim_gpu;

    #[test]
    fn farm_preserves_batch_order_and_results() {
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(4);
        let batch: Vec<ConfigEntity> =
            (0..24).map(|_| task.space.sample(&mut rng)).collect();
        let farm = DeviceFarm::new(sim_gpu(), 4, 7);
        let rs = farm.measure(&task, &batch);
        assert_eq!(rs.len(), batch.len());
        // noise-free comparison: each result must match a direct
        // evaluate() of the same entity up to the lognormal noise bound
        let dev = sim_gpu();
        for (e, r) in batch.iter().zip(&rs) {
            if let Some(secs) = r.seconds {
                let base = dev.evaluate(&task.lower(e).unwrap()).unwrap().seconds;
                assert!((secs / base).ln().abs() < 0.5, "order scrambled?");
            }
        }
    }

    #[test]
    fn flaky_injects_failures_at_rate() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(5);
        let batch: Vec<ConfigEntity> =
            (0..200).map(|_| task.space.sample(&mut rng)).collect();
        let m = FlakyMeasurer::new(SimMeasurer::with_seed(sim_gpu(), 1), 0.3, 9);
        let rs = m.measure(&task, &batch);
        let failures = rs.iter().filter(|r| !r.is_ok()).count();
        assert!((30..100).contains(&failures), "failure count {failures}");
    }

    #[test]
    fn single_class_hetero_farm_boards_match_device_farm() {
        // regression anchor: a one-class HeteroFarm hands out the exact
        // boards a DeviceFarm with the same seed would (class 0's seed
        // base is the farm seed unchanged)
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(11);
        let batch: Vec<ConfigEntity> =
            (0..16).map(|_| task.space.sample(&mut rng)).collect();
        let mono = DeviceFarm::new(sim_gpu(), 3, 42);
        let hetero = HeteroFarm::new(vec![BoardClass::new(sim_gpu(), 3)], 42);
        assert_eq!(mono.replicas(), hetero.replicas());
        assert_eq!(mono.board(), hetero.board());
        for r in 0..3 {
            let a = mono.make(r).unwrap().measure(&task, &batch);
            let b = hetero.make(r).unwrap().measure(&task, &batch);
            assert_eq!(hetero.target_of(r), "sim-gpu");
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.gflops, y.gflops);
                assert_eq!(x.error, y.error);
            }
        }
    }

    #[test]
    fn hetero_farm_maps_replicas_to_classes() {
        use crate::sim::devices::sim_cpu;
        let farm = HeteroFarm::new(
            vec![BoardClass::new(sim_cpu(), 2), BoardClass::new(sim_gpu(), 3)],
            7,
        );
        assert_eq!(farm.replicas(), 5);
        let targets: Vec<String> = (0..5).map(|r| farm.target_of(r)).collect();
        assert_eq!(targets, ["sim-cpu", "sim-cpu", "sim-gpu", "sim-gpu", "sim-gpu"]);
        // growing one class never perturbs another class's seed base:
        // replica 2 here (gpu board 0) matches gpu board 0 of a fleet
        // with a different cpu-class size
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(3);
        let batch: Vec<ConfigEntity> =
            (0..8).map(|_| task.space.sample(&mut rng)).collect();
        let farm2 = HeteroFarm::new(
            vec![BoardClass::new(sim_cpu(), 4), BoardClass::new(sim_gpu(), 3)],
            7,
        );
        let a = farm.make(2).unwrap().measure(&task, &batch);
        let b = farm2.make(4).unwrap().measure(&task, &batch);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.gflops, y.gflops);
        }
    }

    #[test]
    fn tuner_survives_flaky_farm() {
        // end-to-end: 20% failure rate must not stop the search from
        // improving (the paper's system records errors as 0 GFLOPS and
        // keeps going)
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let farm = DeviceFarm::new(sim_gpu(), 3, 2);
        let flaky = FlakyMeasurer::new(farm, 0.2, 3);
        let o = crate::tuner::TuneOptions {
            n_trials: 96,
            batch: 32,
            sa: crate::explore::SaParams { n_chains: 16, n_steps: 30, ..Default::default() },
            ..Default::default()
        };
        let res = crate::tuner::tune_gbt(task, &flaky, o);
        assert!(res.best_gflops() > 0.0);
        assert!(res.records.iter().any(|r| r.error.is_some()), "no failures recorded");
        assert!(
            res.best_at(96) >= res.best_at(32),
            "search failed to improve under failures"
        );
    }
}
