//! Device-farm measurement and failure injection.
//!
//! The paper's system measures batches on a farm of boards behind an
//! RPC tracker; boards flake, time out and return build errors, and the
//! tuner must absorb that. [`DeviceFarm`] reproduces the farm semantics
//! two ways: as a [`Measurer`] (a batch is sharded round-robin across
//! device replicas and measured concurrently — the original in-place
//! farm) and as the sim-backed [`MeasurerFactory`] behind the
//! asynchronous [`MeasureService`] (each service worker builds its own
//! per-replica board, with the farm's RTT and flakiness applied
//! per-board). [`FlakyMeasurer`] injects seeded failures into any
//! back-end and [`LatencyMeasurer`] adds per-candidate round-trip
//! latency, so tests and benches can emulate slow, unreliable fleets.
//!
//! [`MeasureService`]: super::service::MeasureService
//! [`MeasurerFactory`]: super::service::MeasurerFactory

use super::service::MeasurerFactory;
use super::{MeasureResult, Measurer, SimMeasurer};
use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use crate::util::Rng;
use std::sync::Mutex;
use std::time::Duration;

/// Decorrelated per-replica noise seed (real boards differ run to run).
fn replica_seed(base: u64, replica: usize) -> u64 {
    base.wrapping_add(replica as u64 * 1_000_003)
}

/// A farm of simulated boards of the same device type.
pub struct DeviceFarm {
    /// The simulated boards, each with its own noise stream and wrapped
    /// with the farm's RTT ([`LatencyMeasurer`] is the single home of
    /// the latency semantics). These serve the in-place [`Measurer`]
    /// path; the [`MeasurerFactory`] path builds fresh boards with the
    /// same per-replica seeds on the service's worker threads.
    pub replicas: Vec<LatencyMeasurer<SimMeasurer>>,
    /// Per-candidate board latency (RPC round-trip + kernel run time of
    /// the paper's remote farm). Zero by default; benches and the
    /// pipelined-tuner tests use it to emulate slow hardware that the
    /// exploration and model stages should hide behind.
    pub latency: Duration,
    /// Per-candidate board failure probability, applied per replica on
    /// the factory path (the in-place [`Measurer`] path stays
    /// failure-free; wrap it in [`FlakyMeasurer`] instead).
    pub fail_prob: f64,
    device: crate::sim::DeviceModel,
    base_seed: u64,
}

impl DeviceFarm {
    /// `n` boards of the given device model (distinct noise streams —
    /// real boards differ run to run).
    pub fn new(device: crate::sim::DeviceModel, n: usize, seed: u64) -> Self {
        let replicas = (0..n)
            .map(|i| LatencyMeasurer {
                inner: SimMeasurer::with_seed(device.clone(), replica_seed(seed, i)),
                latency: Duration::ZERO,
            })
            .collect();
        DeviceFarm {
            replicas,
            latency: Duration::ZERO,
            fail_prob: 0.0,
            device,
            base_seed: seed,
        }
    }

    /// Farm whose boards take `latency` wall-clock per measurement on
    /// top of the simulated kernel time.
    pub fn with_latency(
        device: crate::sim::DeviceModel,
        n: usize,
        seed: u64,
        latency: Duration,
    ) -> Self {
        let mut farm = DeviceFarm::new(device, n, seed);
        farm.latency = latency;
        for board in &mut farm.replicas {
            board.latency = latency;
        }
        farm
    }

    /// Builder: boards flake with probability `fail_prob` per candidate
    /// on the [`MeasurerFactory`] path (seeded per replica).
    pub fn with_flakiness(mut self, fail_prob: f64) -> Self {
        self.fail_prob = fail_prob;
        self
    }
}

impl MeasurerFactory for DeviceFarm {
    fn make(&self, replica: usize) -> anyhow::Result<Box<dyn Measurer>> {
        let board = LatencyMeasurer {
            inner: SimMeasurer::with_seed(
                self.device.clone(),
                replica_seed(self.base_seed, replica),
            ),
            latency: self.latency,
        };
        Ok(if self.fail_prob > 0.0 {
            Box::new(FlakyMeasurer::new(
                board,
                self.fail_prob,
                replica_seed(self.base_seed ^ 0x5EED_F1A2, replica),
            ))
        } else {
            Box::new(board)
        })
    }

    fn replicas(&self) -> usize {
        self.replicas.len().max(1)
    }

    fn board(&self) -> String {
        self.device.name.to_string()
    }
}

/// Wrap a back-end with per-candidate round-trip latency — the RPC +
/// run time of one remote board in the paper's farm.
pub struct LatencyMeasurer<M: Measurer> {
    /// The wrapped back-end.
    pub inner: M,
    /// Sleep per candidate before measuring.
    pub latency: Duration,
}

impl<M: Measurer> Measurer for LatencyMeasurer<M> {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        if !self.latency.is_zero() && !batch.is_empty() {
            std::thread::sleep(self.latency * batch.len() as u32);
        }
        self.inner.measure(task, batch)
    }

    fn target(&self) -> String {
        self.inner.target()
    }
}

impl Measurer for DeviceFarm {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        let n = self.replicas.len().max(1);
        // shard round-robin, measure shards concurrently, then reassemble
        let shards: Vec<Vec<(usize, ConfigEntity)>> = (0..n)
            .map(|r| {
                batch
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % n == r)
                    .map(|(i, e)| (i, e.clone()))
                    .collect()
            })
            .collect();
        let mut out: Vec<Option<MeasureResult>> = vec![None; batch.len()];
        let results: Vec<Vec<(usize, MeasureResult)>> = std::thread::scope(|s| {
            let handles: Vec<_> = shards
                .iter()
                .zip(&self.replicas)
                .map(|(shard, replica)| {
                    s.spawn(move || {
                        let entities: Vec<ConfigEntity> =
                            shard.iter().map(|(_, e)| e.clone()).collect();
                        // the board itself is RTT-wrapped (LatencyMeasurer)
                        let rs = replica.measure(task, &entities);
                        shard
                            .iter()
                            .map(|(i, _)| *i)
                            .zip(rs)
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("farm worker")).collect()
        });
        for shard in results {
            for (i, r) in shard {
                out[i] = Some(r);
            }
        }
        out.into_iter().map(|r| r.expect("all shards returned")).collect()
    }

    fn target(&self) -> String {
        format!("farm({}x{})", self.replicas.len(), self.device.name)
    }
}

/// Failure-injecting wrapper: with probability `fail_prob` a
/// measurement is replaced by a board error (timeout / crash).
pub struct FlakyMeasurer<M: Measurer> {
    /// The wrapped back-end.
    pub inner: M,
    /// Per-candidate failure probability.
    pub fail_prob: f64,
    rng: Mutex<Rng>,
}

impl<M: Measurer> FlakyMeasurer<M> {
    /// Wrap `inner`, failing each candidate with probability `fail_prob`.
    pub fn new(inner: M, fail_prob: f64, seed: u64) -> Self {
        FlakyMeasurer { inner, fail_prob, rng: Mutex::new(Rng::seed_from_u64(seed)) }
    }
}

impl<M: Measurer> Measurer for FlakyMeasurer<M> {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        let results = self.inner.measure(task, batch);
        let mut rng = self.rng.lock().unwrap();
        results
            .into_iter()
            .map(|r| {
                if rng.gen_bool(self.fail_prob) {
                    MeasureResult::err("injected: board timeout")
                } else {
                    r
                }
            })
            .collect()
    }

    fn target(&self) -> String {
        format!("flaky({})", self.inner.target())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::schedule::template::TemplateKind;
    use crate::sim::devices::sim_gpu;

    #[test]
    fn farm_preserves_batch_order_and_results() {
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(4);
        let batch: Vec<ConfigEntity> =
            (0..24).map(|_| task.space.sample(&mut rng)).collect();
        let farm = DeviceFarm::new(sim_gpu(), 4, 7);
        let rs = farm.measure(&task, &batch);
        assert_eq!(rs.len(), batch.len());
        // noise-free comparison: each result must match a direct
        // evaluate() of the same entity up to the lognormal noise bound
        let dev = sim_gpu();
        for (e, r) in batch.iter().zip(&rs) {
            if let Some(secs) = r.seconds {
                let base = dev.evaluate(&task.lower(e).unwrap()).unwrap().seconds;
                assert!((secs / base).ln().abs() < 0.5, "order scrambled?");
            }
        }
    }

    #[test]
    fn flaky_injects_failures_at_rate() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(5);
        let batch: Vec<ConfigEntity> =
            (0..200).map(|_| task.space.sample(&mut rng)).collect();
        let m = FlakyMeasurer::new(SimMeasurer::with_seed(sim_gpu(), 1), 0.3, 9);
        let rs = m.measure(&task, &batch);
        let failures = rs.iter().filter(|r| !r.is_ok()).count();
        assert!((30..100).contains(&failures), "failure count {failures}");
    }

    #[test]
    fn tuner_survives_flaky_farm() {
        // end-to-end: 20% failure rate must not stop the search from
        // improving (the paper's system records errors as 0 GFLOPS and
        // keeps going)
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let farm = DeviceFarm::new(sim_gpu(), 3, 2);
        let flaky = FlakyMeasurer::new(farm, 0.2, 3);
        let o = crate::tuner::TuneOptions {
            n_trials: 96,
            batch: 32,
            sa: crate::explore::SaParams { n_chains: 16, n_steps: 30, ..Default::default() },
            ..Default::default()
        };
        let res = crate::tuner::tune_gbt(task, &flaky, o);
        assert!(res.best_gflops() > 0.0);
        assert!(res.records.iter().any(|r| r.error.is_some()), "no failures recorded");
        assert!(
            res.best_at(96) >= res.best_at(32),
            "search failed to improve under failures"
        );
    }
}
