//! Asynchronous device-farm measurement service — the shared `f(x)`
//! back-end of every tuning loop.
//!
//! The paper's system (§4) measures trials on a distributed fleet of
//! boards behind an RPC tracker: jobs are sharded across replicas,
//! boards flake and time out, and the tuner keeps going. This module is
//! that fleet as a long-lived in-process **service**:
//!
//! ```text
//!   submit_batch ──▶ sequence-numbered jobs ──▶ per-replica workers
//!        │            (bounded in-flight:        (each builds its own
//!        │             backpressure)              Measurer on-thread
//!        │                                        via MeasurerFactory)
//!        ▼                                              │ events
//!   BatchTicket ◀── results keyed by seq ◀──────── monitor thread
//!   (wait_batch = results                       (timeout / retry /
//!    in submission order)                        quarantine policy)
//! ```
//!
//! * **Thread affinity** — [`Measurer`] is deliberately not `Send`
//!   (PJRT handles must stay on one thread). The service never moves a
//!   measurer across threads: each worker constructs its own through a
//!   [`MeasurerFactory`]; only the factory is shared.
//! * **Deterministic accounting** — every job carries a sequence
//!   number; job `seq` is dispatched to replica `seq % replicas`, and
//!   each worker processes its jobs in sequence order. A fixed-seed sim
//!   run is therefore bit-for-bit reproducible no matter how workers
//!   interleave in wall-clock time, and with one replica the service is
//!   bit-for-bit identical to calling the measurer directly. Results
//!   are handed back strictly in submission order
//!   ([`MeasureService::wait_batch`]), so the trial accountant
//!   downstream observes the same history every run.
//! * **Fault policy** — a worker panic, a measurer construction
//!   failure, or a per-job timeout is a *board* fault: the job is
//!   retried on a replica it has not been dispatched to (up to
//!   [`ServiceOptions::retries`] times; no untried replica ⇒ the job
//!   completes as an error rather than bouncing between broken
//!   boards), and a board accumulating consecutive faults is
//!   quarantined ([`ServiceOptions::quarantine_after`]). A timed-out
//!   board is additionally marked *suspect* — skipped for new
//!   dispatches until it answers again — and jobs queued behind the
//!   timed-out one are relocated immediately (the timeout clock only
//!   runs for started attempts, so queued jobs must not wait on a
//!   wedged board). A [`MeasureResult`] carrying an `error` is a
//!   *measurement* outcome (build error, resource-limit violation) —
//!   returned as-is, exactly like failed trials in the paper, and never
//!   retried. Retried jobs draw fresh measurement noise, so determinism
//!   bends only in runs that actually fault.
//! * **Backpressure** — at most [`ServiceOptions::max_inflight`] jobs
//!   may be in flight; [`MeasureService::submit_batch`] blocks past
//!   that, so a fast proposer cannot flood the farm.
//! * **Class-aware dispatch** — a heterogeneous fleet
//!   ([`HeteroFarm`](super::farm::HeteroFarm)) reports each replica's
//!   device through [`MeasurerFactory::target_of`];
//!   [`MeasureService::submit_batch_for`] (and the per-class
//!   [`TargetedMeasurer`] views from
//!   [`for_target`](MeasureService::for_target)) then restrict
//!   dispatch, retry, and relocation to boards serving the job's
//!   target. When no board of the class can accept work the job
//!   degrades to an error result — measuring on another class's board
//!   would produce numbers for the wrong device.
//!
//! The service implements [`Measurer`], so every loop (`serial_loop`,
//! the pipelined measure stage, graph-scheduler slices) runs through it
//! unchanged — and because it overrides the asynchronous
//! [`Measurer::submit`] / [`Measurer::wait`] pair, the pipelined
//! measure stage keeps batch `k+1` measuring on the farm while batch
//! `k`'s results drain into the accountant.

use super::{BatchTicket, MeasureResult, Measurer};
use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Builds one [`Measurer`] per farm replica, on the worker's own thread
/// (the factory crosses threads; the measurers it builds never do).
/// Implemented by [`DeviceFarm`](super::farm::DeviceFarm) for the
/// simulated fleet; a PJRT deployment would hand out one thread-affine
/// client per board here.
pub trait MeasurerFactory: Send + Sync {
    /// Construct the measurer of replica `replica`. Called on — and the
    /// result only ever used from — that replica's worker thread; called
    /// again to rebuild a measurer that panicked mid-job, and re-tried
    /// on the next job after a failure. A construction error (or panic)
    /// is a **board fault**: the job is retried on another replica and
    /// the broken board accumulates strikes toward quarantine, rather
    /// than burning trials on a board that cannot measure.
    fn make(&self, replica: usize) -> anyhow::Result<Box<dyn Measurer>>;

    /// Number of replicas in the farm.
    fn replicas(&self) -> usize;

    /// Board name for logs and records (e.g. `sim-gpu`).
    fn board(&self) -> String;

    /// Target (device) served by replica `replica` — the class-aware
    /// dispatch hook. Homogeneous farms serve one target everywhere
    /// (the default); a heterogeneous fleet
    /// ([`HeteroFarm`](super::farm::HeteroFarm)) reports each board's
    /// own device so [`MeasureService::submit_batch_for`] only lands a
    /// job for target T on boards serving T.
    fn target_of(&self, replica: usize) -> String {
        let _ = replica;
        self.board()
    }
}

/// Fault and flow-control policy of a [`MeasureService`].
#[derive(Clone, Debug)]
pub struct ServiceOptions {
    /// Per-job wall-clock budget, measured from the moment a worker
    /// *starts measuring* the job (neither queue wait nor back-end
    /// construction counts — a PJRT client coming up slowly is not a
    /// hung job). On expiry the job is treated as a board fault:
    /// retried elsewhere or completed as an error. `None` (the default)
    /// never times out — the right setting for deterministic simulator
    /// runs.
    pub timeout: Option<Duration>,
    /// How many times a job may be re-dispatched after a board fault
    /// (panic / timeout) before it completes as an error result.
    pub retries: usize,
    /// Consecutive board faults after which a replica stops receiving
    /// new jobs. `0` disables quarantine. When every replica is
    /// quarantined, dispatch ignores quarantine — degraded beats
    /// deadlocked.
    pub quarantine_after: usize,
    /// Upper bound on jobs in flight; `submit_batch` blocks past it.
    pub max_inflight: usize,
}

impl Default for ServiceOptions {
    fn default() -> Self {
        ServiceOptions { timeout: None, retries: 1, quarantine_after: 3, max_inflight: 1024 }
    }
}

/// Snapshot of farm accounting (see [`MeasureService::stats`]).
#[derive(Clone, Debug)]
pub struct FarmStats {
    /// Jobs dispatched to each replica (a retry counts again).
    pub jobs: Vec<u64>,
    /// Target (device) served by each replica — parallel to `jobs`;
    /// all entries equal for a homogeneous farm, per-class for a
    /// [`HeteroFarm`](super::farm::HeteroFarm).
    pub targets: Vec<String>,
    /// Seconds each replica spent measuring.
    pub busy_secs: Vec<f64>,
    /// Jobs completed (one per submitted job, however many attempts).
    pub completed: u64,
    /// Re-dispatches after board faults.
    pub retries: u64,
    /// Attempts that hit the per-job timeout.
    pub timeouts: u64,
    /// Non-timeout board faults absorbed: worker panics (the measurer
    /// is rebuilt afterwards) and measurer construction failures.
    pub panics: u64,
    /// Which replicas are currently quarantined.
    pub quarantined: Vec<bool>,
    /// Wall-clock span from the first job start to the last completion.
    pub window_secs: f64,
    /// Jobs currently in flight per task key (sorted by key) — the
    /// live cross-task picture of the farm.
    pub inflight_by_task: Vec<(String, usize)>,
    /// Peak number of *distinct* tasks simultaneously in flight over
    /// the service's lifetime — direct evidence that the overlapped
    /// scheduler kept more than one task's slice on the farm at once
    /// (a barrier scheduler never exceeds 1).
    pub peak_tasks_overlapped: usize,
}

impl FarmStats {
    /// Average number of busy replicas over the measurement window —
    /// `Σ busy_secs / window_secs`. Above 1.0 means the farm genuinely
    /// measured in parallel; the ceiling is the replica count.
    pub fn utilization(&self) -> f64 {
        if self.window_secs <= 0.0 {
            return 0.0;
        }
        self.busy_secs.iter().sum::<f64>() / self.window_secs
    }

    /// Jobs dispatched to replicas serving `target` (retries count
    /// again) — the class-aware slice of `jobs`.
    pub fn jobs_for(&self, target: &str) -> u64 {
        self.jobs
            .iter()
            .zip(&self.targets)
            .filter(|(_, t)| t.as_str() == target)
            .map(|(&j, _)| j)
            .sum()
    }

    /// Distinct targets served by the farm, in replica order.
    pub fn distinct_targets(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for t in &self.targets {
            if !out.contains(t) {
                out.push(t.clone());
            }
        }
        out
    }
}

/// One dispatched measurement job.
struct Job {
    seq: u64,
    attempt: u32,
    task: Arc<Task>,
    entity: ConfigEntity,
}

/// Worker → monitor event stream.
enum Event {
    /// A worker began measuring an attempt.
    Started { seq: u64, attempt: u32, at: Instant },
    /// An attempt finished: `Ok` is the measurement (which may itself be
    /// an errored [`MeasureResult`]); `Err` is a worker panic message.
    Done {
        seq: u64,
        attempt: u32,
        replica: usize,
        result: Result<MeasureResult, String>,
        at: Instant,
    },
}

/// In-flight bookkeeping for one job. Carries the task and entity so
/// the monitor can re-dispatch on a board fault.
struct Pending {
    attempt: u32,
    /// Replicas this job has been dispatched to (first = home replica,
    /// last = the one currently holding it). Dispatches are never
    /// repeated to the same replica.
    tried: Vec<usize>,
    /// Real board faults this job has suffered (panics / timeouts).
    /// Only these consume the retry budget — a relocation off a stalled
    /// board is not the job's fault.
    faults: usize,
    /// When the current attempt started on a worker (`None` while
    /// queued).
    started: Option<Instant>,
    /// Last fault reason, reported if the job exhausts its retries.
    last_fault: String,
    /// Task identity for the per-task in-flight accounting (shared by
    /// every job of a batch).
    task_key: Arc<String>,
    /// Class-aware dispatch filter: `Some(t)` restricts every dispatch
    /// (including retries and relocations) to replicas serving target
    /// `t`; `None` means any replica may run the job.
    target: Option<Arc<String>>,
    task: Arc<Task>,
    entity: ConfigEntity,
}

/// All mutable service state, under one lock. Workers never take it —
/// they only read their own job queue and write the event channel — so
/// the measurement hot path is lock-free with respect to this mutex.
struct State {
    next_seq: u64,
    inflight: usize,
    pending: HashMap<u64, Pending>,
    results: HashMap<u64, MeasureResult>,
    /// `None` once shutdown begins — dropping a sender closes that
    /// worker's queue.
    worker_txs: Vec<Option<mpsc::Sender<Job>>>,
    consecutive_faults: Vec<usize>,
    quarantined: Vec<bool>,
    /// A replica whose running job timed out is *suspect* — skipped for
    /// new dispatches (like quarantine, as a preference) until it
    /// answers again, so a wedged board does not keep accumulating
    /// queued jobs that can never start.
    suspect: Vec<bool>,
    // ---- accounting ----
    jobs: Vec<u64>,
    busy: Vec<Duration>,
    completed: u64,
    retries: u64,
    timeouts: u64,
    panics: u64,
    /// Jobs in flight per task key (incremented at submit, decremented
    /// at completion) — the cross-task overlap picture.
    inflight_tasks: HashMap<String, usize>,
    /// Peak distinct-task count of `inflight_tasks`.
    peak_tasks: usize,
    first_start: Option<Instant>,
    last_done: Option<Instant>,
}

fn complete(st: &mut State, seq: u64, result: MeasureResult, at: Instant) {
    if let Some(p) = st.pending.remove(&seq) {
        if let Some(n) = st.inflight_tasks.get_mut(p.task_key.as_str()) {
            *n -= 1;
            if *n == 0 {
                st.inflight_tasks.remove(p.task_key.as_str());
            }
        }
    }
    st.results.insert(seq, result);
    st.inflight = st.inflight.saturating_sub(1);
    st.completed += 1;
    st.last_done = Some(match st.last_done {
        Some(t) if t > at => t,
        _ => at,
    });
}

struct Inner {
    state: Mutex<State>,
    /// Signals completions (wakes `wait_batch`) and in-flight drops
    /// (wakes a backpressured `submit_batch`).
    cv: Condvar,
    opts: ServiceOptions,
    n: usize,
    /// Target served by each replica (`MeasurerFactory::target_of`),
    /// immutable for the service's lifetime — the class map that
    /// target-filtered dispatch consults.
    replica_targets: Vec<String>,
}

impl Inner {
    /// Deterministic replica choice for `seq`: home replica `seq % n`,
    /// scanning forward past quarantined/suspect boards and past
    /// `exclude` (replicas this job was already dispatched to).
    /// Quarantine is a preference — a quarantined board still *answers*
    /// (it panics or errors promptly), so when nothing better exists
    /// the scan repeats allowing quarantined boards. A *suspect* board
    /// is a hard veto: it is wedged mid-measurement, a job queued on it
    /// may never start, and the timeout clock only arms for started
    /// attempts — so with only suspect candidates left this returns
    /// `None` and the caller fails the job instead of stranding it.
    /// With `target = Some(t)`, only replicas serving target `t` are
    /// candidates in *both* passes — a job for one device class never
    /// lands on another class's board, even when the serving class is
    /// fully quarantined or suspect (degrading that class's jobs to
    /// errors rather than producing measurements for the wrong device).
    fn pick_replica(
        &self,
        st: &State,
        seq: u64,
        exclude: &[usize],
        target: Option<&str>,
    ) -> Option<usize> {
        let start = (seq % self.n as u64) as usize;
        for pass in 0..2 {
            for i in 0..self.n {
                let r = (start + i) % self.n;
                if exclude.contains(&r)
                    || st.suspect[r]
                    || (pass == 0 && st.quarantined[r])
                    || target.map_or(false, |t| self.replica_targets[r] != t)
                {
                    continue;
                }
                return Some(r);
            }
        }
        None
    }

    /// Re-dispatch job `seq` (whose `last_fault` the caller just set)
    /// to a replica it has not been dispatched to yet, or — when its
    /// fault-retry budget is exhausted or no untried replica exists —
    /// complete it as an error result. Only real board faults count
    /// against the budget (relocations off a stalled board are free),
    /// and re-dispatching to an already-tried board is never useful (it
    /// faulted or is wedged), so a farm with no healthy boards left
    /// drains its jobs as errors instead of hanging.
    fn requeue_or_fail(&self, st: &mut State, seq: u64, at: Instant) {
        if st.pending[&seq].faults <= self.opts.retries {
            let tried = st.pending[&seq].tried.clone();
            let target = st.pending[&seq].target.clone();
            let filter = target.as_ref().map(|t| t.as_str());
            if let Some(next) = self.pick_replica(st, seq, &tried, filter) {
                let job = {
                    let p = st.pending.get_mut(&seq).expect("pending job");
                    p.attempt += 1;
                    p.started = None;
                    p.tried.push(next);
                    Job {
                        seq,
                        attempt: p.attempt,
                        task: p.task.clone(),
                        entity: p.entity.clone(),
                    }
                };
                st.retries += 1;
                st.jobs[next] += 1;
                let sent = st.worker_txs[next]
                    .as_ref()
                    .map(|tx| tx.send(job).is_ok())
                    .unwrap_or(false);
                if sent {
                    return;
                }
            }
        }
        let msg = format!(
            "board fault after {} attempt(s): {}",
            st.pending[&seq].tried.len(),
            st.pending[&seq].last_fault
        );
        complete(st, seq, MeasureResult::err(msg), at);
    }

    /// Handle a board fault (panic, construction failure or timeout) on
    /// `replica` for attempt `attempt` of job `seq`: strike the board
    /// (possibly quarantining it; a timeout also marks it suspect), then
    /// re-dispatch the job elsewhere or complete it as an error. Stale
    /// attempts (a newer retry is already out) are ignored.
    fn fault(
        &self,
        st: &mut State,
        seq: u64,
        attempt: u32,
        replica: usize,
        reason: String,
        at: Instant,
        timed_out: bool,
    ) {
        let current = st.pending.get(&seq).map_or(false, |p| p.attempt == attempt);
        if !current {
            return;
        }
        if timed_out {
            st.timeouts += 1;
            st.suspect[replica] = true;
        } else {
            st.panics += 1;
        }
        st.consecutive_faults[replica] += 1;
        if self.opts.quarantine_after > 0
            && st.consecutive_faults[replica] >= self.opts.quarantine_after
        {
            st.quarantined[replica] = true;
        }
        if let Some(started) = st.pending.get_mut(&seq).and_then(|p| p.started.take()) {
            st.busy[replica] += at.saturating_duration_since(started);
        }
        {
            let p = st.pending.get_mut(&seq).expect("current attempt checked");
            p.last_fault = reason;
            p.faults += 1;
        }
        self.requeue_or_fail(st, seq, at);
    }

    /// Move every queued-but-not-started job off `replica`: its running
    /// job just timed out, so anything waiting behind that job could
    /// wait forever (the timeout clock only runs for *started* attempts
    /// — this relocation is what protects queued ones).
    fn relocate_queued(&self, st: &mut State, replica: usize, at: Instant) {
        let stuck: Vec<u64> = st
            .pending
            .iter()
            .filter(|(_, p)| p.started.is_none() && p.tried.last() == Some(&replica))
            .map(|(&seq, _)| seq)
            .collect();
        for seq in stuck {
            if let Some(p) = st.pending.get_mut(&seq) {
                p.last_fault =
                    format!("requeued: board {replica} stalled on an earlier job");
            }
            self.requeue_or_fail(st, seq, at);
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

fn worker_loop(
    factory: Arc<dyn MeasurerFactory>,
    replica: usize,
    rx: mpsc::Receiver<Job>,
    ev: mpsc::Sender<Event>,
) {
    let mut measurer: Option<Box<dyn Measurer>> = None;
    while let Ok(job) = rx.recv() {
        if measurer.is_none() {
            // A construction error or panic is a board fault: the job is
            // retried on another replica and this board takes a strike
            // (construction is re-attempted on its next job, so a board
            // that comes back later rejoins the farm).
            let fault_msg = match catch_unwind(AssertUnwindSafe(|| factory.make(replica))) {
                Ok(Ok(m)) => {
                    measurer = Some(m);
                    None
                }
                Ok(Err(e)) => Some(format!("measurer construction failed: {e:#}")),
                Err(p) => Some(format!(
                    "measurer construction panicked: {}",
                    panic_message(p.as_ref())
                )),
            };
            if let Some(msg) = fault_msg {
                let _ = ev.send(Event::Done {
                    seq: job.seq,
                    attempt: job.attempt,
                    replica,
                    result: Err(msg),
                    at: Instant::now(),
                });
                continue;
            }
        }
        // Started only after the back-end exists: the per-job timeout
        // clock must not charge measurer construction (a slow PJRT
        // client coming up is not a hung job) against the job.
        let _ = ev.send(Event::Started { seq: job.seq, attempt: job.attempt, at: Instant::now() });
        let m = measurer.as_ref().expect("measurer built above");
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            m.measure(&job.task, std::slice::from_ref(&job.entity))
        }));
        let result = match outcome {
            Ok(mut v) => match v.pop() {
                Some(r) if v.is_empty() => Ok(r),
                _ => Ok(MeasureResult::err("backend returned a result-count mismatch")),
            },
            Err(p) => {
                measurer = None; // possibly poisoned: rebuild on the next job
                Err(format!("worker panic: {}", panic_message(p.as_ref())))
            }
        };
        let _ = ev.send(Event::Done {
            seq: job.seq,
            attempt: job.attempt,
            replica,
            result,
            at: Instant::now(),
        });
    }
}

fn monitor_loop(inner: Arc<Inner>, rx: mpsc::Receiver<Event>) {
    loop {
        // Earliest running-attempt deadline, when a timeout is set.
        let wait = inner.opts.timeout.and_then(|t| {
            let st = inner.state.lock().unwrap();
            st.pending
                .values()
                .filter_map(|p| p.started)
                .min()
                .map(|earliest| (earliest + t).saturating_duration_since(Instant::now()))
        });
        let ev = match wait {
            Some(d) => match rx.recv_timeout(d) {
                Ok(ev) => Some(ev),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            },
            None => match rx.recv() {
                Ok(ev) => Some(ev),
                Err(_) => break,
            },
        };
        let mut guard = inner.state.lock().unwrap();
        let st = &mut *guard;
        match ev {
            Some(Event::Started { seq, attempt, at }) => {
                if st.first_start.is_none() {
                    st.first_start = Some(at);
                }
                if let Some(p) = st.pending.get_mut(&seq) {
                    if p.attempt == attempt {
                        p.started = Some(at);
                    }
                }
            }
            Some(Event::Done { seq, attempt, replica, result, at }) => match result {
                Ok(r) => {
                    // Any answer — even a stale, timed-out one — proves
                    // the board is alive again: it may receive new
                    // dispatches (suspicion lifted), though its strike
                    // count below only clears on an in-time answer.
                    st.suspect[replica] = false;
                    let current =
                        st.pending.get(&seq).map_or(false, |p| p.attempt == attempt);
                    if current {
                        // Only an in-time answer clears the board's
                        // strike count and lifts quarantine — a stale
                        // (timed-out) answer must not, or a consistently
                        // slow board that always times out yet
                        // eventually replies could never be quarantined.
                        // (A quarantined board only receives work when
                        // no healthy board exists, so lifting is rare —
                        // that fallback doubles as its probation.)
                        st.consecutive_faults[replica] = 0;
                        st.quarantined[replica] = false;
                        if let Some(s) =
                            st.pending.get_mut(&seq).and_then(|p| p.started.take())
                        {
                            st.busy[replica] += at.saturating_duration_since(s);
                        }
                        complete(st, seq, r, at);
                        inner.cv.notify_all();
                    }
                    // A stale success is discarded: the retry's result
                    // stands (or will arrive).
                }
                Err(msg) => {
                    // `msg` is already labeled by the worker (panic vs
                    // construction failure).
                    inner.fault(st, seq, attempt, replica, msg, at, false);
                    inner.cv.notify_all();
                }
            },
            None => {
                // Timeout tick: fault every running attempt past its
                // deadline.
                let t = inner.opts.timeout.expect("tick implies a timeout");
                let now = Instant::now();
                let expired: Vec<(u64, u32, usize)> = st
                    .pending
                    .iter()
                    .filter_map(|(&seq, p)| {
                        let started = p.started?;
                        if now.saturating_duration_since(started) >= t {
                            Some((seq, p.attempt, *p.tried.last().expect("dispatched")))
                        } else {
                            None
                        }
                    })
                    .collect();
                let mut stalled: Vec<usize> = Vec::new();
                for (seq, attempt, replica) in expired {
                    inner.fault(
                        st,
                        seq,
                        attempt,
                        replica,
                        format!("timeout after {t:?}"),
                        now,
                        true,
                    );
                    if !stalled.contains(&replica) {
                        stalled.push(replica);
                    }
                }
                // Anything queued behind a timed-out job would never
                // start (and so never itself time out): move it now.
                for replica in stalled {
                    inner.relocate_queued(st, replica, now);
                }
                inner.cv.notify_all();
            }
        }
    }
    // Shutdown (every worker gone): fail anything still pending so no
    // waiter can hang.
    let mut guard = inner.state.lock().unwrap();
    let st = &mut *guard;
    let seqs: Vec<u64> = st.pending.keys().copied().collect();
    let now = Instant::now();
    for seq in seqs {
        complete(st, seq, MeasureResult::err("measurement service shut down"), now);
    }
    inner.cv.notify_all();
}

/// The asynchronous device-farm measurement service (see the module
/// docs for the full contract). Drive it through the [`Measurer`] impl
/// (blocking batched measurement, sharded across replicas) or the
/// asynchronous [`submit_batch`](Self::submit_batch) /
/// [`wait_batch`](Self::wait_batch) pair. Dropping the service drains
/// queued jobs and joins every thread — bounded by a grace period when
/// a per-job timeout is configured, so a board wedged inside a
/// measurement that never returns is detached rather than allowed to
/// hang shutdown.
pub struct MeasureService {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
    monitor: Option<std::thread::JoinHandle<()>>,
    target: String,
}

impl MeasureService {
    /// Spawn the worker pool (one thread per factory replica, each
    /// building its measurer on-thread) and the fault monitor.
    pub fn new(factory: Arc<dyn MeasurerFactory>, opts: ServiceOptions) -> MeasureService {
        let n = factory.replicas().max(1);
        // The service's target is the *board* identity, not the farm
        // topology: records streamed into the tuning DB (and warm-start
        // lookups against it) must be keyed by the device they are valid
        // for — a 4-replica sim-gpu farm produces sim-gpu records. The
        // farm shape is run metadata, reported via `report()`.
        let target = factory.board();
        let replica_targets: Vec<String> = (0..n).map(|r| factory.target_of(r)).collect();
        let (ev_tx, ev_rx) = mpsc::channel::<Event>();
        let mut worker_txs = Vec::with_capacity(n);
        let mut job_rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::channel::<Job>();
            worker_txs.push(Some(tx));
            job_rxs.push(rx);
        }
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                next_seq: 0,
                inflight: 0,
                pending: HashMap::new(),
                results: HashMap::new(),
                worker_txs,
                consecutive_faults: vec![0; n],
                quarantined: vec![false; n],
                suspect: vec![false; n],
                jobs: vec![0; n],
                busy: vec![Duration::ZERO; n],
                completed: 0,
                retries: 0,
                timeouts: 0,
                panics: 0,
                inflight_tasks: HashMap::new(),
                peak_tasks: 0,
                first_start: None,
                last_done: None,
            }),
            cv: Condvar::new(),
            opts,
            n,
            replica_targets,
        });
        let workers: Vec<_> = job_rxs
            .into_iter()
            .enumerate()
            .map(|(r, rx)| {
                let factory = factory.clone();
                let ev = ev_tx.clone();
                std::thread::Builder::new()
                    .name(format!("measure-worker-{r}"))
                    .spawn(move || worker_loop(factory, r, rx, ev))
                    .expect("spawn measure worker")
            })
            .collect();
        drop(ev_tx); // monitor exits when the last worker does
        let monitor = {
            let inner = inner.clone();
            Some(
                std::thread::Builder::new()
                    .name("measure-monitor".to_string())
                    .spawn(move || monitor_loop(inner, ev_rx))
                    .expect("spawn measure monitor"),
            )
        };
        MeasureService { inner, workers, monitor, target }
    }

    /// Service over `factory` with the default [`ServiceOptions`].
    pub fn with_defaults(factory: Arc<dyn MeasurerFactory>) -> MeasureService {
        MeasureService::new(factory, ServiceOptions::default())
    }

    /// Enqueue one job per candidate (home replica `seq % replicas`),
    /// blocking only when the in-flight bound is reached. Returns the
    /// batch's sequence numbers, to be redeemed with
    /// [`wait_batch`](Self::wait_batch).
    pub fn submit_batch(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<u64> {
        self.submit_batch_for(None, task, batch)
    }

    /// [`submit_batch`](Self::submit_batch) with a class-aware dispatch
    /// filter: `Some(t)` restricts the batch — initial dispatch, fault
    /// retries and stall relocations alike — to replicas whose
    /// [`MeasurerFactory::target_of`] equals `t`. When no replica of
    /// that class can accept work (all suspect, or no replica serves
    /// `t` at all) the jobs complete as error results immediately:
    /// routing elsewhere would measure on the wrong device, so the
    /// class degrades rather than lies.
    pub fn submit_batch_for(
        &self,
        target: Option<&str>,
        task: &Task,
        batch: &[ConfigEntity],
    ) -> Vec<u64> {
        let task_key = Arc::new(task.key());
        let task = Arc::new(task.clone());
        let target: Option<Arc<String>> = target.map(|t| Arc::new(t.to_string()));
        let filter = target.as_ref().map(|t| t.as_str());
        let mut seqs = Vec::with_capacity(batch.len());
        let mut st = self.inner.state.lock().unwrap();
        for e in batch {
            while st.inflight >= self.inner.opts.max_inflight.max(1) {
                st = self.inner.cv.wait(st).unwrap();
            }
            let seq = st.next_seq;
            st.next_seq += 1;
            // No responsive board serving this job (every candidate
            // replica wedged mid-measurement, or none serves the
            // requested target): fail the job now rather than queue it
            // where the timeout clock can never arm.
            let Some(replica) = self.inner.pick_replica(&st, seq, &[], filter) else {
                let msg = match filter {
                    Some(t) => format!("no responsive board serving {t}"),
                    None => "no responsive board in the farm".to_string(),
                };
                st.results.insert(seq, MeasureResult::err(msg));
                st.completed += 1;
                seqs.push(seq);
                continue;
            };
            st.pending.insert(
                seq,
                Pending {
                    attempt: 0,
                    tried: vec![replica],
                    faults: 0,
                    started: None,
                    last_fault: String::new(),
                    task_key: task_key.clone(),
                    target: target.clone(),
                    task: task.clone(),
                    entity: e.clone(),
                },
            );
            *st.inflight_tasks.entry(task_key.as_ref().clone()).or_insert(0) += 1;
            let distinct = st.inflight_tasks.len();
            st.peak_tasks = st.peak_tasks.max(distinct);
            st.inflight += 1;
            st.jobs[replica] += 1;
            let job = Job { seq, attempt: 0, task: task.clone(), entity: e.clone() };
            let sent = st.worker_txs[replica]
                .as_ref()
                .map(|tx| tx.send(job).is_ok())
                .unwrap_or(false);
            if !sent {
                complete(
                    &mut st,
                    seq,
                    MeasureResult::err("measurement service shut down"),
                    Instant::now(),
                );
            }
            seqs.push(seq);
        }
        drop(st);
        self.inner.cv.notify_all();
        seqs
    }

    /// Block until every job of the batch has completed, returning the
    /// results in submission order (the deterministic-accounting
    /// contract: callers absorbing tickets FIFO observe the same history
    /// every run).
    pub fn wait_batch(&self, seqs: &[u64]) -> Vec<MeasureResult> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if seqs.iter().all(|s| st.results.contains_key(s)) {
                return seqs
                    .iter()
                    .map(|s| st.results.remove(s).expect("presence checked"))
                    .collect();
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    /// Snapshot of the farm accounting (jobs, busy time, faults,
    /// quarantine, utilization window).
    pub fn stats(&self) -> FarmStats {
        let st = self.inner.state.lock().unwrap();
        FarmStats {
            jobs: st.jobs.clone(),
            targets: self.inner.replica_targets.clone(),
            busy_secs: st.busy.iter().map(|d| d.as_secs_f64()).collect(),
            completed: st.completed,
            retries: st.retries,
            timeouts: st.timeouts,
            panics: st.panics,
            quarantined: st.quarantined.clone(),
            window_secs: match (st.first_start, st.last_done) {
                (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
                _ => 0.0,
            },
            inflight_by_task: {
                let mut v: Vec<(String, usize)> =
                    st.inflight_tasks.iter().map(|(k, &n)| (k.clone(), n)).collect();
                v.sort();
                v
            },
            peak_tasks_overlapped: st.peak_tasks,
        }
    }

    /// One-line human summary of [`stats`](Self::stats) for CLI reports.
    /// A heterogeneous fleet appends per-target job counts.
    pub fn report(&self) -> String {
        let s = self.stats();
        let mut line = format!(
            "farm: {} jobs on {} replicas, utilization {:.2}x, peak task overlap {} \
             (retries {}, timeouts {}, other faults {}, quarantined {})",
            s.completed,
            s.jobs.len(),
            s.utilization(),
            s.peak_tasks_overlapped,
            s.retries,
            s.timeouts,
            s.panics,
            s.quarantined.iter().filter(|&&q| q).count(),
        );
        let classes = s.distinct_targets();
        if classes.len() > 1 {
            let per: Vec<String> =
                classes.iter().map(|t| format!("{t}: {}", s.jobs_for(t))).collect();
            line.push_str(&format!(", jobs by target [{}]", per.join(", ")));
        }
        line
    }

    /// A [`Measurer`] view of this service restricted to boards serving
    /// `target`: every batch it submits carries the class filter, and
    /// its [`Measurer::target`] reports `target` — so records streamed
    /// into the tuning DB by a loop driving this view are stamped with
    /// the device they were measured on, not the fleet-wide board name.
    pub fn for_target(&self, target: &str) -> TargetedMeasurer<'_> {
        TargetedMeasurer { service: self, target: target.to_string() }
    }
}

/// Class-restricted [`Measurer`] view of a [`MeasureService`] — see
/// [`MeasureService::for_target`]. One service can hand out several of
/// these (one per device class), letting a multi-target scheduler run
/// every class's loops over a single shared fleet.
pub struct TargetedMeasurer<'a> {
    service: &'a MeasureService,
    target: String,
}

impl Measurer for TargetedMeasurer<'_> {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        let seqs = self.service.submit_batch_for(Some(&self.target), task, batch);
        self.service.wait_batch(&seqs)
    }

    fn target(&self) -> String {
        self.target.clone()
    }

    fn submit(&self, task: &Task, batch: &[ConfigEntity]) -> BatchTicket {
        BatchTicket::pending(self.service.submit_batch_for(Some(&self.target), task, batch))
    }

    fn wait(&self, ticket: BatchTicket) -> Vec<MeasureResult> {
        match ticket.into_parts() {
            (Some(ready), _) => ready,
            (None, seqs) => self.service.wait_batch(&seqs),
        }
    }
}

/// Join `handle`, but give up at `deadline` (if one is set) — a worker
/// wedged inside a `measure()` call that never returns can never be
/// joined, and detaching it beats hanging the process at shutdown.
fn join_by(handle: std::thread::JoinHandle<()>, deadline: Option<Instant>) {
    match deadline {
        None => {
            let _ = handle.join();
        }
        Some(d) => {
            while !handle.is_finished() && Instant::now() < d {
                std::thread::sleep(Duration::from_millis(5));
            }
            if handle.is_finished() {
                let _ = handle.join();
            }
            // else: detached — the process outlives (or kills) it.
        }
    }
}

impl Drop for MeasureService {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            for tx in st.worker_txs.iter_mut() {
                tx.take(); // closing the queue lets the worker drain and exit
            }
        }
        // With no timeout configured the caller accepted indefinite
        // measurements, so shutdown waits for them. With a timeout, a
        // wedged board must not hang shutdown either: joins are bounded
        // by a grace period and stragglers are detached.
        let deadline = self
            .inner
            .opts
            .timeout
            .map(|t| Instant::now() + t.saturating_mul(2) + Duration::from_secs(1));
        for w in self.workers.drain(..) {
            join_by(w, deadline);
        }
        if let Some(m) = self.monitor.take() {
            join_by(m, deadline);
        }
    }
}

impl Measurer for MeasureService {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        let seqs = self.submit_batch(task, batch);
        self.wait_batch(&seqs)
    }

    fn target(&self) -> String {
        self.target.clone()
    }

    fn submit(&self, task: &Task, batch: &[ConfigEntity]) -> BatchTicket {
        BatchTicket::pending(self.submit_batch(task, batch))
    }

    fn wait(&self, ticket: BatchTicket) -> Vec<MeasureResult> {
        match ticket.into_parts() {
            (Some(ready), _) => ready,
            (None, seqs) => self.wait_batch(&seqs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::measure::farm::DeviceFarm;
    use crate::measure::SimMeasurer;
    use crate::schedule::template::TemplateKind;
    use crate::sim::devices::sim_gpu;
    use crate::util::Rng;

    fn batch(task: &Task, n: usize, seed: u64) -> Vec<ConfigEntity> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n).map(|_| task.space.sample(&mut rng)).collect()
    }

    #[test]
    fn single_replica_service_equals_direct_measurer() {
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let b = batch(&task, 24, 3);
        let direct = SimMeasurer::with_seed(sim_gpu(), 7);
        let want = direct.measure(&task, &b);
        let farm = DeviceFarm::new(sim_gpu(), 1, 7);
        let svc = MeasureService::with_defaults(Arc::new(farm));
        let got = svc.measure(&task, &b);
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.gflops, g.gflops);
            assert_eq!(w.seconds, g.seconds);
            assert_eq!(w.error, g.error);
        }
    }

    #[test]
    fn multi_replica_service_is_deterministic_and_ordered() {
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let b = batch(&task, 30, 4);
        let run = || {
            let svc =
                MeasureService::with_defaults(Arc::new(DeviceFarm::new(sim_gpu(), 4, 9)));
            // two batches, so sequence numbers span submissions
            let first = svc.measure(&task, &b[..16]);
            let second = svc.measure(&task, &b[16..]);
            (first, second)
        };
        let (a1, a2) = run();
        let (b1, b2) = run();
        for (x, y) in a1.iter().zip(&b1).chain(a2.iter().zip(&b2)) {
            assert_eq!(x.gflops, y.gflops, "service results not deterministic");
        }
        assert_eq!(a1.len(), 16);
        assert_eq!(a2.len(), 14);
    }

    #[test]
    fn async_tickets_resolve_out_of_wait_order() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let b = batch(&task, 12, 5);
        let svc = MeasureService::with_defaults(Arc::new(DeviceFarm::new(sim_gpu(), 2, 1)));
        let t1 = Measurer::submit(&svc, &task, &b[..6]);
        let t2 = Measurer::submit(&svc, &task, &b[6..]);
        // waiting on the later ticket first must not deadlock or scramble
        let r2 = Measurer::wait(&svc, t2);
        let r1 = Measurer::wait(&svc, t1);
        assert_eq!(r1.len(), 6);
        assert_eq!(r2.len(), 6);
        // replica 0 of the farm shares the direct measurer's seed, so
        // its very first job (seq 0 = the first candidate) must match a
        // direct measurement exactly
        let direct = SimMeasurer::with_seed(sim_gpu(), 1);
        let want = direct.measure(&task, &b[..1]);
        assert_eq!(r1[0].gflops, want[0].gflops);
    }

    #[test]
    fn per_task_inflight_accounting_tracks_cross_task_overlap() {
        let t1 = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let t2 = Task::new(ops::matmul(128, 64, 64), TemplateKind::Gpu);
        let farm = DeviceFarm::with_latency(sim_gpu(), 2, 3, Duration::from_millis(20));
        let svc = MeasureService::with_defaults(Arc::new(farm));
        let b1 = batch(&t1, 4, 1);
        let b2 = batch(&t2, 4, 2);
        // both tasks' jobs are on the farm before either batch drains
        let s1 = svc.submit_batch(&t1, &b1);
        let s2 = svc.submit_batch(&t2, &b2);
        let r1 = svc.wait_batch(&s1);
        let r2 = svc.wait_batch(&s2);
        assert_eq!(r1.len() + r2.len(), 8);
        let s = svc.stats();
        assert_eq!(s.peak_tasks_overlapped, 2, "both tasks were in flight at once");
        assert!(s.inflight_by_task.is_empty(), "accounting must drain: {:?}", s.inflight_by_task);
        assert!(svc.report().contains("peak task overlap 2"));
    }

    #[test]
    fn targeted_dispatch_lands_only_on_matching_boards() {
        use crate::measure::farm::{BoardClass, HeteroFarm};
        use crate::sim::devices::sim_cpu;
        let cpu_task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let gpu_task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let farm = HeteroFarm::new(
            vec![BoardClass::new(sim_cpu(), 2), BoardClass::new(sim_gpu(), 3)],
            11,
        );
        let svc = MeasureService::with_defaults(Arc::new(farm));
        let bc = batch(&cpu_task, 6, 1);
        let bg = batch(&gpu_task, 9, 2);
        let cpu_view = svc.for_target("sim-cpu");
        let gpu_view = svc.for_target("sim-gpu");
        assert_eq!(cpu_view.target(), "sim-cpu");
        let rc = cpu_view.measure(&cpu_task, &bc);
        let rg = gpu_view.measure(&gpu_task, &bg);
        assert!(rc.iter().all(|r| r.is_ok()), "cpu jobs must succeed");
        assert!(rg.iter().all(|r| r.is_ok()), "gpu jobs must succeed");
        let s = svc.stats();
        assert_eq!(s.targets, vec!["sim-cpu", "sim-cpu", "sim-gpu", "sim-gpu", "sim-gpu"]);
        assert_eq!(s.jobs_for("sim-cpu"), 6, "cpu jobs only on cpu boards");
        assert_eq!(s.jobs_for("sim-gpu"), 9, "gpu jobs only on gpu boards");
        assert_eq!(s.distinct_targets(), vec!["sim-cpu", "sim-gpu"]);
        assert!(svc.report().contains("jobs by target ["), "report: {}", svc.report());
    }

    #[test]
    fn targeted_dispatch_fails_fast_for_unserved_target() {
        use crate::measure::farm::{BoardClass, HeteroFarm};
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let farm = HeteroFarm::new(vec![BoardClass::new(sim_gpu(), 2)], 5);
        let svc = MeasureService::with_defaults(Arc::new(farm));
        let b = batch(&task, 3, 4);
        let r = svc.for_target("sim-tpu-v6e").measure(&task, &b);
        assert_eq!(r.len(), 3);
        for res in &r {
            let err = res.error.as_deref().unwrap_or("");
            assert!(
                err.contains("no responsive board serving sim-tpu-v6e"),
                "unexpected error: {err:?}"
            );
        }
        // the farm itself is untouched — real boards still serve
        let ok = svc.for_target("sim-gpu").measure(&task, &b);
        assert!(ok.iter().all(|x| x.is_ok()));
    }

    #[test]
    fn stats_count_every_job() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let b = batch(&task, 20, 6);
        let svc = MeasureService::with_defaults(Arc::new(DeviceFarm::new(sim_gpu(), 4, 2)));
        let _ = svc.measure(&task, &b);
        let s = svc.stats();
        assert_eq!(s.completed, 20);
        assert_eq!(s.jobs.iter().sum::<u64>(), 20);
        assert_eq!(s.jobs, vec![5, 5, 5, 5], "round-robin home assignment");
        assert_eq!(s.retries + s.timeouts + s.panics, 0);
        assert!(s.window_secs >= 0.0);
    }
}
