//! Measurement infrastructure — querying the black box `f(x)`.
//!
//! The paper's system builds candidate programs and runs them on a farm
//! of real devices over RPC. Here a [`Measurer`] abstracts the back-end:
//!
//! * [`SimMeasurer`] — builds (lowers) and "runs" candidates on a
//!   [`DeviceModel`] simulator, in parallel across a worker pool with
//!   seeded measurement noise, mirroring the batched-parallel
//!   measurement semantics of the paper's device farm.
//! * [`pjrt::PjrtMeasurer`] — the real-hardware path: compiles
//!   AOT-generated Pallas kernel variants through the PJRT CPU client
//!   and wall-clocks them (see `examples/pjrt_measure.rs`).
//! * [`service::MeasureService`] — the asynchronous device-farm
//!   service every tuning loop shares: per-replica workers (each
//!   building its own measurer on-thread via
//!   [`service::MeasurerFactory`]), sequence-numbered job queues with
//!   bounded in-flight backpressure, and timeout/retry/quarantine
//!   board-fault policies, with results delivered deterministically in
//!   submission order. A heterogeneous fleet
//!   ([`farm::HeteroFarm`], built from [`farm::BoardClass`] profiles)
//!   plugs in through the same factory: the service dispatches
//!   class-aware, so a job for target T only lands on boards serving T
//!   ([`service::MeasureService::for_target`]).

pub mod farm;
pub mod pjrt;
pub mod service;

use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use crate::util::parallel_map;
use std::sync::atomic::{AtomicU64, Ordering};

/// Outcome of measuring one candidate. Invalid configs (resource-limit
/// violations, compile errors) carry `error` and zero GFLOPS, exactly
/// like failed trials in the paper's system.
#[derive(Clone, Debug)]
pub struct MeasureResult {
    /// Measured throughput (0.0 on failure).
    pub gflops: f64,
    /// Wall-clock seconds, when the back-end reports one.
    pub seconds: Option<f64>,
    /// Failure reason, if the candidate errored.
    pub error: Option<String>,
}

impl MeasureResult {
    /// Successful measurement.
    pub fn ok(gflops: f64, seconds: f64) -> Self {
        MeasureResult { gflops, seconds: Some(seconds), error: None }
    }

    /// Failed measurement.
    pub fn err(msg: impl Into<String>) -> Self {
        MeasureResult { gflops: 0.0, seconds: None, error: Some(msg.into()) }
    }

    /// Whether the candidate ran without error.
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Handle for a measurement batch submitted through
/// [`Measurer::submit`]: redeem it with [`Measurer::wait`] on the same
/// back-end. For plain synchronous back-ends the ticket already carries
/// the results; for the asynchronous [`service::MeasureService`] it
/// carries the batch's job sequence numbers while the farm measures in
/// the background.
pub struct BatchTicket {
    ready: Option<Vec<MeasureResult>>,
    seqs: Vec<u64>,
}

impl BatchTicket {
    /// Ticket that already holds its results (synchronous back-ends).
    pub(crate) fn ready(results: Vec<MeasureResult>) -> Self {
        BatchTicket { ready: Some(results), seqs: Vec::new() }
    }

    /// Ticket for jobs still in flight on a [`service::MeasureService`].
    pub(crate) fn pending(seqs: Vec<u64>) -> Self {
        BatchTicket { ready: None, seqs }
    }

    pub(crate) fn into_parts(self) -> (Option<Vec<MeasureResult>>, Vec<u64>) {
        (self.ready, self.seqs)
    }
}

/// A measurement back-end.
///
/// Not `Send`/`Sync`: the tuner drives measurement from one thread and
/// back-ends parallelize internally (PJRT handles are thread-affine in
/// the `xla` crate). The [`service::MeasureService`] is the exception
/// that proves the rule — it parallelizes across replica *worker
/// threads*, each of which owns its own thread-affine measurer, and is
/// itself driven from one caller thread through this trait.
pub trait Measurer {
    /// Measure a batch of candidates for one task.
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult>;

    /// Human-readable target name (for logs / records).
    fn target(&self) -> String;

    /// Begin measuring a batch, returning a [`BatchTicket`] to redeem
    /// with [`wait`](Self::wait). The default measures synchronously at
    /// submit time (so plain back-ends behave exactly as before);
    /// asynchronous back-ends override both methods to keep the next
    /// batch measuring while the caller absorbs the previous one.
    fn submit(&self, task: &Task, batch: &[ConfigEntity]) -> BatchTicket {
        BatchTicket::ready(self.measure(task, batch))
    }

    /// Redeem a ticket from [`submit`](Self::submit) on this back-end.
    fn wait(&self, ticket: BatchTicket) -> Vec<MeasureResult> {
        ticket
            .ready
            .expect("ticket from an asynchronous service must be waited on that service")
    }
}

impl<'a> Measurer for Box<dyn Measurer + 'a> {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        (**self).measure(task, batch)
    }

    fn target(&self) -> String {
        (**self).target()
    }

    fn submit(&self, task: &Task, batch: &[ConfigEntity]) -> BatchTicket {
        (**self).submit(task, batch)
    }

    fn wait(&self, ticket: BatchTicket) -> Vec<MeasureResult> {
        (**self).wait(ticket)
    }
}

/// Simulator-backed measurer with a parallel build+run worker pool.
pub struct SimMeasurer {
    /// The simulated device.
    pub device: crate::sim::DeviceModel,
    /// Worker threads for parallel build+run.
    pub threads: usize,
    /// deterministic measurement-noise stream
    seed: AtomicU64,
}

impl SimMeasurer {
    /// Measurer over `device` with a fresh noise stream.
    pub fn new(device: crate::sim::DeviceModel) -> Self {
        SimMeasurer { device, threads: crate::util::default_threads(), seed: AtomicU64::new(1) }
    }

    /// Fix the noise stream (for reproducible experiments).
    pub fn with_seed(device: crate::sim::DeviceModel, seed: u64) -> Self {
        SimMeasurer { device, threads: crate::util::default_threads(), seed: AtomicU64::new(seed) }
    }
}

impl Measurer for SimMeasurer {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        // one seed per candidate, drawn up front so parallel order
        // doesn't matter
        let base = self.seed.fetch_add(batch.len() as u64, Ordering::Relaxed);
        let work: Vec<(usize, &ConfigEntity)> = batch.iter().enumerate().collect();
        parallel_map(&work, self.threads, |(i, e)| {
            let prog = match task.lower(e) {
                Ok(p) => p,
                Err(err) => return MeasureResult::err(format!("lowering: {err}")),
            };
            match self.device.measure(&prog, base + *i as u64) {
                Ok(r) => MeasureResult::ok(r.gflops, r.seconds),
                Err(e) => MeasureResult::err(e.to_string()),
            }
        })
    }

    fn target(&self) -> String {
        self.device.name.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::schedule::template::TemplateKind;
    use crate::sim::devices::{sim_cpu, sim_gpu};
    use crate::util::Rng;

    #[test]
    fn sim_measurer_batch_matches_single() {
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Cpu);
        let mut rng = Rng::seed_from_u64(1);
        let batch: Vec<_> = (0..16).map(|_| task.space.sample(&mut rng)).collect();
        let m = SimMeasurer::with_seed(sim_cpu(), 7);
        let results = m.measure(&task, &batch);
        assert_eq!(results.len(), batch.len());
        assert!(results.iter().filter(|r| r.is_ok()).count() > 8);
        // deterministic given the same seed
        let m2 = SimMeasurer::with_seed(sim_cpu(), 7);
        let results2 = m2.measure(&task, &batch);
        for (a, b) in results.iter().zip(&results2) {
            assert_eq!(a.gflops, b.gflops);
        }
    }

    #[test]
    fn invalid_configs_become_errors() {
        let task = Task::new(ops::matmul(1024, 1024, 1024), TemplateKind::Gpu);
        let m = SimMeasurer::new(sim_gpu());
        // thread tile 64x64 exceeds the 1024-thread cap
        let mut e = task.space.entity(0);
        for knob in [0usize, 1] {
            let crate::schedule::space::Knob::Split { options, .. } =
                &task.space.knobs[knob]
            else {
                panic!()
            };
            e.choices[knob] =
                options.iter().position(|o| o == &vec![16, 64, 1]).unwrap() as u32;
        }
        let r = m.measure(&task, &[e]);
        assert!(!r[0].is_ok());
        assert_eq!(r[0].gflops, 0.0);
    }
}
