//! Real-hardware measurement through PJRT: wall-clock AOT-compiled
//! Pallas tiled-matmul kernel variants on this machine's CPU.
//!
//! `python/compile/aot.py --variants` emits one HLO artifact per tile
//! configuration of the L1 Pallas kernel
//! (`matmul{N}_bm{bm}_bn{bn}_bk{bk}.hlo.txt`). This measurer maps a
//! config entity of [`matmul_variant_task`] to its artifact, compiles it
//! once (cached) and times real executions — a genuine `f(x)` proving
//! the whole tuner loop runs against actual hardware, not only the
//! simulator (DESIGN.md §Experiment index, `examples/pjrt_measure.rs`).

use super::{MeasureResult, Measurer};
use crate::expr::ops;
use crate::runtime::{artifacts_dir, literal_f32, PjrtRuntime};
use crate::schedule::space::{ConfigEntity, ConfigSpace, Knob};
use crate::schedule::template::{Task, TemplateKind};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Matmul size of the variant family (matches `aot.py`).
pub const VARIANT_N: i64 = 256;
/// Tile options per dimension (matches `aot.py`).
pub const BM_OPTS: [i64; 3] = [32, 64, 128];
/// Tile options for the N dimension (matches `aot.py`).
pub const BN_OPTS: [i64; 3] = [32, 64, 128];
/// Tile options for the K dimension (matches `aot.py`).
pub const BK_OPTS: [i64; 3] = [64, 128, 256];

/// Build the restricted task whose space enumerates exactly the
/// pre-compiled Pallas variants. The knob layout matches the GPU
/// template (splits per axis, then unroll, then vec) so features and
/// lowering work unchanged; block tiling `(N/b, 1, b)` mirrors the
/// Pallas grid (one program instance per block).
pub fn matmul_variant_task() -> Task {
    let def = ops::matmul(VARIANT_N, VARIANT_N, VARIANT_N);
    let n = VARIANT_N;
    let split3 = |opts: &[i64]| -> Vec<Vec<i64>> {
        opts.iter().map(|&b| vec![n / b, 1, b]).collect()
    };
    let split2 = |opts: &[i64]| -> Vec<Vec<i64>> {
        opts.iter().map(|&b| vec![n / b, b]).collect()
    };
    let space = ConfigSpace {
        knobs: vec![
            Knob::Split { name: "tile_y".into(), extent: n, parts: 3, options: split3(&BM_OPTS) },
            Knob::Split { name: "tile_x".into(), extent: n, parts: 3, options: split3(&BN_OPTS) },
            Knob::Split { name: "tile_k".into(), extent: n, parts: 2, options: split2(&BK_OPTS) },
            Knob::Choice { name: "unroll".into(), options: vec![0] },
            Knob::Choice { name: "vec".into(), options: vec![0] },
        ],
    };
    Task { def, template: TemplateKind::Gpu, space, sketches: None }
}

/// Tile sizes selected by an entity of [`matmul_variant_task`].
pub fn variant_tiles(task: &Task, e: &ConfigEntity) -> (i64, i64, i64) {
    let sched = task.schedule(e);
    (sched.splits[0][2], sched.splits[1][2], sched.splits[2][1])
}

/// Artifact file name for a tile configuration.
pub fn variant_artifact(bm: i64, bn: i64, bk: i64) -> String {
    format!("matmul{VARIANT_N}_bm{bm}_bn{bn}_bk{bk}.hlo.txt")
}

/// PJRT wall-clock measurer over the pre-compiled variant family.
pub struct PjrtMeasurer {
    rt: PjrtRuntime,
    /// compiled-executable cache keyed by artifact name
    cache: Mutex<HashMap<String, std::sync::Arc<crate::runtime::Executable>>>,
    /// timing repetitions (min is reported)
    pub repeats: usize,
    inputs: (xla::Literal, xla::Literal),
}

impl PjrtMeasurer {
    /// Measurer over a PJRT runtime (loads the AOT variant executables).
    pub fn new(rt: PjrtRuntime) -> anyhow::Result<Self> {
        let n = VARIANT_N as usize;
        // fixed pseudo-random inputs (value content doesn't affect time)
        let mut rng = crate::util::Rng::seed_from_u64(0xDA7A);
        let a: Vec<f32> = (0..n * n).map(|_| rng.gen_f64() as f32).collect();
        let b: Vec<f32> = (0..n * n).map(|_| rng.gen_f64() as f32).collect();
        Ok(PjrtMeasurer {
            rt,
            cache: Mutex::new(HashMap::new()),
            repeats: 3,
            inputs: (
                literal_f32(&a, &[VARIANT_N, VARIANT_N])?,
                literal_f32(&b, &[VARIANT_N, VARIANT_N])?,
            ),
        })
    }

    fn executable(
        &self,
        name: &str,
    ) -> anyhow::Result<std::sync::Arc<crate::runtime::Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = artifacts_dir().join(name);
        anyhow::ensure!(path.exists(), "variant artifact {name} missing — run `make artifacts`");
        let exe = std::sync::Arc::new(self.rt.load(&path)?);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

/// [`MeasurerFactory`] for the real-hardware path: each device-farm
/// worker constructs its *own* PJRT client and measurer on its own
/// thread — exactly the thread-affinity constraint the service's
/// factory indirection exists for (PJRT handles must never cross
/// threads). Construction failure (missing artifacts, no PJRT plugin)
/// is a *board fault*: the service retries the job on another replica
/// and quarantines the broken board instead of burning trials on it, so
/// one misconfigured machine degrades — never kills — the farm.
///
/// [`MeasurerFactory`]: super::service::MeasurerFactory
pub struct PjrtMeasurerFactory {
    /// Number of farm workers, each with a private PJRT client.
    pub replicas: usize,
}

impl super::service::MeasurerFactory for PjrtMeasurerFactory {
    fn make(&self, _replica: usize) -> anyhow::Result<Box<dyn Measurer>> {
        let m = PjrtMeasurer::new(crate::runtime::PjrtRuntime::cpu()?)?;
        Ok(Box::new(m))
    }

    fn replicas(&self) -> usize {
        self.replicas.max(1)
    }

    fn board(&self) -> String {
        "pjrt-cpu".to_string()
    }
}

impl Measurer for PjrtMeasurer {
    fn measure(&self, task: &Task, batch: &[ConfigEntity]) -> Vec<MeasureResult> {
        let flops = task.def.total_flops() as f64;
        batch
            .iter()
            .map(|e| {
                let (bm, bn, bk) = variant_tiles(task, e);
                let name = variant_artifact(bm, bn, bk);
                let exe = match self.executable(&name) {
                    Ok(e) => e,
                    Err(err) => return MeasureResult::err(err.to_string()),
                };
                let inputs = [self.inputs.0.clone(), self.inputs.1.clone()];
                // warmup
                if let Err(err) = exe.run(&inputs) {
                    return MeasureResult::err(err.to_string());
                }
                let mut best = f64::INFINITY;
                for _ in 0..self.repeats {
                    let t0 = Instant::now();
                    if let Err(err) = exe.run(&inputs) {
                        return MeasureResult::err(err.to_string());
                    }
                    best = best.min(t0.elapsed().as_secs_f64());
                }
                MeasureResult::ok(flops / best / 1e9, best)
            })
            .collect()
    }

    fn target(&self) -> String {
        format!("pjrt-{}", self.rt.platform())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_task_space_is_exact_grid() {
        let t = matmul_variant_task();
        assert_eq!(t.space.size() as usize, BM_OPTS.len() * BN_OPTS.len() * BK_OPTS.len());
        // every entity lowers and maps to a valid artifact name
        for i in 0..t.space.size() {
            let e = t.space.entity(i);
            let p = t.lower(&e).unwrap();
            assert!(p.flops > 0);
            let (bm, bn, bk) = variant_tiles(&t, &e);
            assert!(BM_OPTS.contains(&bm) && BN_OPTS.contains(&bn) && BK_OPTS.contains(&bk));
        }
    }
}
