//! Vendor-library baselines for Figs. 10–11.
//!
//! The paper compares against cuDNN / TFLite / ARM ComputeLibrary —
//! hand-tuned kernels shipped for common shapes. We model each library
//! as an **expert fixed schedule** per operator class and device: the
//! one-size-fits-most tiling an engineer would bake into a library
//! kernel (DESIGN.md §Substitution). It is chosen once per template,
//! never per-shape-tuned, and cannot fuse epilogues — the two
//! structural disadvantages the paper attributes to library back-ends.
//!
//! The TensorComprehensions baseline of Fig. 10 is modeled by the GA
//! tuner ([`crate::tuner::tune_ga`]) with the paper's trial budget.

use crate::schedule::space::{ConfigEntity, ConfigSpace, Knob};
use crate::schedule::template::{Task, TemplateKind};

/// Choose the split option whose factors are closest (in log space) to
/// the target shape, searching outer→inner significance.
fn pick_split(space: &ConfigSpace, knob: usize, target: &[f64]) -> u32 {
    let Knob::Split { options, .. } = &space.knobs[knob] else {
        panic!("knob {knob} is not a split");
    };
    let mut best = (0u32, f64::INFINITY);
    for (i, opt) in options.iter().enumerate() {
        let d: f64 = opt
            .iter()
            .zip(target)
            .map(|(&f, &t)| ((f as f64).log2() - t.log2()).powi(2))
            .sum();
        if d < best.1 {
            best = (i as u32, d);
        }
    }
    best.0
}

fn pick_choice(space: &ConfigSpace, name: &str, want: i64) -> (usize, u32) {
    let i = space.knob_index(name).expect("choice knob");
    let Knob::Choice { options, .. } = &space.knobs[i] else { panic!() };
    let j = options
        .iter()
        .enumerate()
        .min_by_key(|(_, &v)| (v - want).abs())
        .map(|(j, _)| j as u32)
        .unwrap();
    (i, j)
}

/// The expert fixed schedule a vendor library would ship for this
/// operator class on this device.
pub fn vendor_config(task: &Task) -> ConfigEntity {
    let space = &task.space;
    let ns = task.def.axes.len();
    let _nr = task.def.reduce_axes.len();
    let mut e = ConfigEntity { choices: vec![0; space.num_knobs()] };
    match task.template {
        TemplateKind::Cpu => {
            // parallel outer ≈ cores, mid tile 4, vector-width inner
            for (i, ax) in task.def.axes.iter().enumerate() {
                let ext = ax.extent as f64;
                let inner = if i == ns - 1 { 8.0 } else { 4.0 };
                let target = [4f64.min(ext), (ext / (4.0 * inner)).max(1.0), inner];
                e.choices[i] = pick_split(space, i, &target);
            }
            for (i, ax) in task.def.reduce_axes.iter().enumerate() {
                let ext = ax.extent as f64;
                e.choices[ns + i] = pick_split(space, ns + i, &[(ext / 4.0).max(1.0), 4.0]);
            }
            let (i, j) = pick_choice(space, "unroll", 16);
            e.choices[i] = j;
            let (i, j) = pick_choice(space, "vec", 1);
            e.choices[i] = j;
            let (i, j) = pick_choice(space, "cache_write", 1);
            e.choices[i] = j;
        }
        TemplateKind::Gpu => {
            // 16×16-ish thread blocks over the two largest spatial axes,
            // small register tiles — the classic library kernel shape
            let mut order: Vec<usize> = (0..ns).collect();
            order.sort_by_key(|&i| std::cmp::Reverse(task.def.axes[i].extent));
            for (rank, &i) in order.iter().enumerate() {
                let ext = task.def.axes[i].extent as f64;
                let threads = match rank {
                    0 | 1 => 16.0f64,
                    _ => 1.0,
                }
                .min(ext);
                let inner = if rank < 2 { 2.0f64.min(ext / threads) } else { 1.0 };
                let target = [(ext / (threads * inner)).max(1.0), threads, inner.max(1.0)];
                e.choices[i] = pick_split(space, i, &target);
            }
            for (i, ax) in task.def.reduce_axes.iter().enumerate() {
                let ext = ax.extent as f64;
                e.choices[ns + i] = pick_split(space, ns + i, &[(ext / 8.0).max(1.0), 8.0]);
            }
            let (i, j) = pick_choice(space, "unroll", 64);
            e.choices[i] = j;
            let (i, j) = pick_choice(space, "vec", 1);
            e.choices[i] = j;
        }
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::sim::devices::{sim_cpu, sim_gpu, sim_mali};
    use crate::workloads;

    #[test]
    fn vendor_configs_are_valid_on_all_workloads() {
        for n in 1..=12 {
            for (t, dev) in [
                (TemplateKind::Gpu, sim_gpu()),
                (TemplateKind::Cpu, sim_cpu()),
                (TemplateKind::Gpu, sim_mali()),
            ] {
                let task = workloads::conv_task(n, t);
                let e = vendor_config(&task);
                let prog = task.lower(&e).unwrap_or_else(|err| {
                    panic!("C{n} {t:?}: vendor config fails to lower: {err}")
                });
                let r = dev.evaluate(&prog).unwrap_or_else(|err| {
                    panic!("C{n} on {}: vendor config invalid: {err}", dev.name)
                });
                assert!(r.gflops > 0.0);
            }
        }
    }

    #[test]
    fn vendor_config_is_reasonable_not_terrible() {
        // the library kernel must beat the *median* random config —
        // it's expert-tuned, after all
        let task = workloads::conv_task(6, TemplateKind::Gpu);
        let dev = sim_gpu();
        let vendor = dev
            .evaluate(&task.lower(&vendor_config(&task)).unwrap())
            .unwrap()
            .gflops;
        let mut rng = crate::util::Rng::seed_from_u64(1);
        let mut rand_gflops: Vec<f64> = Vec::new();
        for _ in 0..60 {
            let e = task.space.sample(&mut rng);
            if let Ok(r) = dev.evaluate(&task.lower(&e).unwrap()) {
                rand_gflops.push(r.gflops);
            }
        }
        let med = crate::util::quantile(&mut rand_gflops, 0.5);
        assert!(vendor > med, "vendor {vendor} should beat median random {med}");
    }

    #[test]
    fn vendor_config_on_dense_and_matmul() {
        for t in [TemplateKind::Cpu, TemplateKind::Gpu] {
            for def in [ops::dense(1, 1000, 512), ops::matmul(1024, 1024, 1024)] {
                let task = Task::new(def, t);
                let e = vendor_config(&task);
                assert!(task.lower(&e).is_ok());
            }
        }
    }
}
