//! Mini graph compiler for the end-to-end evaluation (§6.3).
//!
//! A [`Graph`] is a DAG of high-level ops. The compiler supports:
//! * **task extraction** — dedupe tunable ops into [`Task`]s (the paper
//!   tunes each distinct conv/dense workload once; Table 1 is exactly
//!   the distinct conv2ds of ResNet-18);
//! * **operator fusion** — fold elementwise epilogues (ReLU) into their
//!   producer reduction op, the optimization the paper highlights as
//!   impossible for fixed-library baselines;
//! * **latency evaluation** — sum per-node simulated latencies under a
//!   schedule lookup (tuned database / vendor baseline / defaults);
//! * **latency decomposition** — attribute the end-to-end latency to
//!   deduplicated tasks weighted by node multiplicity
//!   ([`Graph::latency_by_task`]), the objective the graph-level
//!   trial allocator ([`crate::tuner::scheduler`]) descends.
//!
//! Task-key invariant: schedule lookups are always keyed by the
//! *epilogue-free* task key — the same key [`Graph::tasks`] and
//! [`Graph::weighted_tasks`] emit — even for nodes that carry a fused
//! epilogue after [`Graph::fuse`]. A fused ReLU changes the lowered
//! program (and its simulated cost) but not the knob space, so a config
//! tuned on the bare operator applies verbatim to the fused node.

use crate::expr::ops::{self, Conv2dParams};
use crate::expr::{ComputeDef, Epilogue};
use crate::measure::Measurer;
use crate::schedule::template::{Task, TemplateKind};
use crate::sim::DeviceModel;
use std::collections::HashMap;

/// High-level operator of a network graph.
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    /// Network input (no cost).
    Input {
        /// Tensor shape.
        shape: Vec<i64>,
    },
    /// 2-D convolution (tunable).
    Conv2d(Conv2dParams),
    /// Depthwise 2-D convolution (tunable).
    DepthwiseConv2d(Conv2dParams),
    /// Fully-connected layer (tunable).
    Dense {
        /// Batch size.
        batch: i64,
        /// Output features.
        out_dim: i64,
        /// Input features.
        in_dim: i64,
    },
    /// Max pooling (glue).
    MaxPool {
        /// Batch.
        n: i64,
        /// Channels.
        c: i64,
        /// Input height.
        h: i64,
        /// Input width.
        w: i64,
        /// Window size.
        k: i64,
        /// Stride.
        s: i64,
    },
    /// ReLU activation (glue; fusable into a tunable producer).
    Relu {
        /// Tensor shape.
        shape: Vec<i64>,
    },
    /// Elementwise addition, e.g. a residual connection (glue).
    Add {
        /// Tensor shape.
        shape: Vec<i64>,
    },
    /// Pool/flatten glue — modeled as an elementwise pass.
    Reduce {
        /// Tensor shape.
        shape: Vec<i64>,
    },
}

impl OpKind {
    /// Whether the tuner optimizes this op (vs. glue defaults).
    pub fn tunable(&self) -> bool {
        matches!(
            self,
            OpKind::Conv2d(_) | OpKind::DepthwiseConv2d(_) | OpKind::Dense { .. }
        )
    }

    /// Build the compute definition (with optional fused epilogue).
    pub fn compute(&self, epilogue: Option<Epilogue>) -> Option<ComputeDef> {
        let mut def = match self {
            OpKind::Input { .. } => return None,
            OpKind::Conv2d(p) => ops::conv2d(*p),
            OpKind::DepthwiseConv2d(p) => ops::depthwise_conv2d(*p),
            OpKind::Dense { batch, out_dim, in_dim } => ops::dense(*batch, *out_dim, *in_dim),
            OpKind::MaxPool { n, c, h, w, k, s } => ops::max_pool2d(*n, *c, *h, *w, *k, *s),
            OpKind::Relu { shape } => ops::relu(shape),
            OpKind::Add { shape } => ops::elemwise_add(shape),
            OpKind::Reduce { shape } => ops::relu(shape),
        };
        if let Some(epi) = epilogue {
            def = ops::with_epilogue(def, epi);
        }
        Some(def)
    }
}

/// A graph node.
#[derive(Clone, Debug)]
pub struct Node {
    /// Unique node name (used in latency breakdowns).
    pub name: String,
    /// The operator this node computes.
    pub op: OpKind,
    /// Producer node ids.
    pub inputs: Vec<usize>,
    /// Epilogue fused into this node (set by [`Graph::fuse`]).
    pub fused_epilogue: Option<Epilogue>,
}

/// A network graph.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Network name (e.g. `resnet18`).
    pub name: String,
    /// Nodes in topological (insertion) order.
    pub nodes: Vec<Node>,
}

impl Graph {
    /// Empty graph with a name.
    pub fn new(name: impl Into<String>) -> Self {
        Graph { name: name.into(), nodes: Vec::new() }
    }

    /// Append a node, returning its id.
    pub fn add(&mut self, name: impl Into<String>, op: OpKind, inputs: &[usize]) -> usize {
        self.nodes.push(Node {
            name: name.into(),
            op,
            inputs: inputs.to_vec(),
            fused_epilogue: None,
        });
        self.nodes.len() - 1
    }

    /// Number of consumers per node.
    fn fanout(&self) -> Vec<usize> {
        let mut f = vec![0usize; self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                f[i] += 1;
            }
        }
        f
    }

    /// Operator fusion: a `Relu` whose single producer is a tunable
    /// reduction op is folded into that producer as an epilogue. The
    /// fused graph is what AutoTVM compiles; fixed-library baselines
    /// run the unfused graph (§6.3: fusion "would otherwise be
    /// impossible if we used libraries with a limited set of
    /// operators").
    pub fn fuse(&self) -> Graph {
        let fanout = self.fanout();
        let mut out = self.clone();
        let mut dead = vec![false; out.nodes.len()];
        // map old id -> replacement id (for rewiring consumers)
        let mut replace: HashMap<usize, usize> = HashMap::new();
        for i in 0..out.nodes.len() {
            let node = out.nodes[i].clone();
            if let OpKind::Relu { .. } = node.op {
                if node.inputs.len() == 1 {
                    let p = node.inputs[0];
                    let producer = replace.get(&p).copied().unwrap_or(p);
                    if out.nodes[producer].op.tunable()
                        && fanout[producer] == 1
                        && out.nodes[producer].fused_epilogue.is_none()
                    {
                        out.nodes[producer].fused_epilogue = Some(Epilogue::Relu);
                        dead[i] = true;
                        replace.insert(i, producer);
                    }
                }
            }
        }
        // rewire inputs through replacements, drop dead nodes
        let mut remap = vec![usize::MAX; out.nodes.len()];
        let mut nodes = Vec::new();
        for (i, node) in out.nodes.iter().enumerate() {
            if dead[i] {
                continue;
            }
            let mut n = node.clone();
            for input in n.inputs.iter_mut() {
                let mut j = *input;
                while let Some(&r) = replace.get(&j) {
                    j = r;
                }
                *input = remap[j];
            }
            remap[i] = nodes.len();
            nodes.push(n);
        }
        Graph { name: format!("{}-fused", self.name), nodes }
    }

    /// Extract deduplicated tunable tasks (the paper's workload list;
    /// for ResNet-18 this yields exactly the C1–C12 conv2ds + dense).
    pub fn tasks(&self, template: TemplateKind) -> Vec<Task> {
        self.weighted_tasks(template).into_iter().map(|(t, _)| t).collect()
    }

    /// Deduplicated tunable tasks with their node multiplicity: how many
    /// graph nodes lower to each task. The multiplicity is the static
    /// per-task weight of the graph-level scheduler — a task that
    /// appears four times (ResNet-18's C2) contributes four times its
    /// per-invocation latency to the end-to-end number, so a GFLOPS
    /// improvement on it is worth four times as much trial budget.
    ///
    /// Tasks are keyed epilogue-free (see the module docs), so fused and
    /// unfused instances of the same operator count toward one task.
    pub fn weighted_tasks(&self, template: TemplateKind) -> Vec<(Task, usize)> {
        let mut index: HashMap<String, usize> = HashMap::new();
        let mut tasks: Vec<(Task, usize)> = Vec::new();
        for n in &self.nodes {
            if !n.op.tunable() {
                continue;
            }
            // tasks are tuned without the epilogue: a fused relu does
            // not change the search space materially
            let def = n.op.compute(None).unwrap();
            match index.get(&def.task_key()) {
                Some(&i) => tasks[i].1 += 1,
                None => {
                    index.insert(def.task_key(), tasks.len());
                    tasks.push((Task::new(def, template), 1));
                }
            }
        }
        tasks
    }

    /// Simulated latency of one node under a schedule lookup. Tunable
    /// nodes are looked up by their epilogue-free task (the key
    /// [`Graph::tasks`] emits) but *evaluated* with the fused definition
    /// — a config tuned on the bare op drives the fused kernel. Glue
    /// ops use [`quick_best`] defaults. Returns `None` for cost-free
    /// nodes (inputs).
    fn node_latency(
        &self,
        node: &Node,
        device: &DeviceModel,
        template: TemplateKind,
        lookup: &mut impl FnMut(&Task) -> Option<crate::schedule::space::ConfigEntity>,
    ) -> Option<anyhow::Result<f64>> {
        let def = node.op.compute(node.fused_epilogue)?;
        let task = Task::new(def, template);
        let entity = if node.op.tunable() {
            // lookups are keyed epilogue-free; the base task is only
            // rebuilt when a fused epilogue makes the keys differ (the
            // knob space is identical either way)
            let looked_up = if node.fused_epilogue.is_some() {
                let base =
                    Task::new(node.op.compute(None).expect("tunable ops lower"), template);
                lookup(&base)
            } else {
                lookup(&task)
            };
            // a config replayed from external storage may not index
            // into this build's space; fall back instead of panicking
            looked_up
                .filter(|e| task.space.contains(e))
                .unwrap_or_else(|| quick_best(&task, device, 32, 7))
        } else {
            quick_best(&task, device, 32, 7)
        };
        let run = |e: &crate::schedule::space::ConfigEntity| -> anyhow::Result<Option<f64>> {
            Ok(device.evaluate(&task.lower(e)?).ok().map(|r| r.seconds))
        };
        let secs = match run(&entity) {
            Err(e) => return Some(Err(e)),
            Ok(Some(s)) => s,
            // invalid lookup config → fall back to a safe default
            Ok(None) => {
                let e2 = quick_best(&task, device, 32, 11);
                match run(&e2) {
                    Err(e) => return Some(Err(e)),
                    Ok(s) => s.unwrap_or(f64::INFINITY),
                }
            }
        };
        Some(Ok(secs))
    }

    /// End-to-end latency under a schedule source.
    ///
    /// `lookup(task) -> ConfigEntity` supplies configs for tunable ops
    /// (tuned DB or baseline), keyed by the *epilogue-free* task (see
    /// the module docs); glue ops use [`quick_best`] defaults.
    /// Returns (total seconds, per-node breakdown).
    pub fn latency(
        &self,
        device: &DeviceModel,
        template: TemplateKind,
        mut lookup: impl FnMut(&Task) -> Option<crate::schedule::space::ConfigEntity>,
    ) -> anyhow::Result<(f64, Vec<(String, f64)>)> {
        let mut total = 0.0;
        let mut breakdown = Vec::new();
        for n in &self.nodes {
            let Some(secs) = self.node_latency(n, device, template, &mut lookup) else {
                continue;
            };
            let secs = secs?;
            total += secs;
            breakdown.push((n.name.clone(), secs));
        }
        Ok((total, breakdown))
    }

    /// Latency of the untunable glue alone — the fixed floor of
    /// [`Graph::latency_by_task`] without pricing any tunable node
    /// (which would simulate a default-schedule search per node the
    /// caller then discards).
    pub fn fixed_latency(
        &self,
        device: &DeviceModel,
        template: TemplateKind,
    ) -> anyhow::Result<f64> {
        let mut fixed = 0.0;
        for n in &self.nodes {
            if n.op.tunable() {
                continue;
            }
            let Some(secs) = self.node_latency(n, device, template, &mut |_| None) else {
                continue;
            };
            fixed += secs?;
        }
        Ok(fixed)
    }

    /// End-to-end latency decomposed by task — the scheduler's view of
    /// the graph: each deduplicated tunable task's contribution is its
    /// per-node latency summed over every node that lowers to it (node
    /// multiplicity × per-invocation cost), and everything the tuner
    /// cannot touch (pools, residual adds, unfused activations) is
    /// lumped into a fixed term.
    ///
    /// `per_task` follows [`Graph::weighted_tasks`] order, so
    /// `per_task[i]` is the weighted latency of `weighted_tasks()[i]`.
    pub fn latency_by_task(
        &self,
        device: &DeviceModel,
        template: TemplateKind,
        mut lookup: impl FnMut(&Task) -> Option<crate::schedule::space::ConfigEntity>,
    ) -> anyhow::Result<LatencyByTask> {
        let weighted = self.weighted_tasks(template);
        let index: HashMap<String, usize> =
            weighted.iter().enumerate().map(|(i, (t, _))| (t.key(), i)).collect();
        let mut out = LatencyByTask {
            total: 0.0,
            fixed: 0.0,
            per_task: vec![0.0; weighted.len()],
        };
        for n in &self.nodes {
            let Some(secs) = self.node_latency(n, device, template, &mut lookup) else {
                continue;
            };
            let secs = secs?;
            out.total += secs;
            if n.op.tunable() {
                let key = Task::key_for(
                    &n.op.compute(None).expect("tunable ops lower"),
                    template,
                );
                out.per_task[index[&key]] += secs;
            } else {
                out.fixed += secs;
            }
        }
        Ok(out)
    }
}

/// Per-task latency decomposition of a graph (see
/// [`Graph::latency_by_task`]).
#[derive(Clone, Debug)]
pub struct LatencyByTask {
    /// End-to-end seconds (equals `fixed + per_task.sum()`).
    pub total: f64,
    /// Seconds spent in untunable glue ops — a floor no trial budget
    /// can reduce.
    pub fixed: f64,
    /// Weighted seconds per deduplicated task, indexed like
    /// [`Graph::weighted_tasks`].
    pub per_task: Vec<f64>,
}

/// Stable per-task hash used to decorrelate seeds across tasks.
pub(crate) fn task_salt(task: &Task) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    task.key().hash(&mut h);
    h.finish()
}

/// Deterministic cheap config choice for glue ops: best of `k` seeded
/// random samples under the simulator (both AutoTVM and the baselines
/// use the same glue, so it cancels in comparisons — except where
/// fusion removes the glue entirely).
pub fn quick_best(
    task: &Task,
    device: &DeviceModel,
    k: usize,
    seed: u64,
) -> crate::schedule::space::ConfigEntity {
    let mut rng = crate::util::Rng::seed_from_u64(seed ^ task_salt(task));
    let mut best: Option<(crate::schedule::space::ConfigEntity, f64)> = None;
    for _ in 0..k {
        let e = task.space.sample(&mut rng);
        if let Ok(p) = task.lower(&e) {
            if let Ok(r) = device.evaluate(&p) {
                if best.as_ref().map_or(true, |(_, g)| r.gflops > *g) {
                    best = Some((e, r.gflops));
                }
            }
        }
    }
    best.map(|(e, _)| e).unwrap_or_else(|| task.space.entity(0))
}

/// Tune every task of a graph with the given budget and return a config
/// lookup map keyed by task key (examples use this; long runs persist
/// through [`crate::tuner::db::Database`] instead).
pub fn tune_graph_tasks(
    graph: &Graph,
    template: TemplateKind,
    measurer: &dyn Measurer,
    options: crate::tuner::TuneOptions,
) -> HashMap<String, crate::schedule::space::ConfigEntity> {
    let mut best = HashMap::new();
    for task in graph.tasks(template) {
        let mut o = options.clone();
        o.seed ^= task_salt(&task);
        let res = crate::tuner::tune_gbt(task.clone(), measurer, o);
        if let Some((e, _)) = res.best {
            best.insert(task.key(), e);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::devices::{sim_cpu, sim_gpu};

    fn tiny_graph() -> Graph {
        let mut g = Graph::new("tiny");
        let input = g.add("data", OpKind::Input { shape: vec![1, 16, 16, 16] }, &[]);
        let p = Conv2dParams {
            n: 1, h: 16, w: 16, ic: 16, oc: 16, kh: 3, kw: 3, stride: 1, pad: 1,
        };
        let c1 = g.add("conv1", OpKind::Conv2d(p), &[input]);
        let r1 = g.add("relu1", OpKind::Relu { shape: vec![1, 16, 16, 16] }, &[c1]);
        let c2 = g.add("conv2", OpKind::Conv2d(p), &[r1]);
        let r2 = g.add("relu2", OpKind::Relu { shape: vec![1, 16, 16, 16] }, &[c2]);
        let _add = g.add("res", OpKind::Add { shape: vec![1, 16, 16, 16] }, &[r1, r2]);
        g
    }

    #[test]
    fn fuse_folds_relu_into_single_consumer_conv() {
        let g = tiny_graph();
        let f = g.fuse();
        // both convs have fanout 1 into their relus, so both pairs fuse
        // (relu1's own fanout of 2 is fine: consumers read the fused
        // output)
        assert_eq!(f.nodes.len(), g.nodes.len() - 2);
        let fused: Vec<_> =
            f.nodes.iter().filter(|n| n.fused_epilogue.is_some()).collect();
        assert_eq!(fused.len(), 2);
        // the residual add now reads the fused conv outputs
        let add = f.nodes.iter().find(|n| matches!(n.op, OpKind::Add { .. })).unwrap();
        for &i in &add.inputs {
            assert!(matches!(f.nodes[i].op, OpKind::Conv2d(_)), "{:?}", f.nodes[i].name);
        }
    }

    #[test]
    fn fuse_rewires_consumers() {
        let mut g = Graph::new("chain");
        let input = g.add("data", OpKind::Input { shape: vec![1, 8, 8, 8] }, &[]);
        let p = Conv2dParams {
            n: 1, h: 8, w: 8, ic: 8, oc: 8, kh: 3, kw: 3, stride: 1, pad: 1,
        };
        let c = g.add("conv", OpKind::Conv2d(p), &[input]);
        let r = g.add("relu", OpKind::Relu { shape: vec![1, 8, 8, 8] }, &[c]);
        let _pool =
            g.add("pool", OpKind::MaxPool { n: 1, c: 8, h: 8, w: 8, k: 2, s: 2 }, &[r]);
        let f = g.fuse();
        assert_eq!(f.nodes.len(), 3);
        let pool = f.nodes.iter().find(|n| n.name == "pool").unwrap();
        assert_eq!(f.nodes[pool.inputs[0]].name, "conv");
    }

    #[test]
    fn task_extraction_dedupes() {
        let g = tiny_graph();
        assert_eq!(g.tasks(TemplateKind::Gpu).len(), 1);
    }

    #[test]
    fn fused_graph_is_faster_than_unfused() {
        let mut g = Graph::new("chain");
        let input = g.add("data", OpKind::Input { shape: vec![1, 16, 16, 16] }, &[]);
        let p = Conv2dParams {
            n: 1, h: 16, w: 16, ic: 16, oc: 16, kh: 3, kw: 3, stride: 1, pad: 1,
        };
        let c = g.add("conv", OpKind::Conv2d(p), &[input]);
        let _r = g.add("relu", OpKind::Relu { shape: vec![1, 16, 16, 16] }, &[c]);
        let f = g.fuse();
        let dev = sim_gpu();
        let (t_unfused, _) = g.latency(&dev, TemplateKind::Gpu, |_| None).unwrap();
        let (t_fused, _) = f.latency(&dev, TemplateKind::Gpu, |_| None).unwrap();
        assert!(t_fused < t_unfused, "fusion should help: {t_fused} !< {t_unfused}");
    }

    #[test]
    fn latency_breakdown_covers_cost_nodes() {
        let g = tiny_graph();
        let dev = sim_cpu();
        let (total, breakdown) = g.latency(&dev, TemplateKind::Cpu, |_| None).unwrap();
        assert!(total > 0.0);
        assert_eq!(breakdown.len(), g.nodes.len() - 1); // input free
        assert!((breakdown.iter().map(|(_, s)| s).sum::<f64>() - total).abs() < 1e-12);
    }

    #[test]
    fn weighted_tasks_count_multiplicity() {
        // tiny_graph has the same conv twice → one task, weight 2
        let g = tiny_graph();
        let w = g.weighted_tasks(TemplateKind::Gpu);
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].1, 2);
        // tasks() is the weight-stripped view
        assert_eq!(g.tasks(TemplateKind::Gpu).len(), 1);
    }

    #[test]
    fn latency_by_task_attributes_multiplicity_and_fixed_cost() {
        let g = tiny_graph();
        let dev = sim_cpu();
        let dec = g.latency_by_task(&dev, TemplateKind::Cpu, |_| None).unwrap();
        let (total, breakdown) = g.latency(&dev, TemplateKind::Cpu, |_| None).unwrap();
        // decomposition sums to the plain latency
        assert!((dec.total - total).abs() < 1e-12);
        assert!(
            (dec.fixed + dec.per_task.iter().sum::<f64>() - dec.total).abs() < 1e-12
        );
        // the duplicated conv's bucket holds both node contributions
        assert_eq!(dec.per_task.len(), 1);
        let conv_nodes: f64 = breakdown
            .iter()
            .filter(|(n, _)| n.starts_with("conv"))
            .map(|(_, s)| s)
            .sum();
        assert!((dec.per_task[0] - conv_nodes).abs() < 1e-12);
        // untunable glue (relus + residual add) is a nonzero fixed floor
        assert!(dec.fixed > 0.0);
        // the glue-only fast path agrees with the full decomposition
        assert_eq!(g.fixed_latency(&dev, TemplateKind::Cpu).unwrap(), dec.fixed);
    }

    #[test]
    fn untunable_only_graph_is_all_fixed_cost() {
        let mut g = Graph::new("glue");
        let input = g.add("data", OpKind::Input { shape: vec![1, 8, 8, 8] }, &[]);
        let _pool =
            g.add("pool", OpKind::MaxPool { n: 1, c: 8, h: 8, w: 8, k: 2, s: 2 }, &[input]);
        let dev = sim_cpu();
        let dec = g.latency_by_task(&dev, TemplateKind::Cpu, |_| None).unwrap();
        assert!(g.tasks(TemplateKind::Cpu).is_empty());
        assert!(dec.per_task.is_empty());
        assert!(dec.fixed > 0.0);
        assert_eq!(dec.fixed, dec.total);
    }

    #[test]
    fn empty_graph_edge_cases() {
        let g = Graph::new("empty");
        assert!(g.tasks(TemplateKind::Gpu).is_empty());
        let f = g.fuse();
        assert!(f.nodes.is_empty());
        let dev = sim_gpu();
        let (total, breakdown) = g.latency(&dev, TemplateKind::Gpu, |_| None).unwrap();
        assert_eq!(total, 0.0);
        assert!(breakdown.is_empty());
        let dec = g.latency_by_task(&dev, TemplateKind::Gpu, |_| None).unwrap();
        assert_eq!((dec.total, dec.fixed), (0.0, 0.0));
        assert!(dec.per_task.is_empty());
    }

    #[test]
    fn fused_nodes_are_looked_up_by_epilogue_free_key() {
        // regression: tuned configs used to miss fused nodes because the
        // lookup key carried the `_relu` epilogue suffix
        let mut g = Graph::new("chain");
        let input = g.add("data", OpKind::Input { shape: vec![1, 16, 16, 16] }, &[]);
        let p = Conv2dParams {
            n: 1, h: 16, w: 16, ic: 16, oc: 16, kh: 3, kw: 3, stride: 1, pad: 1,
        };
        let c = g.add("conv", OpKind::Conv2d(p), &[input]);
        let _r = g.add("relu", OpKind::Relu { shape: vec![1, 16, 16, 16] }, &[c]);
        let f = g.fuse();
        assert!(f.nodes.iter().any(|n| n.fused_epilogue.is_some()));
        let expected: Vec<String> =
            g.tasks(TemplateKind::Gpu).iter().map(|t| t.key()).collect();
        let dev = sim_gpu();
        let mut seen = Vec::new();
        f.latency(&dev, TemplateKind::Gpu, |t| {
            seen.push(t.key());
            None
        })
        .unwrap();
        assert_eq!(seen, expected, "fused node must be keyed like tasks()");
    }

    #[test]
    fn quick_best_is_deterministic() {
        let p = Conv2dParams {
            n: 1, h: 8, w: 8, ic: 8, oc: 8, kh: 3, kw: 3, stride: 1, pad: 1,
        };
        let task = Task::new(ops::conv2d(p), TemplateKind::Cpu);
        let dev = sim_cpu();
        let a = quick_best(&task, &dev, 16, 3);
        let b = quick_best(&task, &dev, 16, 3);
        assert_eq!(a, b);
    }
}
