//! Configuration space: knobs and config entities.
//!
//! A [`ConfigSpace`] is the enumerable set of template knob choices for
//! one operator; a [`ConfigEntity`] is a point `s ∈ S_e`, decomposed
//! into components `s = [s_1 … s_m]` (one per knob) — the decomposition
//! the diversity-aware objective (Eq. 3) counts over.


/// One tunable dimension of the space.
#[derive(Clone, Debug, PartialEq)]
pub enum Knob {
    /// Multi-level tiling of an axis: every ordered factorization of
    /// `extent` into `parts` factors.
    Split {
        /// Knob name (usually the axis name).
        name: String,
        /// The tiled axis extent.
        extent: i64,
        /// Number of tile levels.
        parts: usize,
        /// All ordered factorizations, outermost first.
        options: Vec<Vec<i64>>,
    },
    /// Categorical choice over integer values.
    Choice {
        /// Knob name.
        name: String,
        /// The selectable values.
        options: Vec<i64>,
    },
}

impl Knob {
    /// Knob name.
    pub fn name(&self) -> &str {
        match self {
            Knob::Split { name, .. } | Knob::Choice { name, .. } => name,
        }
    }

    /// Number of selectable options.
    pub fn cardinality(&self) -> usize {
        match self {
            Knob::Split { options, .. } => options.len(),
            Knob::Choice { options, .. } => options.len(),
        }
    }
}

/// Enumerate all ordered factorizations of `n` into `parts` factors
/// (each ≥ 1, product = `n`), outermost first.
pub fn factorizations(n: i64, parts: usize) -> Vec<Vec<i64>> {
    assert!(n >= 1 && parts >= 1);
    if parts == 1 {
        return vec![vec![n]];
    }
    let mut out = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            for first in [d, n / d] {
                for mut rest in factorizations(n / first, parts - 1) {
                    let mut v = Vec::with_capacity(parts);
                    v.push(first);
                    v.append(&mut rest);
                    out.push(v);
                }
                if d * d == n {
                    break;
                }
            }
        }
        d += 1;
    }
    out.sort();
    out.dedup();
    out
}

/// One point of the space: a choice index per knob.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ConfigEntity {
    /// One option index per knob.
    pub choices: Vec<u32>,
}

impl ConfigEntity {
    /// The component `s_j` used by the diversity objective.
    pub fn component(&self, j: usize) -> u32 {
        self.choices[j]
    }
}

/// The knob space of one template-instantiated operator.
#[derive(Clone, Debug, PartialEq)]
pub struct ConfigSpace {
    /// The tunable dimensions, in template order.
    pub knobs: Vec<Knob>,
}

impl ConfigSpace {
    /// |S_e| — the number of candidate programs.
    pub fn size(&self) -> u64 {
        self.knobs.iter().map(|k| k.cardinality() as u64).product()
    }

    /// Number of knobs (the `m` of `s = [s_1 … s_m]`).
    pub fn num_knobs(&self) -> usize {
        self.knobs.len()
    }

    /// Whether a stored choices vector indexes validly into this space
    /// (arity and per-knob option range). Guard configs replayed from
    /// external storage before lowering them — a record written by a
    /// build with a different knob layout would panic the instantiator.
    pub fn contains_choices(&self, choices: &[u32]) -> bool {
        choices.len() == self.knobs.len()
            && choices
                .iter()
                .zip(self.knobs.iter())
                .all(|(&c, k)| (c as usize) < k.cardinality())
    }

    /// [`ConfigSpace::contains_choices`] over an entity.
    pub fn contains(&self, e: &ConfigEntity) -> bool {
        self.contains_choices(&e.choices)
    }

    /// Index of the knob named `name`, if present.
    pub fn knob_index(&self, name: &str) -> Option<usize> {
        self.knobs.iter().position(|k| k.name() == name)
    }

    /// Decode a flat index (mixed radix, first knob most significant).
    ///
    /// `index` must be `< size()`: out-of-range indices used to wrap
    /// silently (breaking the [`ConfigSpace::index_of`] roundtrip), so
    /// debug builds now assert. Callers with an arbitrary integer in
    /// hand must clamp explicitly (`index % size()`).
    pub fn entity(&self, mut index: u64) -> ConfigEntity {
        debug_assert!(
            index < self.size(),
            "entity index {index} out of range for space of size {}",
            self.size()
        );
        let mut choices = vec![0u32; self.knobs.len()];
        for (i, k) in self.knobs.iter().enumerate().rev() {
            let c = k.cardinality() as u64;
            choices[i] = (index % c) as u32;
            index /= c;
        }
        ConfigEntity { choices }
    }

    /// Inverse of [`ConfigSpace::entity`].
    pub fn index_of(&self, e: &ConfigEntity) -> u64 {
        let mut idx = 0u64;
        for (k, &c) in self.knobs.iter().zip(&e.choices) {
            idx = idx * k.cardinality() as u64 + c as u64;
        }
        idx
    }

    /// Uniform random entity.
    pub fn sample(&self, rng: &mut crate::util::Rng) -> ConfigEntity {
        ConfigEntity {
            choices: self
                .knobs
                .iter()
                .map(|k| rng.gen_range(0..k.cardinality()) as u32)
                .collect(),
        }
    }

    /// SA/GA neighbor: re-draw one random knob.
    pub fn mutate(&self, e: &ConfigEntity, rng: &mut crate::util::Rng) -> ConfigEntity {
        self.mutate_knob(e, rng).0
    }

    /// [`ConfigSpace::mutate`], also reporting *which* knob was
    /// re-drawn — the incremental featurizer recomputes only that
    /// knob's feature slice. Draws the identical RNG sequence as
    /// `mutate` (it *is* `mutate`), so fixed-seed runs are unchanged by
    /// callers switching between the two.
    pub fn mutate_knob(
        &self,
        e: &ConfigEntity,
        rng: &mut crate::util::Rng,
    ) -> (ConfigEntity, usize) {
        let mut out = e.clone();
        let j = rng.gen_range(0..self.knobs.len());
        let c = self.knobs[j].cardinality();
        if c > 1 {
            let mut nv = rng.gen_range(0..c) as u32;
            while nv == e.choices[j] {
                nv = rng.gen_range(0..c) as u32;
            }
            out.choices[j] = nv;
        }
        (out, j)
    }

    /// Knob-wise uniform crossover (GA baseline).
    pub fn crossover(
        &self,
        a: &ConfigEntity,
        b: &ConfigEntity,
        rng: &mut crate::util::Rng,
    ) -> ConfigEntity {
        ConfigEntity {
            choices: a
                .choices
                .iter()
                .zip(&b.choices)
                .map(|(&x, &y)| if rng.gen_bool(0.5) { x } else { y })
                .collect(),
        }
    }

    /// Number of feature dimensions knob `j` contributes to
    /// [`ConfigSpace::config_features`] (split → one per tile level,
    /// choice → 1).
    pub fn knob_feature_dim(&self, j: usize) -> usize {
        match &self.knobs[j] {
            Knob::Split { parts, .. } => *parts,
            Knob::Choice { .. } => 1,
        }
    }

    /// Offset of knob `j`'s slice within the
    /// [`ConfigSpace::config_features`] vector (knob slices are
    /// contiguous, in knob order).
    pub fn knob_feature_offset(&self, j: usize) -> usize {
        (0..j).map(|i| self.knob_feature_dim(i)).sum()
    }

    /// Write knob `j`'s feature slice for option `choice` into `out`
    /// (length [`ConfigSpace::knob_feature_dim`]). The single source of
    /// truth for per-knob features — `config_features` delegates here,
    /// so incremental slice updates cannot drift from the full path.
    pub fn knob_features_into(&self, j: usize, choice: u32, out: &mut [f64]) {
        match &self.knobs[j] {
            Knob::Split { options, .. } => {
                for (o, &v) in out.iter_mut().zip(&options[choice as usize]) {
                    *o = (v as f64).log2();
                }
            }
            Knob::Choice { options, .. } => {
                out[0] = (options[choice as usize] as f64 + 1.0).log2();
            }
        }
    }

    /// Configuration-space feature vector (the non-invariant
    /// representation of Fig. 9): log2 tile factors for split knobs,
    /// raw value for choices.
    pub fn config_features(&self, e: &ConfigEntity) -> Vec<f64> {
        let dim: usize = (0..self.knobs.len()).map(|j| self.knob_feature_dim(j)).sum();
        let mut f = vec![0.0; dim];
        let mut off = 0;
        for (j, &c) in e.choices.iter().enumerate() {
            let d = self.knob_feature_dim(j);
            self.knob_features_into(j, c, &mut f[off..off + d]);
            off += d;
        }
        f
    }

    /// Human-readable rendering of a config.
    pub fn describe(&self, e: &ConfigEntity) -> String {
        let mut parts = Vec::new();
        for (k, &c) in self.knobs.iter().zip(&e.choices) {
            match k {
                Knob::Split { name, options, .. } => {
                    parts.push(format!("{name}={:?}", options[c as usize]))
                }
                Knob::Choice { name, options, .. } => {
                    parts.push(format!("{name}={}", options[c as usize]))
                }
            }
        }
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn factorizations_cover_and_multiply() {
        let f = factorizations(12, 2);
        assert_eq!(f.len(), 6); // (1,12)(2,6)(3,4)(4,3)(6,2)(12,1)
        for v in &f {
            assert_eq!(v.iter().product::<i64>(), 12);
        }
        let f3 = factorizations(8, 3);
        // ordered factorizations of 2^3 into 3 parts: C(3+2,2) = 10
        assert_eq!(f3.len(), 10);
    }

    #[test]
    fn factorizations_of_one() {
        assert_eq!(factorizations(1, 3), vec![vec![1, 1, 1]]);
    }

    fn space() -> ConfigSpace {
        ConfigSpace {
            knobs: vec![
                Knob::Split {
                    name: "tile_y".into(),
                    extent: 8,
                    parts: 2,
                    options: factorizations(8, 2),
                },
                Knob::Choice { name: "vec".into(), options: vec![0, 1] },
            ],
        }
    }

    #[test]
    fn entity_roundtrip() {
        let s = space();
        assert_eq!(s.size(), 8);
        for i in 0..s.size() {
            let e = s.entity(i);
            assert_eq!(s.index_of(&e), i);
        }
    }

    #[test]
    fn entity_boundary_roundtrip() {
        let s = space();
        let last = s.size() - 1;
        assert_eq!(s.index_of(&s.entity(0)), 0);
        assert_eq!(s.index_of(&s.entity(last)), last);
        // the last entity picks the last option of every knob
        let e = s.entity(last);
        for (k, &c) in s.knobs.iter().zip(&e.choices) {
            assert_eq!(c as usize, k.cardinality() - 1);
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    #[cfg(debug_assertions)]
    fn entity_out_of_range_asserts_in_debug() {
        let s = space();
        let _ = s.entity(s.size());
    }

    #[test]
    fn mutate_changes_exactly_one_knob() {
        let s = space();
        let mut rng = Rng::seed_from_u64(0);
        let e = s.sample(&mut rng);
        for _ in 0..20 {
            let m = s.mutate(&e, &mut rng);
            let diff = e.choices.iter().zip(&m.choices).filter(|(a, b)| a != b).count();
            assert!(diff <= 1);
        }
    }

    #[test]
    fn config_features_dimension() {
        let s = space();
        let e = s.entity(0);
        // split of 2 parts -> 2 dims, choice -> 1 dim
        assert_eq!(s.config_features(&e).len(), 3);
    }

    #[test]
    fn knob_slices_tile_the_feature_vector() {
        let s = space();
        assert_eq!(s.knob_feature_dim(0), 2);
        assert_eq!(s.knob_feature_dim(1), 1);
        assert_eq!(s.knob_feature_offset(0), 0);
        assert_eq!(s.knob_feature_offset(1), 2);
        // updating one knob's slice in place == recomputing from scratch
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..30 {
            let e = s.sample(&mut rng);
            let (m, j) = s.mutate_knob(&e, &mut rng);
            let mut row = s.config_features(&e);
            let off = s.knob_feature_offset(j);
            let d = s.knob_feature_dim(j);
            s.knob_features_into(j, m.choices[j], &mut row[off..off + d]);
            assert_eq!(row, s.config_features(&m));
        }
    }

    #[test]
    fn mutate_knob_matches_mutate_rng_stream() {
        let s = space();
        let e = s.sample(&mut Rng::seed_from_u64(9));
        let mut r1 = Rng::seed_from_u64(77);
        let mut r2 = Rng::seed_from_u64(77);
        for _ in 0..50 {
            let a = s.mutate(&e, &mut r1);
            let (b, j) = s.mutate_knob(&e, &mut r2);
            assert_eq!(a, b);
            assert!(j < s.num_knobs());
        }
    }
}
