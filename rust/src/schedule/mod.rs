//! Schedule space `S_e` — transformation descriptions from expression to
//! low-level code (§2 of the paper).
//!
//! A [`Schedule`] is a declarative set of choices consumed by
//! [`crate::lower`]: multi-level tiling of every axis, loop ordering,
//! annotations (unroll / vectorize / parallel / GPU thread binding),
//! shared-memory cache reads and a local accumulator (cache write) —
//! the primitive set the paper takes from TVM [9].
//!
//! [`space::ConfigSpace`] enumerates the template knobs and
//! [`space::ConfigEntity`] is one point `s ∈ S_e`; templates in
//! [`template`] map an operator to its space and a config to a
//! `Schedule`.

pub mod sketch;
pub mod space;
pub mod template;

use crate::ast::ForKind;
use std::collections::HashMap;

/// Reference to one leaf loop produced by splitting: axis `axis`
/// (index into spatial-then-reduce axes), tile level `part`
/// (0 = outermost).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LeafRef {
    /// Axis index (spatial axes first, then reduce axes).
    pub axis: usize,
    /// Tile level (0 = outermost).
    pub part: usize,
}

/// Stage a tensor's tile into on-chip shared memory.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheRead {
    /// The staged tensor.
    pub tensor: String,
    /// Order position: the copy nest is emitted immediately before the
    /// loop at this position of [`Schedule::order`].
    pub at: usize,
}

/// A full schedule `s ∈ S_e`.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    /// Per axis (spatial axes first, then reduce axes): tile sizes,
    /// outermost first. The product must equal the axis extent. A
    /// single-element vector means "unsplit".
    pub splits: Vec<Vec<i64>>,
    /// Permutation of all leaves.
    pub order: Vec<LeafRef>,
    /// Explicit annotations (Parallel / BlockBind / ThreadBind).
    pub annotations: HashMap<LeafRef, ForKind>,
    /// Shared-memory staging of input tiles.
    pub cache_reads: Vec<CacheRead>,
    /// Loop kind of shared-memory copy nests. GPU templates use
    /// `ThreadBind` to model cooperative loading (the tile is fetched
    /// once per block, distributed across its threads).
    pub copy_kind: ForKind,
    /// Accumulate into a register/local tile, write back once.
    pub cache_write: bool,
    /// Auto-unroll: innermost serial loops whose cumulative extent stays
    /// ≤ this step are marked `Unrolled` (0 disables).
    pub unroll_max_step: i64,
    /// Mark the innermost leaf `Vectorized`.
    pub vectorize_inner: bool,
}

impl Schedule {
    /// Number of leaves (= loops of the main compute nest).
    pub fn num_leaves(&self) -> usize {
        self.splits.iter().map(|s| s.len()).sum()
    }

    /// Extent of a leaf.
    pub fn leaf_extent(&self, leaf: LeafRef) -> i64 {
        self.splits[leaf.axis][leaf.part]
    }

    /// Validate structural invariants against axis extents
    /// (spatial-then-reduce order must match `splits`).
    pub fn validate(&self, extents: &[i64]) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.splits.len() == extents.len(),
            "splits arity {} != axes {}",
            self.splits.len(),
            extents.len()
        );
        for (i, (sizes, &ext)) in self.splits.iter().zip(extents).enumerate() {
            let prod: i64 = sizes.iter().product();
            anyhow::ensure!(!sizes.is_empty(), "axis {i} has empty split");
            anyhow::ensure!(
                prod == ext,
                "axis {i}: tile sizes {sizes:?} multiply to {prod}, extent {ext}"
            );
            anyhow::ensure!(sizes.iter().all(|&s| s >= 1), "axis {i}: nonpositive tile");
        }
        let mut seen = std::collections::HashSet::new();
        for l in &self.order {
            anyhow::ensure!(
                l.axis < self.splits.len() && l.part < self.splits[l.axis].len(),
                "order references missing leaf {l:?}"
            );
            anyhow::ensure!(seen.insert(*l), "leaf {l:?} ordered twice");
        }
        anyhow::ensure!(
            seen.len() == self.num_leaves(),
            "order covers {} of {} leaves",
            seen.len(),
            self.num_leaves()
        );
        for c in &self.cache_reads {
            anyhow::ensure!(c.at < self.order.len(), "cache read past order end");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_sched() -> Schedule {
        Schedule {
            splits: vec![vec![4, 8], vec![32]],
            order: vec![
                LeafRef { axis: 0, part: 0 },
                LeafRef { axis: 1, part: 0 },
                LeafRef { axis: 0, part: 1 },
            ],
            annotations: HashMap::new(),
            cache_reads: vec![],
            copy_kind: ForKind::Serial,
            cache_write: false,
            unroll_max_step: 0,
            vectorize_inner: false,
        }
    }

    #[test]
    fn validate_ok() {
        simple_sched().validate(&[32, 32]).unwrap();
    }

    #[test]
    fn validate_rejects_bad_product() {
        let s = simple_sched();
        assert!(s.validate(&[33, 32]).is_err());
    }

    #[test]
    fn validate_rejects_duplicate_leaf() {
        let mut s = simple_sched();
        s.order[2] = LeafRef { axis: 0, part: 0 };
        assert!(s.validate(&[32, 32]).is_err());
    }

    #[test]
    fn validate_rejects_incomplete_order() {
        let mut s = simple_sched();
        s.order.pop();
        assert!(s.validate(&[32, 32]).is_err());
    }
}
