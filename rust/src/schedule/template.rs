//! Schedule templates: operator → config space → schedule.
//!
//! Mirrors AutoTVM's per-backend templates. A template decides which
//! axes get multi-level tiling, the canonical loop-order interleaving,
//! GPU thread binding and shared-memory caching, and the annotation
//! knobs (auto-unroll step, vectorization) — together they define `S_e`.

use super::space::{factorizations, ConfigEntity, ConfigSpace, Knob};
use super::{CacheRead, LeafRef, Schedule};
use crate::ast::ForKind;
use crate::expr::ComputeDef;
use std::collections::HashMap;

/// Device class a template targets (device *models* live in
/// [`crate::sim`]; Mali uses the GPU template with its own limits).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TemplateKind {
    /// Multi-core CPU: 3-level spatial tiling, parallel outer, vectorized
    /// inner, optional local accumulator.
    Cpu,
    /// GPU: block/thread/inner tiling, shared-memory cache reads,
    /// register accumulator.
    Gpu,
}

impl TemplateKind {
    /// The template a device class compiles under: CPUs take the CPU
    /// template; everything else (server GPUs, Mali, TPU-style
    /// accelerators) takes the GPU template, matching the per-device
    /// constructors in [`crate::sim::devices`]. The heterogeneous
    /// scheduler uses this to derive each fleet device's task set.
    pub fn for_class(class: crate::sim::DeviceClass) -> TemplateKind {
        match class {
            crate::sim::DeviceClass::Cpu => TemplateKind::Cpu,
            crate::sim::DeviceClass::Gpu => TemplateKind::Gpu,
        }
    }
}

/// A tunable operator: expression + template + knob space.
#[derive(Clone, Debug)]
pub struct Task {
    /// The operator expression.
    pub def: ComputeDef,
    /// Backend template the space was built for.
    pub template: TemplateKind,
    /// The enumerable knob space `S_e`.
    pub space: ConfigSpace,
    /// When set, the task searches the Ansor-style sketch space
    /// ([`super::sketch`]) instead of the hand template: the space's
    /// first knob selects a sketch and [`Task::schedule`] dispatches to
    /// [`super::sketch::instantiate_sketch`]. `None` = hand template.
    pub sketches: Option<std::sync::Arc<Vec<super::sketch::Sketch>>>,
}

impl Task {
    /// Build the task (and its knob space) for an operator under a
    /// template.
    pub fn new(def: ComputeDef, template: TemplateKind) -> Self {
        let space = build_space(&def, template);
        Task { def, template, space, sketches: None }
    }

    /// Build the task over the rule-derived sketch space instead of the
    /// hand template. The template space is strictly contained: every
    /// template config maps to a sketch config with the identical
    /// schedule via [`super::sketch::embed_template_config`].
    pub fn with_sketches(def: ComputeDef, template: TemplateKind) -> Self {
        let sketches = super::sketch::generate(&def, template);
        let space = super::sketch::sketch_space(&def, template, &sketches);
        Task { def, template, space, sketches: Some(std::sync::Arc::new(sketches)) }
    }

    /// Short identity for the database / transfer learning. Sketch
    /// tasks get a `+sketch` suffix: their choice indices are
    /// meaningless in the template space (and vice versa), so the two
    /// must never share DB records.
    pub fn key(&self) -> String {
        let base = Task::key_for(&self.def, self.template);
        if self.sketches.is_some() {
            format!("{base}+sketch")
        } else {
            base
        }
    }

    /// Whether the structure-cached delta featurization path applies.
    /// Sketch tasks opt out: their knob layout (leading sketch
    /// selector) doesn't match the positional contract of
    /// [`Task::split_sizes`] / [`Task::structure_key`], so the
    /// featurizer falls back to full featurization for them.
    pub fn delta_capable(&self) -> bool {
        self.sketches.is_none()
    }

    /// The [`Task::key`] an operator would get under `template`,
    /// without building its config space (cheap key derivation for
    /// lookup/indexing paths).
    pub fn key_for(def: &ComputeDef, template: TemplateKind) -> String {
        format!("{}@{:?}", def.task_key(), template)
    }

    /// Map a config to a schedule.
    pub fn schedule(&self, e: &ConfigEntity) -> Schedule {
        match &self.sketches {
            Some(sk) => super::sketch::instantiate_sketch(
                &self.def,
                self.template,
                sk,
                &self.space,
                e,
            ),
            None => instantiate(&self.def, self.template, &self.space, e),
        }
    }

    /// `g(e, s)` — convenience wrapper over [`crate::lower::lower`].
    pub fn lower(&self, e: &ConfigEntity) -> anyhow::Result<crate::ast::Program> {
        let sched = self.schedule(e);
        crate::lower::lower(&self.def, &sched)
    }

    /// [`Task::lower`] plus the config's [`Task::structure_key`] — the
    /// entry point of the structure-cached analysis path
    /// ([`crate::ast::analysis::StructureCache`]).
    pub fn lower_keyed(
        &self,
        e: &ConfigEntity,
    ) -> anyhow::Result<(crate::ast::Program, u64)> {
        Ok((self.lower(e)?, self.structure_key(e)))
    }

    /// Split sizes of `axis` (spatial axes first, then reduce axes)
    /// under config `e`, read straight from the knob options without
    /// allocating — the delta-featurization hot path calls this per
    /// chain loop.
    pub fn split_sizes(&self, e: &ConfigEntity, axis: usize) -> &[i64] {
        match &self.space.knobs[axis] {
            Knob::Split { options, .. } => &options[e.choices[axis] as usize],
            _ => unreachable!("knob {axis} must be a split"),
        }
    }

    /// Key identifying the *structure* of the program `lower(e)` emits:
    /// two configs with equal keys lower to programs differing only in
    /// loop extents and index coefficients (identical chain topology,
    /// loop kinds, buffer set and guards). Hashes, in leaf order, the
    /// raw annotation kinds (which on CPU depend on whether the outer
    /// tile is > 1) and the effective kinds after vectorize-inner /
    /// auto-unroll (which depend on extents and the unroll knob), plus
    /// the `cache_write` flag. Everything else the lowering emits is
    /// fixed by the template. Extents themselves are excluded — that is
    /// the whole point: configs sharing a key can reuse one donor
    /// analysis through delta replay.
    pub fn structure_key(&self, e: &ConfigEntity) -> u64 {
        debug_assert!(
            self.delta_capable(),
            "structure_key is template-only; gate on Task::delta_capable first"
        );
        let ns = self.def.axes.len();
        let nr = self.def.reduce_axes.len();
        let get_choice = |name: &str| -> i64 {
            let i = self.space.knob_index(name).unwrap();
            match &self.space.knobs[i] {
                Knob::Choice { options, .. } => options[e.choices[i] as usize],
                _ => unreachable!(),
            }
        };
        let unroll = get_choice("unroll");
        let vec = get_choice("vec") != 0;
        let cache_write = match self.template {
            TemplateKind::Cpu => get_choice("cache_write") != 0,
            TemplateKind::Gpu => true,
        };

        let order = leaf_order(ns, nr, spatial_parts(self.template));
        let mut kinds = Vec::with_capacity(order.len());
        let mut extents = Vec::with_capacity(order.len());
        for rf in &order {
            let sizes = self.split_sizes(e, rf.axis);
            // mirror of the annotation block in `instantiate`
            let kind = match self.template {
                TemplateKind::Cpu if rf.axis < ns && rf.part == 0 && sizes[0] > 1 => {
                    ForKind::Parallel
                }
                TemplateKind::Gpu if rf.axis < ns && rf.part == 0 => ForKind::BlockBind,
                TemplateKind::Gpu if rf.axis < ns && rf.part == 1 => ForKind::ThreadBind,
                _ => ForKind::Serial,
            };
            kinds.push(kind);
            extents.push(sizes[rf.part]);
        }

        let mut h = 0xcbf29ce484222325u64;
        mix(&mut h, cache_write as u64);
        mix(&mut h, kinds.len() as u64);
        for k in &kinds {
            mix(&mut h, *k as u64);
        }
        // mirror of `Lowering::effective_kinds`
        if vec {
            if let Some(last) = kinds.last_mut() {
                if *last == ForKind::Serial {
                    *last = ForKind::Vectorized;
                }
            }
        }
        let mut cum = 1i64;
        for i in (0..kinds.len()).rev() {
            cum = cum.saturating_mul(extents[i]);
            if cum > unroll {
                break;
            }
            if kinds[i] == ForKind::Serial {
                kinds[i] = ForKind::Unrolled;
            }
        }
        for k in &kinds {
            mix(&mut h, *k as u64);
        }
        h
    }
}

/// One FNV-1a step.
fn mix(h: &mut u64, x: u64) {
    *h ^= x;
    *h = h.wrapping_mul(0x100000001b3);
}

/// How many tile levels each axis gets.
fn spatial_parts(t: TemplateKind) -> usize {
    match t {
        TemplateKind::Cpu => 3,
        TemplateKind::Gpu => 3, // block / thread / inner
    }
}

/// Build the knob space for an operator under a template.
///
/// Knob layout (consumed positionally by [`instantiate`]):
/// one `Split` per axis (spatial axes first, then reduce axes; axes of
/// extent 1 get a degenerate single-option split), then `unroll`, then
/// `vec`, then (CPU only) `cache_write`.
pub fn build_space(def: &ComputeDef, t: TemplateKind) -> ConfigSpace {
    let sp = spatial_parts(t);
    let mut knobs = Vec::new();
    for ax in def.axes.iter() {
        let opts = if ax.extent == 1 {
            vec![vec![1; sp]]
        } else {
            factorizations(ax.extent, sp)
        };
        knobs.push(Knob::Split {
            name: format!("tile_{}", ax.name),
            extent: ax.extent,
            parts: sp,
            options: opts,
        });
    }
    for ax in def.reduce_axes.iter() {
        let opts =
            if ax.extent == 1 { vec![vec![1, 1]] } else { factorizations(ax.extent, 2) };
        knobs.push(Knob::Split {
            name: format!("tile_{}", ax.name),
            extent: ax.extent,
            parts: 2,
            options: opts,
        });
    }
    let unroll_opts = match t {
        TemplateKind::Cpu => vec![0, 4, 16, 64],
        TemplateKind::Gpu => vec![0, 16, 64, 512],
    };
    knobs.push(Knob::Choice { name: "unroll".into(), options: unroll_opts });
    knobs.push(Knob::Choice { name: "vec".into(), options: vec![0, 1] });
    if t == TemplateKind::Cpu {
        knobs.push(Knob::Choice { name: "cache_write".into(), options: vec![0, 1] });
    }
    ConfigSpace { knobs }
}

/// Canonical interleaved leaf order `S0.. R0.. S1.. R1.. S2..` shared
/// by [`instantiate`] and [`Task::structure_key`] — R0 sits between
/// the outer and middle spatial tiles, R1 just outside the innermost
/// spatial tiles. Delegates to the sketch module's generalized
/// interleaving with the template's fixed 2-level reduce tiling, so
/// the two stay a single source of truth.
fn leaf_order(ns: usize, nr: usize, sp: usize) -> Vec<LeafRef> {
    super::sketch::interleaved_order(ns, nr, sp, 2)
}

/// Instantiate a schedule from a config entity.
pub fn instantiate(
    def: &ComputeDef,
    t: TemplateKind,
    space: &ConfigSpace,
    e: &ConfigEntity,
) -> Schedule {
    let ns = def.axes.len();
    let nr = def.reduce_axes.len();
    let mut splits: Vec<Vec<i64>> = Vec::with_capacity(ns + nr);
    for i in 0..ns + nr {
        match &space.knobs[i] {
            Knob::Split { options, .. } => {
                splits.push(options[e.choices[i] as usize].clone())
            }
            _ => unreachable!("knob {i} must be a split"),
        }
    }
    let get_choice = |name: &str| -> i64 {
        let i = space.knob_index(name).unwrap();
        match &space.knobs[i] {
            Knob::Choice { options, .. } => options[e.choices[i] as usize],
            _ => unreachable!(),
        }
    };
    let unroll = get_choice("unroll");
    let vec = get_choice("vec") != 0;
    let cache_write = match t {
        TemplateKind::Cpu => get_choice("cache_write") != 0,
        TemplateKind::Gpu => true,
    };

    let order = leaf_order(ns, nr, spatial_parts(t));

    let mut annotations = HashMap::new();
    match t {
        TemplateKind::Cpu => {
            // Parallelize the outer spatial tiles (collapsed OMP loop).
            for ax in 0..ns {
                if splits[ax][0] > 1 {
                    annotations.insert(LeafRef { axis: ax, part: 0 }, ForKind::Parallel);
                }
            }
        }
        TemplateKind::Gpu => {
            for ax in 0..ns {
                annotations.insert(LeafRef { axis: ax, part: 0 }, ForKind::BlockBind);
                annotations.insert(LeafRef { axis: ax, part: 1 }, ForKind::ThreadBind);
            }
        }
    }

    // GPU: stage every input tensor's tile into shared memory right
    // inside the outer reduce loops (before R1).
    let mut cache_reads = Vec::new();
    if t == TemplateKind::Gpu && nr > 0 {
        let r1_pos = order
            .iter()
            .position(|l| l.axis >= ns && l.part == 1)
            .expect("reduce leaves exist");
        let mut seen = std::collections::HashSet::new();
        for acc in def.body.accesses() {
            if seen.insert(acc.tensor.clone()) {
                cache_reads.push(CacheRead { tensor: acc.tensor.clone(), at: r1_pos });
            }
        }
    }

    Schedule {
        splits,
        order,
        annotations,
        cache_reads,
        copy_kind: match t {
            TemplateKind::Cpu => ForKind::Serial,
            TemplateKind::Gpu => ForKind::ThreadBind,
        },
        cache_write,
        unroll_max_step: unroll,
        vectorize_inner: vec,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::util::Rng;

    #[test]
    fn matmul_space_is_large() {
        let def = ops::matmul(1024, 1024, 1024);
        let s = build_space(&def, TemplateKind::Gpu);
        // two spatial splits (3 parts of 2^10 → C(12,2)=66 each),
        // one reduce split (2 parts → 11), unroll(4) × vec(2)
        assert_eq!(s.size(), 66 * 66 * 11 * 4 * 2);
    }

    #[test]
    fn conv_space_order_covers_all_leaves() {
        let p = ops::Conv2dParams {
            n: 1, h: 28, w: 28, ic: 128, oc: 128, kh: 3, kw: 3, stride: 1, pad: 1,
        };
        let def = ops::conv2d(p);
        for t in [TemplateKind::Cpu, TemplateKind::Gpu] {
            let task = Task::new(def.clone(), t);
            let mut rng = Rng::seed_from_u64(7);
            for _ in 0..50 {
                let e = task.space.sample(&mut rng);
                let sched = task.schedule(&e);
                let extents: Vec<i64> =
                    def.all_axes().map(|a| a.extent).collect();
                sched.validate(&extents).unwrap();
            }
        }
    }

    #[test]
    fn gpu_template_caches_both_inputs() {
        let def = ops::matmul(64, 64, 64);
        let task = Task::new(def, TemplateKind::Gpu);
        let e = task.space.entity(0);
        let sched = task.schedule(&e);
        let tensors: Vec<_> =
            sched.cache_reads.iter().map(|c| c.tensor.clone()).collect();
        assert_eq!(tensors, vec!["A", "B"]);
        assert!(sched.cache_write);
    }

    #[test]
    fn cpu_template_marks_parallel_outer() {
        let def = ops::matmul(64, 64, 64);
        let task = Task::new(def, TemplateKind::Cpu);
        // pick a config whose outer y tile > 1
        let mut e = task.space.entity(0);
        let Knob::Split { options, .. } = &task.space.knobs[0] else { panic!() };
        e.choices[0] = options.iter().position(|o| o == &vec![4, 4, 4]).unwrap() as u32;
        let s = task.schedule(&e);
        assert_eq!(s.splits[0], vec![4, 4, 4]);
        assert_eq!(
            s.annotations.get(&LeafRef { axis: 0, part: 0 }),
            Some(&ForKind::Parallel)
        );
    }

    #[test]
    fn elementwise_has_no_reduce_leaves() {
        let def = ops::relu(&[64, 56, 56]);
        let task = Task::new(def, TemplateKind::Gpu);
        let e = task.space.entity(0);
        let s = task.schedule(&e);
        assert!(s.cache_reads.is_empty() || !s.cache_reads.is_empty());
        assert_eq!(s.order.len(), s.num_leaves());
    }
}
