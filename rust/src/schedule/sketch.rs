//! Ansor-style sketch generation: derivation rules → sketch set →
//! one merged config space (ROADMAP item 3).
//!
//! The hand template in [`super::template`] fixes every structural
//! decision (tile depth, loop interleaving, cache staging) and tunes
//! only the extents. Ansor's insight is to *derive* the structure too:
//! apply a small set of rules (multi-level tiling depth, reduce-tiling
//! depth, cache-read staging, accumulator staging) to the tensor
//! expression, producing a set of [`Sketch`]es — program structures
//! with free tile extents — and let the search fill the extents.
//!
//! Representation: rather than one `ConfigSpace` per sketch, the module
//! builds **one** space whose first knob selects the sketch and whose
//! split knobs are sized for the *deepest* sketch
//! ([`MAX_SPATIAL_PARTS`] / [`MAX_REDUCE_PARTS`]); shallower sketches
//! fold the surplus tail factors into their innermost tile
//! ([`merge_tail`]). This keeps every existing consumer working — SA
//! mutation, crossover, `Representation::Config` featurization (the
//! sketch id lands as the first config feature) — while multiplying
//! the space size by orders of magnitude.
//!
//! **Containment guarantee:** the current hand template is one point of
//! every sketch space — [`embed_template_config`] maps any template
//! config to a sketch config with an *identical* [`Schedule`], proved
//! by `tests/sketch_evo.rs` on conv2d and matmul.

use super::space::{factorizations, ConfigEntity, ConfigSpace, Knob};
use super::template::TemplateKind;
use super::{CacheRead, LeafRef, Schedule};
use crate::ast::ForKind;
use crate::expr::ComputeDef;
use std::collections::HashMap;

/// Deepest spatial tiling any sketch uses; spatial split knobs carry
/// this many parts and shallower sketches merge the tail.
pub const MAX_SPATIAL_PARTS: usize = 4;
/// Deepest reduce tiling any sketch uses.
pub const MAX_REDUCE_PARTS: usize = 3;

/// One derivation step in a sketch's trace. The trace is explanatory
/// (reports, debugging, docs) — [`Sketch`]'s structural fields are what
/// instantiation consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// Tile every spatial axis into `parts` levels.
    MultiLevelTiling {
        /// Tile levels per spatial axis.
        parts: usize,
    },
    /// Tile every reduce axis into `parts` levels, interleaved with the
    /// spatial levels.
    ReduceTiling {
        /// Tile levels per reduce axis.
        parts: usize,
    },
    /// Stage input tiles into shared memory inside the outer reduce
    /// loops (GPU).
    CacheReadStage {
        /// Whether the stage is inserted.
        on: bool,
    },
    /// Accumulate into a register/local tile, write back once.
    AccumulatorStage {
        /// Whether the accumulator is staged.
        staged: bool,
    },
}

/// One derived program structure with free tile extents.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Sketch {
    /// Tile levels per spatial axis (≤ [`MAX_SPATIAL_PARTS`]).
    pub spatial_parts: usize,
    /// Tile levels per reduce axis (≤ [`MAX_REDUCE_PARTS`]).
    pub reduce_parts: usize,
    /// Stage input tiles into shared memory (GPU, reductions only).
    pub cache_read: bool,
    /// Stage the accumulator in a register/local tile.
    pub cache_write: bool,
    /// The derivation trace that produced this structure.
    pub rules: Vec<Rule>,
}

/// Enumerate the sketch set for an operator under a template: the
/// cross product of the derivation rules that apply to it. The first
/// sketch is always the hand template's structure (3-level spatial,
/// 2-level reduce, template-default staging), so index 0 is the
/// template-compatible anchor.
pub fn generate(def: &ComputeDef, t: TemplateKind) -> Vec<Sketch> {
    let nr = def.reduce_axes.len();
    let mut out = Vec::new();
    for sp in [3usize, 4] {
        let rps: &[usize] = if nr > 0 { &[2, 3] } else { &[2] };
        for &rp in rps {
            let crs: &[bool] =
                if t == TemplateKind::Gpu && nr > 0 { &[true, false] } else { &[false] };
            for &cr in crs {
                for cw in [true, false] {
                    out.push(Sketch {
                        spatial_parts: sp,
                        reduce_parts: rp,
                        cache_read: cr,
                        cache_write: cw,
                        rules: vec![
                            Rule::MultiLevelTiling { parts: sp },
                            Rule::ReduceTiling { parts: rp },
                            Rule::CacheReadStage { on: cr },
                            Rule::AccumulatorStage { staged: cw },
                        ],
                    });
                }
            }
        }
    }
    out
}

/// Build the merged config space over a sketch set.
///
/// Knob layout (consumed positionally by [`instantiate_sketch`]):
/// knob 0 is the `sketch` selector, then one [`MAX_SPATIAL_PARTS`]-part
/// `Split` per spatial axis and one [`MAX_REDUCE_PARTS`]-part `Split`
/// per reduce axis (extent-1 axes get a degenerate single option), then
/// the `unroll` and `vec` choices. There is no `cache_write` knob —
/// accumulator staging is structural (a sketch decision).
pub fn sketch_space(def: &ComputeDef, t: TemplateKind, sketches: &[Sketch]) -> ConfigSpace {
    let mut knobs = vec![Knob::Choice {
        name: "sketch".into(),
        options: (0..sketches.len() as i64).collect(),
    }];
    for ax in def.axes.iter() {
        let opts = if ax.extent == 1 {
            vec![vec![1; MAX_SPATIAL_PARTS]]
        } else {
            factorizations(ax.extent, MAX_SPATIAL_PARTS)
        };
        knobs.push(Knob::Split {
            name: format!("tile_{}", ax.name),
            extent: ax.extent,
            parts: MAX_SPATIAL_PARTS,
            options: opts,
        });
    }
    for ax in def.reduce_axes.iter() {
        let opts = if ax.extent == 1 {
            vec![vec![1; MAX_REDUCE_PARTS]]
        } else {
            factorizations(ax.extent, MAX_REDUCE_PARTS)
        };
        knobs.push(Knob::Split {
            name: format!("tile_{}", ax.name),
            extent: ax.extent,
            parts: MAX_REDUCE_PARTS,
            options: opts,
        });
    }
    let unroll_opts = match t {
        TemplateKind::Cpu => vec![0, 4, 16, 64],
        TemplateKind::Gpu => vec![0, 16, 64, 512],
    };
    knobs.push(Knob::Choice { name: "unroll".into(), options: unroll_opts });
    knobs.push(Knob::Choice { name: "vec".into(), options: vec![0, 1] });
    ConfigSpace { knobs }
}

/// Fold a max-depth factorization down to `parts` levels: keep the
/// first `parts - 1` factors, multiply the tail into the innermost.
/// `merge_tail(&[a, b, c, 1], 3) == [a, b, c]`, which is what makes
/// the template's 3-part splits exactly reachable from 4-part knobs.
pub(crate) fn merge_tail(sizes: &[i64], parts: usize) -> Vec<i64> {
    debug_assert!(parts >= 1 && sizes.len() >= parts);
    let mut out = sizes[..parts - 1].to_vec();
    out.push(sizes[parts - 1..].iter().product());
    out
}

/// Canonical interleaved leaf order for `sp` spatial and `rp` reduce
/// tile levels: reduce level `r` is emitted just before spatial level
/// `min(r + 1, sp - 1)` (the last reduce level always sits just outside
/// the innermost spatial tiles). For `(sp, rp) = (3, 2)` this is
/// exactly the hand template's `S0.. R0.. S1.. R1.. S2..` — the
/// template's `leaf_order` delegates here.
pub(crate) fn interleaved_order(ns: usize, nr: usize, sp: usize, rp: usize) -> Vec<LeafRef> {
    let mut order = Vec::with_capacity(ns * sp + nr * rp);
    for part in 0..sp {
        for r in 0..rp {
            let at = if r + 1 >= rp { sp - 1 } else { (r + 1).min(sp - 1) };
            if at == part {
                for ri in 0..nr {
                    order.push(LeafRef { axis: ns + ri, part: r });
                }
            }
        }
        for ax in 0..ns {
            order.push(LeafRef { axis: ax, part });
        }
    }
    order
}

/// Instantiate a schedule from a sketch-space config: knob 0 picks the
/// sketch (the structure), the split knobs fill its free extents.
/// Annotation policy matches the hand template — CPU parallelizes outer
/// spatial tiles with extent > 1, GPU binds spatial parts 0/1 to
/// blocks/threads — so a sketch config that reproduces the template's
/// structure reproduces its schedule exactly.
pub fn instantiate_sketch(
    def: &ComputeDef,
    t: TemplateKind,
    sketches: &[Sketch],
    space: &ConfigSpace,
    e: &ConfigEntity,
) -> Schedule {
    let ns = def.axes.len();
    let nr = def.reduce_axes.len();
    let sk = &sketches[e.choices[0] as usize];

    let mut splits: Vec<Vec<i64>> = Vec::with_capacity(ns + nr);
    for i in 0..ns + nr {
        let full = match &space.knobs[i + 1] {
            Knob::Split { options, .. } => &options[e.choices[i + 1] as usize],
            _ => unreachable!("knob {} must be a split", i + 1),
        };
        let parts = if i < ns { sk.spatial_parts } else { sk.reduce_parts };
        splits.push(merge_tail(full, parts));
    }
    let get_choice = |name: &str| -> i64 {
        let i = space.knob_index(name).unwrap();
        match &space.knobs[i] {
            Knob::Choice { options, .. } => options[e.choices[i] as usize],
            _ => unreachable!(),
        }
    };
    let unroll = get_choice("unroll");
    let vec = get_choice("vec") != 0;

    let order = interleaved_order(ns, nr, sk.spatial_parts, sk.reduce_parts);

    let mut annotations = HashMap::new();
    match t {
        TemplateKind::Cpu => {
            for (ax, sizes) in splits.iter().enumerate().take(ns) {
                if sizes[0] > 1 {
                    annotations.insert(LeafRef { axis: ax, part: 0 }, ForKind::Parallel);
                }
            }
        }
        TemplateKind::Gpu => {
            for ax in 0..ns {
                annotations.insert(LeafRef { axis: ax, part: 0 }, ForKind::BlockBind);
                annotations.insert(LeafRef { axis: ax, part: 1 }, ForKind::ThreadBind);
            }
        }
    }

    // Cache-read staging: input tiles land in shared memory just inside
    // the second-to-innermost reduce level (part rp−1), mirroring the
    // template's "before R1" placement.
    let mut cache_reads = Vec::new();
    if t == TemplateKind::Gpu && nr > 0 && sk.cache_read {
        let pos = order
            .iter()
            .position(|l| l.axis >= ns && l.part == sk.reduce_parts - 1)
            .expect("reduce leaves exist");
        let mut seen = std::collections::HashSet::new();
        for acc in def.body.accesses() {
            if seen.insert(acc.tensor.clone()) {
                cache_reads.push(CacheRead { tensor: acc.tensor.clone(), at: pos });
            }
        }
    }

    Schedule {
        splits,
        order,
        annotations,
        cache_reads,
        copy_kind: match t {
            TemplateKind::Cpu => ForKind::Serial,
            TemplateKind::Gpu => ForKind::ThreadBind,
        },
        cache_write: sk.cache_write,
        unroll_max_step: unroll,
        vectorize_inner: vec,
    }
}

/// Map a hand-template config to the sketch-space config with the
/// identical [`Schedule`]: pick the template-structured sketch (3-level
/// spatial, 2-level reduce, the template's effective staging), pad each
/// split with trailing 1s up to the sketch knob depth, and copy the
/// annotation choices. This is the constructive proof of the
/// containment guarantee.
pub fn embed_template_config(
    tpl: &super::template::Task,
    sk_task: &super::template::Task,
    e: &ConfigEntity,
) -> ConfigEntity {
    let def = &tpl.def;
    let ns = def.axes.len();
    let nr = def.reduce_axes.len();
    let sketches = sk_task.sketches.as_ref().expect("embed target must be a sketch task");

    let tpl_choice = |name: &str| -> i64 {
        let i = tpl.space.knob_index(name).unwrap();
        match &tpl.space.knobs[i] {
            Knob::Choice { options, .. } => options[e.choices[i] as usize],
            _ => unreachable!(),
        }
    };
    let cw = match tpl.template {
        TemplateKind::Gpu => true,
        TemplateKind::Cpu => tpl_choice("cache_write") != 0,
    };
    let want_cr = tpl.template == TemplateKind::Gpu && nr > 0;
    let sid = sketches
        .iter()
        .position(|s| {
            s.spatial_parts == 3
                && s.reduce_parts == 2
                && s.cache_read == want_cr
                && s.cache_write == cw
        })
        .expect("template-equivalent sketch present");

    let mut choices = vec![0u32; sk_task.space.num_knobs()];
    choices[0] = sid as u32;
    for ax in 0..ns + nr {
        let tpl_sizes = match &tpl.space.knobs[ax] {
            Knob::Split { options, .. } => &options[e.choices[ax] as usize],
            _ => unreachable!("knob {ax} must be a split"),
        };
        let target = if ax < ns { MAX_SPATIAL_PARTS } else { MAX_REDUCE_PARTS };
        let mut padded = tpl_sizes.clone();
        padded.resize(target, 1);
        let pos = match &sk_task.space.knobs[ax + 1] {
            Knob::Split { options, .. } => options
                .iter()
                .position(|o| o == &padded)
                .expect("padded factorization present in sketch knob"),
            _ => unreachable!("knob {} must be a split", ax + 1),
        };
        choices[ax + 1] = pos as u32;
    }
    for name in ["unroll", "vec"] {
        let ti = tpl.space.knob_index(name).unwrap();
        let si = sk_task.space.knob_index(name).unwrap();
        choices[si] = e.choices[ti];
    }
    ConfigEntity { choices }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::schedule::template::Task;
    use crate::util::Rng;

    #[test]
    fn merge_tail_folds_into_innermost() {
        assert_eq!(merge_tail(&[2, 4, 8, 1], 3), vec![2, 4, 8]);
        assert_eq!(merge_tail(&[2, 4, 8, 2], 3), vec![2, 4, 16]);
        assert_eq!(merge_tail(&[3, 5, 7], 2), vec![3, 35]);
        assert_eq!(merge_tail(&[3, 5], 2), vec![3, 5]);
    }

    #[test]
    fn interleaved_order_matches_template_shape() {
        // (sp=3, rp=2): S0 S0' R0 S1 S1' R1 S2 S2' for ns=2, nr=1
        let order = interleaved_order(2, 1, 3, 2);
        let expect = vec![
            LeafRef { axis: 0, part: 0 },
            LeafRef { axis: 1, part: 0 },
            LeafRef { axis: 2, part: 0 },
            LeafRef { axis: 0, part: 1 },
            LeafRef { axis: 1, part: 1 },
            LeafRef { axis: 2, part: 1 },
            LeafRef { axis: 0, part: 2 },
            LeafRef { axis: 1, part: 2 },
        ];
        assert_eq!(order, expect);
    }

    #[test]
    fn interleaved_order_covers_all_leaves() {
        for (ns, nr) in [(2, 1), (4, 3), (1, 0)] {
            for sp in [3, 4] {
                for rp in [2, 3] {
                    let order = interleaved_order(ns, nr, sp, rp);
                    assert_eq!(order.len(), ns * sp + nr * rp);
                    let set: std::collections::HashSet<_> = order.iter().collect();
                    assert_eq!(set.len(), order.len(), "duplicate leaf");
                    // last reduce level precedes the innermost spatial
                    if nr > 0 {
                        let last_r = order
                            .iter()
                            .position(|l| l.axis >= ns && l.part == rp - 1)
                            .unwrap();
                        let last_s = order
                            .iter()
                            .position(|l| l.axis < ns && l.part == sp - 1)
                            .unwrap();
                        assert!(last_r < last_s);
                    }
                }
            }
        }
    }

    #[test]
    fn first_sketch_is_template_shaped() {
        let def = ops::matmul(64, 64, 64);
        for t in [TemplateKind::Cpu, TemplateKind::Gpu] {
            let sks = generate(&def, t);
            assert_eq!(sks[0].spatial_parts, 3);
            assert_eq!(sks[0].reduce_parts, 2);
            assert_eq!(sks[0].cache_read, t == TemplateKind::Gpu);
            assert!(sks[0].cache_write);
        }
    }

    #[test]
    fn sketch_schedules_validate() {
        let def = ops::matmul(128, 128, 128);
        for t in [TemplateKind::Cpu, TemplateKind::Gpu] {
            let task = Task::with_sketches(def.clone(), t);
            let extents: Vec<i64> = def.all_axes().map(|a| a.extent).collect();
            let mut rng = Rng::seed_from_u64(17);
            for _ in 0..60 {
                let e = task.space.sample(&mut rng);
                task.schedule(&e).validate(&extents).unwrap();
                let p = task.lower(&e).unwrap();
                assert!(p.flops > 0);
            }
        }
    }

    #[test]
    fn embedded_template_config_schedules_identically() {
        let def = ops::matmul(64, 64, 64);
        for t in [TemplateKind::Cpu, TemplateKind::Gpu] {
            let tpl = Task::new(def.clone(), t);
            let skt = Task::with_sketches(def.clone(), t);
            let mut rng = Rng::seed_from_u64(23);
            for _ in 0..40 {
                let e = tpl.space.sample(&mut rng);
                let emb = embed_template_config(&tpl, &skt, &e);
                assert!(skt.space.contains(&emb));
                assert_eq!(tpl.schedule(&e), skt.schedule(&emb));
            }
        }
    }

    #[test]
    fn sketch_space_is_strictly_larger() {
        let def = ops::matmul(64, 64, 64);
        for t in [TemplateKind::Cpu, TemplateKind::Gpu] {
            let tpl = Task::new(def.clone(), t);
            let skt = Task::with_sketches(def.clone(), t);
            assert!(
                skt.space.size() > tpl.space.size(),
                "{t:?}: sketch {} !> template {}",
                skt.space.size(),
                tpl.space.size()
            );
        }
    }
}
