//! Hardware simulator — the black-box `f(x)` the tuner measures.
//!
//! The paper measures real TITAN X / ARM A53 / Mali hardware; this
//! testbed has none of them, so we substitute analytic abstract-machine
//! models (see DESIGN.md §Substitution). The simulator walks the
//! [`ProgramAnalysis`] of a lowered program and charges cycles for
//! compute, the memory hierarchy (locality-dependent via touch/reuse
//! analysis), vectorization (contiguity-dependent), multi-core / GPU
//! parallelism (capacity-capped, occupancy-sensitive) and loop
//! overheads (unrolling-sensitive). What matters for reproducing the
//! paper is not absolute fidelity but that the cost landscape rewards
//! the same structural properties real hardware does — locality,
//! contiguity, the right parallel granularity — so that learning `f̂`
//! is a genuinely hard, structured problem.
//!
//! Determinism: `evaluate` is pure; `measure` adds seeded lognormal
//! noise to emulate run-to-run variance of real boards.

pub mod devices;

use crate::ast::analysis::{analyze, ProgramAnalysis, StoreChain};
use crate::ast::{ForKind, MemScope, Program};
use crate::util::Rng;

/// Device class: drives template choice and parallelism semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceClass {
    /// Multi-core CPU (parallel outer loops, SIMD inner).
    Cpu,
    /// Throughput device with block/thread grids (GPU, Mali, TPU-style).
    Gpu,
}

/// An abstract machine.
#[derive(Clone, Debug)]
pub struct DeviceModel {
    /// Registry name (e.g. `sim-gpu`).
    pub name: &'static str,
    /// Device class (drives template choice).
    pub class: DeviceClass,
    /// Core clock in GHz.
    pub clock_ghz: f64,
    /// Peak scalar-equivalent parallel lanes (cores×SIMD for CPU,
    /// resident CUDA lanes for GPU).
    pub max_concurrency: f64,
    /// CPU cores / GPU SMs (for launch overhead and the parallel cap).
    pub num_units: f64,
    /// SIMD lanes a `Vectorized` loop can use.
    pub vector_lanes: f64,
    /// FMA ops per cycle per active lane.
    pub flops_per_cycle: f64,
    /// (capacity bytes, amortized cycles per access) per cache level,
    /// smallest first.
    pub caches: Vec<(f64, f64)>,
    /// Cycles per access for non-contiguous DRAM traffic.
    pub dram_latency: f64,
    /// Bytes per cycle of streaming DRAM bandwidth.
    pub dram_bw: f64,
    /// On-chip software-managed memory per block (bytes); 0 disables
    /// shared staging benefit.
    pub shared_bytes: f64,
    /// Amortized cycles per shared-memory access.
    pub shared_latency: f64,
    /// Max threads per GPU block.
    pub max_threads_per_block: f64,
    /// Warp/wavefront granularity: thread counts are rounded up to this
    /// for occupancy accounting.
    pub warp: f64,
    /// Cycles of overhead per innermost-loop iteration.
    pub loop_overhead: f64,
    /// Unrolled-body op budget before i-cache pressure penalizes.
    pub unroll_budget: f64,
    /// Cycles to launch a parallel region / kernel.
    pub launch_overhead: f64,
    /// Optional systolic matrix unit (TPU-style): (tile dim, speedup).
    pub mxu: Option<(f64, f64)>,
    /// Lognormal measurement-noise sigma.
    pub noise_sigma: f64,
}

/// Why a configuration is invalid on this device (the paper's search
/// also produces configs that fail to build/run; they are recorded as
/// errors with zero GFLOPS).
#[derive(Clone, Debug, PartialEq)]
pub enum SimError {
    /// The block's thread count exceeds the device limit.
    TooManyThreads {
        /// Threads requested per block.
        got: f64,
        /// Device limit.
        max: f64,
    },
    /// The staged working set exceeds on-chip shared memory.
    SharedMemOverflow {
        /// Bytes requested.
        got: f64,
        /// Device capacity in bytes.
        max: f64,
    },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::TooManyThreads { got, max } => {
                write!(f, "threads per block {got} exceeds {max}")
            }
            SimError::SharedMemOverflow { got, max } => {
                write!(f, "shared memory {got}B exceeds {max}B")
            }
        }
    }
}

impl std::error::Error for SimError {}

/// Simulated measurement result.
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    /// Modeled execution time.
    pub seconds: f64,
    /// Useful-flops throughput at that time.
    pub gflops: f64,
}

const ELEM_BYTES: f64 = 4.0;

impl DeviceModel {
    /// Pure analytic cost (no noise). Errors on invalid configs.
    pub fn evaluate(&self, program: &Program) -> Result<SimResult, SimError> {
        let analysis = analyze(program);
        self.evaluate_analyzed(program, &analysis)
    }

    /// Evaluate with a precomputed analysis (hot path: the tuner shares
    /// the analysis between feature extraction and simulation).
    pub fn evaluate_analyzed(
        &self,
        program: &Program,
        analysis: &ProgramAnalysis,
    ) -> Result<SimResult, SimError> {
        self.validate(program, analysis)?;
        let mut cycles = 0.0;
        let threads_per_block = self.threads_per_block(analysis);
        for chain in &analysis.chains {
            cycles += self.chain_cycles(chain, threads_per_block);
        }
        cycles += self.launch_overhead;
        let seconds = cycles / (self.clock_ghz * 1e9);
        Ok(SimResult { seconds, gflops: program.flops as f64 / seconds / 1e9 })
    }

    /// Noisy measurement (log-normal multiplicative noise), seeded.
    pub fn measure(&self, program: &Program, seed: u64) -> Result<SimResult, SimError> {
        let base = self.evaluate(program)?;
        if self.noise_sigma == 0.0 {
            return Ok(base);
        }
        let mut rng = Rng::seed_from_u64(seed);
        let factor = (self.noise_sigma * rng.normal()).exp();
        let seconds = base.seconds * factor;
        Ok(SimResult { seconds, gflops: base.gflops / factor })
    }

    /// Hard resource-limit checks.
    fn validate(
        &self,
        program: &Program,
        analysis: &ProgramAnalysis,
    ) -> Result<(), SimError> {
        if self.class == DeviceClass::Gpu {
            let tpb = self.threads_per_block(analysis);
            if tpb > self.max_threads_per_block {
                return Err(SimError::TooManyThreads {
                    got: tpb,
                    max: self.max_threads_per_block,
                });
            }
        }
        let shared: f64 = program
            .buffers
            .iter()
            .filter(|b| b.scope == MemScope::Shared)
            .map(|b| b.numel() as f64 * ELEM_BYTES)
            .sum();
        if self.shared_bytes > 0.0 && shared > self.shared_bytes {
            return Err(SimError::SharedMemOverflow { got: shared, max: self.shared_bytes });
        }
        Ok(())
    }

    /// Threads per block = max ThreadBind extent product over compute
    /// (non-copy) chains.
    fn threads_per_block(&self, analysis: &ProgramAnalysis) -> f64 {
        analysis
            .chains
            .iter()
            .filter(|c| c.accesses[0].scope != MemScope::Shared)
            .map(|c| {
                c.loops
                    .iter()
                    .filter(|l| l.kind == ForKind::ThreadBind)
                    .map(|l| l.extent as f64)
                    .product::<f64>()
            })
            .fold(1.0, f64::max)
    }

    /// Cycles charged for one store chain.
    fn chain_cycles(&self, chain: &StoreChain, threads_per_block: f64) -> f64 {
        let trip = chain.trip;
        let speedup = self.parallel_speedup(chain, threads_per_block);
        let serial_iters = trip / speedup;

        // --- compute ---
        let (has_vec, vec_contig, vec_extent) = self.vector_info(chain);
        let mut flop_cycles = chain.value_flops as f64 / self.flops_per_cycle;
        if has_vec && vec_contig {
            flop_cycles /= self.vector_lanes.min(vec_extent);
        }
        // Padding guards cost a couple of comparisons.
        if chain.has_guard {
            flop_cycles += 2.0 / self.flops_per_cycle;
        }
        // Systolic matrix unit: dense accumulate chains with aligned
        // inner tiles run at `speedup`× with utilization given by tile
        // alignment to the MXU dimension.
        if let Some((dim, mxu_speedup)) = self.mxu {
            if chain.accumulate && chain.accesses.len() >= 3 {
                let util = self.mxu_utilization(chain, dim);
                let accel = 1.0 + (mxu_speedup - 1.0) * util;
                flop_cycles /= accel;
            }
        }

        // --- memory ---
        let mut mem_cycles = 0.0;
        for a in &chain.accesses {
            mem_cycles += self.access_cycles(chain, a, has_vec);
        }

        // --- loop overhead ---
        let innermost_kind =
            chain.loops.last().map(|l| l.kind).unwrap_or(ForKind::Serial);
        let mut overhead = match innermost_kind {
            ForKind::Unrolled => self.loop_overhead / 8.0,
            ForKind::Vectorized => self.loop_overhead / self.vector_lanes,
            _ => self.loop_overhead,
        };
        // i-cache pressure: unrolled body too large.
        let unrolled_ext: f64 = chain
            .loops
            .iter()
            .filter(|l| l.kind == ForKind::Unrolled)
            .map(|l| l.extent as f64)
            .product();
        let body_ops = (chain.value_flops as f64 + chain.accesses.len() as f64).max(1.0);
        if unrolled_ext * body_ops > self.unroll_budget {
            overhead += self.loop_overhead * 0.5;
        }

        // Parallel-region / kernel launch costs.
        let regions: f64 = if self.class == DeviceClass::Cpu {
            chain.loops.iter().filter(|l| l.kind == ForKind::Parallel).count() as f64
        } else {
            1.0
        };

        // Compulsory (cold) DRAM traffic: every distinct global byte must
        // cross the bus at least once.
        let cold_bytes: f64 = chain
            .accesses
            .iter()
            .filter(|a| a.scope == MemScope::Global)
            .map(|a| a.touch.first().copied().unwrap_or(0.0) * ELEM_BYTES)
            .sum();
        let cold_cycles = cold_bytes / self.dram_bw;

        serial_iters * (flop_cycles + mem_cycles + overhead)
            + cold_cycles
            + regions * self.launch_overhead
    }

    /// Effective parallel speedup for a chain.
    fn parallel_speedup(&self, chain: &StoreChain, threads_per_block: f64) -> f64 {
        match self.class {
            DeviceClass::Cpu => {
                let par: f64 = chain
                    .loops
                    .iter()
                    .filter(|l| l.kind == ForKind::Parallel)
                    .map(|l| l.extent as f64)
                    .product();
                par.min(self.num_units).max(1.0)
            }
            DeviceClass::Gpu => {
                let blocks: f64 = chain
                    .loops
                    .iter()
                    .filter(|l| l.kind == ForKind::BlockBind)
                    .map(|l| l.extent as f64)
                    .product();
                let is_copy = chain.accesses[0].scope == MemScope::Shared;
                let threads: f64 = {
                    let t: f64 = chain
                        .loops
                        .iter()
                        .filter(|l| l.kind == ForKind::ThreadBind)
                        .map(|l| l.extent as f64)
                        .product();
                    if is_copy {
                        // Cooperative staging: the copy loops (marked
                        // ThreadBind by the template) are distributed over
                        // the block's compute threads.
                        t.min(threads_per_block)
                    } else {
                        t
                    }
                };
                // Occupancy: threads are scheduled at warp granularity.
                let warp_eff = if threads <= 1.0 {
                    1.0
                } else {
                    threads / (self.warp * (threads / self.warp).ceil())
                };
                let raw = blocks * threads.max(1.0);
                raw.min(self.max_concurrency).max(1.0) * warp_eff
            }
        }
    }

    /// (has a vectorized loop, all accesses contiguous along it, extent).
    ///
    /// Vector math pays off only when every access is contiguous or
    /// invariant along the vector loop; otherwise the compiler emits
    /// gathers (penalized per access in [`Self::access_cycles`]).
    fn vector_info(&self, chain: &StoreChain) -> (bool, bool, f64) {
        let Some((li, inner)) = chain
            .loops
            .iter()
            .enumerate()
            .rev()
            .find(|(_, l)| l.kind == ForKind::Vectorized)
        else {
            return (false, false, 1.0);
        };
        let contig = chain
            .accesses
            .iter()
            .all(|a| matches!(a.strides.get(li), Some(0) | Some(1) | Some(&-1)));
        (true, contig, inner.extent as f64)
    }

    /// MXU utilization: alignment of the innermost unbound loops to the
    /// systolic tile dimension.
    fn mxu_utilization(&self, chain: &StoreChain, dim: f64) -> f64 {
        // product of innermost serial/unrolled/vectorized loop extents
        let mut inner = 1.0;
        for l in chain.loops.iter().rev() {
            match l.kind {
                ForKind::Serial | ForKind::Unrolled | ForKind::Vectorized => {
                    inner *= l.extent as f64
                }
                _ => break,
            }
        }
        let tile = dim * dim;
        (inner / (tile * (inner / tile).ceil())).clamp(0.0, 1.0)
    }

    /// Amortized cycles per access for one buffer access in the chain.
    fn access_cycles(
        &self,
        chain: &StoreChain,
        a: &crate::ast::analysis::AccessInfo,
        vectorized: bool,
    ) -> f64 {
        let n = chain.loops.len();
        if n == 0 {
            return self.dram_latency;
        }
        match a.scope {
            MemScope::Local => 0.05, // register file
            MemScope::Shared => {
                // invariant in the innermost loop → register-promoted
                if a.strides[n - 1] == 0 {
                    0.1
                } else {
                    self.shared_latency
                }
            }
            MemScope::Global => {
                // innermost-loop behaviour
                let s_inner = a.strides[n - 1];
                if s_inner == 0 {
                    // register promotion across the innermost loop
                    return 0.1;
                }
                // Reuse analysis: deepest loop whose var doesn't move the
                // access (temporal reuse); footprint below it decides the
                // cache level the access is served from.
                let mut footprint = a.touch[0] * ELEM_BYTES;
                for l in (0..n).rev() {
                    if a.strides[l] == 0 && chain.loops[l].extent > 1 {
                        footprint = if l + 1 < n {
                            a.touch[l + 1] * ELEM_BYTES
                        } else {
                            ELEM_BYTES
                        };
                        break;
                    }
                }
                let contiguous = s_inner.abs() == 1;
                let mut cost = self.serve_cost(footprint, contiguous);
                // Strided vector access forces a gather.
                if vectorized && !contiguous {
                    cost *= 1.5;
                }
                cost
            }
        }
    }

    /// Cycles per element served from the smallest level holding
    /// `footprint` bytes.
    fn serve_cost(&self, footprint: f64, contiguous: bool) -> f64 {
        for (size, lat) in &self.caches {
            if footprint <= *size {
                return *lat;
            }
        }
        if contiguous {
            ELEM_BYTES / self.dram_bw
        } else {
            self.dram_latency
        }
    }
}

#[cfg(test)]
mod tests {
    use super::devices::{sim_cpu, sim_gpu, sim_tpu};
    use super::*;
    use crate::expr::ops;
    use crate::schedule::space::Knob;
    use crate::schedule::template::{Task, TemplateKind};

    fn cpu_config(
        task: &Task,
        tiles: &[(usize, Vec<i64>)],
        named: &[(&str, u32)],
    ) -> crate::schedule::space::ConfigEntity {
        let mut e = task.space.entity(0);
        for (knob, tile) in tiles {
            let Knob::Split { options, .. } = &task.space.knobs[*knob] else { panic!() };
            e.choices[*knob] = options
                .iter()
                .position(|o| o == tile)
                .unwrap_or_else(|| panic!("tile {tile:?} not in knob {knob}"))
                as u32;
        }
        for (name, v) in named {
            e.choices[task.space.knob_index(name).unwrap()] = *v;
        }
        e
    }

    #[test]
    fn tiling_improves_locality_on_cpu() {
        let dev = sim_cpu();
        let task = Task::new(ops::matmul(256, 256, 256), TemplateKind::Cpu);
        // naive: no tiling at all
        let naive = cpu_config(
            &task,
            &[(0, vec![1, 1, 256]), (1, vec![1, 1, 256]), (2, vec![1, 256])],
            &[],
        );
        // blocked: classic tiles with inner k
        let blocked = cpu_config(
            &task,
            &[(0, vec![8, 4, 8]), (1, vec![2, 16, 8]), (2, vec![16, 16])],
            &[],
        );
        let c_naive = dev.evaluate(&task.lower(&naive).unwrap()).unwrap();
        let c_blocked = dev.evaluate(&task.lower(&blocked).unwrap()).unwrap();
        assert!(
            c_blocked.seconds < c_naive.seconds,
            "blocked {} !< naive {}",
            c_blocked.seconds,
            c_naive.seconds
        );
    }

    #[test]
    fn vectorization_needs_contiguity() {
        let dev = sim_cpu();
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Cpu);
        // vectorize innermost x (stride 1 in C and B): profitable
        let tiles: &[(usize, Vec<i64>)] =
            &[(0, vec![4, 32, 1]), (1, vec![4, 4, 8]), (2, vec![8, 16])];
        let good = cpu_config(&task, tiles, &[("vec", 1)]);
        let base = cpu_config(&task, tiles, &[("vec", 0)]);
        let g = dev.evaluate(&task.lower(&good).unwrap()).unwrap();
        let b = dev.evaluate(&task.lower(&base).unwrap()).unwrap();
        assert!(g.seconds < b.seconds, "vec {} !< novec {}", g.seconds, b.seconds);

        // stride-2 conv: input loads are non-contiguous along the
        // innermost ox loop, so vectorizing forces gathers
        let cp = ops::Conv2dParams {
            n: 1, h: 32, w: 32, ic: 32, oc: 32, kh: 3, kw: 3, stride: 2, pad: 0,
        };
        let ctask = Task::new(ops::conv2d(cp), TemplateKind::Cpu);
        let iv = ctask.space.knob_index("vec").unwrap();
        let mut rng = Rng::seed_from_u64(2);
        let mut worse = 0;
        let mut cases = 0;
        for _ in 0..40 {
            let mut e = ctask.space.sample(&mut rng);
            e.choices[iv] = 0;
            let mut ev = e.clone();
            ev.choices[iv] = 1;
            if let (Ok(a), Ok(b)) = (
                dev.evaluate(&ctask.lower(&e).unwrap()),
                dev.evaluate(&ctask.lower(&ev).unwrap()),
            ) {
                cases += 1;
                if b.seconds >= a.seconds * 0.98 {
                    worse += 1;
                }
            }
        }
        assert!(cases > 10);
        assert!(
            worse * 2 >= cases,
            "strided vectorize should rarely help: helped in {}/{cases}",
            cases - worse
        );
    }

    #[test]
    fn parallel_speedup_caps_at_cores() {
        let dev = sim_cpu(); // 4 cores
        let task = Task::new(ops::matmul(256, 256, 256), TemplateKind::Cpu);
        let mk = |outer_y: Vec<i64>| {
            cpu_config(
                &task,
                &[(0, outer_y), (1, vec![1, 16, 16]), (2, vec![16, 16])],
                &[],
            )
        };
        let s = dev.evaluate(&task.lower(&mk(vec![1, 16, 16])).unwrap()).unwrap().seconds;
        let p4 = dev.evaluate(&task.lower(&mk(vec![4, 4, 16])).unwrap()).unwrap().seconds;
        let p64 = dev.evaluate(&task.lower(&mk(vec![64, 2, 2])).unwrap()).unwrap().seconds;
        assert!(p4 < s * 0.5, "4-way parallel should speed up: {p4} vs {s}");
        assert!(p64 > p4 * 0.5, "64-way can't be much faster than 4-way");
    }

    #[test]
    fn gpu_thread_cap_is_enforced() {
        let dev = sim_gpu();
        let task = Task::new(ops::matmul(1024, 1024, 1024), TemplateKind::Gpu);
        // thread tile 64x64 = 4096 threads > 1024 cap
        let mut e = task.space.entity(0);
        for knob in [0usize, 1] {
            let Knob::Split { options, .. } = &task.space.knobs[knob] else { panic!() };
            e.choices[knob] =
                options.iter().position(|o| o == &vec![16, 64, 1]).unwrap() as u32;
        }
        let p = task.lower(&e).unwrap();
        assert!(matches!(dev.evaluate(&p), Err(SimError::TooManyThreads { .. })));
    }

    #[test]
    fn shared_memory_overflow_detected() {
        let dev = sim_gpu();
        let task = Task::new(ops::matmul(1024, 1024, 1024), TemplateKind::Gpu);
        let mut e = task.space.entity(0);
        // modest thread tiles but a giant reduce-outer tile: k split
        // [1, 1024] stages 1024×tile elements of A and B in shared memory
        let picks: &[(usize, Vec<i64>)] = &[
            (0, vec![8, 8, 16]),
            (1, vec![8, 8, 16]),
            (2, vec![1, 1024]),
        ];
        for (knob, tile) in picks {
            let Knob::Split { options, .. } = &task.space.knobs[*knob] else { panic!() };
            e.choices[*knob] = options.iter().position(|o| o == tile).unwrap() as u32;
        }
        let p = task.lower(&e).unwrap();
        assert!(matches!(dev.evaluate(&p), Err(SimError::SharedMemOverflow { .. })));
    }

    #[test]
    fn gpu_beats_cpu_on_big_matmul() {
        let cpu = sim_cpu();
        let gpu = sim_gpu();
        let tc = Task::new(ops::matmul(512, 512, 512), TemplateKind::Cpu);
        let tg = Task::new(ops::matmul(512, 512, 512), TemplateKind::Gpu);
        let ec = cpu_config(
            &tc,
            &[(0, vec![4, 16, 8]), (1, vec![1, 64, 8]), (2, vec![32, 16])],
            &[("vec", 1)],
        );
        let mut eg = tg.space.entity(0);
        for knob in [0usize, 1] {
            let Knob::Split { options, .. } = &tg.space.knobs[knob] else { panic!() };
            eg.choices[knob] =
                options.iter().position(|o| o == &vec![32, 16, 1]).unwrap() as u32;
        }
        let Knob::Split { options, .. } = &tg.space.knobs[2] else { panic!() };
        eg.choices[2] = options.iter().position(|o| o == &vec![64, 8]).unwrap() as u32;
        let c = cpu.evaluate(&tc.lower(&ec).unwrap()).unwrap();
        let g = gpu.evaluate(&tg.lower(&eg).unwrap()).unwrap();
        assert!(
            g.gflops > c.gflops * 5.0,
            "gpu {} gflops vs cpu {} gflops",
            g.gflops,
            c.gflops
        );
    }

    #[test]
    fn mxu_rewards_aligned_tiles() {
        let dev = sim_tpu();
        let task = Task::new(ops::matmul(512, 512, 512), TemplateKind::Gpu);
        // identical block/thread tiling; only the inner k split differs,
        // so the innermost run is 16*4*4 = 256 (one full 16x16 MXU tile)
        // vs 8*4*4 = 128 (half a tile)
        let mk = |ksplit: Vec<i64>| {
            let mut e = task.space.entity(0);
            for knob in [0usize, 1] {
                let Knob::Split { options, .. } = &task.space.knobs[knob] else { panic!() };
                e.choices[knob] =
                    options.iter().position(|o| o == &vec![8, 16, 4]).unwrap() as u32;
            }
            let Knob::Split { options, .. } = &task.space.knobs[2] else { panic!() };
            e.choices[2] = options.iter().position(|o| o == &ksplit).unwrap() as u32;
            e
        };
        let aligned = mk(vec![32, 16]);
        let ragged = mk(vec![64, 8]);
        let a = dev.evaluate(&task.lower(&aligned).unwrap()).unwrap();
        let r = dev.evaluate(&task.lower(&ragged).unwrap()).unwrap();
        assert!(a.gflops > r.gflops, "aligned {} !> ragged {}", a.gflops, r.gflops);
    }

    #[test]
    fn measurement_noise_is_seeded_and_bounded() {
        let dev = sim_gpu();
        let task = Task::new(ops::matmul(256, 256, 256), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(4);
        let mut checked = 0;
        for _ in 0..30 {
            let e = task.space.sample(&mut rng);
            let p = task.lower(&e).unwrap();
            if let (Ok(a), Ok(b), Ok(c)) =
                (dev.measure(&p, 1), dev.measure(&p, 1), dev.measure(&p, 2))
            {
                assert_eq!(a.seconds, b.seconds, "same seed must reproduce");
                assert_ne!(a.seconds, c.seconds, "different seeds must differ");
                let base = dev.evaluate(&p).unwrap();
                assert!((a.seconds / base.seconds).ln().abs() < 0.5);
                checked += 1;
            }
        }
        assert!(checked > 5);
    }

    #[test]
    fn evaluate_is_deterministic() {
        let dev = sim_cpu();
        let task = Task::new(ops::dense(64, 256, 256), TemplateKind::Cpu);
        let e = task.space.entity(777 % task.space.size());
        let p = task.lower(&e).unwrap();
        let a = dev.evaluate(&p).unwrap();
        let b = dev.evaluate(&p).unwrap();
        assert_eq!(a.seconds, b.seconds);
    }

    #[test]
    fn conv_c6_runs_on_all_devices() {
        // C6 of Table 1: 28x28, 128->128, k3 s1
        let p = ops::Conv2dParams {
            n: 1, h: 28, w: 28, ic: 128, oc: 128, kh: 3, kw: 3, stride: 1, pad: 1,
        };
        for (dev, t) in [
            (sim_gpu(), TemplateKind::Gpu),
            (sim_cpu(), TemplateKind::Cpu),
            (super::devices::sim_mali(), TemplateKind::Gpu),
        ] {
            let task = Task::new(ops::conv2d(p), t);
            let mut rng = Rng::seed_from_u64(5);
            let mut ok = 0;
            for _ in 0..50 {
                let e = task.space.sample(&mut rng);
                let prog = task.lower(&e).unwrap();
                if let Ok(r) = dev.evaluate(&prog) {
                    assert!(r.seconds > 0.0 && r.gflops > 0.0);
                    ok += 1;
                }
            }
            assert!(ok > 10, "{}: only {ok}/50 configs valid", dev.name);
        }
    }

    #[test]
    fn cost_varies_across_configs() {
        // the landscape must not be flat: spread between best and worst
        // random configs should exceed 5x
        let dev = sim_gpu();
        let task = Task::new(ops::matmul(256, 256, 256), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(9);
        let mut costs = Vec::new();
        for _ in 0..200 {
            let e = task.space.sample(&mut rng);
            if let Ok(r) = dev.evaluate(&task.lower(&e).unwrap()) {
                costs.push(r.seconds);
            }
        }
        let min = costs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = costs.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 5.0, "landscape too flat: {min}..{max}");
    }
}
