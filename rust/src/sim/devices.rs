//! Device model registry — the three back-ends of the paper's
//! evaluation plus a TPU-style systolic device for the
//! hardware-adaptation ablation.
//!
//! Parameters are scaled from public spec sheets (TITAN X Pascal,
//! Cortex-A53, Mali-T860 MP4); the paper's claims are about *relative*
//! shapes (who wins, crossovers), not absolute numbers — see DESIGN.md.

use super::{DeviceClass, DeviceModel};
use crate::schedule::template::Task;

/// Deterministic diminishing-returns tuning curve of one task on one
/// device: the best-so-far per-invocation latency as a function of
/// trials spent, `secs(n) = floor + span · exp(−n/τ)`.
///
/// This is the simulated-farm stand-in the graph-level scheduler
/// ([`crate::tuner::scheduler`]) is tested against: real tuning curves
/// are noisy and seed-dependent, but allocation *decisions* must be
/// auditable — gradient allocation has to beat uniform at equal budget
/// deterministically, not on a lucky seed. Curve parameters are derived
/// from the task's FLOPs and a hash of its key, so different tasks get
/// heterogeneous (but reproducible) headrooms and decay rates.
#[derive(Clone, Debug)]
pub struct TaskCurve {
    /// Latency floor approached as trials → ∞ (seconds).
    pub floor: f64,
    /// Latency above the floor at zero trials (seconds).
    pub span: f64,
    /// Trials for the remaining gap to shrink by e×.
    pub tau: f64,
}

impl TaskCurve {
    /// Best-so-far latency after `trials` measurements (seconds).
    pub fn secs_after(&self, trials: usize) -> f64 {
        self.floor + self.span * (-(trials as f64) / self.tau).exp()
    }

    /// Derive the curve of `task` on `device`: the floor is the task's
    /// FLOPs at half the device's peak throughput; untuned headroom
    /// (2–8× the floor) and decay rate (τ ∈ [24, 120]) come from a hash
    /// of the task key, so they are stable across runs but differ
    /// between tasks.
    pub fn for_task(task: &Task, device: &DeviceModel) -> TaskCurve {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        task.key().hash(&mut h);
        device.name.hash(&mut h);
        let salt = h.finish();
        let peak_gflops = device.max_concurrency * device.flops_per_cycle * device.clock_ghz;
        let floor = task.def.total_flops() as f64 / (0.5 * peak_gflops * 1e9);
        let headroom = 1.0 + (salt % 7) as f64; // 2×..8× above the floor
        let tau = 24.0 + (salt % 97) as f64;
        TaskCurve { floor, span: headroom * floor, tau }
    }
}

/// A deterministic best-so-far latency curve: seconds as a function of
/// trials spent. The scheduler's
/// [`CurveExecutor`](crate::tuner::scheduler::CurveExecutor) replays
/// any implementation — [`TaskCurve`] for a single smooth regime,
/// [`StagedCurve`] for curves with genuine regime changes — so
/// allocation behavior (including slices interleaved across tasks by
/// the overlapped scheduler, and EMA restart detection) is testable
/// exactly.
pub trait LatencyCurve {
    /// Best-so-far latency after `trials` measurements (seconds).
    fn secs_after(&self, trials: usize) -> f64;
}

impl LatencyCurve for TaskCurve {
    fn secs_after(&self, trials: usize) -> f64 {
        TaskCurve::secs_after(self, trials)
    }
}

/// Piecewise tuning curve: several exponential-decay regimes, each
/// activating at a trial offset. Models a *regime change* — a task that
/// flatlines, then suddenly finds fresh headroom (a new template
/// region, a transferred model kicking in). The best-so-far latency is
/// the minimum over every active regime, so the curve stays monotone
/// nonincreasing; when a later regime decays below the earlier floor,
/// per-slice gains jump back up — exactly the signal the scheduler's
/// EMA restart detection must catch (and must catch exactly once).
#[derive(Clone, Debug)]
pub struct StagedCurve {
    /// `(start_trial, regime)` pairs; the first must start at 0.
    pub stages: Vec<(usize, TaskCurve)>,
}

impl StagedCurve {
    /// Single-regime curve (equivalent to the plain [`TaskCurve`]).
    pub fn new(first: TaskCurve) -> Self {
        StagedCurve { stages: vec![(0, first)] }
    }

    /// Builder: add a regime activating at `start_trial`.
    pub fn then(mut self, start_trial: usize, regime: TaskCurve) -> Self {
        self.stages.push((start_trial, regime));
        self
    }

    /// Best-so-far latency after `trials` measurements (seconds): the
    /// minimum over all regimes active by then.
    pub fn secs_after(&self, trials: usize) -> f64 {
        let mut best = f64::INFINITY;
        for (start, regime) in &self.stages {
            if trials >= *start {
                best = best.min(regime.secs_after(trials - start));
            }
        }
        best
    }
}

impl LatencyCurve for StagedCurve {
    fn secs_after(&self, trials: usize) -> f64 {
        StagedCurve::secs_after(self, trials)
    }
}

/// TITAN-X-class server GPU (`sim-gpu`): 28 SMs, ~11 TFLOPS fp32,
/// 480 GB/s GDDR5X, 48 KiB shared memory per block, 1024-thread blocks.
pub fn sim_gpu() -> DeviceModel {
    DeviceModel {
        name: "sim-gpu",
        class: DeviceClass::Gpu,
        clock_ghz: 1.4,
        max_concurrency: 3584.0,
        num_units: 28.0,
        vector_lanes: 4.0, // float4 loads
        flops_per_cycle: 2.0,
        caches: vec![(48.0 * 1024.0, 2.0), (3.0 * 1024.0 * 1024.0, 8.0)],
        dram_latency: 40.0,
        dram_bw: 340.0,
        shared_bytes: 48.0 * 1024.0,
        shared_latency: 1.0,
        max_threads_per_block: 1024.0,
        warp: 32.0,
        loop_overhead: 1.0,
        unroll_budget: 2048.0,
        launch_overhead: 8000.0,
        mxu: None,
        noise_sigma: 0.03,
    }
}

/// Cortex-A53-class embedded CPU (`sim-cpu`): 4 cores @1.2 GHz, NEON
/// (4×f32), 32 KiB L1 / 512 KiB L2, slim DRAM pipe.
pub fn sim_cpu() -> DeviceModel {
    DeviceModel {
        name: "sim-cpu",
        class: DeviceClass::Cpu,
        clock_ghz: 1.2,
        max_concurrency: 16.0,
        num_units: 4.0,
        vector_lanes: 4.0,
        flops_per_cycle: 2.0,
        caches: vec![(32.0 * 1024.0, 1.0), (512.0 * 1024.0, 6.0)],
        dram_latency: 90.0,
        dram_bw: 4.0,
        shared_bytes: 0.0,
        shared_latency: 1.0,
        max_threads_per_block: 1.0,
        warp: 1.0,
        loop_overhead: 1.5,
        unroll_budget: 512.0,
        launch_overhead: 2000.0,
        mxu: None,
        noise_sigma: 0.05,
    }
}

/// Mali-T860-class mobile GPU (`sim-mali`): 4 shader cores @650 MHz,
/// unified memory (no fast shared scratch), vec4 ALUs, 256-thread
/// workgroups.
pub fn sim_mali() -> DeviceModel {
    DeviceModel {
        name: "sim-mali",
        class: DeviceClass::Gpu,
        clock_ghz: 0.65,
        max_concurrency: 256.0,
        num_units: 4.0,
        vector_lanes: 4.0,
        flops_per_cycle: 2.0,
        caches: vec![(32.0 * 1024.0, 2.0), (256.0 * 1024.0, 8.0)],
        dram_latency: 70.0,
        dram_bw: 8.0,
        // Mali "shared" is just L2-backed: allow staging but with L2-ish
        // latency and a generous size so the knob is near-neutral, as on
        // the real device.
        shared_bytes: 32.0 * 1024.0,
        shared_latency: 4.0,
        max_threads_per_block: 256.0,
        warp: 4.0,
        loop_overhead: 1.0,
        unroll_budget: 1024.0,
        launch_overhead: 4000.0,
        mxu: None,
        noise_sigma: 0.05,
    }
}

/// TPU-style device (`sim-tpu`): systolic 16×16 MXU with 8× dense-math
/// speedup at full tile alignment, large VMEM-like scratch. Used by the
/// hardware-adaptation ablation (DESIGN.md §Hardware-Adaptation), not by
/// the paper's original experiments.
pub fn sim_tpu() -> DeviceModel {
    DeviceModel {
        name: "sim-tpu",
        class: DeviceClass::Gpu,
        clock_ghz: 0.94,
        max_concurrency: 2048.0,
        num_units: 2.0,
        vector_lanes: 8.0,
        flops_per_cycle: 2.0,
        caches: vec![(16.0 * 1024.0 * 1024.0, 2.0)],
        dram_latency: 60.0,
        dram_bw: 300.0,
        shared_bytes: 16.0 * 1024.0 * 1024.0,
        shared_latency: 1.0,
        max_threads_per_block: 2048.0,
        warp: 8.0,
        loop_overhead: 1.0,
        unroll_budget: 4096.0,
        launch_overhead: 10000.0,
        mxu: Some((16.0, 8.0)),
        noise_sigma: 0.02,
    }
}

/// Look up a device by name.
pub fn by_name(name: &str) -> Option<DeviceModel> {
    match name {
        "sim-gpu" => Some(sim_gpu()),
        "sim-cpu" => Some(sim_cpu()),
        "sim-mali" => Some(sim_mali()),
        "sim-tpu" => Some(sim_tpu()),
        _ => None,
    }
}

/// All devices of the paper's evaluation.
pub fn all() -> Vec<DeviceModel> {
    vec![sim_gpu(), sim_cpu(), sim_mali()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_roundtrip() {
        for d in all() {
            assert_eq!(by_name(d.name).unwrap().name, d.name);
        }
        assert!(by_name("sim-tpu").is_some());
        assert!(by_name("a100").is_none());
    }

    #[test]
    fn task_curves_are_deterministic_and_monotone() {
        let task = crate::workloads::conv_task(6, crate::schedule::template::TemplateKind::Gpu);
        let dev = sim_gpu();
        let a = TaskCurve::for_task(&task, &dev);
        let b = TaskCurve::for_task(&task, &dev);
        assert_eq!((a.floor, a.span, a.tau), (b.floor, b.span, b.tau));
        assert!(a.floor > 0.0 && a.span > 0.0);
        // monotone nonincreasing, approaching the floor
        let mut prev = a.secs_after(0);
        for n in [1usize, 8, 64, 512, 4096] {
            let s = a.secs_after(n);
            assert!(s <= prev && s >= a.floor);
            prev = s;
        }
        assert!(a.secs_after(100_000) < a.floor + 1e-3 * a.span);
        // a different device yields a different (still deterministic) curve
        let c = TaskCurve::for_task(&task, &sim_cpu());
        assert!(c.floor != a.floor);
    }

    #[test]
    fn staged_curve_is_monotone_and_changes_regime() {
        // flat by ~trial 40, then a second regime at trial 64 opens
        // fresh headroom below the first floor
        let c = StagedCurve::new(TaskCurve { floor: 1.0, span: 1.0, tau: 8.0 })
            .then(64, TaskCurve { floor: 0.2, span: 0.7, tau: 8.0 });
        let mut prev = c.secs_after(0);
        for n in 1..256 {
            let s = c.secs_after(n);
            assert!(s <= prev + 1e-15, "not monotone at {n}: {s} > {prev}");
            prev = s;
        }
        // before the regime change: pinned at the first floor
        assert!((c.secs_after(60) - 1.0).abs() < 1e-2);
        // after: well below it
        assert!(c.secs_after(200) < 0.3);
        // the regime change produces a fresh burst of per-trial gain
        let gain_before = c.secs_after(48) - c.secs_after(56);
        let gain_after = c.secs_after(72) - c.secs_after(80);
        assert!(gain_after > 10.0 * gain_before.max(1e-12));
    }

    #[test]
    fn peak_flops_ordering() {
        // peak = max_concurrency * flops_per_cycle * clock
        let peak = |d: &DeviceModel| d.max_concurrency * d.flops_per_cycle * d.clock_ghz;
        assert!(peak(&sim_gpu()) > 50.0 * peak(&sim_cpu()));
        assert!(peak(&sim_gpu()) > 10.0 * peak(&sim_mali()));
    }
}
