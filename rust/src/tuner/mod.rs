//! The tuning loop — Algorithm 1 of the paper.
//!
//! Each round: run parallel simulated annealing with the cost model as
//! energy to collect the top `λ·b` candidates, pick a `(1−ε)b`-subset
//! by greedy submodular diversity-aware selection (Eq. 3), add `ε·b`
//! random candidates, measure the batch on the hardware back-end,
//! append to the database `D`, and refit `f̂` on all of `D`.
//!
//! Two drivers share that round structure:
//!
//! * [`Tuner`] — the serial reference loop (exactly Algorithm 1, one
//!   stage at a time; kept for reference experiments and for models
//!   that cannot be snapshotted across threads).
//! * [`pipeline::PipelinedTuner`] — the asynchronous production loop:
//!   proposal, measurement and model refit run concurrently on three
//!   stages connected by bounded channels, so the device farm never
//!   idles while SA runs or the GBT refits.
//!
//! Both are built from the same parts: [`Featurizer`] (shared feature
//! extraction + cache), [`BatchProposer`] (SA + diversity selection +
//! ε-greedy batch construction) and [`TrialAccountant`] (records,
//! best-so-far curve, failure handling).
//!
//! Either driver measures through any [`Measurer`] — including the
//! shared asynchronous device-farm service
//! ([`MeasureService`](crate::measure::service::MeasureService)), which
//! shards every batch across replica workers while preserving the
//! deterministic trial history (one replica reproduces the direct
//! measurer bit-for-bit). The pipelined driver additionally keeps the
//! farm busy *across* batch boundaries via the async
//! [`Measurer::submit`]/[`Measurer::wait`] pair.
//!
//! Transfer learning (§4): pass a [`TransferModel`] built from a prior
//! database — the global model makes the very first SA round informed
//! instead of random, in either driver. The coordinator builds that
//! model automatically from the shared [`db::TuningDb`] service layer
//! (cross-workload warm starts; on a heterogeneous fleet also
//! *cross-target* warm starts, with other targets' records
//! down-weighted below same-target siblings), and every loop can
//! stream its measured trials into the same DB live via [`DbSink`]
//! ([`TuneOptions::sink`]) instead of bulk-dumping at the end.
//!
//! Both drivers are **incremental**: SA chains, the dedup set, the
//! model and the training set persist across calls, so a budget can be
//! spent in slices (`tune_more`) — or in *pollable* slices
//! (`begin_slice`/`step_slice` returning a [`SliceRun`]), which cut the
//! same op sequence into single-batch steps so the overlapped
//! graph-level [`scheduler`] can interleave several tasks' slices on
//! one thread while their batches drain on the farm. The scheduler
//! builds on exactly that contract to allocate one global budget across
//! all tasks of a network by expected end-to-end gain.
//!
//! [`TransferModel`]: crate::model::TransferModel

pub mod db;
pub mod pipeline;
pub mod scheduler;
pub mod serve;

use crate::explore::{
    diverse_select, random_batch, Evolutionary, ParallelSa, Scorer, SearchKind,
};
use crate::features::Representation;
use crate::gbt::Matrix;
use crate::measure::{BatchTicket, MeasureResult, Measurer};
use crate::model::{Acquisition, CostModel};
use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use crate::util::Rng;
use db::{Record, TuningDb};
use std::cell::RefCell;
use std::collections::{HashMap, HashSet, VecDeque};

pub use crate::explore::{EvoParams, SaParams};

/// Tuning options (defaults follow the paper's experiment configuration:
/// b = 64, ε = 0.05, 128 SA chains × 500 steps).
#[derive(Clone, Debug)]
pub struct TuneOptions {
    /// Total measurement trials of the run.
    pub n_trials: usize,
    /// Measurement batch size `b`.
    pub batch: usize,
    /// ε-greedy share of each batch filled with random configs.
    pub eps: f64,
    /// SA candidate pool multiplier: diversity selection picks from the
    /// top `λ·b`.
    pub lambda: usize,
    /// Diversity weight α of Eq. 3; `diversity = false` ⇒ plain top-b.
    pub alpha: f64,
    /// Use diversity-aware batch selection (Eq. 3) instead of top-b.
    pub diversity: bool,
    /// Acquisition function over model predictions.
    pub acquisition: Acquisition,
    /// Program representation used for featurization.
    pub repr: Representation,
    /// Which model-guided explorer proposes candidates: persistent
    /// parallel SA (the paper's §3.3 default) or the Ansor-style
    /// evolutionary refiner (`--search evo`). Both are model-fitness
    /// searches sharing the round structure, dedup contract and
    /// determinism discipline, so they are interchangeable per run.
    pub search: SearchKind,
    /// Simulated-annealing exploration budget (`search = Sa`).
    pub sa: SaParams,
    /// Evolutionary-search budget (`search = Evo`).
    pub evo: EvoParams,
    /// Seed of every RNG stream in the loop.
    pub seed: u64,
    /// Print per-round progress.
    pub verbose: bool,
    /// Pipelined loop only: how many measurement batches the proposal
    /// stage may run ahead of the model stage. Depth `d` means batch
    /// `k` is proposed from the model snapshot of epoch
    /// `max(0, k − (d − 1))`; `d = 1` reproduces the serial schedule
    /// exactly. See [`pipeline`].
    pub pipeline_depth: usize,
    /// Live record sink: every measured trial is appended to the shared
    /// [`TuningDb`] as it is absorbed (from the measurement stage in the
    /// pipelined loop), so concurrent readers — the graph compiler, a
    /// warm-starting coordinator — see records immediately. `None` (the
    /// default) keeps the loop side-effect free.
    pub sink: Option<DbSink>,
    /// Use the bit-exact fast paths on the model-query loop: compiled
    /// [`PredictPlan`](crate::gbt::PredictPlan) batch inference instead
    /// of the scalar tree walk, incremental per-knob SA neighbor
    /// featurization under [`Representation::Config`], and
    /// structure-cached delta featurization (donor analysis replay, no
    /// lowering) under the program-derived representations. All paths
    /// produce bit-identical scores, so this toggle exists only for A/B
    /// timing (`--no-fast-paths`, the perf harness) — fixed-seed
    /// results are unchanged either way.
    pub fast_paths: bool,
    /// Row-cache bound of every [`Featurizer`] the loop builds; `None`
    /// uses [`FEAT_CACHE_CAP`]. Capping changes wall-clock only (rows
    /// are recomputed after eviction, never approximated), so
    /// fixed-seed results are identical at any capacity.
    pub feat_cache_cap: Option<usize>,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions {
            n_trials: 512,
            batch: 64,
            eps: 0.05,
            lambda: 2,
            alpha: 1.0,
            diversity: true,
            acquisition: Acquisition::Mean,
            repr: Representation::Full,
            search: SearchKind::Sa,
            sa: SaParams::default(),
            evo: EvoParams::default(),
            seed: 0,
            verbose: false,
            pipeline_depth: 2,
            sink: None,
            fast_paths: true,
            feat_cache_cap: None,
        }
    }
}

/// Where a tuning loop streams its measured trials: a shared
/// [`TuningDb`] handle plus the task/target identity stamped onto every
/// [`Record`]. Cloning is cheap (the DB handle is an `Arc`).
#[derive(Clone)]
pub struct DbSink {
    /// The shared tuning DB handle records stream into.
    pub db: TuningDb,
    /// Task identity stamped onto every record.
    pub task_key: String,
    /// Target (device) identity stamped onto every record.
    pub target: String,
}

impl DbSink {
    /// Sink for `task` on `target` streaming into `db`.
    pub fn new(db: &TuningDb, task: &Task, target: &str) -> Self {
        DbSink { db: db.clone(), task_key: task.key(), target: target.to_string() }
    }

    /// Append one measured trial. WAL failures are reported, not fatal:
    /// the in-flight tuning run keeps its own records either way.
    fn record(&self, e: &ConfigEntity, gflops: f64, r: &MeasureResult) {
        let rec = Record {
            task_key: self.task_key.clone(),
            target: self.target.clone(),
            choices: e.choices.clone(),
            gflops,
            seconds: r.seconds.unwrap_or(0.0),
            error: r.error.clone(),
        };
        if let Err(err) = self.db.append(rec) {
            eprintln!("tuning-db: record append failed: {err:#}");
        }
    }
}

impl std::fmt::Debug for DbSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DbSink")
            .field("task_key", &self.task_key)
            .field("target", &self.target)
            .finish()
    }
}

/// One measured trial.
#[derive(Clone, Debug)]
pub struct TrialRecord {
    /// The measured config.
    pub entity: ConfigEntity,
    /// Throughput (0.0 for failed trials).
    pub gflops: f64,
    /// Wall-clock seconds, when the back-end reports one.
    pub seconds: Option<f64>,
    /// Failure reason, if the trial errored.
    pub error: Option<String>,
}

/// Outcome of a tuning run.
#[derive(Clone, Debug)]
pub struct TuneResult {
    /// Best successful (config, GFLOPS), if any trial succeeded.
    pub best: Option<(ConfigEntity, f64)>,
    /// best-so-far GFLOPS after each trial (x = trial count, 1-based).
    pub curve: Vec<f64>,
    /// Every measured trial, in measurement order.
    pub records: Vec<TrialRecord>,
}

impl TuneResult {
    /// Best GFLOPS of the run (0.0 when every trial failed).
    pub fn best_gflops(&self) -> f64 {
        self.best.as_ref().map(|(_, g)| *g).unwrap_or(0.0)
    }

    /// Best-so-far at a trial count (for curve comparison plots).
    pub fn best_at(&self, trials: usize) -> f64 {
        if self.curve.is_empty() {
            return 0.0;
        }
        self.curve[trials.min(self.curve.len()).saturating_sub(1)]
    }

    /// First trial count reaching `target` GFLOPS (speedup metric of
    /// Fig. 8), if ever.
    pub fn trials_to_reach(&self, target: f64) -> Option<usize> {
        self.curve.iter().position(|&g| g >= target).map(|i| i + 1)
    }
}

/// Default bound of the [`Featurizer`] row cache (rows, not bytes).
pub const FEAT_CACHE_CAP: usize = 16384;

/// Snapshot of a [`Featurizer`]'s cache and delta-path counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FeaturizerStats {
    /// Memoized feature rows currently held.
    pub cached: usize,
    /// Row-cache capacity (oldest-epoch generations are evicted at the
    /// bound).
    pub capacity: usize,
    /// Rows evicted so far.
    pub evictions: u64,
    /// Distinct program structures seen by the delta path.
    pub structures: usize,
    /// Analyses served by delta replay without lowering.
    pub delta_hits: u64,
    /// Full lower+analyze fallbacks on recipe-less structures.
    pub fallbacks: u64,
}

/// Shared feature extraction with a per-owner memo cache keyed by the
/// config's flat space index (`u64` — cheaper to hash and compare than
/// a full choices vector). One implementation serves the serial loop,
/// the pipelined proposal stage and the pipelined model stage — each
/// stage owns its own `Featurizer`, so no locks sit on the SA hot path.
/// The row cache is bounded: every `features`/`neighbor_features` call
/// opens a new epoch, inserts stamp the current epoch, and crossing the
/// capacity evicts the oldest epoch's rows wholesale (values are
/// unchanged by eviction, only recomputed — fixed-seed results are
/// bit-identical at any capacity).
///
/// With `fast` on (the default) two bit-exact shortcuts apply:
///
/// * [`Representation::Config`] rows are computed directly from the
///   knob choices ([`config_padded`](crate::features::config_padded))
///   without lowering the program, and
///   [`neighbor_features`](Self::neighbor_features) rewrites only the
///   mutated knob's feature slice of the cached parent row.
/// * The program-derived representations ([`Representation::Full`],
///   [`Representation::ContextRelation`], [`Representation::FlatAst`])
///   skip lowering through the structure-cached delta path
///   ([`StructureCache`](crate::ast::analysis::StructureCache)): one
///   donor lower+analyze per [`structure
///   key`](crate::schedule::template::Task::structure_key), then every
///   config sharing the structure replays the donor analysis with its
///   own extents and re-emits the row — bit-identical to the fresh
///   path, which remains both the `fast = false` A/B reference and the
///   fallback for structures whose replay recipe fails verification.
pub struct Featurizer {
    /// Representation rows are extracted under.
    pub repr: Representation,
    fast: bool,
    capacity: usize,
    epoch: std::cell::Cell<u64>,
    evictions: std::cell::Cell<u64>,
    cache: RefCell<HashMap<u64, (u64, Vec<f64>)>>,
    structures: RefCell<crate::ast::analysis::StructureCache>,
    scratch: RefCell<crate::ast::analysis::ProgramAnalysis>,
}

impl Featurizer {
    /// Empty-cache featurizer for a representation, fast paths on.
    pub fn new(repr: Representation) -> Self {
        Featurizer::with_fast(repr, true)
    }

    /// Empty-cache featurizer with the fast paths toggled explicitly
    /// (`fast = false` forces the reference full-extraction path; see
    /// [`TuneOptions::fast_paths`]).
    pub fn with_fast(repr: Representation, fast: bool) -> Self {
        Featurizer::with_capacity(repr, fast, FEAT_CACHE_CAP)
    }

    /// Featurizer with an explicit row-cache capacity (≥ 1). Capping
    /// the cache changes wall-clock only — rows are recomputed, never
    /// approximated — so results stay bit-for-bit identical.
    pub fn with_capacity(repr: Representation, fast: bool, capacity: usize) -> Self {
        Featurizer {
            repr,
            fast,
            capacity: capacity.max(1),
            epoch: std::cell::Cell::new(0),
            evictions: std::cell::Cell::new(0),
            cache: RefCell::new(HashMap::new()),
            structures: RefCell::new(crate::ast::analysis::StructureCache::new()),
            scratch: RefCell::new(crate::ast::analysis::ProgramAnalysis {
                chains: Vec::new(),
            }),
        }
    }

    /// Whether the bit-exact fast paths are enabled.
    pub fn is_fast(&self) -> bool {
        self.fast
    }

    /// Insert a row, evicting the oldest epoch's rows when the cache is
    /// at capacity (wholesale — a generation at a time; if every entry
    /// shares the current epoch the whole cache turns over, which still
    /// guarantees progress).
    fn insert_row(&self, cache: &mut HashMap<u64, (u64, Vec<f64>)>, key: u64, row: Vec<f64>) {
        if cache.len() >= self.capacity && !cache.contains_key(&key) {
            let min = cache.values().map(|(ep, _)| *ep).min().unwrap_or(0);
            let before = cache.len();
            cache.retain(|_, (ep, _)| *ep != min);
            self.evictions.set(self.evictions.get() + (before - cache.len()) as u64);
        }
        cache.insert(key, (self.epoch.get(), row));
    }

    /// One program-repr row via the structure-cached delta path.
    fn delta_row(&self, task: &Task, e: &ConfigEntity) -> Vec<f64> {
        let mut analysis = self.scratch.borrow_mut();
        self.structures
            .borrow_mut()
            .analyze_delta(task, e, &mut analysis)
            .expect("template configs must lower");
        let mut row = vec![0.0; self.repr.dim()];
        crate::features::extract_into(self.repr, task, e, &analysis, &mut row);
        row
    }

    /// Feature matrix for `entities`, computing missing rows (in
    /// parallel on the reference path, through the delta path when the
    /// fast paths are on) and memoizing them.
    pub fn features(&self, task: &Task, entities: &[ConfigEntity]) -> Matrix {
        self.epoch.set(self.epoch.get() + 1);
        let keys: Vec<u64> = entities.iter().map(|e| task.space.index_of(e)).collect();
        // Snapshot cached rows up front: the inserts below may evict
        // older generations (and, when the capacity is smaller than the
        // batch, even this call's), so the output rows must not rely on
        // re-reading the cache after computing.
        let mut rows: Vec<Option<Vec<f64>>> = {
            let c = self.cache.borrow();
            keys.iter().map(|k| c.get(k).map(|(_, r)| r.clone())).collect()
        };
        let missing: Vec<(usize, ConfigEntity)> = rows
            .iter()
            .enumerate()
            .filter(|(_, r)| r.is_none())
            .map(|(i, _)| (i, entities[i].clone()))
            .collect();
        if !missing.is_empty() {
            if self.fast && self.repr == Representation::Config {
                // Config features depend only on the knob choices:
                // identical to extract(Config, ..) minus the lower +
                // analyze the Config arm ignores anyway.
                for (i, e) in missing {
                    let row = crate::features::config_padded(&task.space, &e);
                    self.insert_row(&mut self.cache.borrow_mut(), keys[i], row.clone());
                    rows[i] = Some(row);
                }
            } else if self.fast && task.delta_capable() {
                // Program-derived representations: delta replay per row
                // (serial — the replay is allocation-light and far
                // cheaper than a parallel fresh lower+analyze). Sketch
                // tasks skip this arm — their leading sketch knob breaks
                // the positional split contract the replay keys on — and
                // take the reference batch path below instead.
                for (i, e) in missing {
                    let row = self.delta_row(task, &e);
                    self.insert_row(&mut self.cache.borrow_mut(), keys[i], row.clone());
                    rows[i] = Some(row);
                }
            } else {
                let es: Vec<ConfigEntity> =
                    missing.iter().map(|(_, e)| e.clone()).collect();
                let batch = crate::features::featurize_batch(self.repr, task, &es);
                for (bi, (i, _)) in missing.into_iter().enumerate() {
                    let row = batch.row(bi).expect("template configs must lower");
                    self.insert_row(&mut self.cache.borrow_mut(), keys[i], row.to_vec());
                    rows[i] = Some(row.to_vec());
                }
            }
        }
        let rows: Vec<Vec<f64>> = rows.into_iter().map(|r| r.unwrap()).collect();
        Matrix::from_rows(&rows)
    }

    /// Feature matrix for single-knob SA neighbors: each `proposals[i]`
    /// differs from `parents[i]` in (at most) knob `knobs[i]`.
    ///
    /// Under [`Representation::Config`] the row is the cached parent
    /// row with only that knob's feature slice rewritten — bit-identical
    /// to a fresh extraction (the slice helpers on
    /// [`ConfigSpace`](crate::schedule::space::ConfigSpace) are the
    /// single source of truth for both paths). Under the program-derived
    /// representations the row comes from the structure-cached delta
    /// path: the proposal's structure key picks a cached donor analysis,
    /// the donor is replayed with the proposal's extents (no lowering),
    /// and the row is re-emitted through the same
    /// [`extract_into`](crate::features::extract_into) the fresh path
    /// uses. Computed rows are memoized like any other. Returns `None`
    /// (caller falls back to the full path) when the fast paths are off,
    /// or when a Config-repr parent row is not cached.
    pub fn neighbor_features(
        &self,
        task: &Task,
        parents: &[ConfigEntity],
        proposals: &[ConfigEntity],
        knobs: &[usize],
    ) -> Option<Matrix> {
        if !self.fast {
            return None;
        }
        self.epoch.set(self.epoch.get() + 1);
        debug_assert_eq!(parents.len(), proposals.len());
        debug_assert_eq!(parents.len(), knobs.len());
        let space = &task.space;
        let mut rows: Vec<Vec<f64>> = Vec::with_capacity(proposals.len());
        if self.repr != Representation::Config {
            if !task.delta_capable() {
                // Sketch tasks have no structure-cached delta path;
                // fall back to the full score path (slower, identical).
                return None;
            }
            // Program-derived representations: delta replay per missing
            // row (the parent row is not needed — the donor analysis of
            // the proposal's structure is).
            for e in proposals {
                let key = space.index_of(e);
                if let Some((_, r)) = self.cache.borrow().get(&key) {
                    rows.push(r.clone());
                    continue;
                }
                let row = self.delta_row(task, e);
                self.insert_row(&mut self.cache.borrow_mut(), key, row.clone());
                rows.push(row);
            }
            return Some(Matrix::from_rows(&rows));
        }
        let mut cache = self.cache.borrow_mut();
        for ((p, e), &j) in parents.iter().zip(proposals).zip(knobs) {
            let key = space.index_of(e);
            if let Some((_, r)) = cache.get(&key) {
                rows.push(r.clone());
                continue;
            }
            let mut row = cache.get(&space.index_of(p))?.1.clone();
            let off = space.knob_feature_offset(j);
            // Rows are padded/truncated to CONFIG_DIM; a slice past the
            // end was truncated away by the full path too.
            if off < row.len() {
                let d = space.knob_feature_dim(j);
                let mut buf = vec![0.0; d];
                space.knob_features_into(j, e.choices[j], &mut buf);
                let end = (off + d).min(row.len());
                row[off..end].copy_from_slice(&buf[..end - off]);
            }
            self.insert_row(&mut cache, key, row.clone());
            rows.push(row);
        }
        Some(Matrix::from_rows(&rows))
    }

    /// Number of memoized feature rows.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Cache and delta-path counters.
    pub fn stats(&self) -> FeaturizerStats {
        let s = self.structures.borrow().stats();
        FeaturizerStats {
            cached: self.cache.borrow().len(),
            capacity: self.capacity,
            evictions: self.evictions.get(),
            structures: s.structures,
            delta_hits: s.delta_hits,
            fallbacks: s.fallbacks,
        }
    }
}

struct TunerScorer<'a> {
    task: &'a Task,
    feat: &'a Featurizer,
    model: &'a dyn CostModel,
    acquisition: Acquisition,
    best: f64,
}

impl TunerScorer<'_> {
    /// Acquisition scores for an already-featurized batch (shared by
    /// the full and incremental paths, so they cannot drift).
    fn score_rows(&self, x: &Matrix) -> Vec<f64> {
        match self.acquisition {
            Acquisition::Mean => self.model.predict(x),
            acq => self
                .model
                .predict_stats(x)
                .into_iter()
                .map(|(m, s)| acq.score(m, s, self.best))
                .collect(),
        }
    }
}

impl Scorer for TunerScorer<'_> {
    fn score(&self, entities: &[ConfigEntity]) -> Vec<f64> {
        let x = self.feat.features(self.task, entities);
        self.score_rows(&x)
    }

    fn score_neighbors(
        &self,
        parents: &[ConfigEntity],
        proposals: &[ConfigEntity],
        knobs: &[usize],
    ) -> Vec<f64> {
        // Incremental featurization (fast paths on): per-knob slice
        // patching under Config, structure-cached delta replay under the
        // program-derived representations. The feature rows are
        // bit-identical to a fresh extraction either way, so this
        // changes wall-clock only, never scores.
        if let Some(x) =
            self.feat.neighbor_features(self.task, parents, proposals, knobs)
        {
            return self.score_rows(&x);
        }
        self.score(proposals)
    }
}

/// Trial accounting shared by every loop: best-so-far tracking, the
/// per-trial curve, the failure policy (errored trials are recorded
/// with 0 GFLOPS and never become `best`), and optional live streaming
/// of every trial into a shared [`TuningDb`] via [`DbSink`].
#[derive(Default)]
pub struct TrialAccountant {
    /// Best successful (config, GFLOPS) so far.
    pub best: Option<(ConfigEntity, f64)>,
    /// best-so-far GFLOPS after each trial (1-based trial count).
    pub curve: Vec<f64>,
    /// Every absorbed trial, in measurement order.
    pub records: Vec<TrialRecord>,
    /// Trials absorbed so far.
    pub trials: usize,
    sink: Option<DbSink>,
}

impl TrialAccountant {
    /// Fresh accountant without a DB sink.
    pub fn new() -> Self {
        TrialAccountant::default()
    }

    /// Accountant that streams every absorbed trial into `sink` (if
    /// any) as a side effect of [`absorb`](Self::absorb).
    pub fn with_sink(sink: Option<DbSink>) -> Self {
        TrialAccountant { sink, ..TrialAccountant::default() }
    }

    /// Best GFLOPS so far (0.0 before any success).
    pub fn best_gflops(&self) -> f64 {
        self.best.as_ref().map(|(_, g)| *g).unwrap_or(0.0)
    }

    /// Record one measured batch; returns the training labels
    /// (GFLOPS, with failures mapped to 0.0).
    pub fn absorb(&mut self, batch: &[ConfigEntity], results: &[MeasureResult]) -> Vec<f64> {
        debug_assert_eq!(batch.len(), results.len());
        let mut labels = Vec::with_capacity(batch.len());
        for (e, r) in batch.iter().zip(results) {
            let gf = if r.is_ok() { r.gflops } else { 0.0 };
            if r.is_ok() && self.best.as_ref().map_or(true, |(_, bg)| gf > *bg) {
                self.best = Some((e.clone(), gf));
            }
            self.curve.push(self.best.as_ref().map(|(_, g)| *g).unwrap_or(0.0));
            self.records.push(TrialRecord {
                entity: e.clone(),
                gflops: gf,
                seconds: r.seconds,
                error: r.error.clone(),
            });
            if let Some(sink) = &self.sink {
                sink.record(e, gf, r);
            }
            labels.push(gf);
        }
        self.trials += batch.len();
        labels
    }

    /// Consume the accountant into its final [`TuneResult`].
    pub fn into_result(self) -> TuneResult {
        TuneResult { best: self.best, curve: self.curve, records: self.records }
    }

    /// Clone the accounting so far into a [`TuneResult`] without ending
    /// the run — the incremental drivers ([`Tuner::tune_more`], the
    /// graph-level [`scheduler`]) read results between slices.
    pub fn result_snapshot(&self) -> TuneResult {
        TuneResult {
            best: self.best.clone(),
            curve: self.curve.clone(),
            records: self.records.clone(),
        }
    }
}

/// The model-guided candidate collector a [`BatchProposer`] runs each
/// round: persistent-chain SA or the evolutionary refiner, both
/// exposing the same `collect` contract (distinct candidates,
/// best-first, all randomness from the caller's [`Rng`]).
enum Explorer {
    Sa(ParallelSa),
    Evo(Evolutionary),
}

impl Explorer {
    fn collect(
        &mut self,
        space: &crate::schedule::space::ConfigSpace,
        scorer: &dyn Scorer,
        top_k: usize,
        rng: &mut Rng,
    ) -> Vec<(ConfigEntity, f64)> {
        match self {
            Explorer::Sa(sa) => sa.collect(space, scorer, top_k, rng),
            Explorer::Evo(evo) => evo.collect(space, scorer, top_k, rng),
        }
    }
}

/// Batch proposal per Algorithm 1: explorer pool (SA chains or the
/// evolutionary population, per [`TuneOptions::search`]) → dedup
/// against everything already proposed → diversity (or top-b) selection
/// → ε-greedy random tail. Owns the persistent explorer state, the
/// proposal RNG stream and a [`Featurizer`]; shared verbatim by the
/// serial and pipelined loops.
pub struct BatchProposer {
    /// Shared feature extraction + memo cache.
    pub feat: Featurizer,
    explorer: Explorer,
    rng: Rng,
    proposed: HashSet<ConfigEntity>,
}

impl BatchProposer {
    /// Fresh proposer (explorer state, RNG stream, dedup set) for a run.
    pub fn new(options: &TuneOptions) -> Self {
        BatchProposer {
            feat: Featurizer::with_capacity(
                options.repr,
                options.fast_paths,
                options.feat_cache_cap.unwrap_or(FEAT_CACHE_CAP),
            ),
            explorer: match options.search {
                SearchKind::Sa => Explorer::Sa(ParallelSa::new(options.sa.clone())),
                SearchKind::Evo => Explorer::Evo(Evolutionary::new(options.evo.clone())),
            },
            rng: Rng::seed_from_u64(options.seed ^ 0x7u64.wrapping_mul(0x9E3779B97F4A7C15)),
            proposed: HashSet::new(),
        }
    }

    /// Number of configs proposed so far (all distinct).
    pub fn proposed_count(&self) -> usize {
        self.proposed.len()
    }

    /// Pick the next measurement batch of (at most) `b` configs, none
    /// of which has been proposed before. Empty ⇒ space exhausted.
    pub fn propose(
        &mut self,
        task: &Task,
        options: &TuneOptions,
        model: &dyn CostModel,
        b: usize,
        best_y: f64,
    ) -> Vec<ConfigEntity> {
        let BatchProposer { feat, explorer, rng, proposed } = self;
        let mut batch: Vec<ConfigEntity> = Vec::with_capacity(b);
        if model.ready() {
            let scorer = TunerScorer {
                task,
                feat,
                model,
                acquisition: options.acquisition,
                best: best_y,
            };
            let pool = explorer.collect(&task.space, &scorer, options.lambda * b, rng);
            let fresh: Vec<(ConfigEntity, f64)> =
                pool.into_iter().filter(|(e, _)| !proposed.contains(e)).collect();
            let n_rand = ((b as f64 * options.eps).round() as usize).min(b);
            let n_model = b - n_rand;
            let picked = if options.diversity {
                diverse_select(task.space.num_knobs(), &fresh, n_model, options.alpha)
            } else {
                crate::explore::top_select(&fresh, n_model)
            };
            batch.extend(picked);
            // ε-greedy random tail + top-up if SA pool was too small
            let mut avoid: HashSet<ConfigEntity> = proposed.clone();
            avoid.extend(batch.iter().cloned());
            let tail = random_batch(&task.space, b - batch.len(), &avoid, rng);
            batch.extend(tail);
        } else {
            batch = random_batch(&task.space, b, proposed, rng);
        }
        proposed.extend(batch.iter().cloned());
        batch
    }
}

/// Persistent state of an incremental tuning loop: the trial accountant
/// plus the growing training set `D` (measured configs, labels, batch
/// groups) the model refits on. Both serial and pipelined drivers keep
/// one across calls, so a run can be spent in slices — the contract the
/// graph-level [`scheduler`] builds on.
pub(crate) struct LoopState {
    /// Best-so-far / curve / record accounting (and the live DB sink).
    pub(crate) acct: TrialAccountant,
    pub(crate) xs: Vec<ConfigEntity>,
    pub(crate) ys: Vec<f64>,
    pub(crate) groups: Vec<usize>,
}

impl LoopState {
    pub(crate) fn new(sink: Option<DbSink>) -> Self {
        LoopState {
            acct: TrialAccountant::with_sink(sink),
            xs: Vec::new(),
            ys: Vec::new(),
            groups: Vec::new(),
        }
    }
}

/// The serial Algorithm-1 round structure over shared parts: propose →
/// measure → absorb → refit on all of `D`, continuing from `state`
/// until the accountant reaches `target_trials` total trials (or the
/// space is exhausted). Used by [`Tuner`] and as the pipelined tuner's
/// fallback for models without snapshot support.
pub(crate) fn serial_steps(
    task: &Task,
    opts: &TuneOptions,
    proposer: &mut BatchProposer,
    model: &mut dyn CostModel,
    measurer: &dyn Measurer,
    state: &mut LoopState,
    target_trials: usize,
) {
    while state.acct.trials < target_trials {
        let b = opts.batch.min(target_trials - state.acct.trials);
        let batch = proposer.propose(task, opts, model, b, state.acct.best_gflops());
        if batch.is_empty() {
            break; // space exhausted
        }
        let results = measurer.measure(task, &batch);
        let labels = state.acct.absorb(&batch, &results);
        state.xs.extend(batch.iter().cloned());
        state.ys.extend(labels);
        state.groups.push(batch.len());

        // refit f̂ on all of D
        let x = proposer.feat.features(task, &state.xs);
        model.fit(&x, &state.ys, &state.groups);
        if opts.verbose {
            println!(
                "[{}] trials={:4} best={:.1} GFLOPS",
                measurer.target(),
                state.acct.trials,
                state.acct.best_gflops()
            );
        }
    }
}

/// Progress report of one [`SliceRun`] step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SliceStep {
    /// The step performed one unit of work (a batch proposed and
    /// submitted, or a measured batch absorbed and refitted); call
    /// again.
    Working,
    /// The slice is finished: every proposed batch has been measured,
    /// **absorbed and streamed into the DB sink** (if one is
    /// configured). Nothing of the slice is still in flight — the
    /// completion barrier covers the sink, so a caller computing gains
    /// from DB-served state at this point sees every record of the
    /// slice.
    Complete,
}

/// A cooperative (pollable) slice of an incremental tuning run — the
/// joinable-`tune_more` contract, cut into single-batch steps so a
/// caller can interleave several tasks' slices on one thread while
/// their measurement batches drain on a shared asynchronous farm.
///
/// Obtained from [`Tuner::begin_slice`] /
/// [`pipeline::PipelinedTuner::begin_slice`] and advanced with the
/// matching `step_slice`. Each step either proposes-and-submits one
/// batch (through the asynchronous [`Measurer::submit`] pair, so the
/// farm measures it in the background) or waits-absorbs-refits the
/// oldest in-flight batch. The op sequence is identical to the blocking
/// drivers — `begin_slice` + steps on the serial [`Tuner`] reproduces
/// [`Tuner::tune_more`] bit-for-bit, and on the pipelined driver it
/// reproduces the threaded epoch discipline (batch `k` proposed from
/// the model state of epoch `max(0, k − (depth − 1))`) — so polled and
/// joined slices are interchangeable under a fixed seed.
pub struct SliceRun {
    /// Absolute accountant trial count at which the slice is complete.
    target: usize,
    /// In-flight ticket bound: 1 = the serial schedule, `d` = the
    /// pipelined epoch discipline at depth `d`.
    depth: usize,
    /// Trials proposed so far (absorbed + in flight), absolute.
    proposed: usize,
    /// Submitted-but-unabsorbed batches, oldest first.
    inflight: VecDeque<(Vec<ConfigEntity>, BatchTicket)>,
    /// The proposer returned an empty batch: the space is exhausted.
    exhausted: bool,
}

impl SliceRun {
    /// Whether any submitted batch is still unabsorbed.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }
}

/// One cooperative step of a slice: fill the slice's own pipeline (one
/// propose + submit) if there is room, else absorb + refit the oldest
/// in-flight batch. Returns [`SliceStep::Complete`] only when nothing
/// is proposed, in flight, or left to propose — i.e. after the last
/// absorb has streamed its records into the sink, never before.
#[allow(clippy::too_many_arguments)]
pub(crate) fn slice_step(
    task: &Task,
    opts: &TuneOptions,
    proposer: &mut BatchProposer,
    model: &mut dyn CostModel,
    fit_feat: Option<&Featurizer>,
    measurer: &dyn Measurer,
    state: &mut LoopState,
    run: &mut SliceRun,
) -> SliceStep {
    if !run.exhausted && run.proposed < run.target && run.inflight.len() < run.depth {
        let b = opts.batch.min(run.target - run.proposed);
        let batch =
            proposer.propose(task, opts, model, b, state.acct.best_gflops());
        if batch.is_empty() {
            run.exhausted = true;
        } else {
            run.proposed += batch.len();
            let ticket = measurer.submit(task, &batch);
            run.inflight.push_back((batch, ticket));
            return SliceStep::Working;
        }
    }
    if let Some((batch, ticket)) = run.inflight.pop_front() {
        let results = measurer.wait(ticket);
        let labels = state.acct.absorb(&batch, &results);
        state.xs.extend(batch.iter().cloned());
        state.ys.extend(labels);
        state.groups.push(batch.len());
        // refit f̂ on all of D (the fit featurizer is the proposal cache
        // for the serial schedule, a dedicated one for the pipelined)
        let feat = fit_feat.unwrap_or(&proposer.feat);
        let x = feat.features(task, &state.xs);
        model.fit(&x, &state.ys, &state.groups);
        if opts.verbose {
            println!(
                "[{}|slice] trials={:4} best={:.1} GFLOPS",
                measurer.target(),
                state.acct.trials,
                state.acct.best_gflops()
            );
        }
        if !run.inflight.is_empty()
            || (!run.exhausted && state.acct.trials < run.target)
        {
            return SliceStep::Working;
        }
    }
    SliceStep::Complete
}

/// The serial Algorithm-1 driver (reference loop). The pipelined
/// production driver is [`pipeline::PipelinedTuner`].
///
/// The tuner is *incremental*: its SA chains, dedup set, model and
/// training set persist across calls, so the budget can be spent in
/// slices via [`tune_more`](Self::tune_more) — the execution contract
/// of the graph-level [`scheduler`]. [`tune`](Self::tune) runs up to
/// the `n_trials` of [`TuneOptions`] and is equivalent to one
/// `tune_more(n_trials)` on a fresh tuner.
pub struct Tuner {
    /// The task being tuned.
    pub task: Task,
    /// Loop configuration (batch size, SA budget, seed, sink, …).
    pub options: TuneOptions,
    model: Box<dyn CostModel>,
    proposer: BatchProposer,
    state: LoopState,
}

impl Tuner {
    /// Build a tuner from a task, a cost model and loop options.
    pub fn new(task: Task, model: Box<dyn CostModel>, options: TuneOptions) -> Self {
        let proposer = BatchProposer::new(&options);
        let state = LoopState::new(options.sink.clone());
        Tuner { task, options, model, proposer, state }
    }

    /// Run the tuning loop against a measurement back-end until the
    /// configured `n_trials` total trials have been measured.
    pub fn tune(&mut self, measurer: &dyn Measurer) -> TuneResult {
        let target = self.options.n_trials;
        let extra = target.saturating_sub(self.state.acct.trials);
        self.tune_more(measurer, extra);
        self.state.acct.result_snapshot()
    }

    /// Spend `extra` more measurement trials, continuing the persistent
    /// loop (same SA chains, no re-proposals, model refit on all of
    /// `D`). Returns the best GFLOPS so far.
    pub fn tune_more(&mut self, measurer: &dyn Measurer, extra: usize) -> f64 {
        let opts = self.options.clone();
        let target = self.state.acct.trials + extra;
        serial_steps(
            &self.task,
            &opts,
            &mut self.proposer,
            self.model.as_mut(),
            measurer,
            &mut self.state,
            target,
        );
        self.state.acct.best_gflops()
    }

    /// Trials measured so far (across all slices).
    pub fn trials(&self) -> usize {
        self.state.acct.trials
    }

    /// Best measured (config, GFLOPS) so far, if any trial succeeded.
    pub fn best(&self) -> Option<&(ConfigEntity, f64)> {
        self.state.acct.best.as_ref()
    }

    /// Snapshot of the accounting so far (curve, records, best).
    pub fn result(&self) -> TuneResult {
        self.state.acct.result_snapshot()
    }

    /// Begin a *pollable* slice of `extra` trials: the cooperative
    /// counterpart of [`tune_more`](Self::tune_more), advanced one
    /// batch at a time with [`step_slice`](Self::step_slice) so a
    /// caller (the overlapped graph scheduler) can interleave several
    /// tasks' slices on one thread. Stepping a slice to completion
    /// performs exactly the `tune_more` op sequence — bit-for-bit
    /// identical results under a fixed seed.
    pub fn begin_slice(&mut self, extra: usize) -> SliceRun {
        let at = self.state.acct.trials;
        SliceRun {
            target: at + extra,
            depth: 1,
            proposed: at,
            inflight: VecDeque::new(),
            exhausted: false,
        }
    }

    /// Advance a slice from [`begin_slice`](Self::begin_slice) by one
    /// unit of work (propose-and-submit one batch, or absorb-and-refit
    /// the oldest in-flight one). Only one slice may be in flight per
    /// tuner at a time; interleave slices of *different* tuners.
    pub fn step_slice(&mut self, measurer: &dyn Measurer, run: &mut SliceRun) -> SliceStep {
        let opts = self.options.clone();
        slice_step(
            &self.task,
            &opts,
            &mut self.proposer,
            self.model.as_mut(),
            None,
            measurer,
            &mut self.state,
            run,
        )
    }
}

/// Convenience: tune with a fresh GBT(rank) model — the paper's default.
pub fn tune_gbt(
    task: Task,
    measurer: &dyn Measurer,
    options: TuneOptions,
) -> TuneResult {
    let params = crate::gbt::GbtParams { seed: options.seed, ..Default::default() };
    let model = Box::new(crate::model::GbtModel::with_fast_paths(params, options.fast_paths));
    Tuner::new(task, model, options).tune(measurer)
}

/// Pipelined counterpart of [`tune_gbt`]: same trial budget and
/// batch construction, but exploration, measurement and model refits
/// overlap (see [`pipeline`] for the stage diagram and the determinism
/// contract).
pub fn tune_gbt_pipelined(
    task: Task,
    measurer: &dyn Measurer,
    options: TuneOptions,
) -> TuneResult {
    let params = crate::gbt::GbtParams { seed: options.seed, ..Default::default() };
    let model = Box::new(crate::model::GbtModel::with_fast_paths(params, options.fast_paths));
    pipeline::PipelinedTuner::new(task, model, options).tune(measurer)
}

/// Baseline: pure random search (Fig. 4 "Random").
pub fn tune_random(task: Task, measurer: &dyn Measurer, options: TuneOptions) -> TuneResult {
    let mut rng = Rng::seed_from_u64(options.seed ^ 0xAA55);
    let mut seen = HashSet::new();
    let mut acct = TrialAccountant::with_sink(options.sink.clone());
    while acct.trials < options.n_trials {
        let b = options.batch.min(options.n_trials - acct.trials);
        let batch = random_batch(&task.space, b, &seen, &mut rng);
        if batch.is_empty() {
            break;
        }
        seen.extend(batch.iter().cloned());
        let results = measurer.measure(&task, &batch);
        acct.absorb(&batch, &results);
    }
    acct.into_result()
}

/// Baseline: genetic algorithm (Fig. 4 "GA").
pub fn tune_ga(task: Task, measurer: &dyn Measurer, options: TuneOptions) -> TuneResult {
    let mut rng = Rng::seed_from_u64(options.seed ^ 0x6A6A);
    let mut ga = crate::explore::Genetic::new(options.batch);
    let mut acct = TrialAccountant::with_sink(options.sink.clone());
    while acct.trials < options.n_trials {
        let batch = ga.propose(&task.space, &mut rng);
        let batch: Vec<ConfigEntity> =
            batch.into_iter().take(options.n_trials - acct.trials).collect();
        let results = measurer.measure(&task, &batch);
        let fitness = acct.absorb(&batch, &results);
        ga.update(&batch, &fitness);
    }
    acct.into_result()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::measure::SimMeasurer;
    use crate::schedule::template::TemplateKind;
    use crate::sim::devices::sim_gpu;

    fn small_options(n: usize) -> TuneOptions {
        TuneOptions {
            n_trials: n,
            batch: 16,
            sa: SaParams { n_chains: 16, n_steps: 40, ..Default::default() },
            seed: 3,
            ..Default::default()
        }
    }

    #[test]
    fn gbt_tuner_improves_and_tracks_curve() {
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let m = SimMeasurer::with_seed(sim_gpu(), 1);
        let res = tune_gbt(task, &m, small_options(96));
        assert_eq!(res.curve.len(), 96);
        assert!(res.best.is_some());
        // curve is monotone nondecreasing
        for w in res.curve.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // later best must be >= first-batch best
        assert!(res.best_at(96) >= res.best_at(16));
    }

    #[test]
    fn model_beats_random_on_average() {
        // the core §6.1 claim, in miniature
        let mk_task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let mut wins = 0;
        for seed in 0..3u64 {
            let m = SimMeasurer::with_seed(sim_gpu(), 100 + seed);
            let mut o = small_options(96);
            o.seed = seed;
            let gbt = tune_gbt(mk_task(), &m, o.clone());
            let m2 = SimMeasurer::with_seed(sim_gpu(), 100 + seed);
            let rnd = tune_random(mk_task(), &m2, o);
            if gbt.best_gflops() >= rnd.best_gflops() {
                wins += 1;
            }
        }
        assert!(wins >= 2, "GBT won only {wins}/3 against random");
    }

    #[test]
    fn random_and_ga_produce_full_curves() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let m = SimMeasurer::with_seed(crate::sim::devices::sim_cpu(), 5);
        let r = tune_random(task.clone(), &m, small_options(48));
        assert_eq!(r.curve.len(), 48);
        let g = tune_ga(task, &m, small_options(48));
        assert_eq!(g.curve.len(), 48);
        assert!(g.best_gflops() > 0.0);
    }

    #[test]
    fn trials_to_reach_semantics() {
        let res = TuneResult {
            best: None,
            curve: vec![1.0, 1.0, 5.0, 5.0],
            records: vec![],
        };
        assert_eq!(res.trials_to_reach(1.0), Some(1));
        assert_eq!(res.trials_to_reach(5.0), Some(3));
        assert_eq!(res.trials_to_reach(9.0), None);
    }

    #[test]
    fn batches_never_remeasure_configs() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let m = SimMeasurer::with_seed(crate::sim::devices::sim_cpu(), 6);
        let res = tune_gbt(task, &m, small_options(64));
        let mut uniq = HashSet::new();
        for r in &res.records {
            assert!(uniq.insert(r.entity.clone()), "config measured twice");
        }
    }

    #[test]
    fn fast_paths_do_not_change_fixed_seed_results() {
        // the fast-path determinism contract: compiled-plan inference +
        // incremental Config featurization are bit-exact, so the whole
        // run is identical with them on or off.
        for repr in [Representation::Config, Representation::Full] {
            let mk_task = || Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
            let mut o = small_options(64);
            o.repr = repr;
            o.fast_paths = true;
            let fast =
                tune_gbt(mk_task(), &SimMeasurer::with_seed(sim_gpu(), 11), o.clone());
            o.fast_paths = false;
            let slow = tune_gbt(mk_task(), &SimMeasurer::with_seed(sim_gpu(), 11), o);
            assert_eq!(fast.curve, slow.curve, "curve diverged under {repr:?}");
            let fe: Vec<_> = fast.records.iter().map(|r| r.entity.clone()).collect();
            let se: Vec<_> = slow.records.iter().map(|r| r.entity.clone()).collect();
            assert_eq!(fe, se, "trial sequence diverged under {repr:?}");
            assert_eq!(
                fast.best_gflops().to_bits(),
                slow.best_gflops().to_bits(),
                "best diverged under {repr:?}"
            );
        }
    }

    #[test]
    fn neighbor_features_match_fresh_extraction() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let feat = Featurizer::new(Representation::Config);
        let mut rng = Rng::seed_from_u64(21);
        let parents: Vec<ConfigEntity> =
            (0..16).map(|_| task.space.sample(&mut rng)).collect();
        feat.features(&task, &parents); // seed the cache with parent rows
        let mut knobs = Vec::new();
        let proposals: Vec<ConfigEntity> = parents
            .iter()
            .map(|p| {
                let (e, j) = task.space.mutate_knob(p, &mut rng);
                knobs.push(j);
                e
            })
            .collect();
        let inc = feat
            .neighbor_features(&task, &parents, &proposals, &knobs)
            .expect("parents are cached");
        let fresh = Featurizer::with_fast(Representation::Config, false)
            .features(&task, &proposals);
        assert_eq!(inc.rows, fresh.rows);
        for i in 0..inc.rows {
            assert_eq!(inc.row(i), fresh.row(i), "row {i} diverged");
        }
        // a fast featurizer without cached parents falls back cleanly
        let cold = Featurizer::new(Representation::Config);
        assert!(cold.neighbor_features(&task, &parents, &proposals, &knobs).is_none());
    }

    #[test]
    fn program_repr_neighbor_features_match_fresh_extraction() {
        for repr in [Representation::Full, Representation::ContextRelation] {
            let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
            let feat = Featurizer::new(repr);
            let mut rng = Rng::seed_from_u64(23);
            let parents: Vec<ConfigEntity> =
                (0..16).map(|_| task.space.sample(&mut rng)).collect();
            feat.features(&task, &parents);
            let mut knobs = Vec::new();
            let proposals: Vec<ConfigEntity> = parents
                .iter()
                .map(|p| {
                    let (e, j) = task.space.mutate_knob(p, &mut rng);
                    knobs.push(j);
                    e
                })
                .collect();
            let inc = feat
                .neighbor_features(&task, &parents, &proposals, &knobs)
                .expect("program representations take the delta path");
            let fresh = Featurizer::with_fast(repr, false).features(&task, &proposals);
            assert_eq!(inc.rows, fresh.rows);
            for i in 0..inc.rows {
                assert_eq!(inc.row(i), fresh.row(i), "row {i} diverged under {repr:?}");
            }
            assert!(feat.stats().structures >= 1);
        }
    }

    #[test]
    fn delta_path_counts_structure_replays() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let feat = Featurizer::new(Representation::ContextRelation);
        let mut rng = Rng::seed_from_u64(9);
        let e = task.space.sample(&mut rng);
        // A duplicated entity is computed twice within one call (both
        // occurrences miss the row cache) — the second analysis must be
        // served by replaying the structure cached by the first.
        feat.features(&task, &[e.clone(), e]);
        let s = feat.stats();
        assert_eq!(s.structures, 1);
        assert!(s.delta_hits + s.fallbacks >= 1);
    }

    #[test]
    fn row_cache_eviction_is_bounded_and_bit_exact() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(31);
        let ents: Vec<ConfigEntity> =
            (0..16).map(|_| task.space.sample(&mut rng)).collect();
        let capped = Featurizer::with_capacity(Representation::Config, true, 4);
        let unbounded = Featurizer::new(Representation::Config);
        let a = capped.features(&task, &ents);
        let b = unbounded.features(&task, &ents);
        assert_eq!(a.rows, b.rows);
        for i in 0..a.rows {
            assert_eq!(a.row(i), b.row(i), "row {i} diverged under eviction");
        }
        let s = capped.stats();
        assert!(s.evictions > 0, "a 16-row batch must evict at capacity 4");
        assert!(s.cached <= 4);
        assert_eq!(s.capacity, 4);
        assert_eq!(unbounded.stats().evictions, 0);
    }

    #[test]
    fn accountant_failure_policy() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let mut rng = Rng::seed_from_u64(1);
        let batch: Vec<ConfigEntity> = (0..4).map(|_| task.space.sample(&mut rng)).collect();
        let results = vec![
            MeasureResult::err("board timeout"),
            MeasureResult::ok(10.0, 1e-3),
            MeasureResult::err("build error"),
            MeasureResult::ok(5.0, 2e-3),
        ];
        let mut acct = TrialAccountant::new();
        let labels = acct.absorb(&batch, &results);
        assert_eq!(labels, vec![0.0, 10.0, 0.0, 5.0]);
        assert_eq!(acct.curve, vec![0.0, 10.0, 10.0, 10.0]);
        // best comes from a successful trial, never from a failure
        assert_eq!(acct.best.as_ref().unwrap().0, batch[1]);
        let res = acct.into_result();
        assert_eq!(res.best_gflops(), 10.0);
        assert_eq!(res.records.iter().filter(|r| r.error.is_some()).count(), 2);
    }

    #[test]
    fn evo_search_is_deterministic_and_improves() {
        let mk_task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let mut o = small_options(64);
        o.search = crate::explore::SearchKind::Evo;
        o.evo = EvoParams { population: 32, generations: 8, ..Default::default() };
        let a = tune_gbt(mk_task(), &SimMeasurer::with_seed(sim_gpu(), 41), o.clone());
        let b = tune_gbt(mk_task(), &SimMeasurer::with_seed(sim_gpu(), 41), o);
        assert_eq!(a.curve, b.curve, "evo search not seed-deterministic");
        let ea: Vec<_> = a.records.iter().map(|r| r.entity.clone()).collect();
        let eb: Vec<_> = b.records.iter().map(|r| r.entity.clone()).collect();
        assert_eq!(ea, eb);
        assert!(a.best_gflops() > 0.0);
        assert!(a.best_at(64) >= a.best_at(16));
    }

    #[test]
    fn evo_search_never_remeasures_configs() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let m = SimMeasurer::with_seed(crate::sim::devices::sim_cpu(), 43);
        let mut o = small_options(64);
        o.search = crate::explore::SearchKind::Evo;
        o.evo = EvoParams { population: 32, generations: 8, ..Default::default() };
        let res = tune_gbt(task, &m, o);
        let mut uniq = HashSet::new();
        for r in &res.records {
            assert!(uniq.insert(r.entity.clone()), "config measured twice");
        }
    }

    #[test]
    fn sketch_task_tunes_end_to_end() {
        // Sketch spaces flow through the whole loop: the leading sketch
        // knob disables the delta path (delta_capable gating), Config
        // rows carry the sketch id, and every proposed config lowers.
        for repr in [Representation::Config, Representation::Full] {
            let task = Task::with_sketches(ops::matmul(64, 64, 64), TemplateKind::Gpu);
            assert!(!task.delta_capable());
            assert!(task.key().ends_with("+sketch"));
            let m = SimMeasurer::with_seed(sim_gpu(), 47);
            let mut o = small_options(32);
            o.repr = repr;
            let res = tune_gbt(task, &m, o);
            assert_eq!(res.curve.len(), 32);
            assert!(res.best_gflops() > 0.0, "no successful trial under {repr:?}");
        }
    }

    #[test]
    fn sketch_task_evo_search_works() {
        let task = Task::with_sketches(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let m = SimMeasurer::with_seed(sim_gpu(), 53);
        let mut o = small_options(32);
        o.repr = Representation::Config;
        o.search = crate::explore::SearchKind::Evo;
        o.evo = EvoParams { population: 32, generations: 6, ..Default::default() };
        let res = tune_gbt(task, &m, o);
        assert_eq!(res.curve.len(), 32);
        assert!(res.best_gflops() > 0.0);
    }
}
