//! The tuning loop — Algorithm 1 of the paper.
//!
//! Each round: run parallel simulated annealing with the cost model as
//! energy to collect the top `λ·b` candidates, pick a `(1−ε)b`-subset
//! by greedy submodular diversity-aware selection (Eq. 3), add `ε·b`
//! random candidates, measure the batch on the hardware back-end,
//! append to the database `D`, and refit `f̂` on all of `D`.
//!
//! Transfer learning (§4): pass a [`TransferModel`] built from a prior
//! database — the global model makes the very first SA round informed
//! instead of random.

pub mod db;

use crate::explore::{diverse_select, random_batch, ParallelSa, SaParams, Scorer};
use crate::features::Representation;
use crate::gbt::Matrix;
use crate::measure::Measurer;
use crate::model::{Acquisition, CostModel};
use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use crate::util::{parallel_map, Rng};
use std::cell::RefCell;
use std::collections::{HashMap, HashSet};

/// Tuning options (defaults follow the paper's experiment configuration:
/// b = 64, ε = 0.05, 128 SA chains × 500 steps).
#[derive(Clone, Debug)]
pub struct TuneOptions {
    pub n_trials: usize,
    pub batch: usize,
    pub eps: f64,
    /// SA candidate pool multiplier: diversity selection picks from the
    /// top `λ·b`.
    pub lambda: usize,
    /// Diversity weight α of Eq. 3; `diversity = false` ⇒ plain top-b.
    pub alpha: f64,
    pub diversity: bool,
    pub acquisition: Acquisition,
    pub repr: Representation,
    pub sa: SaParams,
    pub seed: u64,
    /// Print per-round progress.
    pub verbose: bool,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions {
            n_trials: 512,
            batch: 64,
            eps: 0.05,
            lambda: 2,
            alpha: 1.0,
            diversity: true,
            acquisition: Acquisition::Mean,
            repr: Representation::Full,
            sa: SaParams::default(),
            seed: 0,
            verbose: false,
        }
    }
}

/// One measured trial.
#[derive(Clone, Debug)]
pub struct TrialRecord {
    pub entity: ConfigEntity,
    pub gflops: f64,
    pub seconds: Option<f64>,
    pub error: Option<String>,
}

/// Outcome of a tuning run.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: Option<(ConfigEntity, f64)>,
    /// best-so-far GFLOPS after each trial (x = trial count, 1-based).
    pub curve: Vec<f64>,
    pub records: Vec<TrialRecord>,
}

impl TuneResult {
    pub fn best_gflops(&self) -> f64 {
        self.best.as_ref().map(|(_, g)| *g).unwrap_or(0.0)
    }

    /// Best-so-far at a trial count (for curve comparison plots).
    pub fn best_at(&self, trials: usize) -> f64 {
        if self.curve.is_empty() {
            return 0.0;
        }
        self.curve[trials.min(self.curve.len()).saturating_sub(1)]
    }

    /// First trial count reaching `target` GFLOPS (speedup metric of
    /// Fig. 8), if ever.
    pub fn trials_to_reach(&self, target: f64) -> Option<usize> {
        self.curve.iter().position(|&g| g >= target).map(|i| i + 1)
    }
}

/// Shared feature cache: entity → feature row.
type FeatureCache = RefCell<HashMap<ConfigEntity, Vec<f64>>>;

fn featurize_batch(
    task: &Task,
    repr: Representation,
    cache: &FeatureCache,
    entities: &[ConfigEntity],
) -> Matrix {
    // compute missing rows in parallel
    let missing: Vec<ConfigEntity> = {
        let c = cache.borrow();
        entities.iter().filter(|e| !c.contains_key(*e)).cloned().collect()
    };
    if !missing.is_empty() {
        let rows = parallel_map(&missing, crate::util::default_threads(), |e| {
            let analysis = task
                .lower(e)
                .map(|p| crate::ast::analysis::analyze(&p))
                .expect("template configs must lower");
            crate::features::extract(repr, task, e, &analysis)
        });
        let mut c = cache.borrow_mut();
        for (e, r) in missing.into_iter().zip(rows) {
            c.insert(e, r);
        }
    }
    let c = cache.borrow();
    let rows: Vec<Vec<f64>> = entities.iter().map(|e| c[e].clone()).collect();
    Matrix::from_rows(&rows)
}

struct TunerScorer<'a> {
    task: &'a Task,
    repr: Representation,
    model: &'a dyn CostModel,
    cache: &'a FeatureCache,
    acquisition: Acquisition,
    best: f64,
}

impl Scorer for TunerScorer<'_> {
    fn score(&self, entities: &[ConfigEntity]) -> Vec<f64> {
        let x = featurize_batch(self.task, self.repr, self.cache, entities);
        match self.acquisition {
            Acquisition::Mean => self.model.predict(&x),
            acq => self
                .model
                .predict_stats(&x)
                .into_iter()
                .map(|(m, s)| acq.score(m, s, self.best))
                .collect(),
        }
    }
}

/// The Algorithm-1 driver.
pub struct Tuner {
    pub task: Task,
    pub options: TuneOptions,
    model: Box<dyn CostModel>,
    sa: ParallelSa,
    cache: FeatureCache,
    rng: Rng,
}

impl Tuner {
    pub fn new(task: Task, model: Box<dyn CostModel>, options: TuneOptions) -> Self {
        let sa = ParallelSa::new(options.sa.clone());
        let rng = Rng::seed_from_u64(options.seed ^ 0x7u64.wrapping_mul(0x9E3779B97F4A7C15));
        Tuner { task, options, model, sa, cache: RefCell::new(HashMap::new()), rng }
    }

    /// Run the tuning loop against a measurement back-end.
    pub fn tune(&mut self, measurer: &dyn Measurer) -> TuneResult {
        let opts = self.options.clone();
        let mut seen: HashSet<ConfigEntity> = HashSet::new();
        let mut records: Vec<TrialRecord> = Vec::new();
        let mut curve: Vec<f64> = Vec::new();
        let mut best: Option<(ConfigEntity, f64)> = None;
        // training set (features of measured configs) + labels + groups
        let mut xs: Vec<ConfigEntity> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        let mut groups: Vec<usize> = Vec::new();

        let mut trials = 0usize;
        while trials < opts.n_trials {
            let b = opts.batch.min(opts.n_trials - trials);
            let batch = self.next_batch(b, &seen, best.as_ref().map(|(_, g)| *g).unwrap_or(0.0));
            if batch.is_empty() {
                break; // space exhausted
            }
            let results = measurer.measure(&self.task, &batch);
            for (e, r) in batch.iter().zip(&results) {
                seen.insert(e.clone());
                let gf = if r.is_ok() { r.gflops } else { 0.0 };
                if r.is_ok() && best.as_ref().map_or(true, |(_, bg)| gf > *bg) {
                    best = Some((e.clone(), gf));
                }
                curve.push(best.as_ref().map(|(_, g)| *g).unwrap_or(0.0));
                records.push(TrialRecord {
                    entity: e.clone(),
                    gflops: gf,
                    seconds: r.seconds,
                    error: r.error.clone(),
                });
                xs.push(e.clone());
                ys.push(gf);
            }
            groups.push(batch.len());
            trials += batch.len();

            // refit f̂ on all of D
            let x = featurize_batch(&self.task, opts.repr, &self.cache, &xs);
            self.model.fit(&x, &ys, &groups);
            if opts.verbose {
                println!(
                    "[{}] trials={trials:4} best={:.1} GFLOPS",
                    measurer.target(),
                    best.as_ref().map(|(_, g)| *g).unwrap_or(0.0)
                );
            }
        }
        TuneResult { best, curve, records }
    }

    /// Pick the next measurement batch per Algorithm 1.
    fn next_batch(
        &mut self,
        b: usize,
        seen: &HashSet<ConfigEntity>,
        best_y: f64,
    ) -> Vec<ConfigEntity> {
        let Tuner { task, options, model, sa, cache, rng } = self;
        let mut batch: Vec<ConfigEntity> = Vec::with_capacity(b);
        if model.ready() {
            let scorer = TunerScorer {
                task,
                repr: options.repr,
                model: model.as_ref(),
                cache,
                acquisition: options.acquisition,
                best: best_y,
            };
            let pool = sa.collect(&task.space, &scorer, options.lambda * b, rng);
            let fresh: Vec<(ConfigEntity, f64)> =
                pool.into_iter().filter(|(e, _)| !seen.contains(e)).collect();
            let n_rand = ((b as f64 * options.eps).round() as usize).min(b);
            let n_model = b - n_rand;
            let picked = if options.diversity {
                diverse_select(task.space.num_knobs(), &fresh, n_model, options.alpha)
            } else {
                crate::explore::top_select(&fresh, n_model)
            };
            batch.extend(picked);
            // ε-greedy random tail + top-up if SA pool was too small
            let mut avoid: HashSet<ConfigEntity> = seen.clone();
            avoid.extend(batch.iter().cloned());
            let tail = random_batch(&task.space, b - batch.len(), &avoid, rng);
            batch.extend(tail);
        } else {
            batch = random_batch(&task.space, b, seen, rng);
        }
        batch
    }
}

/// Convenience: tune with a fresh GBT(rank) model — the paper's default.
pub fn tune_gbt(
    task: Task,
    measurer: &dyn Measurer,
    options: TuneOptions,
) -> TuneResult {
    let params = crate::gbt::GbtParams { seed: options.seed, ..Default::default() };
    let model = Box::new(crate::model::GbtModel::new(params));
    Tuner::new(task, model, options).tune(measurer)
}

/// Baseline: pure random search (Fig. 4 "Random").
pub fn tune_random(task: Task, measurer: &dyn Measurer, options: TuneOptions) -> TuneResult {
    let mut rng = Rng::seed_from_u64(options.seed ^ 0xAA55);
    let mut seen = HashSet::new();
    let mut best: Option<(ConfigEntity, f64)> = None;
    let mut curve = Vec::new();
    let mut records = Vec::new();
    let mut trials = 0;
    while trials < options.n_trials {
        let b = options.batch.min(options.n_trials - trials);
        let batch = random_batch(&task.space, b, &seen, &mut rng);
        if batch.is_empty() {
            break;
        }
        let results = measurer.measure(&task, &batch);
        for (e, r) in batch.iter().zip(&results) {
            seen.insert(e.clone());
            let gf = if r.is_ok() { r.gflops } else { 0.0 };
            if r.is_ok() && best.as_ref().map_or(true, |(_, bg)| gf > *bg) {
                best = Some((e.clone(), gf));
            }
            curve.push(best.as_ref().map(|(_, g)| *g).unwrap_or(0.0));
            records.push(TrialRecord {
                entity: e.clone(),
                gflops: gf,
                seconds: r.seconds,
                error: r.error.clone(),
            });
        }
        trials += batch.len();
    }
    TuneResult { best, curve, records }
}

/// Baseline: genetic algorithm (Fig. 4 "GA").
pub fn tune_ga(task: Task, measurer: &dyn Measurer, options: TuneOptions) -> TuneResult {
    let mut rng = Rng::seed_from_u64(options.seed ^ 0x6A6A);
    let mut ga = crate::explore::Genetic::new(options.batch);
    let mut best: Option<(ConfigEntity, f64)> = None;
    let mut curve = Vec::new();
    let mut records = Vec::new();
    let mut trials = 0;
    while trials < options.n_trials {
        let batch = ga.propose(&task.space, &mut rng);
        let batch: Vec<ConfigEntity> =
            batch.into_iter().take(options.n_trials - trials).collect();
        let results = measurer.measure(&task, &batch);
        let fitness: Vec<f64> =
            results.iter().map(|r| if r.is_ok() { r.gflops } else { 0.0 }).collect();
        for (e, r) in batch.iter().zip(&results) {
            let gf = if r.is_ok() { r.gflops } else { 0.0 };
            if r.is_ok() && best.as_ref().map_or(true, |(_, bg)| gf > *bg) {
                best = Some((e.clone(), gf));
            }
            curve.push(best.as_ref().map(|(_, g)| *g).unwrap_or(0.0));
            records.push(TrialRecord {
                entity: e.clone(),
                gflops: gf,
                seconds: r.seconds,
                error: r.error.clone(),
            });
        }
        ga.update(&batch, &fitness);
        trials += batch.len();
    }
    TuneResult { best, curve, records }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::measure::SimMeasurer;
    use crate::schedule::template::TemplateKind;
    use crate::sim::devices::sim_gpu;

    fn small_options(n: usize) -> TuneOptions {
        TuneOptions {
            n_trials: n,
            batch: 16,
            sa: SaParams { n_chains: 16, n_steps: 40, ..Default::default() },
            seed: 3,
            ..Default::default()
        }
    }

    #[test]
    fn gbt_tuner_improves_and_tracks_curve() {
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let m = SimMeasurer::with_seed(sim_gpu(), 1);
        let res = tune_gbt(task, &m, small_options(96));
        assert_eq!(res.curve.len(), 96);
        assert!(res.best.is_some());
        // curve is monotone nondecreasing
        for w in res.curve.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // later best must be >= first-batch best
        assert!(res.best_at(96) >= res.best_at(16));
    }

    #[test]
    fn model_beats_random_on_average() {
        // the core §6.1 claim, in miniature
        let mk_task = || Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu);
        let mut wins = 0;
        for seed in 0..3u64 {
            let m = SimMeasurer::with_seed(sim_gpu(), 100 + seed);
            let mut o = small_options(96);
            o.seed = seed;
            let gbt = tune_gbt(mk_task(), &m, o.clone());
            let m2 = SimMeasurer::with_seed(sim_gpu(), 100 + seed);
            let rnd = tune_random(mk_task(), &m2, o);
            if gbt.best_gflops() >= rnd.best_gflops() {
                wins += 1;
            }
        }
        assert!(wins >= 2, "GBT won only {wins}/3 against random");
    }

    #[test]
    fn random_and_ga_produce_full_curves() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let m = SimMeasurer::with_seed(crate::sim::devices::sim_cpu(), 5);
        let r = tune_random(task.clone(), &m, small_options(48));
        assert_eq!(r.curve.len(), 48);
        let g = tune_ga(task, &m, small_options(48));
        assert_eq!(g.curve.len(), 48);
        assert!(g.best_gflops() > 0.0);
    }

    #[test]
    fn trials_to_reach_semantics() {
        let res = TuneResult {
            best: None,
            curve: vec![1.0, 1.0, 5.0, 5.0],
            records: vec![],
        };
        assert_eq!(res.trials_to_reach(1.0), Some(1));
        assert_eq!(res.trials_to_reach(5.0), Some(3));
        assert_eq!(res.trials_to_reach(9.0), None);
    }

    #[test]
    fn batches_never_remeasure_configs() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let m = SimMeasurer::with_seed(crate::sim::devices::sim_cpu(), 6);
        let res = tune_gbt(task, &m, small_options(64));
        let mut uniq = HashSet::new();
        for r in &res.records {
            assert!(uniq.insert(r.entity.clone()), "config measured twice");
        }
    }
}
