//! ServeConfig — the long-lived config-serving front-end.
//!
//! Once tuned, "best config for (task, target)" is the hot path a
//! compiler stack hits on every build (the role the config log plays
//! in TVM): many concurrent readers, occasional tuning loops streaming
//! writes through [`crate::tuner::DbSink`]. This module wraps the
//! [`TuningDb`] index in a service handle that:
//!
//! * answers [`ServeConfig::best_config`] / [`ServeConfig::top_k`]
//!   straight from the O(1) incremental index, recording each lookup's
//!   latency into a lock-free log-linear histogram ([`ServeStats`],
//!   ~12.5% bucket granularity) so p50/p99 under load are observable
//!   without perturbing the serve path;
//! * drives reproducible load tests: [`query_storm`] hammers the DB
//!   from N reader threads (with optional live writer threads) and
//!   reports QPS + latency percentiles as a [`StormReport`] — the
//!   `coordinator serve` subcommand and `bench_serve` are thin shells
//!   around it.
//!
//! Serving and tuning stay split: tuning owns the write path (sinks,
//! WAL, compaction), serving owns the read path; both share one
//! `TuningDb` handle and contend only on the touched shard bucket.

use crate::schedule::space::ConfigEntity;
use crate::tuner::db::{Record, TuningDb};
use crate::util::json::Json;
use crate::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Exact buckets for latencies below 8 ns.
const HIST_EXACT: usize = 8;
/// Octaves 2^3 .. 2^39 ns (~9 minutes), 8 sub-buckets each.
const HIST_OCTAVES: usize = 37;
/// Total histogram buckets.
const HIST_BUCKETS: usize = HIST_EXACT + HIST_OCTAVES * 8;

/// Lock-free lookup statistics: counters plus a log-linear latency
/// histogram (8 sub-buckets per power of two, ~12.5% resolution) —
/// precise enough to compare p99s at a 2× threshold without a lock or
/// an allocation on the serve path.
pub struct ServeStats {
    lookups: AtomicU64,
    hits: AtomicU64,
    hist: [AtomicU64; HIST_BUCKETS],
}

impl Default for ServeStats {
    fn default() -> Self {
        ServeStats {
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            hist: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Histogram bucket for a latency of `ns` nanoseconds.
fn bucket_of(ns: u64) -> usize {
    if ns < HIST_EXACT as u64 {
        return ns as usize;
    }
    let o = (63 - ns.leading_zeros() as usize).min(HIST_OCTAVES + 2);
    let sub = ((ns >> (o - 3)) & 7) as usize;
    HIST_EXACT + (o - 3) * 8 + sub
}

/// Inclusive upper bound (in ns) of histogram bucket `idx`.
fn upper_ns(idx: usize) -> u64 {
    if idx < HIST_EXACT {
        return idx as u64;
    }
    let o = 3 + (idx - HIST_EXACT) / 8;
    let sub = ((idx - HIST_EXACT) % 8) as u64;
    (1u64 << o) + (sub + 1) * (1u64 << (o - 3)) - 1
}

impl ServeStats {
    /// Record one lookup: its latency and whether it found a config.
    fn record(&self, elapsed: Duration, hit: bool) {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        let ns = elapsed.as_nanos().min(u64::MAX as u128) as u64;
        self.hist[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Total lookups recorded.
    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    /// Lookups that found at least one config.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Latency percentile (`p` in 0..=1) as the upper bound of the
    /// histogram bucket containing it, in nanoseconds. 0 when no
    /// lookups have been recorded.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let counts: Vec<u64> =
            self.hist.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((p.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return upper_ns(i);
            }
        }
        upper_ns(HIST_BUCKETS - 1)
    }
}

/// The config-serving service handle: a cheap clone wrapping a shared
/// [`TuningDb`] plus shared lookup stats. Many threads hold clones and
/// query concurrently while tuning loops stream writes into the same
/// DB.
#[derive(Clone)]
pub struct ServeConfig {
    db: TuningDb,
    stats: Arc<ServeStats>,
}

impl ServeConfig {
    /// Serve lookups from `db` (shared, not copied).
    pub fn new(db: TuningDb) -> Self {
        ServeConfig { db, stats: Arc::new(ServeStats::default()) }
    }

    /// The underlying DB handle.
    pub fn db(&self) -> &TuningDb {
        &self.db
    }

    /// The shared lookup statistics.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Same DB, fresh zeroed stats — so separate measurement phases
    /// (idle vs. storm) don't pollute each other.
    pub fn fresh_stats(&self) -> ServeConfig {
        ServeConfig { db: self.db.clone(), stats: Arc::new(ServeStats::default()) }
    }

    /// Timed [`TuningDb::best_config`]: the serve hot path.
    pub fn best_config(&self, task_key: &str, target: &str) -> Option<(ConfigEntity, f64)> {
        let t0 = Instant::now();
        let res = self.db.best_config(task_key, target);
        self.stats.record(t0.elapsed(), res.is_some());
        res
    }

    /// Timed [`TuningDb::top_k`].
    pub fn top_k(&self, task_key: &str, target: &str, k: usize) -> Vec<(ConfigEntity, f64)> {
        let t0 = Instant::now();
        let res = self.db.top_k(task_key, target, k);
        self.stats.record(t0.elapsed(), !res.is_empty());
        res
    }
}

/// Parameters for one [`query_storm`] run.
#[derive(Clone, Copy, Debug)]
pub struct StormOptions {
    /// Concurrent reader threads.
    pub threads: usize,
    /// Concurrent writer threads streaming appends during the storm.
    pub writers: usize,
    /// How long the storm runs.
    pub duration: Duration,
    /// Seed for the per-thread query key sequences.
    pub seed: u64,
}

impl Default for StormOptions {
    fn default() -> Self {
        StormOptions { threads: 64, writers: 0, duration: Duration::from_secs(2), seed: 0 }
    }
}

/// Outcome of one [`query_storm`] run.
#[derive(Clone, Debug)]
pub struct StormReport {
    /// Total lookups completed.
    pub lookups: u64,
    /// Lookups that found a config.
    pub hits: u64,
    /// Records appended by the writer threads during the storm.
    pub writes: u64,
    /// Lookups per second across all reader threads.
    pub qps: f64,
    /// Median lookup latency (histogram bucket upper bound).
    pub p50_ns: u64,
    /// 99th-percentile lookup latency (histogram bucket upper bound).
    pub p99_ns: u64,
    /// Actual wall-clock duration of the storm.
    pub duration_secs: f64,
    /// Reader threads used.
    pub threads: usize,
    /// Writer threads used.
    pub writers: usize,
}

impl StormReport {
    /// JSON form for `BENCH_serve.json` / `--bench-json` dumps.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lookups", Json::from(self.lookups)),
            ("hits", Json::from(self.hits)),
            ("writes", Json::from(self.writes)),
            ("qps", Json::from(self.qps)),
            ("p50_ns", Json::from(self.p50_ns)),
            ("p99_ns", Json::from(self.p99_ns)),
            ("duration_secs", Json::from(self.duration_secs)),
            ("threads", Json::from(self.threads)),
            ("writers", Json::from(self.writers)),
        ])
    }
}

impl std::fmt::Display for StormReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "storm: {} lookups ({:.0}/s) p50 {} ns p99 {} ns, {} hits, {} live writes, \
             {} readers + {} writers over {:.2}s",
            self.lookups,
            self.qps,
            self.p50_ns,
            self.p99_ns,
            self.hits,
            self.writes,
            self.threads,
            self.writers,
            self.duration_secs
        )
    }
}

/// Hammer the serve path: `opts.threads` reader threads issue
/// `best_config` (and occasional `top_k`) lookups against random shard
/// keys for `opts.duration`, while `opts.writers` threads stream
/// appends into the same shards. Returns the aggregate QPS/latency
/// report (measured on fresh stats, so prior lookups don't pollute it).
pub fn query_storm(serve: &ServeConfig, opts: &StormOptions) -> StormReport {
    let serve = serve.fresh_stats();
    let mut keys = serve.db().shard_keys();
    if keys.is_empty() {
        // Nothing tuned yet: storm a single (missing) key — lookups
        // still exercise the full path and report misses.
        keys.push(("storm@Serve".to_string(), "storm".to_string()));
    }
    let keys = Arc::new(keys);
    let writes = AtomicU64::new(0);
    let deadline = Instant::now() + opts.duration;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..opts.threads.max(1) {
            let serve = serve.clone();
            let keys = Arc::clone(&keys);
            let mut rng = Rng::seed_from_u64(opts.seed ^ (t as u64).wrapping_mul(0x9e37));
            s.spawn(move || {
                let mut n = 0u64;
                while Instant::now() < deadline {
                    let (task, target) = &keys[rng.gen_range(0..keys.len())];
                    if n % 8 == 7 {
                        serve.top_k(task, target, 8);
                    } else {
                        serve.best_config(task, target);
                    }
                    n += 1;
                }
            });
        }
        for wtr in 0..opts.writers {
            let db = serve.db().clone();
            let keys = Arc::clone(&keys);
            let writes = &writes;
            let mut rng =
                Rng::seed_from_u64(opts.seed ^ 0xA11CE ^ (wtr as u64).wrapping_mul(0x9e37));
            s.spawn(move || {
                let mut i = wtr;
                while Instant::now() < deadline {
                    let (task, target) = &keys[i % keys.len()];
                    let rec = Record {
                        task_key: task.clone(),
                        target: target.clone(),
                        choices: vec![
                            rng.next_u64() as u32,
                            rng.next_u64() as u32,
                            rng.next_u64() as u32,
                            rng.next_u64() as u32,
                        ],
                        gflops: rng.gen_f64() * 100.0,
                        seconds: 1e-4,
                        error: None,
                    };
                    if db.append(rec).is_ok() {
                        writes.fetch_add(1, Ordering::Relaxed);
                    }
                    i += 1;
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let stats = serve.stats();
    StormReport {
        lookups: stats.lookups(),
        hits: stats.hits(),
        writes: writes.load(Ordering::Relaxed),
        qps: stats.lookups() as f64 / elapsed,
        p50_ns: stats.percentile_ns(0.50),
        p99_ns: stats.percentile_ns(0.99),
        duration_secs: elapsed,
        threads: opts.threads.max(1),
        writers: opts.writers,
    }
}

/// Fill `db` with `n` synthetic records spread over `tasks` task keys ×
/// `targets` targets — the record population for serve benchmarks
/// (serving never lowers a config, so opaque choices are fine).
pub fn fill_synthetic(db: &TuningDb, n: usize, tasks: usize, targets: usize, seed: u64) {
    let mut rng = Rng::seed_from_u64(seed);
    let tasks = tasks.max(1);
    let targets = targets.max(1);
    for i in 0..n {
        let rec = Record {
            task_key: format!("task{}@Serve", i % tasks),
            target: format!("dev{}", (i / tasks) % targets),
            choices: vec![
                rng.next_u64() as u32,
                rng.next_u64() as u32,
                rng.next_u64() as u32,
                rng.next_u64() as u32,
            ],
            gflops: rng.gen_f64() * 100.0,
            seconds: 1e-4,
            error: None,
        };
        // In-memory fills never fail; WAL-backed fills surface errors
        // via the caller checking `db.len()`.
        let _ = db.append(rec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_monotone_and_consistent() {
        // every ns value lands in a bucket whose bounds contain it
        for ns in [0u64, 1, 7, 8, 9, 100, 1000, 12345, 1 << 20, u64::MAX >> 1] {
            let b = bucket_of(ns);
            assert!(b < HIST_BUCKETS, "bucket out of range for {ns}");
            assert!(upper_ns(b) >= ns.min(upper_ns(HIST_BUCKETS - 1)), "upper bound below {ns}");
            if b > 0 {
                assert!(upper_ns(b - 1) < upper_ns(b), "bounds not monotone at {b}");
            }
        }
        // monotone: larger latency never maps to a smaller bucket
        let mut prev = 0usize;
        for shift in 0..40 {
            let b = bucket_of(1u64 << shift);
            assert!(b >= prev);
            prev = b;
        }
    }

    #[test]
    fn percentiles_track_recorded_latencies() {
        let stats = ServeStats::default();
        for ns in 1..=1000u64 {
            stats.record(Duration::from_nanos(ns), true);
        }
        assert_eq!(stats.lookups(), 1000);
        assert_eq!(stats.hits(), 1000);
        let p50 = stats.percentile_ns(0.50);
        let p99 = stats.percentile_ns(0.99);
        // log-linear buckets: within one 12.5% bucket of the true value
        assert!((440..=580).contains(&p50), "p50 {p50} far from 500");
        assert!((900..=1200).contains(&p99), "p99 {p99} far from 990");
        assert!(p50 <= p99);
    }

    #[test]
    fn storm_on_empty_db_reports_misses() {
        let serve = ServeConfig::new(TuningDb::new());
        let report = query_storm(
            &serve,
            &StormOptions {
                threads: 2,
                writers: 0,
                duration: Duration::from_millis(30),
                seed: 1,
            },
        );
        assert!(report.lookups > 0);
        assert_eq!(report.hits, 0, "empty DB cannot hit");
        assert_eq!(report.writes, 0);
    }

    #[test]
    fn fill_synthetic_populates_expected_shards() {
        let db = TuningDb::new();
        fill_synthetic(&db, 1000, 10, 2, 7);
        assert_eq!(db.len(), 1000);
        let keys = db.shard_keys();
        assert!(keys.len() <= 20);
        assert!(keys.iter().all(|(t, _)| t.ends_with("@Serve")));
        let serve = ServeConfig::new(db);
        let (task, target) = &keys[0];
        assert!(serve.best_config(task, target).is_some());
        assert_eq!(serve.stats().hits(), 1);
    }
}
