//! Graph-level task scheduler: allocate one global trial budget across
//! the tasks of a network by expected marginal reduction in end-to-end
//! latency.
//!
//! The paper's headline numbers are end-to-end (§6.3: ResNet-18,
//! MobileNet, LSTM-LM, DQN, DCGAN), but Algorithm 1 tunes a *single*
//! operator. Chaining per-task runs with a uniform budget wastes trials:
//! a network's latency is dominated by a few hot tasks (node
//! multiplicity × per-invocation cost), and tuning curves flatten at
//! different rates. The scheduler closes that loop — graph → tasks →
//! tuner → db → graph latency:
//!
//! 1. Derive the task set and static weights from the graph
//!    ([`Graph::weighted_tasks`]: deduplicated tasks with node
//!    multiplicity; [`Graph::latency_by_task`] attributes the current
//!    latency to tasks plus an untunable fixed floor).
//! 2. Spend the budget in **rounds**: each round runs one `slice` of
//!    trials on one task through the persistent incremental loops
//!    ([`Tuner::tune_more`] / [`PipelinedTuner::tune_more`]), streaming
//!    every trial into the shared [`TuningDb`] so later rounds of
//!    *other* tasks warm-start from the records
//!    ([`TransferModel::from_db`]).
//! 3. Pick the next task **greedily** by predicted marginal gain
//!    ([`AllocPolicy::Gradient`]): the observed weighted
//!    latency-reduction-per-trial of a task's last slice, decayed by the
//!    task's own measured curvature (the ratio of its last two slice
//!    gains) — a discrete gradient of end-to-end latency with respect to
//!    trial budget, in the spirit of Ansor's task scheduler (Zheng et
//!    al., OSDI 2020).
//!
//! Two guardrails keep the greedy loop honest:
//!
//! * **Bootstrap** — every task gets two slices before any gradient is
//!   trusted (a single slice has no curvature estimate), round-robin:
//!   everyone receives a first slice before anyone gets a second, so
//!   even a budget below `2·k·slice` covers every task.
//! * **ε floor** — a task whose share of spent trials falls below
//!   `ε × (uniform share)` is topped up next, so a task written off by
//!   a noisy early estimate is never starved forever — and no task ever
//!   receives zero trials.
//!
//! Execution is abstracted behind [`SliceExecutor`], with two
//! implementations: [`LoopExecutor`] drives the real tuning loops, and
//! [`CurveExecutor`] replays deterministic per-task latency curves
//! ([`TaskCurve`]) so allocation decisions are testable exactly — at
//! equal budget, gradient allocation must beat uniform on the simulated
//! farm deterministically, not on a lucky seed.
//!
//! ## Cross-task overlap and the gain ledger
//!
//! With [`SchedulerOptions::overlap`]` = N > 1` the scheduler keeps up
//! to `N` task-slices in flight at once: while task A's measurement
//! batches drain on the shared asynchronous farm
//! ([`MeasureService`](crate::measure::service::MeasureService)), task
//! B's proposal and refit stages run on the caller thread — the farm
//! never idles behind one task's slice barrier. Determinism survives
//! through the [`GainLedger`]: every allocation decision records the
//! ledger *version* (number of committed slices) it read, slices
//! **retire in issue order** no matter which one's measurements
//! physically return first, and in-flight slices are stepped in a fixed
//! rotation rather than by wall-clock readiness — so a fixed-seed run
//! produces bit-for-bit identical allocation decisions at any replica
//! count or farm timing, and `overlap = 1` reproduces the barrier
//! scheduler exactly (asserted by `tests/scheduler_overlap.rs`).
//!
//! Because overlapped decisions read gains up to `N − 1` slices stale,
//! raw last-slice gain differences get noisier;
//! [`SchedulerOptions::gain_ema`] smooths gain-per-trial with an
//! exponential moving average and adds *restart detection* — a task
//! whose fresh slice beats its
//! decayed estimate by [`SchedulerOptions::restart_margin`]× resets the
//! estimator (and its curvature decay), so a genuine regime change
//! ([`StagedCurve`](crate::sim::devices::StagedCurve)) is chased
//! immediately instead of being averaged away.
//!
//! ```
//! use autotvm::expr::ops;
//! use autotvm::schedule::template::{Task, TemplateKind};
//! use autotvm::sim::devices::{sim_gpu, TaskCurve};
//! use autotvm::tuner::scheduler::{
//!     AllocPolicy, CurveExecutor, SchedulerOptions, TaskScheduler,
//! };
//!
//! let tasks = vec![
//!     Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu),
//!     Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu),
//! ];
//! let dev = sim_gpu();
//! let mut farm = CurveExecutor::new(
//!     tasks.iter().map(|t| TaskCurve::for_task(t, &dev)).collect(),
//! );
//! let sched = TaskScheduler::for_tasks(
//!     tasks,
//!     SchedulerOptions {
//!         budget: 64,
//!         slice: 8,
//!         policy: AllocPolicy::Gradient,
//!         ..Default::default()
//!     },
//! );
//! let alloc = sched.run(&mut farm);
//! assert_eq!(alloc.trials.iter().sum::<usize>(), 64);
//! assert!(alloc.trials.iter().all(|&n| n > 0)); // ε floor
//! ```
//!
//! [`Graph::weighted_tasks`]: crate::graph::Graph::weighted_tasks
//! [`Graph::latency_by_task`]: crate::graph::Graph::latency_by_task
//! [`Tuner::tune_more`]: super::Tuner::tune_more
//! [`PipelinedTuner::tune_more`]: super::pipeline::PipelinedTuner::tune_more
//! [`TransferModel::from_db`]: crate::model::TransferModel::from_db
//! [`TaskCurve`]: crate::sim::devices::TaskCurve
//! [`TuningDb`]: super::db::TuningDb

use super::db::TuningDb;
use super::pipeline::PipelinedTuner;
use super::{DbSink, SliceRun, SliceStep, TuneOptions, Tuner};
use crate::features::Representation;
use crate::gbt::{GbtParams, Objective};
use crate::graph::{task_salt, Graph};
use crate::measure::Measurer;
use crate::model::{CostModel, GbtModel, TransferModel, WarmStartStats};
use crate::schedule::template::{Task, TemplateKind};
use crate::sim::devices::{LatencyCurve, TaskCurve};
use crate::sim::DeviceModel;
use std::collections::{HashMap, VecDeque};

/// How the global trial budget is spread across tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Round-robin equal shares — the pre-scheduler `tune-all` behavior,
    /// kept as the comparison baseline.
    Uniform,
    /// Greedy on the predicted marginal reduction in end-to-end latency
    /// per trial (with bootstrap and ε floor; see the module docs).
    Gradient,
}

impl AllocPolicy {
    /// CLI name of the policy.
    pub fn name(self) -> &'static str {
        match self {
            AllocPolicy::Uniform => "uniform",
            AllocPolicy::Gradient => "gradient",
        }
    }

    /// Parse a CLI name (`uniform` / `gradient`).
    pub fn parse(s: &str) -> Option<AllocPolicy> {
        match s {
            "uniform" => Some(AllocPolicy::Uniform),
            "gradient" => Some(AllocPolicy::Gradient),
            _ => None,
        }
    }
}

/// Budget-allocation options of one scheduler run.
#[derive(Clone, Debug)]
pub struct SchedulerOptions {
    /// Total measurement trials across all tasks.
    pub budget: usize,
    /// Trials per round-slice. Normalized down to `budget / (2·tasks)`
    /// when the budget is too small for two bootstrap slices per task,
    /// so the floor guarantee survives small budgets.
    pub slice: usize,
    /// Allocation policy (gradient by default).
    pub policy: AllocPolicy,
    /// Starvation floor: a task whose trial share drops below
    /// `eps × (spent / tasks)` is topped up next round.
    pub eps: f64,
    /// How many task-slices may be in flight at once. `1` (the
    /// default) is the barrier scheduler: each slice fully drains
    /// before the next allocation decision. `N > 1` overlaps slices
    /// across tasks through the [`GainLedger`] — task B proposes and
    /// refits while task A's batches drain on the farm — with
    /// allocation decisions still bit-for-bit reproducible (see the
    /// module docs).
    pub overlap: usize,
    /// EMA smoothing factor `α ∈ (0, 1]` for the gain-per-trial
    /// estimate, with restart detection. `None` (the default) keeps
    /// the raw last-slice gain — the historical estimator, and the one
    /// the `overlap = 1` bit-for-bit equivalence is stated against.
    pub gain_ema: Option<f64>,
    /// Restart-detection margin (only read when `gain_ema` is set): a
    /// task whose fresh slice gain exceeds `margin ×` its decayed
    /// estimate resets the estimator and its curvature decay — a
    /// genuine regime change is chased, not averaged away.
    pub restart_margin: f64,
    /// Print one line per round (task picked, gain estimate, latency).
    pub verbose: bool,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions {
            budget: 512,
            slice: 64,
            policy: AllocPolicy::Gradient,
            eps: 0.05,
            overlap: 1,
            gain_ema: None,
            restart_margin: 3.0,
            verbose: false,
        }
    }
}

/// One task of the schedule with its static end-to-end weight.
#[derive(Clone, Debug)]
pub struct TaskPlan {
    /// The tunable task.
    pub task: Task,
    /// End-to-end weight: how many times the task's latency counts
    /// toward the graph latency (node multiplicity; 1.0 for plain task
    /// lists).
    pub weight: f64,
    /// Device target the plan's trials must run on (`None` means "the
    /// executor's only target" — the single-device shape every
    /// pre-multi-target caller builds). A heterogeneous plan
    /// ([`TaskScheduler::from_graph_multi`]) carries one plan per
    /// `(task, target)` pair, all drawing from the same global budget.
    pub target: Option<String>,
}

/// Outcome of a scheduler run: where the budget went and where latency
/// ended up. Vectors are indexed like [`TaskScheduler::plans`].
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Trials spent per task (sums to the budget when the executor
    /// never exhausts a space).
    pub trials: Vec<usize>,
    /// Best per-invocation latency per task after tuning (seconds;
    /// `INFINITY` when a task never measured a valid config).
    pub secs: Vec<f64>,
    /// Rounds executed.
    pub rounds: usize,
    /// Estimated end-to-end latency: fixed glue cost plus the
    /// weighted sum of `secs`.
    pub est_latency: f64,
    /// The allocation decision log: one [`LedgerEntry`] per issued
    /// slice, in issue order, each tagged with the ledger version it
    /// read. Two runs that made the same decisions have equal logs —
    /// the replay-equivalence artifact of the [`GainLedger`].
    pub log: Vec<LedgerEntry>,
    /// EMA restart-detection events per task (all zero unless
    /// [`SchedulerOptions::gain_ema`] is set).
    pub restarts: Vec<usize>,
}

/// Executes trial slices for the scheduler — the boundary between the
/// allocation *decision* (pure, deterministic, testable) and tuning
/// *execution* (real loops or replayed curves).
pub trait SliceExecutor {
    /// Current best per-invocation latency of task `idx` in seconds
    /// (`INFINITY` before any valid measurement).
    fn best_secs(&mut self, idx: usize) -> f64;

    /// Cheap pre-tuning baseline latency of task `idx` in seconds —
    /// what the task costs *before* any trial is spent (a default /
    /// vendor schedule). A finite baseline gives the scheduler a real
    /// slice-1 gain, so the curvature decay activates from a task's
    /// second slice instead of its third. The default delegates to
    /// [`best_secs`](Self::best_secs) (replayed curves are already
    /// finite at zero trials); executors without a cheap baseline may
    /// return `INFINITY`, which degrades gracefully to the old
    /// zero-gain bootstrap.
    fn baseline_secs(&mut self, idx: usize) -> f64 {
        self.best_secs(idx)
    }

    /// Spend up to `trials` more measurements on task `idx`. Returns
    /// the number actually measured — less than `trials` when the
    /// task's config space is exhausted (the scheduler then stops
    /// allocating to that task).
    fn run_slice(&mut self, idx: usize, trials: usize) -> usize;

    /// Begin slice `no` of `trials` on task `idx` without waiting for
    /// it — the overlapped scheduler's entry point. The default defers
    /// everything to the first [`step_slice`](Self::step_slice) call,
    /// which executes the whole slice synchronously, so plain barrier
    /// executors participate in overlapped runs unchanged (each slice
    /// simply completes at its first step).
    fn begin_slice(&mut self, no: u64, idx: usize, trials: usize) {
        let _ = (no, idx, trials);
    }

    /// Advance slice `no` (of `trials` on task `idx`) by one unit of
    /// work, returning its [`SliceOutcome`] once **everything** of the
    /// slice — including streamed DB-sink records — has landed; `None`
    /// while work remains. The scheduler steps in-flight slices in a
    /// fixed rotation and never steps a slice while an earlier
    /// incomplete slice of the *same* task exists (per-task execution
    /// is strictly sequential).
    fn step_slice(&mut self, no: u64, idx: usize, trials: usize) -> Option<SliceOutcome> {
        let _ = no;
        let spent = self.run_slice(idx, trials);
        Some(SliceOutcome { spent, secs_after: self.best_secs(idx) })
    }
}

/// What one completed slice reported back to the allocator.
#[derive(Clone, Copy, Debug)]
pub struct SliceOutcome {
    /// Trials actually measured (less than planned ⇒ the task's space
    /// is exhausted).
    pub spent: usize,
    /// The task's best per-invocation latency at the moment the slice
    /// completed — captured *at completion*, not at commit, so a later
    /// slice of the same task can never pollute this slice's gain.
    pub secs_after: f64,
}

/// Replays deterministic latency curves ([`TaskCurve`] /
/// [`StagedCurve`](crate::sim::devices::StagedCurve)) instead of
/// running tuning loops — the simulated farm the allocator is tested
/// against.
pub struct CurveExecutor {
    curves: Vec<Box<dyn LatencyCurve>>,
    spent: Vec<usize>,
}

impl CurveExecutor {
    /// Executor over one curve per task (same order as the plans).
    pub fn new(curves: Vec<TaskCurve>) -> Self {
        CurveExecutor::from_curves(
            curves.into_iter().map(|c| Box::new(c) as Box<dyn LatencyCurve>).collect(),
        )
    }

    /// Executor over arbitrary curve models — staged curves with
    /// regime changes, hand-built shapes — one per task.
    pub fn from_curves(curves: Vec<Box<dyn LatencyCurve>>) -> Self {
        let spent = vec![0; curves.len()];
        CurveExecutor { curves, spent }
    }

    /// Trials spent per task so far.
    pub fn spent(&self) -> &[usize] {
        &self.spent
    }
}

impl SliceExecutor for CurveExecutor {
    fn best_secs(&mut self, idx: usize) -> f64 {
        self.curves[idx].secs_after(self.spent[idx])
    }

    fn run_slice(&mut self, idx: usize, trials: usize) -> usize {
        self.spent[idx] += trials;
        trials // curves never exhaust
    }
}

/// Per-task incremental tuning driver of the [`LoopExecutor`].
enum Driver {
    Serial(Tuner),
    Pipelined(PipelinedTuner),
}

impl Driver {
    fn trials(&self) -> usize {
        match self {
            Driver::Serial(t) => t.trials(),
            Driver::Pipelined(t) => t.trials(),
        }
    }
}

/// One pollable slice in flight on a [`LoopExecutor`].
struct ActiveLoopSlice {
    idx: usize,
    /// Trials planned for the slice.
    planned: usize,
    /// Armed at the slice's first step: the driver trial count when it
    /// actually began (spent = now − start), and its slice session.
    /// Deferred because an earlier slice of the same task may still be
    /// in flight at issue time — the driver's incremental state only
    /// becomes this slice's starting point once the scheduler's
    /// per-task FIFO lets it step.
    session: Option<(usize, SliceRun)>,
}

/// Stable per-target hash used to decorrelate seeds across *targets*
/// of a heterogeneous plan, exactly as [`task_salt`] decorrelates
/// across tasks. Single-target executors use salt `0` everywhere, so
/// pre-multi-target runs stay bit-for-bit unchanged.
fn target_salt(target: &str) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    target.hash(&mut h);
    h.finish()
}

/// Drives the real incremental tuning loops: one persistent driver per
/// plan (created lazily at its first slice), every measured trial
/// streamed into the shared [`TuningDb`], and — when the DB already
/// holds usable sibling records — a transfer warm start under
/// [`Representation::ContextRelation`], so the order the scheduler
/// visits tasks in is also the order knowledge flows. Warm starts are
/// **tiered** ([`TransferModel::warm_start_tiered`]): same-target
/// sibling records at full weight, records measured on *other* targets
/// down-weighted below them — a heterogeneous plan's CPU trials still
/// inform its GPU searches.
///
/// Each plan carries its own measurer and target name; the
/// single-measurer constructor ([`LoopExecutor::new`]) degenerates to
/// the historical one-device executor bit-for-bit.
///
/// [`TransferModel::warm_start_tiered`]: crate::model::TransferModel::warm_start_tiered
pub struct LoopExecutor<'a> {
    tasks: Vec<Task>,
    /// One measurement back-end per plan (aliased to a single back-end
    /// for single-target plans).
    measurers: Vec<&'a dyn Measurer>,
    db: TuningDb,
    /// Record/lookup target name per plan.
    targets: Vec<String>,
    /// Per-plan seed salt (all zero for single-target executors).
    salts: Vec<u64>,
    opts: TuneOptions,
    pipelined: bool,
    warm_start: bool,
    drivers: Vec<Option<Driver>>,
    /// Memoized default-schedule baseline latencies (one cheap
    /// measurement of the vendor config per task, outside the trial
    /// budget and the DB).
    baselines: Vec<Option<f64>>,
    /// Pollable slices in flight (overlapped scheduling), by slice
    /// number.
    active: HashMap<u64, ActiveLoopSlice>,
}

impl<'a> LoopExecutor<'a> {
    /// Build an executor over `tasks` (same order as the scheduler's
    /// plans). `opts` seeds every per-task loop (each task's seed is
    /// decorrelated by its key hash); `pipelined` selects the
    /// three-stage loop, `warm_start` enables cross-task transfer from
    /// `db`.
    pub fn new(
        tasks: Vec<Task>,
        measurer: &'a dyn Measurer,
        db: TuningDb,
        opts: TuneOptions,
        pipelined: bool,
        warm_start: bool,
    ) -> Self {
        let n = tasks.len();
        let target = measurer.target();
        LoopExecutor {
            measurers: vec![measurer; n],
            targets: vec![target; n],
            salts: vec![0; n],
            drivers: (0..n).map(|_| None).collect(),
            baselines: vec![None; n],
            tasks,
            db,
            opts,
            pipelined,
            warm_start,
            active: HashMap::new(),
        }
    }

    /// Build a heterogeneous executor: one measurer per plan, each
    /// dispatching to its own target (e.g. per-target
    /// [`TargetedMeasurer`](crate::measure::service::TargetedMeasurer)
    /// views of one shared farm service). Record targets come from each
    /// measurer, and per-plan seed salts decorrelate the same operator
    /// tuned on different devices.
    pub fn with_measurers(
        tasks: Vec<Task>,
        measurers: Vec<&'a dyn Measurer>,
        db: TuningDb,
        opts: TuneOptions,
        pipelined: bool,
        warm_start: bool,
    ) -> Self {
        assert_eq!(tasks.len(), measurers.len(), "one measurer per plan");
        let targets: Vec<String> = measurers.iter().map(|m| m.target()).collect();
        let salts: Vec<u64> = targets.iter().map(|t| target_salt(t)).collect();
        LoopExecutor {
            drivers: (0..tasks.len()).map(|_| None).collect(),
            baselines: vec![None; tasks.len()],
            tasks,
            measurers,
            db,
            targets,
            salts,
            opts,
            pipelined,
            warm_start,
            active: HashMap::new(),
        }
    }

    /// The shared tuning DB (read best configs from it after a run).
    pub fn db(&self) -> &TuningDb {
        &self.db
    }

    /// Build the warm-start model for plan `idx` from sibling records,
    /// if the DB has any usable rows — the shared
    /// [`TransferModel::warm_start_tiered`] service entry point, with
    /// this plan's sibling tasks as the source inventory and the plan's
    /// own target as tier 1.
    ///
    /// [`TransferModel::warm_start_tiered`]: crate::model::TransferModel::warm_start_tiered
    fn warm_model(
        &self,
        idx: usize,
        task: &Task,
        seed: u64,
    ) -> Option<(TransferModel, WarmStartStats)> {
        if !self.warm_start {
            return None;
        }
        TransferModel::warm_start_tiered(
            &self.db,
            &self.tasks,
            task,
            &self.targets[idx],
            Objective::Rank,
            seed,
        )
    }

    fn ensure_driver(&mut self, idx: usize) {
        if self.drivers[idx].is_some() {
            return;
        }
        let task = self.tasks[idx].clone();
        let mut o = self.opts.clone();
        o.seed ^= task_salt(&task) ^ self.salts[idx];
        o.sink = Some(DbSink::new(&self.db, &task, &self.targets[idx]));
        let model: Box<dyn CostModel + Send> = match self.warm_model(idx, &task, o.seed) {
            Some((warm, stats)) => {
                // features must match the representation the global
                // model was trained on
                o.repr = Representation::ContextRelation;
                if o.verbose {
                    println!("# scheduler: warm-starting {} from sibling records", task.key());
                }
                if stats.used_cross_target() {
                    // unconditional: the cross-target tier is the
                    // multi-target feature's observable artifact (CI
                    // greps for this line)
                    println!(
                        "# warm-start: cross-target D' for {} on {}: {} rows from [{}] at \
                         weight {}",
                        task.key(),
                        self.targets[idx],
                        stats.cross_target_rows,
                        stats.cross_targets.join(", "),
                        crate::model::CROSS_TARGET_WEIGHT,
                    );
                }
                Box::new(warm)
            }
            None => {
                let params = GbtParams { seed: o.seed, ..Default::default() };
                Box::new(GbtModel::new(params))
            }
        };
        self.drivers[idx] = Some(if self.pipelined {
            Driver::Pipelined(PipelinedTuner::new(task, model, o))
        } else {
            Driver::Serial(Tuner::new(task, model, o))
        });
    }
}

impl SliceExecutor for LoopExecutor<'_> {
    fn baseline_secs(&mut self, idx: usize) -> f64 {
        if let Some(s) = self.baselines[idx] {
            return s;
        }
        // One measurement of the vendor (default-schedule) config —
        // outside the trial budget, the accountant and the DB — so the
        // scheduler has a finite pre-tuning latency to compute the
        // slice-1 gain against.
        let task = &self.tasks[idx];
        let cfg = crate::baselines::vendor_config(task);
        let r = self.measurers[idx].measure(task, std::slice::from_ref(&cfg));
        let s = match r.first() {
            Some(res) if res.is_ok() && res.gflops > 0.0 => {
                task.def.total_flops() as f64 / (res.gflops * 1e9)
            }
            _ => f64::INFINITY,
        };
        self.baselines[idx] = Some(s);
        s
    }

    fn best_secs(&mut self, idx: usize) -> f64 {
        let gflops = match &self.drivers[idx] {
            Some(Driver::Serial(t)) => t.best().map(|(_, g)| *g),
            Some(Driver::Pipelined(t)) => t.best().map(|(_, g)| *g),
            None => None,
        };
        match gflops {
            Some(g) if g > 0.0 => {
                self.tasks[idx].def.total_flops() as f64 / (g * 1e9)
            }
            _ => f64::INFINITY,
        }
    }

    fn run_slice(&mut self, idx: usize, trials: usize) -> usize {
        self.ensure_driver(idx);
        let measurer = self.measurers[idx];
        match self.drivers[idx].as_mut().expect("driver ensured") {
            Driver::Serial(t) => {
                let before = t.trials();
                t.tune_more(measurer, trials);
                t.trials() - before
            }
            Driver::Pipelined(t) => {
                let before = t.trials();
                t.tune_more(measurer, trials);
                t.trials() - before
            }
        }
    }

    fn begin_slice(&mut self, no: u64, idx: usize, trials: usize) {
        // Construct the driver (and its warm-start model) at issue
        // time; the slice session itself is armed lazily at the first
        // step, once any earlier slice of the same task has drained.
        self.ensure_driver(idx);
        self.active.insert(no, ActiveLoopSlice { idx, planned: trials, session: None });
    }

    fn step_slice(&mut self, no: u64, idx: usize, trials: usize) -> Option<SliceOutcome> {
        if !self.active.contains_key(&no) {
            // begin_slice was never called for this slice (a
            // barrier-style caller): run it synchronously.
            let spent = self.run_slice(idx, trials);
            let secs_after = self.best_secs(idx);
            return Some(SliceOutcome { spent, secs_after });
        }
        let measurer = self.measurers[idx];
        let step = {
            let slot = self.active.get_mut(&no).expect("checked above");
            let driver = self.drivers[slot.idx].as_mut().expect("driver ensured at begin");
            if slot.session.is_none() {
                let start = driver.trials();
                let run = match driver {
                    Driver::Serial(t) => t.begin_slice(slot.planned),
                    Driver::Pipelined(t) => t.begin_slice(slot.planned),
                };
                slot.session = Some((start, run));
            }
            let (_, run) = slot.session.as_mut().expect("armed above");
            match driver {
                Driver::Serial(t) => t.step_slice(measurer, run),
                Driver::Pipelined(t) => t.step_slice(measurer, run),
            }
        };
        match step {
            SliceStep::Working => None,
            SliceStep::Complete => {
                // The slice's last batch is absorbed — and with it,
                // every record is already streamed through the DB sink
                // (the completion barrier covers the sink; see
                // `SliceStep::Complete`). Only now is the outcome — and
                // the best-latency snapshot gains are computed from —
                // released to the allocator.
                let slot = self.active.remove(&no).expect("checked above");
                let (start, _) = slot.session.expect("stepped at least once");
                let spent =
                    self.drivers[slot.idx].as_ref().expect("driver present").trials() - start;
                let secs_after = self.best_secs(slot.idx);
                Some(SliceOutcome { spent, secs_after })
            }
        }
    }
}

/// Per-task gain history: the smoothed weighted latency reduction per
/// trial (raw last-slice by default, EMA under
/// [`SchedulerOptions::gain_ema`]) and the estimate before it (for the
/// curvature decay), plus restart-detection accounting.
#[derive(Clone, Copy, Default)]
struct Gain {
    slices: usize,
    /// Raw gain of the last committed slice.
    last: f64,
    /// Estimate before the last observation (curvature denominator).
    prev: Option<f64>,
    /// Current estimate: equals `last` in raw mode, the EMA otherwise.
    est: f64,
    /// Restart-detection events (EMA mode only).
    restarts: usize,
}

impl Gain {
    /// Fold in one committed slice's observed gain-per-trial.
    ///
    /// Raw mode (`gain_ema: None`) keeps the historical estimator
    /// exactly: estimate = the last observation, curvature = ratio of
    /// the last two. EMA mode smooths the estimate
    /// (`est ← α·δ + (1−α)·est`) and detects restarts: a fresh
    /// observation beating the decayed estimate by the margin resets
    /// the estimator to the observation and forgets the curvature — a
    /// regime change must be chased at full strength, not blended into
    /// a stale average.
    fn observe(&mut self, delta: f64, opts: &SchedulerOptions) {
        match opts.gain_ema {
            None => {
                self.prev = if self.slices == 0 { None } else { Some(self.last) };
                self.est = delta;
            }
            Some(alpha) => {
                if self.slices == 0 {
                    self.prev = None;
                    self.est = delta;
                } else if delta > 0.0 && delta > opts.restart_margin * self.predicted() {
                    self.prev = None;
                    self.est = delta;
                    self.restarts += 1;
                } else {
                    self.prev = Some(self.est);
                    self.est = alpha * delta + (1.0 - alpha) * self.est;
                }
            }
        }
        self.last = delta;
        self.slices += 1;
    }

    /// Predicted per-trial gain of the *next* slice: the current
    /// estimate, decayed by the task's measured curvature (exact for
    /// exponential-decay curves at a fixed slice size).
    ///
    /// The slice-1 gain is measured against the executor's cheap
    /// default-schedule baseline ([`SliceExecutor::baseline_secs`]), so
    /// `prev` is already finite entering the second slice and the decay
    /// activates from slice 2. Executors without a baseline (those
    /// returning `INFINITY`) degrade to the old behavior: slice-1 gain
    /// 0, decay from slice 3.
    fn predicted(self) -> f64 {
        match self.prev {
            None => self.est,
            Some(prev) if prev > 0.0 => self.est * (self.est / prev).clamp(0.0, 1.0),
            Some(_) => self.est,
        }
    }
}

/// One allocation decision recorded by the [`GainLedger`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LedgerEntry {
    /// Global slice sequence number (issue order).
    pub slice: usize,
    /// Plan index the slice was allocated to.
    pub task: usize,
    /// Ledger version — the number of committed slices — the decision
    /// read. Replayed fixed-seed runs produce identical `(slice, task,
    /// version)` sequences regardless of farm timing.
    pub version: u64,
    /// Trials planned for the slice.
    pub trials: usize,
}

/// Versioned per-task gain snapshots — the bookkeeping that lets the
/// scheduler overlap slices across tasks *without* giving up
/// deterministic gain accounting.
///
/// The ledger's **version** is the number of committed slices. Every
/// allocation decision reads the ledger at its current version (and is
/// recorded in the [`log`](Self::log) with that version); a completed
/// slice **commits** in issue order — never in physical completion
/// order — bumping the version by one. Issued-but-uncommitted slices
/// are visible only through optimistic trial/slice counters (so the
/// bootstrap round-robin and ε floor account for in-flight work), while
/// gains, latencies and exhaustion flags change exclusively at commit.
/// Decisions are therefore a pure function of the commit sequence: a
/// replayed fixed-seed run makes bit-for-bit identical decisions no
/// matter which task's measurements return first, and `overlap = 1`
/// degenerates to the barrier scheduler exactly.
pub struct GainLedger {
    version: u64,
    gains: Vec<Gain>,
    /// Best per-invocation latency per task, as of the last commit.
    secs: Vec<f64>,
    /// Trials issued per task (optimistic: charged at issue, corrected
    /// at commit when a space exhausts mid-slice).
    issued: Vec<usize>,
    /// Trials actually measured per task (commit-time truth).
    committed: Vec<usize>,
    /// Slices issued per task (feeds the bootstrap round-robin).
    slices_issued: Vec<usize>,
    exhausted: Vec<bool>,
    log: Vec<LedgerEntry>,
}

impl GainLedger {
    /// Ledger over `secs0.len()` tasks with their pre-tuning latencies.
    fn new(secs0: Vec<f64>) -> Self {
        let k = secs0.len();
        GainLedger {
            version: 0,
            gains: vec![Gain::default(); k],
            secs: secs0,
            issued: vec![0; k],
            committed: vec![0; k],
            slices_issued: vec![0; k],
            exhausted: vec![false; k],
            log: Vec::new(),
        }
    }

    /// Number of committed slices.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// The decision log so far (issue order).
    pub fn log(&self) -> &[LedgerEntry] {
        &self.log
    }

    /// Record an allocation decision at the current version and charge
    /// the task optimistically.
    fn issue(&mut self, task: usize, trials: usize) {
        self.log.push(LedgerEntry {
            slice: self.log.len(),
            task,
            version: self.version,
            trials,
        });
        self.issued[task] += trials;
        self.slices_issued[task] += 1;
    }

    /// Commit one completed slice (in issue order): fold the observed
    /// gain into the task's estimate, update its latency, refund
    /// unspendable trials and mark exhaustion. Returns the observed
    /// weighted gain-per-trial.
    fn commit(
        &mut self,
        task: usize,
        planned: usize,
        spent: usize,
        secs_after: f64,
        weight: f64,
        opts: &SchedulerOptions,
    ) -> f64 {
        if spent < planned {
            // the space ran dry mid-slice: stop allocating here, and
            // hand the un-measurable trials back for live tasks
            self.exhausted[task] = true;
            self.issued[task] -= planned - spent;
        }
        let delta = if self.secs[task].is_finite() && secs_after.is_finite() && spent > 0 {
            (self.secs[task] - secs_after).max(0.0) * weight / spent as f64
        } else {
            0.0
        };
        self.gains[task].observe(delta, opts);
        self.secs[task] = secs_after;
        self.committed[task] += spent;
        self.version += 1;
        delta
    }
}

/// The graph-level trial allocator (see the module docs). Holds the
/// static plan — tasks, weights, untunable fixed cost — and drives a
/// [`SliceExecutor`] round by round.
pub struct TaskScheduler {
    plans: Vec<TaskPlan>,
    fixed_secs: f64,
    opts: SchedulerOptions,
}

impl TaskScheduler {
    /// Scheduler over explicit plans plus a fixed (untunable) latency
    /// term.
    pub fn new(plans: Vec<TaskPlan>, fixed_secs: f64, opts: SchedulerOptions) -> Self {
        TaskScheduler { plans, fixed_secs, opts }
    }

    /// Scheduler over a plain task list with unit weights and no fixed
    /// cost (the `tune-all` shape: the "graph" is a sum of operators).
    pub fn for_tasks(tasks: Vec<Task>, opts: SchedulerOptions) -> Self {
        let plans = tasks
            .into_iter()
            .map(|task| TaskPlan { task, weight: 1.0, target: None })
            .collect();
        TaskScheduler::new(plans, 0.0, opts)
    }

    /// Scheduler for a network graph on a simulated device: tasks and
    /// multiplicities from [`Graph::weighted_tasks`], the fixed glue
    /// cost from [`Graph::fixed_latency`] under default schedules.
    ///
    /// [`Graph::weighted_tasks`]: crate::graph::Graph::weighted_tasks
    /// [`Graph::fixed_latency`]: crate::graph::Graph::fixed_latency
    pub fn from_graph(
        graph: &Graph,
        device: &DeviceModel,
        template: TemplateKind,
        opts: SchedulerOptions,
    ) -> anyhow::Result<Self> {
        let plans = graph
            .weighted_tasks(template)
            .into_iter()
            .map(|(task, mult)| TaskPlan { task, weight: mult as f64, target: None })
            .collect();
        let fixed = graph.fixed_latency(device, template)?;
        Ok(TaskScheduler::new(plans, fixed, opts))
    }

    /// Scheduler for a network deployed across a **heterogeneous
    /// fleet**: one plan per `(task, target)` pair — each device
    /// contributes its task set under the template of its class
    /// ([`TemplateKind::for_class`]) with plans tagged by device name —
    /// all spending one global trial budget. The fixed glue cost sums
    /// over the devices (each deployment pays its own untunable floor).
    ///
    /// Because [`Task::key`] embeds the template, CPU and GPU plans of
    /// the same operator are distinct tasks to the allocator, while the
    /// tiered warm start ([`TransferModel::warm_start_tiered`]) still
    /// transfers their records across targets through the
    /// target-invariant `ContextRelation` features.
    ///
    /// [`TransferModel::warm_start_tiered`]: crate::model::TransferModel::warm_start_tiered
    pub fn from_graph_multi(
        graph: &Graph,
        devices: &[DeviceModel],
        opts: SchedulerOptions,
    ) -> anyhow::Result<Self> {
        let mut plans = Vec::new();
        let mut fixed = 0.0;
        for device in devices {
            let template = TemplateKind::for_class(device.class);
            for (task, mult) in graph.weighted_tasks(template) {
                plans.push(TaskPlan {
                    task,
                    weight: mult as f64,
                    target: Some(device.name.to_string()),
                });
            }
            fixed += graph.fixed_latency(device, template)?;
        }
        Ok(TaskScheduler::new(plans, fixed, opts))
    }

    /// Replace the trial budget (builder-style) — lets callers derive a
    /// per-task default from [`plans`](Self::plans)`.len()` without
    /// rebuilding the plan.
    pub fn with_budget(mut self, budget: usize) -> Self {
        self.opts.budget = budget;
        self
    }

    /// The static plan (tasks + weights), in allocation index order.
    pub fn plans(&self) -> &[TaskPlan] {
        &self.plans
    }

    /// Seconds of untunable glue latency included in
    /// [`Allocation::est_latency`].
    pub fn fixed_secs(&self) -> f64 {
        self.fixed_secs
    }

    /// Pick the task for the next slice, skipping exhausted spaces.
    /// Deterministic: ties break on the lowest index. `None` when every
    /// task is exhausted.
    ///
    /// `trials` and `slices` count *issued* work (committed plus
    /// in-flight under overlap — the bootstrap and ε floor must see
    /// what is already on the farm), while `gains`/`exhausted` are
    /// commit-time truth. In a barrier run the two views coincide.
    fn pick(
        &self,
        trials: &[usize],
        slices: &[usize],
        gains: &[Gain],
        exhausted: &[bool],
    ) -> Option<usize> {
        let k = self.plans.len();
        let argmin_trials = |trials: &[usize]| -> Option<usize> {
            let mut best: Option<usize> = None;
            for i in 0..k {
                if exhausted[i] {
                    continue;
                }
                if best.map_or(true, |b| trials[i] < trials[b]) {
                    best = Some(i);
                }
            }
            best
        };
        match self.opts.policy {
            AllocPolicy::Uniform => argmin_trials(trials),
            AllocPolicy::Gradient => {
                // bootstrap: two slices per task before trusting gains,
                // round-robin (everyone gets a first slice before anyone
                // gets a second, so small budgets still cover all tasks)
                let mut boot: Option<usize> = None;
                for i in 0..k {
                    if exhausted[i] || slices[i] >= 2 {
                        continue;
                    }
                    if boot.map_or(true, |b: usize| slices[i] < slices[b]) {
                        boot = Some(i);
                    }
                }
                if boot.is_some() {
                    return boot;
                }
                // ε floor: top up a starved task
                let total: usize = trials.iter().sum();
                if let Some(imin) = argmin_trials(trials) {
                    if (trials[imin] as f64) < self.opts.eps * total as f64 / k as f64 {
                        return Some(imin);
                    }
                }
                // greedy on the predicted next-slice gain (ties break on
                // the first index via strict gt)
                let mut best: Option<(usize, f64)> = None;
                for i in 0..k {
                    if exhausted[i] {
                        continue;
                    }
                    let p = gains[i].predicted();
                    if best.map_or(true, |(_, g)| p > g) {
                        best = Some((i, p));
                    }
                }
                best.map(|(i, _)| i)
            }
        }
    }

    /// Convenience driver over the real tuning loops: builds a
    /// [`LoopExecutor`] for this plan's tasks (streaming into `db`,
    /// with optional pipelined slices and cross-task warm starts) and
    /// runs the allocation — overlapped across tasks when
    /// [`SchedulerOptions::overlap`]` > 1`. Best configs are served
    /// from `db` afterwards. One entry point shared by `tune-graph`,
    /// `tune-all --alloc gradient` and the fig11 driver.
    pub fn run_tuning(
        &self,
        measurer: &dyn Measurer,
        db: &TuningDb,
        opts: TuneOptions,
        pipelined: bool,
        warm_start: bool,
    ) -> Allocation {
        let tasks: Vec<Task> = self.plans.iter().map(|p| p.task.clone()).collect();
        let mut exec =
            LoopExecutor::new(tasks, measurer, db.clone(), opts, pipelined, warm_start);
        self.run(&mut exec)
    }

    /// [`run_tuning`](Self::run_tuning) for heterogeneous plans: each
    /// plan's trials run on the measurer registered for its target
    /// (name → back-end, e.g. per-target
    /// [`for_target`](crate::measure::service::MeasureService::for_target)
    /// views of one shared farm service). Plans without a target — and
    /// plans whose target has no registered measurer — fall back to the
    /// first entry, so a single-device measurer list still drives a
    /// multi-target plan (on one device).
    ///
    /// # Panics
    /// Panics when `measurers` is empty.
    pub fn run_tuning_multi(
        &self,
        measurers: &[(String, &dyn Measurer)],
        db: &TuningDb,
        opts: TuneOptions,
        pipelined: bool,
        warm_start: bool,
    ) -> Allocation {
        assert!(!measurers.is_empty(), "at least one measurer");
        let tasks: Vec<Task> = self.plans.iter().map(|p| p.task.clone()).collect();
        let per_plan: Vec<&dyn Measurer> = self
            .plans
            .iter()
            .map(|p| match &p.target {
                Some(t) => measurers
                    .iter()
                    .find(|(name, _)| name == t)
                    .map_or(measurers[0].1, |(_, m)| *m),
                None => measurers[0].1,
            })
            .collect();
        let mut exec =
            LoopExecutor::with_measurers(tasks, per_plan, db.clone(), opts, pipelined, warm_start);
        self.run(&mut exec)
    }

    /// Run the allocation loop: spend the whole budget in slices,
    /// returning where it went and the resulting latency estimate.
    /// With [`SchedulerOptions::overlap`]` > 1` this is the overlapped
    /// loop ([`run_overlapped`](Self::run_overlapped)); otherwise the
    /// historical barrier loop — each slice fully drains before the
    /// next allocation decision.
    pub fn run(&self, exec: &mut dyn SliceExecutor) -> Allocation {
        if self.opts.overlap > 1 {
            self.run_overlapped(exec)
        } else {
            self.run_barrier(exec)
        }
    }

    /// Empty-plan / zero-budget result.
    fn empty_allocation(&self) -> Allocation {
        let k = self.plans.len();
        Allocation {
            trials: vec![0; k],
            secs: vec![f64::INFINITY; k],
            rounds: 0,
            est_latency: self.fixed_secs,
            log: Vec::new(),
            restarts: vec![0; k],
        }
    }

    /// Normalized slice size: small enough for two bootstrap slices per
    /// task, at least 1.
    fn norm_slice(&self, k: usize) -> usize {
        self.opts.slice.max(1).min((self.opts.budget / (2 * k)).max(1))
    }

    /// Pre-tuning latencies: finite default-schedule baselines so the
    /// very first slice's gain is observable (curvature decay from
    /// slice 2; see `Gain::predicted`). Uniform allocation never reads
    /// gains, so it must not pay the per-task baseline measurement.
    fn initial_secs(&self, exec: &mut dyn SliceExecutor, k: usize) -> Vec<f64> {
        match self.opts.policy {
            AllocPolicy::Gradient => (0..k).map(|i| exec.baseline_secs(i)).collect(),
            AllocPolicy::Uniform => (0..k).map(|i| exec.best_secs(i)).collect(),
        }
    }

    fn round_report(
        &self,
        rounds: usize,
        i: usize,
        spent: usize,
        total: usize,
        delta: f64,
        new: f64,
    ) {
        if self.opts.verbose {
            println!(
                "# round {rounds:3}: {} +{spent} trials (total {total}), {:.3} ms/invocation, \
                 gain {:.3e} s/trial",
                self.plans[i].task.key(),
                new * 1e3,
                delta
            );
        }
    }

    /// The barrier allocation loop: one slice at a time, each fully
    /// drained before the next decision.
    fn run_barrier(&self, exec: &mut dyn SliceExecutor) -> Allocation {
        let k = self.plans.len();
        if k == 0 || self.opts.budget == 0 {
            return self.empty_allocation();
        }
        let slice = self.norm_slice(k);
        let mut ledger = GainLedger::new(self.initial_secs(exec, k));
        let mut rounds = 0usize;
        let mut remaining = self.opts.budget;
        while remaining > 0 {
            let s = slice.min(remaining);
            let Some(i) = self.pick(
                &ledger.issued,
                &ledger.slices_issued,
                &ledger.gains,
                &ledger.exhausted,
            ) else {
                break; // every config space is exhausted
            };
            ledger.issue(i, s);
            let spent = exec.run_slice(i, s).min(s);
            let new = exec.best_secs(i);
            // weighted latency reduction per trial; unknown (±∞) states
            // contribute no gradient and are left to the ε floor
            let delta = ledger.commit(i, s, spent, new, self.plans[i].weight, &self.opts);
            // unspent budget stays available for the remaining live
            // tasks; the loop ends when it is gone or everyone is
            // exhausted (at most k zero-spend probe rounds)
            remaining -= spent;
            rounds += 1;
            self.round_report(rounds, i, spent, ledger.committed[i], delta, new);
        }
        self.finish(ledger, rounds)
    }

    /// The overlapped allocation loop: up to
    /// [`SchedulerOptions::overlap`] task-slices in flight at once,
    /// with deterministic gain accounting through the [`GainLedger`]
    /// (see the module docs). In-flight slices are stepped in a fixed
    /// oldest-first rotation — never by wall-clock readiness — and a
    /// slice only steps when it is the earliest incomplete slice of its
    /// task; completed slices retire strictly in issue order. The
    /// decision sequence is therefore a pure function of the committed
    /// outcomes, regardless of which task's measurements physically
    /// return first.
    pub fn run_overlapped(&self, exec: &mut dyn SliceExecutor) -> Allocation {
        let k = self.plans.len();
        let overlap = self.opts.overlap.max(1);
        if k == 0 || self.opts.budget == 0 {
            return self.empty_allocation();
        }
        let slice = self.norm_slice(k);
        let mut ledger = GainLedger::new(self.initial_secs(exec, k));
        /// One issued slice awaiting completion (FIFO retire order).
        struct InFlight {
            no: u64,
            idx: usize,
            planned: usize,
            outcome: Option<SliceOutcome>,
        }
        let mut active: VecDeque<InFlight> = VecDeque::new();
        let mut remaining = self.opts.budget;
        let mut rounds = 0usize;
        let mut next_no = 0u64;
        // Issue one slice at the ledger's current version (a decision),
        // if budget and a live task allow. The optimistic
        // issued-counters keep the bootstrap round-robin and ε floor
        // aware of in-flight work.
        let fill_one = |ledger: &mut GainLedger,
                            active: &mut VecDeque<InFlight>,
                            remaining: &mut usize,
                            next_no: &mut u64,
                            exec: &mut dyn SliceExecutor|
         -> bool {
            if *remaining == 0 {
                return false;
            }
            let s = slice.min(*remaining);
            let Some(i) = self.pick(
                &ledger.issued,
                &ledger.slices_issued,
                &ledger.gains,
                &ledger.exhausted,
            ) else {
                return false; // nothing issuable: every live task exhausted
            };
            ledger.issue(i, s);
            exec.begin_slice(*next_no, i, s);
            active.push_back(InFlight { no: *next_no, idx: i, planned: s, outcome: None });
            *remaining -= s;
            *next_no += 1;
            true
        };
        loop {
            // (Re)fill an empty window up to the overlap bound — the
            // initial fill, and the restart after refunds revive a
            // drained budget. Otherwise slices are issued ONLY at
            // commits (one per commit, below): slice k's decision then
            // always reads version max(0, k − N + 1), however
            // completions bunch in wall-clock — the timing-invariance
            // half of the determinism story.
            if active.is_empty() {
                while active.len() < overlap
                    && fill_one(&mut ledger, &mut active, &mut remaining, &mut next_no, &mut *exec)
                {}
                if active.is_empty() {
                    break; // budget spent (or refunded but unissuable)
                }
            }
            // Advance every in-flight slice by one step, oldest first —
            // a fixed rotation, so the executor's op sequence (and with
            // it every RNG and farm-sequence draw) is reproducible. A
            // slice waits while an earlier incomplete slice of the same
            // task exists: per-task execution is strictly sequential.
            for pos in 0..active.len() {
                if active[pos].outcome.is_some() {
                    continue;
                }
                let idx = active[pos].idx;
                let blocked =
                    (0..pos).any(|q| active[q].idx == idx && active[q].outcome.is_none());
                if blocked {
                    continue;
                }
                let (no, planned) = (active[pos].no, active[pos].planned);
                active[pos].outcome = exec.step_slice(no, idx, planned);
            }
            // Retire strictly in issue order: a slice that finished
            // early waits for its predecessors, so commits — and the
            // ledger versions later decisions read — form the same
            // sequence every run. Each commit releases exactly one new
            // decision at the just-bumped version.
            while let Some(front) = active.front() {
                let Some(out) = front.outcome else { break };
                let (idx, planned) = (front.idx, front.planned);
                active.pop_front();
                let spent = out.spent.min(planned);
                remaining += planned - spent; // refund unspendable budget
                let delta = ledger.commit(
                    idx,
                    planned,
                    spent,
                    out.secs_after,
                    self.plans[idx].weight,
                    &self.opts,
                );
                rounds += 1;
                self.round_report(rounds, idx, spent, ledger.committed[idx], delta, out.secs_after);
                fill_one(&mut ledger, &mut active, &mut remaining, &mut next_no, &mut *exec);
            }
        }
        self.finish(ledger, rounds)
    }

    /// Fold a finished ledger into the [`Allocation`] report.
    fn finish(&self, ledger: GainLedger, rounds: usize) -> Allocation {
        let est_latency = self.fixed_secs
            + self
                .plans
                .iter()
                .zip(&ledger.secs)
                .map(|(p, s)| p.weight * s)
                .sum::<f64>();
        let restarts = ledger.gains.iter().map(|g| g.restarts).collect();
        Allocation {
            trials: ledger.committed,
            secs: ledger.secs,
            rounds,
            est_latency,
            log: ledger.log,
            restarts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;

    fn tiny_tasks(n: usize) -> Vec<Task> {
        (0..n)
            .map(|i| {
                Task::new(ops::matmul(32 << i, 32, 32), TemplateKind::Cpu)
            })
            .collect()
    }

    /// Hand-built curves: no hashing, so the test controls the shape.
    fn curves(params: &[(f64, f64, f64)]) -> CurveExecutor {
        CurveExecutor::new(
            params
                .iter()
                .map(|&(floor, span, tau)| TaskCurve { floor, span, tau })
                .collect(),
        )
    }

    #[test]
    fn uniform_is_round_robin() {
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(3),
            SchedulerOptions {
                budget: 96,
                slice: 16,
                policy: AllocPolicy::Uniform,
                ..Default::default()
            },
        );
        let mut exec = curves(&[(1.0, 1.0, 10.0), (2.0, 3.0, 40.0), (0.5, 0.1, 5.0)]);
        let alloc = sched.run(&mut exec);
        assert_eq!(alloc.trials, vec![32, 32, 32]);
        assert_eq!(alloc.rounds, 6);
        assert_eq!(alloc.trials.iter().sum::<usize>(), 96);
    }

    #[test]
    fn gradient_prefers_the_high_gain_task() {
        // task 1 has 30× the tunable headroom of task 0 at the same
        // decay rate — after bootstrap, gradient allocation must send
        // (nearly) all remaining budget its way
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(2),
            SchedulerOptions {
                budget: 160,
                slice: 16,
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        );
        let mut exec = curves(&[(1.0, 0.1, 50.0), (1.0, 3.0, 50.0)]);
        let alloc = sched.run(&mut exec);
        assert!(alloc.trials[1] > alloc.trials[0], "{:?}", alloc.trials);
        // bootstrap gave task 0 its two slices; everything else went to 1
        assert_eq!(alloc.trials[0], 32, "{:?}", alloc.trials);
        assert_eq!(alloc.trials.iter().sum::<usize>(), 160);
    }

    #[test]
    fn weights_redirect_the_budget() {
        // identical curves, but task 0 appears 8× in the graph — its
        // weighted gain dominates
        let plans: Vec<TaskPlan> = tiny_tasks(2)
            .into_iter()
            .enumerate()
            .map(|(i, task)| {
                let weight = if i == 0 { 8.0 } else { 1.0 };
                TaskPlan { task, weight, target: None }
            })
            .collect();
        let sched = TaskScheduler::new(
            plans,
            0.0,
            SchedulerOptions {
                budget: 160,
                slice: 16,
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        );
        let mut exec = curves(&[(1.0, 2.0, 60.0), (1.0, 2.0, 60.0)]);
        let alloc = sched.run(&mut exec);
        assert!(alloc.trials[0] > alloc.trials[1], "{:?}", alloc.trials);
    }

    #[test]
    fn multi_target_plans_tag_each_device() {
        use crate::sim::devices::{sim_cpu, sim_gpu};
        let graph = crate::workloads::dqn();
        let devices = [sim_cpu(), sim_gpu()];
        let opts = SchedulerOptions::default();
        let sched = TaskScheduler::from_graph_multi(&graph, &devices, opts.clone()).unwrap();
        let single =
            TaskScheduler::from_graph(&graph, &devices[0], TemplateKind::Cpu, opts).unwrap();
        // one plan per (task, target): each device contributes its full
        // task set under its class's template
        assert_eq!(sched.plans().len(), 2 * single.plans().len());
        for plan in sched.plans() {
            let t = plan.target.as_deref().expect("multi plans are targeted");
            let want = if t == "sim-cpu" { TemplateKind::Cpu } else { TemplateKind::Gpu };
            assert_eq!(plan.task.template, want, "{t}");
        }
        // each deployment pays its own untunable glue floor
        assert!(sched.fixed_secs() >= single.fixed_secs());
    }

    #[test]
    fn eps_floor_prevents_starvation() {
        // task 0 flatlines immediately (zero span): its gradient is 0
        // after bootstrap, but the ε floor must keep topping it up as
        // the run gets long
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(2),
            SchedulerOptions {
                budget: 50 * 16,
                slice: 16,
                policy: AllocPolicy::Gradient,
                eps: 0.2,
                ..Default::default()
            },
        );
        let mut exec = curves(&[(1.0, 0.0, 50.0), (1.0, 5.0, 100.0)]);
        let alloc = sched.run(&mut exec);
        assert!(alloc.trials[0] > 2 * 16, "floor never triggered: {:?}", alloc.trials);
        // the floor share stays close to ε of the uniform share
        let share = alloc.trials[0] as f64 / (alloc.trials.iter().sum::<usize>() as f64 / 2.0);
        assert!(share < 0.5, "floor overshot: {share}");
    }

    #[test]
    fn small_budgets_shrink_the_slice_for_full_coverage() {
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(4),
            SchedulerOptions {
                budget: 16,
                slice: 64, // nominal slice is bigger than the whole budget
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        );
        let mut exec =
            curves(&[(1.0, 1.0, 10.0), (1.0, 1.0, 10.0), (1.0, 1.0, 10.0), (1.0, 1.0, 10.0)]);
        let alloc = sched.run(&mut exec);
        assert_eq!(alloc.trials.iter().sum::<usize>(), 16);
        assert!(alloc.trials.iter().all(|&n| n > 0), "{:?}", alloc.trials);
    }

    #[test]
    fn bootstrap_round_robin_covers_all_tasks_below_two_slices_each() {
        // budget in [k, 2k): the interleaved bootstrap must still reach
        // every task once before anyone's second slice
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(4),
            SchedulerOptions {
                budget: 5,
                slice: 64,
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        );
        let mut exec =
            curves(&[(1.0, 1.0, 10.0), (1.0, 1.0, 10.0), (1.0, 1.0, 10.0), (1.0, 1.0, 10.0)]);
        let alloc = sched.run(&mut exec);
        assert_eq!(alloc.trials, vec![2, 1, 1, 1]);
    }

    /// Executor whose tasks run out of configs: unspendable budget must
    /// not be charged as phantom trials, and the loop must terminate.
    struct CappedExecutor {
        caps: Vec<usize>,
        spent: Vec<usize>,
    }

    impl SliceExecutor for CappedExecutor {
        fn best_secs(&mut self, idx: usize) -> f64 {
            1.0 / (1.0 + self.spent[idx] as f64)
        }

        fn run_slice(&mut self, idx: usize, trials: usize) -> usize {
            let n = trials.min(self.caps[idx] - self.spent[idx]);
            self.spent[idx] += n;
            n
        }
    }

    #[test]
    fn exhausted_spaces_are_not_charged_phantom_trials() {
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(2),
            SchedulerOptions {
                budget: 320,
                slice: 16,
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        );
        // total capacity (40) is far below the budget (320)
        let mut exec = CappedExecutor { caps: vec![24, 16], spent: vec![0, 0] };
        let alloc = sched.run(&mut exec);
        assert_eq!(alloc.trials, vec![24, 16], "trials must reflect real spend");
        assert_eq!(exec.spent, vec![24, 16]);
        // terminated after everyone exhausted, without burning rounds on
        // the full nominal budget
        assert!(alloc.rounds <= 6, "{} rounds", alloc.rounds);
    }

    #[test]
    fn empty_schedule_is_a_noop() {
        let sched = TaskScheduler::for_tasks(vec![], SchedulerOptions::default());
        let mut exec = curves(&[]);
        let alloc = sched.run(&mut exec);
        assert_eq!(alloc.rounds, 0);
        assert!(alloc.trials.is_empty());
        assert_eq!(alloc.est_latency, 0.0);
    }

    #[test]
    fn est_latency_matches_curves() {
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(2),
            SchedulerOptions {
                budget: 64,
                slice: 16,
                policy: AllocPolicy::Uniform,
                ..Default::default()
            },
        );
        let mut exec = curves(&[(1.0, 1.0, 20.0), (2.0, 2.0, 30.0)]);
        let alloc = sched.run(&mut exec);
        let expect: f64 = exec
            .spent()
            .iter()
            .zip(&[(1.0, 1.0, 20.0), (2.0, 2.0, 30.0)])
            .map(|(&n, &(f, s, t))| TaskCurve { floor: f, span: s, tau: t }.secs_after(n))
            .sum();
        assert!((alloc.est_latency - expect).abs() < 1e-12);
    }
}
