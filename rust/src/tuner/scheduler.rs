//! Graph-level task scheduler: allocate one global trial budget across
//! the tasks of a network by expected marginal reduction in end-to-end
//! latency.
//!
//! The paper's headline numbers are end-to-end (§6.3: ResNet-18,
//! MobileNet, LSTM-LM, DQN, DCGAN), but Algorithm 1 tunes a *single*
//! operator. Chaining per-task runs with a uniform budget wastes trials:
//! a network's latency is dominated by a few hot tasks (node
//! multiplicity × per-invocation cost), and tuning curves flatten at
//! different rates. The scheduler closes that loop — graph → tasks →
//! tuner → db → graph latency:
//!
//! 1. Derive the task set and static weights from the graph
//!    ([`Graph::weighted_tasks`]: deduplicated tasks with node
//!    multiplicity; [`Graph::latency_by_task`] attributes the current
//!    latency to tasks plus an untunable fixed floor).
//! 2. Spend the budget in **rounds**: each round runs one `slice` of
//!    trials on one task through the persistent incremental loops
//!    ([`Tuner::tune_more`] / [`PipelinedTuner::tune_more`]), streaming
//!    every trial into the shared [`TuningDb`] so later rounds of
//!    *other* tasks warm-start from the records
//!    ([`TransferModel::from_db`]).
//! 3. Pick the next task **greedily** by predicted marginal gain
//!    ([`AllocPolicy::Gradient`]): the observed weighted
//!    latency-reduction-per-trial of a task's last slice, decayed by the
//!    task's own measured curvature (the ratio of its last two slice
//!    gains) — a discrete gradient of end-to-end latency with respect to
//!    trial budget, in the spirit of Ansor's task scheduler (Zheng et
//!    al., OSDI 2020).
//!
//! Two guardrails keep the greedy loop honest:
//!
//! * **Bootstrap** — every task gets two slices before any gradient is
//!   trusted (a single slice has no curvature estimate), round-robin:
//!   everyone receives a first slice before anyone gets a second, so
//!   even a budget below `2·k·slice` covers every task.
//! * **ε floor** — a task whose share of spent trials falls below
//!   `ε × (uniform share)` is topped up next, so a task written off by
//!   a noisy early estimate is never starved forever — and no task ever
//!   receives zero trials.
//!
//! Execution is abstracted behind [`SliceExecutor`], with two
//! implementations: [`LoopExecutor`] drives the real tuning loops, and
//! [`CurveExecutor`] replays deterministic per-task latency curves
//! ([`TaskCurve`]) so allocation decisions are testable exactly — at
//! equal budget, gradient allocation must beat uniform on the simulated
//! farm deterministically, not on a lucky seed.
//!
//! ```
//! use autotvm::expr::ops;
//! use autotvm::schedule::template::{Task, TemplateKind};
//! use autotvm::sim::devices::{sim_gpu, TaskCurve};
//! use autotvm::tuner::scheduler::{
//!     AllocPolicy, CurveExecutor, SchedulerOptions, TaskScheduler,
//! };
//!
//! let tasks = vec![
//!     Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu),
//!     Task::new(ops::matmul(128, 128, 128), TemplateKind::Gpu),
//! ];
//! let dev = sim_gpu();
//! let mut farm = CurveExecutor::new(
//!     tasks.iter().map(|t| TaskCurve::for_task(t, &dev)).collect(),
//! );
//! let sched = TaskScheduler::for_tasks(
//!     tasks,
//!     SchedulerOptions {
//!         budget: 64,
//!         slice: 8,
//!         policy: AllocPolicy::Gradient,
//!         ..Default::default()
//!     },
//! );
//! let alloc = sched.run(&mut farm);
//! assert_eq!(alloc.trials.iter().sum::<usize>(), 64);
//! assert!(alloc.trials.iter().all(|&n| n > 0)); // ε floor
//! ```
//!
//! [`Graph::weighted_tasks`]: crate::graph::Graph::weighted_tasks
//! [`Graph::latency_by_task`]: crate::graph::Graph::latency_by_task
//! [`Tuner::tune_more`]: super::Tuner::tune_more
//! [`PipelinedTuner::tune_more`]: super::pipeline::PipelinedTuner::tune_more
//! [`TransferModel::from_db`]: crate::model::TransferModel::from_db
//! [`TaskCurve`]: crate::sim::devices::TaskCurve
//! [`TuningDb`]: super::db::TuningDb

use super::db::TuningDb;
use super::pipeline::PipelinedTuner;
use super::{DbSink, TuneOptions, Tuner};
use crate::features::Representation;
use crate::gbt::{GbtParams, Objective};
use crate::graph::{task_salt, Graph};
use crate::measure::Measurer;
use crate::model::{CostModel, GbtModel, TransferModel};
use crate::schedule::template::{Task, TemplateKind};
use crate::sim::devices::TaskCurve;
use crate::sim::DeviceModel;

/// How the global trial budget is spread across tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Round-robin equal shares — the pre-scheduler `tune-all` behavior,
    /// kept as the comparison baseline.
    Uniform,
    /// Greedy on the predicted marginal reduction in end-to-end latency
    /// per trial (with bootstrap and ε floor; see the module docs).
    Gradient,
}

impl AllocPolicy {
    /// CLI name of the policy.
    pub fn name(self) -> &'static str {
        match self {
            AllocPolicy::Uniform => "uniform",
            AllocPolicy::Gradient => "gradient",
        }
    }

    /// Parse a CLI name (`uniform` / `gradient`).
    pub fn parse(s: &str) -> Option<AllocPolicy> {
        match s {
            "uniform" => Some(AllocPolicy::Uniform),
            "gradient" => Some(AllocPolicy::Gradient),
            _ => None,
        }
    }
}

/// Budget-allocation options of one scheduler run.
#[derive(Clone, Debug)]
pub struct SchedulerOptions {
    /// Total measurement trials across all tasks.
    pub budget: usize,
    /// Trials per round-slice. Normalized down to `budget / (2·tasks)`
    /// when the budget is too small for two bootstrap slices per task,
    /// so the floor guarantee survives small budgets.
    pub slice: usize,
    /// Allocation policy (gradient by default).
    pub policy: AllocPolicy,
    /// Starvation floor: a task whose trial share drops below
    /// `eps × (spent / tasks)` is topped up next round.
    pub eps: f64,
    /// Print one line per round (task picked, gain estimate, latency).
    pub verbose: bool,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions {
            budget: 512,
            slice: 64,
            policy: AllocPolicy::Gradient,
            eps: 0.05,
            verbose: false,
        }
    }
}

/// One task of the schedule with its static end-to-end weight.
#[derive(Clone, Debug)]
pub struct TaskPlan {
    /// The tunable task.
    pub task: Task,
    /// End-to-end weight: how many times the task's latency counts
    /// toward the graph latency (node multiplicity; 1.0 for plain task
    /// lists).
    pub weight: f64,
}

/// Outcome of a scheduler run: where the budget went and where latency
/// ended up. Vectors are indexed like [`TaskScheduler::plans`].
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Trials spent per task (sums to the budget when the executor
    /// never exhausts a space).
    pub trials: Vec<usize>,
    /// Best per-invocation latency per task after tuning (seconds;
    /// `INFINITY` when a task never measured a valid config).
    pub secs: Vec<f64>,
    /// Rounds executed.
    pub rounds: usize,
    /// Estimated end-to-end latency: fixed glue cost plus the
    /// weighted sum of `secs`.
    pub est_latency: f64,
}

/// Executes trial slices for the scheduler — the boundary between the
/// allocation *decision* (pure, deterministic, testable) and tuning
/// *execution* (real loops or replayed curves).
pub trait SliceExecutor {
    /// Current best per-invocation latency of task `idx` in seconds
    /// (`INFINITY` before any valid measurement).
    fn best_secs(&mut self, idx: usize) -> f64;

    /// Cheap pre-tuning baseline latency of task `idx` in seconds —
    /// what the task costs *before* any trial is spent (a default /
    /// vendor schedule). A finite baseline gives the scheduler a real
    /// slice-1 gain, so the curvature decay activates from a task's
    /// second slice instead of its third. The default delegates to
    /// [`best_secs`](Self::best_secs) (replayed curves are already
    /// finite at zero trials); executors without a cheap baseline may
    /// return `INFINITY`, which degrades gracefully to the old
    /// zero-gain bootstrap.
    fn baseline_secs(&mut self, idx: usize) -> f64 {
        self.best_secs(idx)
    }

    /// Spend up to `trials` more measurements on task `idx`. Returns
    /// the number actually measured — less than `trials` when the
    /// task's config space is exhausted (the scheduler then stops
    /// allocating to that task).
    fn run_slice(&mut self, idx: usize, trials: usize) -> usize;
}

/// Replays deterministic [`TaskCurve`]s instead of running tuning loops
/// — the simulated farm the allocator is tested against.
pub struct CurveExecutor {
    curves: Vec<TaskCurve>,
    spent: Vec<usize>,
}

impl CurveExecutor {
    /// Executor over one curve per task (same order as the plans).
    pub fn new(curves: Vec<TaskCurve>) -> Self {
        let spent = vec![0; curves.len()];
        CurveExecutor { curves, spent }
    }

    /// Trials spent per task so far.
    pub fn spent(&self) -> &[usize] {
        &self.spent
    }
}

impl SliceExecutor for CurveExecutor {
    fn best_secs(&mut self, idx: usize) -> f64 {
        self.curves[idx].secs_after(self.spent[idx])
    }

    fn run_slice(&mut self, idx: usize, trials: usize) -> usize {
        self.spent[idx] += trials;
        trials // curves never exhaust
    }
}

/// Per-task incremental tuning driver of the [`LoopExecutor`].
enum Driver {
    Serial(Tuner),
    Pipelined(PipelinedTuner),
}

/// Drives the real incremental tuning loops: one persistent driver per
/// task (created lazily at its first slice), every measured trial
/// streamed into the shared [`TuningDb`], and — when the DB already
/// holds records of *sibling* tasks on the same target — a transfer
/// warm start under [`Representation::ContextRelation`], so the order
/// the scheduler visits tasks in is also the order knowledge flows.
pub struct LoopExecutor<'a> {
    tasks: Vec<Task>,
    measurer: &'a dyn Measurer,
    db: TuningDb,
    target: String,
    opts: TuneOptions,
    pipelined: bool,
    warm_start: bool,
    drivers: Vec<Option<Driver>>,
    /// Memoized default-schedule baseline latencies (one cheap
    /// measurement of the vendor config per task, outside the trial
    /// budget and the DB).
    baselines: Vec<Option<f64>>,
}

impl<'a> LoopExecutor<'a> {
    /// Build an executor over `tasks` (same order as the scheduler's
    /// plans). `opts` seeds every per-task loop (each task's seed is
    /// decorrelated by its key hash); `pipelined` selects the
    /// three-stage loop, `warm_start` enables cross-task transfer from
    /// `db`.
    pub fn new(
        tasks: Vec<Task>,
        measurer: &'a dyn Measurer,
        db: TuningDb,
        opts: TuneOptions,
        pipelined: bool,
        warm_start: bool,
    ) -> Self {
        let drivers = tasks.iter().map(|_| None).collect();
        let baselines = tasks.iter().map(|_| None).collect();
        let target = measurer.target();
        LoopExecutor { tasks, measurer, db, target, opts, pipelined, warm_start, drivers, baselines }
    }

    /// The shared tuning DB (read best configs from it after a run).
    pub fn db(&self) -> &TuningDb {
        &self.db
    }

    /// Build the warm-start model for `task` from sibling records, if
    /// the DB has any usable rows — the shared
    /// [`TransferModel::warm_start`] service entry point, with this
    /// plan's sibling tasks as the source inventory.
    fn warm_model(&self, task: &Task, seed: u64) -> Option<TransferModel> {
        if !self.warm_start {
            return None;
        }
        TransferModel::warm_start(&self.db, &self.tasks, task, &self.target, Objective::Rank, seed)
    }

    fn ensure_driver(&mut self, idx: usize) {
        if self.drivers[idx].is_some() {
            return;
        }
        let task = self.tasks[idx].clone();
        let mut o = self.opts.clone();
        o.seed ^= task_salt(&task);
        o.sink = Some(DbSink::new(&self.db, &task, &self.target));
        let model: Box<dyn CostModel + Send> = match self.warm_model(&task, o.seed) {
            Some(warm) => {
                // features must match the representation the global
                // model was trained on
                o.repr = Representation::ContextRelation;
                if o.verbose {
                    println!("# scheduler: warm-starting {} from sibling records", task.key());
                }
                Box::new(warm)
            }
            None => {
                let params = GbtParams { seed: o.seed, ..Default::default() };
                Box::new(GbtModel::new(params))
            }
        };
        self.drivers[idx] = Some(if self.pipelined {
            Driver::Pipelined(PipelinedTuner::new(task, model, o))
        } else {
            Driver::Serial(Tuner::new(task, model, o))
        });
    }
}

impl SliceExecutor for LoopExecutor<'_> {
    fn baseline_secs(&mut self, idx: usize) -> f64 {
        if let Some(s) = self.baselines[idx] {
            return s;
        }
        // One measurement of the vendor (default-schedule) config —
        // outside the trial budget, the accountant and the DB — so the
        // scheduler has a finite pre-tuning latency to compute the
        // slice-1 gain against.
        let task = &self.tasks[idx];
        let cfg = crate::baselines::vendor_config(task);
        let r = self.measurer.measure(task, std::slice::from_ref(&cfg));
        let s = match r.first() {
            Some(res) if res.is_ok() && res.gflops > 0.0 => {
                task.def.total_flops() as f64 / (res.gflops * 1e9)
            }
            _ => f64::INFINITY,
        };
        self.baselines[idx] = Some(s);
        s
    }

    fn best_secs(&mut self, idx: usize) -> f64 {
        let gflops = match &self.drivers[idx] {
            Some(Driver::Serial(t)) => t.best().map(|(_, g)| *g),
            Some(Driver::Pipelined(t)) => t.best().map(|(_, g)| *g),
            None => None,
        };
        match gflops {
            Some(g) if g > 0.0 => {
                self.tasks[idx].def.total_flops() as f64 / (g * 1e9)
            }
            _ => f64::INFINITY,
        }
    }

    fn run_slice(&mut self, idx: usize, trials: usize) -> usize {
        self.ensure_driver(idx);
        let measurer = self.measurer;
        match self.drivers[idx].as_mut().expect("driver ensured") {
            Driver::Serial(t) => {
                let before = t.trials();
                t.tune_more(measurer, trials);
                t.trials() - before
            }
            Driver::Pipelined(t) => {
                let before = t.trials();
                t.tune_more(measurer, trials);
                t.trials() - before
            }
        }
    }
}

/// Per-task gain history: weighted latency reduction per trial of the
/// last slice, and of the one before (for the curvature estimate).
#[derive(Clone, Copy, Default)]
struct Gain {
    slices: usize,
    last: f64,
    prev: Option<f64>,
}

impl Gain {
    /// Predicted per-trial gain of the *next* slice: the last observed
    /// gain, decayed by the task's measured curvature (exact for
    /// exponential-decay curves at a fixed slice size).
    ///
    /// The slice-1 gain is measured against the executor's cheap
    /// default-schedule baseline ([`SliceExecutor::baseline_secs`]), so
    /// `prev` is already finite entering the second slice and the decay
    /// activates from slice 2. Executors without a baseline (those
    /// returning `INFINITY`) degrade to the old behavior: slice-1 gain
    /// 0, decay from slice 3.
    fn predicted(self) -> f64 {
        match self.prev {
            None => self.last,
            Some(prev) if prev > 0.0 => self.last * (self.last / prev).clamp(0.0, 1.0),
            Some(_) => self.last,
        }
    }
}

/// The graph-level trial allocator (see the module docs). Holds the
/// static plan — tasks, weights, untunable fixed cost — and drives a
/// [`SliceExecutor`] round by round.
pub struct TaskScheduler {
    plans: Vec<TaskPlan>,
    fixed_secs: f64,
    opts: SchedulerOptions,
}

impl TaskScheduler {
    /// Scheduler over explicit plans plus a fixed (untunable) latency
    /// term.
    pub fn new(plans: Vec<TaskPlan>, fixed_secs: f64, opts: SchedulerOptions) -> Self {
        TaskScheduler { plans, fixed_secs, opts }
    }

    /// Scheduler over a plain task list with unit weights and no fixed
    /// cost (the `tune-all` shape: the "graph" is a sum of operators).
    pub fn for_tasks(tasks: Vec<Task>, opts: SchedulerOptions) -> Self {
        let plans =
            tasks.into_iter().map(|task| TaskPlan { task, weight: 1.0 }).collect();
        TaskScheduler::new(plans, 0.0, opts)
    }

    /// Scheduler for a network graph on a simulated device: tasks and
    /// multiplicities from [`Graph::weighted_tasks`], the fixed glue
    /// cost from [`Graph::fixed_latency`] under default schedules.
    ///
    /// [`Graph::weighted_tasks`]: crate::graph::Graph::weighted_tasks
    /// [`Graph::fixed_latency`]: crate::graph::Graph::fixed_latency
    pub fn from_graph(
        graph: &Graph,
        device: &DeviceModel,
        template: TemplateKind,
        opts: SchedulerOptions,
    ) -> anyhow::Result<Self> {
        let plans = graph
            .weighted_tasks(template)
            .into_iter()
            .map(|(task, mult)| TaskPlan { task, weight: mult as f64 })
            .collect();
        let fixed = graph.fixed_latency(device, template)?;
        Ok(TaskScheduler::new(plans, fixed, opts))
    }

    /// Replace the trial budget (builder-style) — lets callers derive a
    /// per-task default from [`plans`](Self::plans)`.len()` without
    /// rebuilding the plan.
    pub fn with_budget(mut self, budget: usize) -> Self {
        self.opts.budget = budget;
        self
    }

    /// The static plan (tasks + weights), in allocation index order.
    pub fn plans(&self) -> &[TaskPlan] {
        &self.plans
    }

    /// Seconds of untunable glue latency included in
    /// [`Allocation::est_latency`].
    pub fn fixed_secs(&self) -> f64 {
        self.fixed_secs
    }

    /// Pick the task for the next slice, skipping exhausted spaces.
    /// Deterministic: ties break on the lowest index. `None` when every
    /// task is exhausted.
    fn pick(&self, trials: &[usize], gains: &[Gain], exhausted: &[bool]) -> Option<usize> {
        let k = self.plans.len();
        let argmin_trials = |trials: &[usize]| -> Option<usize> {
            let mut best: Option<usize> = None;
            for i in 0..k {
                if exhausted[i] {
                    continue;
                }
                if best.map_or(true, |b| trials[i] < trials[b]) {
                    best = Some(i);
                }
            }
            best
        };
        match self.opts.policy {
            AllocPolicy::Uniform => argmin_trials(trials),
            AllocPolicy::Gradient => {
                // bootstrap: two slices per task before trusting gains,
                // round-robin (everyone gets a first slice before anyone
                // gets a second, so small budgets still cover all tasks)
                let mut boot: Option<usize> = None;
                for i in 0..k {
                    if exhausted[i] || gains[i].slices >= 2 {
                        continue;
                    }
                    if boot.map_or(true, |b: usize| gains[i].slices < gains[b].slices) {
                        boot = Some(i);
                    }
                }
                if boot.is_some() {
                    return boot;
                }
                // ε floor: top up a starved task
                let total: usize = trials.iter().sum();
                if let Some(imin) = argmin_trials(trials) {
                    if (trials[imin] as f64) < self.opts.eps * total as f64 / k as f64 {
                        return Some(imin);
                    }
                }
                // greedy on the predicted next-slice gain (ties break on
                // the first index via strict gt)
                let mut best: Option<(usize, f64)> = None;
                for i in 0..k {
                    if exhausted[i] {
                        continue;
                    }
                    let p = gains[i].predicted();
                    if best.map_or(true, |(_, g)| p > g) {
                        best = Some((i, p));
                    }
                }
                best.map(|(i, _)| i)
            }
        }
    }

    /// Convenience driver over the real tuning loops: builds a
    /// [`LoopExecutor`] for this plan's tasks (streaming into `db`,
    /// with optional pipelined slices and cross-task warm starts) and
    /// runs the allocation. Best configs are served from `db`
    /// afterwards. One entry point shared by `tune-graph`, `tune-all
    /// --alloc gradient` and the fig11 driver.
    pub fn run_tuning(
        &self,
        measurer: &dyn Measurer,
        db: &TuningDb,
        opts: TuneOptions,
        pipelined: bool,
        warm_start: bool,
    ) -> Allocation {
        let tasks: Vec<Task> = self.plans.iter().map(|p| p.task.clone()).collect();
        let mut exec =
            LoopExecutor::new(tasks, measurer, db.clone(), opts, pipelined, warm_start);
        self.run(&mut exec)
    }

    /// Run the allocation loop: spend the whole budget in slices,
    /// returning where it went and the resulting latency estimate.
    pub fn run(&self, exec: &mut dyn SliceExecutor) -> Allocation {
        let k = self.plans.len();
        if k == 0 || self.opts.budget == 0 {
            return Allocation {
                trials: vec![0; k],
                secs: vec![f64::INFINITY; k],
                rounds: 0,
                est_latency: self.fixed_secs,
            };
        }
        // keep the slice small enough for two bootstrap slices per task
        let slice = self.opts.slice.max(1).min((self.opts.budget / (2 * k)).max(1));
        // Pre-tuning baselines: a finite default-schedule latency per
        // task makes the very first slice's gain observable (curvature
        // decay from slice 2; see `Gain::predicted`). Uniform allocation
        // never reads gains, so it must not pay the per-task baseline
        // measurement.
        let mut secs: Vec<f64> = match self.opts.policy {
            AllocPolicy::Gradient => (0..k).map(|i| exec.baseline_secs(i)).collect(),
            AllocPolicy::Uniform => (0..k).map(|i| exec.best_secs(i)).collect(),
        };
        let mut trials = vec![0usize; k];
        let mut gains = vec![Gain::default(); k];
        let mut exhausted = vec![false; k];
        let mut rounds = 0usize;
        let mut remaining = self.opts.budget;
        while remaining > 0 {
            let s = slice.min(remaining);
            let Some(i) = self.pick(&trials, &gains, &exhausted) else {
                break; // every config space is exhausted
            };
            let spent = exec.run_slice(i, s).min(s);
            if spent < s {
                // the space ran dry mid-slice: stop allocating here
                exhausted[i] = true;
            }
            let new = exec.best_secs(i);
            // weighted latency reduction per trial; unknown (±∞) states
            // contribute no gradient and are left to the ε floor
            let delta = if secs[i].is_finite() && new.is_finite() && spent > 0 {
                (secs[i] - new).max(0.0) * self.plans[i].weight / spent as f64
            } else {
                0.0
            };
            gains[i] = Gain { slices: gains[i].slices + 1, last: delta, prev: Some(gains[i].last) };
            if gains[i].slices == 1 {
                gains[i].prev = None;
            }
            secs[i] = new;
            trials[i] += spent;
            // unspent budget stays available for the remaining live
            // tasks; the loop ends when it is gone or everyone is
            // exhausted (at most k zero-spend probe rounds)
            remaining -= spent;
            rounds += 1;
            if self.opts.verbose {
                println!(
                    "# round {rounds:3}: {} +{spent} trials (total {}), {:.3} ms/invocation, \
                     gain {:.3e} s/trial",
                    self.plans[i].task.key(),
                    trials[i],
                    new * 1e3,
                    delta
                );
            }
        }
        let est_latency = self.fixed_secs
            + self
                .plans
                .iter()
                .zip(&secs)
                .map(|(p, s)| p.weight * s)
                .sum::<f64>();
        Allocation { trials, secs, rounds, est_latency }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;

    fn tiny_tasks(n: usize) -> Vec<Task> {
        (0..n)
            .map(|i| {
                Task::new(ops::matmul(32 << i, 32, 32), TemplateKind::Cpu)
            })
            .collect()
    }

    /// Hand-built curves: no hashing, so the test controls the shape.
    fn curves(params: &[(f64, f64, f64)]) -> CurveExecutor {
        CurveExecutor::new(
            params
                .iter()
                .map(|&(floor, span, tau)| TaskCurve { floor, span, tau })
                .collect(),
        )
    }

    #[test]
    fn uniform_is_round_robin() {
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(3),
            SchedulerOptions {
                budget: 96,
                slice: 16,
                policy: AllocPolicy::Uniform,
                ..Default::default()
            },
        );
        let mut exec = curves(&[(1.0, 1.0, 10.0), (2.0, 3.0, 40.0), (0.5, 0.1, 5.0)]);
        let alloc = sched.run(&mut exec);
        assert_eq!(alloc.trials, vec![32, 32, 32]);
        assert_eq!(alloc.rounds, 6);
        assert_eq!(alloc.trials.iter().sum::<usize>(), 96);
    }

    #[test]
    fn gradient_prefers_the_high_gain_task() {
        // task 1 has 30× the tunable headroom of task 0 at the same
        // decay rate — after bootstrap, gradient allocation must send
        // (nearly) all remaining budget its way
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(2),
            SchedulerOptions {
                budget: 160,
                slice: 16,
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        );
        let mut exec = curves(&[(1.0, 0.1, 50.0), (1.0, 3.0, 50.0)]);
        let alloc = sched.run(&mut exec);
        assert!(alloc.trials[1] > alloc.trials[0], "{:?}", alloc.trials);
        // bootstrap gave task 0 its two slices; everything else went to 1
        assert_eq!(alloc.trials[0], 32, "{:?}", alloc.trials);
        assert_eq!(alloc.trials.iter().sum::<usize>(), 160);
    }

    #[test]
    fn weights_redirect_the_budget() {
        // identical curves, but task 0 appears 8× in the graph — its
        // weighted gain dominates
        let plans: Vec<TaskPlan> = tiny_tasks(2)
            .into_iter()
            .enumerate()
            .map(|(i, task)| TaskPlan { task, weight: if i == 0 { 8.0 } else { 1.0 } })
            .collect();
        let sched = TaskScheduler::new(
            plans,
            0.0,
            SchedulerOptions {
                budget: 160,
                slice: 16,
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        );
        let mut exec = curves(&[(1.0, 2.0, 60.0), (1.0, 2.0, 60.0)]);
        let alloc = sched.run(&mut exec);
        assert!(alloc.trials[0] > alloc.trials[1], "{:?}", alloc.trials);
    }

    #[test]
    fn eps_floor_prevents_starvation() {
        // task 0 flatlines immediately (zero span): its gradient is 0
        // after bootstrap, but the ε floor must keep topping it up as
        // the run gets long
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(2),
            SchedulerOptions {
                budget: 50 * 16,
                slice: 16,
                policy: AllocPolicy::Gradient,
                eps: 0.2,
                ..Default::default()
            },
        );
        let mut exec = curves(&[(1.0, 0.0, 50.0), (1.0, 5.0, 100.0)]);
        let alloc = sched.run(&mut exec);
        assert!(alloc.trials[0] > 2 * 16, "floor never triggered: {:?}", alloc.trials);
        // the floor share stays close to ε of the uniform share
        let share = alloc.trials[0] as f64 / (alloc.trials.iter().sum::<usize>() as f64 / 2.0);
        assert!(share < 0.5, "floor overshot: {share}");
    }

    #[test]
    fn small_budgets_shrink_the_slice_for_full_coverage() {
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(4),
            SchedulerOptions {
                budget: 16,
                slice: 64, // nominal slice is bigger than the whole budget
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        );
        let mut exec =
            curves(&[(1.0, 1.0, 10.0), (1.0, 1.0, 10.0), (1.0, 1.0, 10.0), (1.0, 1.0, 10.0)]);
        let alloc = sched.run(&mut exec);
        assert_eq!(alloc.trials.iter().sum::<usize>(), 16);
        assert!(alloc.trials.iter().all(|&n| n > 0), "{:?}", alloc.trials);
    }

    #[test]
    fn bootstrap_round_robin_covers_all_tasks_below_two_slices_each() {
        // budget in [k, 2k): the interleaved bootstrap must still reach
        // every task once before anyone's second slice
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(4),
            SchedulerOptions {
                budget: 5,
                slice: 64,
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        );
        let mut exec =
            curves(&[(1.0, 1.0, 10.0), (1.0, 1.0, 10.0), (1.0, 1.0, 10.0), (1.0, 1.0, 10.0)]);
        let alloc = sched.run(&mut exec);
        assert_eq!(alloc.trials, vec![2, 1, 1, 1]);
    }

    /// Executor whose tasks run out of configs: unspendable budget must
    /// not be charged as phantom trials, and the loop must terminate.
    struct CappedExecutor {
        caps: Vec<usize>,
        spent: Vec<usize>,
    }

    impl SliceExecutor for CappedExecutor {
        fn best_secs(&mut self, idx: usize) -> f64 {
            1.0 / (1.0 + self.spent[idx] as f64)
        }

        fn run_slice(&mut self, idx: usize, trials: usize) -> usize {
            let n = trials.min(self.caps[idx] - self.spent[idx]);
            self.spent[idx] += n;
            n
        }
    }

    #[test]
    fn exhausted_spaces_are_not_charged_phantom_trials() {
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(2),
            SchedulerOptions {
                budget: 320,
                slice: 16,
                policy: AllocPolicy::Gradient,
                ..Default::default()
            },
        );
        // total capacity (40) is far below the budget (320)
        let mut exec = CappedExecutor { caps: vec![24, 16], spent: vec![0, 0] };
        let alloc = sched.run(&mut exec);
        assert_eq!(alloc.trials, vec![24, 16], "trials must reflect real spend");
        assert_eq!(exec.spent, vec![24, 16]);
        // terminated after everyone exhausted, without burning rounds on
        // the full nominal budget
        assert!(alloc.rounds <= 6, "{} rounds", alloc.rounds);
    }

    #[test]
    fn empty_schedule_is_a_noop() {
        let sched = TaskScheduler::for_tasks(vec![], SchedulerOptions::default());
        let mut exec = curves(&[]);
        let alloc = sched.run(&mut exec);
        assert_eq!(alloc.rounds, 0);
        assert!(alloc.trials.is_empty());
        assert_eq!(alloc.est_latency, 0.0);
    }

    #[test]
    fn est_latency_matches_curves() {
        let sched = TaskScheduler::for_tasks(
            tiny_tasks(2),
            SchedulerOptions {
                budget: 64,
                slice: 16,
                policy: AllocPolicy::Uniform,
                ..Default::default()
            },
        );
        let mut exec = curves(&[(1.0, 1.0, 20.0), (2.0, 2.0, 30.0)]);
        let alloc = sched.run(&mut exec);
        let expect: f64 = exec
            .spent()
            .iter()
            .zip(&[(1.0, 1.0, 20.0), (2.0, 2.0, 30.0)])
            .map(|(&n, &(f, s, t))| TaskCurve { floor: f, span: s, tau: t }.secs_after(n))
            .sum();
        assert!((alloc.est_latency - expect).abs() < 1e-12);
    }
}
