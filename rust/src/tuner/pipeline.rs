//! Pipelined asynchronous tuning loop: explore ∥ measure ∥ retrain.
//!
//! Algorithm 1 alternates explore → measure → retrain serially, so the
//! device farm idles while simulated annealing runs and the GBT refits.
//! This module splits the round into three stages on separate threads,
//! connected by bounded channels, so batch `k+1` is being explored
//! while batch `k` measures and the model retrains in the background:
//!
//! ```text
//!            proposals (bounded, cap = depth)
//!   ┌─────────────┐ ──────────────────────────▶ ┌──────────────┐
//!   │  PROPOSAL    │                            │ MEASUREMENT   │
//!   │ ParallelSa + │                            │ caller thread │
//!   │ diversity +  │                            │ (owns the     │
//!   │ ε-random     │                            │  Measurer /   │
//!   └─────────────┘ ◀────────────────────────── │  DeviceFarm)  │
//!          ▲     model snapshots (epoch-tagged) └──────────────┘
//!          │                                            │
//!          │        ┌─────────────┐   measured batches  │
//!          └─────── │ MODEL STAGE  │ ◀──────────────────┘
//!                   │ GBT refit on │    (entities + labels)
//!                   │ all of D     │
//!                   └─────────────┘
//! ```
//!
//! * The **proposal stage** owns the persistent SA chains, the proposal
//!   RNG stream and its own feature cache ([`super::BatchProposer`]);
//!   it scores candidates against the latest *required* model snapshot.
//! * The **measurement stage** runs on the calling thread — the
//!   [`Measurer`] never crosses a thread boundary, so thread-affine
//!   back-ends (PJRT) and the non-`Sync` trait contract are honored.
//!   Batches are handed to the back-end through the asynchronous
//!   [`Measurer::submit`]/[`Measurer::wait`] pair: against a
//!   [`MeasureService`](crate::measure::service::MeasureService) the
//!   batch is sharded across the farm's replica workers and the *next*
//!   batch is already measuring while this one's results are absorbed;
//!   against a plain measurer the default implementation degenerates to
//!   the old synchronous call.
//! * The **model stage** owns the cost model, accumulates every
//!   measured [`TrialRecord`](super::TrialRecord)'s features and label,
//!   refits after each batch (on all of `D`, like the paper) and
//!   publishes an epoch-tagged snapshot ([`CostModel::snapshot`]).
//!
//! ## Determinism
//!
//! A fixed seed reproduces a pipelined run bit-for-bit, even though the
//! stages race in wall-clock time: batch `k` is always proposed from
//! the snapshot of epoch `max(0, k − (depth − 1))` — the proposal stage
//! *waits* for exactly that epoch rather than using "latest available",
//! so thread scheduling never leaks into candidate selection. The
//! schedule differs from the serial loop only in model staleness
//! (`depth − 1` batches); `depth = 1` reproduces the serial loop
//! exactly.
//!
//! The same discipline bounds backpressure: proposals can never outrun
//! measurement by more than `depth` batches (enforced structurally by
//! the epoch wait, and by the bounded proposal channel).
//!
//! The serial loop ([`super::Tuner`]) is kept for reference experiments
//! and for models whose [`CostModel::snapshot`] returns `None`.
//!
//! ## Live DB streaming
//!
//! With [`TuneOptions::sink`](super::TuneOptions::sink) set, the
//! measurement stage appends every measured trial to the shared
//! [`TuningDb`](super::db::TuningDb) as it is absorbed — the service
//! behavior: concurrent readers (graph-compiler `best_config` lookups,
//! a coordinator warm-starting the next task) observe records while the
//! run is still in flight. Streaming is a pure side effect and does not
//! perturb the determinism contract above.

use super::{
    serial_steps, slice_step, BatchProposer, Featurizer, LoopState, SliceRun, SliceStep,
    TuneOptions, TuneResult, FEAT_CACHE_CAP,
};
use crate::measure::Measurer;
use crate::model::CostModel;
use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// Live counters of one pipelined run (all monotone).
#[derive(Debug, Default)]
pub struct PipelineStats {
    proposed: AtomicUsize,
    measured: AtomicUsize,
    fitted: AtomicUsize,
    max_lead: AtomicUsize,
}

impl PipelineStats {
    /// Batches emitted by the proposal stage.
    pub fn proposed_batches(&self) -> usize {
        self.proposed.load(Ordering::SeqCst)
    }

    /// Batches measured and accounted.
    pub fn measured_batches(&self) -> usize {
        self.measured.load(Ordering::SeqCst)
    }

    /// Model refit epochs completed.
    pub fn fitted_epochs(&self) -> usize {
        self.fitted.load(Ordering::SeqCst)
    }

    /// Maximum observed lead of the proposal stage over the measurement
    /// stage, in batches. Structurally ≤ `pipeline_depth`.
    pub fn max_lead(&self) -> usize {
        self.max_lead.load(Ordering::SeqCst)
    }

    fn record_propose(&self) {
        let p = self.proposed.fetch_add(1, Ordering::SeqCst) + 1;
        let m = self.measured.load(Ordering::SeqCst);
        self.max_lead.fetch_max(p.saturating_sub(m), Ordering::SeqCst);
    }

    fn reset(&self) {
        self.proposed.store(0, Ordering::SeqCst);
        self.measured.store(0, Ordering::SeqCst);
        self.fitted.store(0, Ordering::SeqCst);
        self.max_lead.store(0, Ordering::SeqCst);
    }
}

/// One epoch-tagged model snapshot flowing model stage → proposal stage.
struct ModelUpdate {
    /// Number of measured batches the model has been fitted on.
    epoch: usize,
    /// Best GFLOPS among those batches (for UCB/EI acquisition).
    best_y: f64,
    model: Box<dyn CostModel + Send>,
}

/// The pipelined production driver. Construction requires a `Send`
/// model; models without snapshot support transparently fall back to
/// the serial schedule inside [`PipelinedTuner::tune`].
///
/// Like the serial [`Tuner`](super::Tuner), the pipelined driver is
/// *incremental*: its SA chains, dedup set, model and training set
/// persist across calls, so the budget can be spent in slices via
/// [`tune_more`](Self::tune_more) (the graph-level
/// [`scheduler`](super::scheduler) contract). Slice boundaries are full
/// barriers — a run spent as two slices refits on all of `D` but is not
/// bit-identical to one unsliced run, because the model staleness
/// window restarts at each boundary.
pub struct PipelinedTuner {
    /// The task being tuned.
    pub task: Task,
    /// Loop configuration (batch size, depth, seed, sink, …).
    pub options: TuneOptions,
    model: Option<Box<dyn CostModel + Send>>,
    /// Whether the model supports [`CostModel::snapshot`] (probed once
    /// at construction — snapshot support is a property of the model
    /// type, and probing clones the model).
    snapshottable: bool,
    proposer: BatchProposer,
    state: LoopState,
    /// Fit-stage feature memo, persisted across slices so a new slice
    /// doesn't re-featurize the whole accumulated training set.
    fit_feat: Option<Featurizer>,
    stats: Arc<PipelineStats>,
}

impl PipelinedTuner {
    /// Build a pipelined tuner from a task, a `Send` cost model and
    /// loop options.
    pub fn new(task: Task, model: Box<dyn CostModel + Send>, options: TuneOptions) -> Self {
        let proposer = BatchProposer::new(&options);
        let state = LoopState::new(options.sink.clone());
        let snapshottable = model.snapshot().is_some();
        PipelinedTuner {
            task,
            options,
            model: Some(model),
            snapshottable,
            proposer,
            state,
            fit_feat: None,
            stats: Arc::new(PipelineStats::default()),
        }
    }

    /// Counters of the most recent [`tune`](Self::tune) /
    /// [`tune_more`](Self::tune_more) call (reset at each call).
    pub fn stats(&self) -> Arc<PipelineStats> {
        self.stats.clone()
    }

    /// Run the pipelined loop against a measurement back-end until the
    /// configured `n_trials` total trials have been measured. The
    /// back-end stays on the calling thread for its whole lifetime.
    pub fn tune(&mut self, measurer: &dyn Measurer) -> TuneResult {
        let extra = self.options.n_trials.saturating_sub(self.state.acct.trials);
        self.tune_more(measurer, extra);
        self.state.acct.result_snapshot()
    }

    /// Trials measured so far (across all slices).
    pub fn trials(&self) -> usize {
        self.state.acct.trials
    }

    /// Best measured (config, GFLOPS) so far, if any trial succeeded.
    pub fn best(&self) -> Option<&(ConfigEntity, f64)> {
        self.state.acct.best.as_ref()
    }

    /// Snapshot of the accounting so far (curve, records, best).
    pub fn result(&self) -> TuneResult {
        self.state.acct.result_snapshot()
    }

    /// Begin a *pollable* slice of `extra` trials: the cooperative
    /// counterpart of [`tune_more`](Self::tune_more). Advanced one
    /// batch at a time with [`step_slice`](Self::step_slice), the slice
    /// keeps up to `pipeline_depth` measurement batches in flight
    /// through the asynchronous [`Measurer::submit`]/[`Measurer::wait`]
    /// pair, honoring the threaded loop's epoch discipline exactly —
    /// batch `k` is proposed from the model state of epoch
    /// `max(0, k − (depth − 1))`, so a polled slice reproduces a joined
    /// `tune_more` bit-for-bit under a fixed seed. Models without
    /// snapshot support run the slice at depth 1 (the serial schedule),
    /// mirroring the threaded fallback.
    pub fn begin_slice(&mut self, extra: usize) -> SliceRun {
        let depth = if self.snapshottable { self.options.pipeline_depth.max(1) } else { 1 };
        // The fit-stage featurizer persists across slices, exactly as
        // in the threaded driver.
        let fresh = match &self.fit_feat {
            Some(f)
                if f.repr == self.options.repr
                    && f.is_fast() == self.options.fast_paths =>
            {
                None
            }
            _ => Some(Featurizer::with_capacity(
                self.options.repr,
                self.options.fast_paths,
                self.options.feat_cache_cap.unwrap_or(FEAT_CACHE_CAP),
            )),
        };
        if let Some(f) = fresh {
            self.fit_feat = Some(f);
        }
        let at = self.state.acct.trials;
        SliceRun {
            target: at + extra,
            depth,
            proposed: at,
            inflight: std::collections::VecDeque::new(),
            exhausted: false,
        }
    }

    /// Advance a slice from [`begin_slice`](Self::begin_slice) by one
    /// unit of work. Only one slice may be in flight per tuner at a
    /// time; interleave slices of *different* tuners.
    pub fn step_slice(&mut self, measurer: &dyn Measurer, run: &mut SliceRun) -> SliceStep {
        let opts = self.options.clone();
        let model = self.model.as_mut().expect("model present");
        slice_step(
            &self.task,
            &opts,
            &mut self.proposer,
            model.as_mut(),
            self.fit_feat.as_ref(),
            measurer,
            &mut self.state,
            run,
        )
    }

    /// Spend `extra` more measurement trials through the three-stage
    /// pipeline, continuing the persistent loop (no re-proposals; the
    /// first refit of the slice trains on all of `D` accumulated so
    /// far). Returns the best GFLOPS so far.
    pub fn tune_more(&mut self, measurer: &dyn Measurer, extra: usize) -> f64 {
        let opts = self.options.clone();
        let depth = opts.pipeline_depth.max(1);
        // Reset the counters in place so Arcs handed out before this
        // run (via `stats()`) observe it live.
        let stats = self.stats.clone();
        stats.reset();

        // Fixed batch plan: sizes of every measurement batch up front,
        // so all three stages agree on the schedule without negotiation.
        let mut sizes: Vec<usize> = Vec::new();
        let mut planned = 0usize;
        while planned < extra && opts.batch > 0 {
            let b = opts.batch.min(extra - planned);
            sizes.push(b);
            planned += b;
        }
        let n_batches = sizes.len();

        let mut model = self.model.take().expect("model present");
        if n_batches == 0 {
            self.model = Some(model);
            return self.state.acct.best_gflops();
        }
        // The first snapshot doubles as the epoch-0 model update (an
        // unfitted model ⇒ random bootstrap batches; a transfer model or
        // a model fitted in an earlier slice ⇒ warm-started SA from the
        // very first batch).
        let Some(epoch0) = model.snapshot() else {
            // Non-cloneable model: serial reference schedule in place.
            let target = self.state.acct.trials + extra;
            serial_steps(
                &self.task,
                &opts,
                &mut self.proposer,
                model.as_mut(),
                measurer,
                &mut self.state,
                target,
            );
            self.model = Some(model);
            return self.state.acct.best_gflops();
        };

        let proposer = &mut self.proposer;
        // Fit-stage featurizer persists across slices (recreated only if
        // the representation changed between calls).
        let fit_feat = match self.fit_feat.take() {
            Some(f) if f.repr == opts.repr && f.is_fast() == opts.fast_paths => f,
            _ => Featurizer::with_capacity(
                opts.repr,
                opts.fast_paths,
                opts.feat_cache_cap.unwrap_or(FEAT_CACHE_CAP),
            ),
        };
        let state = &mut self.state;
        // The persistent training set moves into the model stage for
        // this slice and is restored after the scope.
        let xs0 = std::mem::take(&mut state.xs);
        let ys0 = std::mem::take(&mut state.ys);
        let groups0 = std::mem::take(&mut state.groups);
        let acct = &mut state.acct;
        let best_y0 = acct.best_gflops();
        let task = self.task.clone();

        // proposal stage → measurement stage (bounded: backpressure)
        let (prop_tx, prop_rx) = mpsc::sync_channel::<Vec<ConfigEntity>>(depth);
        // measurement stage → model stage (entities + labels)
        let (train_tx, train_rx) = mpsc::channel::<(Vec<ConfigEntity>, Vec<f64>)>();
        // model stage → proposal stage (epoch-tagged snapshots)
        let (snap_tx, snap_rx) = mpsc::channel::<ModelUpdate>();

        let (model_back, xs_back, ys_back, groups_back, feat_back) = std::thread::scope(|s| {
            // ---- proposal stage ----
            let explore_task = task.clone();
            let explore_opts = opts.clone();
            let explore_sizes = sizes.clone();
            let explore_stats = stats.clone();
            s.spawn(move || {
                let mut cur: Option<ModelUpdate> = None;
                for (k, &b) in explore_sizes.iter().enumerate() {
                    // Deterministic model choice: wait for exactly the
                    // required epoch (snapshots arrive in epoch order).
                    let need = k.saturating_sub(depth - 1);
                    while cur.as_ref().map_or(true, |u| u.epoch < need) {
                        match snap_rx.recv() {
                            Ok(u) => cur = Some(u),
                            Err(_) => return, // run aborted downstream
                        }
                    }
                    let u = cur.as_ref().expect("snapshot for required epoch");
                    let batch = proposer.propose(
                        &explore_task,
                        &explore_opts,
                        &*u.model,
                        b,
                        u.best_y,
                    );
                    // Empty batch (space exhausted) is forwarded so the
                    // measurement stage can terminate the run cleanly.
                    let stop = batch.is_empty();
                    if prop_tx.send(batch).is_err() {
                        return;
                    }
                    explore_stats.record_propose();
                    if stop {
                        return;
                    }
                }
            });

            // ---- model stage ----
            let fit_task = task.clone();
            let fit_stats = stats.clone();
            let fit_handle = s.spawn(move || {
                let feat = fit_feat;
                let mut best_y = best_y0;
                let _ = snap_tx.send(ModelUpdate { epoch: 0, best_y, model: epoch0 });
                // training set carried over from earlier slices
                let mut xs: Vec<ConfigEntity> = xs0;
                let mut ys: Vec<f64> = ys0;
                let mut groups: Vec<usize> = groups0;
                let mut epoch = 0usize;
                while let Ok((batch, labels)) = train_rx.recv() {
                    for &gf in &labels {
                        if gf > best_y {
                            best_y = gf;
                        }
                    }
                    groups.push(batch.len());
                    xs.extend(batch);
                    ys.extend(labels);
                    // refit f̂ on all of D, then publish the new epoch
                    let x = feat.features(&fit_task, &xs);
                    model.fit(&x, &ys, &groups);
                    epoch += 1;
                    fit_stats.fitted.fetch_add(1, Ordering::SeqCst);
                    if let Some(snap) = model.snapshot() {
                        let _ = snap_tx.send(ModelUpdate { epoch, best_y, model: snap });
                    }
                }
                (model, xs, ys, groups, feat)
            });

            // ---- measurement stage (this thread owns the measurer) ----
            // The persistent accountant streams each measured batch
            // straight into the shared TuningDb (if a sink is
            // configured), so DB readers on other threads see records
            // live instead of a bulk dump when the run ends.
            //
            // Batches go to the back-end through the submit/wait pair:
            // against an asynchronous MeasureService, batch `k+1` is
            // already measuring on the device farm while batch `k`'s
            // results are absorbed here; against a plain measurer the
            // default submit measures synchronously and nothing changes.
            // Submission order equals batch order either way, so the
            // result stream — and every fixed-seed run — is identical
            // whichever timing the farm exhibits. In-flight submissions
            // are bounded by `depth`, and the stage never blocks on the
            // proposal channel while a ticket is outstanding (labels
            // the proposer's epoch wait needs are always absorbed
            // first), so no stage can deadlock another.
            let mut inflight: std::collections::VecDeque<(
                Vec<ConfigEntity>,
                crate::measure::BatchTicket,
            )> = std::collections::VecDeque::new();
            let mut received = 0usize;
            let mut proposals_done = false;
            'measure: loop {
                // Top up the farm: take whatever the proposal stage has
                // ready (blocking only when nothing is measuring).
                while !proposals_done && received < n_batches && inflight.len() < depth {
                    let next = if inflight.is_empty() {
                        prop_rx.recv().map_err(|_| ())
                    } else {
                        match prop_rx.try_recv() {
                            Ok(b) => Ok(b),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => Err(()),
                        }
                    };
                    match next {
                        Ok(batch) => {
                            received += 1;
                            if batch.is_empty() {
                                proposals_done = true; // space exhausted upstream
                            } else {
                                let ticket = measurer.submit(&task, &batch);
                                inflight.push_back((batch, ticket));
                            }
                        }
                        Err(()) => proposals_done = true,
                    }
                }
                // Absorb the oldest in-flight batch; results reach the
                // accountant in submission order regardless of how the
                // farm interleaved the work.
                let Some((batch, ticket)) = inflight.pop_front() else {
                    break 'measure;
                };
                let results = measurer.wait(ticket);
                let labels = acct.absorb(&batch, &results);
                stats.measured.fetch_add(1, Ordering::SeqCst);
                if opts.verbose {
                    println!(
                        "[{}|pipeline d={depth}] trials={:4} best={:.1} GFLOPS",
                        measurer.target(),
                        acct.trials,
                        acct.best_gflops()
                    );
                }
                if train_tx.send((batch, labels)).is_err() {
                    break 'measure;
                }
            }
            // Unblock any stage still waiting, then drain the model
            // stage — every measured TrialRecord is already in `acct`,
            // so nothing is lost regardless of shutdown order.
            drop(prop_rx);
            drop(train_tx);
            fit_handle.join().expect("model stage panicked")
        });

        state.xs = xs_back;
        state.ys = ys_back;
        state.groups = groups_back;
        self.fit_feat = Some(feat_back);
        self.model = Some(model_back);
        self.state.acct.best_gflops()
    }
}
