//! Tuning database `D = {(e_i, s_i, c_i)}` (§3): persistent JSONL log of
//! every measured trial, queryable per task — the source of `D'` for
//! transfer learning (§4) and of best-config lookups for the graph
//! compiler.

use crate::features::Representation;
use crate::gbt::Matrix;
use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use crate::tuner::TrialRecord;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::Path;

/// One persisted measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    pub task_key: String,
    pub target: String,
    pub choices: Vec<u32>,
    pub gflops: f64,
    pub seconds: f64,
    pub error: Option<String>,
}

impl Record {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("task", Json::from(self.task_key.clone())),
            ("target", Json::from(self.target.clone())),
            (
                "choices",
                Json::Arr(self.choices.iter().map(|&c| Json::from(c as u64)).collect()),
            ),
            ("gflops", Json::from(self.gflops)),
            ("seconds", Json::from(self.seconds)),
        ];
        if let Some(e) = &self.error {
            fields.push(("error", Json::from(e.clone())));
        }
        Json::obj(fields)
    }

    fn from_json(j: &Json) -> anyhow::Result<Record> {
        let get_str = |k: &str| -> anyhow::Result<String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("record missing {k}"))?
                .to_string())
        };
        let choices = j
            .get("choices")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("record missing choices"))?
            .iter()
            .map(|v| v.as_u64().unwrap_or(0) as u32)
            .collect();
        Ok(Record {
            task_key: get_str("task")?,
            target: get_str("target")?,
            choices,
            gflops: j.get("gflops").and_then(Json::as_f64).unwrap_or(0.0),
            seconds: j.get("seconds").and_then(Json::as_f64).unwrap_or(0.0),
            error: j.get("error").and_then(Json::as_str).map(String::from),
        })
    }
}

/// The tuning log.
#[derive(Clone, Debug, Default)]
pub struct Database {
    pub records: Vec<Record>,
}

impl Database {
    pub fn new() -> Self {
        Database::default()
    }

    /// Append the trials of one tuning run.
    pub fn add_run(&mut self, task: &Task, target: &str, records: &[TrialRecord]) {
        for r in records {
            self.records.push(Record {
                task_key: task.key(),
                target: target.to_string(),
                choices: r.entity.choices.clone(),
                gflops: r.gflops,
                seconds: r.seconds.unwrap_or(0.0),
                error: r.error.clone(),
            });
        }
    }

    /// Persist as JSONL.
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut out = String::new();
        for r in &self.records {
            out.push_str(&r.to_json().dump());
            out.push('\n');
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Database> {
        let text = std::fs::read_to_string(path)?;
        let mut records = Vec::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            records.push(Record::from_json(&Json::parse(line)?)?);
        }
        Ok(Database { records })
    }

    /// Records belonging to one task+target.
    pub fn for_task(&self, task_key: &str, target: &str) -> Vec<&Record> {
        self.records
            .iter()
            .filter(|r| r.task_key == task_key && r.target == target)
            .collect()
    }

    /// Best valid config per task (for the graph compiler).
    pub fn best_config(&self, task_key: &str, target: &str) -> Option<(ConfigEntity, f64)> {
        self.for_task(task_key, target)
            .into_iter()
            .filter(|r| r.error.is_none())
            .max_by(|a, b| a.gflops.partial_cmp(&b.gflops).unwrap())
            .map(|r| (ConfigEntity { choices: r.choices.clone() }, r.gflops))
    }

    /// Build a training set from source-domain records under an
    /// invariant representation — the `D'` featurization for the global
    /// model of Eq. 4. Tasks must be supplied so configs can be
    /// re-lowered; records for unknown tasks are skipped. Returns
    /// (features, labels-normalized-per-task, group sizes per task).
    ///
    /// Labels are normalized to relative throughput within each task
    /// (gflops / task max) so the global model learns *shape*, not
    /// absolute workload scale — with the rank objective only per-task
    /// order matters and tasks are separate rank groups.
    pub fn to_training(
        &self,
        tasks: &[&Task],
        target: &str,
        repr: Representation,
        limit_per_task: usize,
    ) -> (Matrix, Vec<f64>, Vec<usize>) {
        let by_key: HashMap<String, &Task> =
            tasks.iter().map(|t| (t.key(), *t)).collect();
        let mut rows: Vec<Vec<f64>> = Vec::new();
        let mut ys = Vec::new();
        let mut groups = Vec::new();
        for (key, task) in &by_key {
            let recs: Vec<&Record> = self
                .for_task(key, target)
                .into_iter()
                .take(limit_per_task)
                .collect();
            if recs.is_empty() {
                continue;
            }
            let max_g =
                recs.iter().map(|r| r.gflops).fold(f64::MIN_POSITIVE, f64::max);
            let entities: Vec<ConfigEntity> =
                recs.iter().map(|r| ConfigEntity { choices: r.choices.clone() }).collect();
            let feats = crate::util::parallel_map(
                &entities,
                crate::util::default_threads(),
                |e| {
                    let analysis =
                        crate::ast::analysis::analyze(&task.lower(e).expect("db config lowers"));
                    crate::features::extract(repr, task, e, &analysis)
                },
            );
            for (f, r) in feats.into_iter().zip(&recs) {
                rows.push(f);
                ys.push(r.gflops / max_g);
            }
            groups.push(recs.len());
        }
        (Matrix::from_rows(&rows), ys, groups)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::measure::{Measurer, SimMeasurer};
    use crate::schedule::template::TemplateKind;
    use crate::sim::devices::sim_cpu;
    use crate::util::Rng;

    fn sample_records(task: &Task, n: usize) -> Vec<TrialRecord> {
        let m = SimMeasurer::with_seed(sim_cpu(), 1);
        let mut rng = Rng::seed_from_u64(2);
        let batch: Vec<ConfigEntity> =
            (0..n).map(|_| task.space.sample(&mut rng)).collect();
        let res = m.measure(task, &batch);
        batch
            .into_iter()
            .zip(res)
            .map(|(e, r)| TrialRecord {
                entity: e,
                gflops: r.gflops,
                seconds: r.seconds,
                error: r.error,
            })
            .collect()
    }

    #[test]
    fn save_load_roundtrip() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let mut db = Database::new();
        db.add_run(&task, "sim-cpu", &sample_records(&task, 20));
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.jsonl");
        db.save(&path).unwrap();
        let back = Database::load(&path).unwrap();
        assert_eq!(db.records, back.records);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn best_config_skips_errors() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let mut db = Database::new();
        let mut recs = sample_records(&task, 10);
        // poison: an error record with absurd gflops must not win
        recs.push(TrialRecord {
            entity: task.space.entity(0),
            gflops: 1e12,
            seconds: None,
            error: Some("boom".into()),
        });
        db.add_run(&task, "sim-cpu", &recs);
        let (_, g) = db.best_config(&task.key(), "sim-cpu").unwrap();
        assert!(g < 1e12);
    }

    #[test]
    fn to_training_builds_invariant_features() {
        let t1 = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let t2 = Task::new(
            ops::conv2d(ops::Conv2dParams {
                n: 1, h: 14, w: 14, ic: 16, oc: 16, kh: 3, kw: 3, stride: 1, pad: 1,
            }),
            TemplateKind::Cpu,
        );
        let mut db = Database::new();
        db.add_run(&t1, "sim-cpu", &sample_records(&t1, 12));
        db.add_run(&t2, "sim-cpu", &sample_records(&t2, 12));
        let (x, y, groups) = db.to_training(
            &[&t1, &t2],
            "sim-cpu",
            Representation::ContextRelation,
            100,
        );
        assert_eq!(x.rows, 24);
        assert_eq!(x.cols, Representation::ContextRelation.dim());
        assert_eq!(groups, vec![12, 12]);
        // labels normalized per task
        assert!(y.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
