//! TuningDb — the indexed, concurrent tuning-record service layer.
//!
//! The paper's headline transfer speedup (§4, Eq. 4) comes from reusing
//! the tuning log `D = {(e_i, s_i, c_i)}` across workloads, and the
//! graph compiler serves `argmax D` per task on its hot path. This
//! module is the record store behind both:
//!
//! * **Sharded index** — records live in per-`(task_key, target)`
//!   shards behind `N_SHARDS` bucket locks, so concurrent writers
//!   (the pipelined tuner's measurement stage) and readers (the graph
//!   compiler, warm-start queries) contend only when they touch the
//!   same bucket.
//! * **Incremental best / top-k** — every shard maintains its best
//!   valid record and a descending top-[`TOP_K`] list as records
//!   arrive, so [`TuningDb::best_config`] and [`TuningDb::top_k`] are
//!   O(1)/O(k) lookups, never scans ([`TuningDb::best_config_scan`] is
//!   the linear reference kept for tests and the `bench_db` baseline).
//!   Ordering uses `f64::total_cmp`; records with NaN/non-finite
//!   GFLOPS or an error are stored but never indexed as best.
//! * **Append-only WAL** — a file-backed DB ([`TuningDb::open`])
//!   appends one JSONL line per record as it is measured, so a crash
//!   loses at most the line being written; `open` tolerates (and
//!   drops) a torn trailing line, while any other malformed record is
//!   a hard parse error ([`Record::from_json`] is strict).
//! * **Compaction + snapshotting** — [`TuningDb::compact`] folds the
//!   grown WAL into a snapshot file (`<wal>.snap`) holding only the
//!   records a [`RetentionPolicy`] retains (per-shard best top-k plus
//!   the newest-N tail), then rename-swaps a fresh, marker-led WAL
//!   tail into place. `open` loads snapshot-then-tail, so startup cost
//!   is bounded by the retention policy instead of the full append
//!   history, and every crash window recovers to a consistent state
//!   (the protocol is documented on [`TuningDb::compact`]). A long
//!   tuning run can arm the same fold automatically:
//!   [`TuningDb::set_auto_compact_bytes`] makes any append that sees
//!   the WAL tail past a byte threshold trigger a keep-all compaction
//!   in place (`--auto-compact-bytes` on the CLI).
//! * **Per-task feature cache** — [`TuningDb::to_training`] memoizes
//!   lowered+extracted feature rows per `(shard, representation)`, so
//!   building `D'` for a transfer model re-featurizes only records it
//!   has never seen, instead of re-lowering the whole log every call.
//! * **Canonical target keys** — record targets and lookup targets are
//!   both normalized through [`canonical_target`] at the DB boundary:
//!   farm-topology / fault-injection wrappers (`farm(4xsim-gpu)`,
//!   `flaky(sim-gpu)`) collapse to the board name, so records stamped
//!   by a wrapped measurer are never silently invisible to warm-start
//!   and serving lookups keyed by device.
//! * **Thread-safe handle** — [`TuningDb`] is a cheap `Arc` clone
//!   (`Send + Sync`); the tuner streams records in live through
//!   [`crate::tuner::DbSink`] while other threads query.
//!
//! Training sets are deterministic: tasks are visited in sorted-key
//! order, records in insertion order, and errored / non-finite /
//! unlowerable records are excluded from `D'`.

use crate::features::Representation;
use crate::gbt::Matrix;
use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use crate::tuner::TrialRecord;
use crate::util::json::Json;
use anyhow::Context as _;
use std::collections::hash_map::DefaultHasher;
use std::collections::{BTreeSet, HashMap};
use std::fs::{File, OpenOptions};
use std::hash::{Hash, Hasher};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Cap on the incrementally maintained per-task top-k index.
pub const TOP_K: usize = 16;

/// Lock buckets for the shard map.
const N_SHARDS: usize = 16;

/// One persisted measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    /// Task identity ([`Task::key`]).
    pub task_key: String,
    /// Target (device) the trial ran on.
    pub target: String,
    /// The measured config's knob choices.
    pub choices: Vec<u32>,
    /// Measured throughput (0.0 / non-finite for failed trials).
    pub gflops: f64,
    /// Measured wall-clock seconds (0.0 when unknown).
    pub seconds: f64,
    /// Failure reason, if the trial errored.
    pub error: Option<String>,
}

impl Record {
    /// Valid for serving / training: finished without error and with a
    /// finite throughput (a NaN gflops must never win `best_config`).
    fn is_valid(&self) -> bool {
        self.error.is_none() && self.gflops.is_finite()
    }

    fn to_json(&self) -> Json {
        // Non-finite floats have no JSON representation (`{x}` would
        // emit `NaN`, which the parser rejects) — serialize them as
        // null so a NaN record round-trips as an invalid-but-parseable
        // record instead of poisoning the WAL.
        let num_or_null = |x: f64| if x.is_finite() { Json::from(x) } else { Json::Null };
        let mut fields = vec![
            ("task", Json::from(self.task_key.clone())),
            ("target", Json::from(self.target.clone())),
            (
                "choices",
                Json::Arr(self.choices.iter().map(|&c| Json::from(c as u64)).collect()),
            ),
            ("gflops", num_or_null(self.gflops)),
            ("seconds", num_or_null(self.seconds)),
        ];
        if let Some(e) = &self.error {
            fields.push(("error", Json::from(e.clone())));
        }
        Json::obj(fields)
    }

    /// Strict parse: missing fields and malformed `choices` entries are
    /// errors, not silently-coerced zeros (a corrupt config replayed as
    /// `choices = [0, …]` would poison `D'` and the serving path).
    fn from_json(j: &Json) -> anyhow::Result<Record> {
        let get_str = |k: &str| -> anyhow::Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(String::from)
                .ok_or_else(|| anyhow::anyhow!("record missing {k}"))
        };
        let arr = j
            .get("choices")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("record missing choices"))?;
        let mut choices = Vec::with_capacity(arr.len());
        for v in arr {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric choices entry {}", v.dump()))?;
            anyhow::ensure!(
                x.fract() == 0.0 && x >= 0.0 && x <= u32::MAX as f64,
                "choices entry {x} is not a u32"
            );
            choices.push(x as u32);
        }
        let gflops = match j.get("gflops") {
            Some(Json::Null) => f64::NAN, // serialized non-finite value
            Some(v) => v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("record gflops is not a number"))?,
            None => anyhow::bail!("record missing gflops"),
        };
        let seconds = match j.get("seconds") {
            Some(Json::Null) => f64::NAN, // serialized non-finite value
            Some(v) => v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("record seconds is not a number"))?,
            None => 0.0,
        };
        Ok(Record {
            task_key: get_str("task")?,
            target: get_str("target")?,
            choices,
            gflops,
            seconds,
            error: j.get("error").and_then(Json::as_str).map(String::from),
        })
    }
}

/// Per-representation memo of feature rows: record index → extracted
/// row (`None` = the stored config does not lower under this task, e.g.
/// a foreign record; such rows are skipped when building `D'`).
type FeatureCache = HashMap<Representation, HashMap<usize, Option<Vec<f64>>>>;

/// What [`TuningDb::compact`] keeps per `(task_key, target)` shard;
/// everything else is evicted from the index and the snapshot.
#[derive(Clone, Copy, Debug)]
pub struct RetentionPolicy {
    /// Best valid records to keep (capped at [`TOP_K`] — the index
    /// never tracks more than that many ranked records).
    pub top_k: usize,
    /// Newest records to keep regardless of quality (the tail a refit
    /// still learns from). `usize::MAX` keeps everything.
    pub newest: usize,
}

impl Default for RetentionPolicy {
    fn default() -> Self {
        RetentionPolicy::keep_all()
    }
}

impl RetentionPolicy {
    /// Keep every record: compaction only folds the WAL into a
    /// snapshot, evicting nothing.
    pub fn keep_all() -> Self {
        RetentionPolicy { top_k: TOP_K, newest: usize::MAX }
    }

    /// Keep the best [`TOP_K`] plus the newest `n` records per shard
    /// (the `--retain-per-task n` serving knob).
    pub fn newest(n: usize) -> Self {
        RetentionPolicy { top_k: TOP_K, newest: n }
    }
}

/// Outcome of one [`TuningDb::compact`] call.
#[derive(Clone, Copy, Debug)]
pub struct CompactStats {
    /// Snapshot generation this compaction produced (monotonic, ≥ 1).
    pub gen: u64,
    /// Records retained (the DB's new `len`).
    pub kept: usize,
    /// Records evicted by the retention policy.
    pub dropped: usize,
    /// Size of the written snapshot file in bytes.
    pub snapshot_bytes: u64,
}

/// All records of one `(task_key, target)` pair plus its incremental
/// serving indexes and feature cache.
#[derive(Default)]
struct TaskShard {
    records: Vec<Record>,
    /// `(record index, gflops)` of the best valid record — O(1) serving.
    best: Option<(usize, f64)>,
    /// Valid records by descending gflops (ties: earliest first), at
    /// most [`TOP_K`] entries.
    top_k: Vec<(usize, f64)>,
    feat_cache: FeatureCache,
    /// Bumped whenever records are renumbered (compaction eviction), so
    /// phase-split readers like `to_training` can detect that indices
    /// captured under an earlier lock are stale.
    epoch: u64,
}

impl TaskShard {
    /// Apply a retention policy: keep the union of the best
    /// `policy.top_k` valid records and the newest `policy.newest`
    /// records, renumbering the survivors in their original order (and
    /// remapping the feature cache with them). Returns how many records
    /// were dropped.
    fn retain(&mut self, policy: &RetentionPolicy) -> usize {
        let n = self.records.len();
        let mut keep: BTreeSet<usize> =
            self.top_k.iter().take(policy.top_k).map(|&(i, _)| i).collect();
        keep.extend(n.saturating_sub(policy.newest)..n);
        if keep.len() == n {
            return 0;
        }
        let dropped = n - keep.len();
        let old_records = std::mem::take(&mut self.records);
        let old_cache = std::mem::take(&mut self.feat_cache);
        self.best = None;
        self.top_k.clear();
        // old index → new index, in ascending (insertion) order
        let mut new_idx: HashMap<usize, usize> = HashMap::with_capacity(keep.len());
        for (new, &old) in keep.iter().enumerate() {
            new_idx.insert(old, new);
        }
        let mut it = old_records.into_iter().enumerate();
        for &old in &keep {
            // advance to record `old` (enumerate preserves positions)
            let rec = loop {
                let (i, r) = it.next().expect("keep index within records");
                if i == old {
                    break r;
                }
            };
            self.insert(rec);
        }
        for (repr, rows) in old_cache {
            let remapped: HashMap<usize, Option<Vec<f64>>> = rows
                .into_iter()
                .filter_map(|(old, row)| new_idx.get(&old).map(|&new| (new, row)))
                .collect();
            self.feat_cache.insert(repr, remapped);
        }
        self.epoch += 1;
        dropped
    }

    fn insert(&mut self, rec: Record) {
        let idx = self.records.len();
        let valid = rec.is_valid();
        let g = rec.gflops;
        self.records.push(rec);
        if !valid {
            return;
        }
        // NaN-safe ordering: f64::total_cmp (non-finite never reaches
        // here, so total order == numeric order).
        if self
            .best
            .map_or(true, |(_, bg)| g.total_cmp(&bg) == std::cmp::Ordering::Greater)
        {
            self.best = Some((idx, g));
        }
        let pos = self
            .top_k
            .partition_point(|&(_, tg)| tg.total_cmp(&g) != std::cmp::Ordering::Less);
        if pos < TOP_K {
            self.top_k.insert(pos, (idx, g));
            self.top_k.truncate(TOP_K);
        }
    }
}

type ShardKey = (String, String); // (task_key, target)

/// The live WAL tail of a file-backed DB.
struct Wal {
    file: File,
    /// WAL path; the snapshot lives beside it at `<path>.snap`.
    path: PathBuf,
    /// Snapshot generation this tail belongs to (0 = never compacted).
    gen: u64,
}

struct DbInner {
    shards: Vec<Mutex<HashMap<ShardKey, TaskShard>>>,
    /// Append-only JSONL write-ahead log (file-backed DBs only). Held
    /// across the index update so file order matches insertion order.
    wal: Mutex<Option<Wal>>,
    /// Fast-path flag mirroring `wal.is_some()`: in-memory DBs skip the
    /// global WAL lock entirely, so their writers contend only on the
    /// touched shard bucket (the concurrency the sharding exists for).
    wal_enabled: AtomicBool,
    len: AtomicUsize,
    /// WAL-size threshold (bytes) past which an append triggers an
    /// automatic keep-all compaction; 0 = off (the default).
    auto_compact_bytes: AtomicU64,
    /// Re-entrancy guard: exactly one appender runs the triggered
    /// compaction while the others keep appending.
    auto_compacting: AtomicBool,
    /// Completed automatic compactions (for tests and ops visibility).
    auto_compactions: AtomicUsize,
}

/// The unparseable fragment a crashed append leaves after the last
/// newline, if any. A complete (newline-terminated) malformed line is
/// NOT a torn tail — that is real corruption and stays a hard error.
fn torn_tail(text: &str) -> Option<&str> {
    let tail = match text.rfind('\n') {
        Some(i) => &text[i + 1..],
        None => text,
    };
    if tail.trim().is_empty() {
        return None;
    }
    match Json::parse(tail).and_then(|j| Record::from_json(&j)) {
        Ok(_) => None,
        Err(_) => Some(tail),
    }
}

/// `<wal>.snap` — the snapshot file beside a WAL.
fn snapshot_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".snap");
    PathBuf::from(os)
}

/// `<file>.tmp` — the staging name rename-swapped over `file`.
fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// First line of `text`, without the newline.
fn first_line(text: &str) -> &str {
    match text.find('\n') {
        Some(i) => &text[..i],
        None => text,
    }
}

/// A meta line (snapshot header or WAL generation marker) — stored
/// alongside records in the log files but never a record itself.
fn is_meta(j: &Json) -> bool {
    j.get("autotvm_snapshot").is_some() || j.get("autotvm_wal_gen").is_some()
}

/// The generation a WAL tail declares in its leading marker line, if it
/// has one. Fresh post-compaction tails do; pre-compaction logs (and
/// empty files) do not.
fn wal_gen_of(text: &str) -> Option<u64> {
    Json::parse(first_line(text)).ok()?.get("autotvm_wal_gen")?.as_u64()
}

fn wal_marker_line(gen: u64) -> String {
    let mut s = Json::obj(vec![("autotvm_wal_gen", Json::from(gen))]).dump();
    s.push('\n');
    s
}

/// Parse a snapshot file into its generation and record section.
fn parse_snapshot(text: &str) -> anyhow::Result<(u64, &str)> {
    let j = Json::parse(first_line(text)).context("snapshot header")?;
    anyhow::ensure!(j.get("autotvm_snapshot").is_some(), "snapshot file missing header");
    let gen = j
        .get("gen")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("snapshot header missing gen"))?;
    let rest = match text.find('\n') {
        Some(i) => &text[i + 1..],
        None => "",
    };
    Ok((gen, rest))
}

/// Rename-swap a fresh, marker-only WAL tail over `path` — the last
/// step of the compaction protocol, also run by `open` to complete a
/// swap that a crash interrupted.
fn swap_in_fresh_wal(path: &Path, gen: u64) -> anyhow::Result<()> {
    let tmp = tmp_path(path);
    let mut f = File::create(&tmp)?;
    f.write_all(wal_marker_line(gen).as_bytes())?;
    f.sync_all()?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Canonical device identity of a target string: farm-topology and
/// fault-injection wrappers (`farm(4xsim-gpu)`, `flaky(sim-gpu)`,
/// nested combinations) are stripped, iteratively, down to the board
/// name they decorate. A record is valid for a *device*, not a fleet
/// shape — one stamped by a 4-replica farm wrapper must still be found
/// by a warm-start lookup asking for `sim-gpu`. Applied to every record
/// entering the index (and the WAL) and to every lookup's `target`
/// argument, so the write and read sides can never silently drift.
pub fn canonical_target(raw: &str) -> String {
    let mut t = raw.trim();
    loop {
        if let Some(inner) = t.strip_prefix("flaky(").and_then(|s| s.strip_suffix(')')) {
            t = inner;
            continue;
        }
        if let Some(inner) = t.strip_prefix("farm(").and_then(|s| s.strip_suffix(')')) {
            // farm(<replicas>x<board>)
            let after_count = inner.find('x').and_then(|i| {
                let (count, rest) = inner.split_at(i);
                if !count.is_empty() && count.chars().all(|c| c.is_ascii_digit()) {
                    Some(&rest[1..])
                } else {
                    None
                }
            });
            if let Some(rest) = after_count {
                t = rest;
                continue;
            }
        }
        return t.to_string();
    }
}

fn shard_idx(task_key: &str, target: &str) -> usize {
    let mut h = DefaultHasher::new();
    task_key.hash(&mut h);
    target.hash(&mut h);
    (h.finish() as usize) % N_SHARDS
}

/// The tuning-DB service handle: a cheap `Arc` clone, `Send + Sync`.
/// See the module docs for the index / WAL / cache layout.
#[derive(Clone)]
pub struct TuningDb {
    inner: Arc<DbInner>,
}

/// Historical name of the record store (pre-service-layer); kept as an
/// alias so experiment drivers and tests read naturally.
pub type Database = TuningDb;

impl Default for TuningDb {
    fn default() -> Self {
        TuningDb::new()
    }
}

impl std::fmt::Debug for TuningDb {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TuningDb").field("records", &self.len()).finish()
    }
}

impl TuningDb {
    /// Fresh in-memory DB (no WAL).
    pub fn new() -> Self {
        TuningDb {
            inner: Arc::new(DbInner {
                shards: (0..N_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
                wal: Mutex::new(None),
                wal_enabled: AtomicBool::new(false),
                len: AtomicUsize::new(0),
                auto_compact_bytes: AtomicU64::new(0),
                auto_compacting: AtomicBool::new(false),
                auto_compactions: AtomicUsize::new(0),
            }),
        }
    }

    /// Open (or create) a WAL-backed DB at `path`: existing records are
    /// loaded and indexed, and every subsequent [`append`](Self::append)
    /// is written through to the file immediately.
    ///
    /// Loading is **snapshot-then-tail**: if a compaction snapshot
    /// (`<path>.snap`) exists, its retained records load first and only
    /// the fresh WAL tail is replayed on top — startup cost is bounded
    /// by the retention policy, not the full append history. Without a
    /// snapshot the whole WAL is replayed.
    ///
    /// Crash recovery, by window:
    /// * a torn trailing WAL line (crash mid-append, i.e. an
    ///   unparseable fragment after the last newline) is dropped AND
    ///   truncated from the file, so the next append starts on a clean
    ///   line instead of concatenating onto the fragment;
    /// * leftover `.tmp` staging files (crash mid-compaction before the
    ///   snapshot rename committed) are deleted — the pre-compaction
    ///   state is still fully intact;
    /// * a committed snapshot whose WAL swap was interrupted (the WAL
    ///   still holds the pre-compaction history, every line of which
    ///   was folded into the snapshot before the rename) — the snapshot
    ///   wins and `open` completes the swap, yielding exactly the
    ///   retained records.
    ///
    /// Any other malformed record is a hard error.
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<TuningDb> {
        let path = path.as_ref();
        let db = TuningDb::new();
        let snap = snapshot_path(path);
        // Staging leftovers are dead weight: a compaction commits at
        // the snapshot rename, never at a tmp write.
        let _ = std::fs::remove_file(tmp_path(&snap));
        let _ = std::fs::remove_file(tmp_path(path));
        let mut gen = 0u64;
        if snap.exists() {
            let text = std::fs::read_to_string(&snap)?;
            let (snap_gen, records) = parse_snapshot(&text)?;
            gen = snap_gen;
            // The snapshot was rename-committed, so it is never torn:
            // load it strictly.
            db.load_lines(records)
                .map_err(|e| e.context(format!("snapshot {}", snap.display())))?;
            let tail_current = if path.exists() {
                let wtext = std::fs::read_to_string(path)?;
                match wal_gen_of(&wtext) {
                    Some(wg) if wg == gen => {
                        db.load_wal_text(path, &wtext)?;
                        true
                    }
                    Some(wg) if wg > gen => anyhow::bail!(
                        "WAL tail generation {wg} is newer than snapshot generation {gen} \
                         at {} — inconsistent snapshot/WAL pair",
                        path.display()
                    ),
                    // A stale marker (wg < gen) or no marker at all is
                    // the pre-compaction log an interrupted rename-swap
                    // left behind; its records are already in the
                    // snapshot, so the snapshot wins.
                    _ => false,
                }
            } else {
                false
            };
            if !tail_current {
                // Complete the interrupted swap so appends land on a
                // clean, marker-led tail.
                swap_in_fresh_wal(path, gen)?;
            }
        } else if path.exists() {
            let text = std::fs::read_to_string(path)?;
            anyhow::ensure!(
                wal_gen_of(&text).is_none(),
                "WAL {} declares a snapshot generation but {} is missing",
                path.display(),
                snap.display()
            );
            db.load_wal_text(path, &text)?;
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        *db.inner.wal.lock().unwrap() =
            Some(Wal { file, path: path.to_path_buf(), gen });
        db.inner.wal_enabled.store(true, Ordering::Release);
        Ok(db)
    }

    /// Load a JSONL log into an in-memory DB (strict: every line must
    /// parse; meta lines from compacted logs are skipped). Works on WAL
    /// and snapshot files alike. Use [`open`](Self::open) for the live
    /// service path.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<TuningDb> {
        let db = TuningDb::new();
        let text = std::fs::read_to_string(path)?;
        let body = match parse_snapshot(&text) {
            Ok((_, records)) => records,
            Err(_) => &text,
        };
        db.load_lines(body)?;
        Ok(db)
    }

    /// Load WAL `text` into the index with torn-tail handling: an
    /// unparseable fragment after the last newline (crash mid-append)
    /// is dropped and truncated from the file; a valid but unterminated
    /// last line gets its newline appended so the next record starts
    /// clean. Any complete malformed line is a hard error.
    fn load_wal_text(&self, path: &Path, text: &str) -> anyhow::Result<()> {
        let valid = match torn_tail(text) {
            Some(tail) => {
                eprintln!(
                    "tuning-db: truncating torn trailing WAL line ({} bytes)",
                    tail.len()
                );
                // In-place truncation to the last newline: the valid
                // prefix is never rewritten, so a crash during recovery
                // cannot lose durably-appended records.
                let keep = text.len() - tail.len();
                OpenOptions::new().write(true).open(path)?.set_len(keep as u64)?;
                &text[..keep]
            }
            None => {
                if !text.is_empty() && !text.ends_with('\n') {
                    OpenOptions::new().append(true).open(path)?.write_all(b"\n")?;
                }
                text
            }
        };
        self.load_lines(valid)
    }

    fn load_lines(&self, text: &str) -> anyhow::Result<()> {
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(line).and_then(|j| {
                if is_meta(&j) {
                    Ok(None) // snapshot header / WAL marker, not a record
                } else {
                    Record::from_json(&j).map(Some)
                }
            });
            match parsed {
                Ok(Some(r)) => self.insert(r),
                Ok(None) => {}
                Err(e) => return Err(e.context(format!("tuning-db record on line {}", i + 1))),
            }
        }
        Ok(())
    }

    /// Index one record (no WAL write). The record's target is
    /// normalized to its canonical device identity
    /// ([`canonical_target`]) — the single in-memory chokepoint, so
    /// WAL replays of pre-normalization logs land in the right shard
    /// too.
    fn insert(&self, mut rec: Record) {
        rec.target = canonical_target(&rec.target);
        let b = shard_idx(&rec.task_key, &rec.target);
        let mut bucket = self.inner.shards[b].lock().unwrap();
        bucket
            .entry((rec.task_key.clone(), rec.target.clone()))
            .or_default()
            .insert(rec);
        self.inner.len.fetch_add(1, Ordering::SeqCst);
    }

    /// Append one record: crash-safe incremental WAL write (if
    /// file-backed) plus index update. Safe to call from any thread.
    ///
    /// The record is indexed in memory even when the WAL write fails
    /// (the error is still returned): the service keeps serving while
    /// persistence degrades. A failed write may leave a partial line on
    /// disk, so the file is truncated back to its pre-write length; if
    /// even that fails the WAL is disabled rather than risk mid-file
    /// corruption on the next append.
    pub fn append(&self, mut rec: Record) -> anyhow::Result<()> {
        // Normalize before the WAL write so the on-disk line already
        // carries the canonical device identity (`insert` re-normalizes
        // for replayed legacy lines — idempotent).
        rec.target = canonical_target(&rec.target);
        // In-memory DBs never touch the WAL lock: writers to different
        // shards proceed fully in parallel.
        if !self.inner.wal_enabled.load(Ordering::Acquire) {
            self.insert(rec);
            return Ok(());
        }
        let wal_err = {
            let mut wal = self.inner.wal.lock().unwrap();
            let mut wal_err: Option<std::io::Error> = None;
            let mut disable = false;
            if let Some(w) = wal.as_mut() {
                let mut line = rec.to_json().dump();
                line.push('\n');
                let prev_len = w.file.metadata().ok().map(|m| m.len());
                if let Err(e) = w.file.write_all(line.as_bytes()) {
                    let repaired = prev_len.map_or(false, |p| w.file.set_len(p).is_ok());
                    disable = !repaired;
                    wal_err = Some(e);
                }
            }
            if disable {
                eprintln!(
                    "tuning-db: WAL unrecoverable after failed write; disabling persistence"
                );
                *wal = None;
                self.inner.wal_enabled.store(false, Ordering::Release);
            }
            // Still under the WAL lock: file order == insertion order even
            // with concurrent appenders.
            self.insert(rec);
            wal_err
        };
        // WAL lock released above — `compact` re-takes it, so the
        // threshold check must run outside the guard.
        if wal_err.is_none() {
            self.maybe_auto_compact();
        }
        match wal_err {
            Some(e) => Err(e.into()),
            None => Ok(()),
        }
    }

    /// Arm (or disarm, with 0) automatic compaction: whenever a
    /// successful [`append`](Self::append) observes the live WAL tail at
    /// or past `bytes`, it folds the tail into the snapshot with
    /// [`RetentionPolicy::keep_all`] — no record is evicted, so serving
    /// answers and training sets are untouched; only the on-disk layout
    /// changes. One appender runs the compaction while concurrent
    /// appenders keep writing (they land on the fresh tail). No-op for
    /// in-memory DBs.
    pub fn set_auto_compact_bytes(&self, bytes: u64) {
        self.inner.auto_compact_bytes.store(bytes, Ordering::Release);
    }

    /// Automatic compactions completed so far.
    pub fn auto_compactions(&self) -> usize {
        self.inner.auto_compactions.load(Ordering::SeqCst)
    }

    /// Run the threshold-triggered keep-all compaction if armed and due.
    /// Must be called WITHOUT the WAL lock held ([`compact`](Self::compact)
    /// takes it). Failures are reported, not fatal — the WAL simply
    /// keeps growing until the next trigger.
    fn maybe_auto_compact(&self) {
        let threshold = self.inner.auto_compact_bytes.load(Ordering::Acquire);
        if threshold == 0 {
            return;
        }
        match self.wal_bytes() {
            Some(bytes) if bytes >= threshold => {}
            _ => return,
        }
        if self.inner.auto_compacting.swap(true, Ordering::AcqRel) {
            return; // another appender is already compacting
        }
        // Re-check under the guard: a racing appender may have just
        // folded the tail below the threshold.
        let due = self.wal_bytes().map_or(false, |b| b >= threshold);
        if due {
            match self.compact(&RetentionPolicy::keep_all()) {
                Ok(stats) => {
                    self.inner.auto_compactions.fetch_add(1, Ordering::SeqCst);
                    eprintln!(
                        "tuning-db: auto-compacted to gen {} ({} records kept)",
                        stats.gen, stats.kept
                    );
                }
                Err(e) => eprintln!("tuning-db: auto-compaction failed: {e:#}"),
            }
        }
        self.inner.auto_compacting.store(false, Ordering::Release);
    }

    /// Append the trials of one tuning run (bulk path; the live path is
    /// [`crate::tuner::DbSink`] streaming through [`append`](Self::append)).
    ///
    /// `append`'s serving-continues-while-persistence-degrades contract
    /// holds for the whole batch: every record is indexed in memory
    /// even when WAL writes fail mid-batch, and the first WAL error is
    /// returned at the end instead of aborting the loop.
    pub fn add_run(
        &self,
        task: &Task,
        target: &str,
        records: &[TrialRecord],
    ) -> anyhow::Result<()> {
        let mut first_err: Option<anyhow::Error> = None;
        for r in records {
            if let Err(e) = self.append(Record {
                task_key: task.key(),
                target: target.to_string(),
                choices: r.entity.choices.clone(),
                gflops: r.gflops,
                seconds: r.seconds.unwrap_or(0.0),
                error: r.error.clone(),
            }) {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Total number of records across all shards.
    pub fn len(&self) -> usize {
        self.inner.len.load(Ordering::SeqCst)
    }

    /// Whether the DB holds no records at all.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sorted list of every `(task_key, target)` shard key — the query
    /// population for serving storms and the iteration order of
    /// [`write_jsonl`](Self::write_jsonl).
    pub fn shard_keys(&self) -> Vec<(String, String)> {
        let mut keys: Vec<ShardKey> = Vec::new();
        for bucket in &self.inner.shards {
            keys.extend(bucket.lock().unwrap().keys().cloned());
        }
        keys.sort();
        keys
    }

    /// Deterministic copy of every record: shards in sorted
    /// `(task_key, target)` order, records in insertion order. Clones
    /// the whole DB into one `Vec` — tests and small exports only; the
    /// bounded-memory path is [`write_jsonl`](Self::write_jsonl).
    pub fn records(&self) -> Vec<Record> {
        let mut out = Vec::new();
        for (task, target) in self.shard_keys() {
            out.extend(self.for_task(&task, &target));
        }
        out
    }

    /// Stream every record as JSONL into `out`, shard by shard in
    /// sorted key order (insertion order within a shard). Buffers one
    /// shard at a time, never the whole DB — at millions of records
    /// this is the difference between a snapshot write and a memory
    /// spike. Shared by [`save`](Self::save) and
    /// [`compact`](Self::compact).
    pub fn write_jsonl(&self, out: &mut dyn Write) -> anyhow::Result<()> {
        for key in self.shard_keys() {
            let buf = {
                let bucket = self.inner.shards[shard_idx(&key.0, &key.1)].lock().unwrap();
                let Some(shard) = bucket.get(&key) else { continue };
                let mut buf = String::new();
                for r in &shard.records {
                    buf.push_str(&r.to_json().dump());
                    buf.push('\n');
                }
                buf
            };
            out.write_all(buf.as_bytes())?;
        }
        Ok(())
    }

    /// Export the whole DB as JSONL, streamed shard-by-shard (for
    /// in-memory DBs; a file-backed DB's WAL is already on disk).
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        self.write_jsonl(&mut w)?;
        w.flush()?;
        Ok(())
    }

    /// Size of the live WAL tail in bytes (`None` for in-memory DBs) —
    /// the signal a serving deployment watches to schedule compaction.
    pub fn wal_bytes(&self) -> Option<u64> {
        let wal = self.inner.wal.lock().unwrap();
        wal.as_ref().and_then(|w| w.file.metadata().ok()).map(|m| m.len())
    }

    /// Snapshot generation of the live WAL tail (`None` for in-memory
    /// DBs; 0 = never compacted).
    pub fn snapshot_gen(&self) -> Option<u64> {
        self.inner.wal.lock().unwrap().as_ref().map(|w| w.gen)
    }

    /// Fold the WAL into a snapshot and swap in a fresh tail — the
    /// production answer to an append-only log that otherwise grows
    /// without bound.
    ///
    /// Protocol (each step leaves a state [`open`](Self::open) recovers
    /// from; see its crash-window list):
    /// 1. **Evict** — the retention policy runs in memory: every
    ///    `(task, target)` shard keeps its best `policy.top_k` valid
    ///    records plus its newest `policy.newest` records, dropping the
    ///    rest from the index. The WAL lock is held for the whole
    ///    compaction, so writers are parked and the snapshot observes a
    ///    frozen DB; readers take only shard locks and are never
    ///    blocked for longer than one shard's serialization.
    /// 2. **Snapshot** — the retained records stream shard-by-shard
    ///    into `<wal>.snap.tmp` (header line first), which is fsynced
    ///    and renamed to `<wal>.snap`. The rename is the commit point.
    /// 3. **Swap** — a fresh tail holding only the generation marker
    ///    line is rename-swapped over the WAL; subsequent appends land
    ///    on the new tail and `open` loads snapshot-then-tail.
    ///
    /// Fails (without touching any state) on in-memory DBs and on DBs
    /// whose WAL was disabled after an unrecoverable write error.
    pub fn compact(&self, policy: &RetentionPolicy) -> anyhow::Result<CompactStats> {
        let mut wal = self.inner.wal.lock().unwrap();
        let Some(w) = wal.as_mut() else {
            anyhow::bail!("compact requires a file-backed DB with a live WAL");
        };
        let gen = w.gen + 1;
        // 1. Evict. Shard locks nest inside the WAL lock, same order as
        // `append`.
        let mut dropped = 0usize;
        for bucket in &self.inner.shards {
            let mut bucket = bucket.lock().unwrap();
            for shard in bucket.values_mut() {
                dropped += shard.retain(policy);
            }
        }
        self.inner.len.fetch_sub(dropped, Ordering::SeqCst);
        // 2. Snapshot: stream to the staging file, fsync, rename.
        let snap = snapshot_path(&w.path);
        let staging = tmp_path(&snap);
        {
            let mut out = BufWriter::new(File::create(&staging)?);
            let header = Json::obj(vec![
                ("autotvm_snapshot", Json::from(1u64)),
                ("gen", Json::from(gen)),
                ("records", Json::from(self.len())),
            ]);
            out.write_all(header.dump().as_bytes())?;
            out.write_all(b"\n")?;
            self.write_jsonl(&mut out)?;
            out.flush()?;
            out.get_ref().sync_all()?;
        }
        std::fs::rename(&staging, &snap)?;
        let snapshot_bytes = std::fs::metadata(&snap).map(|m| m.len()).unwrap_or(0);
        // 3. Swap in the fresh tail and move the append handle onto it.
        swap_in_fresh_wal(&w.path, gen)?;
        w.file = OpenOptions::new().append(true).open(&w.path)?;
        w.gen = gen;
        Ok(CompactStats { gen, kept: self.len(), dropped, snapshot_bytes })
    }

    /// Records belonging to one task+target, in insertion order.
    /// (`target` is looked up by canonical device identity, like every
    /// query below.)
    pub fn for_task(&self, task_key: &str, target: &str) -> Vec<Record> {
        let target = canonical_target(target);
        let bucket = self.inner.shards[shard_idx(task_key, &target)].lock().unwrap();
        bucket
            .get(&(task_key.to_string(), target))
            .map(|s| s.records.clone())
            .unwrap_or_default()
    }

    /// Sorted task keys with at least one record on `target`.
    pub fn task_keys(&self, target: &str) -> Vec<String> {
        let target = canonical_target(target);
        let mut keys: Vec<String> = Vec::new();
        for bucket in &self.inner.shards {
            let bucket = bucket.lock().unwrap();
            for (k, _) in bucket.iter() {
                if k.1 == target {
                    keys.push(k.0.clone());
                }
            }
        }
        keys.sort();
        keys.dedup();
        keys
    }

    /// Best valid config per task — served from the incremental index
    /// in O(1), the graph-compiler hot path.
    pub fn best_config(&self, task_key: &str, target: &str) -> Option<(ConfigEntity, f64)> {
        let target = canonical_target(target);
        let bucket = self.inner.shards[shard_idx(task_key, &target)].lock().unwrap();
        let shard = bucket.get(&(task_key.to_string(), target))?;
        let (idx, g) = shard.best?;
        Some((ConfigEntity { choices: shard.records[idx].choices.clone() }, g))
    }

    /// Linear-scan reference for [`best_config`](Self::best_config) —
    /// kept for tests and the `bench_db` indexed-vs-scan comparison.
    /// (On a tie the scan may return a different record than the index;
    /// the gflops value is always identical.)
    pub fn best_config_scan(
        &self,
        task_key: &str,
        target: &str,
    ) -> Option<(ConfigEntity, f64)> {
        let target = canonical_target(target);
        let bucket = self.inner.shards[shard_idx(task_key, &target)].lock().unwrap();
        let shard = bucket.get(&(task_key.to_string(), target))?;
        shard
            .records
            .iter()
            .filter(|r| r.is_valid())
            .max_by(|a, b| a.gflops.total_cmp(&b.gflops))
            .map(|r| (ConfigEntity { choices: r.choices.clone() }, r.gflops))
    }

    /// Up to `k` best valid configs (descending gflops, ties earliest
    /// first) from the incremental index; `k` is capped at [`TOP_K`].
    pub fn top_k(&self, task_key: &str, target: &str, k: usize) -> Vec<(ConfigEntity, f64)> {
        let target = canonical_target(target);
        let bucket = self.inner.shards[shard_idx(task_key, &target)].lock().unwrap();
        let Some(shard) = bucket.get(&(task_key.to_string(), target)) else {
            return Vec::new();
        };
        shard
            .top_k
            .iter()
            .take(k)
            .map(|&(i, g)| (ConfigEntity { choices: shard.records[i].choices.clone() }, g))
            .collect()
    }

    /// Build a training set from source-domain records under an
    /// invariant representation — the `D'` featurization for the global
    /// model of Eq. 4. Tasks must be supplied so configs can be
    /// re-lowered; records for unknown tasks are skipped. Returns
    /// (features, labels-normalized-per-task, group sizes per task).
    ///
    /// Deterministic: tasks are visited in sorted-key order (duplicates
    /// dropped) and records in insertion order. Errored, non-finite and
    /// unlowerable records are excluded. Feature rows are memoized in
    /// the per-shard cache, so repeated calls only featurize records
    /// appended since the last call.
    ///
    /// Labels are normalized to relative throughput within each task
    /// (gflops / task max) so the global model learns *shape*, not
    /// absolute workload scale — with the rank objective only per-task
    /// order matters and tasks are separate rank groups.
    pub fn to_training(
        &self,
        tasks: &[&Task],
        target: &str,
        repr: Representation,
        limit_per_task: usize,
    ) -> (Matrix, Vec<f64>, Vec<usize>) {
        let target = canonical_target(target);
        let mut sorted: Vec<&Task> = tasks.to_vec();
        sorted.sort_by_key(|t| t.key());
        sorted.dedup_by_key(|t| t.key());
        let mut rows: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        let mut groups: Vec<usize> = Vec::new();
        for task in sorted {
            let key = (task.key(), target.clone());
            let bucket_idx = shard_idx(&key.0, &target);
            // Phase 1 (locked, cheap): pick the valid records and find
            // which of them the feature cache is missing.
            let (sel, epoch0, missing_idx, missing_ents) = {
                let mut bucket = self.inner.shards[bucket_idx].lock().unwrap();
                let Some(shard) = bucket.get_mut(&key) else { continue };
                let epoch0 = shard.epoch;
                let TaskShard { records, feat_cache, top_k, .. } = shard;
                let valid: Vec<usize> = records
                    .iter()
                    .enumerate()
                    .filter(|(_, r)| r.is_valid())
                    .map(|(i, _)| i)
                    .collect();
                // Past the cap, D' keeps the shard's best half plus the
                // newest rest (emitted in insertion order): a record
                // appended after the task crossed `limit_per_task`
                // still reaches the training set, so refits keep
                // learning, while the top of the ranking stays
                // represented. (Taking the *first* N would freeze D'
                // at the task's cold start forever.)
                let sel: Vec<usize> = if valid.len() <= limit_per_task {
                    valid
                } else {
                    let k_best = limit_per_task / 2;
                    let mut keep: BTreeSet<usize> =
                        top_k.iter().take(k_best).map(|&(i, _)| i).collect();
                    for &i in valid.iter().rev() {
                        if keep.len() >= limit_per_task {
                            break;
                        }
                        keep.insert(i);
                    }
                    keep.into_iter().collect()
                };
                if sel.is_empty() {
                    continue;
                }
                let cache = feat_cache.entry(repr).or_default();
                let mut missing_idx: Vec<usize> = Vec::new();
                let mut missing_ents: Vec<ConfigEntity> = Vec::new();
                for &i in sel.iter().filter(|i| !cache.contains_key(*i)) {
                    // stale/foreign configs that don't index into this
                    // build's space are excluded from D', not lowered
                    // (lowering them would panic)
                    if task.space.contains_choices(&records[i].choices) {
                        missing_idx.push(i);
                        missing_ents.push(ConfigEntity {
                            choices: records[i].choices.clone(),
                        });
                    } else {
                        cache.insert(i, None);
                    }
                }
                (sel, epoch0, missing_idx, missing_ents)
            };
            // Phase 2 (no locks): the expensive lower+analyze+extract —
            // writers streaming into this shard are not stalled.
            // Appends never renumber existing records, so the selected
            // indices stay valid unless a compaction evicts (detected
            // below via the shard epoch).
            let computed: Vec<Option<Vec<f64>>> = if missing_ents.is_empty() {
                Vec::new()
            } else {
                let batch = crate::features::featurize_batch(repr, task, &missing_ents);
                (0..batch.rows()).map(|i| batch.row(i).map(|r| r.to_vec())).collect()
            };
            // Phase 3 (locked, cheap): install the new cache rows, then
            // emit the training rows in selection order.
            let mut bucket = self.inner.shards[bucket_idx].lock().unwrap();
            let Some(shard) = bucket.get_mut(&key) else { continue };
            if shard.epoch != epoch0 {
                // A compaction renumbered this shard between the
                // phases: the captured indices (and the rows computed
                // for them) are stale. Skip the task this call; the
                // next call re-selects and re-featurizes.
                continue;
            }
            let TaskShard { records, feat_cache, .. } = shard;
            let cache = feat_cache.entry(repr).or_default();
            for (i, f) in missing_idx.into_iter().zip(computed) {
                cache.insert(i, f);
            }
            let mut task_rows: Vec<(Vec<f64>, f64)> = Vec::new();
            for &i in &sel {
                if let Some(Some(f)) = cache.get(&i) {
                    task_rows.push((f.clone(), records[i].gflops));
                }
            }
            if task_rows.is_empty() {
                continue;
            }
            let max_g = task_rows.iter().map(|(_, g)| *g).fold(f64::MIN_POSITIVE, f64::max);
            groups.push(task_rows.len());
            for (f, g) in task_rows {
                rows.push(f);
                ys.push(g / max_g);
            }
        }
        (Matrix::from_rows(&rows), ys, groups)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::measure::{Measurer, SimMeasurer};
    use crate::schedule::template::TemplateKind;
    use crate::sim::devices::sim_cpu;
    use crate::util::Rng;

    fn sample_records(task: &Task, n: usize) -> Vec<TrialRecord> {
        let m = SimMeasurer::with_seed(sim_cpu(), 1);
        let mut rng = Rng::seed_from_u64(2);
        let batch: Vec<ConfigEntity> =
            (0..n).map(|_| task.space.sample(&mut rng)).collect();
        let res = m.measure(task, &batch);
        batch
            .into_iter()
            .zip(res)
            .map(|(e, r)| TrialRecord {
                entity: e,
                gflops: r.gflops,
                seconds: r.seconds,
                error: r.error,
            })
            .collect()
    }

    #[test]
    fn save_load_roundtrip() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        db.add_run(&task, "sim-cpu", &sample_records(&task, 20)).unwrap();
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.jsonl");
        db.save(&path).unwrap();
        let back = Database::load(&path).unwrap();
        assert_eq!(db.records(), back.records());
        assert_eq!(db.len(), back.len());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn best_config_skips_errors() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        let mut recs = sample_records(&task, 10);
        // poison: an error record with absurd gflops must not win
        recs.push(TrialRecord {
            entity: task.space.entity(0),
            gflops: 1e12,
            seconds: None,
            error: Some("boom".into()),
        });
        db.add_run(&task, "sim-cpu", &recs).unwrap();
        let (_, g) = db.best_config(&task.key(), "sim-cpu").unwrap();
        assert!(g < 1e12);
    }

    /// Regression (satellite): records stamped with a *wrapped* board
    /// name — `farm(4xsim-gpu)` from the in-place [`Measurer`] path of
    /// a `DeviceFarm`, `flaky(sim-gpu)` from a fault injector — used to
    /// land in a shard no warm-start lookup keyed by `sim-gpu` could
    /// see. Target keys are now canonicalized at the DB boundary on
    /// both the write and read side.
    #[test]
    fn wrapped_target_names_hit_device_lookups() {
        assert_eq!(canonical_target("sim-gpu"), "sim-gpu");
        assert_eq!(canonical_target("farm(4xsim-gpu)"), "sim-gpu");
        assert_eq!(canonical_target("flaky(sim-gpu)"), "sim-gpu");
        assert_eq!(canonical_target("flaky(farm(12xsim-cpu))"), "sim-cpu");
        // not a topology wrapper: left alone
        assert_eq!(canonical_target("farm(sim-gpu)"), "farm(sim-gpu)");
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        let recs = sample_records(&task, 12);
        db.add_run(&task, "farm(4xsim-cpu)", &recs[..6]).unwrap();
        db.add_run(&task, "flaky(sim-cpu)", &recs[6..]).unwrap();
        // all 12 records land in — and are served from — the canonical
        // device shard, whichever spelling the query uses
        assert_eq!(db.for_task(&task.key(), "sim-cpu").len(), 12);
        assert_eq!(db.for_task(&task.key(), "farm(2xsim-cpu)").len(), 12);
        assert!(db.best_config(&task.key(), "sim-cpu").is_some());
        assert_eq!(db.task_keys("sim-cpu"), vec![task.key()]);
        assert_eq!(db.task_keys("flaky(sim-cpu)"), vec![task.key()]);
        let (x, _, groups) =
            db.to_training(&[&task], "farm(9xsim-cpu)", Representation::Config, usize::MAX);
        assert!(x.rows > 0, "wrapped-target training lookup found nothing");
        assert_eq!(groups.len(), 1);
        // and the stored records themselves carry the canonical name
        assert!(db.records().iter().all(|r| r.target == "sim-cpu"));
    }

    /// Regression (satellite): a NaN gflops record used to panic
    /// `best_config` via `partial_cmp().unwrap()`; now ordering is
    /// `total_cmp` and non-finite records never enter the index.
    #[test]
    fn best_config_nan_safe() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        let mut recs = sample_records(&task, 8);
        recs.push(TrialRecord {
            entity: task.space.entity(1),
            gflops: f64::NAN,
            seconds: None,
            error: None,
        });
        db.add_run(&task, "sim-cpu", &recs).unwrap();
        let (_, g) = db.best_config(&task.key(), "sim-cpu").unwrap();
        assert!(g.is_finite(), "NaN record won the serving path");
        // index agrees with the linear scan
        let (_, gs) = db.best_config_scan(&task.key(), "sim-cpu").unwrap();
        assert_eq!(g, gs);
        // a shard with only a NaN record serves nothing
        let db2 = Database::new();
        db2.add_run(
            &task,
            "sim-cpu",
            &[TrialRecord {
                entity: task.space.entity(1),
                gflops: f64::NAN,
                seconds: None,
                error: None,
            }],
        )
        .unwrap();
        assert!(db2.best_config(&task.key(), "sim-cpu").is_none());
    }

    #[test]
    fn top_k_is_sorted_and_capped() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        db.add_run(&task, "sim-cpu", &sample_records(&task, 40)).unwrap();
        let top = db.top_k(&task.key(), "sim-cpu", 64);
        assert!(top.len() <= TOP_K);
        assert!(!top.is_empty());
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1, "top-k not descending");
        }
        let (_, best) = db.best_config(&task.key(), "sim-cpu").unwrap();
        assert_eq!(top[0].1, best);
        // a k below the cap truncates
        assert_eq!(db.top_k(&task.key(), "sim-cpu", 3).len(), 3.min(top.len()));
    }

    #[test]
    fn to_training_builds_invariant_features() {
        let t1 = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let t2 = Task::new(
            ops::conv2d(ops::Conv2dParams {
                n: 1, h: 14, w: 14, ic: 16, oc: 16, kh: 3, kw: 3, stride: 1, pad: 1,
            }),
            TemplateKind::Cpu,
        );
        let db = Database::new();
        let r1 = sample_records(&t1, 12);
        let r2 = sample_records(&t2, 12);
        let ok1 = r1.iter().filter(|r| r.error.is_none()).count();
        let ok2 = r2.iter().filter(|r| r.error.is_none()).count();
        db.add_run(&t1, "sim-cpu", &r1).unwrap();
        db.add_run(&t2, "sim-cpu", &r2).unwrap();
        let (x, y, groups) = db.to_training(
            &[&t1, &t2],
            "sim-cpu",
            Representation::ContextRelation,
            100,
        );
        // errored trials are filtered out of D'
        assert_eq!(x.rows, ok1 + ok2);
        assert_eq!(x.cols, Representation::ContextRelation.dim());
        assert_eq!(groups.iter().sum::<usize>(), ok1 + ok2);
        // labels normalized per task
        assert!(y.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    /// Stale/foreign records whose choices don't index into this
    /// build's space must be skipped by `to_training` — not lowered
    /// (which would panic in `instantiate`).
    #[test]
    fn to_training_skips_out_of_space_records() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        let recs = sample_records(&task, 6);
        let ok = recs.iter().filter(|r| r.error.is_none()).count();
        db.add_run(&task, "sim-cpu", &recs).unwrap();
        // wrong arity (too few knobs) and out-of-range option index
        for choices in [vec![0u32], vec![u32::MAX; task.space.num_knobs()]] {
            db.append(Record {
                task_key: task.key(),
                target: "sim-cpu".into(),
                choices,
                gflops: 5.0,
                seconds: 0.1,
                error: None,
            })
            .unwrap();
        }
        let (x, _, groups) =
            db.to_training(&[&task], "sim-cpu", Representation::ContextRelation, 100);
        assert_eq!(x.rows, ok, "poisoned records must be excluded from D'");
        assert_eq!(groups.iter().sum::<usize>(), ok);
    }

    /// Satellite regression: the training set must not depend on caller
    /// task order (the old HashMap iteration made row order vary
    /// run-to-run) and the cached second call must equal the first.
    #[test]
    fn to_training_is_deterministic_and_cached() {
        let t1 = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let t2 = Task::new(ops::matmul(32, 32, 32), TemplateKind::Cpu);
        let db = Database::new();
        db.add_run(&t1, "sim-cpu", &sample_records(&t1, 10)).unwrap();
        db.add_run(&t2, "sim-cpu", &sample_records(&t2, 10)).unwrap();
        let (xa, ya, ga) =
            db.to_training(&[&t1, &t2], "sim-cpu", Representation::ContextRelation, 100);
        // reversed task order: identical output (sorted-key iteration)
        let (xb, yb, gb) =
            db.to_training(&[&t2, &t1], "sim-cpu", Representation::ContextRelation, 100);
        assert_eq!(xa.data, xb.data);
        assert_eq!(ya, yb);
        assert_eq!(ga, gb);
        // third call is served from the feature cache — same result
        let (xc, yc, gc) =
            db.to_training(&[&t1, &t2], "sim-cpu", Representation::ContextRelation, 100);
        assert_eq!(xa.data, xc.data);
        assert_eq!(ya, yc);
        assert_eq!(ga, gc);
        // duplicate tasks don't duplicate groups
        let (xd, _, gd) =
            db.to_training(&[&t1, &t1, &t2], "sim-cpu", Representation::ContextRelation, 100);
        assert_eq!(xd.rows, xa.rows);
        assert_eq!(gd, ga);
    }

    /// Satellite regression: malformed `choices` entries used to be
    /// silently coerced to 0; now they are parse errors. A torn
    /// trailing WAL line is tolerated by `open` only.
    #[test]
    fn strict_parse_rejects_malformed_records() {
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let good = r#"{"task":"t@Cpu","target":"d","choices":[1,2],"gflops":5,"seconds":0.1}"#;
        let bad = r#"{"task":"t@Cpu","target":"d","choices":[1,"x"],"gflops":5,"seconds":0.1}"#;

        let path = dir.join("strict-mid.jsonl");
        std::fs::write(&path, format!("{bad}\n{good}\n")).unwrap();
        assert!(Database::load(&path).is_err(), "malformed choices must not parse");
        assert!(Database::open(&path).is_err(), "mid-file corruption is fatal");
        let _ = std::fs::remove_file(&path);

        let path = dir.join("strict-missing.jsonl");
        std::fs::write(&path, r#"{"task":"t@Cpu","target":"d","gflops":5}"#).unwrap();
        assert!(Database::load(&path).is_err(), "missing choices must not parse");
        let _ = std::fs::remove_file(&path);

        // torn trailing line: open() truncates it from the file (so the
        // next append starts clean), load() rejects it
        let path = dir.join("torn.jsonl");
        std::fs::write(&path, format!("{good}\n{{\"task\":\"t@C")).unwrap();
        assert!(Database::load(&path).is_err());
        {
            let db = Database::open(&path).unwrap();
            assert_eq!(db.len(), 1);
            // appending after a torn tail must not concatenate onto the
            // truncated fragment
            db.append(Record {
                task_key: "t@Cpu".into(),
                target: "d".into(),
                choices: vec![3, 4],
                gflops: 7.0,
                seconds: 0.2,
                error: None,
            })
            .unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.len(), 2, "WAL corrupted by append-after-torn-tail");
        assert!(Database::load(&path).is_ok(), "WAL no longer strictly parseable");
        let _ = std::fs::remove_file(&path);

        // a valid but newline-unterminated last line is terminated on
        // open, so the next append starts on its own line
        let path = dir.join("unterminated.jsonl");
        std::fs::write(&path, good).unwrap(); // no trailing newline
        {
            let db = Database::open(&path).unwrap();
            assert_eq!(db.len(), 1);
            db.append(Record {
                task_key: "t@Cpu".into(),
                target: "d".into(),
                choices: vec![5],
                gflops: 1.0,
                seconds: 0.1,
                error: None,
            })
            .unwrap();
        }
        assert_eq!(Database::open(&path).unwrap().len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    /// Regression: a non-finite gflops used to serialize as `NaN`,
    /// which the JSON parser rejects — poisoning the WAL. It now
    /// round-trips as null → NaN (still invalid for serving).
    #[test]
    fn nan_record_roundtrips_through_wal() {
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("nan-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let db = Database::open(&path).unwrap();
            db.append(Record {
                task_key: "t@Cpu".into(),
                target: "d".into(),
                choices: vec![1],
                gflops: f64::NAN,
                seconds: 0.1,
                error: None,
            })
            .unwrap();
            db.append(Record {
                task_key: "t@Cpu".into(),
                target: "d".into(),
                choices: vec![2],
                gflops: 5.0,
                seconds: 0.1,
                error: None,
            })
            .unwrap();
        }
        let back = Database::open(&path).unwrap();
        assert_eq!(back.len(), 2, "NaN record poisoned the WAL");
        let recs = back.for_task("t@Cpu", "d");
        assert!(recs[0].gflops.is_nan());
        // the NaN record is stored but never served
        assert_eq!(back.best_config("t@Cpu", "d").unwrap().1, 5.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn wal_appends_survive_reopen() {
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("wal-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let recs = sample_records(&task, 6);
        {
            let db = Database::open(&path).unwrap();
            db.add_run(&task, "sim-cpu", &recs[..4]).unwrap();
            assert_eq!(db.len(), 4);
        } // drop: no explicit save — the WAL is the persistence
        {
            let db = Database::open(&path).unwrap();
            assert_eq!(db.len(), 4, "WAL records lost across reopen");
            db.add_run(&task, "sim-cpu", &recs[4..]).unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.len(), 6, "reopen must append, not clobber");
        assert_eq!(db.for_task(&task.key(), "sim-cpu").len(), 6);
        let _ = std::fs::remove_file(&path);
    }

    /// Satellite regression: a mid-batch WAL failure used to abort
    /// `add_run` (`?` inside the loop), silently dropping the remaining
    /// records from the in-memory index. Every record must be indexed
    /// (serving continues while persistence degrades) and the first WAL
    /// error returned at the end.
    #[test]
    fn add_run_indexes_past_wal_failure() {
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("walfail-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let recs: Vec<TrialRecord> = (0..6)
            .map(|i| TrialRecord {
                entity: task.space.entity(i),
                gflops: (i + 1) as f64,
                seconds: Some(0.1),
                error: None,
            })
            .collect();
        let db = Database::open(&path).unwrap();
        // Poison the WAL: swap the append handle for a read-only one,
        // so every write fails (and so does the truncate repair, which
        // then disables the WAL).
        db.inner.wal.lock().unwrap().as_mut().unwrap().file = File::open(&path).unwrap();
        let res = db.add_run(&task, "sim-cpu", &recs);
        assert!(res.is_err(), "WAL failure must surface to the caller");
        assert_eq!(db.len(), 6, "records dropped from the index on WAL failure");
        assert_eq!(db.for_task(&task.key(), "sim-cpu").len(), 6);
        // serving still works from memory
        assert_eq!(db.best_config(&task.key(), "sim-cpu").unwrap().1, 6.0);
        let _ = std::fs::remove_file(&path);
    }

    /// Satellite regression: `limit_per_task` used to take the *first*
    /// N valid records, so a task past the cap never got new trials
    /// into D'. Selection is now best-half ∪ newest-rest: a record
    /// appended past the cap reaches the training set.
    #[test]
    fn to_training_limit_prefers_best_and_newest() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        let mut rng = Rng::seed_from_u64(9);
        // 20 valid records with known gflops 1..=20 (ascending)
        for g in 1..=20u32 {
            db.append(Record {
                task_key: task.key(),
                target: "sim-cpu".into(),
                choices: task.space.sample(&mut rng).choices,
                gflops: g as f64,
                seconds: 0.1,
                error: None,
            })
            .unwrap();
        }
        let limit = 8;
        let (x, y, _) =
            db.to_training(&[&task], "sim-cpu", Representation::ContextRelation, limit);
        assert_eq!(x.rows, limit);
        // best half = {20,19,18,17}, newest rest = {16,15,14,13}: the
        // cold-start records 1..=8 (which the old first-N selection
        // would have returned) are all gone.
        let selected: Vec<f64> = y.iter().map(|v| v * 20.0).collect();
        assert!(
            selected.iter().all(|&g| g >= 12.5),
            "stale cold-start records selected: {selected:?}"
        );
        // a mediocre record appended past the cap must reach the next
        // training set (only the newest-rest rule can admit it)
        db.append(Record {
            task_key: task.key(),
            target: "sim-cpu".into(),
            choices: task.space.sample(&mut rng).choices,
            gflops: 5.0,
            seconds: 0.1,
            error: None,
        })
        .unwrap();
        let (x2, y2, _) =
            db.to_training(&[&task], "sim-cpu", Representation::ContextRelation, limit);
        assert_eq!(x2.rows, limit);
        assert!(
            y2.iter().any(|&v| (v * 20.0 - 5.0).abs() < 1e-9),
            "past-cap record missing from D'"
        );
    }

    /// Threshold-armed appends compact automatically (keep-all fold):
    /// the tail shrinks, nothing is evicted, serving is unchanged, and
    /// an unarmed or in-memory DB never triggers.
    #[test]
    fn auto_compaction_triggers_on_threshold() {
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("autocompact-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(snapshot_path(&path));
        let mk = |i: u32, g: f64| Record {
            task_key: "t@Cpu".into(),
            target: "d".into(),
            choices: vec![i],
            gflops: g,
            seconds: 0.1,
            error: None,
        };
        let db = Database::open(&path).unwrap();
        db.set_auto_compact_bytes(512);
        for i in 0..40u32 {
            db.append(mk(i, (i + 1) as f64)).unwrap();
        }
        assert!(db.auto_compactions() >= 1, "threshold never triggered");
        // keep-all fold: nothing evicted, serving unchanged
        assert_eq!(db.len(), 40);
        assert_eq!(db.best_config("t@Cpu", "d").unwrap().1, 40.0);
        // the live tail was swapped under the threshold at the last fold
        assert!(db.snapshot_gen().unwrap() >= 1);
        // the folded state round-trips through open
        let back = Database::open(&path).unwrap();
        assert_eq!(back.len(), 40);
        assert_eq!(back.best_config("t@Cpu", "d").unwrap().1, 40.0);
        // in-memory DBs ignore the knob entirely
        let mem = Database::new();
        mem.set_auto_compact_bytes(1);
        mem.append(mk(0, 1.0)).unwrap();
        assert_eq!(mem.auto_compactions(), 0);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(snapshot_path(&path));
    }

    /// Tentpole smoke: compaction folds the WAL into a snapshot + fresh
    /// marker-led tail; reopening loads snapshot-then-tail with
    /// identical serving answers, and a retention policy bounds the
    /// index while keeping best/top-k intact.
    #[test]
    fn compact_snapshot_roundtrip_and_retention() {
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("compact-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(snapshot_path(&path));
        let mk = |i: u32, g: f64| Record {
            task_key: "t@Cpu".into(),
            target: "d".into(),
            choices: vec![i],
            gflops: g,
            seconds: 0.1,
            error: None,
        };
        let db = Database::open(&path).unwrap();
        // descending gflops: top-k = the oldest records, newest = the
        // youngest — the retention union is exercised from both ends
        for i in 0..40u32 {
            db.append(mk(i, (100 - i) as f64)).unwrap();
        }
        let stats = db.compact(&RetentionPolicy::keep_all()).unwrap();
        assert_eq!((stats.gen, stats.kept, stats.dropped), (1, 40, 0));
        assert!(snapshot_path(&path).exists());
        // the fresh tail holds only the generation marker
        let tail = std::fs::read_to_string(&path).unwrap();
        assert_eq!(tail.lines().count(), 1, "tail still replays history");
        assert_eq!(wal_gen_of(&tail), Some(1));
        db.append(mk(40, 60.5)).unwrap();
        db.append(mk(41, 60.6)).unwrap();

        let before_best = db.best_config("t@Cpu", "d").unwrap();
        let before_top: Vec<f64> = db.top_k("t@Cpu", "d", TOP_K).iter().map(|r| r.1).collect();
        let back = Database::open(&path).unwrap();
        assert_eq!(back.len(), 42, "snapshot-then-tail load lost records");
        assert_eq!(back.best_config("t@Cpu", "d").unwrap().1, before_best.1);
        let back_top: Vec<f64> =
            back.top_k("t@Cpu", "d", TOP_K).iter().map(|r| r.1).collect();
        assert_eq!(back_top, before_top, "top-k diverged across compaction reload");

        // retention: top-16 (oldest) ∪ newest-4 = 20 records
        let stats = back.compact(&RetentionPolicy::newest(4)).unwrap();
        assert_eq!((stats.gen, stats.kept, stats.dropped), (2, 20, 22));
        assert_eq!(back.len(), 20);
        assert_eq!(back.best_config("t@Cpu", "d").unwrap().1, before_best.1);
        let kept_top: Vec<f64> =
            back.top_k("t@Cpu", "d", TOP_K).iter().map(|r| r.1).collect();
        assert_eq!(kept_top, before_top, "eviction disturbed the retained top-k");
        // and the evicted state round-trips through open again
        let again = Database::open(&path).unwrap();
        assert_eq!(again.len(), 20);
        assert_eq!(again.snapshot_gen(), Some(2));
        assert_eq!(
            again.top_k("t@Cpu", "d", TOP_K).iter().map(|r| r.1).collect::<Vec<_>>(),
            before_top
        );
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(snapshot_path(&path));
    }
}
