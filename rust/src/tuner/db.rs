//! TuningDb — the indexed, concurrent tuning-record service layer.
//!
//! The paper's headline transfer speedup (§4, Eq. 4) comes from reusing
//! the tuning log `D = {(e_i, s_i, c_i)}` across workloads, and the
//! graph compiler serves `argmax D` per task on its hot path. This
//! module is the record store behind both:
//!
//! * **Sharded index** — records live in per-`(task_key, target)`
//!   shards behind `N_SHARDS` bucket locks, so concurrent writers
//!   (the pipelined tuner's measurement stage) and readers (the graph
//!   compiler, warm-start queries) contend only when they touch the
//!   same bucket.
//! * **Incremental best / top-k** — every shard maintains its best
//!   valid record and a descending top-[`TOP_K`] list as records
//!   arrive, so [`TuningDb::best_config`] and [`TuningDb::top_k`] are
//!   O(1)/O(k) lookups, never scans ([`TuningDb::best_config_scan`] is
//!   the linear reference kept for tests and the `bench_db` baseline).
//!   Ordering uses `f64::total_cmp`; records with NaN/non-finite
//!   GFLOPS or an error are stored but never indexed as best.
//! * **Append-only WAL** — a file-backed DB ([`TuningDb::open`])
//!   appends one JSONL line per record as it is measured, so a crash
//!   loses at most the line being written; `open` tolerates (and
//!   drops) a torn trailing line, while any other malformed record is
//!   a hard parse error ([`Record::from_json`] is strict).
//! * **Per-task feature cache** — [`TuningDb::to_training`] memoizes
//!   lowered+extracted feature rows per `(shard, representation)`, so
//!   building `D'` for a transfer model re-featurizes only records it
//!   has never seen, instead of re-lowering the whole log every call.
//! * **Thread-safe handle** — [`TuningDb`] is a cheap `Arc` clone
//!   (`Send + Sync`); the tuner streams records in live through
//!   [`crate::tuner::DbSink`] while other threads query.
//!
//! Training sets are deterministic: tasks are visited in sorted-key
//! order, records in insertion order, and errored / non-finite /
//! unlowerable records are excluded from `D'`.

use crate::features::Representation;
use crate::gbt::Matrix;
use crate::schedule::space::ConfigEntity;
use crate::schedule::template::Task;
use crate::tuner::TrialRecord;
use crate::util::json::Json;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::hash::{Hash, Hasher};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Cap on the incrementally maintained per-task top-k index.
pub const TOP_K: usize = 16;

/// Lock buckets for the shard map.
const N_SHARDS: usize = 16;

/// One persisted measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    /// Task identity ([`Task::key`]).
    pub task_key: String,
    /// Target (device) the trial ran on.
    pub target: String,
    /// The measured config's knob choices.
    pub choices: Vec<u32>,
    /// Measured throughput (0.0 / non-finite for failed trials).
    pub gflops: f64,
    /// Measured wall-clock seconds (0.0 when unknown).
    pub seconds: f64,
    /// Failure reason, if the trial errored.
    pub error: Option<String>,
}

impl Record {
    /// Valid for serving / training: finished without error and with a
    /// finite throughput (a NaN gflops must never win `best_config`).
    fn is_valid(&self) -> bool {
        self.error.is_none() && self.gflops.is_finite()
    }

    fn to_json(&self) -> Json {
        // Non-finite floats have no JSON representation (`{x}` would
        // emit `NaN`, which the parser rejects) — serialize them as
        // null so a NaN record round-trips as an invalid-but-parseable
        // record instead of poisoning the WAL.
        let num_or_null = |x: f64| if x.is_finite() { Json::from(x) } else { Json::Null };
        let mut fields = vec![
            ("task", Json::from(self.task_key.clone())),
            ("target", Json::from(self.target.clone())),
            (
                "choices",
                Json::Arr(self.choices.iter().map(|&c| Json::from(c as u64)).collect()),
            ),
            ("gflops", num_or_null(self.gflops)),
            ("seconds", num_or_null(self.seconds)),
        ];
        if let Some(e) = &self.error {
            fields.push(("error", Json::from(e.clone())));
        }
        Json::obj(fields)
    }

    /// Strict parse: missing fields and malformed `choices` entries are
    /// errors, not silently-coerced zeros (a corrupt config replayed as
    /// `choices = [0, …]` would poison `D'` and the serving path).
    fn from_json(j: &Json) -> anyhow::Result<Record> {
        let get_str = |k: &str| -> anyhow::Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(String::from)
                .ok_or_else(|| anyhow::anyhow!("record missing {k}"))
        };
        let arr = j
            .get("choices")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("record missing choices"))?;
        let mut choices = Vec::with_capacity(arr.len());
        for v in arr {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric choices entry {}", v.dump()))?;
            anyhow::ensure!(
                x.fract() == 0.0 && x >= 0.0 && x <= u32::MAX as f64,
                "choices entry {x} is not a u32"
            );
            choices.push(x as u32);
        }
        let gflops = match j.get("gflops") {
            Some(Json::Null) => f64::NAN, // serialized non-finite value
            Some(v) => v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("record gflops is not a number"))?,
            None => anyhow::bail!("record missing gflops"),
        };
        let seconds = match j.get("seconds") {
            Some(Json::Null) => f64::NAN, // serialized non-finite value
            Some(v) => v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("record seconds is not a number"))?,
            None => 0.0,
        };
        Ok(Record {
            task_key: get_str("task")?,
            target: get_str("target")?,
            choices,
            gflops,
            seconds,
            error: j.get("error").and_then(Json::as_str).map(String::from),
        })
    }
}

/// Per-representation memo of feature rows: record index → extracted
/// row (`None` = the stored config does not lower under this task, e.g.
/// a foreign record; such rows are skipped when building `D'`).
type FeatureCache = HashMap<Representation, HashMap<usize, Option<Vec<f64>>>>;

/// All records of one `(task_key, target)` pair plus its incremental
/// serving indexes and feature cache.
#[derive(Default)]
struct TaskShard {
    records: Vec<Record>,
    /// `(record index, gflops)` of the best valid record — O(1) serving.
    best: Option<(usize, f64)>,
    /// Valid records by descending gflops (ties: earliest first), at
    /// most [`TOP_K`] entries.
    top_k: Vec<(usize, f64)>,
    feat_cache: FeatureCache,
}

impl TaskShard {
    fn insert(&mut self, rec: Record) {
        let idx = self.records.len();
        let valid = rec.is_valid();
        let g = rec.gflops;
        self.records.push(rec);
        if !valid {
            return;
        }
        // NaN-safe ordering: f64::total_cmp (non-finite never reaches
        // here, so total order == numeric order).
        if self
            .best
            .map_or(true, |(_, bg)| g.total_cmp(&bg) == std::cmp::Ordering::Greater)
        {
            self.best = Some((idx, g));
        }
        let pos = self
            .top_k
            .partition_point(|&(_, tg)| tg.total_cmp(&g) != std::cmp::Ordering::Less);
        if pos < TOP_K {
            self.top_k.insert(pos, (idx, g));
            self.top_k.truncate(TOP_K);
        }
    }
}

type ShardKey = (String, String); // (task_key, target)

struct DbInner {
    shards: Vec<Mutex<HashMap<ShardKey, TaskShard>>>,
    /// Append-only JSONL write-ahead log (file-backed DBs only). Held
    /// across the index update so file order matches insertion order.
    wal: Mutex<Option<File>>,
    /// Fast-path flag mirroring `wal.is_some()`: in-memory DBs skip the
    /// global WAL lock entirely, so their writers contend only on the
    /// touched shard bucket (the concurrency the sharding exists for).
    wal_enabled: AtomicBool,
    len: AtomicUsize,
}

/// The unparseable fragment a crashed append leaves after the last
/// newline, if any. A complete (newline-terminated) malformed line is
/// NOT a torn tail — that is real corruption and stays a hard error.
fn torn_tail(text: &str) -> Option<&str> {
    let tail = match text.rfind('\n') {
        Some(i) => &text[i + 1..],
        None => text,
    };
    if tail.trim().is_empty() {
        return None;
    }
    match Json::parse(tail).and_then(|j| Record::from_json(&j)) {
        Ok(_) => None,
        Err(_) => Some(tail),
    }
}

fn shard_idx(task_key: &str, target: &str) -> usize {
    let mut h = DefaultHasher::new();
    task_key.hash(&mut h);
    target.hash(&mut h);
    (h.finish() as usize) % N_SHARDS
}

/// The tuning-DB service handle: a cheap `Arc` clone, `Send + Sync`.
/// See the module docs for the index / WAL / cache layout.
#[derive(Clone)]
pub struct TuningDb {
    inner: Arc<DbInner>,
}

/// Historical name of the record store (pre-service-layer); kept as an
/// alias so experiment drivers and tests read naturally.
pub type Database = TuningDb;

impl Default for TuningDb {
    fn default() -> Self {
        TuningDb::new()
    }
}

impl std::fmt::Debug for TuningDb {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TuningDb").field("records", &self.len()).finish()
    }
}

impl TuningDb {
    /// Fresh in-memory DB (no WAL).
    pub fn new() -> Self {
        TuningDb {
            inner: Arc::new(DbInner {
                shards: (0..N_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
                wal: Mutex::new(None),
                wal_enabled: AtomicBool::new(false),
                len: AtomicUsize::new(0),
            }),
        }
    }

    /// Open (or create) a WAL-backed DB at `path`: existing records are
    /// loaded and indexed, and every subsequent [`append`](Self::append)
    /// is written through to the file immediately. A torn trailing line
    /// (crash mid-append, i.e. an unparseable fragment after the last
    /// newline) is dropped AND truncated from the file — so the next
    /// append starts on a clean line instead of concatenating onto the
    /// fragment. Any other malformed record is a hard error.
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<TuningDb> {
        let path = path.as_ref();
        let db = TuningDb::new();
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            let valid = match torn_tail(&text) {
                Some(tail) => {
                    eprintln!(
                        "tuning-db: truncating torn trailing WAL line ({} bytes)",
                        tail.len()
                    );
                    // In-place truncation to the last newline: the valid
                    // prefix is never rewritten, so a crash during
                    // recovery cannot lose durably-appended records.
                    let keep = text.len() - tail.len();
                    OpenOptions::new().write(true).open(path)?.set_len(keep as u64)?;
                    &text[..keep]
                }
                None => {
                    if !text.is_empty() && !text.ends_with('\n') {
                        // Valid but unterminated last line: append the
                        // missing newline so the next record doesn't
                        // merge with it (append-only, crash-safe).
                        OpenOptions::new().append(true).open(path)?.write_all(b"\n")?;
                    }
                    text.as_str()
                }
            };
            db.load_lines(valid)?;
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        *db.inner.wal.lock().unwrap() = Some(file);
        db.inner.wal_enabled.store(true, Ordering::Release);
        Ok(db)
    }

    /// Load a JSONL log into an in-memory DB (strict: every line must
    /// parse). Use [`open`](Self::open) for the live service path.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<TuningDb> {
        let db = TuningDb::new();
        db.load_lines(&std::fs::read_to_string(path)?)?;
        Ok(db)
    }

    fn load_lines(&self, text: &str) -> anyhow::Result<()> {
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            match Json::parse(line).and_then(|j| Record::from_json(&j)) {
                Ok(r) => self.insert(r),
                Err(e) => return Err(e.context(format!("tuning-db record on line {}", i + 1))),
            }
        }
        Ok(())
    }

    /// Index one record (no WAL write).
    fn insert(&self, rec: Record) {
        let b = shard_idx(&rec.task_key, &rec.target);
        let mut bucket = self.inner.shards[b].lock().unwrap();
        bucket
            .entry((rec.task_key.clone(), rec.target.clone()))
            .or_default()
            .insert(rec);
        self.inner.len.fetch_add(1, Ordering::SeqCst);
    }

    /// Append one record: crash-safe incremental WAL write (if
    /// file-backed) plus index update. Safe to call from any thread.
    ///
    /// The record is indexed in memory even when the WAL write fails
    /// (the error is still returned): the service keeps serving while
    /// persistence degrades. A failed write may leave a partial line on
    /// disk, so the file is truncated back to its pre-write length; if
    /// even that fails the WAL is disabled rather than risk mid-file
    /// corruption on the next append.
    pub fn append(&self, rec: Record) -> anyhow::Result<()> {
        // In-memory DBs never touch the WAL lock: writers to different
        // shards proceed fully in parallel.
        if !self.inner.wal_enabled.load(Ordering::Acquire) {
            self.insert(rec);
            return Ok(());
        }
        let mut wal = self.inner.wal.lock().unwrap();
        let mut wal_err: Option<std::io::Error> = None;
        let mut disable = false;
        if let Some(f) = wal.as_mut() {
            let mut line = rec.to_json().dump();
            line.push('\n');
            let prev_len = f.metadata().ok().map(|m| m.len());
            if let Err(e) = f.write_all(line.as_bytes()) {
                let repaired = prev_len.map_or(false, |p| f.set_len(p).is_ok());
                disable = !repaired;
                wal_err = Some(e);
            }
        }
        if disable {
            eprintln!(
                "tuning-db: WAL unrecoverable after failed write; disabling persistence"
            );
            *wal = None;
            self.inner.wal_enabled.store(false, Ordering::Release);
        }
        // Still under the WAL lock: file order == insertion order even
        // with concurrent appenders.
        self.insert(rec);
        match wal_err {
            Some(e) => Err(e.into()),
            None => Ok(()),
        }
    }

    /// Append the trials of one tuning run (bulk path; the live path is
    /// [`crate::tuner::DbSink`] streaming through [`append`](Self::append)).
    pub fn add_run(
        &self,
        task: &Task,
        target: &str,
        records: &[TrialRecord],
    ) -> anyhow::Result<()> {
        for r in records {
            self.append(Record {
                task_key: task.key(),
                target: target.to_string(),
                choices: r.entity.choices.clone(),
                gflops: r.gflops,
                seconds: r.seconds.unwrap_or(0.0),
                error: r.error.clone(),
            })?;
        }
        Ok(())
    }

    /// Total number of records across all shards.
    pub fn len(&self) -> usize {
        self.inner.len.load(Ordering::SeqCst)
    }

    /// Whether the DB holds no records at all.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deterministic snapshot of every record: shards in sorted
    /// `(task_key, target)` order, records in insertion order.
    pub fn records(&self) -> Vec<Record> {
        let mut groups: Vec<(ShardKey, Vec<Record>)> = Vec::new();
        for bucket in &self.inner.shards {
            let bucket = bucket.lock().unwrap();
            for (k, s) in bucket.iter() {
                groups.push((k.clone(), s.records.clone()));
            }
        }
        groups.sort_by(|a, b| a.0.cmp(&b.0));
        groups.into_iter().flat_map(|(_, r)| r).collect()
    }

    /// Export the whole DB as JSONL (for in-memory DBs; a file-backed
    /// DB's WAL is already on disk).
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut out = String::new();
        for r in self.records() {
            out.push_str(&r.to_json().dump());
            out.push('\n');
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// Records belonging to one task+target, in insertion order.
    pub fn for_task(&self, task_key: &str, target: &str) -> Vec<Record> {
        let bucket = self.inner.shards[shard_idx(task_key, target)].lock().unwrap();
        bucket
            .get(&(task_key.to_string(), target.to_string()))
            .map(|s| s.records.clone())
            .unwrap_or_default()
    }

    /// Sorted task keys with at least one record on `target`.
    pub fn task_keys(&self, target: &str) -> Vec<String> {
        let mut keys: Vec<String> = Vec::new();
        for bucket in &self.inner.shards {
            let bucket = bucket.lock().unwrap();
            for (k, _) in bucket.iter() {
                if k.1 == target {
                    keys.push(k.0.clone());
                }
            }
        }
        keys.sort();
        keys.dedup();
        keys
    }

    /// Best valid config per task — served from the incremental index
    /// in O(1), the graph-compiler hot path.
    pub fn best_config(&self, task_key: &str, target: &str) -> Option<(ConfigEntity, f64)> {
        let bucket = self.inner.shards[shard_idx(task_key, target)].lock().unwrap();
        let shard = bucket.get(&(task_key.to_string(), target.to_string()))?;
        let (idx, g) = shard.best?;
        Some((ConfigEntity { choices: shard.records[idx].choices.clone() }, g))
    }

    /// Linear-scan reference for [`best_config`](Self::best_config) —
    /// kept for tests and the `bench_db` indexed-vs-scan comparison.
    /// (On a tie the scan may return a different record than the index;
    /// the gflops value is always identical.)
    pub fn best_config_scan(
        &self,
        task_key: &str,
        target: &str,
    ) -> Option<(ConfigEntity, f64)> {
        let bucket = self.inner.shards[shard_idx(task_key, target)].lock().unwrap();
        let shard = bucket.get(&(task_key.to_string(), target.to_string()))?;
        shard
            .records
            .iter()
            .filter(|r| r.is_valid())
            .max_by(|a, b| a.gflops.total_cmp(&b.gflops))
            .map(|r| (ConfigEntity { choices: r.choices.clone() }, r.gflops))
    }

    /// Up to `k` best valid configs (descending gflops, ties earliest
    /// first) from the incremental index; `k` is capped at [`TOP_K`].
    pub fn top_k(&self, task_key: &str, target: &str, k: usize) -> Vec<(ConfigEntity, f64)> {
        let bucket = self.inner.shards[shard_idx(task_key, target)].lock().unwrap();
        let Some(shard) = bucket.get(&(task_key.to_string(), target.to_string())) else {
            return Vec::new();
        };
        shard
            .top_k
            .iter()
            .take(k)
            .map(|&(i, g)| (ConfigEntity { choices: shard.records[i].choices.clone() }, g))
            .collect()
    }

    /// Build a training set from source-domain records under an
    /// invariant representation — the `D'` featurization for the global
    /// model of Eq. 4. Tasks must be supplied so configs can be
    /// re-lowered; records for unknown tasks are skipped. Returns
    /// (features, labels-normalized-per-task, group sizes per task).
    ///
    /// Deterministic: tasks are visited in sorted-key order (duplicates
    /// dropped) and records in insertion order. Errored, non-finite and
    /// unlowerable records are excluded. Feature rows are memoized in
    /// the per-shard cache, so repeated calls only featurize records
    /// appended since the last call.
    ///
    /// Labels are normalized to relative throughput within each task
    /// (gflops / task max) so the global model learns *shape*, not
    /// absolute workload scale — with the rank objective only per-task
    /// order matters and tasks are separate rank groups.
    pub fn to_training(
        &self,
        tasks: &[&Task],
        target: &str,
        repr: Representation,
        limit_per_task: usize,
    ) -> (Matrix, Vec<f64>, Vec<usize>) {
        let mut sorted: Vec<&Task> = tasks.to_vec();
        sorted.sort_by_key(|t| t.key());
        sorted.dedup_by_key(|t| t.key());
        let mut rows: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        let mut groups: Vec<usize> = Vec::new();
        for task in sorted {
            let key = (task.key(), target.to_string());
            let bucket_idx = shard_idx(&key.0, target);
            // Phase 1 (locked, cheap): pick the valid records and find
            // which of them the feature cache is missing.
            let (sel, missing_idx, missing_ents) = {
                let mut bucket = self.inner.shards[bucket_idx].lock().unwrap();
                let Some(shard) = bucket.get_mut(&key) else { continue };
                let TaskShard { records, feat_cache, .. } = shard;
                let sel: Vec<usize> = records
                    .iter()
                    .enumerate()
                    .filter(|(_, r)| r.is_valid())
                    .map(|(i, _)| i)
                    .take(limit_per_task)
                    .collect();
                if sel.is_empty() {
                    continue;
                }
                let cache = feat_cache.entry(repr).or_default();
                let mut missing_idx: Vec<usize> = Vec::new();
                let mut missing_ents: Vec<ConfigEntity> = Vec::new();
                for &i in sel.iter().filter(|i| !cache.contains_key(*i)) {
                    // stale/foreign configs that don't index into this
                    // build's space are excluded from D', not lowered
                    // (lowering them would panic)
                    if task.space.contains_choices(&records[i].choices) {
                        missing_idx.push(i);
                        missing_ents.push(ConfigEntity {
                            choices: records[i].choices.clone(),
                        });
                    } else {
                        cache.insert(i, None);
                    }
                }
                (sel, missing_idx, missing_ents)
            };
            // Phase 2 (no locks): the expensive lower+analyze+extract —
            // writers streaming into this shard are not stalled. Records
            // are append-only, so the selected indices stay valid.
            let computed = if missing_ents.is_empty() {
                Vec::new()
            } else {
                crate::features::featurize_batch(repr, task, &missing_ents)
            };
            // Phase 3 (locked, cheap): install the new cache rows, then
            // emit the training rows in selection order.
            let mut bucket = self.inner.shards[bucket_idx].lock().unwrap();
            let Some(shard) = bucket.get_mut(&key) else { continue };
            let TaskShard { records, feat_cache, .. } = shard;
            let cache = feat_cache.entry(repr).or_default();
            for (i, f) in missing_idx.into_iter().zip(computed) {
                cache.insert(i, f);
            }
            let mut task_rows: Vec<(Vec<f64>, f64)> = Vec::new();
            for &i in &sel {
                if let Some(Some(f)) = cache.get(&i) {
                    task_rows.push((f.clone(), records[i].gflops));
                }
            }
            if task_rows.is_empty() {
                continue;
            }
            let max_g = task_rows.iter().map(|(_, g)| *g).fold(f64::MIN_POSITIVE, f64::max);
            groups.push(task_rows.len());
            for (f, g) in task_rows {
                rows.push(f);
                ys.push(g / max_g);
            }
        }
        (Matrix::from_rows(&rows), ys, groups)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::ops;
    use crate::measure::{Measurer, SimMeasurer};
    use crate::schedule::template::TemplateKind;
    use crate::sim::devices::sim_cpu;
    use crate::util::Rng;

    fn sample_records(task: &Task, n: usize) -> Vec<TrialRecord> {
        let m = SimMeasurer::with_seed(sim_cpu(), 1);
        let mut rng = Rng::seed_from_u64(2);
        let batch: Vec<ConfigEntity> =
            (0..n).map(|_| task.space.sample(&mut rng)).collect();
        let res = m.measure(task, &batch);
        batch
            .into_iter()
            .zip(res)
            .map(|(e, r)| TrialRecord {
                entity: e,
                gflops: r.gflops,
                seconds: r.seconds,
                error: r.error,
            })
            .collect()
    }

    #[test]
    fn save_load_roundtrip() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        db.add_run(&task, "sim-cpu", &sample_records(&task, 20)).unwrap();
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.jsonl");
        db.save(&path).unwrap();
        let back = Database::load(&path).unwrap();
        assert_eq!(db.records(), back.records());
        assert_eq!(db.len(), back.len());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn best_config_skips_errors() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        let mut recs = sample_records(&task, 10);
        // poison: an error record with absurd gflops must not win
        recs.push(TrialRecord {
            entity: task.space.entity(0),
            gflops: 1e12,
            seconds: None,
            error: Some("boom".into()),
        });
        db.add_run(&task, "sim-cpu", &recs).unwrap();
        let (_, g) = db.best_config(&task.key(), "sim-cpu").unwrap();
        assert!(g < 1e12);
    }

    /// Regression (satellite): a NaN gflops record used to panic
    /// `best_config` via `partial_cmp().unwrap()`; now ordering is
    /// `total_cmp` and non-finite records never enter the index.
    #[test]
    fn best_config_nan_safe() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        let mut recs = sample_records(&task, 8);
        recs.push(TrialRecord {
            entity: task.space.entity(1),
            gflops: f64::NAN,
            seconds: None,
            error: None,
        });
        db.add_run(&task, "sim-cpu", &recs).unwrap();
        let (_, g) = db.best_config(&task.key(), "sim-cpu").unwrap();
        assert!(g.is_finite(), "NaN record won the serving path");
        // index agrees with the linear scan
        let (_, gs) = db.best_config_scan(&task.key(), "sim-cpu").unwrap();
        assert_eq!(g, gs);
        // a shard with only a NaN record serves nothing
        let db2 = Database::new();
        db2.add_run(
            &task,
            "sim-cpu",
            &[TrialRecord {
                entity: task.space.entity(1),
                gflops: f64::NAN,
                seconds: None,
                error: None,
            }],
        )
        .unwrap();
        assert!(db2.best_config(&task.key(), "sim-cpu").is_none());
    }

    #[test]
    fn top_k_is_sorted_and_capped() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        db.add_run(&task, "sim-cpu", &sample_records(&task, 40)).unwrap();
        let top = db.top_k(&task.key(), "sim-cpu", 64);
        assert!(top.len() <= TOP_K);
        assert!(!top.is_empty());
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1, "top-k not descending");
        }
        let (_, best) = db.best_config(&task.key(), "sim-cpu").unwrap();
        assert_eq!(top[0].1, best);
        // a k below the cap truncates
        assert_eq!(db.top_k(&task.key(), "sim-cpu", 3).len(), 3.min(top.len()));
    }

    #[test]
    fn to_training_builds_invariant_features() {
        let t1 = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let t2 = Task::new(
            ops::conv2d(ops::Conv2dParams {
                n: 1, h: 14, w: 14, ic: 16, oc: 16, kh: 3, kw: 3, stride: 1, pad: 1,
            }),
            TemplateKind::Cpu,
        );
        let db = Database::new();
        let r1 = sample_records(&t1, 12);
        let r2 = sample_records(&t2, 12);
        let ok1 = r1.iter().filter(|r| r.error.is_none()).count();
        let ok2 = r2.iter().filter(|r| r.error.is_none()).count();
        db.add_run(&t1, "sim-cpu", &r1).unwrap();
        db.add_run(&t2, "sim-cpu", &r2).unwrap();
        let (x, y, groups) = db.to_training(
            &[&t1, &t2],
            "sim-cpu",
            Representation::ContextRelation,
            100,
        );
        // errored trials are filtered out of D'
        assert_eq!(x.rows, ok1 + ok2);
        assert_eq!(x.cols, Representation::ContextRelation.dim());
        assert_eq!(groups.iter().sum::<usize>(), ok1 + ok2);
        // labels normalized per task
        assert!(y.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    /// Stale/foreign records whose choices don't index into this
    /// build's space must be skipped by `to_training` — not lowered
    /// (which would panic in `instantiate`).
    #[test]
    fn to_training_skips_out_of_space_records() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let db = Database::new();
        let recs = sample_records(&task, 6);
        let ok = recs.iter().filter(|r| r.error.is_none()).count();
        db.add_run(&task, "sim-cpu", &recs).unwrap();
        // wrong arity (too few knobs) and out-of-range option index
        for choices in [vec![0u32], vec![u32::MAX; task.space.num_knobs()]] {
            db.append(Record {
                task_key: task.key(),
                target: "sim-cpu".into(),
                choices,
                gflops: 5.0,
                seconds: 0.1,
                error: None,
            })
            .unwrap();
        }
        let (x, _, groups) =
            db.to_training(&[&task], "sim-cpu", Representation::ContextRelation, 100);
        assert_eq!(x.rows, ok, "poisoned records must be excluded from D'");
        assert_eq!(groups.iter().sum::<usize>(), ok);
    }

    /// Satellite regression: the training set must not depend on caller
    /// task order (the old HashMap iteration made row order vary
    /// run-to-run) and the cached second call must equal the first.
    #[test]
    fn to_training_is_deterministic_and_cached() {
        let t1 = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let t2 = Task::new(ops::matmul(32, 32, 32), TemplateKind::Cpu);
        let db = Database::new();
        db.add_run(&t1, "sim-cpu", &sample_records(&t1, 10)).unwrap();
        db.add_run(&t2, "sim-cpu", &sample_records(&t2, 10)).unwrap();
        let (xa, ya, ga) =
            db.to_training(&[&t1, &t2], "sim-cpu", Representation::ContextRelation, 100);
        // reversed task order: identical output (sorted-key iteration)
        let (xb, yb, gb) =
            db.to_training(&[&t2, &t1], "sim-cpu", Representation::ContextRelation, 100);
        assert_eq!(xa.data, xb.data);
        assert_eq!(ya, yb);
        assert_eq!(ga, gb);
        // third call is served from the feature cache — same result
        let (xc, yc, gc) =
            db.to_training(&[&t1, &t2], "sim-cpu", Representation::ContextRelation, 100);
        assert_eq!(xa.data, xc.data);
        assert_eq!(ya, yc);
        assert_eq!(ga, gc);
        // duplicate tasks don't duplicate groups
        let (xd, _, gd) =
            db.to_training(&[&t1, &t1, &t2], "sim-cpu", Representation::ContextRelation, 100);
        assert_eq!(xd.rows, xa.rows);
        assert_eq!(gd, ga);
    }

    /// Satellite regression: malformed `choices` entries used to be
    /// silently coerced to 0; now they are parse errors. A torn
    /// trailing WAL line is tolerated by `open` only.
    #[test]
    fn strict_parse_rejects_malformed_records() {
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let good = r#"{"task":"t@Cpu","target":"d","choices":[1,2],"gflops":5,"seconds":0.1}"#;
        let bad = r#"{"task":"t@Cpu","target":"d","choices":[1,"x"],"gflops":5,"seconds":0.1}"#;

        let path = dir.join("strict-mid.jsonl");
        std::fs::write(&path, format!("{bad}\n{good}\n")).unwrap();
        assert!(Database::load(&path).is_err(), "malformed choices must not parse");
        assert!(Database::open(&path).is_err(), "mid-file corruption is fatal");
        let _ = std::fs::remove_file(&path);

        let path = dir.join("strict-missing.jsonl");
        std::fs::write(&path, r#"{"task":"t@Cpu","target":"d","gflops":5}"#).unwrap();
        assert!(Database::load(&path).is_err(), "missing choices must not parse");
        let _ = std::fs::remove_file(&path);

        // torn trailing line: open() truncates it from the file (so the
        // next append starts clean), load() rejects it
        let path = dir.join("torn.jsonl");
        std::fs::write(&path, format!("{good}\n{{\"task\":\"t@C")).unwrap();
        assert!(Database::load(&path).is_err());
        {
            let db = Database::open(&path).unwrap();
            assert_eq!(db.len(), 1);
            // appending after a torn tail must not concatenate onto the
            // truncated fragment
            db.append(Record {
                task_key: "t@Cpu".into(),
                target: "d".into(),
                choices: vec![3, 4],
                gflops: 7.0,
                seconds: 0.2,
                error: None,
            })
            .unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.len(), 2, "WAL corrupted by append-after-torn-tail");
        assert!(Database::load(&path).is_ok(), "WAL no longer strictly parseable");
        let _ = std::fs::remove_file(&path);

        // a valid but newline-unterminated last line is terminated on
        // open, so the next append starts on its own line
        let path = dir.join("unterminated.jsonl");
        std::fs::write(&path, good).unwrap(); // no trailing newline
        {
            let db = Database::open(&path).unwrap();
            assert_eq!(db.len(), 1);
            db.append(Record {
                task_key: "t@Cpu".into(),
                target: "d".into(),
                choices: vec![5],
                gflops: 1.0,
                seconds: 0.1,
                error: None,
            })
            .unwrap();
        }
        assert_eq!(Database::open(&path).unwrap().len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    /// Regression: a non-finite gflops used to serialize as `NaN`,
    /// which the JSON parser rejects — poisoning the WAL. It now
    /// round-trips as null → NaN (still invalid for serving).
    #[test]
    fn nan_record_roundtrips_through_wal() {
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("nan-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let db = Database::open(&path).unwrap();
            db.append(Record {
                task_key: "t@Cpu".into(),
                target: "d".into(),
                choices: vec![1],
                gflops: f64::NAN,
                seconds: 0.1,
                error: None,
            })
            .unwrap();
            db.append(Record {
                task_key: "t@Cpu".into(),
                target: "d".into(),
                choices: vec![2],
                gflops: 5.0,
                seconds: 0.1,
                error: None,
            })
            .unwrap();
        }
        let back = Database::open(&path).unwrap();
        assert_eq!(back.len(), 2, "NaN record poisoned the WAL");
        let recs = back.for_task("t@Cpu", "d");
        assert!(recs[0].gflops.is_nan());
        // the NaN record is stored but never served
        assert_eq!(back.best_config("t@Cpu", "d").unwrap().1, 5.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn wal_appends_survive_reopen() {
        let dir = std::env::temp_dir().join("autotvm-test-db");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("wal-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let recs = sample_records(&task, 6);
        {
            let db = Database::open(&path).unwrap();
            db.add_run(&task, "sim-cpu", &recs[..4]).unwrap();
            assert_eq!(db.len(), 4);
        } // drop: no explicit save — the WAL is the persistence
        {
            let db = Database::open(&path).unwrap();
            assert_eq!(db.len(), 4, "WAL records lost across reopen");
            db.add_run(&task, "sim-cpu", &recs[4..]).unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.len(), 6, "reopen must append, not clobber");
        assert_eq!(db.for_task(&task.key(), "sim-cpu").len(), 6);
        let _ = std::fs::remove_file(&path);
    }
}
